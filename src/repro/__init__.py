"""repro: SimpleFSDP (Zhang et al., 2024) as a production JAX framework.

Compiler-based Fully Sharded Data Parallel with full-graph tracing,
communication bucketing + reordering, manual/auto wrapping, and TP/EP/PP/SP
composition — targeting multi-pod TPU v5e meshes. See DESIGN.md.
"""

__version__ = "1.0.0"
