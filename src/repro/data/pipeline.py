"""Deterministic synthetic C4-like token pipeline.

Production shape without the dataset gate: a seeded Zipf-ish sampler emits
packed documents (BOS/EOS delimited) so the stream has realistic token
statistics; every (seed, step, dp_rank) triple is reproducible, which the
fault-tolerance tests rely on (bit-exact resume). Batches are generated
host-side per data-parallel rank and prefetched on a background thread.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    bos: int = 1
    eos: int = 2


class SyntheticC4:
    """Stateless per-step batch synthesis: batch(step) is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf-ish unigram distribution over the vocab (heavy head like C4)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = (p / p.sum()).astype(np.float64)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        B, S = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab, size=(B, S + 1), p=self._p) \
            .astype(np.int32)
        toks = np.maximum(toks, 3)                # reserve specials
        # doc boundaries: geometric lengths, packed
        n_docs = max(1, (S + 1) // cfg.mean_doc_len)
        for b in range(B):
            cuts = rng.integers(1, S, size=n_docs)
            toks[b, cuts] = cfg.eos
        toks[:, 0] = cfg.bos
        tokens, targets = toks[:, :-1], toks[:, 1:]
        valid = (targets != cfg.bos).astype(np.float32)
        return {"tokens": tokens, "targets": np.ascontiguousarray(targets),
                "valid": valid}


def adapt_batch(base: dict, specs: dict, step: int, seed: int = 0) -> dict:
    """Fit a SyntheticC4 token batch to a model's `input_specs`.

    Token-shaped fields (tokens/targets/valid) are CROPPED from the base
    batch (models like the VLM or the enc-dec reserve part of the sequence
    budget for the modality stream, so their text spans are shorter);
    non-token float fields (img_embeds, frames) are synthesized from a
    seeded rng — deterministic per (seed, step), like the token stream.
    """
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, 0x5eed]))
    out = {}
    for k, sd in specs.items():
        if k in base:
            a = base[k]
            if a.ndim != len(sd.shape) or any(
                    have < want for have, want in zip(a.shape, sd.shape)):
                raise ValueError(
                    f"batch field {k!r}: base {a.shape} cannot cover "
                    f"spec {sd.shape}")
            out[k] = np.ascontiguousarray(
                a[tuple(slice(0, n) for n in sd.shape)])
        elif np.issubdtype(np.dtype(sd.dtype), np.integer):
            out[k] = rng.integers(3, 100, size=sd.shape).astype(sd.dtype)
        else:
            out[k] = (rng.standard_normal(sd.shape) * 0.3).astype(sd.dtype)
    return out


class Prefetcher:
    """Background-thread batch prefetch with bounded queue."""

    def __init__(self, ds: SyntheticC4, start_step: int = 0, depth: int = 2):
        self._ds = ds
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._work, daemon=True)
        self._t.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._ds.batch(step)), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._t.join(timeout=2)
