"""Training step assembly: SimpleFSDP forward/backward + gradient
accumulation (microbatches) + clipping + AdamW + LR schedule, all inside one
shard_map'd jit — the "full computation-communication graph" the paper traces.

Two families, one front door (`wrap_any_train_step` / `wrap_loss_step`,
driven by `core/api.parallelize` off the resolved `ParallelPlan`):

  * pp = 1 — the whole-model step (`make_train_step`): microbatch scan +
    AdamW on the plain storage layout.
  * pp > 1 — the STAGED step (`make_staged_train_step`): storage is
    stage-stacked (models/staging.py), each pipe rank trains its stage
    slice through `core/pipeline`'s GPipe/1F1B schedules using the model's
    stage-partition contract (stage_pre / stage_blocks / stage_loss); the
    batch splits into `plan.microbatches` microbatches, stage-replicated
    groups (StageSpec.replicated_keys) get their grads psum'ed over the
    pipe axis, and AdamW runs on each rank's own stage shards — all still
    one shard_map'd jit (FSDP gathers AND pipeline sends in one graph).

`make_pipeline_train_step` (bring-your-own `stage_fn`/`stage_metas`)
remains for explicitly staged synthetic modules (benchmarks,
dist_harness `pipeline`).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.dist import DistConfig, make_mesh
from repro.models import runtime as RT
from repro.optim.adamw import AdamWConfig, apply_adamw, init_opt_state
from repro.optim.schedule import warmup_cosine


def step_wire_metrics(model, plan) -> dict:
    """Per-step collective wire-byte accounting by comm precision, straight
    from the plan's own bucket groups and precision assignments — the
    numbers `Trainer` mirrors into `train/wire_bytes/<prec>` counters each
    step.  Host math only (no tracing): {"total_bytes", "by_precision"}."""
    from repro.core.autowrap import _cfg_precision
    from repro.core.irgraph import build_nodes

    dcfg = plan.dcfg
    metas = model.metas(dcfg)
    by_prec: dict[str, float] = {}
    total = 0.0
    for key, bplan in plan.bucket_plans.items():
        if key not in metas:
            continue
        nodes = {n.name: n for n in build_nodes(metas[key], dcfg, None)}
        precs = bplan.precisions or \
            [_cfg_precision(dcfg)] * len(bplan.groups)
        mult = max(1, plan.stacked_keys.get(key, 1))
        for grp, prec in zip(bplan.groups, precs):
            wire = sum(nodes[n].ag_wire(prec) + nodes[n].rs_wire(prec)
                       for n in grp if n in nodes) * mult
            by_prec[prec] = by_prec.get(prec, 0.0) + wire
            total += wire
    return {"total_bytes": total, "by_precision": by_prec}


def _opt_specs(pspecs, dcfg: DistConfig):
    """Optimizer-state specs: moments mirror the params; the error-feedback
    accumulator (quantized-RS configs, `DistConfig.needs_ef`) is
    storage-shaped too."""
    specs = {"m": pspecs, "v": pspecs, "step": P()}
    if dcfg.needs_ef:
        specs["ef"] = pspecs
    return specs


def _opt_local(opt_state, local):
    """Strip the leading stage dim off every storage-shaped entry."""
    return {k: (v if k == "step" else local(v)) for k, v in opt_state.items()}


def make_train_step(model, dcfg: DistConfig, ocfg: AdamWConfig,
                    schedule: Callable | None = None):
    """Returns step_local(storage, opt_state, batch) -> (storage, opt_state,
    metrics); run it inside shard_map via `wrap_train_step`."""
    metas = model.metas(dcfg)
    sched = schedule or (lambda t: ocfg.lr)

    def loss_of(storage, mb):
        return model.loss_local(storage, mb, dcfg)[0]

    def step_local(storage, opt_state, batch):
        k = dcfg.microbatches
        if k > 1:
            split = jax.tree.map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)
            mb0 = jax.tree.map(lambda x: x[0], split)
            # peel microbatch 0 so the accumulator carry has real vma types
            loss, grads = jax.value_and_grad(loss_of)(storage, mb0)

            def body(carry, mb):
                acc_l, acc_g = carry
                l, g = jax.value_and_grad(loss_of)(storage, mb)
                return (acc_l + l, jax.tree.map(jnp.add, acc_g, g)), None

            rest = jax.tree.map(lambda x: x[1:], split)
            (loss, grads), _ = lax.scan(body, (loss, grads), rest)
            inv = 1.0 / k
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(storage, batch)

        lr = sched(opt_state["step"])
        new_p, new_opt, gnorm = apply_adamw(storage, grads, opt_state,
                                            metas, dcfg, ocfg, lr)
        metrics = {
            "loss": lax.pmean(loss, dcfg.mesh_axes) * dcfg.tp_size,
            "grad_norm": gnorm,
            "lr": jnp.asarray(lr, jnp.float32),
        }
        return new_p, new_opt, metrics

    return step_local


def wrap_train_step(model, dcfg: DistConfig, shape, ocfg: AdamWConfig,
                    schedule=None, mesh=None, donate: bool = True):
    """jit(shard_map(train_step)) with the full in/out sharding specs."""
    mesh = mesh or make_mesh(dcfg)
    step_local = make_train_step(model, dcfg, ocfg, schedule)
    pspecs = RT.model_storage_specs(model, dcfg)
    opt_specs = _opt_specs(pspecs, dcfg)
    in_specs = (pspecs, opt_specs, RT.batch_specs(model, shape, dcfg))
    out_specs = (pspecs, opt_specs,
                 {"loss": P(), "grad_norm": P(), "lr": P()})
    fn = shard_map(step_local, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs)
    return jax.jit(fn, donate_argnums=(0, 1) if donate else ()), mesh


# ---------------------------------------------------------------------------
# Staged full-model training (paper SS4 x the stage-partition contract):
# the model's own embedding/blocks/head partitioned across the pipe axis.
# ---------------------------------------------------------------------------
def _staged_pieces(model, plan, dcfg: DistConfig):
    """The pre_fn/stage_step/chunk_step/loss_fn quadruple + state template
    builder for the model contract (see core/pipeline module docstring).

    `pre_fn` is the hoisted stage-0 entry stream: `model.stage_pre`
    (including encdec's ENTIRE encoder) is traced exactly once per step —
    a single `lax.map` over the M microbatches — instead of once per
    pipeline slot; the engines thread the per-microbatch entry states (and
    their cotangents) through the scan carry."""
    from jax import lax as _lax

    spec = plan.stage
    M = plan.microbatches
    bplan = plan.bucket_plan(spec.pipelined)

    def pre_fn(params, mbs):
        return _lax.map(lambda mb: model.stage_pre(params, mb, dcfg), mbs)

    def stage_step(params, state, mb, pre):
        # every rank ran the (SPMD-uniform) entry stream via pre_fn; only
        # rank 0 keeps it — others pass the piped state through
        rank0 = _lax.axis_index(dcfg.pp_axis) == 0
        state = jax.tree.map(lambda a, b: jnp.where(rank0, a, b),
                             pre, state)
        return model.stage_blocks(params, state, dcfg, plan=bplan)

    def chunk_step(params, chunk, state, mb, pre):
        # interleaved: the pipelined stack is laid out (V, Lp/V, ...) per
        # rank; virtual stage j = chunk*S + rank runs chunk's layer slice.
        # The entry state injects at virtual stage 0 = (rank 0, chunk 0).
        inject = (_lax.axis_index(dcfg.pp_axis) == 0) & (chunk == 0)
        state = jax.tree.map(lambda a, b: jnp.where(inject, a, b),
                             pre, state)
        sliced = dict(params)
        sliced[spec.pipelined] = jax.tree.map(
            lambda a: _lax.dynamic_index_in_dim(a, chunk, axis=0,
                                                keepdims=False),
            params[spec.pipelined])
        return model.stage_blocks(sliced, state, dcfg, plan=bplan)

    def loss_fn(params, y, mb):
        # per-microbatch contribution; 1/M makes the total the local mean
        return model.stage_loss(params, y, mb, dcfg) / M

    def state_template(params, mb0):
        # zeros_like of a traced stage_pre: only shapes/dtypes survive (the
        # computation is dead-code-eliminated), no eval_shape needed
        return jax.tree.map(jnp.zeros_like,
                            model.stage_pre(params, mb0, dcfg))

    return pre_fn, stage_step, chunk_step, loss_fn, state_template


def _split_microbatches(batch, m: int):
    def one(x):
        if x.shape[0] % m:
            raise ValueError(
                f"local batch {x.shape[0]} does not split into {m} "
                "pipeline microbatches; adjust global_batch or "
                "pp_microbatches")
        return x.reshape(m, x.shape[0] // m, *x.shape[1:])
    return jax.tree.map(one, batch)


def _materialize_fn(model, plan, dcfg: DistConfig):
    """(stage-LOCAL storage) -> storage with pipe-SHARDED pre/post groups
    re-assembled into full FSDP chunks (ONE pipe-axis all-gather per group
    per step; models/staging.py).  Differentiated with jax.vjp around the
    whole pipeline engine, so the transpose is the matching psum-scatter —
    non-consuming ranks contribute exact-zero cotangents by schedule
    masking, keeping pp parity exact."""
    from repro.core import collectives as coll
    from repro.models import staging

    sharded = staging.pipe_sharded_groups(model, dcfg, plan.stage)

    def materialize(local):
        out = dict(local)
        for k in sharded:
            out[k] = jax.tree.map(
                lambda a: coll.pipe_param_gather(a, dcfg.pp_axis,
                                                 dcfg.pp_size),
                local[k])
        return out

    return materialize


def _staged_loss_grads_fn(model, plan, dcfg: DistConfig):
    """The shared staged core: (stage-LOCAL storage, batch) ->
    (total loss, stage grads with replicated groups psum'ed over pipe).

    Routes the plan-resolved schedule (dcfg here is plan.exec_dcfg, which
    carries the scored pp_schedule/pp_virtual write-back)."""
    from repro.core.pipeline import pipeline_loss_grads

    spec = plan.stage
    pre_fn, stage_step, chunk_step, loss_fn, state_template = \
        _staged_pieces(model, plan, dcfg)
    materialize = _materialize_fn(model, plan, dcfg)

    def loss_grads(local, batch):
        mbs = _split_microbatches(batch, plan.microbatches)
        full, mat_vjp = jax.vjp(materialize, local)
        state0 = state_template(full, jax.tree.map(lambda a: a[0], mbs))
        loss, grads, _ = pipeline_loss_grads(
            stage_step, loss_fn, full, mbs, state0, dcfg,
            pre_fn=pre_fn,
            chunk_step=chunk_step if spec.virtual > 1 else None)
        (grads,) = mat_vjp(grads)
        for k in spec.replicated_keys:
            grads[k] = jax.tree.map(lambda g: lax.psum(g, dcfg.pp_axis),
                                    grads[k])
        return loss, grads

    return loss_grads


def make_staged_loss_step(model, plan, dcfg: DistConfig,
                          with_grads: bool = True):
    """step(staged_storage, batch) -> (loss, staged_grads?) under pp."""
    from repro.core.pipeline import gpipe_loss

    spec = plan.stage
    loss_grads = _staged_loss_grads_fn(model, plan, dcfg)
    pre_fn, stage_step, _, loss_fn, state_template = \
        _staged_pieces(model, plan, dcfg)
    materialize = _materialize_fn(model, plan, dcfg)

    def step(staged, batch):
        local = jax.tree.map(lambda a: a[0], staged)   # this rank's stage
        if with_grads or spec.virtual > 1:
            # interleaved lays the stack out in virtual chunks, which the
            # plain forward-only gpipe stream cannot traverse — reuse the
            # full engine and drop the grads for eval
            loss, grads = loss_grads(local, batch)
        else:
            mbs = _split_microbatches(batch, plan.microbatches)
            full = materialize(local)
            state0 = state_template(full,
                                    jax.tree.map(lambda a: a[0], mbs))
            loss = gpipe_loss(stage_step, loss_fn, full, mbs, state0,
                              dcfg.pp_size, dcfg.pp_axis, pre_fn=pre_fn)
        loss = lax.pmean(loss, dcfg.mesh_axes) * dcfg.tp_size
        if not with_grads:
            return loss
        return loss, jax.tree.map(lambda g: g[None], grads)

    return step


def make_staged_train_step(model, plan, dcfg: DistConfig, ocfg: AdamWConfig,
                           schedule: Callable | None = None):
    """Staged analogue of `make_train_step`: pipeline schedule + AdamW on
    each rank's stage shards, stage-replicated grads psum'ed over pipe."""
    spec = plan.stage
    metas = model.metas(dcfg)
    sched = schedule or (lambda t: ocfg.lr)
    loss_grads = _staged_loss_grads_fn(model, plan, dcfg)

    def _local(tree):
        return jax.tree.map(lambda a: a[0], tree)

    def _restack(tree):
        return jax.tree.map(lambda a: a[None], tree)

    def step_local(staged, opt_state, batch):
        local = _local(staged)
        opt_local = _opt_local(opt_state, _local)
        loss, grads = loss_grads(local, batch)
        lr = sched(opt_local["step"])
        new_p, new_opt, gnorm = apply_adamw(
            local, grads, opt_local, metas, dcfg, ocfg, lr,
            pp_replicated=spec.replicated_keys)
        metrics = {
            "loss": lax.pmean(loss, dcfg.mesh_axes) * dcfg.tp_size,
            "grad_norm": gnorm,
            "lr": jnp.asarray(lr, jnp.float32),
        }
        return _restack(new_p), _opt_local(new_opt, _restack), metrics

    return step_local


def _staged_specs(model, dcfg: DistConfig, spec=None):
    from repro.models import staging

    return staging.stage_storage_specs(model, dcfg, spec)


def wrap_loss_step(model, plan, dcfg: DistConfig, shape,
                   with_grads: bool = True, mesh=None):
    """jit(shard_map(step)): (storage, batch) -> loss | (loss, grads) —
    staged under plan.pipelined, the whole-model step otherwise."""
    mesh = mesh or make_mesh(dcfg)
    if not plan.pipelined:
        step = RT.make_loss_step(model, dcfg, with_grads=with_grads)
        pspecs = RT.model_storage_specs(model, dcfg)
        out_specs = (P(), pspecs) if with_grads else P()
        fn, _ = RT.wrap_step(model, dcfg, shape, step, out_specs, mesh=mesh)
        return fn
    pspecs = _staged_specs(model, dcfg, plan.stage)
    step = make_staged_loss_step(model, plan, dcfg, with_grads=with_grads)
    in_specs = (pspecs, RT.batch_specs(model, shape, dcfg))
    out_specs = (P(), pspecs) if with_grads else P()
    fn = shard_map(step, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def wrap_any_train_step(model, plan, dcfg: DistConfig, shape,
                        ocfg: AdamWConfig, schedule=None, mesh=None,
                        donate: bool = True):
    """jit(shard_map(train_step)), staged or whole-model per the plan."""
    mesh = mesh or make_mesh(dcfg)
    if not plan.pipelined:
        fn, _ = wrap_train_step(model, dcfg, shape, ocfg, schedule,
                                mesh=mesh, donate=donate)
        return fn
    step_local = make_staged_train_step(model, plan, dcfg, ocfg, schedule)
    pspecs = _staged_specs(model, dcfg, plan.stage)
    opt_specs = _opt_specs(pspecs, dcfg)
    in_specs = (pspecs, opt_specs, RT.batch_specs(model, shape, dcfg))
    out_specs = (pspecs, opt_specs,
                 {"loss": P(), "grad_norm": P(), "lr": P()})
    fn = shard_map(step_local, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return jax.jit(fn, donate_argnums=(0, 1) if donate else ())


# ---------------------------------------------------------------------------
# Pipeline-parallel training with a bring-your-own staged module (synthetic
# stage stacks under pp x dp x tp; benchmarks and the raw parity harness).
# ---------------------------------------------------------------------------
def make_pipeline_train_step(stage_fn, stage_metas, dcfg: DistConfig,
                             ocfg: AdamWConfig, loss_fn,
                             schedule: str | None = None, plan=None,
                             lr_schedule: Callable | None = None):
    """Pipelined analogue of `make_train_step` for an explicitly staged
    module: `stage_fn(full_params, x) -> y` is ONE stage's compute (TP-local;
    psum over `dcfg.tp_axis` yourself where needed), `loss_fn(y) -> scalar`
    is one microbatch's contribution to the total loss.

    Storage/opt-state leaves carry a leading stage dim sharded over
    `dcfg.pp_axis` (spec `ParamMeta.pipe_stacked_storage_spec`); inside the
    step each rank trains its own stage with SimpleFSDP bucket gathers per
    use (ZeRO-3 over `fsdp_axes`), activations streaming between stages per
    `dcfg.pp_schedule` — all inside one shard_map'd jit, the paper's
    full-graph property.
    """
    from repro.core.pipeline import fsdp_stage_fn, pipeline_grads

    sched = lr_schedule or (lambda t: ocfg.lr)
    stage = fsdp_stage_fn(stage_fn, stage_metas, dcfg, plan)
    dp_axes = RT.dp_axes(dcfg)

    def _local(tree):
        return jax.tree.map(lambda a: a[0], tree)

    def _restack(tree):
        return jax.tree.map(lambda a: a[None], tree)

    def step_local(storage, opt_state, xs):
        local = _local(storage)               # this rank's stage shards
        opt_local = _opt_local(opt_state, _local)
        loss, grads, _ = pipeline_grads(stage, local, xs, loss_fn, dcfg,
                                        schedule)
        lr = sched(opt_local["step"])
        new_p, new_opt, gnorm = apply_adamw(local, grads, opt_local,
                                            stage_metas, dcfg, ocfg, lr)
        metrics = {
            "loss": lax.pmean(loss, dp_axes) if dp_axes else loss,
            "grad_norm": gnorm,
            "lr": jnp.asarray(lr, jnp.float32),
        }
        return _restack(new_p), _opt_local(new_opt, _restack), metrics

    return step_local


def pipeline_storage_specs(stage_metas, dcfg: DistConfig):
    from repro.core.meta import ParamMeta

    return jax.tree.map(lambda m: m.pipe_stacked_storage_spec(dcfg),
                        stage_metas,
                        is_leaf=lambda x: isinstance(x, ParamMeta))


def wrap_pipeline_train_step(stage_fn, stage_metas, dcfg: DistConfig,
                             ocfg: AdamWConfig, loss_fn, xs_ndim: int,
                             schedule: str | None = None, plan=None,
                             lr_schedule=None, mesh=None,
                             donate: bool = True):
    """jit(shard_map(pipeline_train_step)). `xs_ndim` is the rank of the
    (M, batch, ...) microbatch activation stack fed to stage 0 (dim 0 is the
    microbatch schedule dim — replicated; dim 1 is sharded over the data
    axes)."""
    mesh = mesh or make_mesh(dcfg)
    step_local = make_pipeline_train_step(stage_fn, stage_metas, dcfg, ocfg,
                                          loss_fn, schedule, plan,
                                          lr_schedule)
    pspecs = pipeline_storage_specs(stage_metas, dcfg)
    opt_specs = _opt_specs(pspecs, dcfg)
    xs_spec = P(None, RT.dp_axes(dcfg), *([None] * (xs_ndim - 2)))
    in_specs = (pspecs, opt_specs, xs_spec)
    out_specs = (pspecs, opt_specs,
                 {"loss": P(), "grad_norm": P(), "lr": P()})
    fn = shard_map(step_local, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return jax.jit(fn, donate_argnums=(0, 1) if donate else ()), mesh


def init_pipeline_state(stage_params_fn, stage_metas, dcfg: DistConfig,
                        key=None):
    """Build the (S, storage...) stage-stacked params + fresh opt state.

    `stage_params_fn(key, stage_idx) -> full param tree` initializes one
    stage; stage s's tree is converted to ZeRO-3 storage and stacked along
    the leading pipe dim.
    """
    from repro.core.meta import ParamMeta, to_storage

    key = key if key is not None else jax.random.PRNGKey(0)
    fulls = [stage_params_fn(jax.random.fold_in(key, s), s)
             for s in range(dcfg.pp_size)]
    storage = jax.tree.map(
        lambda m, *ps: jnp.stack([to_storage(p, m, dcfg) for p in ps]),
        stage_metas, *fulls, is_leaf=lambda x: isinstance(x, ParamMeta))
    return storage, init_opt_state(storage, dcfg)


def make_eval_step(model, dcfg: DistConfig, shape, mesh=None):
    mesh = mesh or make_mesh(dcfg)
    step = RT.make_loss_step(model, dcfg, with_grads=False)
    pspecs = RT.model_storage_specs(model, dcfg)
    fn = shard_map(step, mesh=mesh,
                   in_specs=(pspecs, RT.batch_specs(model, shape, dcfg)),
                   out_specs=P())
    return jax.jit(fn), mesh


def default_schedule(ocfg: AdamWConfig, total_steps: int, warmup: int = 100):
    return functools.partial(warmup_cosine, peak_lr=ocfg.lr, warmup=warmup,
                             total=total_steps)


def init_train_state(model, dcfg: DistConfig, key=None, plan=None):
    """Fresh storage + optimizer state (stage-stacked when `plan` pipelines
    — the optimizer moments live in the same layout as the params)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    storage = RT.init_storage(model, key, dcfg)
    if plan is not None and plan.pipelined:
        from repro.models import staging
        storage = staging.stage_tree(
            storage, plan.stage, dcfg,
            staging.pipe_sharded_groups(model, dcfg, plan.stage))
    return storage, init_opt_state(storage, dcfg)
