"""Training step assembly: SimpleFSDP forward/backward + gradient
accumulation (microbatches) + clipping + AdamW + LR schedule, all inside one
shard_map'd jit — the "full computation-communication graph" the paper traces.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax, shard_map
from jax.sharding import PartitionSpec as P

from repro.core.dist import DistConfig, make_mesh
from repro.models import runtime as RT
from repro.optim.adamw import AdamWConfig, apply_adamw, init_opt_state
from repro.optim.schedule import warmup_cosine


def make_train_step(model, dcfg: DistConfig, ocfg: AdamWConfig,
                    schedule: Callable | None = None):
    """Returns step_local(storage, opt_state, batch) -> (storage, opt_state,
    metrics); run it inside shard_map via `wrap_train_step`."""
    metas = model.metas(dcfg)
    sched = schedule or (lambda t: ocfg.lr)

    def loss_of(storage, mb):
        return model.loss_local(storage, mb, dcfg)[0]

    def step_local(storage, opt_state, batch):
        k = dcfg.microbatches
        if k > 1:
            split = jax.tree.map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)
            mb0 = jax.tree.map(lambda x: x[0], split)
            # peel microbatch 0 so the accumulator carry has real vma types
            loss, grads = jax.value_and_grad(loss_of)(storage, mb0)

            def body(carry, mb):
                acc_l, acc_g = carry
                l, g = jax.value_and_grad(loss_of)(storage, mb)
                return (acc_l + l, jax.tree.map(jnp.add, acc_g, g)), None

            rest = jax.tree.map(lambda x: x[1:], split)
            (loss, grads), _ = lax.scan(body, (loss, grads), rest)
            inv = 1.0 / k
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(storage, batch)

        lr = sched(opt_state["step"])
        new_p, new_opt, gnorm = apply_adamw(storage, grads, opt_state,
                                            metas, dcfg, ocfg, lr)
        metrics = {
            "loss": lax.pmean(loss, dcfg.mesh_axes) * dcfg.tp_size,
            "grad_norm": gnorm,
            "lr": jnp.asarray(lr, jnp.float32),
        }
        return new_p, new_opt, metrics

    return step_local


def wrap_train_step(model, dcfg: DistConfig, shape, ocfg: AdamWConfig,
                    schedule=None, mesh=None, donate: bool = True):
    """jit(shard_map(train_step)) with the full in/out sharding specs."""
    mesh = mesh or make_mesh(dcfg)
    step_local = make_train_step(model, dcfg, ocfg, schedule)
    pspecs = RT.model_storage_specs(model, dcfg)
    opt_specs = {"m": pspecs, "v": pspecs, "step": P()}
    in_specs = (pspecs, opt_specs, RT.batch_specs(model, shape, dcfg))
    out_specs = (pspecs, opt_specs,
                 {"loss": P(), "grad_norm": P(), "lr": P()})
    fn = shard_map(step_local, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs)
    return jax.jit(fn, donate_argnums=(0, 1) if donate else ()), mesh


def make_eval_step(model, dcfg: DistConfig, shape, mesh=None):
    mesh = mesh or make_mesh(dcfg)
    step = RT.make_loss_step(model, dcfg, with_grads=False)
    pspecs = RT.model_storage_specs(model, dcfg)
    fn = shard_map(step, mesh=mesh,
                   in_specs=(pspecs, RT.batch_specs(model, shape, dcfg)),
                   out_specs=P())
    return jax.jit(fn), mesh


def default_schedule(ocfg: AdamWConfig, total_steps: int, warmup: int = 100):
    return functools.partial(warmup_cosine, peak_lr=ocfg.lr, warmup=warmup,
                             total=total_steps)


def init_train_state(model, dcfg: DistConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    storage = RT.init_storage(model, key, dcfg)
    return storage, init_opt_state(storage)
