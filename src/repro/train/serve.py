"""Serving runtime: TP-sharded weight layout, KV-cache/recurrent-state
abstracts, and shard_map-wrapped prefill/decode steps.

Inference keeps weights TP-sharded and FSDP-ungathered-once (gathered at
load; the inference analogue of ``reshard_after_forward=False`` — see
DESIGN.md SSArch-applicability): every param is a stacked TP-local tensor
with spec P(None, ..., 'model' @ tp_dim, ...), replicated over data/pod.
Caches shard batch over the data axes and heads over the model axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.core.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.dist import DistConfig, make_mesh
from repro.core.meta import ParamMeta
from repro.kernels.quant import ops as QOPS
from repro.models import runtime as RT
from repro.models.common import ShapeConfig


def _dp_axes(dcfg: DistConfig):
    return tuple(a for a in dcfg.mesh_axes if a != dcfg.tp_axis)


# ---------------------------------------------------------------------------
# Serve parameter layout
# ---------------------------------------------------------------------------
def _serve_spec(m: ParamMeta, dcfg: DistConfig, stacked: bool) -> P:
    dims = [None] * len(m.global_shape)
    if m.tp_dim is not None:
        dims[m.tp_dim] = dcfg.tp_axis
    return P(None, *dims) if stacked else P(*dims)


def _serve_abs(m: ParamMeta, dcfg: DistConfig, stacked: bool, n: int):
    shape = m.global_shape if not stacked else (n, *m.global_shape)
    return jax.ShapeDtypeStruct(shape, dcfg.param_dtype)


def serve_param_specs(model, dcfg: DistConfig):
    metas = model.metas(dcfg)
    sk = RT.stacked_keys(model)
    is_meta = lambda x: isinstance(x, ParamMeta)
    return {
        k: jax.tree.map(lambda m: _serve_spec(m, dcfg, k in sk), metas[k],
                        is_leaf=is_meta)
        for k in metas
    }


def serve_abstract_params(model, dcfg: DistConfig):
    metas = model.metas(dcfg)
    sk = RT.stacked_keys(model)
    is_meta = lambda x: isinstance(x, ParamMeta)
    return {
        k: jax.tree.map(lambda m: _serve_abs(m, dcfg, k in sk, sk.get(k, 0)),
                        metas[k], is_leaf=is_meta)
        for k in metas
    }


def serve_params_from_storage(model, storage, dcfg: DistConfig):
    """Gather-once: training storage -> logical arrays in param_dtype."""
    metas = model.metas(dcfg)
    logical = {k: RT.tree_from_storage(storage[k], metas[k], dcfg)
               for k in storage}
    return jax.tree.map(lambda x: x.astype(dcfg.param_dtype), logical)


# ---------------------------------------------------------------------------
# Cache / recurrent-state abstracts per family
# ---------------------------------------------------------------------------
def _kl_total(cfg, tp):
    """Global head count of the cache: per-rank kl x tp (grouped-kv archs
    store each rank's contiguous slice explicitly — runtime state, not
    params)."""
    lay = cfg.gqa_layout(tp)
    if lay["mode"] == "sharded":
        return cfg.n_kv_heads
    return max(1, lay["kvp"] // tp) * tp


def cache_abstract(model, shape: ShapeConfig, dcfg: DistConfig):
    """(cache_abstract_pytree, cache_specs_pytree) for one decode step."""
    cfg = model.cfg
    tp = dcfg.tp_size
    dp = _dp_axes(dcfg)
    B, T = shape.global_batch, shape.seq_len
    fam = cfg.family

    def kv_pair(t_len, heads):
        spec = P(None, dp, None, dcfg.tp_axis, None)
        codec = dcfg.kv_codec
        if codec:
            q = jax.ShapeDtypeStruct((model.n_steps, B, t_len, heads,
                                      cfg.head_dim), QOPS.kv_wire_dtype(codec))
            sc = jax.ShapeDtypeStruct(
                (model.n_steps, B, t_len, heads,
                 QOPS.kv_chunks(cfg.head_dim)), jnp.float32)
            return ({"k": q, "ks": sc, "v": q, "vs": sc},
                    {"k": spec, "ks": spec, "v": spec, "vs": spec})
        sds = jax.ShapeDtypeStruct((model.n_steps, B, t_len, heads,
                                    cfg.head_dim), dcfg.param_dtype)
        return (sds, sds), (spec, spec)

    if fam in ("dense", "moe", "vlm"):
        heads = _kl_total(cfg, tp)
        a, s = kv_pair(T, heads)
        if cfg.local_global_alternate:   # gemma2 (local, global) pairs
            return (a, a), (s, s)
        return a, s

    if fam == "encdec":
        heads = _kl_total(cfg, tp)
        S_src = T // 2
        self_sds = jax.ShapeDtypeStruct(
            (model.n_dec, B, T, heads, cfg.head_dim), dcfg.param_dtype)
        cross_sds = jax.ShapeDtypeStruct(
            (model.n_dec, B, S_src, heads, cfg.head_dim), dcfg.param_dtype)
        spec = P(None, dp, None, dcfg.tp_axis, None)
        return ({"self": (self_sds, self_sds),
                 "cross": (cross_sds, cross_sds)},
                {"self": (spec, spec), "cross": (spec, spec)})

    if fam == "xlstm":
        H, dk = model.n_heads, model.dk
        dv = dk                       # dv == dk per head
        d = cfg.d_model
        hd = d // H
        K = cfg.ssm_conv
        L = model.n_steps
        di = model.d_inner

        def sds(shape_, spec_):
            return (jax.ShapeDtypeStruct((L, *shape_), jnp.float32), spec_)

        m_abs, m_spec = {}, {}
        for i in range(model.per - 1):
            a = {"C": sds((B, H, dk, dv), P(None, dp, None, None,
                                            dcfg.tp_axis)),
                 "n": sds((B, H, dk), P(None, dp, None, None)),
                 "m": sds((B, H), P(None, dp, None)),
                 "conv": sds((B, K - 1, di), P(None, dp, None, None))}
            m_abs[f"m{i}"] = {k: v[0] for k, v in a.items()}
            m_spec[f"m{i}"] = {k: v[1] for k, v in a.items()}
        s_a = {"h": sds((B, H, hd), P(None, dp, None, None)),
               "c": sds((B, H, hd), P(None, dp, None, None)),
               "n": sds((B, H, hd), P(None, dp, None, None)),
               "m": sds((B, H, hd), P(None, dp, None, None))}
        m_abs["s"] = {k: v[0] for k, v in s_a.items()}
        m_spec["s"] = {k: v[1] for k, v in s_a.items()}
        return m_abs, m_spec

    if fam == "zamba":
        L = cfg.n_layers
        nh, hd, ds = model.nh, model.hd, model.ds
        K = cfg.ssm_conv
        heads = _kl_total(cfg, tp)
        abs_ = {
            "S": jax.ShapeDtypeStruct((L, B, nh, hd, ds), jnp.float32),
            "conv_x": jax.ShapeDtypeStruct((L, B, K - 1, nh * hd),
                                           jnp.float32),
            "conv_bc": jax.ShapeDtypeStruct((L, B, K - 1, 2 * ds),
                                            jnp.float32),
            "sh_kv": tuple(
                (jax.ShapeDtypeStruct((B, T, heads, cfg.head_dim),
                                      dcfg.param_dtype),) * 2
                for _ in range(model.n_super)),
        }
        spec = {
            "S": P(None, dp, dcfg.tp_axis, None, None),
            "conv_x": P(None, dp, None, dcfg.tp_axis),
            "conv_bc": P(None, dp, None, None),
            "sh_kv": tuple(
                (P(dp, None, dcfg.tp_axis, None),) * 2
                for _ in range(model.n_super)),
        }
        return abs_, spec

    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------
def make_decode_step(model, dcfg: DistConfig, shape: ShapeConfig, mesh=None):
    mesh = mesh or make_mesh(dcfg)
    dp = _dp_axes(dcfg)
    _, cache_specs = cache_abstract(model, shape, dcfg)

    def step(params, cache, tok, pos):
        logits, cache = model.decode_local(params, cache, tok, pos, dcfg)
        return logits, cache

    in_specs = (serve_param_specs(model, dcfg), cache_specs, P(dp), P(dp))
    out_specs = (P(dp, dcfg.tp_axis), cache_specs)
    return jax.jit(shard_map(step, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False),
                   donate_argnums=(1,)), mesh


def make_prefill_step(model, dcfg: DistConfig, shape: ShapeConfig,
                      mesh=None):
    mesh = mesh or make_mesh(dcfg)
    dp = _dp_axes(dcfg)

    def step(params, batch):
        return model.prefill_local(params, batch, dcfg)

    batch_specs = {}
    for k, sds in model.input_specs(shape, dcfg).items():
        batch_specs[k] = P(dp, *([None] * (len(sds.shape) - 1)))
    # cache out specs are family-shaped; infer from a decode-cache template
    _, cache_specs = cache_abstract(model, shape, dcfg)
    out_specs = (P(dp, dcfg.tp_axis), _prefill_cache_specs(model, dcfg,
                                                           cache_specs))
    in_specs = (serve_param_specs(model, dcfg), batch_specs)
    return jax.jit(shard_map(step, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)), mesh


def _prefill_cache_specs(model, dcfg, decode_specs):
    """Prefill emits the same pytree as decode consumes (specs identical)."""
    return decode_specs


def decode_inputs_abstract(model, shape: ShapeConfig, dcfg: DistConfig):
    B = shape.global_batch
    cache_abs, _ = cache_abstract(model, shape, dcfg)
    return {
        "params": serve_abstract_params(model, dcfg),
        "cache": cache_abs,
        "tok": jax.ShapeDtypeStruct((B,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Paged decode/chunked-prefill step (core.serving arena layout)
# ---------------------------------------------------------------------------
def paged_abstracts(model, shape: ShapeConfig, dcfg: DistConfig, *,
                    page: int, n_pages_local: int, max_pages: int):
    """(arena_abs, arena_specs, table_abs, table_spec) for a paged step."""
    from repro.core.serving import pages as PG
    cache_abs, cache_specs = cache_abstract(model, shape, dcfg)
    arena_abs, arena_specs = PG.arena_abstract(
        cache_abs, cache_specs, n_pages_local, page, dcfg.dp_total)
    dp = _dp_axes(dcfg)
    table_abs = jax.ShapeDtypeStruct(
        (shape.global_batch, max_pages), jnp.int32)
    return arena_abs, arena_specs, table_abs, P(dp)


def make_paged_step(model, dcfg: DistConfig, shape: ShapeConfig, *,
                    page: int, n_pages_local: int, max_pages: int,
                    chunk: int = 1, mesh=None):
    """Jitted paged step over (params, arena, table, toks, qpos).

    chunk=1 is one decode step; chunk>1 runs one chunked-prefill slab
    through the same kernel.  toks/qpos are (B, chunk); the table holds
    LOCAL page ids (each data shard allocates from its own pool)."""
    if not getattr(model, "paged_kv", False):
        raise ValueError(
            f"{model.cfg.family}: no paged decode path (see plan_serve)")
    mesh = mesh or make_mesh(dcfg)
    dp = _dp_axes(dcfg)
    _, arena_specs, _, table_spec = paged_abstracts(
        model, shape, dcfg, page=page, n_pages_local=n_pages_local,
        max_pages=max_pages)

    def step(params, arena, table, toks, qpos):
        return model.paged_step_local(params, arena, table, toks, qpos,
                                      dcfg, page=page)

    in_specs = (serve_param_specs(model, dcfg), arena_specs, table_spec,
                P(dp), P(dp))
    out_specs = (P(dp, dcfg.tp_axis), arena_specs)
    return jax.jit(shard_map(step, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False),
                   donate_argnums=(1,)), mesh
