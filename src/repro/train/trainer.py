"""Production training loop: data prefetch, checkpoint/restart, failure
recovery, straggler mitigation, metrics.

ONE `Trainer` for every parallelism layout: it resolves a frozen
`ParallelPlan` via `core/api.parallelize` and drives the plan's train step —
whole-model SimpleFSDP at pp=1, the staged GPipe/1F1B pipeline (per-stage
SimpleFSDP storage, models' stage-partition contract) when `dcfg.pp_axis`
is set.  pp x dp x tp is a config flip, not a different trainer (the old
`PipelineTrainer` is gone; bring-your-own-stage modules keep
`train_step.make_pipeline_train_step`).

`Trainer.run` survives injected failures by restarting from the newest
checkpoint (same or different mesh — checkpoints are topology-independent:
they always store the PLAIN storage layout, staged layouts are converted on
save/restore, so a run can move between pipeline degrees across restarts);
see ft/failures.py for what is simulated vs. real on this container.
"""

from __future__ import annotations

import dataclasses
import logging
import math

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.api import parallelize
from repro.core.dist import DistConfig
from repro.data.pipeline import DataConfig, SyntheticC4, adapt_batch
from repro.ft.failures import (FailureSource, StepTimer, StragglerMonitor)
from repro.models.common import ShapeConfig
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import default_schedule, init_train_state

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    warmup: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    async_ckpt: bool = False
    max_restarts: int = 3
    stop_after: int | None = None     # pause the job early (schedule horizon
                                      # stays total_steps — used for resume
                                      # tests and preemption drills)
    metrics_jsonl: str | None = None  # append a registry snapshot here at
                                      # every log interval (core/obs)
    # profile-guided replanning (core/obs/profile + calibrate): when the
    # step_time drift |rel| stays above replan_threshold for
    # replan_patience consecutive steps, harvest a MeasuredProfile and
    # re-run the planners under calibration.  replan_apply additionally
    # restarts the loop onto the new plan through the checkpoint path.
    replan_threshold: float | None = None
    replan_patience: int = 3
    replan_apply: bool = False
    replan_profile_steps: int = 2


class Trainer:
    def __init__(self, model, dcfg: DistConfig, shape: ShapeConfig,
                 ocfg: AdamWConfig, tcfg: TrainerConfig,
                 failure_source: FailureSource | None = None,
                 seed: int = 0, registry=None):
        from repro.core.obs import (DriftMonitor, MetricsRegistry,
                                    modeled_step_time)
        from repro.train.train_step import step_wire_metrics

        self.model, self.dcfg, self.shape = model, dcfg, shape
        self.ocfg, self.tcfg = ocfg, tcfg
        self.failures = failure_source or FailureSource()
        self.straggler = StragglerMonitor()
        self.ckpt = Checkpointer(tcfg.ckpt_dir, async_save=tcfg.async_ckpt)
        self.data = SyntheticC4(DataConfig(
            vocab=model.cfg.vocab, seq_len=shape.seq_len,
            global_batch=shape.global_batch, seed=seed))
        self._seed = seed
        sched = default_schedule(ocfg, tcfg.total_steps, tcfg.warmup)
        self.par = parallelize(model, dcfg, shape)
        self.plan = self.par.plan
        self.mesh = self.par.mesh
        self.step_fn = self.par.train_step(ocfg, sched)
        self.history: list[dict] = []
        self.restarts = 0
        # profile-guided replanning state: drift streak, the latest
        # harvested MeasuredProfile, and one delta record per replan
        self._drift_streak = 0
        self._replan_pending = False
        self.profile = None
        self.replans: list[dict] = []
        # observability: one registry + drift monitor per trainer; the
        # plan's own step-time promise and per-step wire bytes are frozen
        # up front so the run loop only records measurements
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.drift = DriftMonitor(self.registry)
        try:
            self._modeled_step_s = modeled_step_time(model, self.plan,
                                                     shape)
        except Exception:
            self._modeled_step_s = None
        try:
            self._wire = step_wire_metrics(model, self.plan)
        except Exception:
            self._wire = None
        if self.plan.memory is not None:
            log.info("plan: %s", self.plan.describe())
            for b in self.plan.memory.breakdown:
                log.info("modeled peak %s", b.describe())

    def memory_report(self, measured: bool = True) -> dict:
        """Modeled (live-range simulator, core/memory) vs measured (XLA
        ``memory_analysis`` of THIS trainer's compiled step) per-device
        peak.  `measured=False` skips the extra compile and reports the
        model side only."""
        mem_plan = self.plan.memory
        rep = {
            "modeled_peak_bytes": mem_plan.peak if mem_plan else None,
            "policy_spec": mem_plan.policy_spec if mem_plan else
            self.dcfg.remat,
            "per_stage": [b.describe() for b in mem_plan.breakdown]
            if mem_plan else [],
        }
        if measured:
            from repro.optim.adamw import init_opt_state
            params_abs = self.par.abstract_storage
            opt_abs = jax.eval_shape(
                lambda s: init_opt_state(s, self.dcfg), params_abs)
            batch_abs = self.model.input_specs(self.shape, self.dcfg)
            m = self.step_fn.lower(params_abs, opt_abs,
                                   batch_abs).compile().memory_analysis()
            meas = (m.argument_size_in_bytes + m.temp_size_in_bytes
                    + m.output_size_in_bytes - m.alias_size_in_bytes)
            rep["measured_peak_bytes"] = meas
            if mem_plan is not None:
                rep["modeled_over_measured"] = mem_plan.peak / max(1, meas)
                # ONE audited modeled-vs-measured path (core/obs):
                # record_peak writes the gauges and formats the line the
                # dryrun's [mem] print shares
                log.info("memory: %s", self.registry.record_peak(
                    "train", mem_plan.peak, meas,
                    note=f"remat={rep['policy_spec']}"))
                self.drift.record("peak_memory", mem_plan.peak, meas)
        return rep

    # ------------------------------------------------------------------ --
    def _init_or_restore(self, key):
        latest = self.ckpt.latest_step()
        if latest is not None:
            storage, opt_state, _ = self.ckpt.restore(latest, self.model,
                                                      self.dcfg)
            # checkpoints hold the plain layout; stage it for this plan
            storage = self.par.stage_storage(storage)
            if self.plan.pipelined:
                from repro.models import staging
                opt_state = staging.stage_opt_state(
                    opt_state, self.plan.stage, self.dcfg,
                    self.par.pipe_sharded)
            log.info("restored step %d", latest)
            return storage, opt_state, latest
        storage, opt_state = init_train_state(self.model, self.dcfg, key,
                                              plan=self.plan)
        return storage, opt_state, 0

    def _save(self, step, storage, opt_state):
        if self.plan.pipelined:
            from repro.models import staging
            storage = self.par.unstage_storage(storage)
            opt_state = staging.unstage_opt_state(
                opt_state, self.plan.stage, self.dcfg,
                self.par.pipe_sharded)
        self.ckpt.save(step, storage, opt_state, self.model, self.dcfg)

    def _batch(self, step):
        batch = adapt_batch(
            self.data.batch(step),
            self.model.input_specs(self.shape, self.dcfg),
            step=step, seed=self._seed)
        if self.dcfg.cp_size > 1:
            # zigzag sequence permutation so the contiguous ctx sharding
            # delivers each rank its load-balanced chunks (core/context.py)
            from repro.core.context import zigzag_batch
            batch = zigzag_batch(batch, self.dcfg)
        return batch

    def _record_step(self, step: int, dt: float, metrics) -> None:
        """Mirror one completed step into the registry + drift monitor."""
        r = self.registry
        r.counter("train/steps").inc()
        r.gauge("train/step_time_s").set(dt)
        r.gauge("train/tokens_per_s").set(
            self.shape.seq_len * self.shape.global_batch / max(1e-9, dt))
        r.gauge("train/grad_norm").set(float(metrics["grad_norm"]))
        r.gauge("train/loss").set(float(metrics["loss"]))
        if self._wire is not None:
            for prec, nbytes in self._wire["by_precision"].items():
                r.counter(f"train/wire_bytes/{prec}").inc(nbytes)
        if self._modeled_step_s is not None:
            rel = self.drift.record("step_time", self._modeled_step_s, dt,
                                    step=step)
            if self.tcfg.replan_threshold is not None \
                    and math.isfinite(rel):
                if abs(rel) > self.tcfg.replan_threshold:
                    self._drift_streak += 1
                    if self._drift_streak >= self.tcfg.replan_patience:
                        self._replan_pending = True
                else:
                    self._drift_streak = 0

    def _replan(self, step, storage, opt_state):
        """Profile-guided replanning: harvest a `MeasuredProfile` against
        the drifting plan, re-run the planners under calibration, log the
        delta, and — when `replan_apply` — restart the loop onto the new
        plan through the checkpoint path (the same topology-independent
        restart the failure path uses).  Returns the (possibly restaged)
        train state."""
        from repro.core.obs import calibrated_step_time, profile_step
        from repro.core.obs import replan as obs_replan

        self._replan_pending = False
        self._drift_streak = 0
        rows = self.drift.records.get("step_time", [])
        recent = [r["measured"]
                  for r in rows[-max(1, self.tcfg.replan_patience):]]
        wall = sum(recent) / len(recent) if recent else None
        try:
            self.profile = profile_step(
                self.model, self.plan, self.shape,
                steps=self.tcfg.replan_profile_steps, wall_step_s=wall)
            new_plan, delta = obs_replan(self.model, self.plan, self.shape,
                                         self.profile)
        except Exception:
            log.exception("replan failed at step %d; keeping current plan",
                          step)
            return storage, opt_state
        delta["step"] = step
        delta["applied"] = False
        self.replans.append(delta)
        r = self.registry
        r.counter("replan/count").inc()
        for k in ("modeled_step_before_s", "modeled_step_after_s"):
            if delta[k] is not None:
                r.gauge(f"replan/{k}").set(delta[k])
        log.info("replan at step %d: changed=%s gain=%s fields=%s", step,
                 delta["changed"], delta["modeled_gain_s"],
                 sorted(delta["fields"]))
        if not (self.tcfg.replan_apply and delta["changed"]):
            return storage, opt_state
        # restart onto the new plan: checkpoints store the plain layout,
        # so save, rebuild the parallelized bundle, and restore staged
        self._save(step, storage, opt_state)
        self.ckpt.wait()
        sched = default_schedule(self.ocfg, self.tcfg.total_steps,
                                 self.tcfg.warmup)
        self.par = parallelize(self.model, self.dcfg, self.shape,
                               plan=new_plan)
        self.plan = self.par.plan
        self.mesh = self.par.mesh
        self.step_fn = self.par.train_step(self.ocfg, sched)
        try:
            self._modeled_step_s = calibrated_step_time(
                self.model, self.plan, self.shape, self.profile)
        except Exception:
            self._modeled_step_s = None
        storage, opt_state, _ = self._init_or_restore(
            jax.random.PRNGKey(self._seed))
        delta["applied"] = True
        log.info("replan applied at step %d: %s", step, self.plan.describe())
        return storage, opt_state

    def run(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        storage, opt_state, start = self._init_or_restore(key)
        step = start
        stop_at = self.tcfg.stop_after or self.tcfg.total_steps
        while step < stop_at:
            if self.failures.check(step):
                self.restarts += 1
                if self.restarts > self.tcfg.max_restarts:
                    raise RuntimeError("restart budget exhausted")
                log.warning("failure detected at step %d; restarting", step)
                self.ckpt.wait()
                storage, opt_state, step = self._init_or_restore(key)
                continue

            batch = self._batch(step)
            with StepTimer() as t:
                storage, opt_state, metrics = self.step_fn(
                    storage, opt_state, batch)
                metrics = jax.tree.map(np.asarray, metrics)
            verdict = self.straggler.observe(t.dt)
            if verdict == "escalate":
                log.warning("straggler escalation at step %d", step)
            step += 1
            self._record_step(step, t.dt, metrics)
            if self._replan_pending:
                storage, opt_state = self._replan(step, storage, opt_state)
            if step % self.tcfg.log_every == 0 or step == 1:
                self.history.append(
                    {"step": step, "dt": t.dt,
                     **{k: float(v) for k, v in metrics.items()}})
                log.info("step %d loss %.4f gnorm %.3f %.0fms", step,
                         metrics["loss"], metrics["grad_norm"],
                         t.dt * 1e3)
                if self.tcfg.metrics_jsonl:
                    self.registry.dump_jsonl(self.tcfg.metrics_jsonl,
                                             step=step)
            if step % self.tcfg.ckpt_every == 0 \
                    or step in (self.tcfg.total_steps, stop_at):
                self._save(step, storage, opt_state)
        self.ckpt.wait()
        return storage, opt_state, self.history
