"""Production training loop: data prefetch, checkpoint/restart, failure
recovery, straggler mitigation, metrics.

`Trainer.run` survives injected failures by restarting from the newest
checkpoint (same or different mesh — checkpoints are topology-independent),
exactly the restart path a 1000-node deployment needs; see ft/failures.py
for what is simulated vs. real on this container.
"""

from __future__ import annotations

import dataclasses
import logging
import time

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.dist import DistConfig
from repro.data.pipeline import DataConfig, SyntheticC4
from repro.ft.failures import (FailureSource, StepTimer, StragglerMonitor)
from repro.models.common import ShapeConfig
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import (default_schedule, init_train_state,
                                    wrap_train_step)

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    warmup: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    async_ckpt: bool = False
    max_restarts: int = 3
    stop_after: int | None = None     # pause the job early (schedule horizon
                                      # stays total_steps — used for resume
                                      # tests and preemption drills)


class Trainer:
    def __init__(self, model, dcfg: DistConfig, shape: ShapeConfig,
                 ocfg: AdamWConfig, tcfg: TrainerConfig,
                 failure_source: FailureSource | None = None,
                 seed: int = 0):
        if dcfg.pp_axis is not None:
            raise ValueError(
                "Trainer drives whole-model loss_local steps; a pipe mesh "
                "axis needs an explicitly staged module — use "
                "PipelineTrainer (same file) with stage_fn/stage_metas.")
        self.model, self.dcfg, self.shape = model, dcfg, shape
        self.ocfg, self.tcfg = ocfg, tcfg
        self.failures = failure_source or FailureSource()
        self.straggler = StragglerMonitor()
        self.ckpt = Checkpointer(tcfg.ckpt_dir, async_save=tcfg.async_ckpt)
        self.data = SyntheticC4(DataConfig(
            vocab=model.cfg.vocab, seq_len=shape.seq_len,
            global_batch=shape.global_batch, seed=seed))
        sched = default_schedule(ocfg, tcfg.total_steps, tcfg.warmup)
        self.step_fn, self.mesh = wrap_train_step(model, dcfg, shape, ocfg,
                                                  sched)
        self.history: list[dict] = []
        self.restarts = 0

    # ------------------------------------------------------------------ --
    def _init_or_restore(self, key):
        latest = self.ckpt.latest_step()
        if latest is not None:
            storage, opt_state, _ = self.ckpt.restore(latest, self.model,
                                                      self.dcfg)
            log.info("restored step %d", latest)
            return storage, opt_state, latest
        storage, opt_state = init_train_state(self.model, self.dcfg, key)
        return storage, opt_state, 0

    def run(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        storage, opt_state, start = self._init_or_restore(key)
        step = start
        stop_at = self.tcfg.stop_after or self.tcfg.total_steps
        while step < stop_at:
            if self.failures.check(step):
                self.restarts += 1
                if self.restarts > self.tcfg.max_restarts:
                    raise RuntimeError("restart budget exhausted")
                log.warning("failure detected at step %d; restarting", step)
                self.ckpt.wait()
                storage, opt_state, step = self._init_or_restore(key)
                continue

            batch = self.data.batch(step)
            with StepTimer() as t:
                storage, opt_state, metrics = self.step_fn(
                    storage, opt_state, batch)
                metrics = jax.tree.map(np.asarray, metrics)
            verdict = self.straggler.observe(t.dt)
            if verdict == "escalate":
                log.warning("straggler escalation at step %d", step)
            step += 1
            if step % self.tcfg.log_every == 0 or step == 1:
                self.history.append(
                    {"step": step, "dt": t.dt,
                     **{k: float(v) for k, v in metrics.items()}})
                log.info("step %d loss %.4f gnorm %.3f %.0fms", step,
                         metrics["loss"], metrics["grad_norm"],
                         t.dt * 1e3)
            if step % self.tcfg.ckpt_every == 0 \
                    or step in (self.tcfg.total_steps, stop_at):
                self.ckpt.save(step, storage, opt_state, self.model,
                               self.dcfg)
        self.ckpt.wait()
        return storage, opt_state, self.history


class PipelineTrainer:
    """Training loop for an explicitly staged module under pp x dp x tp.

    Drives `wrap_pipeline_train_step` (GPipe or 1F1B per
    `dcfg.pp_schedule`): each pipe rank owns one stage's ZeRO-3 storage,
    bucket-gathers it per use, and streams activations to the next stage —
    paper SS4's composition, one shard_map'd jit per step.  Batches are
    synthetic (M, microbatch, ...) activation stacks fed to stage 0; the
    full-LM partition (embedding on stage 0, head+loss on the last stage)
    is tracked in ROADMAP's open items.
    """

    def __init__(self, stage_fn, stage_metas, stage_params_fn,
                 dcfg: DistConfig, ocfg: AdamWConfig, loss_fn,
                 xs_shape: tuple[int, ...], total_steps: int = 100,
                 log_every: int = 10, schedule: str | None = None,
                 plan=None, seed: int = 0):
        if dcfg.pp_axis is None:
            raise ValueError("PipelineTrainer needs dcfg.pp_axis")
        from repro.train.train_step import (init_pipeline_state,
                                            wrap_pipeline_train_step)

        self.dcfg, self.ocfg = dcfg, ocfg
        self.xs_shape, self.seed = tuple(xs_shape), seed
        self.total_steps, self.log_every = total_steps, log_every
        self.straggler = StragglerMonitor()
        sched = default_schedule(ocfg, total_steps, warmup=min(
            10, total_steps))
        self.step_fn, self.mesh = wrap_pipeline_train_step(
            stage_fn, stage_metas, dcfg, ocfg, loss_fn,
            xs_ndim=len(self.xs_shape), schedule=schedule, plan=plan,
            lr_schedule=sched)
        self.storage, self.opt_state = init_pipeline_state(
            stage_params_fn, stage_metas, dcfg, jax.random.PRNGKey(seed))
        self.history: list[dict] = []

    def _batch(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step)
        return jax.random.normal(key, self.xs_shape)

    def run(self):
        for step in range(1, self.total_steps + 1):
            with StepTimer() as t:
                self.storage, self.opt_state, metrics = self.step_fn(
                    self.storage, self.opt_state, self._batch(step))
                metrics = jax.tree.map(np.asarray, metrics)
            if self.straggler.observe(t.dt) == "escalate":
                log.warning("straggler escalation at step %d", step)
            if step % self.log_every == 0 or step == 1:
                self.history.append(
                    {"step": step, "dt": t.dt,
                     **{k: float(v) for k, v in metrics.items()}})
                log.info("pipe step %d loss %.4f gnorm %.3f %.0fms", step,
                         metrics["loss"], metrics["grad_norm"], t.dt * 1e3)
        return self.storage, self.opt_state, self.history
