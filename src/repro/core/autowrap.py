"""Auto-wrapping: the paper's greedy Algorithm 1.

Walks the per-parameter CommNodes in execution order and merges node *i* into
the current bucket iff

  forward   T_AG(bucket + i)              <= T_C(previous bucket's compute)
  backward  T_RS(prev bucket) + T_AG(...) <= T_C(previous bucket's compute)
  memory    M_C(next step) + M_C(i)       <= M_max

(paper Alg. 1 lines 4-5 / 10-11; both directions must admit the merge since
one plan serves forward and backward — the paper buckets "the corresponding
reduce-scatter IR nodes of the all-gathers as well").

The first bucket has no preceding compute to hide behind (it is the exposed
prologue gather, paper Fig. 2 AG12); it is bounded by its own compute time
and the memory cap.

`auto_layer_group` additionally answers "how many *whole layers* can share one
bucket" — the cross-layer generalization the runtime exploits for scanned
stacks (a beyond-paper lever; logged in EXPERIMENTS.md SSPerf when used).
"""

from __future__ import annotations

import math

from repro.core.bucketing import BucketPlan
from repro.core.dist import DistConfig
from repro.core.irgraph import (BlockStats, CommNode, ag_time, build_nodes,
                                comp_time, rs_time)


def greedy_buckets(nodes: list[CommNode], cfg: DistConfig,
                   mem_limit: float | None = None) -> list[list[CommNode]]:
    if not nodes:
        return []
    m_max = cfg.autowrap_mem_limit if mem_limit is None else mem_limit
    buckets: list[list[CommNode]] = []
    cur: list[CommNode] = [nodes[0]]
    for nd in nodes[1:]:
        # bucket k+1's AG hides behind bucket k's compute; the FIRST bucket
        # (exposed prologue, paper Fig. 2) is bounded by its own compute so
        # comm-dominated graphs don't degenerate into one giant bucket.
        prev_c = comp_time(buckets[-1]) if buckets else comp_time(cur)
        cand = cur + [nd]
        t_ag = ag_time(cand, cfg)
        t_rs = rs_time(buckets[-1], cfg) if buckets else 0.0
        time_ok = (t_ag <= prev_c) and (t_rs + t_ag <= prev_c)
        # `cand` already includes nd; counting nd.mem_bytes again would halve
        # the effective cap for the incoming node (regression-tested in
        # tests/test_core.py::test_greedy_mem_cap_not_double_counted).
        mem_ok = sum(c.mem_bytes for c in cand) <= m_max
        if time_ok and mem_ok:
            cur.append(nd)
        else:
            buckets.append(cur)
            cur = [nd]
    buckets.append(cur)
    return buckets


def auto_plan(metas_tree, cfg: DistConfig,
              stats: BlockStats | None = None) -> BucketPlan:
    nodes = build_nodes(metas_tree, cfg, stats)
    buckets = greedy_buckets(nodes, cfg)
    return BucketPlan(tuple(tuple(n.name for n in grp) for grp in buckets))


def exposed_comm_time(plan: BucketPlan, metas_tree, cfg: DistConfig,
                      stats: BlockStats | None = None) -> dict:
    """Analytic exposure of a plan: how much collective time is NOT hidden.

    Used by benchmarks/fig4 to compare manual vs auto plans the way the
    paper's Figure 4 compares their throughput.
    """
    nodes = {n.name: n for n in build_nodes(metas_tree, cfg, stats)}
    groups = [[nodes[name] for name in grp] for grp in plan.groups]
    # STEADY-STATE exposure across the scanned layer stack: bucket i of
    # layer l prefetches behind bucket i-1's compute (cyclically — bucket 0
    # hides behind the previous layer's last bucket). The one-time prologue
    # gather is amortized over L layers and ignored here.
    exposed = 0.0
    total_comm = 0.0
    n = len(groups)
    for i, grp in enumerate(groups):
        t_ag = ag_time(grp, cfg)
        t_rs = rs_time(grp, cfg)
        total_comm += t_ag + t_rs
        prev = groups[(i - 1) % n]
        hide = comp_time(prev)
        exposed += max(0.0, t_ag + rs_time(prev, cfg) - hide)
    return {
        "exposed_s": exposed,
        "total_comm_s": total_comm,
        "compute_s": comp_time(list(nodes.values())),
        "n_buckets": len(groups),
    }


def auto_layer_group(layer_nodes: list[CommNode], cfg: DistConfig,
                     n_layers: int, mem_limit: float | None = None) -> int:
    """Largest k (dividing n_layers) s.t. k layers' bucketed AG+RS still hides
    behind k layers' compute and fits the memory cap."""
    m_max = cfg.autowrap_mem_limit if mem_limit is None else mem_limit
    best = 1
    for k in range(2, n_layers + 1):
        if n_layers % k:
            continue
        grp = layer_nodes * k
        if ag_time(grp, cfg) + rs_time(grp, cfg) > comp_time(grp):
            break
        if 2 * sum(n.mem_bytes for n in grp) > m_max:
            break
        best = k
    return best
