"""Auto-wrapping: greedy Algorithm 1 plus the exposure-minimizing DP planner.

Two planners over the per-parameter `CommNode` list (execution order):

`greedy_buckets` — the paper's Algorithm 1. Walks nodes and merges node *i*
into the current bucket iff

  forward   T_AG(bucket + i)              <= T_C(previous bucket's compute)
  backward  T_RS(prev bucket) + T_AG(...) <= T_C(previous bucket's compute)
  memory    M_C(bucket + i)               <= M_max

(paper Alg. 1 lines 4-5 / 10-11; both directions must admit the merge since
one plan serves forward and backward — the paper buckets "the corresponding
reduce-scatter IR nodes of the all-gathers as well"). The first bucket has no
preceding compute to hide behind (the exposed prologue gather, paper Fig. 2
AG12); it is bounded by its own compute time and the memory cap.

`dp_buckets` — interval-partition dynamic program that minimizes the modeled
STEADY-STATE exposed communication directly (the objective `greedy_buckets`
only approximates through its local merge test).  The objective is the cyclic
exposure of `partition_exposure`: bucket b's all-gather plus bucket b-1's
delayed reduce-scatter hide behind bucket b-1's compute, with wraparound —
bucket 0 of layer l hides behind the last bucket of layer l-1 (exactly the
schedule `core/stack.py` realizes at bucket granularity).  States are
(previous-bucket start, current boundary) pairs, transitions extend the last
bucket, and the cyclic term is closed by enumerating the first bucket's
boundary; the feasible set is every contiguous partition whose multi-node
buckets fit the memory cap (singletons are exempt, matching greedy — a single
parameter over the cap must still gather).  Because the search is exhaustive
over that set and greedy's output lies inside it, the invariant

    exposure(dp) <= exposure(greedy) <= exposure(per-param)

holds by construction (the greedy result used in plans is itself guarded by
`greedy_partition`, which falls back to per-param when a merge hurt the
cyclic objective).  DeepCompile (arXiv 2504.09983) motivates optimizing the
measured/modeled schedule directly over fixed heuristics for exactly this
AG/RS-placement problem.

`auto_layer_group` additionally answers "how many *whole layers* can share one
bucket" — the cross-layer generalization the runtime exploits for scanned
stacks (a beyond-paper lever; logged in EXPERIMENTS.md SSPerf when used).
"""

from __future__ import annotations

import math

from repro.core import hw
from repro.core.bucketing import BucketPlan
from repro.core.dist import AUTO_PRECISIONS, DistConfig
from repro.core.irgraph import (BlockStats, CommNode, ag_time, build_nodes,
                                comp_time, quant_overhead_s, rs_time)


def _cfg_precision(cfg: DistConfig) -> str:
    """The uniform wire precision a planner prices when it is NOT doing the
    per-bucket search: the config's own value, with 'auto' planning at bf16
    (precisions are then assigned per bucket afterwards)."""
    return "bf16" if cfg.comm_precision == "auto" else cfg.comm_precision


def greedy_buckets(nodes: list[CommNode], cfg: DistConfig,
                   mem_limit: float | None = None,
                   cuts: frozenset[int] = frozenset()
                   ) -> list[list[CommNode]]:
    """`cuts`: node indices where a bucket MUST close (segment boundaries —
    the runtime gathers per segment, so planning across one would describe
    a schedule the stack cannot execute)."""
    if not nodes:
        return []
    m_max = cfg.autowrap_mem_limit if mem_limit is None else mem_limit
    buckets: list[list[CommNode]] = []
    cur: list[CommNode] = [nodes[0]]
    for k, nd in enumerate(nodes[1:], start=1):
        # bucket k+1's AG hides behind bucket k's compute; the FIRST bucket
        # (exposed prologue, paper Fig. 2) is bounded by its own compute so
        # comm-dominated graphs don't degenerate into one giant bucket.
        prev_c = comp_time(buckets[-1]) if buckets else comp_time(cur)
        cand = cur + [nd]
        prec = _cfg_precision(cfg)
        t_ag = ag_time(cand, cfg, prec)
        t_rs = rs_time(buckets[-1], cfg, prec) if buckets else 0.0
        time_ok = (t_ag <= prev_c) and (t_rs + t_ag <= prev_c)
        # `cand` already includes nd; counting nd.mem_bytes again would halve
        # the effective cap for the incoming node (regression-tested in
        # tests/test_core.py::test_greedy_mem_cap_not_double_counted).
        mem_ok = sum(c.mem_bytes for c in cand) <= m_max
        if time_ok and mem_ok and k not in cuts:
            cur.append(nd)
        else:
            buckets.append(cur)
            cur = [nd]
    buckets.append(cur)
    return buckets


# ---------------------------------------------------------------------------
# The modeled objective both planners are scored on.
# ---------------------------------------------------------------------------
def partition_exposure(buckets: list[list[CommNode]], cfg: DistConfig,
                       pools: list[int] | None = None,
                       precisions: list[str] | None = None) -> float:
    """Cyclic steady-state exposed collective time of a node partition.

    Without `pools` (one pool per bucket): bucket i's all-gather and bucket
    i-1's (rs_delay'ed) reduce-scatter hide behind bucket i-1's compute,
    bucket 0 wrapping to the last bucket — Algorithm 1's idealized premise,
    which matches the unsegmented runtime at LAYER granularity (one
    whole-layer gather point per layer).

    With `pools` (one id per bucket, consecutive buckets sharing an id form
    one pool): buckets in a pool are all gathered at ONE program point —
    `core/stack.gather_seg` issues every bucket of segment s+1 around
    segment s's compute — so their AG (and the previous pool's RS) hide
    behind the previous POOL's compute collectively; each bucket still pays
    its own collective alpha. This is the executed schedule's exposure for
    segmented blocks: intra-pool bucket boundaries only trade alpha against
    the memory cap, they create no extra hiding windows.

    The one-time prologue gather is amortized over the layer count and
    ignored in both forms.

    With `precisions` (one resolved wire precision per bucket; default = the
    config's uniform precision) each bucket's AG/RS is priced at its own
    wire bytes and the bucket's encode/decode overhead (quant_overhead_s —
    unhidden compute added to the critical path) is included, so the value
    is the objective the precision-aware planners minimize.
    """
    if not buckets:
        return 0.0
    if pools is None:
        pools = list(range(len(buckets)))
    if precisions is None:
        precisions = [_cfg_precision(cfg)] * len(buckets)
    # merge consecutive same-pool buckets into pooled AG/RS/compute terms
    pooled: list[tuple[float, float, float]] = []   # (ag, rs, comp)
    cur_id = None
    overhead = 0.0
    for pid, grp, prec in zip(pools, buckets, precisions):
        if pid != cur_id:
            pooled.append((0.0, 0.0, 0.0))
            cur_id = pid
        ag, rs, cp = pooled[-1]
        pooled[-1] = (ag + ag_time(grp, cfg, prec),
                      rs + rs_time(grp, cfg, prec),
                      cp + comp_time(grp))
        overhead += quant_overhead_s(grp, prec)
    exposed = overhead
    k = len(pooled)
    for i, (ag, _, _) in enumerate(pooled):
        _, rs_prev, comp_prev = pooled[(i - 1) % k]
        exposed += max(0.0, ag + rs_prev - comp_prev)
    return exposed


def per_param_partition(nodes: list[CommNode]) -> list[list[CommNode]]:
    return [[nd] for nd in nodes]


def greedy_partition(nodes: list[CommNode], cfg: DistConfig,
                     mem_limit: float | None = None,
                     cuts: frozenset[int] = frozenset()
                     ) -> list[list[CommNode]]:
    """Greedy buckets, guarded on the cyclic objective: Algorithm 1's local
    merge test is acyclic, so on some workloads a merge it admits *worsens*
    the steady-state exposure — never return a plan worse than no bucketing
    under the planner's own model."""
    if not nodes:
        return []
    buckets = greedy_buckets(nodes, cfg, mem_limit, cuts)
    solo = per_param_partition(nodes)
    if partition_exposure(buckets, cfg) > partition_exposure(solo, cfg):
        return solo
    return buckets


# ---------------------------------------------------------------------------
# Exposure-minimizing dynamic program.
# ---------------------------------------------------------------------------
def _linear_coll(cfg: DistConfig) -> tuple[float, float]:
    """hw.collective_time_s over the FSDP axes is affine in the payload:
    t(n) = alpha + beta*n. Derive (alpha, beta) from the model itself so the
    DP's O(1) interval costs can never drift from the source of truth."""
    alpha = hw.collective_time_s(0.0, cfg.axis_sizes, cfg.fsdp_axes)
    beta = hw.collective_time_s(1.0, cfg.axis_sizes, cfg.fsdp_axes) - alpha
    return alpha, beta


def dp_buckets(nodes: list[CommNode], cfg: DistConfig,
               mem_limit: float | None = None,
               cuts: frozenset[int] = frozenset()) -> list[list[CommNode]]:
    """Exact minimum-exposure contiguous partition (cyclic objective).

    DP over (last-bucket start j, boundary i) states with O(1) interval
    costs from prefix sums; the cyclic wraparound term is closed by
    enumerating the first bucket's end. Feasibility matches greedy: buckets
    of >1 node must fit the memory cap and may not span a forced cut
    (segment boundary). Exhaustive over that set, so the result is <=
    greedy's exposure by construction (asserted in tests and a
    belt-and-braces min at the end).
    """
    n = len(nodes)
    if n == 0:
        return []
    if n == 1:
        return [list(nodes)]
    m_max = cfg.autowrap_mem_limit if mem_limit is None else mem_limit
    alpha, beta = _linear_coll(cfg)

    prec = _cfg_precision(cfg)
    agb = [0.0] * (n + 1)
    rsb = [0.0] * (n + 1)
    cpt = [0.0] * (n + 1)
    memb = [0.0] * (n + 1)
    for i, nd in enumerate(nodes):
        agb[i + 1] = agb[i] + nd.ag_wire(prec)
        rsb[i + 1] = rsb[i] + nd.rs_wire(prec)
        cpt[i + 1] = cpt[i] + nd.t_comp()
        memb[i + 1] = memb[i] + nd.mem_bytes

    def feasible(i: int, j: int) -> bool:          # bucket = nodes[i:j]
        if any(i < c < j for c in cuts):
            return False
        return j - i == 1 or memb[j] - memb[i] <= m_max

    def cost(h: int, i: int, j: int) -> float:     # prev nodes[h:i], cur [i:j]
        t_ag = alpha + beta * (agb[j] - agb[i])
        t_rs = alpha + beta * (rsb[i] - rsb[h])
        return max(0.0, t_ag + t_rs - (cpt[i] - cpt[h]))

    def wrap_cost(j: int, f: int) -> float:        # first [0:f] after last [j:n]
        t_ag = alpha + beta * agb[f]
        t_rs = alpha + beta * (rsb[n] - rsb[j])
        return max(0.0, t_ag + t_rs - (cpt[n] - cpt[j]))

    best_total = math.inf
    best_cut: list[int] | None = None

    if feasible(0, n):   # the single-bucket partition wraps onto itself
        e = max(0.0, (alpha + beta * agb[n]) + (alpha + beta * rsb[n])
                - cpt[n])
        best_total, best_cut = e, [0, n]

    for f in range(1, n):                          # first bucket = nodes[0:f]
        if not feasible(0, f):
            continue
        # dp[i][j]: min exposure of nodes[0:i] whose last bucket is
        # nodes[j:i], counting each non-first bucket's term (the first
        # bucket's own cyclic term is added by wrap_cost at closure).
        dp: list[dict[int, float]] = [dict() for _ in range(n + 1)]
        parent: list[dict[int, int]] = [dict() for _ in range(n + 1)]
        dp[f][0] = 0.0
        for i in range(f, n):
            for j, base in dp[i].items():
                for t in range(i + 1, n + 1):
                    if not feasible(i, t):
                        continue
                    cand = base + cost(j, i, t)
                    if cand < dp[t].get(i, math.inf):
                        dp[t][i] = cand
                        parent[t][i] = j
        for j, val in dp[n].items():
            total = val + wrap_cost(j, f)
            if total < best_total:
                bounds, end, start = [n], n, j
                while start > 0:
                    bounds.append(start)
                    end, start = start, parent[end][start]
                bounds.append(0)
                best_total, best_cut = total, bounds[::-1]

    assert best_cut is not None   # per-param partition is always feasible
    buckets = [list(nodes[a:b]) for a, b in zip(best_cut, best_cut[1:])]

    # Belt and braces: the invariant exposure(dp) <= exposure(greedy) must
    # survive any future drift between cost() and partition_exposure().
    greedy = greedy_partition(nodes, cfg, mem_limit, cuts)
    if partition_exposure(greedy, cfg) < partition_exposure(buckets, cfg):
        return greedy
    return buckets


def dp_buckets_precision(
        nodes: list[CommNode], cfg: DistConfig,
        mem_limit: float | None = None,
        cuts: frozenset[int] = frozenset()
) -> tuple[list[list[CommNode]], list[str]]:
    """Joint partition x per-bucket-precision DP (comm_precision='auto').

    Same interval DP as `dp_buckets`, with states extended by the LAST
    bucket's wire precision (the cyclic cost of bucket i prices bucket i's
    AG at its own precision and bucket i-1's RS at the previous one) and by
    the FIRST bucket's precision (needed to close the wraparound term).
    Each bucket additionally pays its encode/decode overhead
    (quant_overhead_s).  Values are (exposure, quantized-bucket count)
    tuples compared lexicographically, so at equal exposure the plan
    prefers bf16 — quantization must buy modeled time to be chosen.

    The lattice is `AUTO_PRECISIONS` (bf16 + the fp8 and int8 codec
    modes).  fp8 and int8 share identical wire bytes, so analytically
    they tie and strict-< improvement keeps fp8 (listed first); they
    separate only when measured per-codec rates are installed
    (`irgraph.set_measured_quant_rate`, fed by the step profiler /
    `calibration` — core/obs), which reprices quant_overhead_s per codec.
    """
    n = len(nodes)
    if n == 0:
        return [], []
    m_max = cfg.autowrap_mem_limit if mem_limit is None else mem_limit
    alpha, beta = _linear_coll(cfg)
    precs = AUTO_PRECISIONS

    agb = {p: [0.0] * (n + 1) for p in precs}
    rsb = {p: [0.0] * (n + 1) for p in precs}
    ovh = {p: [0.0] * (n + 1) for p in precs}
    cpt = [0.0] * (n + 1)
    memb = [0.0] * (n + 1)
    for i, nd in enumerate(nodes):
        for p in precs:
            agb[p][i + 1] = agb[p][i] + nd.ag_wire(p)
            rsb[p][i + 1] = rsb[p][i] + nd.rs_wire(p)
            ovh[p][i + 1] = ovh[p][i] + quant_overhead_s([nd], p)
        cpt[i + 1] = cpt[i] + nd.t_comp()
        memb[i + 1] = memb[i] + nd.mem_bytes

    def feasible(i: int, j: int) -> bool:          # bucket = nodes[i:j]
        if any(i < c < j for c in cuts):
            return False
        return j - i == 1 or memb[j] - memb[i] <= m_max

    def ag_t(i: int, j: int, p: str) -> float:
        return alpha + beta * (agb[p][j] - agb[p][i])

    def rs_t(i: int, j: int, p: str) -> float:
        return alpha + beta * (rsb[p][j] - rsb[p][i])

    def nq(p: str) -> int:
        return 0 if p == "bf16" else 1

    inf = (math.inf, math.inf)
    best_total, best_sol = inf, None

    for p in precs:                 # single-bucket partition wraps on itself
        if not feasible(0, n):
            break
        e = max(0.0, ag_t(0, n, p) + rs_t(0, n, p) - cpt[n]) + ovh[p][n]
        cand = (e, nq(p))
        if cand < best_total:
            best_total, best_sol = cand, ([0, n], [p])

    for f in range(1, n):                          # first bucket = nodes[0:f]
        if not feasible(0, f):
            continue
        # dp[i][(j, p, pf)]: best (exposure, n_quant) of nodes[0:i] whose
        # last bucket is nodes[j:i] at precision p, with the first bucket
        # (nodes[0:f]) at precision pf; each non-first bucket's cyclic term
        # and every bucket's overhead are counted, the first bucket's own
        # cyclic term closes at wrap-up.
        dp: list[dict] = [dict() for _ in range(n + 1)]
        parent: list[dict] = [dict() for _ in range(n + 1)]
        for pf in precs:
            dp[f][(0, pf, pf)] = (ovh[pf][f], nq(pf))
        for i in range(f, n):
            for (j, p, pf), base in dp[i].items():
                for t in range(i + 1, n + 1):
                    if not feasible(i, t):
                        continue
                    for q in precs:
                        step = max(0.0, ag_t(i, t, q) + rs_t(j, i, p)
                                   - (cpt[i] - cpt[j])) \
                            + ovh[q][t] - ovh[q][i]
                        cand = (base[0] + step, base[1] + nq(q))
                        key = (i, q, pf)
                        if cand < dp[t].get(key, inf):
                            dp[t][key] = cand
                            parent[t][key] = (j, p)
        for (j, p, pf), val in dp[n].items():
            wrap = max(0.0, ag_t(0, f, pf) + rs_t(j, n, p)
                       - (cpt[n] - cpt[j]))
            total = (val[0] + wrap, val[1])
            if total < best_total:
                bounds, pvec = [n], [p]
                end, cur = n, (j, p, pf)
                while cur[0] > 0:
                    bounds.append(cur[0])
                    prev = parent[end][cur]
                    pvec.append(prev[1])
                    end, cur = cur[0], (prev[0], prev[1], pf)
                bounds.append(0)
                best_total = total
                best_sol = (bounds[::-1], pvec[::-1])

    assert best_sol is not None   # per-param partition is always feasible
    best_cut, best_prec = best_sol
    buckets = [list(nodes[a:b]) for a, b in zip(best_cut, best_cut[1:])]

    # Belt and braces, mirroring dp_buckets: never return a plan worse
    # under the shared objective than greedy-at-bf16 with post-hoc local
    # precision assignment.
    greedy = greedy_partition(nodes, cfg, mem_limit, cuts)
    g_prec = _local_precisions(greedy, cfg)
    if partition_exposure(greedy, cfg, precisions=g_prec) \
            < partition_exposure(buckets, cfg, precisions=best_prec):
        return greedy, g_prec
    return buckets, best_prec


def _local_precisions(buckets: list[list[CommNode]], cfg: DistConfig,
                      pools: list[int] | None = None) -> list[str]:
    """Per-bucket precisions for a FIXED partition: one coordinate-descent
    pass over the global exposure objective — each bucket in turn picks the
    precision minimizing partition_exposure with the others held fixed
    (ties prefer bf16, the first lattice entry).  Used when the partition
    came from a planner that did not search precisions jointly."""
    precs = ["bf16"] * len(buckets)
    for b in range(len(buckets)):
        best, best_p = None, "bf16"
        for p in AUTO_PRECISIONS:
            precs[b] = p
            e = partition_exposure(buckets, cfg, pools, precs)
            if best is None or e < best:
                best, best_p = e, p
        precs[b] = best_p
    return precs


# ---------------------------------------------------------------------------
# Plan-level entry points (consumed by bucketing.plan_for).
# ---------------------------------------------------------------------------
def _segment_order(metas_tree, segments):
    """Execution-order view of a segmented block: node permutation
    (segment-major, flatten order within a segment), the forced cuts at
    segment starts (in permuted index space), and the segment id of each
    permuted node. The stack executes gathers in exactly this order."""
    from repro.core.bucketing import assign_segments
    from repro.core.meta import named_leaves

    names = [k for k, _ in named_leaves(metas_tree)]
    seg_of = assign_segments(names, segments.param_globs, segments.names)
    perm = sorted(range(len(names)), key=lambda i: (seg_of[i], i))
    seg_x = [seg_of[i] for i in perm]
    cuts = frozenset(i for i in range(1, len(perm))
                     if seg_x[i] != seg_x[i - 1])
    return perm, cuts, seg_x


def _min_count_packing(nodes: list[CommNode], m_max: float,
                       cuts: frozenset[int]) -> list[list[CommNode]]:
    """Fewest contiguous buckets under the memory cap, closing at forced
    cuts (singletons exempt from the cap, as everywhere). Under the POOLED
    exposure objective this is exact: intra-segment bucket boundaries only
    add collective alpha, so fewer buckets strictly dominate."""
    buckets: list[list[CommNode]] = []
    cur: list[CommNode] = []
    for k, nd in enumerate(nodes):
        if cur and (k in cuts
                    or sum(c.mem_bytes for c in cur) + nd.mem_bytes > m_max):
            buckets.append(cur)
            cur = []
        cur.append(nd)
    if cur:
        buckets.append(cur)
    return buckets


def _active(segments) -> bool:
    return segments is not None and len(segments.fns) > 1


def auto_plan(metas_tree, cfg: DistConfig,
              stats: BlockStats | None = None,
              segments=None) -> BucketPlan:
    """Paper Algorithm 1 (guarded greedy) -> BucketPlan.

    With `segments` (models/common.BlockSegments) the walk runs in
    execution order with forced cuts at segment boundaries and the guard
    scores the POOLED exposure — i.e. the schedule the segmented runtime
    executes, not the flatten-order fiction."""
    nodes = build_nodes(metas_tree, cfg, stats)
    if not _active(segments):
        buckets = greedy_partition(nodes, cfg)
    else:
        perm, cuts, seg_x = _segment_order(metas_tree, segments)
        nodes_x = [nodes[i] for i in perm]
        buckets = greedy_buckets(nodes_x, cfg, cuts=cuts)
        pools = _bucket_pools(buckets, seg_x)
        solo = per_param_partition(nodes_x)
        if partition_exposure(buckets, cfg, pools) \
                > partition_exposure(solo, cfg, seg_x):
            buckets = solo
    return BucketPlan(tuple(tuple(n.name for n in grp) for grp in buckets))


def auto_dp_plan(metas_tree, cfg: DistConfig,
                 stats: BlockStats | None = None,
                 segments=None) -> BucketPlan:
    """Exposure-minimizing planner -> BucketPlan (bucket_mode='auto_dp').

    Unsegmented blocks: the exact interval DP over the cyclic per-bucket
    objective — joint over partition x per-bucket precision when
    comm_precision='auto' (halved wire bytes change the optimal cuts, so
    the dimensions cannot be searched separately). Segmented blocks: the
    executed schedule pools each segment's gathers at one program point, so
    the exact minimizer of the pooled objective is minimum-bucket-count
    packing per segment under the memory cap (fewer collectives = less
    alpha; hiding windows are fixed by the segment chain), with precisions
    assigned per bucket afterwards."""
    nodes = build_nodes(metas_tree, cfg, stats)
    if not _active(segments):
        if cfg.comm_precision == "auto":
            buckets, precs = dp_buckets_precision(nodes, cfg)
            return BucketPlan(
                tuple(tuple(n.name for n in grp) for grp in buckets),
                tuple(precs))
        buckets = dp_buckets(nodes, cfg)
        pools = None
    else:
        m_max = cfg.autowrap_mem_limit
        perm, cuts, seg_x = _segment_order(metas_tree, segments)
        buckets = _min_count_packing([nodes[i] for i in perm], m_max, cuts)
        pools = _bucket_pools(buckets, seg_x)
    groups = tuple(tuple(n.name for n in grp) for grp in buckets)
    if cfg.comm_precision == "auto":
        return BucketPlan(groups,
                          tuple(_local_precisions(buckets, cfg, pools)))
    return BucketPlan(groups)


def assign_precisions(plan: BucketPlan, metas_tree, cfg: DistConfig,
                      stats: BlockStats | None = None) -> BucketPlan:
    """Attach per-bucket precisions to a partition produced without the
    joint search (bucket_mode none/block/auto/manual under
    comm_precision='auto'): coordinate descent on the exposure objective
    over the plan's own groups."""
    if cfg.comm_precision != "auto" or plan.precisions is not None:
        return plan
    nodes = {n.name: n for n in build_nodes(metas_tree, cfg, stats)}
    buckets = [[nodes[name] for name in grp] for grp in plan.groups]
    return BucketPlan(plan.groups, tuple(_local_precisions(buckets, cfg)))


def _bucket_pools(buckets: list[list[CommNode]],
                  seg_of_node: list[int]) -> list[int]:
    """Segment id per bucket, from the segment of each bucket's first node
    (buckets never span segments once cuts are enforced)."""
    pos = 0
    pools = []
    for b in buckets:
        pools.append(seg_of_node[pos])
        pos += len(b)
    return pools


def exposed_comm_time(plan: BucketPlan, metas_tree, cfg: DistConfig,
                      stats: BlockStats | None = None,
                      segments=None) -> dict:
    """Modeled exposure of a plan: how much collective time is NOT hidden.

    With `segments`, the plan is first rewritten to the partition the
    segmented runtime executes (split at segment boundaries, segment-major
    order) and scored with pooled hiding windows — so fig4 /
    BENCH_overlap.json / the dryrun rows all describe the schedule
    core/stack actually runs. Without segments, the per-bucket cyclic model
    (Alg. 1's premise) applies.
    """
    nodes = {n.name: n for n in build_nodes(metas_tree, cfg, stats)}
    pools = None
    if _active(segments):
        from repro.core.bucketing import (assign_segments,
                                          split_plan_at_segments)
        from repro.core.meta import named_leaves

        plan = split_plan_at_segments(plan, metas_tree, segments)
        names = [k for k, _ in named_leaves(metas_tree)]
        seg_of = assign_segments(names, segments.param_globs, segments.names)
        name_seg = dict(zip(names, seg_of))
        pools = [name_seg[grp[0]] for grp in plan.groups]
    groups = [[nodes[name] for name in grp] for grp in plan.groups]
    if plan.precisions is not None:
        precisions = list(plan.precisions)
    else:
        precisions = [_cfg_precision(cfg)] * len(groups)
    total_comm = sum(ag_time(g, cfg, p) + rs_time(g, cfg, p)
                     for g, p in zip(groups, precisions))
    wire = sum(n.ag_wire(p) + n.rs_wire(p)
               for g, p in zip(groups, precisions) for n in g)
    overhead = sum(quant_overhead_s(g, p)
                   for g, p in zip(groups, precisions))
    exposed = partition_exposure(groups, cfg, pools, precisions)
    return {
        # the planners' full objective: unhidden comm + encode/decode cost
        "exposed_s": exposed,
        # the comm component alone (overhead enters linearly, never hidden)
        "exposed_comm_s": exposed - overhead,
        "quant_overhead_s": overhead,
        "total_comm_s": total_comm,
        "compute_s": comp_time(list(nodes.values())),
        "n_buckets": len(groups),
        "comm_wire_bytes": wire,
        "precisions": tuple(precisions),
    }


def auto_layer_group(layer_nodes: list[CommNode], cfg: DistConfig,
                     n_layers: int, mem_limit: float | None = None) -> int:
    """Largest k (dividing n_layers) s.t. k layers' bucketed AG+RS still hides
    behind k layers' compute and fits the memory cap."""
    m_max = cfg.autowrap_mem_limit if mem_limit is None else mem_limit
    best = 1
    for k in range(2, n_layers + 1):
        if n_layers % k:
            continue
        grp = layer_nodes * k
        if ag_time(grp, cfg) + rs_time(grp, cfg) > comp_time(grp):
            break
        # Single-count cap, same accounting as greedy_buckets: the candidate
        # bucket's bytes are counted once (an ad-hoc 2x multiplier here
        # halved the effective cap relative to greedy — regression-tested in
        # tests/test_autowrap.py::test_auto_layer_group_mem_single_counted).
        if sum(n.mem_bytes for n in grp) > m_max:
            break
        best = k
    return best
