"""Distributed configuration: mesh axes, FSDP domain, dtypes, schedule flags.

One frozen `DistConfig` object flows through the whole system (models, core,
train/serve steps). It is the JAX-side analogue of the paper's
``torch._inductor.config.simplefsdp.*`` knobs plus the DTensor device-mesh.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


Dtype = Any  # jnp dtype-like


@dataclasses.dataclass(frozen=True)
class DistConfig:
    # Mesh ------------------------------------------------------------------
    mesh_axes: tuple[str, ...] = ("data", "model")
    mesh_shape: tuple[int, ...] = (16, 16)
    # ZeRO-3 sharding domain for parameters/grads/optimizer states.
    # ('data',)        -> HSDP when a 'pod' axis exists (shard in-pod,
    #                     replicate across pods, grad all-reduce over 'pod')
    # ('pod', 'data')  -> global ZeRO-3 over every data-parallel chip
    fsdp_axes: tuple[str, ...] = ("data",)
    tp_axis: str | None = "model"

    # Mixed precision (paper SS4) --------------------------------------------
    param_dtype: Dtype = jnp.bfloat16    # forward/backward compute dtype
    reduce_dtype: Dtype = jnp.float32    # gradient reduce-scatter dtype
    storage_dtype: Dtype = jnp.float32   # sharded master weights

    # Beyond-paper: cast to param_dtype BEFORE the all-gather (halves AG
    # bytes). The paper gathers in param_dtype too via DTensor forward_dtype;
    # turning this off gathers in storage_dtype (the naive ZeRO-3 baseline).
    gather_in_param_dtype: bool = True

    # SimpleFSDP schedule knobs (paper SS3.2, Tables 5/6) ----------------------
    bucket_mode: str = "block"           # 'none' | 'block' | 'auto'
    reorder: bool = True                 # prefetch next bucket (reordering)
    # Table 6 ablation: issue the prefetch AG before (True) or after (False)
    # the current block's compute, in forward and backward respectively.
    ag_before_wait_fwd: bool = True
    ag_before_wait_bwd: bool = False
    # Delay each reduce-scatter by one layer so it overlaps the next layer's
    # backward compute (paper: "Wr12 placed before RS34").
    rs_delay: bool = True

    # Memory policy -----------------------------------------------------------
    remat: str = "fsdp_only"             # 'none' | 'fsdp_only' | 'full'
    # Auto-wrap memory cap (paper Alg. 1 M_max), bytes of prefetched params.
    autowrap_mem_limit: float = 1.0 * 1024**3

    # Gradient compression: reduce-scatter in bf16 with fp32 master accumulate.
    grad_compression: bool = False

    # int8 KV cache (per-token/head absmax scales) — halves decode HBM.
    kv_cache_int8: bool = False

    # Microbatching (gradient accumulation) for activation memory.
    microbatches: int = 1

    # ------------------------------------------------------------------ utils
    def axis_size(self, name: str) -> int:
        return self.mesh_shape[self.mesh_axes.index(name)]

    @property
    def axis_sizes(self) -> dict[str, int]:
        return dict(zip(self.mesh_axes, self.mesh_shape))

    @property
    def fsdp_size(self) -> int:
        return math.prod(self.axis_size(a) for a in self.fsdp_axes)

    @property
    def tp_size(self) -> int:
        return self.axis_size(self.tp_axis) if self.tp_axis else 1

    @property
    def dp_total(self) -> int:
        """Total data-parallel ways = every axis that is not TP."""
        return math.prod(
            s for a, s in self.axis_sizes.items() if a != self.tp_axis
        )

    @property
    def grad_sync_axes(self) -> tuple[str, ...]:
        """Axes over which params are replicated (grads need all-reduce).

        Under HSDP the 'pod' axis replicates parameters, so gradients are
        psum'ed over it after the in-pod reduce-scatter.
        """
        return tuple(
            a for a in self.mesh_axes
            if a not in self.fsdp_axes and a != self.tp_axis
        )

    @property
    def n_devices(self) -> int:
        return math.prod(self.mesh_shape)

    def with_(self, **kw) -> "DistConfig":
        return dataclasses.replace(self, **kw)


def make_mesh(cfg: DistConfig, devices=None) -> jax.sharding.Mesh:
    if devices is None:
        return jax.make_mesh(
            cfg.mesh_shape,
            cfg.mesh_axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(cfg.mesh_axes),
        )
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(devices).reshape(cfg.mesh_shape), cfg.mesh_axes
    )


def single_device_config(**kw) -> DistConfig:
    """A 1x1 mesh config — used by smoke tests and eager debugging."""
    defaults = dict(mesh_axes=("data", "model"), mesh_shape=(1, 1))
    defaults.update(kw)
    return DistConfig(**defaults)
