"""Distributed configuration: mesh axes, FSDP domain, dtypes, schedule flags.

One frozen `DistConfig` object flows through the whole system (models, core,
train/serve steps). It is the JAX-side analogue of the paper's
``torch._inductor.config.simplefsdp.*`` knobs plus the DTensor device-mesh.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


Dtype = Any  # jnp dtype-like

# Wire-precision vocabulary for the bucket collectives (see
# DistConfig.comm_precision) and the per-bucket lattice the auto_dp planner
# searches over.  'fp8'/'int8' (stateless SR reduce-scatter, no error
# feedback) are valid config values but not in the auto lattice: at equal
# wire bytes the *_ef variants strictly dominate them on convergence.
# int8 and fp8 occupy the same wire format (1 byte/elem + per-chunk f32
# scales), so the planner can only separate them through a measured codec
# rate (`irgraph.set_measured_quant_rate(rate, codec)`, harvested by
# `launch/dryrun.harvest_quant_timing` / the step profiler); the int8
# entries sit AFTER fp8 in the lattice, and every planner improves on
# strict `<` only — with no measured rates installed the resolved plans
# are unchanged.
COMM_PRECISIONS = ("bf16", "fp8_ag", "fp8", "fp8_ef",
                   "int8_ag", "int8", "int8_ef", "auto")
AUTO_PRECISIONS = ("bf16", "fp8_ag", "fp8_ef", "int8_ag", "int8_ef")


def precision_codecs(precision: str) -> tuple[str | None, str | None]:
    """(all-gather codec, reduce-scatter codec) of one RESOLVED precision —
    None means uncompressed.  'auto' must be resolved to a per-bucket
    precision by the planner before reaching here."""
    return {
        "bf16": (None, None),
        "fp8_ag": ("fp8", None),
        "fp8": ("fp8", "fp8"),
        "fp8_ef": ("fp8", "fp8"),
        "int8_ag": ("int8", None),
        "int8": ("int8", "int8"),
        "int8_ef": ("int8", "int8"),
    }[precision]


@dataclasses.dataclass(frozen=True)
class DistConfig:
    # Mesh ------------------------------------------------------------------
    mesh_axes: tuple[str, ...] = ("data", "model")
    mesh_shape: tuple[int, ...] = (16, 16)
    # ZeRO-3 sharding domain for parameters/grads/optimizer states.
    # ('data',)        -> HSDP when a 'pod' axis exists (shard in-pod,
    #                     replicate across pods, grad all-reduce over 'pod')
    # ('pod', 'data')  -> global ZeRO-3 over every data-parallel chip
    fsdp_axes: tuple[str, ...] = ("data",)
    tp_axis: str | None = "model"

    # Pipeline parallelism (paper SS4 "Pipeline Parallel") --------------------
    # When set, the named mesh axis holds one pipeline stage per rank: stage
    # parameters are ordinary SimpleFSDP storage sharded over `fsdp_axes`
    # WITHIN each pipe rank, and activations stream between stages with
    # ppermute inside the same shard_map (core/pipeline.py).  Convention:
    # 'pipe' is the OUTERMOST mesh axis — per-slot activation traffic is tiny
    # point-to-point, so it tolerates the slowest interconnect, while the fat
    # FSDP gathers stay on the inner (ICI) axes.
    pp_axis: str | None = None
    # 'gpipe' | '1f1b' | 'interleaved' | 'zb' | 'auto'.  'interleaved' gives
    # each pipe rank V non-contiguous virtual stage slices (bubble / V);
    # 'zb' splits the backward into input-grad (Bx) and weight-grad (W)
    # halves so the W work fills the cooldown bubble; 'auto' lets
    # plan_parallel score every valid schedule (bubble_fraction + the memory
    # simulator) and pick the argmin (core/pipeline.py, core/api.py).
    pp_schedule: str = "gpipe"
    # Virtual stages per pipe rank for the interleaved schedule (0 = let the
    # planner pick the smallest divisor >= 2 of layers_per_stage).  Ignored
    # by the other schedules.
    pp_virtual: int = 0
    # Expected microbatch count M per pipelined step; 0 accepts any M.
    # When set, pipeline_grads rejects an xs stack whose leading dim
    # disagrees (M is otherwise inferred from xs).  GPipe keeps M live
    # activations per stage; 1F1B bounds that to S (see core/pipeline.py).
    pp_microbatches: int = 0

    # Context parallelism (core/context.py) -----------------------------------
    # When set, the named mesh axis shards the SEQUENCE dimension: every
    # batch row is split into load-balanced zigzag chunks (rank r owns
    # chunks r and 2*cp-1-r of 2*cp, so each rank carries equal causal
    # attention work) and attention runs as a ring — KV blocks circulate
    # over the ctx axis via ppermute, the next hop's exchange overlapped
    # behind the current chunk's attention compute.  Convention: 'ctx' sits
    # BETWEEN the data and model axes — its per-hop ppermute traffic (one
    # KV block) is lighter than the fat FSDP all-gathers on 'data' but
    # heavier/more frequent than pipeline sends, while TP psums stay
    # innermost.  The ctx axis must be part of `fsdp_axes`: parameters are
    # then ZeRO-3 sharded over data x ctx and every cross-rank gradient
    # flow (bucket reduce-scatter, ring reverse permute) is an explicit
    # collective with an exact transpose — no reliance on vma
    # replication-transpose (exact on every jax, like core/pipeline).
    cp_axis: str | None = None

    # Mixed precision (paper SS4) --------------------------------------------
    param_dtype: Dtype = jnp.bfloat16    # forward/backward compute dtype
    reduce_dtype: Dtype = jnp.float32    # gradient reduce-scatter dtype
    storage_dtype: Dtype = jnp.float32   # sharded master weights

    # Beyond-paper: cast to param_dtype BEFORE the all-gather (halves AG
    # bytes). The paper gathers in param_dtype too via DTensor forward_dtype;
    # turning this off gathers in storage_dtype (the naive ZeRO-3 baseline).
    gather_in_param_dtype: bool = True

    # SimpleFSDP schedule knobs (paper SS3.2, Tables 5/6) ----------------------
    # 'none' | 'block' | 'auto' (greedy Alg. 1) | 'auto_dp' (exposure-
    # minimizing DP, core/autowrap.py) | an explicit BucketPlan.
    bucket_mode: str = "block"
    reorder: bool = True                 # prefetch next bucket (reordering)
    # Pipeline the prefetch at BUCKET granularity when the model declares
    # block segments (models/common.BlockSegments): segment b's compute
    # overlaps bucket b+1's all-gather within the layer, and the last bucket
    # prefetches layer i+1's first bucket across the boundary. Off = one
    # whole-layer gather point per layer (the pre-v2 schedule).
    segment_prefetch: bool = True
    # Table 6 ablation: issue the prefetch AG before (True) or after (False)
    # the current block's compute, in forward and backward respectively.
    ag_before_wait_fwd: bool = True
    ag_before_wait_bwd: bool = False
    # Delay each reduce-scatter by one layer so it overlaps the next layer's
    # backward compute (paper: "Wr12 placed before RS34").
    rs_delay: bool = True

    # Memory policy -----------------------------------------------------------
    # Activation-checkpoint spec (core/remat.py, ONE vocabulary):
    #   'none' | 'fsdp_only' | 'full' | 'save_dots'   — uniform policy
    #   'auto:<GB>'    — budgeted auto-SAC: core/memory picks the cheapest
    #                    per-segment vector (+ offload) whose modeled peak
    #                    fits the per-device HBM budget (resolved once by
    #                    core/api.plan_parallel)
    #   'attn=full,mlp=fsdp_only' — an explicit per-segment vector
    remat: str = "fsdp_only"
    # Auto-wrap memory cap (paper Alg. 1 M_max), bytes of prefetched params.
    autowrap_mem_limit: float = 1.0 * 1024**3

    # Gradient compression: reduce-scatter in bf16 with fp32 master accumulate.
    grad_compression: bool = False

    # Quantized collectives (kernels/quant): per-128-chunk-scaled fp8 e4m3
    # wire format for the bucket collectives.  Modes:
    #   'bf16'    — off (bit-exact today's path; the name is the wire story:
    #               payloads already travel in param/reduce dtype)
    #   'fp8_ag'  — quantize param all-gathers only (deterministic RTN;
    #               grads stay full precision)
    #   'fp8'     — AG + stochastically-rounded grad reduce-scatter
    #               (unbiased, stateless — Markov et al.'s SR condition)
    #   'fp8_ef'  — 'fp8' plus a persistent per-shard error-feedback
    #               accumulator in the optimizer state compensating the
    #               reduced shard's wire format (optim/adamw.py)
    #   'int8_ag' / 'int8' / 'int8_ef' — the same three modes on the int8
    #               wire codec (identical wire bytes; chosen over fp8 only
    #               when a measured codec rate makes it cheaper)
    #   'auto'    — the auto_dp planner picks per-BUCKET from
    #               AUTO_PRECISIONS (bf16 + the fp8/int8 *_ag and *_ef
    #               variants) jointly with the partition
    comm_precision: str = "bf16"

    # Quantized KV cache: serving caches/pages store wire-codec values +
    # per-128-chunk f32 scales (kernels/quant — the SAME audited codec the
    # quantized collectives use).  'int8' | 'fp8' | None.  The legacy
    # ``kv_cache_int8`` bool is kept as an alias for codec='int8'.
    kv_cache_codec: str | None = None
    kv_cache_int8: bool = False

    # Microbatching (gradient accumulation) for activation memory.
    microbatches: int = 1

    def __post_init__(self):
        if self.comm_precision not in COMM_PRECISIONS:
            raise ValueError(
                f"comm_precision={self.comm_precision!r} not in "
                f"{COMM_PRECISIONS}")
        if self.kv_cache_codec not in (None, "int8", "fp8"):
            raise ValueError(
                f"kv_cache_codec={self.kv_cache_codec!r} not in "
                f"(None, 'int8', 'fp8')")

    @property
    def kv_codec(self) -> str | None:
        """Resolved KV-cache wire codec (kernels/quant vocabulary)."""
        return self.kv_cache_codec or ("int8" if self.kv_cache_int8
                                       else None)

    # ------------------------------------------------------------------ utils
    @property
    def needs_ef(self) -> bool:
        """Whether the optimizer state carries the error-feedback
        accumulator: the *_ef modes always, 'auto' too (the planner may
        assign an _ef precision to any bucket, and the state tree's
        structure must not depend on the plan)."""
        return self.comm_precision in ("fp8_ef", "int8_ef", "auto")

    def axis_size(self, name: str) -> int:
        return self.mesh_shape[self.mesh_axes.index(name)]

    @property
    def axis_sizes(self) -> dict[str, int]:
        return dict(zip(self.mesh_axes, self.mesh_shape))

    @property
    def fsdp_size(self) -> int:
        return math.prod(self.axis_size(a) for a in self.fsdp_axes)

    @property
    def tp_size(self) -> int:
        return self.axis_size(self.tp_axis) if self.tp_axis else 1

    @property
    def pp_size(self) -> int:
        """Number of pipeline stages (1 when no pipe axis is configured)."""
        return self.axis_size(self.pp_axis) if self.pp_axis else 1

    @property
    def cp_size(self) -> int:
        """Context-parallel degree (1 when no ctx axis is configured)."""
        return self.axis_size(self.cp_axis) if self.cp_axis else 1

    @property
    def dp_total(self) -> int:
        """Total data-parallel ways = every axis that is not TP or PP.

        Pipe ranks hold DIFFERENT stage parameters and see the same
        microbatch stream, so the pipe axis is neither a data- nor a
        tensor-parallel domain.  The ctx axis COUNTS here: cp ranks hold
        disjoint token shards of the same rows, so the per-device-mean
        gradient convention (reduce-scatter divides by dp_total) treats
        sequence shards exactly like batch shards.
        """
        return math.prod(
            s for a, s in self.axis_sizes.items()
            if a != self.tp_axis and a != self.pp_axis
        )

    @property
    def batch_dp(self) -> int:
        """Batch-ROW sharding ways: dp_total without the ctx axis (cp ranks
        replicate rows and shard the sequence dim instead)."""
        return self.dp_total // self.cp_size

    @property
    def grad_sync_axes(self) -> tuple[str, ...]:
        """Axes over which params are replicated (grads need all-reduce).

        Under HSDP the 'pod' axis replicates parameters, so gradients are
        psum'ed over it after the in-pod reduce-scatter.  The pipe axis is
        excluded: each pipe rank owns a distinct stage, nothing to sync.
        """
        return tuple(
            a for a in self.mesh_axes
            if a not in self.fsdp_axes and a != self.tp_axis
            and a != self.pp_axis
        )

    @property
    def n_devices(self) -> int:
        return math.prod(self.mesh_shape)

    def with_(self, **kw) -> "DistConfig":
        return dataclasses.replace(self, **kw)


def make_mesh(cfg: DistConfig, devices=None) -> jax.sharding.Mesh:
    from repro.core import compat

    return compat.make_mesh(cfg.mesh_shape, cfg.mesh_axes, devices=devices)


def single_device_config(**kw) -> DistConfig:
    """A 1x1 mesh config — used by smoke tests and eager debugging."""
    defaults = dict(mesh_axes=("data", "model"), mesh_shape=(1, 1))
    defaults.update(kw)
    return DistConfig(**defaults)
