"""Activation-checkpoint policies: the paper's selective-AC FSDP trick.

Paper Fig. 1(1): the SAC policy marks exactly the FSDP collectives
(``all_gather_into_tensor`` / ``wait_tensor``) as MUST_RECOMPUTE so gathered
parameters are dropped after forward use and re-gathered before backward use.

JAX equivalent: gathered tensors are tagged ``checkpoint_name('fsdp_gather')``
(core/collectives.py) and blocks are wrapped in ``jax.checkpoint`` with a
policy that refuses to save that name. ``'full'`` additionally recomputes all
block-internal activations (the paper's "Full AC" rows); ``'none'`` disables
remat entirely (the paper's "no AC" row of Table 3 — note it then saves the
*gathered* params, which is why SimpleFSDP-noAC uses more memory than FSDP2
in the paper; we reproduce that behaviour faithfully).
"""

from __future__ import annotations

import jax

from repro.core.collectives import FSDP_GATHER_NAME

POLICIES = ("none", "fsdp_only", "full", "save_dots")


def checkpoint_policy(kind: str):
    if kind == "fsdp_only":
        return jax.checkpoint_policies.save_anything_except_these_names(
            FSDP_GATHER_NAME
        )
    if kind == "full":
        return jax.checkpoint_policies.nothing_saveable
    if kind == "save_dots":
        # paper SS5.1: whole-model compile saves SDPA outputs only; closest
        # native policy — keep matmul outputs, recompute elementwise + gathers.
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    raise ValueError(f"unknown remat policy {kind!r}")


def maybe_remat(fn, kind: str):
    """Wrap a block function according to the remat policy."""
    if kind == "none":
        return fn
    return jax.checkpoint(fn, policy=checkpoint_policy(kind))
