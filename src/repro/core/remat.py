"""Activation-checkpoint policies: the paper's selective-AC FSDP trick.

Paper Fig. 1(1): the SAC policy marks exactly the FSDP collectives
(``all_gather_into_tensor`` / ``wait_tensor``) as MUST_RECOMPUTE so gathered
parameters are dropped after forward use and re-gathered before backward use.

JAX equivalent: gathered tensors are tagged ``checkpoint_name('fsdp_gather')``
(core/collectives.py) and blocks are wrapped in ``jax.checkpoint`` with a
policy that refuses to save that name. ``'full'`` additionally recomputes all
block-internal activations (the paper's "Full AC" rows); ``'none'`` disables
remat entirely (the paper's "no AC" row of Table 3 — note it then saves the
*gathered* params, which is why SimpleFSDP-noAC uses more memory than FSDP2
in the paper; we reproduce that behaviour faithfully).

This module is the ONE place the remat vocabulary lives:

  * ``POLICIES``      — the four concrete per-segment policies;
  * ``"auto:<GB>"``   — the budgeted form: `core/memory` picks the cheapest
    per-segment policy vector (plus optional host offload) whose modeled
    peak fits the HBM budget.  ``parse_remat`` validates both forms with
    pointed errors and is called ONCE by `core/api.plan_parallel` (and by
    `core/stack.apply_stack` when it self-resolves at trace time), so a
    malformed string fails at plan time, not at first trace.
"""

from __future__ import annotations

import math

import jax

from repro.core.collectives import FSDP_GATHER_NAME

POLICIES = ("none", "fsdp_only", "full", "save_dots")
AUTO_PREFIX = "auto"
VECTOR_KIND = "vector"

# memory aggressiveness order, least -> most residuals DROPPED: 'none' saves
# everything (incl. gathers), 'fsdp_only' everything but gathers,
# 'save_dots' only dot outputs, 'full' only the block input — the same
# ordering the simulator's peak monotonicity asserts.  Used when a
# whole-block wrap must represent a per-segment vector (core/pipeline's BYO
# stage fn, the segment_prefetch-off collapse).
_AGGRESSIVENESS = ("none", "fsdp_only", "save_dots", "full")


def parse_remat(spec) -> tuple[str, float | None]:
    """Validate a remat spec -> (kind, budget_bytes).

    `kind` is one of POLICIES, ``"auto"`` or ``"vector"`` (a comma-joined
    per-segment form, see `parse_policy_vector`); `budget_bytes` is the
    parsed HBM budget for the auto form (None otherwise).  Raises a pointed
    ValueError for malformed strings — ``auto`` / ``auto:`` without a
    budget, a non-numeric or non-positive budget, or an unknown policy.
    """
    if not isinstance(spec, str):
        raise ValueError(
            f"remat must be a string, got {type(spec).__name__}; one of "
            f"{POLICIES} or 'auto:<GB>' (e.g. 'auto:12.5')")
    if "," in spec or "=" in spec:
        parse_policy_vector(spec)        # validates each entry pointedly
        return VECTOR_KIND, None
    if spec == AUTO_PREFIX or spec.startswith(AUTO_PREFIX + ":"):
        body = spec[len(AUTO_PREFIX):]
        if not body or body == ":":
            raise ValueError(
                f"remat={spec!r}: the auto form needs an HBM budget in GiB "
                "after the colon, e.g. remat='auto:12.5'")
        try:
            gb = float(body[1:])
        except ValueError:
            raise ValueError(
                f"remat={spec!r}: budget {body[1:]!r} is not a number; "
                "expected remat='auto:<GB>' with a positive GiB value") \
                from None
        # NaN fails every comparison, so `gb <= 0` alone would let a NaN
        # budget through and the planner would accept every candidate
        if not math.isfinite(gb) or gb <= 0:
            raise ValueError(
                f"remat={spec!r}: budget must be a finite GiB value > 0")
        return AUTO_PREFIX, gb * 1024**3
    if spec not in POLICIES:
        raise ValueError(
            f"unknown remat policy {spec!r}; one of {POLICIES} or "
            "'auto:<GB>'")
    return spec, None


def parse_policy_vector(spec: str) -> tuple[tuple[str | None, str], ...]:
    """Parse the resolved per-segment form into ((seg_name|None, policy), ...).

    Grammar (comma-joined, one entry per block segment in execution order):

        "full,fsdp_only"            positional
        "attn=full,mlp=fsdp_only"   named (models/common.BlockSegments names)

    This is the form `plan_parallel` writes back into the executed
    DistConfig once ``remat="auto:<GB>"`` is resolved, and users may set it
    directly to pin a hand-chosen vector.
    """
    entries = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            raise ValueError(
                f"remat={spec!r}: empty entry in the per-segment vector")
        name, _, pol = part.rpartition("=")
        name = name or None
        if pol not in POLICIES:
            raise ValueError(
                f"remat={spec!r}: unknown policy {pol!r} in the per-segment "
                f"vector; each entry must be one of {POLICIES}")
        entries.append((name, pol))
    named = [n is not None for n, _ in entries]
    if any(named) and not all(named):
        raise ValueError(
            f"remat={spec!r}: mix of named (seg=policy) and positional "
            "entries; use one form")
    return tuple(entries)


def resolve_segment_policies(spec: str, seg_names) -> tuple[str, ...]:
    """One concrete policy per block segment for a validated remat spec.

    Uniform specs broadcast over the segments; vector specs must match the
    segment count (positional) or name every segment exactly once (named).
    ``"auto:<GB>"`` cannot be resolved here — it must have been replaced by
    the planner's vector before trace time (`core/api.plan_parallel`).
    """
    seg_names = tuple(seg_names)
    kind, _ = parse_remat(spec)
    if kind == AUTO_PREFIX:
        raise ValueError(
            f"remat={spec!r} reached the runtime unresolved; the budgeted "
            "auto form is resolved to a per-segment vector by "
            "core/api.plan_parallel — go through parallelize()/plan_parallel "
            "or set an explicit policy (vector)")
    if kind != VECTOR_KIND:
        return (kind,) * max(1, len(seg_names))
    entries = parse_policy_vector(spec)
    if entries[0][0] is None:                       # positional
        if len(entries) != max(1, len(seg_names)):
            raise ValueError(
                f"remat={spec!r}: {len(entries)} entries for "
                f"{max(1, len(seg_names))} block segment(s) "
                f"{seg_names or '(unsegmented)'}")
        return tuple(p for _, p in entries)
    by_name = dict(entries)
    if len(by_name) != len(entries):
        raise ValueError(f"remat={spec!r}: a segment is named twice")
    missing = [s for s in seg_names if s not in by_name]
    unknown = [n for n in by_name if n not in seg_names]
    if missing or unknown or not seg_names:
        raise ValueError(
            f"remat={spec!r}: named entries must cover the block segments "
            f"{seg_names} exactly; missing={missing} unknown={unknown}")
    return tuple(by_name[s] for s in seg_names)


def most_aggressive(policies) -> str:
    """The most memory-aggressive entry of a policy vector — what a
    whole-block wrap must use so it never saves more than the vector
    promised (the collapse rule for paths that cannot apply a vector)."""
    return max(policies, key=_AGGRESSIVENESS.index)


def whole_block_policy(spec: str) -> str:
    """Collapse a (possibly per-segment) spec to ONE policy for whole-block
    wraps that cannot apply a vector (core/pipeline's bring-your-own stage
    fn)."""
    kind, _ = parse_remat(spec)
    if kind == AUTO_PREFIX:
        raise ValueError(
            f"remat={spec!r} reached the runtime unresolved (see "
            "resolve_segment_policies)")
    if kind != VECTOR_KIND:
        return kind
    return most_aggressive([p for _, p in parse_policy_vector(spec)])


def checkpoint_policy(kind: str):
    if kind == "fsdp_only":
        return jax.checkpoint_policies.save_anything_except_these_names(
            FSDP_GATHER_NAME
        )
    if kind == "full":
        return jax.checkpoint_policies.nothing_saveable
    if kind == "save_dots":
        # paper SS5.1: whole-model compile saves SDPA outputs only; closest
        # native policy — keep matmul outputs, recompute elementwise + gathers.
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    raise ValueError(f"unknown remat policy {kind!r}")


def maybe_remat(fn, kind: str):
    """Wrap a block function according to the remat policy."""
    if kind == "none":
        return fn
    return jax.checkpoint(fn, policy=checkpoint_policy(kind))
