"""Bucket plans: which parameters share one all-gather / reduce-scatter.

The paper's TorchInductor pass merges communication IR nodes; here a
`BucketPlan` is an explicit partition of a block's parameter leaves into
ordered groups. It is produced either

  * manually (`manual_plan`) from user module-name lists — the paper's
    manual wrapping (FSDP2-style per-transformer-block in the evals), or
  * automatically (`core/autowrap.py`) by the greedy Algorithm 1.

The runtime consumers are `collectives.replicate_tree` (vanilla path) and
`core/stack.py` (prefetch-scheduled scan), which issue ONE packed collective
per group.
"""

from __future__ import annotations

import dataclasses
import fnmatch

import jax

from repro.core.dist import DistConfig
from repro.core.meta import ParamMeta, named_leaves


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Ordered partition of parameter names into gather groups."""

    groups: tuple[tuple[str, ...], ...]

    def index_groups(self, metas_tree) -> list[list[int]]:
        """Map name groups -> leaf indices in tree-flatten order."""
        names = [k for k, _ in named_leaves(metas_tree)]
        pos = {n: i for i, n in enumerate(names)}
        seen: set[str] = set()
        out: list[list[int]] = []
        for grp in self.groups:
            idxs = []
            for name in grp:
                if name not in pos:
                    raise KeyError(f"bucket plan names unknown param {name!r};"
                                   f" known: {names[:8]}...")
                idxs.append(pos[name])
                seen.add(name)
            out.append(sorted(idxs))
        missing = [n for n in names if n not in seen]
        if missing:  # unplanned params gather individually (paper default)
            out.extend([[pos[n]] for n in missing])
        return out

    @property
    def n_buckets(self) -> int:
        return len(self.groups)

    def bucket_bytes(self, metas_tree, cfg: DistConfig) -> list[int]:
        """Gathered payload per bucket (param_dtype bytes) — feeds Alg. 1."""
        import jax.numpy as jnp

        metas = dict(named_leaves(metas_tree))
        itemsize = jnp.dtype(cfg.param_dtype).itemsize
        return [
            sum(metas[n].padded_len(cfg) * itemsize for n in grp)
            for grp in self.groups
        ]


def per_param_plan(metas_tree) -> BucketPlan:
    """No bucketing: one collective per parameter (paper's 'vanilla')."""
    return BucketPlan(tuple((k,) for k, _ in named_leaves(metas_tree)))


def whole_block_plan(metas_tree) -> BucketPlan:
    """One bucket for the whole block — the paper's per-transformer-block
    manual wrapping used in its main evals."""
    return BucketPlan((tuple(k for k, _ in named_leaves(metas_tree)),))


def manual_plan(metas_tree, module_lists: list[list[str]]) -> BucketPlan:
    """Bucket by user-provided module name (glob) lists, in order.

    Mirrors the paper's manual wrapping: each inner list is one bucket; a
    name matches if any glob in the list matches the param path.
    """
    names = [k for k, _ in named_leaves(metas_tree)]
    taken: set[str] = set()
    groups: list[tuple[str, ...]] = []
    for globs in module_lists:
        grp = tuple(
            n for n in names
            if n not in taken and any(fnmatch.fnmatch(n, g) for g in globs)
        )
        if grp:
            groups.append(grp)
            taken.update(grp)
    return BucketPlan(tuple(groups))


def plan_for(metas_tree, cfg: DistConfig, block_stats=None) -> BucketPlan:
    """Resolve cfg.bucket_mode into a concrete plan for one block."""
    if cfg.bucket_mode == "none":
        return per_param_plan(metas_tree)
    if cfg.bucket_mode == "block":
        return whole_block_plan(metas_tree)
    if cfg.bucket_mode == "auto":
        from repro.core.autowrap import auto_plan

        return auto_plan(metas_tree, cfg, block_stats)
    if isinstance(cfg.bucket_mode, BucketPlan):
        return cfg.bucket_mode
    raise ValueError(f"unknown bucket_mode {cfg.bucket_mode!r}")
