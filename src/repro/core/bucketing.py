"""Bucket plans: which parameters share one all-gather / reduce-scatter.

The paper's TorchInductor pass merges communication IR nodes; here a
`BucketPlan` is an explicit partition of a block's parameter leaves into
ordered groups. It is produced either

  * manually (`manual_plan`) from user module-name lists — the paper's
    manual wrapping (FSDP2-style per-transformer-block in the evals), or
  * automatically (`core/autowrap.py`) by the greedy Algorithm 1
    (``bucket_mode="auto"``) or by the exposure-minimizing interval DP
    (``bucket_mode="auto_dp"``).

The runtime consumers are `collectives.replicate_tree` (vanilla path) and
`core/stack.py` (prefetch-scheduled scan), which issue ONE packed collective
per group.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import logging

import jax

from repro.core.dist import DistConfig
from repro.core.meta import ParamMeta, named_leaves

log = logging.getLogger("repro.bucketing")


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Ordered partition of parameter names into gather groups."""

    groups: tuple[tuple[str, ...], ...]
    # Per-group resolved wire precision (core/dist.COMM_PRECISIONS minus
    # 'auto'), aligned with `groups`.  None = every bucket at the config's
    # own (non-auto) comm_precision; set by the auto planners when
    # comm_precision='auto'.
    precisions: tuple[str, ...] | None = None

    def index_groups(self, metas_tree) -> list[list[int]]:
        """Map name groups -> leaf indices in tree-flatten order."""
        names = [k for k, _ in named_leaves(metas_tree)]
        pos = {n: i for i, n in enumerate(names)}
        seen: set[str] = set()
        out: list[list[int]] = []
        for grp in self.groups:
            idxs = []
            for name in grp:
                if name not in pos:
                    raise KeyError(f"bucket plan names unknown param {name!r};"
                                   f" known: {names[:8]}...")
                idxs.append(pos[name])
                seen.add(name)
            out.append(sorted(idxs))
        missing = [n for n in names if n not in seen]
        if missing:  # unplanned params gather individually (paper default)
            out.extend([[pos[n]] for n in missing])
        return out

    @property
    def n_buckets(self) -> int:
        return len(self.groups)

    def bucket_bytes(self, metas_tree, cfg: DistConfig) -> list[int]:
        """Gathered payload per bucket (param_dtype bytes) — feeds Alg. 1."""
        import jax.numpy as jnp

        from repro.core.irgraph import wire_bytes

        metas = dict(named_leaves(metas_tree))
        itemsize = jnp.dtype(cfg.param_dtype).itemsize
        return [
            sum(wire_bytes(metas[n].padded_len(cfg), itemsize) for n in grp)
            for grp in self.groups
        ]

    def group_precisions(self, metas_tree, cfg: DistConfig) -> list[str]:
        """Resolved per-bucket wire precision aligned with `index_groups`
        output (unplanned params gather individually at the default).  The
        default is the config's own precision, with 'auto' degrading to
        bf16 for any bucket the planner did not annotate."""
        default = cfg.comm_precision if cfg.comm_precision != "auto" \
            else "bf16"
        n_groups = len(self.index_groups(metas_tree))
        out = list(self.precisions) if self.precisions is not None \
            else [default] * len(self.groups)
        out += [default] * (n_groups - len(out))
        return out


def assign_segments(names: list[str], param_globs, seg_names) -> list[int]:
    """Map each block-param name to the first segment whose globs match it
    (models/common.BlockSegments contract; consumed by core/stack and the
    segment-aware planners). Raises on unassigned params."""
    seg_of: list = [None] * len(names)
    for s, globs in enumerate(param_globs):
        for i, n in enumerate(names):
            if seg_of[i] is None and any(fnmatch.fnmatch(n, g)
                                         for g in globs):
                seg_of[i] = s
    missing = [n for n, s in zip(names, seg_of) if s is None]
    if missing:
        raise ValueError(
            f"block segments {tuple(seg_names)} leave params unassigned: "
            f"{missing}; every param must match one segment's globs")
    return seg_of


def split_plan_at_segments(plan: BucketPlan, metas_tree,
                           segments) -> BucketPlan:
    """The partition the runtime executes for `plan` under a segmented
    block: groups split at segment boundaries (a bucket must be gathered no
    later than the first segment consuming any of its params), segment-major
    order. THE single implementation of this rewrite — core/stack applies it
    before scheduling and exposed_comm_time before scoring, so 'scored' and
    'executed' cannot drift."""
    if segments is None:
        return plan
    names = [k for k, _ in named_leaves(metas_tree)]
    seg_of = assign_segments(names, segments.param_globs, segments.names)
    pos = {n: i for i, n in enumerate(names)}
    n_seg = len(segments.names)
    out: list[list[tuple[str, ...]]] = [[] for _ in range(n_seg)]
    out_prec: list[list[str]] = [[] for _ in range(n_seg)]
    precs = None
    if plan.precisions is not None:
        # appended singletons (params the plan left out) carry bf16, the
        # same default group_precisions resolves for them
        precs = list(plan.precisions)
    for gi, grp in enumerate(plan.index_groups(metas_tree)):
        parent_prec = precs[gi] if precs is not None and gi < len(precs) \
            else "bf16"
        by_seg: dict[int, list[int]] = {}
        for i in grp:
            by_seg.setdefault(seg_of[i], []).append(i)
        for s in sorted(by_seg):
            out[s].append(tuple(names[i] for i in sorted(by_seg[s])))
            out_prec[s].append(parent_prec)
    return BucketPlan(
        tuple(g for s in range(n_seg) for g in out[s]),
        tuple(p for s in range(n_seg) for p in out_prec[s])
        if precs is not None else None)


def per_param_plan(metas_tree) -> BucketPlan:
    """No bucketing: one collective per parameter (paper's 'vanilla')."""
    return BucketPlan(tuple((k,) for k, _ in named_leaves(metas_tree)))


def whole_block_plan(metas_tree) -> BucketPlan:
    """One bucket for the whole block — the paper's per-transformer-block
    manual wrapping used in its main evals."""
    return BucketPlan((tuple(k for k, _ in named_leaves(metas_tree)),))


def manual_plan(metas_tree, module_lists: list[list[str]]) -> BucketPlan:
    """Bucket by user-provided module name (glob) lists, in order.

    Mirrors the paper's manual wrapping: each inner list is one bucket; a
    name matches if any glob in the list matches the param path.
    """
    names = [k for k, _ in named_leaves(metas_tree)]
    taken: set[str] = set()
    groups: list[tuple[str, ...]] = []
    for globs in module_lists:
        grp = tuple(
            n for n in names
            if n not in taken and any(fnmatch.fnmatch(n, g) for g in globs)
        )
        if grp:
            groups.append(grp)
            taken.update(grp)
    return BucketPlan(tuple(groups))


# ---------------------------------------------------------------------------
# Plan resolution + memoization.
#
# plan_for runs at TRACE time, once per layer-stack trace — and jit retraces
# (new shapes, donated buffers, microbatch variants) would re-run the auto
# planners (the DP one is exhaustive) on identical inputs. Plans depend only
# on (named metas, cfg, stats), all value-like, so they are memoized on that
# key; the chosen auto plan and its modeled exposure are logged once per key
# (the dryrun path records the same numbers into its result rows).
# ---------------------------------------------------------------------------
_PLAN_CACHE: dict[tuple, BucketPlan] = {}


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


def _plan_cache_key(metas_tree, cfg: DistConfig, block_stats,
                    seg_key) -> tuple:
    import jax.numpy as jnp

    metas_key = tuple(
        (k, m.global_shape, m.tp_dim, str(jnp.dtype(m.dtype)))
        for k, m in named_leaves(metas_tree)
    )
    stats_key = block_stats.cache_key() if block_stats is not None else None
    return (metas_key, cfg, stats_key, seg_key)


def _resolve_plan(metas_tree, cfg: DistConfig, block_stats,
                  segments) -> BucketPlan:
    if cfg.bucket_mode == "none":
        plan = per_param_plan(metas_tree)
    elif cfg.bucket_mode == "block":
        plan = whole_block_plan(metas_tree)
    elif cfg.bucket_mode in ("auto", "auto_dp"):
        from repro.core.autowrap import (auto_dp_plan, auto_plan,
                                         exposed_comm_time)

        planner = auto_plan if cfg.bucket_mode == "auto" else auto_dp_plan
        plan = planner(metas_tree, cfg, block_stats, segments=segments)
        plan = _with_precisions(plan, metas_tree, cfg, block_stats)
        r = exposed_comm_time(plan, metas_tree, cfg, block_stats,
                              segments=segments)
        log.info(
            "bucket_mode=%s (stats=%s): %d buckets, exposed=%.1fus "
            "comm=%.1fus compute=%.1fus, precisions=%s, plan=%s",
            cfg.bucket_mode,
            getattr(block_stats, "source", "default"),
            r["n_buckets"], r["exposed_s"] * 1e6, r["total_comm_s"] * 1e6,
            r["compute_s"] * 1e6, list(r["precisions"]),
            [list(g) for g in plan.groups])
        return plan
    elif isinstance(cfg.bucket_mode, BucketPlan):
        plan = cfg.bucket_mode
    else:
        raise ValueError(f"unknown bucket_mode {cfg.bucket_mode!r}")
    return _with_precisions(plan, metas_tree, cfg, block_stats)


def _with_precisions(plan: BucketPlan, metas_tree, cfg: DistConfig,
                     block_stats) -> BucketPlan:
    """Under comm_precision='auto', every resolved plan leaves here with
    per-bucket precisions attached (no-op otherwise)."""
    if cfg.comm_precision != "auto" or plan.precisions is not None:
        return plan
    from repro.core.autowrap import assign_precisions

    return assign_precisions(plan, metas_tree, cfg, block_stats)


def _active_segments(metas_tree, cfg: DistConfig, segments):
    """Segments the runtime will actually execute (reorder +
    segment_prefetch + >1 segment) — only then do the auto planners plan in
    execution order with pooled hiding windows, so planned exposure ==
    executed exposure. Returns (segments-or-None, hashable cache key)."""
    if (segments is None or not cfg.reorder or not cfg.segment_prefetch
            or len(segments.fns) <= 1):
        return None, None
    names = [k for k, _ in named_leaves(metas_tree)]
    seg_of = assign_segments(names, segments.param_globs, segments.names)
    return segments, tuple(seg_of)


def plan_for(metas_tree, cfg: DistConfig, block_stats=None,
             segments=None) -> BucketPlan:
    """Resolve cfg.bucket_mode into a concrete plan for one block (memoized
    per (metas, cfg, stats, segment assignment) so retraces don't re-run
    the planners). `segments` (models/common.BlockSegments) makes the auto
    planners plan the segmented schedule the stack executes."""
    active, seg_key = _active_segments(metas_tree, cfg, segments)
    key = _plan_cache_key(metas_tree, cfg, block_stats, seg_key)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = _PLAN_CACHE[key] = _resolve_plan(metas_tree, cfg,
                                                block_stats, active)
    return plan
