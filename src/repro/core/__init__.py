"""SimpleFSDP-JAX core: the paper's contribution as a composable library.

Layers (see DESIGN.md):
  dist        DistConfig — mesh axes, FSDP domain, dtypes, schedule flags
  meta        ParamMeta — ZeRO-3 flat-shard storage layout
  collectives replicate/gather_group — the differentiable parametrization
  remat       selective-AC policies (re-gather in backward)
  bucketing   BucketPlan — manual wrapping; plan_for memoizes auto plans
  autowrap    greedy Algorithm 1 + exposure-minimizing DP — auto wrapping
  stack       apply_stack — bucketed + reordered (prefetch) layer stacks,
              pipelined at bucket granularity for segmented blocks
  pipeline    gpipe / 1F1B schedules over a 'pipe' mesh axis (paper SS4)
  context     zigzag sequence sharding + ring attention over a 'ctx' axis
              (context parallelism; reverse-ring exact gradients)
  api         parallelize() + ParallelPlan — the single entry point
              (simple_fsdp kept as the deprecated bring-your-own-module
              shim)
  compat      jax version shims (shard_map / make_mesh / keystr)
"""

from repro.core.api import (ParallelPlan, Parallelized, build_metas,
                            parallelize, plan_parallel, shard_params,
                            simple_fsdp, unshard_params)
from repro.core.autowrap import (auto_dp_plan, auto_plan, exposed_comm_time,
                                 partition_exposure)
from repro.core.bucketing import (BucketPlan, manual_plan, per_param_plan,
                                  whole_block_plan)
from repro.core.collectives import gather_group, replicate, replicate_tree
from repro.core.compat import shard_map
from repro.core.context import (ring_attention, ring_cost, zigzag_batch,
                                zigzag_positions)
from repro.core.dist import DistConfig, make_mesh, single_device_config
from repro.core.irgraph import BlockStats
from repro.core.meta import (ParamMeta, abstract_storage, from_storage,
                             storage_specs, to_storage)
from repro.core.pipeline import (fsdp_stage_fn, gpipe, gpipe_grads,
                                 one_f_one_b, pipe_shift, pipeline_grads,
                                 pipeline_loss_grads)
from repro.core.remat import checkpoint_policy, maybe_remat
from repro.core.stack import apply_stack

__all__ = [
    "BlockStats", "BucketPlan", "DistConfig", "ParallelPlan",
    "Parallelized", "ParamMeta", "abstract_storage", "apply_stack",
    "auto_dp_plan", "auto_plan", "build_metas", "checkpoint_policy",
    "exposed_comm_time", "from_storage", "fsdp_stage_fn", "gather_group",
    "gpipe", "gpipe_grads", "make_mesh", "manual_plan", "maybe_remat",
    "one_f_one_b", "parallelize", "partition_exposure", "per_param_plan",
    "pipe_shift", "pipeline_grads", "pipeline_loss_grads", "plan_parallel",
    "replicate", "replicate_tree", "ring_attention", "ring_cost",
    "shard_map", "shard_params", "simple_fsdp", "single_device_config",
    "storage_specs", "to_storage", "unshard_params", "whole_block_plan",
    "zigzag_batch", "zigzag_positions",
]
