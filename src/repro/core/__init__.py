"""SimpleFSDP-JAX core: the paper's contribution as a composable library.

Layers (see DESIGN.md):
  dist        DistConfig — mesh axes, FSDP domain, dtypes, schedule flags
  meta        ParamMeta — ZeRO-3 flat-shard storage layout
  collectives replicate/gather_group — the differentiable parametrization
  remat       selective-AC policies (re-gather in backward)
  bucketing   BucketPlan — manual wrapping
  autowrap    greedy Algorithm 1 — auto wrapping
  stack       apply_stack — bucketed + reordered (prefetch) layer stacks
  api         simple_fsdp() one-liner
"""

from repro.core.api import build_metas, shard_params, simple_fsdp
from repro.core.autowrap import auto_plan, exposed_comm_time
from repro.core.bucketing import (BucketPlan, manual_plan, per_param_plan,
                                  whole_block_plan)
from repro.core.collectives import gather_group, replicate, replicate_tree
from repro.core.dist import DistConfig, make_mesh, single_device_config
from repro.core.irgraph import BlockStats
from repro.core.meta import (ParamMeta, abstract_storage, from_storage,
                             storage_specs, to_storage)
from repro.core.remat import checkpoint_policy, maybe_remat
from repro.core.stack import apply_stack

__all__ = [
    "BlockStats", "BucketPlan", "DistConfig", "ParamMeta",
    "abstract_storage", "apply_stack", "auto_plan", "build_metas",
    "checkpoint_policy", "exposed_comm_time", "from_storage", "gather_group",
    "make_mesh", "manual_plan", "maybe_remat", "per_param_plan", "replicate",
    "replicate_tree", "shard_params", "simple_fsdp", "single_device_config",
    "storage_specs", "to_storage", "whole_block_plan",
]
