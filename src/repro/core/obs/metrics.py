"""Typed per-step metrics registry: counters / gauges / histograms with
EWMA aggregation and a JSONL sink.

One registry instance is threaded through the runtime surfaces that used
to print their numbers ad hoc — `train/trainer.py` (step time, tokens/s,
grad norm, modeled-vs-measured peak), `train/train_step.py`'s wire-bytes
accounting, and the serving scheduler/router (queue depth, tail
latencies, prefix hit rate, arena occupancy).  The registry is the ONE
audited path for modeled-vs-measured peak reporting (`record_peak`), so
the trainer log line and the dryrun `[mem]` line can never disagree on
the arithmetic or the format.

Design constraints:
  * near-zero overhead per record — a metric update is one attribute
    write plus one multiply (the EWMA); `benchmarks/run.py obs` asserts
    the per-step instrumentation cost stays under 2% of a smoke step
    (BENCH_obs.json, `bench_obs_v1`);
  * deterministic snapshots — insertion-ordered dicts, no wall clock
    anywhere in this module (timestamps are the caller's business);
  * a metric name is bound to ONE type — re-registering `train/steps` as
    a gauge after it was a counter is a pointed TypeError, not a silent
    shadow.
"""

from __future__ import annotations

import json
import math
import os


class Counter:
    """Monotonic accumulator (events, bytes, tokens)."""

    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def snapshot(self) -> dict:
        return {"kind": "counter", "value": self.value}


class Gauge:
    """Last-value metric with a built-in EWMA (the smoothed series the
    drift monitor and the router posterior consume)."""

    __slots__ = ("name", "alpha", "value", "ewma", "n")
    kind = "gauge"

    def __init__(self, name: str, alpha: float = 0.2):
        self.name = name
        self.alpha = alpha
        self.value: float | None = None
        self.ewma: float | None = None
        self.n = 0

    def set(self, v: float) -> None:
        self.value = v
        self.ewma = v if self.ewma is None \
            else self.alpha * v + (1.0 - self.alpha) * self.ewma
        self.n += 1

    def snapshot(self) -> dict:
        return {"kind": "gauge", "value": self.value, "ewma": self.ewma,
                "n": self.n}


class Histogram:
    """Bounded-window distribution: count/sum over the full stream,
    percentiles over the last `window` observations (enough for p50/p99
    of a serving trace without unbounded growth on a long run)."""

    __slots__ = ("name", "window", "count", "sum", "min", "max", "_ring",
                 "_pos")
    kind = "histogram"

    def __init__(self, name: str, window: int = 1024):
        self.name = name
        self.window = window
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._ring: list[float] = []
        self._pos = 0

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._ring) < self.window:
            self._ring.append(v)
        else:
            self._ring[self._pos] = v
            self._pos = (self._pos + 1) % self.window

    def percentile(self, q: float) -> float:
        if not self._ring:
            return 0.0
        ys = sorted(self._ring)
        i = min(len(ys) - 1, int(round((q / 100.0) * (len(ys) - 1))))
        return float(ys[i])

    def snapshot(self) -> dict:
        return {"kind": "histogram", "count": self.count, "sum": self.sum,
                "mean": self.sum / self.count if self.count else 0.0,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "p50": self.percentile(50), "p99": self.percentile(99)}


class MetricsRegistry:
    """Get-or-create registry of typed metrics + the JSONL sink.

    Naming convention is path-like (`train/step_time_s`,
    `serving/queue_depth`, `router/rejected`) so one registry can carry
    every subsystem without collisions.
    """

    def __init__(self, ewma_alpha: float = 0.2):
        self.ewma_alpha = ewma_alpha
        self._metrics: dict[str, object] = {}

    # ------------------------------------------------------------ typed --
    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} is a {m.kind}, not a {cls.kind}; one "
                "name binds one type (rename one of the call sites)")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, alpha=self.ewma_alpha)

    def histogram(self, name: str, window: int = 1024) -> Histogram:
        return self._get(name, Histogram, window=window)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list[str]:
        return list(self._metrics)

    # --------------------------------------------------------- snapshot --
    def snapshot(self) -> dict:
        """{name: metric snapshot} in registration order (deterministic
        for a deterministic call sequence)."""
        return {k: m.snapshot() for k, m in self._metrics.items()}

    def dump_jsonl(self, path: str, step: int | None = None,
                   **extra) -> None:
        """Append one JSON object (step + full snapshot) to `path` — the
        sink `Trainer` writes at every log interval when
        `TrainerConfig.metrics_jsonl` is set."""
        row = {"step": step, **extra, "metrics": self.snapshot()}
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "a") as f:
            f.write(json.dumps(row, sort_keys=True) + "\n")

    # -------------------------------------------- modeled vs measured ----
    def record_peak(self, scope: str, modeled_bytes: float,
                    measured_bytes: float, budget_bytes: float | None = None,
                    note: str = "") -> str:
        """THE modeled-vs-measured peak-memory path: records both sides
        (plus their ratio) as gauges under `scope/` and returns the one
        canonical log line.  `trainer.memory_report()` and the dryrun's
        `[mem]` print both route through here, so the two sites can never
        diverge in arithmetic or format."""
        gib = 1.0 / 2**30
        ratio = modeled_bytes / max(1.0, measured_bytes)
        self.gauge(f"{scope}/modeled_peak_bytes").set(float(modeled_bytes))
        self.gauge(f"{scope}/measured_peak_bytes").set(float(measured_bytes))
        self.gauge(f"{scope}/modeled_over_measured").set(ratio)
        line = (f"{scope}: modeled peak {modeled_bytes * gib:.2f} GiB vs "
                f"measured {measured_bytes * gib:.2f} GiB "
                f"(modeled/measured {ratio:.2f}")
        if budget_bytes is not None:
            line += f", budget {budget_bytes * gib:.0f} GiB"
        if note:
            line += f", {note}"
        return line + ")"


_DEFAULT: MetricsRegistry | None = None


def default_registry() -> MetricsRegistry:
    """Process-wide registry for call sites with no owner to thread one
    through (the dryrun's per-cell records)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = MetricsRegistry()
    return _DEFAULT
