"""Measured-execution step profiler: the measurement half of the
profile -> calibrate -> replan loop.

`profile_step(model, plan, shape)` times the EXECUTED schedule of a frozen
`ParallelPlan` at span granularity and freezes the result as a
JSON-serializable `MeasuredProfile`:

  * per-segment compute — each block segment (models/common.BlockSegments)
    is compiled as its own sub-step on a 1-device mesh (the same masked
    params + threaded-state scaffolding `launch/dryrun.harvest_block_stats`
    uses to COST segments, here executed with concrete buffers and
    block-until-ready fences).  Measured-over-modeled ratios become the
    per-segment scales `calibrated_block_stats` applies.
  * per-bucket AG/RS — the flat-buffer collective path
    (`core/collectives.gather_flat` / `reduce_scatter_flat`) is timed at
    the plan's own bucket sizes on the plan's mesh; an effective per-axis
    bandwidth is fit for the calibration context.
  * quant codec — the existing `launch/dryrun.harvest_quant_timing`, once
    per wire codec the plan (or the 'auto' lattice) can use.
  * wall step — `steps` full optimizer steps through the plan's own
    `train_step`; per-rank rows when more than one JAX process is attached
    (tests/dist_harness.py runs one process, so it contributes one row).

Every measurement here is host wall clock on THIS backend (the container
runs CPU), while the analytic model targets the TPU roofline — the point
of the profile is exactly to close that gap: a global closure factor is
folded into the segment scales so the plan's own `modeled_step_time`,
re-evaluated with the calibrated stats, lands on the measured wall step.
"""

from __future__ import annotations

import dataclasses
import json
import statistics
import time

import jax
import jax.numpy as jnp

from repro.core import compat, hw
from repro.core.dist import precision_codecs
from repro.core.irgraph import build_nodes


@dataclasses.dataclass(frozen=True)
class MeasuredProfile:
    """Frozen result of one `profile_step` run.  JSON-serializable; every
    consumer (`calibrated_block_stats`, `calibration`, the trace overlay)
    reads it read-only, so two emissions from the same profile are
    byte-identical."""

    # provenance: arch/plan describe, steps, backend, closure factor,
    # segment-name order (segment index -> name, for the trace overlay)
    meta: dict = dataclasses.field(default_factory=dict)
    # measured wall clock of ONE optimizer step (median over steps)
    wall_step_s: float = 0.0
    # raw span table: {"name", "cat", "dur_s", ...} rows in record order
    spans: tuple = ()
    # segment name -> multiplicative scale on that segment's analytic
    # (flops, bytes) — scaling both scales the roofline time linearly
    seg_scales: dict = dataclasses.field(default_factory=dict)
    # param name -> segment name (how the scales distribute over params)
    param_segment: dict = dataclasses.field(default_factory=dict)
    # mesh axis -> {"bytes_per_s", "alpha_s"} measured collective bandwidth
    comm_bandwidth: dict = dataclasses.field(default_factory=dict)
    # wire codec -> measured roundtrip rate (bytes of input / s)
    quant_rates: dict = dataclasses.field(default_factory=dict)
    # process rank -> measured wall step (straggler rows)
    rank_step_s: dict = dataclasses.field(default_factory=dict)

    def is_empty(self) -> bool:
        return not (self.seg_scales or self.comm_bandwidth
                    or self.quant_rates)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "MeasuredProfile":
        d = json.loads(s)
        d["spans"] = tuple(d.get("spans", ()))
        return cls(**d)

    @classmethod
    def empty(cls) -> "MeasuredProfile":
        return cls(meta={"source": "empty"})

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path


def _block(tree):
    """block_until_ready over an arbitrary pytree (old-jax safe)."""
    jax.tree.map(lambda a: a.block_until_ready()
                 if hasattr(a, "block_until_ready") else a, tree)
    return tree


def _dcfg1(dcfg):
    """The degenerate 1-device mesh config the harvest scaffolding uses."""
    return dcfg.with_(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                      fsdp_axes=("data",), tp_axis="model", pp_axis=None,
                      microbatches=1)


def _time_fn(fn, args, iters: int) -> float:
    """Median wall time of `fn(*args)` with full-readiness fences; one
    warmup call absorbs compile."""
    _block(fn(*args))
    walls = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        _block(fn(*args))
        walls.append(time.perf_counter() - t0)
    return statistics.median(walls)


# ---------------------------------------------------------------------------
# per-segment compute sub-steps
# ---------------------------------------------------------------------------
def _profile_segments(model, dcfg, bshape, iters, spans):
    """Compile + execute each block segment on a 1-device mesh; return
    (seg_scales, param_segment, seg_names).  Scales are measured-over-
    modeled at the SAME mesh/shape, so they transfer multiplicatively to
    the target mesh's analytic stats (the assumption
    `harvest_block_stats` already rests on)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.bucketing import assign_segments
    from repro.core.meta import ParamMeta, named_leaves

    if not (hasattr(model, "block_stats") and hasattr(model, "block_metas")
            and hasattr(model, "block_fn")):
        return {}, {}, []
    saved = getattr(model, "measured_stats", None)
    if hasattr(model, "measured_stats"):
        model.measured_stats = None
    try:
        dcfg1 = _dcfg1(dcfg)
        an_ref = model.block_stats(dcfg1, bshape)
    finally:
        if hasattr(model, "measured_stats"):
            model.measured_stats = saved

    mesh1 = compat.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
    metas = model.block_metas(dcfg1)
    B, S = bshape
    consts = model.consts(S, dcfg1)
    x = jnp.zeros((B, S, model.cfg.d_model), dcfg1.param_dtype)
    params = jax.tree.map(
        lambda m: jnp.zeros(m.local_shape(dcfg1), dcfg1.param_dtype),
        metas, is_leaf=lambda v: isinstance(v, ParamMeta))
    names = [k for k, _ in named_leaves(metas)]
    nodes = {n.name: n for n in build_nodes(metas, dcfg1, an_ref)}

    segments = model.block_segments(dcfg1) \
        if hasattr(model, "block_segments") else None
    if segments is not None and len(segments.fns) > 1:
        seg_names = list(segments.names)
        seg_of = assign_segments(names, segments.param_globs, seg_names)
        seg_fns = list(segments.fns)
    else:
        seg_names = ["block"]
        seg_of = [0] * len(names)
        seg_fns = [lambda p, c, st: model.block_fn(p, c, st, dcfg1)]

    leaves, treedef = jax.tree_util.tree_flatten(
        params, is_leaf=lambda v: v is None)
    param_segment = {n: seg_names[sg] for n, sg in zip(names, seg_of)}
    seg_scales = {}
    state = x
    for s, seg_name in enumerate(seg_names):
        masked = jax.tree_util.tree_unflatten(
            treedef, [lf if seg_of[i] == s else None
                      for i, lf in enumerate(leaves)])

        def seg_fn(p, st, s=s):
            return seg_fns[s](p, consts, st)

        wrapped = compat.shard_map(seg_fn, mesh=mesh1, in_specs=(P(), P()),
                                   out_specs=P(), check_vma=False)
        jfn = jax.jit(wrapped)
        dt = _time_fn(jfn, (masked, state), iters)
        state = jfn(masked, state)
        modeled = sum(nodes[n].t_comp()
                      for n, sg in zip(names, seg_of) if sg == s)
        spans.append({"name": f"compute[{seg_name}]", "cat": "compute",
                      "dur_s": dt, "modeled_s": modeled,
                      "segment": seg_name})
        if modeled > 0.0 and dt > 0.0:
            seg_scales[seg_name] = dt / modeled
    return seg_scales, param_segment, seg_names


# ---------------------------------------------------------------------------
# per-bucket collectives through the flat-buffer path
# ---------------------------------------------------------------------------
def _profile_collectives(model, plan, iters, spans,
                         cap_elems: int = 1 << 20):
    """Time one flat-buffer all-gather + reduce-scatter per bucket of the
    plan's main group and fit an effective bandwidth per FSDP axis.
    Skipped (empty dict back) when the FSDP domain is trivial or the
    attached devices cannot host the plan's mesh."""
    import math

    from jax.sharding import PartitionSpec as P

    from repro.core import collectives as C

    dcfg = plan.dcfg
    if dcfg.fsdp_size <= 1 \
            or math.prod(dcfg.mesh_shape) > jax.device_count():
        return {}
    key = "blocks" if "blocks" in plan.bucket_plans \
        else next(iter(plan.bucket_plans))
    metas = model.block_metas(dcfg) if key == "blocks" \
        and hasattr(model, "block_metas") else None
    if metas is None:
        return {}
    nodes = {n.name: n for n in build_nodes(metas, dcfg, None)}
    mesh = compat.make_mesh(dcfg.mesh_shape, dcfg.mesh_axes)
    fsdp = dcfg.fsdp_size
    itemsize = jnp.dtype(dcfg.param_dtype).itemsize
    axes = dcfg.fsdp_axes
    frac = sum((dcfg.axis_size(a) - 1) / dcfg.axis_size(a)
               for a in axes if dcfg.axis_size(a) > 1)

    def ag_fn(buf):
        return C.gather_flat(buf, dcfg)

    def rs_fn(ct):
        return C.reduce_scatter_flat(ct, dcfg)

    ag_w = jax.jit(compat.shard_map(ag_fn, mesh=mesh, in_specs=(P(axes),),
                                    out_specs=P(), check_vma=False))
    rs_w = jax.jit(compat.shard_map(rs_fn, mesh=mesh, in_specs=(P(),),
                                    out_specs=P(axes), check_vma=False))

    rows = []
    groups = plan.bucket_plans[key].groups
    for i, grp in enumerate(groups):
        n_tot = sum(nodes[p].n_elems for p in grp if p in nodes)
        if n_tot <= 0:
            continue
        shard = min(max(1, n_tot // fsdp), cap_elems)
        buf = jnp.zeros((fsdp * shard,), dcfg.param_dtype)
        ct = jnp.zeros((fsdp, shard), dcfg.param_dtype)
        t_ag = _time_fn(ag_w, (buf,), iters)
        t_rs = _time_fn(rs_w, (ct,), iters)
        nbytes = fsdp * shard * itemsize
        modeled = hw.collective_time_s(nbytes, dcfg.axis_sizes, axes)
        spans.append({"name": f"AG[bucket {i}]", "cat": "all_gather",
                      "dur_s": t_ag, "modeled_s": modeled,
                      "bytes": nbytes, "bucket": i})
        spans.append({"name": f"RS[bucket {i}]", "cat": "reduce_scatter",
                      "dur_s": t_rs, "modeled_s": modeled,
                      "bytes": nbytes, "bucket": i})
        rows.append((nbytes, t_ag, t_rs))
    if not rows or frac <= 0.0:
        return {}
    # effective bandwidth from the largest timed bucket (alpha ~ 0 there),
    # split evenly over the active FSDP axes: t = frac * n / bw
    nbytes, t_ag, t_rs = max(rows)
    t = (t_ag + t_rs) / 2.0
    bw = frac * nbytes / max(1e-12, t)
    n_active = sum(1 for a in axes if dcfg.axis_size(a) > 1)
    # residual fixed cost from the smallest bucket, floored at zero
    nb0, ta0, tr0 = min(rows)
    alpha = max(0.0, (ta0 + tr0) / 2.0 - frac * nb0 / bw) / max(1, n_active)
    return {a: {"bytes_per_s": bw, "alpha_s": alpha}
            for a in axes if dcfg.axis_size(a) > 1}


# ---------------------------------------------------------------------------
# quant codec rates (the existing dryrun harvest, per codec in play)
# ---------------------------------------------------------------------------
def _plan_codecs(plan) -> list[str]:
    """Wire codecs the plan executes — or, under comm_precision='auto',
    every codec the planner lattice can assign (so a replan can price
    int8 against fp8 with measured rates on both)."""
    dcfg = plan.dcfg
    if dcfg.comm_precision == "bf16":
        return []
    if dcfg.comm_precision == "auto":
        return ["fp8", "int8"]
    codecs = set()
    for bp in plan.bucket_plans.values():
        for prec in (bp.precisions or [dcfg.comm_precision]):
            codecs.update(c for c in precision_codecs(prec) if c)
    return sorted(codecs)


def _profile_quant(model, plan, spans) -> dict:
    from repro.launch.dryrun import harvest_quant_timing

    codecs = _plan_codecs(plan)
    if not codecs:
        return {}
    key = "blocks" if "blocks" in plan.bucket_plans \
        else next(iter(plan.bucket_plans))
    metas = model.block_metas(plan.dcfg) if hasattr(model, "block_metas") \
        else None
    if metas is None:
        return {}
    nodes = {n.name: n for n in build_nodes(metas, plan.dcfg, None)}
    elems = [sum(nodes[p].n_elems for p in grp if p in nodes)
             for grp in plan.bucket_plans[key].groups]
    rates = {}
    for codec in codecs:
        q = harvest_quant_timing(elems, codec=codec)
        if q is None:
            continue
        rates[codec] = q["rate_bytes_per_s"]
        for s in q["samples"]:
            spans.append({"name": f"quant[{codec} n={s['n_elems']}]",
                          "cat": "quant", "dur_s": s["t_us"] * 1e-6,
                          "bytes": s["bytes"], "codec": codec})
    return rates


# ---------------------------------------------------------------------------
# wall step through the plan's own train step
# ---------------------------------------------------------------------------
def _profile_wall(model, plan, shape, steps, spans):
    from repro.core.api import parallelize
    from repro.data.pipeline import DataConfig, SyntheticC4, adapt_batch
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import init_train_state

    dcfg = plan.dcfg
    par = parallelize(model, dcfg, shape, plan=plan)
    step_fn = par.train_step(AdamWConfig(lr=1e-3))
    storage, opt = init_train_state(model, dcfg, jax.random.PRNGKey(0),
                                    plan=plan)
    data = SyntheticC4(DataConfig(vocab=model.cfg.vocab,
                                  seq_len=shape.seq_len,
                                  global_batch=shape.global_batch))
    batch = adapt_batch(data.batch(0), model.input_specs(shape, dcfg),
                        step=0)
    if dcfg.cp_size > 1:
        from repro.core.context import zigzag_batch
        batch = zigzag_batch(batch, dcfg)
    storage, opt, m = step_fn(storage, opt, batch)       # compile + warmup
    _block((storage, m))
    walls = []
    for k in range(max(1, steps)):
        t0 = time.perf_counter()
        storage, opt, m = step_fn(storage, opt, batch)
        _block((storage, m))
        dt = time.perf_counter() - t0
        walls.append(dt)
        spans.append({"name": f"step[{k}]", "cat": "wall", "dur_s": dt})
    return statistics.median(walls)


# ---------------------------------------------------------------------------
# closure: fold the residual model error into the segment scales
# ---------------------------------------------------------------------------
def _close_scales(model, plan, shape, profile: MeasuredProfile,
                  rounds: int = 6, tol: float = 0.02) -> MeasuredProfile:
    """Multiply every segment scale by a common factor until the plan's
    own `modeled_step_time`, evaluated with the calibrated stats under the
    calibration context, lands on the measured wall step.  Fixed-point
    iteration — `modeled_step_time` is monotone in a uniform compute
    scale, so g <- g * wall / modeled converges in a few rounds."""
    from repro.core.obs.calibrate import calibrated_step_time

    if not profile.seg_scales or profile.wall_step_s <= 0.0:
        return profile
    g, wall = 1.0, profile.wall_step_s
    base = dict(profile.seg_scales)
    for _ in range(rounds):
        trial = dataclasses.replace(
            profile, seg_scales={k: v * g for k, v in base.items()})
        m = calibrated_step_time(model, plan, shape, trial)
        if m is None or m <= 0.0:
            return profile
        if abs(m - wall) / wall <= tol:
            break
        g = min(1e12, max(1e-12, g * wall / m))
    meta = dict(profile.meta)
    meta["closure_factor"] = g
    return dataclasses.replace(
        profile, meta=meta,
        seg_scales={k: v * g for k, v in base.items()})


def profile_step(model, plan, shape, steps: int = 2,
                 wall_step_s: float | None = None) -> MeasuredProfile:
    """Profile the executed schedule of a frozen plan; returns the frozen
    `MeasuredProfile` (see module docstring for what is timed).  Pass
    `wall_step_s` (e.g. the Trainer's own drift-measured step time) to
    skip re-executing the full train step."""
    dcfg = plan.dcfg
    spans: list[dict] = []
    mb = max(1, plan.microbatches)
    b_local = max(1, shape.global_batch // max(1, dcfg.batch_dp) // mb)
    bshape = (b_local, shape.seq_len // max(1, dcfg.cp_size))

    seg_scales, param_segment, seg_names = _profile_segments(
        model, dcfg, bshape, steps, spans)
    comm_bw = _profile_collectives(model, plan, steps, spans)
    quant_rates = _profile_quant(model, plan, spans)
    if wall_step_s is None:
        wall_step_s = _profile_wall(model, plan, shape, steps, spans)
    else:
        spans.append({"name": "step[given]", "cat": "wall",
                      "dur_s": wall_step_s})

    rank_step_s = {str(jax.process_index()): wall_step_s}
    if jax.process_count() > 1:       # per-rank rows under a real multi-
        try:                          # process launch (dist harness style)
            from jax.experimental import multihost_utils
            walls = multihost_utils.process_allgather(
                jnp.asarray(wall_step_s))
            rank_step_s = {str(r): float(w) for r, w in enumerate(walls)}
        except Exception:
            pass

    profile = MeasuredProfile(
        meta={"plan": plan.describe(),
              "arch": type(model).__name__,
              "steps": steps,
              "backend": jax.default_backend(),
              "seg_names": seg_names},
        wall_step_s=wall_step_s,
        spans=tuple(spans),
        seg_scales=seg_scales,
        param_segment=param_segment,
        comm_bandwidth=comm_bw,
        quant_rates=quant_rates,
        rank_step_s=rank_step_s,
    )
    return _close_scales(model, plan, shape, profile)
