"""Calibration + replan: feed measured rates back into the planners.

The replan half of the measured-cost loop (ROADMAP "Pallas-first hot
path"): a frozen `MeasuredProfile` (core/obs/profile.py) rewrites the
model's cost contract and the hw rate constants, and the ORIGINAL
planners — bucket-partition/precision DP, `auto:<GB>` remat search,
`auto_microbatches`, `pp_schedule="auto"` scoring — re-run against the
calibrated numbers.  Nothing here plans; it only changes what the
planners believe.

  * `calibrated_block_stats(stats, profile)` — per-segment multiplicative
    rewrite of BlockStats.  Monotone: a param the profiler never saw
    keeps its analytic value; an empty profile returns `stats` itself.
  * `calibration(profile)` — context manager installing the measured
    per-axis collective bandwidths (core/hw) and per-codec quant rates
    (core/irgraph), restoring the priors on exit.
  * `calibrated_step_time(model, plan, shape, profile)` — the plan's
    `modeled_step_time` promise re-evaluated under calibration.
  * `replan(model, plan, shape, profile)` — a NEW frozen `ParallelPlan`
    from `plan_parallel` under calibration (same DistConfig, so
    `parallelize(plan=...)` accepts it) plus a delta report.
"""

from __future__ import annotations

import contextlib

from repro.core.irgraph import BlockStats


def calibrated_block_stats(stats: BlockStats | None,
                           profile) -> BlockStats | None:
    """Rewrite `stats` from the profile's measured per-segment rates.

    Each param's (flops, bytes) are multiplied by its segment's scale —
    scaling both scales the roofline `compute_time_s` linearly, so the
    calibrated stats reproduce the measured segment times under the
    unchanged cost model.  Monotone: params outside `param_segment` (or
    in a segment the profiler never timed) keep their analytic values;
    with no scales at all the SAME object comes back (identity)."""
    if stats is None or profile is None:
        return stats
    scales = getattr(profile, "seg_scales", None) or {}
    if not scales:
        return stats
    pseg = getattr(profile, "param_segment", None) or {}

    def s_for(name: str) -> float:
        return scales.get(pseg.get(name, ""), 1.0)

    return BlockStats(
        param_flops={k: v * s_for(k)
                     for k, v in stats.param_flops.items()},
        param_bytes={k: v * s_for(k)
                     for k, v in stats.param_bytes.items()},
        act_bytes=stats.act_bytes,
        source="calibrated",
        seg_act_bytes=stats.seg_act_bytes,
    )


@contextlib.contextmanager
def calibration(profile):
    """Install the profile's measured hw rates (per-axis collective
    bandwidth, per-codec quant throughput) for the dynamic extent of the
    block; the analytic priors are restored on exit.  An empty profile is
    a no-op."""
    from repro.core import hw, irgraph

    comm = getattr(profile, "comm_bandwidth", None) or {}
    quant = getattr(profile, "quant_rates", None) or {}
    prev_bw: dict = {}
    prev_q: dict = {}
    try:
        for ax in sorted(comm):
            d = comm[ax]
            prev_bw[ax] = hw.set_measured_axis_bandwidth(
                ax, hw.AxisBandwidth(bytes_per_s=d["bytes_per_s"],
                                     alpha_s=d["alpha_s"]))
        for codec in sorted(quant):
            prev_q[codec] = irgraph.set_measured_quant_rate(
                quant[codec], codec)
        yield
    finally:
        for ax, prev in prev_bw.items():
            hw.set_measured_axis_bandwidth(ax, prev)
        for codec, prev in prev_q.items():
            irgraph.set_measured_quant_rate(prev, codec)


@contextlib.contextmanager
def _installed_stats(model, plan, shape, profile):
    """Yield with the model's cost contract swapped for the calibrated
    stats (restored on exit); yields the calibrated BlockStats or None
    when the model carries no contract."""
    if not hasattr(model, "measured_stats") \
            or not hasattr(model, "block_stats"):
        yield None
        return
    dcfg = plan.dcfg
    b_local = max(1, shape.global_batch // max(1, dcfg.batch_dp))
    base = model.block_stats(
        dcfg, (b_local, shape.seq_len // max(1, dcfg.cp_size)))
    cal = calibrated_block_stats(base, profile)
    saved = model.measured_stats
    model.measured_stats = cal
    try:
        yield cal
    finally:
        model.measured_stats = saved


def calibrated_step_time(model, plan, shape, profile) -> float | None:
    """`modeled_step_time` of the plan with the calibrated stats
    installed and the measured hw rates active — the promise the drift
    monitor should hold a replanned run to."""
    from repro.core.obs.drift import modeled_step_time

    with _installed_stats(model, plan, shape, profile), \
            calibration(profile):
        return modeled_step_time(model, plan, shape)


def replan(model, plan, shape, profile):
    """Re-run `plan_parallel` against the calibrated cost model; returns
    (new_plan, delta).

    The DistConfig is the original plan's — unchanged — so the new plan
    passes `parallelize`'s plan/dcfg equality check and every auto
    resolution (bucket partition + per-bucket precision, `auto:<GB>`
    remat, microbatches, `pp_schedule='auto'`) re-runs with the
    calibrated stats and measured rates.  `delta` records what changed
    and the modeled gain, both evaluated UNDER calibration so the two
    step times are comparable."""
    from repro.core.api import plan_parallel
    from repro.core.obs.drift import modeled_step_time

    with _installed_stats(model, plan, shape, profile), \
            calibration(profile):
        before_s = modeled_step_time(model, plan, shape)
        new_plan = plan_parallel(model, plan.dcfg, shape)
        after_s = modeled_step_time(model, new_plan, shape)

    def _buckets(p):
        return {k: len(bp.groups) for k, bp in p.bucket_plans.items()}

    fields = {}
    for name in ("remat", "microbatches", "pp_schedule", "pp_virtual"):
        old, new = getattr(plan, name), getattr(new_plan, name)
        if old != new:
            fields[name] = [old, new]
    if _buckets(plan) != _buckets(new_plan):
        fields["n_buckets"] = [_buckets(plan), _buckets(new_plan)]
    delta = {
        "changed": new_plan.describe() != plan.describe(),
        "before": plan.describe(),
        "after": new_plan.describe(),
        "fields": fields,
        "modeled_step_before_s": before_s,
        "modeled_step_after_s": after_s,
        "modeled_gain_s": (before_s - after_s)
        if before_s is not None and after_s is not None else None,
        "wall_step_s": getattr(profile, "wall_step_s", None),
    }
    return new_plan, delta
