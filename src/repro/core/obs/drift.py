"""Modeled-vs-measured drift monitor.

The repo's planners promise numbers — step time (exposure + roofline
compute), per-device peak (live-range memory simulator), pipeline bubble
(schedule tables), decode rate (serving roofline).  This module records
what actually happened next to what was promised, per step, and names the
subsystem whose model drifts worst — the validation hook a future
`plan_search` autotuner scores candidate plans against, and the number
`BENCH_obs.json` tracks per arch.

Residuals are relative: (measured - modeled) / modeled.  Positive means
reality is slower/bigger than the model promised.  A record with
``modeled == 0`` carries no usable relative residual — it is stored with
the NaN sentinel and EXCLUDED from every aggregate (`mean_abs_rel`,
`worst()`), so one degenerate promise cannot poison a channel forever.
"""

from __future__ import annotations

import math

# channel -> the cost model on the hook for its residual
SUBSYSTEMS = {
    "step_time": "exposure/roofline cost model (core/autowrap + core/hw)",
    "peak_memory": "live-range memory simulator (core/memory)",
    "bubble": "pipeline schedule tables (core/pipeline)",
    "decode_rate": "serving roofline (core/serving ServePlan)",
}


class DriftMonitor:
    """Per-channel (modeled, measured) series + the pointed report.

    `registry`: optional `MetricsRegistry`; every record also lands as
    `drift/<channel>` gauges (the EWMA'd residual the router/autotuner
    side consumes)."""

    def __init__(self, registry=None):
        self.registry = registry
        self.records: dict[str, list[dict]] = {}

    def record(self, channel: str, modeled: float, measured: float,
               step: int | None = None) -> float:
        """Append one observation; returns the relative residual (NaN
        sentinel when ``modeled == 0`` — undefined, excluded from every
        aggregate)."""
        rel = (measured - modeled) / modeled if modeled else math.nan
        self.records.setdefault(channel, []).append(
            {"step": step, "modeled": modeled, "measured": measured,
             "rel": rel})
        if self.registry is not None:
            if math.isfinite(rel):
                self.registry.gauge(f"drift/{channel}/rel_residual").set(rel)
            self.registry.gauge(f"drift/{channel}/measured").set(measured)
            self.registry.gauge(f"drift/{channel}/modeled").set(modeled)
        return rel

    def residuals(self, channel: str) -> list[float]:
        return [r["rel"] for r in self.records.get(channel, [])]

    def summary(self) -> dict:
        """{channel: {n, modeled_mean, measured_mean, mean_abs_rel,
        last_rel, subsystem}} — the per-arch record BENCH_obs carries.
        Sentinel (non-finite) residuals are excluded from `mean_abs_rel`
        and `last_rel`; a channel with ONLY sentinels reports 0.0."""
        out = {}
        for ch, rows in self.records.items():
            finite = [r["rel"] for r in rows if math.isfinite(r["rel"])]
            out[ch] = {
                "n": len(rows),
                "modeled_mean": sum(r["modeled"] for r in rows) / len(rows),
                "measured_mean": sum(r["measured"] for r in rows) / len(rows),
                "mean_abs_rel": sum(abs(x) for x in finite) / len(finite)
                if finite else 0.0,
                "last_rel": finite[-1] if finite else 0.0,
                "subsystem": SUBSYSTEMS.get(ch, ch),
            }
        return out

    def worst(self) -> str | None:
        """Channel with the largest mean |relative residual|."""
        s = self.summary()
        if not s:
            return None
        return max(s, key=lambda ch: s[ch]["mean_abs_rel"])

    def report(self) -> str:
        """Human-readable drift report, worst-drifting subsystem first."""
        s = self.summary()
        if not s:
            return "drift: no observations recorded"
        w = self.worst()
        lines = [
            f"drift report ({sum(v['n'] for v in s.values())} observations)",
            f"  worst-drifting subsystem: {s[w]['subsystem']} "
            f"[{w}: mean |rel| {s[w]['mean_abs_rel']:.2f}]",
        ]
        for ch in sorted(s, key=lambda c: -s[c]["mean_abs_rel"]):
            v = s[ch]
            lines.append(
                f"  {ch:12s} n={v['n']:<4d} modeled {v['modeled_mean']:.3e} "
                f"measured {v['measured_mean']:.3e} "
                f"mean|rel| {v['mean_abs_rel']:.2f} "
                f"last {v['last_rel']:+.2f}")
        return "\n".join(lines)


def modeled_step_time(model, plan, shape) -> float | None:
    """The plan's own wall-clock promise for ONE optimizer step: per-layer
    roofline compute (forward + ~2x backward) plus the modeled exposed
    collective time, over the stacked depth, inflated by the resolved
    pipeline bubble.  This is the modeled side of the trainer's
    `step_time` drift channel — deliberately built from the same
    `exposed_comm_time` numbers the planners already trust, not a new
    model.  None when the model carries no cost contract."""
    from repro.core.autowrap import exposed_comm_time

    dcfg = plan.dcfg
    key = "blocks" if "blocks" in plan.bucket_plans else None
    if key is None or not hasattr(model, "block_stats"):
        return None
    metas = model.metas(dcfg)
    b_local = max(1, shape.global_batch // max(1, dcfg.batch_dp))
    stats = model.block_stats(
        dcfg, (b_local, shape.seq_len // max(1, dcfg.cp_size)))
    segments = model.block_segments(dcfg) \
        if hasattr(model, "block_segments") else None
    r = exposed_comm_time(plan.bucket_plans[key], metas[key], dcfg, stats,
                          segments=segments)
    per_layer = 3.0 * r["compute_s"] + r["exposed_s"]
    layers = max(1, plan.stacked_keys.get(key, 1))
    step = layers * per_layer
    if plan.pipelined:
        from repro.core.pipeline import bubble_fraction
        bf = bubble_fraction(plan.microbatches, plan.stage.n_stages,
                             plan.pp_schedule, plan.pp_virtual)
        step /= max(1e-9, 1.0 - bf)
    return step
