"""Unified telemetry: plan-aligned trace timelines (`trace`), the typed
per-step metrics registry (`metrics`), and the modeled-vs-measured drift
monitor (`drift`).

The observability counterpart of the plan-centric architecture: every
cost model in the repo (collective exposure, pipeline bubble, memory
simulator, ring hops, serving roofline) renders into ONE Chrome-trace
timeline and ONE registry, and the drift monitor closes the
model->measure loop by scoring the residuals per subsystem.
"""

from repro.core.obs.drift import SUBSYSTEMS, DriftMonitor, modeled_step_time
from repro.core.obs.metrics import (Counter, Gauge, Histogram,
                                    MetricsRegistry, default_registry)
from repro.core.obs.trace import (PID_MEASURED, PID_MODELED, PID_SERVING,
                                  TID_COMM, TID_COMPUTE, TraceBuilder,
                                  comm_windows, emit_comm_lanes, lane_spans,
                                  nonoverlapped_comm_s, pipeline_lanes,
                                  plan_comm_windows, plan_trace, ring_lanes,
                                  serving_lanes)

__all__ = [
    "SUBSYSTEMS", "DriftMonitor", "modeled_step_time",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "default_registry",
    "PID_MEASURED", "PID_MODELED", "PID_SERVING", "TID_COMM", "TID_COMPUTE",
    "TraceBuilder", "comm_windows", "emit_comm_lanes", "lane_spans",
    "nonoverlapped_comm_s", "pipeline_lanes", "plan_comm_windows",
    "plan_trace", "ring_lanes", "serving_lanes",
]
