"""Unified telemetry: plan-aligned trace timelines (`trace`), the typed
per-step metrics registry (`metrics`), the modeled-vs-measured drift
monitor (`drift`), and the profile -> calibrate -> replan loop
(`profile` + `calibrate`).

The observability counterpart of the plan-centric architecture: every
cost model in the repo (collective exposure, pipeline bubble, memory
simulator, ring hops, serving roofline) renders into ONE Chrome-trace
timeline and ONE registry; the drift monitor scores the residuals per
subsystem, the step profiler measures the executed schedule, and
calibration feeds the measured rates back into the planners so a drifted
plan can be re-planned against reality.
"""

from repro.core.obs.calibrate import (calibrated_block_stats,
                                      calibrated_step_time, calibration,
                                      replan)
from repro.core.obs.drift import SUBSYSTEMS, DriftMonitor, modeled_step_time
from repro.core.obs.metrics import (Counter, Gauge, Histogram,
                                    MetricsRegistry, default_registry)
from repro.core.obs.profile import MeasuredProfile, profile_step
from repro.core.obs.trace import (PID_MEASURED, PID_MODELED, PID_SERVING,
                                  TID_COMM, TID_COMPUTE, TID_STRAGGLER,
                                  TraceBuilder, comm_windows,
                                  emit_comm_lanes, lane_spans,
                                  measured_overlay, nonoverlapped_comm_s,
                                  pipeline_lanes, plan_comm_windows,
                                  plan_trace, ring_lanes, serving_lanes)

__all__ = [
    "SUBSYSTEMS", "DriftMonitor", "modeled_step_time",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "default_registry",
    "MeasuredProfile", "profile_step",
    "calibrated_block_stats", "calibrated_step_time", "calibration",
    "replan",
    "PID_MEASURED", "PID_MODELED", "PID_SERVING", "TID_COMM", "TID_COMPUTE",
    "TID_STRAGGLER", "TraceBuilder", "comm_windows", "emit_comm_lanes",
    "lane_spans", "measured_overlay", "nonoverlapped_comm_s",
    "pipeline_lanes", "plan_comm_windows", "plan_trace", "ring_lanes",
    "serving_lanes",
]
