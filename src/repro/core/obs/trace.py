"""Plan-aligned Chrome/Perfetto trace emitter.

Walks the SAME executed schedules the cost models walk and lays them out
as trace-event JSON (`chrome://tracing` / Perfetto "trace event format"):

  * collective lanes — the pooled cyclic (AG, RS, compute) hiding windows
    `core/autowrap.partition_exposure` scores.  The layout is constructed
    so that the comm-lane span time NOT covered by a compute-lane span
    equals the modeled exposure EXACTLY: window i issues pool i's
    all-gather and pool i-1's reduce-scatter against pool i-1's compute,
    the window advances by max(compute, comm), and the quant codec
    overhead (never hidden — it is unoverlappable critical-path work) is
    appended after the window.  `nonoverlapped_comm_s` recovers the
    number from the emitted JSON alone; tests assert it matches
    `exposed_comm_time`'s `exposed_s` within 1%.
  * pipeline lanes — one lane per stage rank, F/B/W spans straight from
    the `core/pipeline.PipeSchedule` tables (all four schedules).
  * ring lanes — per-hop ppermute exchange vs per-hop attention compute
    from `core/context.ring_cost` (live hops hide an exchange, skipped
    hops expose theirs).
  * serving lanes — admission / prefill chunks / decode windows /
    preemptions from the `ContinuousBatcher`'s virtual-clock event log
    (`enable_trace()`), which already timestamps every action.

Modeled lanes live under their own pid; measured wall-clock spans
(`measured_span`) render under a second pid next to them, so overlap is
visually auditable plan-vs-reality in one timeline.

Everything modeled here is host math over the frozen plan — two
emissions of the same plan are byte-identical (asserted in
tests/test_obs.py).
"""

from __future__ import annotations

import contextlib
import json
import time

from repro.core.autowrap import _active, _cfg_precision
from repro.core.irgraph import (ag_time, build_nodes, quant_overhead_s,
                                rs_time)

US = 1e6      # trace-event timestamps are microseconds

PID_MODELED = 1
PID_MEASURED = 2
PID_SERVING = 3

TID_COMPUTE = 0
TID_COMM = 1
TID_RING_COMM = 2
TID_RING_COMPUTE = 3
TID_STRAGGLER = 4             # per-rank straggler gauge (measured pid)
TID_PIPE_BASE = 10            # + stage rank

SERVE_TID_ADMIT = 0
SERVE_TID_PREFILL = 1
SERVE_TID_DECODE = 2
SERVE_TID_PREEMPT = 3


class TraceBuilder:
    """Accumulates trace events; serializes deterministically."""

    def __init__(self):
        self.events: list[dict] = []
        self._origin: float | None = None   # wall-clock zero (measured pid)

    # ------------------------------------------------------- metadata ----
    def process(self, pid: int, name: str) -> None:
        self.events.append({"ph": "M", "pid": pid, "tid": 0,
                            "name": "process_name", "args": {"name": name}})

    def thread(self, pid: int, tid: int, name: str) -> None:
        self.events.append({"ph": "M", "pid": pid, "tid": tid,
                            "name": "thread_name", "args": {"name": name}})

    # --------------------------------------------------------- events ----
    def span(self, pid: int, tid: int, name: str, ts_s: float, dur_s: float,
             cat: str = "modeled", args: dict | None = None) -> None:
        # no rounding: adjacent spans must stay exactly adjacent (the
        # within-lane no-overlap invariant is asserted at float precision)
        ev = {"ph": "X", "pid": pid, "tid": tid, "name": name, "cat": cat,
              "ts": ts_s * US, "dur": dur_s * US}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, pid: int, tid: int, name: str, ts_s: float,
                cat: str = "modeled", args: dict | None = None) -> None:
        ev = {"ph": "i", "s": "t", "pid": pid, "tid": tid, "name": name,
              "cat": cat, "ts": ts_s * US}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # ----------------------------------------------- measured wall clock --
    @contextlib.contextmanager
    def measured_span(self, name: str, tid: int = 0, cat: str = "measured"):
        """Wall-clock span hook: renders under PID_MEASURED next to the
        modeled lanes.  First use pins the trace's wall-clock origin."""
        t0 = time.perf_counter()
        if self._origin is None:
            self._origin = t0
        try:
            yield
        finally:
            t1 = time.perf_counter()
            self.span(PID_MEASURED, tid, name, t0 - self._origin, t1 - t0,
                      cat=cat)

    # ------------------------------------------------------ serialize ----
    def to_doc(self) -> dict:
        order = {"M": 0, "X": 1, "i": 1}
        evs = sorted(self.events,
                     key=lambda e: (e["pid"], e["tid"], order[e["ph"]],
                                    e.get("ts", -1.0), e["name"]))
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        return json.dumps(self.to_doc(), sort_keys=True)

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path


# ---------------------------------------------------------------------------
# collective lanes: the pooled cyclic hiding windows, materialized
# ---------------------------------------------------------------------------
def comm_windows(plan, metas_tree, cfg, stats=None, segments=None
                 ) -> list[dict]:
    """The pooled (ag, rs, comp, overhead) windows `partition_exposure`
    scores, one dict per pool, resolved with the SAME rewrite
    `exposed_comm_time` applies (split at segment boundaries,
    segment-major order, per-bucket precisions).  Summing
    ``overhead + max(0, ag_i + rs_{i-1} - comp_{i-1})`` cyclically over
    these windows reproduces `exposed_s` exactly — the invariant the
    trace layout (and its 1%-match test) rests on."""
    nodes = {n.name: n for n in build_nodes(metas_tree, cfg, stats)}
    pools = None
    if _active(segments):
        from repro.core.bucketing import (assign_segments,
                                          split_plan_at_segments)
        from repro.core.meta import named_leaves

        plan = split_plan_at_segments(plan, metas_tree, segments)
        names = [k for k, _ in named_leaves(metas_tree)]
        seg_of = assign_segments(names, segments.param_globs, segments.names)
        name_seg = dict(zip(names, seg_of))
        pools = [name_seg[grp[0]] for grp in plan.groups]
    groups = [[nodes[name] for name in grp] for grp in plan.groups]
    if pools is None:
        pools = list(range(len(groups)))
    if plan.precisions is not None:
        precisions = list(plan.precisions)
    else:
        precisions = [_cfg_precision(cfg)] * len(groups)

    windows: list[dict] = []
    cur_id = None
    for pid, grp, prec in zip(pools, groups, precisions):
        if pid != cur_id:
            windows.append({"pool": pid, "ag_s": 0.0, "rs_s": 0.0,
                            "comp_s": 0.0, "overhead_s": 0.0,
                            "n_params": 0, "precisions": []})
            cur_id = pid
        w = windows[-1]
        w["ag_s"] += ag_time(grp, cfg, prec)
        w["rs_s"] += rs_time(grp, cfg, prec)
        w["comp_s"] += sum(n.t_comp() for n in grp)
        w["overhead_s"] += quant_overhead_s(grp, prec)
        w["n_params"] += len(grp)
        w["precisions"].append(prec)
    return windows


def emit_comm_lanes(tb: TraceBuilder, windows: list[dict],
                    pid: int = PID_MODELED, t0: float = 0.0,
                    repeats: int = 1) -> dict:
    """Lay the cyclic steady state out as spans.  Per window step i:
    pool i-1's compute span and, concurrently on the comm lane, pool i's
    AG then pool i-1's RS; the clock advances by max(compute, comm), then
    the quant codec overhead of pool i runs unhidden.  Comm-lane time not
    covered by a compute span is therefore exactly the modeled
    exposure."""
    k = len(windows)
    t = t0
    exposed = comm_total = comp_total = 0.0
    for rep in range(repeats):
        for i in range(k):
            w, prev = windows[i], windows[(i - 1) % k]
            comp, ag, rs = prev["comp_s"], w["ag_s"], prev["rs_s"]
            oh = w["overhead_s"]
            if comp > 0.0:
                tb.span(pid, TID_COMPUTE, f"compute[pool {prev['pool']}]",
                        t, comp, cat="compute",
                        args={"layer": rep, "pool": prev["pool"]})
            if ag > 0.0:
                tb.span(pid, TID_COMM, f"AG[pool {w['pool']}]", t, ag,
                        cat="all_gather",
                        args={"layer": rep, "pool": w["pool"],
                              "precisions": list(w["precisions"])})
            if rs > 0.0:
                tb.span(pid, TID_COMM, f"RS[pool {prev['pool']}]", t + ag,
                        rs, cat="reduce_scatter",
                        args={"layer": rep, "pool": prev["pool"]})
            adv = max(comp, ag + rs)
            if oh > 0.0:
                tb.span(pid, TID_COMM, f"quant[pool {w['pool']}]", t + adv,
                        oh, cat="quant", args={"layer": rep})
            exposed += max(0.0, ag + rs - comp) + oh
            comm_total += ag + rs + oh
            comp_total += comp
            t += adv + oh
    return {"end_s": t, "exposed_s": exposed, "comm_s": comm_total,
            "compute_s": comp_total}


# ---------------------------------------------------------------------------
# measured overlay: the profiler's numbers, span-for-span next to modeled
# ---------------------------------------------------------------------------
def measured_overlay(tb: TraceBuilder, windows: list[dict], profile,
                     repeats: int = 1, t0: float = 0.0) -> dict:
    """Second process (PID_MEASURED): the SAME cyclic walk as
    `emit_comm_lanes`, with span durations resolved from a frozen
    `MeasuredProfile` instead of the cost model — compute spans carry the
    profiled segment scales, AG/RS spans the measured-over-modeled
    collective ratio, quant spans the measured codec rate.  Every span is
    aligned span-for-span with its modeled twin (same name, same
    lane, same walk order) and carries {modeled_s, measured_s,
    rel_residual} args, so "which window is the model wrong about" is a
    trace click.  A per-rank straggler gauge rides its own lane.  Pure
    host math over the frozen profile — two emissions are
    byte-identical.  PID_MODELED is untouched, so `nonoverlapped_comm_s`
    (the PR-9 exposed_s invariant) is preserved by construction."""
    from repro.core import hw as _hw

    tb.process(PID_MEASURED,
               f"measured profile [{profile.meta.get('plan', '?')}]")
    tb.thread(PID_MEASURED, TID_COMPUTE, "compute (measured)")
    tb.thread(PID_MEASURED, TID_COMM, "collectives (measured)")

    # per-pool compute scale: pool ids are segment indices when the plan
    # is segmented (seg_names carries the index -> name order), bucket
    # indices otherwise (a single unsegmented scale covers them all)
    seg_names = list(profile.meta.get("seg_names", []))
    scales = profile.seg_scales or {}

    def comp_scale(pool) -> float:
        if len(seg_names) == 1:
            return scales.get(seg_names[0], 1.0)
        if isinstance(pool, int) and 0 <= pool < len(seg_names):
            return scales.get(seg_names[pool], 1.0)
        return scales.get(str(pool), 1.0)

    # one global measured/modeled ratio per collective kind, from the
    # profiler's per-bucket rows (1.0 = unseen: measured == modeled)
    def span_ratio(cat: str) -> float:
        meas = sum(s["dur_s"] for s in profile.spans
                   if s.get("cat") == cat and s.get("modeled_s"))
        mod = sum(s["modeled_s"] for s in profile.spans
                  if s.get("cat") == cat and s.get("modeled_s"))
        return meas / mod if mod > 0.0 and meas > 0.0 else 1.0

    ag_ratio = span_ratio("all_gather")
    rs_ratio = span_ratio("reduce_scatter")
    q_rates = profile.quant_rates or {}
    q_ratio = ((_hw.HBM_BANDWIDTH / 2.0)
               / (sum(q_rates.values()) / len(q_rates))) if q_rates else 1.0

    def emit(tid, name, cat, t, modeled, measured, args):
        rel = (measured - modeled) / modeled if modeled else 0.0
        tb.span(PID_MEASURED, tid, name, t, measured, cat=cat,
                args={**args, "modeled_s": modeled, "measured_s": measured,
                      "rel_residual": rel})

    k = len(windows)
    t = t0
    for rep in range(repeats):
        for i in range(k):
            w, prev = windows[i], windows[(i - 1) % k]
            comp_m = prev["comp_s"] * comp_scale(prev["pool"])
            ag_m = w["ag_s"] * ag_ratio
            rs_m = prev["rs_s"] * rs_ratio
            oh_m = w["overhead_s"] * q_ratio
            if prev["comp_s"] > 0.0:
                emit(TID_COMPUTE, f"compute[pool {prev['pool']}]",
                     "compute", t, prev["comp_s"], comp_m,
                     {"layer": rep, "pool": prev["pool"]})
            if w["ag_s"] > 0.0:
                emit(TID_COMM, f"AG[pool {w['pool']}]", "all_gather", t,
                     w["ag_s"], ag_m, {"layer": rep, "pool": w["pool"]})
            if prev["rs_s"] > 0.0:
                emit(TID_COMM, f"RS[pool {prev['pool']}]",
                     "reduce_scatter", t + ag_m, prev["rs_s"], rs_m,
                     {"layer": rep, "pool": prev["pool"]})
            adv = max(comp_m, ag_m + rs_m)
            if w["overhead_s"] > 0.0:
                emit(TID_COMM, f"quant[pool {w['pool']}]", "quant",
                     t + adv, w["overhead_s"], oh_m, {"layer": rep})
            t += adv + oh_m

    ranks = sorted((profile.rank_step_s or {}).items())
    if ranks:
        tb.thread(PID_MEASURED, TID_STRAGGLER, "straggler (per rank)")
        mean = sum(v for _, v in ranks) / len(ranks)
        for r, v in ranks:
            tb.instant(PID_MEASURED, TID_STRAGGLER, f"rank {r} step", t0,
                       cat="straggler",
                       args={"rank": r, "step_s": v,
                             "rel_vs_mean": (v - mean) / mean
                             if mean else 0.0})
    return {"end_s": t, "ag_ratio": ag_ratio, "rs_ratio": rs_ratio,
            "quant_ratio": q_ratio}


# ---------------------------------------------------------------------------
# pipeline lanes: one lane per stage rank, spans from the slot tables
# ---------------------------------------------------------------------------
def pipeline_lanes(tb: TraceBuilder, n_micro: int, n_stages: int,
                   schedule: str, virtual: int = 1, slot_s: float = 1e-3,
                   pid: int = PID_MODELED, t0: float = 0.0) -> float:
    """F/B/W spans per stage rank, one lane each, from the schedule's own
    slot tables: gpipe/1f1b from their closed-form tables, interleaved/zb
    from the tabulated `PipeSchedule` (the exact tables the staged step
    executes).  Uniform slot duration — the same unit-cost model
    `bubble_fraction` scores; idle slots stay empty, so the bubbles are
    visible gaps."""
    from repro.core.pipeline import (build_pipe_schedule, gpipe_schedule,
                                     one_f_one_b_schedule)

    # (slot, stage) -> (name, cat, args) span table, schedule-specific
    if schedule == "gpipe":
        f = gpipe_schedule(n_micro, n_stages)
        T = f.shape[0]
        cells = {(t, s): (f"F{f[t, s]}", "pipe_fwd", int(f[t, s]))
                 for t in range(T) for s in range(n_stages) if f[t, s] >= 0}
    elif schedule == "1f1b":
        f, b = one_f_one_b_schedule(n_micro, n_stages)
        T = f.shape[0]
        cells = {(t, s): (f"F{f[t, s]}", "pipe_fwd", int(f[t, s]))
                 for t in range(T) for s in range(n_stages) if f[t, s] >= 0}
        cells.update({(t, s): (f"B{b[t, s]}", "pipe_bwd", int(b[t, s]))
                      for t in range(T) for s in range(n_stages)
                      if b[t, s] >= 0})
    else:
        sched = build_pipe_schedule(n_micro, n_stages, schedule, virtual)
        T = sched.slots
        cells = {}
        for t in range(T):
            for s in range(n_stages):
                if sched.f_mb[t, s] >= 0:
                    m, c = int(sched.f_mb[t, s]), int(sched.f_chunk[t, s])
                    name = f"F{m}" if virtual == 1 else f"F{m}.{c}"
                    cells[(t, s)] = (name, "pipe_fwd", m)
                elif sched.b_mb[t, s] >= 0:
                    m, c = int(sched.b_mb[t, s]), int(sched.b_chunk[t, s])
                    name = f"B{m}" if virtual == 1 else f"B{m}.{c}"
                    cells[(t, s)] = (name, "pipe_bwd", m)
                elif sched.w_idx[t, s] >= 0:
                    cells[(t, s)] = (f"W@{int(sched.w_idx[t, s])}",
                                     "pipe_wgrad", -1)
    for s in range(n_stages):
        tid = TID_PIPE_BASE + s
        tb.thread(pid, tid, f"pipe stage {s} [{schedule}]")
        for t in range(T):
            cell = cells.get((t, s))
            if cell is not None:
                name, cat, mb = cell
                tb.span(pid, tid, name, t0 + t * slot_s, slot_s, cat=cat,
                        args={"mb": mb, "slot": t})
    return t0 + T * slot_s


# ---------------------------------------------------------------------------
# ring lanes: per-hop ppermute exchange vs per-hop attention compute
# ---------------------------------------------------------------------------
def ring_lanes(tb: TraceBuilder, ring: dict, pid: int = PID_MODELED,
               t0: float = 0.0) -> float:
    """One layer's ring-attention schedule from `core/context.ring_cost`:
    `live-1` exchanges ride a compute hop (hidden up to the spill), the
    remaining `cp-1-live+1` windowed-out exchanges run bare."""
    cp = ring["cp"]
    if cp <= 1:
        return t0
    comm, comp = ring["hop_comm_s"], ring["hop_comp_s"]
    hidden = max(0, ring["live_hops"] - 1)
    t = t0
    # hop 0: the local block's attention compute, exchange 1 in flight
    tb.span(pid, TID_RING_COMPUTE, "ring attn[hop 0]", t, comp, cat="ring")
    for h in range(cp - 1):
        tb.span(pid, TID_RING_COMM, f"ppermute[{h}]", t, comm, cat="ring",
                args={"hop": h, "bytes": ring["hop_bytes"]})
        if h < hidden:
            if h > 0:
                tb.span(pid, TID_RING_COMPUTE, f"ring attn[hop {h}]", t,
                        comp, cat="ring")
            t += max(comm, comp)
        else:
            t += comm      # windowed-out hop: exchange runs, compute skipped
    return t


# ---------------------------------------------------------------------------
# serving lanes: the batcher's virtual-clock event log
# ---------------------------------------------------------------------------
def serving_lanes(tb: TraceBuilder, batcher, pid: int = PID_SERVING,
                  t0: float = 0.0) -> float:
    """Render a `ContinuousBatcher`'s event log (`enable_trace()` before
    driving it).  Virtual timestamps are already monotonic per lane, so
    spans never overlap within a lane by construction."""
    events = getattr(batcher, "events", None)
    if events is None:
        raise ValueError(
            "batcher has no event log; call batcher.enable_trace() before "
            "driving it (run_virtual(..., trace=True))")
    tb.process(pid, "serving (virtual clock)")
    tb.thread(pid, SERVE_TID_ADMIT, "admission")
    tb.thread(pid, SERVE_TID_PREFILL, "prefill chunks")
    tb.thread(pid, SERVE_TID_DECODE, "decode windows")
    tb.thread(pid, SERVE_TID_PREEMPT, "preemption/finish")
    end = t0
    for ev in events:
        kind = ev[0]
        if kind == "admit":
            _, t, rid = ev
            tb.instant(pid, SERVE_TID_ADMIT, f"admit r{rid}", t0 + t,
                       cat="serving")
        elif kind == "prefill":
            _, ts, te, rid, n = ev
            tb.span(pid, SERVE_TID_PREFILL, f"prefill r{rid} +{n}", t0 + ts,
                    te - ts, cat="serving", args={"rid": rid, "tokens": n})
            end = max(end, t0 + te)
        elif kind == "decode":
            _, ts, te, nseq = ev
            tb.span(pid, SERVE_TID_DECODE, f"decode x{nseq}", t0 + ts,
                    te - ts, cat="serving", args={"batch": nseq})
            end = max(end, t0 + te)
        elif kind == "preempt":
            _, t, rid = ev
            tb.instant(pid, SERVE_TID_PREEMPT, f"preempt r{rid}", t0 + t,
                       cat="serving")
        elif kind == "finish":
            _, t, rid = ev
            tb.instant(pid, SERVE_TID_PREEMPT, f"finish r{rid}", t0 + t,
                       cat="serving")
    return end


# ---------------------------------------------------------------------------
# the one-call entry point: everything a ParallelPlan implies
# ---------------------------------------------------------------------------
def plan_comm_windows(model, plan, shape) -> list[dict]:
    """Resolve (metas, stats, segments) for the plan's main stacked group
    exactly the way `plan_parallel` did, then build the hiding windows."""
    dcfg = plan.dcfg
    metas = model.metas(dcfg)
    key = "blocks" if "blocks" in plan.bucket_plans \
        else next(iter(plan.bucket_plans))
    stats = None
    if shape is not None and hasattr(model, "block_stats") \
            and key == "blocks":
        b_local = max(1, shape.global_batch // max(1, dcfg.batch_dp))
        stats = model.block_stats(
            dcfg, (b_local, shape.seq_len // max(1, dcfg.cp_size)))
    segments = model.block_segments(dcfg) \
        if key == "blocks" and hasattr(model, "block_segments") else None
    return comm_windows(plan.bucket_plans[key], metas[key], dcfg,
                        stats=stats, segments=segments)


def plan_trace(model, plan, shape, *, repeats: int = 1, batcher=None,
               arch_cfg=None, profile=None,
               tb: TraceBuilder | None = None) -> TraceBuilder:
    """Full modeled timeline of a frozen `ParallelPlan`: collective
    hiding windows (`repeats` steady-state layers), the pipeline slot
    tables when the plan is pipelined, the ring-attention hops when the
    plan has a ctx axis (needs `arch_cfg` for head geometry), and —
    optionally — a traced serving batcher's lanes.  Pass a frozen
    `MeasuredProfile` as `profile` to also render the measured overlay
    (`measured_overlay`) under PID_MEASURED.  Pure host math:
    deterministic, no devices touched."""
    tb = tb or TraceBuilder()
    dcfg = plan.dcfg
    tb.process(PID_MODELED, f"modeled plan [{plan.describe()}]")
    tb.thread(PID_MODELED, TID_COMPUTE, "compute")
    tb.thread(PID_MODELED, TID_COMM, "collectives (AG/RS/quant)")

    windows = plan_comm_windows(model, plan, shape)
    layout = emit_comm_lanes(tb, windows, repeats=repeats)
    if profile is not None:
        measured_overlay(tb, windows, profile, repeats=repeats)

    if dcfg.cp_size > 1 and arch_cfg is not None:
        from repro.core.context import ring_cost
        tb.thread(PID_MODELED, TID_RING_COMM, "ring ppermute")
        tb.thread(PID_MODELED, TID_RING_COMPUTE, "ring attention")
        b_local = max(1, shape.global_batch // max(1, dcfg.batch_dp))
        ring = ring_cost(arch_cfg, dcfg,
                         (b_local, shape.seq_len // dcfg.cp_size),
                         window=getattr(arch_cfg, "sliding_window", None))
        ring_lanes(tb, ring, t0=layout["end_s"])

    if plan.pipelined:
        # slot unit: one stage's per-microbatch block compute under the
        # plan's own workload model — visual scale, not a new cost model
        per_layer = sum(w["comp_s"] for w in windows)
        slot_s = max(per_layer * plan.stage.layers_per_stage
                     / max(1, plan.microbatches), 1e-6)
        pipeline_lanes(tb, plan.microbatches, plan.stage.n_stages,
                       plan.pp_schedule, plan.pp_virtual, slot_s=slot_s)

    if batcher is not None:
        serving_lanes(tb, batcher)
    return tb


# ---------------------------------------------------------------------------
# reading traces back (tests + drift reports)
# ---------------------------------------------------------------------------
def lane_spans(doc: dict, pid: int, tid: int) -> list[tuple[float, float]]:
    """(ts, dur) of every complete event in one lane, sorted by ts."""
    return sorted((e["ts"], e["dur"]) for e in doc["traceEvents"]
                  if e["ph"] == "X" and e["pid"] == pid and e["tid"] == tid)


def nonoverlapped_comm_s(doc: dict, pid: int = PID_MODELED,
                         comm_tid: int = TID_COMM,
                         compute_tid: int = TID_COMPUTE) -> float:
    """Comm-lane span time NOT covered by any compute-lane span, computed
    from the emitted JSON alone — the trace-side measurement of the
    planner's `exposed_s` (asserted to match within 1%)."""
    compute = [(ts, ts + d) for ts, d in lane_spans(doc, pid, compute_tid)]
    total = 0.0
    for ts, d in lane_spans(doc, pid, comm_tid):
        t0, t1 = ts, ts + d
        covered = 0.0
        for c0, c1 in compute:
            covered += max(0.0, min(t1, c1) - max(t0, c0))
        total += (t1 - t0) - covered
    return total / US
