"""apply_stack: run a homogeneous layer stack under SimpleFSDP scheduling.

This module is the JAX incarnation of the paper's TorchInductor *backend*
passes (SS3.2). PyTorch reorders already-built IR nodes; XLA exposes no user
IR pass, so we get the same schedules by *constructing* the dataflow so each
communication is independent of the compute it must overlap (DESIGN.md SS2):

  reorder=False  ("vanilla")
      lax.scan(checkpoint(gather -> compute)); every layer's all-gather is
      data-adjacent to its compute — fully exposed communication, exactly the
      paper's unoptimized trace. Gathers are still bucketed per `plan`.
      Backward collectives come from the `gather_group` custom_vjp.

  reorder=True   (bucketing + reordering, paper Fig. 2)
      A hand-scheduled double-buffered scan with a custom VJP, pipelined at
      BUCKET granularity.  The layer is an ordered chain of *segments*
      (models/common.BlockSegments — e.g. attn / mlp); the bucket plan is
      split at segment boundaries so every bucket belongs to exactly one
      segment, and the schedule realizes Algorithm 1's premise inside the
      layer, not just across layers:

        forward  — the scan carry holds the gathered FIRST bucket group of
                   layer i; segment s's compute overlaps segment s+1's
                   all-gather (AG_{s+1} "before Wa_s"), and the last segment
                   prefetches layer i+1's first bucket across the layer
                   boundary. Saves ONLY per-layer block inputs (= full
                   activation checkpointing) — the carry now holds one
                   bucket group instead of a whole gathered layer.
        backward — re-gathers bucket by bucket while the layer recomputes
                   segment by segment (re-gather = the selective-AC
                   MUST_RECOMPUTE semantics): segment s's recompute overlaps
                   segment s+1's gather, the last segment prefetches layer
                   i-1's first bucket, and under rs_delay the previous
                   layer's per-bucket reduce-scatters are interleaved with
                   this layer's backward segment sweep ("Wr12 before RS34",
                   one RS issue point per bucket).

      Models that declare no segments (or cfg.segment_prefetch=False) run
      the same machinery with a single whole-layer segment, which is exactly
      the pre-v2 schedule. The Table-6 ablation flags (ag_before_wait_fwd/
      bwd, rs_delay) keep their meanings at segment granularity; the "after"
      variants insert an optimization_barrier to force the sequential
      schedule they name.

The first (forward) / last (backward) iteration is peeled out of the scan so
every carried value gets its true varying-manual-axes (vma) type from real
computation — scan carries must type-match exactly under shard_map vma.

Block contract (unsegmented):
    block_fn(params_full, consts, x) -> (y, aux)
      params_full : pytree of TP-local compute tensors (structure == metas)
      consts      : pytree treated as constants (rope caches, masks) — zero
                    cotangent (stop-grad)
      x / y       : activation carry pytree (same structure both sides)
      aux         : dict of scalars summed over layers (MoE aux loss etc.)

Segmented contract (models/common.BlockSegments): fns[s](params, consts,
state) -> state, where `params` is the full metas-shaped pytree with ONLY
segment s's leaves populated (others None — touching a foreign leaf fails at
trace time, which is what keeps the bucket pipelining honest), state_0 is the
block input x and the last segment returns (y, aux).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import collectives as coll
from repro.core import compat
from repro.core.bucketing import (BucketPlan, assign_segments, plan_for,
                                  split_plan_at_segments)
from repro.core.dist import DistConfig
from repro.core.meta import ParamMeta, named_leaves
from repro.core.remat import maybe_remat, resolve_segment_policies


def _meta_leaves(metas_tree):
    is_meta = lambda x: isinstance(x, ParamMeta)
    leaves, treedef = jax.tree_util.tree_flatten(metas_tree, is_leaf=is_meta)
    return leaves, treedef


def _zero_cotangent(x):
    def one(v):
        if jnp.issubdtype(v.dtype, jnp.floating) or jnp.issubdtype(
                v.dtype, jnp.complexfloating):
            return jnp.zeros(v.shape, v.dtype)
        return np.zeros(v.shape, jax.dtypes.float0)
    return jax.tree.map(one, x)


def apply_stack(block_fn: Callable, metas_tree, cfg: DistConfig,
                stacked, consts, x, plan: BucketPlan | None = None,
                block_stats=None, segments=None, remat=None):
    """Run the layer stack; returns (y, aux_sums).

    `segments` is an optional models/common.BlockSegments declaring the
    ordered segment chain of one block; with cfg.segment_prefetch it enables
    bucket-granular pipelining on the reorder path and makes the auto
    planners respect segment boundaries, so the planned partition is the
    one the schedule executes.

    `remat` is the resolved per-segment policy vector (one entry per
    segment; `core/memory`'s auto-SAC planner output).  When omitted it is
    resolved from ``cfg.remat`` — a single policy broadcasts, the vector
    grammar ("attn=full,mlp=fsdp_only") maps segments by name, and the
    unresolved ``"auto:<GB>"`` form raises pointedly (it must be resolved by
    `core/api.plan_parallel` before trace time).  On the vanilla path a
    non-uniform vector checkpoints each segment separately (gathers INSIDE
    the wrap, so `fsdp_only` still drops them); on the prefetch path —
    whose hand-written VJP already saves only block inputs and re-gathers
    per bucket — residual-dropping entries (`full`/`save_dots`) bound the
    backward recompute residency per segment.
    """
    if plan is None:
        plan = plan_for(metas_tree, cfg, block_stats, segments=segments)
    seg_names = tuple(segments.names) \
        if segments is not None and len(segments.fns) > 1 else ()
    if remat is None:
        remat = resolve_segment_policies(cfg.remat, seg_names)
    remat = tuple(remat)
    if len(remat) != max(1, len(seg_names)):
        raise ValueError(
            f"remat vector {remat} does not match the block's "
            f"{max(1, len(seg_names))} segment(s) {seg_names or '(block)'}")
    if cfg.reorder:
        return _prefetch_stack(block_fn, metas_tree, cfg, plan, stacked,
                               consts, x, segments, remat)
    return _vanilla_stack(block_fn, metas_tree, cfg, plan, stacked, consts,
                          x, segments, remat)


# ---------------------------------------------------------------------------
# Vanilla: scan(remat(gather -> compute)). Exposed comm; autodiff backward.
# ---------------------------------------------------------------------------
def _segmented_vanilla_layer(block_fn, metas_tree, cfg, plan, consts,
                             segments, policies):
    """One layer as a per-segment checkpointed chain (non-uniform remat).

    Each segment gathers ITS buckets inside its own `jax.checkpoint` wrap
    (via the differentiable `gather_group`), so a `fsdp_only` entry drops
    exactly that segment's gathered params while a neighbouring `none`
    entry keeps its own — the auto-SAC planner's per-segment policy vector,
    realized on the autodiff path."""
    metas, treedef = _meta_leaves(metas_tree)
    names = [k for k, _ in named_leaves(metas_tree)]
    seg_of = assign_segments(names, segments.param_globs, segments.names)
    exec_plan = split_plan_at_segments(plan, metas_tree, segments)
    S = len(segments.fns)
    seg_idxs = [sorted(i for i, s in enumerate(seg_of) if s == s_id)
                for s_id in range(S)]
    pos_in = [{i: p for p, i in enumerate(idxs)} for idxs in seg_idxs]
    seg_groups: list[list[list[int]]] = [[] for _ in range(S)]
    seg_precs: list[list[str]] = [[] for _ in range(S)]
    exec_precs = exec_plan.group_precisions(metas_tree, cfg)
    for grp, prec in zip(exec_plan.index_groups(metas_tree), exec_precs):
        seg_groups[seg_of[grp[0]]].append(grp)
        seg_precs[seg_of[grp[0]]].append(prec)

    def seg_run(s, shards_s, state):
        full: list = [None] * len(metas)
        for grp, prec in zip(seg_groups[s], seg_precs[s]):
            outs = coll.gather_group(
                tuple(shards_s[pos_in[s][i]] for i in grp),
                tuple(metas[i] for i in grp), cfg, prec)
            for i, o in zip(grp, outs):
                full[i] = o
        params = jax.tree_util.tree_unflatten(treedef, full)
        return segments.fns[s](params, consts, state)

    def layer(xc, layer_shards):
        shard_leaves = treedef.flatten_up_to(layer_shards)
        state = xc
        for s in range(S):
            shards_s = tuple(shard_leaves[i] for i in seg_idxs[s])
            state = maybe_remat(
                lambda sh, st, s=s: seg_run(s, sh, st),
                policies[s])(shards_s, state)
        return state                     # last segment returns (y, aux)

    return layer


def _vanilla_stack(block_fn, metas_tree, cfg, plan, stacked, consts, x,
                   segments=None, policies=None):
    metas, treedef = _meta_leaves(metas_tree)
    leaves = treedef.flatten_up_to(stacked)
    L = leaves[0].shape[0]

    policies = policies or (cfg.remat,)
    if (len(set(policies)) > 1 and segments is not None
            and len(segments.fns) > 1):
        layer = _segmented_vanilla_layer(block_fn, metas_tree, cfg, plan,
                                         consts, segments, policies)
    else:
        def layer(xc, layer_shards):
            params = coll.replicate_tree(layer_shards, metas_tree, cfg, plan)
            return block_fn(params, consts, xc)

        layer = maybe_remat(layer, policies[0])

    # peel layer 0 (gives the aux accumulator its true vma type)
    y, aux = layer(x, jax.tree_util.tree_unflatten(
        treedef, [l[0] for l in leaves]))
    if L == 1:
        return y, aux

    def body(carry, layer_shards):
        xc, aux = carry
        y, aux_l = layer(xc, layer_shards)
        return (y, jax.tree.map(jnp.add, aux, aux_l)), None

    rest = jax.tree_util.tree_unflatten(treedef, [l[1:] for l in leaves])
    (y, aux), _ = lax.scan(body, (y, aux), rest)
    return y, aux


# ---------------------------------------------------------------------------
# Prefetch: bucket-granular double-buffered scan with hand-written VJP.
# ---------------------------------------------------------------------------
def _prefetch_stack(block_fn, metas_tree, cfg, plan, stacked, consts, x,
                    segments=None, policies=None):
    metas, treedef = _meta_leaves(metas_tree)
    names = [k for k, _ in named_leaves(metas_tree)]
    stacked_leaves = treedef.flatten_up_to(stacked)
    L = stacked_leaves[0].shape[0]
    shard_shapes = [m.shard_shape(cfg) for m in metas]

    if (segments is not None and cfg.segment_prefetch
            and len(segments.fns) > 1):
        seg_fns = tuple(segments.fns)
        seg_of = assign_segments(names, segments.param_globs, segments.names)
        # the executed partition: split at segment boundaries, segment-major
        # (the SAME rewrite exposed_comm_time scores — one implementation)
        plan = split_plan_at_segments(plan, metas_tree, segments)
    else:
        # single whole-layer segment == the pre-segmentation schedule
        seg_fns = (lambda params, cst, state: block_fn(params, cst, state),)
        seg_of = [0] * len(names)
    # Per-segment remat on the prefetch path: the hand-written VJP already
    # saves only block inputs and re-gathers per bucket (fsdp_only-or-
    # better semantics by construction), so only the residual-DROPPING
    # policies change anything — they checkpoint the segment so the
    # backward recompute (`one_bwd`'s jax.vjp sweep) holds that segment's
    # input instead of all its intermediates. `none`/`fsdp_only` entries
    # keep the schedule exactly as-is (values are identical either way;
    # this is a residency knob, modeled by core/memory's simulator).
    if policies is not None:
        if len(policies) != len(seg_fns):
            # segments declared but not active (cfg.segment_prefetch off):
            # collapse the vector to its most memory-aggressive entry so the
            # whole-layer wrap never saves more than the vector promised
            from repro.core.remat import most_aggressive
            policies = (most_aggressive(policies),) * len(seg_fns)
        seg_fns = tuple(
            maybe_remat(fn, p) if p in ("full", "save_dots") else fn
            for fn, p in zip(seg_fns, policies))
    S = len(seg_fns)

    seg_groups: list[list[list[int]]] = [[] for _ in range(S)]
    seg_precs: list[list[str]] = [[] for _ in range(S)]
    for grp, prec in zip(plan.index_groups(metas_tree),
                         plan.group_precisions(metas_tree, cfg)):
        seg_groups[seg_of[grp[0]]].append(grp)
        seg_precs[seg_of[grp[0]]].append(prec)
    # flat group order is segment-major — the RS finalization order
    flat_groups = [g for s in range(S) for g in seg_groups[s]]
    flat_precs = [p for s in range(S) for p in seg_precs[s]]
    seg_base = [sum(len(seg_groups[t]) for t in range(s)) for s in range(S)]
    seg_idxs = [sorted(i for g in seg_groups[s] for i in g)
                for s in range(S)]
    pos_in = [{i: p for p, i in enumerate(idxs)} for idxs in seg_idxs]

    def slice_seg(leaves, idx, s):
        return [lax.dynamic_index_in_dim(leaves[i], idx, 0, keepdims=False)
                for i in seg_idxs[s]]

    def gather_seg(leaves, idx, s, barrier=None):
        """Gather segment s's bucket groups of layer `idx` (one packed AG
        per vma class per group); returns tensors ordered as seg_idxs[s]."""
        shards = slice_seg(leaves, idx, s)
        if barrier is not None:
            # Table-6 'after' placement: tie the gather's inputs to the
            # previous compute so it cannot be scheduled ahead of it.
            # optimization_barrier JOINS the vma of everything it ties, so a
            # raw tie would up-vary TP-replicated shards; instead tie each
            # shard to a zero scalar token derived from the barrier value and
            # psum-reduced down to that shard's own vma class.
            lf = jax.tree.leaves(barrier)[0]
            base = (lf.ravel()[:1].sum() * 0).astype(jnp.float32)
            tokens: dict = {}

            def tok(vma):
                key = frozenset(vma)
                if key not in tokens:
                    extra = tuple(a for a in compat.vma_of(base)
                                  if a not in key)
                    tokens[key] = lax.psum(base, extra) if extra else base
                return tokens[key]

            shards = [
                lax.optimization_barrier((sh, tok(compat.vma_of(sh))))[0]
                for sh in shards
            ]
        full: list = [None] * len(shards)
        for grp, prec in zip(seg_groups[s], seg_precs[s]):
            outs = coll.gather_group_fwd_raw(
                [shards[pos_in[s][i]] for i in grp],
                [metas[i] for i in grp], cfg, prec)
            for i, o in zip(grp, outs):
                full[pos_in[s][i]] = o
        return full

    def seg_apply(s, g_seg, cst, state):
        """Run segment s on its gathered tensors (masked full-tree view)."""
        full: list = [None] * len(metas)
        for i, t in zip(seg_idxs[s], g_seg):
            full[i] = t
        params = jax.tree_util.tree_unflatten(treedef, full)
        return seg_fns[s](params, cst, state)

    # -------------------------------------------------- forward (primal) --
    def one_fwd(leaves, g, xc, idx, nxt_idx, cst, prefetch_last=True):
        """Layer idx's segment chain; bucket s+1 gathers around segment s's
        compute; the last segment prefetches layer nxt_idx's first bucket."""
        state = xc
        for s in range(S):
            last = s == S - 1
            t_idx, t_seg = (nxt_idx, 0) if last else (idx, s + 1)
            do = (not last) or prefetch_last
            g_next = None
            if do and cfg.ag_before_wait_fwd:
                g_next = gather_seg(leaves, t_idx, t_seg)   # AG before Wa
            state = seg_apply(s, g, cst, state)
            if do and not cfg.ag_before_wait_fwd:
                g_next = gather_seg(leaves, t_idx, t_seg, barrier=state)
            g = g_next
        y, aux = state
        return y, aux, g   # g = gathered first bucket of layer nxt_idx

    def fwd_scan(leaves, x0, cst):
        g0 = gather_seg(leaves, 0, 0)   # exposed prologue gather (Fig. 2)
        if L == 1:
            y, aux, _ = one_fwd(leaves, g0, x0, 0, 0, cst,
                                prefetch_last=False)
            return y, aux, jax.tree.map(lambda v: v[None], x0)

        y, aux, g1 = one_fwd(leaves, g0, x0, 0, 1, cst)   # peeled layer 0

        def body(carry, idx):
            xc, aux, g = carry
            nxt = jnp.minimum(idx + 1, L - 1)     # last prefetch is a no-op
            yb, aux_l, g_next = one_fwd(leaves, g, xc, idx, nxt, cst)
            return (yb, jax.tree.map(jnp.add, aux, aux_l), g_next), xc

        (y, aux, _), xs_rest = lax.scan(body, (y, aux, g1),
                                        jnp.arange(1, L))
        xs = jax.tree.map(lambda a, b: jnp.concatenate([a[None], b], 0),
                          x0, xs_rest)
        return y, aux, xs

    # ----------------------------------------------------------- backward --
    def bwd_scan(leaves, xs, dy, daux, cst):
        x_treedef = jax.tree.structure(dy)
        xs_leaves = jax.tree.leaves(xs)
        G = len(flat_groups)

        def slice_x(idx):
            sliced = [lax.dynamic_index_in_dim(v, idx, 0, keepdims=False)
                      for v in xs_leaves]
            return jax.tree_util.tree_unflatten(x_treedef, sliced)

        def pack_seg(s, dg_seg):
            """Segment s's param cotangents -> packed ct per bucket group."""
            return [
                coll.pack_grad_bucket([dg_seg[pos_in[s][i]] for i in grp],
                                      [metas[i] for i in grp], cfg)
                for grp in seg_groups[s]
            ]

        def finalize_group(gi, ct, out):
            """One bucket's RS -> per-leaf local grad chunks into `out`."""
            grp = flat_groups[gi]
            parts = coll.finalize_grad_bucket(
                ct, [metas[i] for i in grp], cfg,
                [shard_shapes[i] for i in grp], flat_precs[gi])
            for i, p in zip(grp, parts):
                out[i] = p

        def finalize(pending):
            out: list = [None] * len(metas)
            for gi, ct in enumerate(pending):
                finalize_group(gi, ct, out)
            return out

        def one_bwd(g_first, idx, dx, prv_idx, prefetch, emit=None):
            """Recompute + vjp layer idx, segment-pipelined.

            g_first: gathered first bucket group of layer idx. The forward
            recompute gathers bucket s+1 around segment s (re-gather =
            selective-AC); the backward segment sweep interleaves the
            delayed per-bucket RS of `emit` (the previous layer's pending
            grads, rs_delay) and the cross-layer prefetch of layer
            prv_idx's first bucket rides the schedule flag.
            """
            x_l = slice_x(idx)
            # ---- forward recompute, bucket-pipelined gathers ----
            vjps: list = [None] * S
            state = x_l
            g = g_first
            g_prev = None
            for s in range(S):
                last = s == S - 1
                if cfg.ag_before_wait_bwd:
                    if not last:
                        g_next = gather_seg(leaves, idx, s + 1)
                    elif prefetch:
                        g_prev = gather_seg(leaves, prv_idx, 0)
                state, vjps[s] = jax.vjp(
                    lambda gl, st, s=s: seg_apply(s, gl, cst, st), g, state)
                if not cfg.ag_before_wait_bwd and not last:
                    g_next = gather_seg(leaves, idx, s + 1, barrier=state)
                if not last:
                    g = g_next
            # ---- backward segment sweep, delayed RS interleaved ----
            emitted = [None] * len(metas) if emit is not None else None
            cts: list = [None] * G
            ct = (dx, daux)
            for s in reversed(range(S)):
                if emit is not None:
                    lo = (S - 1 - s) * G // S
                    hi = (S - s) * G // S
                    for gi in range(lo, hi):   # one RS issue point per bucket
                        finalize_group(gi, emit[gi], emitted)
                dg_seg, ct = vjps[s](ct)
                for k, packed in enumerate(pack_seg(s, dg_seg)):
                    cts[seg_base[s] + k] = packed
            dx_new = ct
            if prefetch and not cfg.ag_before_wait_bwd:
                g_prev = gather_seg(leaves, prv_idx, 0, barrier=dx_new)
            return cts, dx_new, g_prev, emitted

        # peeled layer L-1
        gL = gather_seg(leaves, L - 1, 0)
        pending, dx, g_cur, _ = one_bwd(gL, L - 1, dy, max(L - 2, 0),
                                        prefetch=L > 1)
        if L == 1:
            d_last = finalize(pending)
            return [d[None] for d in d_last], dx
        if not cfg.rs_delay:
            d_top = finalize(pending)  # layer L-1, reduced immediately

        def body(carry, idx):
            dx, g_cur, pending = carry
            prv = jnp.maximum(idx - 1, 0)
            if cfg.rs_delay:
                pending_new, dx_new, g_prev, emitted = one_bwd(
                    g_cur, idx, dx, prv, prefetch=True, emit=pending)
            else:
                pending_new, dx_new, g_prev, _ = one_bwd(
                    g_cur, idx, dx, prv, prefetch=True)
                emitted = finalize(pending_new)   # layer idx, immediate
                pending_new = pending
            return (dx_new, g_prev, pending_new), emitted

        (dx, _, pending), emitted = lax.scan(
            body, (dx, g_cur, pending), jnp.arange(L - 2, -1, -1))

        # Reassemble per-layer grad stacks. Scan step j handled idx = L-2-j.
        if cfg.rs_delay:
            d0 = finalize(pending)   # layer 0 grads still pending
            # emitted[j] = layer L-1-j  ->  flip = layers 1..L-1
            dstack = [
                jnp.concatenate([p0[None], jnp.flip(e, 0)], axis=0)
                for p0, e in zip(d0, emitted)
            ]
        else:
            # emitted[j] = layer L-2-j  ->  flip = layers 0..L-2
            dstack = [
                jnp.concatenate([jnp.flip(e, 0), dt[None]], axis=0)
                for dt, e in zip(d_top, emitted)
            ]
        return dstack, dx

    # ------------------------------------------------------- custom_vjp ----
    @jax.custom_vjp
    def run(stacked_, consts_, x_):
        leaves = treedef.flatten_up_to(stacked_)
        y, aux, _ = fwd_scan(leaves, x_, consts_)
        return y, aux

    def run_fwd(stacked_, consts_, x_):
        leaves = treedef.flatten_up_to(stacked_)
        y, aux, xs = fwd_scan(leaves, x_, consts_)
        return (y, aux), (leaves, consts_, xs)

    def run_bwd(res, cts):
        leaves, consts_, xs = res
        dy, daux = cts
        dstack_leaves, dx = bwd_scan(leaves, xs, dy, daux, consts_)
        dstacked = jax.tree_util.tree_unflatten(treedef, dstack_leaves)
        return dstacked, _zero_cotangent(consts_), dx

    run.defvjp(run_fwd, run_bwd)
    return run(stacked, consts, x)
