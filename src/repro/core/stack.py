"""apply_stack: run a homogeneous layer stack under SimpleFSDP scheduling.

This module is the JAX incarnation of the paper's TorchInductor *backend*
passes (SS3.2). PyTorch reorders already-built IR nodes; XLA exposes no user
IR pass, so we get the same schedules by *constructing* the dataflow so each
communication is independent of the compute it must overlap (DESIGN.md SS2):

  reorder=False  ("vanilla")
      lax.scan(checkpoint(gather -> compute)); every layer's all-gather is
      data-adjacent to its compute — fully exposed communication, exactly the
      paper's unoptimized trace. Gathers are still bucketed per `plan`.
      Backward collectives come from the `gather_group` custom_vjp.

  reorder=True   (bucketing + reordering, paper Fig. 2)
      A hand-scheduled double-buffered scan with a custom VJP:
        forward  — the scan carry holds layer i's gathered bucket; the body
                   first issues bucket i+1's all-gather (AG_{i+1} "before
                   Wa_i"), then computes layer i. Saves ONLY per-layer block
                   inputs (= full activation checkpointing).
        backward — re-gathers bucket i-1 while layer i recomputes+grads
                   (re-gather = the selective-AC MUST_RECOMPUTE semantics),
                   and optionally delays layer i+1's packed reduce-scatter to
                   the start of layer i's step so RS overlaps compute
                   ("Wr12 before RS34").
      The Table-6 ablation flags (ag_before_wait_fwd/bwd, rs_delay) flip these
      placements; the "after" variants insert an optimization_barrier to
      force the sequential schedule they name.

The first (forward) / last (backward) iteration is peeled out of the scan so
every carried value gets its true varying-manual-axes (vma) type from real
computation — scan carries must type-match exactly under shard_map vma.

Block contract:
    block_fn(params_full, consts, x) -> (y, aux)
      params_full : pytree of TP-local compute tensors (structure == metas)
      consts      : pytree treated as constants (rope caches, masks) — zero
                    cotangent (stop-grad)
      x / y       : activation carry pytree (same structure both sides)
      aux         : dict of scalars summed over layers (MoE aux loss etc.)
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import collectives as coll
from repro.core import compat
from repro.core.bucketing import BucketPlan, plan_for
from repro.core.dist import DistConfig
from repro.core.meta import ParamMeta, named_leaves
from repro.core.remat import maybe_remat


def _meta_leaves(metas_tree):
    is_meta = lambda x: isinstance(x, ParamMeta)
    leaves, treedef = jax.tree_util.tree_flatten(metas_tree, is_leaf=is_meta)
    return leaves, treedef


def _zero_cotangent(x):
    def one(v):
        if jnp.issubdtype(v.dtype, jnp.floating) or jnp.issubdtype(
                v.dtype, jnp.complexfloating):
            return jnp.zeros(v.shape, v.dtype)
        return np.zeros(v.shape, jax.dtypes.float0)
    return jax.tree.map(one, x)


def apply_stack(block_fn: Callable, metas_tree, cfg: DistConfig,
                stacked, consts, x, plan: BucketPlan | None = None,
                block_stats=None):
    """Run the layer stack; returns (y, aux_sums)."""
    if plan is None:
        plan = plan_for(metas_tree, cfg, block_stats)
    if cfg.reorder:
        return _prefetch_stack(block_fn, metas_tree, cfg, plan, stacked,
                               consts, x)
    return _vanilla_stack(block_fn, metas_tree, cfg, plan, stacked, consts, x)


# ---------------------------------------------------------------------------
# Vanilla: scan(remat(gather -> compute)). Exposed comm; autodiff backward.
# ---------------------------------------------------------------------------
def _vanilla_stack(block_fn, metas_tree, cfg, plan, stacked, consts, x):
    metas, treedef = _meta_leaves(metas_tree)
    leaves = treedef.flatten_up_to(stacked)
    L = leaves[0].shape[0]

    def layer(xc, layer_shards):
        params = coll.replicate_tree(layer_shards, metas_tree, cfg, plan)
        return block_fn(params, consts, xc)

    layer = maybe_remat(layer, cfg.remat)

    # peel layer 0 (gives the aux accumulator its true vma type)
    y, aux = layer(x, jax.tree_util.tree_unflatten(
        treedef, [l[0] for l in leaves]))
    if L == 1:
        return y, aux

    def body(carry, layer_shards):
        xc, aux = carry
        y, aux_l = layer(xc, layer_shards)
        return (y, jax.tree.map(jnp.add, aux, aux_l)), None

    rest = jax.tree_util.tree_unflatten(treedef, [l[1:] for l in leaves])
    (y, aux), _ = lax.scan(body, (y, aux), rest)
    return y, aux


# ---------------------------------------------------------------------------
# Prefetch: double-buffered scan with hand-written VJP.
# ---------------------------------------------------------------------------
def _prefetch_stack(block_fn, metas_tree, cfg, plan, stacked, consts, x):
    metas, treedef = _meta_leaves(metas_tree)
    groups = plan.index_groups(metas_tree)
    stacked_leaves = treedef.flatten_up_to(stacked)
    L = stacked_leaves[0].shape[0]
    shard_shapes = [m.shard_shape(cfg) for m in metas]

    def slice_layer(leaves, idx):
        return [lax.dynamic_index_in_dim(s, idx, 0, keepdims=False)
                for s in leaves]

    def gather_layer(leaves, idx, barrier=None):
        shards = slice_layer(leaves, idx)
        if barrier is not None:
            # Table-6 'after' placement: tie the gather's inputs to the
            # previous compute so it cannot be scheduled ahead of it.
            # optimization_barrier JOINS the vma of everything it ties, so a
            # raw tie would up-vary TP-replicated shards; instead tie each
            # shard to a zero scalar token derived from the barrier value and
            # psum-reduced down to that shard's own vma class.
            lf = jax.tree.leaves(barrier)[0]
            base = (lf.ravel()[:1].sum() * 0).astype(jnp.float32)
            tokens: dict = {}

            def tok(vma):
                key = frozenset(vma)
                if key not in tokens:
                    extra = tuple(a for a in compat.vma_of(base)
                                  if a not in key)
                    tokens[key] = lax.psum(base, extra) if extra else base
                return tokens[key]

            shards = [
                lax.optimization_barrier((s, tok(compat.vma_of(s))))[0]
                for s in shards
            ]
        full: list = [None] * len(shards)
        for grp in groups:
            outs = coll.gather_group_fwd_raw(
                [shards[i] for i in grp], [metas[i] for i in grp], cfg)
            for i, o in zip(grp, outs):
                full[i] = o
        return full

    def block_on(full_leaves, xc, cst):
        params = jax.tree_util.tree_unflatten(treedef, full_leaves)
        return block_fn(params, cst, xc)

    # -------------------------------------------------- forward (primal) --
    def one_fwd(leaves, g, xc, nxt_idx, cst):
        """One layer: prefetch bucket `nxt_idx` around the compute."""
        if cfg.ag_before_wait_fwd:
            g_next = gather_layer(leaves, nxt_idx)            # AG before Wa
            y, aux_l = block_on(g, xc, cst)
        else:
            y, aux_l = block_on(g, xc, cst)
            g_next = gather_layer(leaves, nxt_idx, barrier=y)
        return y, aux_l, g_next

    def fwd_scan(leaves, x0, cst):
        g0 = gather_layer(leaves, 0)
        if L == 1:
            y, aux = block_on(g0, x0, cst)
            return y, aux, jax.tree.map(lambda v: v[None], x0)

        y, aux, g1 = one_fwd(leaves, g0, x0, 1, cst)   # peeled layer 0

        def body(carry, idx):
            xc, aux, g = carry
            nxt = jnp.minimum(idx + 1, L - 1)     # last prefetch is a no-op
            yb, aux_l, g_next = one_fwd(leaves, g, xc, nxt, cst)
            return (yb, jax.tree.map(jnp.add, aux, aux_l), g_next), xc

        (y, aux, _), xs_rest = lax.scan(body, (y, aux, g1),
                                        jnp.arange(1, L))
        xs = jax.tree.map(lambda a, b: jnp.concatenate([a[None], b], 0),
                          x0, xs_rest)
        return y, aux, xs

    # ----------------------------------------------------------- backward --
    def bwd_scan(leaves, xs, dy, daux, cst):
        x_treedef = jax.tree.structure(dy)
        xs_leaves = jax.tree.leaves(xs)

        def grads_to_buckets(dg_full_leaves):
            return [
                coll.pack_grad_bucket([dg_full_leaves[i] for i in grp],
                                      [metas[i] for i in grp], cfg)
                for grp in groups
            ]

        def finalize(pending):
            """RS each bucket -> per-leaf local grad chunks (flatten order)."""
            out: list = [None] * len(metas)
            for grp, ct in zip(groups, pending):
                parts = coll.finalize_grad_bucket(
                    ct, [metas[i] for i in grp], cfg,
                    [shard_shapes[i] for i in grp])
                for i, p in zip(grp, parts):
                    out[i] = p
            return out

        def one_bwd(g_cur, idx, dx, prv_idx, prefetch):
            """Recompute + vjp layer idx; prefetch bucket prv_idx."""
            g_prev = None
            if prefetch and cfg.ag_before_wait_bwd:
                g_prev = gather_layer(leaves, prv_idx)
            x_l = jax.tree_util.tree_unflatten(
                x_treedef, slice_layer(xs_leaves, idx))
            _, vjp_fn = jax.vjp(
                lambda fl, xc: block_on(fl, xc, cst), g_cur, x_l)
            dg_full, dx_new = vjp_fn((dx, daux))
            if prefetch and not cfg.ag_before_wait_bwd:
                g_prev = gather_layer(leaves, prv_idx, barrier=dx_new)
            return grads_to_buckets(dg_full), dx_new, g_prev

        # peeled layer L-1
        gL = gather_layer(leaves, L - 1)
        pending, dx, g_cur = one_bwd(gL, L - 1, dy, max(L - 2, 0),
                                     prefetch=L > 1)
        if L == 1:
            d_last = finalize(pending)
            return [d[None] for d in d_last], dx
        if not cfg.rs_delay:
            d_top = finalize(pending)  # layer L-1, reduced immediately

        def body(carry, idx):
            dx, g_cur, pending = carry
            if cfg.rs_delay:
                emitted = finalize(pending)   # layer idx+1's RS, issued first
            prv = jnp.maximum(idx - 1, 0)
            pending_new, dx_new, g_prev = one_bwd(g_cur, idx, dx, prv,
                                                  prefetch=True)
            if not cfg.rs_delay:
                emitted = finalize(pending_new)   # layer idx, immediate
                pending_new = pending
            return (dx_new, g_prev, pending_new), emitted

        (dx, _, pending), emitted = lax.scan(
            body, (dx, g_cur, pending), jnp.arange(L - 2, -1, -1))

        # Reassemble per-layer grad stacks. Scan step j handled idx = L-2-j.
        if cfg.rs_delay:
            d0 = finalize(pending)   # layer 0 grads still pending
            # emitted[j] = layer L-1-j  ->  flip = layers 1..L-1
            dstack = [
                jnp.concatenate([p0[None], jnp.flip(e, 0)], axis=0)
                for p0, e in zip(d0, emitted)
            ]
        else:
            # emitted[j] = layer L-2-j  ->  flip = layers 0..L-2
            dstack = [
                jnp.concatenate([jnp.flip(e, 0), dt[None]], axis=0)
                for dt, e in zip(d_top, emitted)
            ]
        return dstack, dx

    # ------------------------------------------------------- custom_vjp ----
    @jax.custom_vjp
    def run(stacked_, consts_, x_):
        leaves = treedef.flatten_up_to(stacked_)
        y, aux, _ = fwd_scan(leaves, x_, consts_)
        return y, aux

    def run_fwd(stacked_, consts_, x_):
        leaves = treedef.flatten_up_to(stacked_)
        y, aux, xs = fwd_scan(leaves, x_, consts_)
        return (y, aux), (leaves, consts_, xs)

    def run_bwd(res, cts):
        leaves, consts_, xs = res
        dy, daux = cts
        dstack_leaves, dx = bwd_scan(leaves, xs, dy, daux, consts_)
        dstacked = jax.tree_util.tree_unflatten(treedef, dstack_leaves)
        return dstacked, _zero_cotangent(consts_), dx

    run.defvjp(run_fwd, run_bwd)
    return run(stacked, consts, x)
