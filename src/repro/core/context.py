"""Context parallelism: zigzag sequence sharding + differentiable ring
attention over the ``ctx`` mesh axis.

The fourth parallelism subsystem (after FSDP, TP/SP and the pipe axis):
``DistConfig.cp_axis`` shards the SEQUENCE dimension of every batch row, so
the trainable context length scales with the ctx degree instead of being
capped by one device's activation memory.  Three pieces:

  * **Zigzag layout** — causal attention work is triangular, so contiguous
    sequence shards leave rank 0 nearly idle.  The global sequence is cut
    into ``2*cp`` chunks and rank ``r`` owns chunks ``r`` and
    ``2*cp-1-r``: every rank holds one early and one late chunk and the
    causal key span summed over a rank's queries is identical across ranks
    (asserted in tests/test_context.py).  `zigzag_batch` applies the
    host-side permutation so a plain contiguous ``P(..., ctx)`` sharding
    spec delivers each rank its zigzag chunks; `zigzag_positions` gives a
    rank its GLOBAL token positions (RoPE phases, causal masks).

  * **Ring attention** (`ring_attention`) — each rank computes its local
    queries against every KV block: blocks circulate over the ctx axis via
    ``lax.ppermute`` with the next hop's exchange issued BEFORE the current
    chunk's attention compute (the CP analogue of `_prefetch_stack`'s
    AG-before-wait), while an online softmax (the same flash blocking as
    `models/layers.attention_chunked`) accumulates across hops so the full
    score matrix never materializes.  Causal masking, gemma2's sliding
    window and attn softcap are applied per block from global positions;
    windowed hops with no in-window pair skip their attention compute via
    ``lax.cond`` (the exchange still runs — the ring must keep moving).

  * **Reverse-ring custom VJP** — gradients are exact and EXPLICIT: the
    backward recirculates KV with travelling dK/dV accumulators (after
    ``cp`` hops each accumulator is back at its owner carrying every
    rank's contribution — the transpose of the forward ring), dQ stays
    local, and softcap/window chain rules are hand-written.  Like
    `core/pipeline.pipe_shift`, every cross-rank cotangent flow is an
    explicit collective with an exact transpose, so cp parity holds on
    every jax version (no vma replication-transpose required — which is
    also why `core/api.plan_parallel` requires the ctx axis to be part of
    ``fsdp_axes``: parameter gradients then cross the ctx axis through the
    bucket reduce-scatter, another explicit collective).

The per-hop math lives in standalone helpers shared by the mesh path and
by `ring_attention_host` / `ring_attention_host_grads` — single-process
emulators that run the identical block updates over sliced shards, which is
what lets tests/test_context.py assert exact parity against
`attention_ref` (forward AND the hand-written backward) without a mesh.

Cost model: `ring_cost` prices one layer's ring (hop bytes, hop compute,
live hops under a sliding window, exposed exchange time) from
`hw.ring_hop_time_s` — the same single cost source the exposure planner
and the dry-run use, so ctx plans and bucket plans are costed coherently.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import hw
from repro.core.dist import DistConfig

_NEG = 1e30          # finite -inf stand-in (matches attention_ref's -1e30)


# ---------------------------------------------------------------------------
# Zigzag layout
# ---------------------------------------------------------------------------
def chunk_len(seq_len: int, cp: int) -> int:
    """Zigzag chunk length: the sequence is viewed as 2*cp chunks (padded
    up when 2*cp does not divide seq_len — pad positions are >= seq_len and
    masked out of attention)."""
    return -(-seq_len // (2 * cp))


def shard_len(seq_len: int, cp: int) -> int:
    """Per-rank sequence shard length (2 chunks)."""
    return 2 * chunk_len(seq_len, cp)


def zigzag_positions(rank, cp: int, seq_len: int):
    """Global token positions of rank `rank`'s shard: chunks (r, 2*cp-1-r).

    `rank` may be a traced scalar (``lax.axis_index`` inside shard_map).
    Positions >= seq_len mark padding (only when 2*cp does not divide
    seq_len — the model path validates divisibility at plan time)."""
    c = chunk_len(seq_len, cp)
    lo = rank * c + jnp.arange(c)
    hi = (2 * cp - 1 - rank) * c + jnp.arange(c)
    return jnp.concatenate([lo, hi])


def zigzag_index(seq_len: int, cp: int) -> np.ndarray:
    """Host-side permutation: ``x[:, zigzag_index(S, cp)]`` reorders the
    sequence so CONTIGUOUS ctx shards (the plain ``P(..., ctx)`` batch
    spec) are exactly each rank's zigzag chunks."""
    if seq_len % (2 * cp):
        raise ValueError(
            f"zigzag sharding needs seq_len % (2*cp) == 0, got "
            f"seq_len={seq_len}, cp={cp}")
    c = seq_len // (2 * cp)
    idx = np.concatenate([
        np.concatenate([np.arange(r * c, (r + 1) * c),
                        np.arange((2 * cp - 1 - r) * c, (2 * cp - r) * c)])
        for r in range(cp)
    ])
    return idx


def zigzag_batch(batch: dict, dcfg: DistConfig) -> dict:
    """Apply the zigzag sequence permutation to every (B, S, ...) entry of
    a host batch (no-op at cp=1).  The Trainer calls this on each batch;
    anything feeding a cp step directly (harness, benches) must too."""
    cp = dcfg.cp_size
    if cp <= 1:
        return batch
    out = {}
    idx_cache: dict[int, np.ndarray] = {}
    for k, v in batch.items():
        if getattr(v, "ndim", 0) >= 2:
            S = v.shape[1]
            if S not in idx_cache:
                idx_cache[S] = zigzag_index(S, cp)
            out[k] = np.ascontiguousarray(np.asarray(v)[:, idx_cache[S]])
        else:
            out[k] = v
    return out


def shard_positions(dcfg: DistConfig, seq_len: int):
    """This ctx rank's global positions (inside shard_map)."""
    if dcfg.cp_size <= 1:
        return jnp.arange(seq_len)
    return zigzag_positions(lax.axis_index(dcfg.cp_axis), dcfg.cp_size,
                            seq_len)


def supports_cp(model) -> bool:
    """Model-contract flag: does this model route attention/RoPE/loss
    through the cp shard (models set ``cp_supported = True``)?"""
    return bool(getattr(model, "cp_supported", False))


# ---------------------------------------------------------------------------
# Per-hop block math (shared by the mesh ring and the host emulators).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RingOpts:
    """Static ring-attention configuration (hashable: custom_vjp nondiff
    arg).  `axis` is None for the host emulators."""

    axis: str | None
    cp: int
    seq_len: int
    causal: bool = True
    window: int | None = None
    softcap: float | None = None
    q_scale: float = 1.0


def _softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


def hop_mask(pos_q, pos_k, opts: RingOpts):
    """(Sq, Sk) bool: which (query, key) pairs of one hop are attended."""
    dq = pos_q[:, None]
    dk = pos_k[None, :]
    m = dk < opts.seq_len                      # pad keys (remainder shards)
    if opts.causal:
        m = m & (dq >= dk)
    if opts.window is not None:
        m = m & (dq - dk < opts.window)
    return m


def _hop_scores(qgs, kb, opts: RingOpts):
    """Scaled-q scores of one hop, softcapped, fp32, UNmasked.
    qgs: (B, Sq, Kh, g, hd) pre-scaled; kb: (B, Sk, Kh, hd)."""
    s = jnp.einsum("bskgh,btkh->bkgst", qgs, kb,
                   preferred_element_type=jnp.float32)
    return _softcap(s, opts.softcap)


def _accum_hop(acc, m, l, qgs, kb, vb, mask, opts: RingOpts):
    """One online-softmax update: fold hop (kb, vb) into (acc, m, l).

    `m` is initialized to -_NEG (finite), so a fully-masked hop leaves the
    carry exactly unchanged (corr == 1, p == 0) with no inf/nan traffic."""
    sc = _hop_scores(qgs, kb, opts)
    sm = jnp.where(mask[None, None, None], sc, -_NEG)
    m_new = jnp.maximum(m, sm.max(-1))
    p = jnp.exp(sm - m_new[..., None]) * mask[None, None, None]
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bkgst,btkh->bkgsh", p, vb.astype(jnp.float32))
    return acc_new, m_new, l_new


def _finish(acc, m, l, q_dtype):
    """(acc, m, l) -> (out (B,Sq,H,hd), lse (B,Kh,g,Sq)).  Dead rows (all
    hops masked — only padding queries) emit 0 with lse clamped finite."""
    lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), 0.0)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    B, Kh, g, Sq, hd = out.shape
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, Sq, Kh * g, hd)
    return out.astype(q_dtype), lse


def _hop_grads(qgs, kb, vb, do_r, D, lse, mask, opts: RingOpts):
    """Hand-written flash backward of one hop.

    qgs: pre-scaled q (B,Sq,Kh,g,hd); do_r/D/lse in the (B,Kh,g,Sq[,hd])
    layout; returns (dqs (B,Kh,g,Sq,hd) — gradient w.r.t. the SCALED q,
    dk_b, dv_b (B,Sk,Kh,hd), all fp32).  Softcap chain rule:
    d tanh-cap/ds = 1 - (sc/cap)^2 with sc the capped score."""
    sc = _hop_scores(qgs, kb, opts)
    p = jnp.exp(sc - lse[..., None]) * mask[None, None, None]
    dv_b = jnp.einsum("bkgst,bkgsh->btkh", p, do_r)
    dp = jnp.einsum("bkgsh,btkh->bkgst", do_r, vb.astype(jnp.float32))
    dsc = p * (dp - D[..., None])
    if opts.softcap:
        dsc = dsc * (1.0 - (sc / opts.softcap) ** 2)
    dqs = jnp.einsum("bkgst,btkh->bkgsh", dsc, kb.astype(jnp.float32))
    dk_b = jnp.einsum("bkgst,bskgh->btkh", dsc, qgs)
    return dqs, dk_b, dv_b


def _hop_maybe(live_fn, idle, mask, opts: RingOpts, skippable: bool):
    """Run one hop's compute, or skip it entirely when the mask admits no
    pair (sliding-window hops whose chunks are out of range).  The skip is
    a per-rank ``lax.cond`` — branches contain NO collectives, so ranks may
    disagree; the ring exchange itself always runs (issued by the caller,
    outside)."""
    if not skippable:
        return live_fn(idle)
    return lax.cond(jnp.any(mask), live_fn, lambda c: c, idle)


# ---------------------------------------------------------------------------
# The mesh ring (runs inside shard_map over dcfg.cp_axis).
# ---------------------------------------------------------------------------
def _ring_perm(cp: int):
    return [(i, (i + 1) % cp) for i in range(cp)]


def _shift(x, opts: RingOpts):
    return lax.ppermute(x, opts.axis, _ring_perm(opts.cp))


def _ring_fwd_impl(q, k, v, opts: RingOpts):
    B, Sq, H, hd = q.shape
    Kh = k.shape[2]
    g = H // Kh
    rank = lax.axis_index(opts.axis)
    pos_q = zigzag_positions(rank, opts.cp, opts.seq_len)
    qgs = (q.astype(jnp.float32) * opts.q_scale).reshape(B, Sq, Kh, g, hd)
    acc = jnp.zeros((B, Kh, g, Sq, hd), jnp.float32)
    m = jnp.full((B, Kh, g, Sq), -_NEG, jnp.float32)
    l = jnp.zeros((B, Kh, g, Sq), jnp.float32)
    kb, vb = k, v
    for t in range(opts.cp):
        src = (rank - t) % opts.cp
        pos_k = zigzag_positions(src, opts.cp, opts.seq_len)
        mask = hop_mask(pos_q, pos_k, opts)
        if t + 1 < opts.cp:
            # issue the NEXT hop's exchange before this hop's attention —
            # the ring analogue of ag_before_wait (overlap by construction)
            kb_n, vb_n = _shift(kb, opts), _shift(vb, opts)
        acc, m, l = _hop_maybe(
            lambda c, kb=kb, vb=vb, mask=mask: _accum_hop(
                *c, qgs, kb, vb, mask, opts),
            (acc, m, l), mask, opts,
            skippable=opts.window is not None and t > 0)
        if t + 1 < opts.cp:
            kb, vb = kb_n, vb_n
    return _finish(acc, m, l, q.dtype)


def _ring_bwd_impl(q, k, v, out, lse, do, opts: RingOpts):
    B, Sq, H, hd = q.shape
    Kh = k.shape[2]
    g = H // Kh
    rank = lax.axis_index(opts.axis)
    pos_q = zigzag_positions(rank, opts.cp, opts.seq_len)
    qgs = (q.astype(jnp.float32) * opts.q_scale).reshape(B, Sq, Kh, g, hd)
    do_r = jnp.transpose(do.astype(jnp.float32)
                         .reshape(B, Sq, Kh, g, hd), (0, 2, 3, 1, 4))
    o_r = jnp.transpose(out.astype(jnp.float32)
                        .reshape(B, Sq, Kh, g, hd), (0, 2, 3, 1, 4))
    D = (do_r * o_r).sum(-1)                       # (B, Kh, g, Sq)
    dq = jnp.zeros((B, Kh, g, Sq, hd), jnp.float32)
    kb, vb = k, v
    # travelling accumulators: dK/dV of the block currently held — they
    # shift WITH the block each hop, so after cp hops each is home with
    # every rank's contribution summed (the reverse ring).
    dka = jnp.zeros(k.shape, jnp.float32)
    dva = jnp.zeros(v.shape, jnp.float32)
    for t in range(opts.cp):
        src = (rank - t) % opts.cp
        pos_k = zigzag_positions(src, opts.cp, opts.seq_len)
        mask = hop_mask(pos_q, pos_k, opts)
        if t + 1 < opts.cp:
            kb_n, vb_n = _shift(kb, opts), _shift(vb, opts)

        def live(c, kb=kb, vb=vb, mask=mask):
            dq_c, dka_c, dva_c = c
            dqs, dk_b, dv_b = _hop_grads(qgs, kb, vb, do_r, D, lse, mask,
                                         opts)
            return (dq_c + dqs, dka_c + dk_b, dva_c + dv_b)

        dq, dka, dva = _hop_maybe(
            live, (dq, dka, dva), mask, opts,
            skippable=opts.window is not None and t > 0)
        dka, dva = _shift(dka, opts), _shift(dva, opts)
        if t + 1 < opts.cp:
            kb, vb = kb_n, vb_n
    dq_full = jnp.transpose(dq, (0, 3, 1, 2, 4)).reshape(B, Sq, H, hd)
    dq_full = (dq_full * opts.q_scale).astype(q.dtype)
    return dq_full, dka.astype(k.dtype), dva.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _ring_attention(q, k, v, opts: RingOpts):
    return _ring_fwd_impl(q, k, v, opts)[0]


def _ring_attention_fwd(q, k, v, opts):
    out, lse = _ring_fwd_impl(q, k, v, opts)
    return out, (q, k, v, out, lse)


def _ring_attention_bwd(opts, res, do):
    q, k, v, out, lse = res
    return _ring_bwd_impl(q, k, v, out, lse, do, opts)


_ring_attention.defvjp(_ring_attention_fwd, _ring_attention_bwd)


def ring_attention(q, k, v, *, dcfg: DistConfig, seq_len: int,
                   causal: bool = True, window: int | None = None,
                   softcap: float | None = None,
                   q_scale: float | None = None):
    """Differentiable ring attention over ``dcfg.cp_axis``.

    q: (B, S/cp, H, hd); k/v: (B, S/cp, Kh, hd) — this rank's ZIGZAG shard
    (positions from `zigzag_positions`); `seq_len` the GLOBAL sequence
    length.  Returns (B, S/cp, H, hd).  Runs inside shard_map; gradients
    are exact via the reverse-ring custom VJP (see module docstring)."""
    hd = q.shape[-1]
    opts = RingOpts(axis=dcfg.cp_axis, cp=dcfg.cp_size, seq_len=seq_len,
                    causal=causal, window=window, softcap=softcap,
                    q_scale=q_scale if q_scale is not None
                    else 1.0 / math.sqrt(hd))
    return _ring_attention(q, k, v, opts)


# ---------------------------------------------------------------------------
# Host emulators: the same per-hop math over sliced shards (no mesh) —
# the unit-test surface for forward AND the hand-written backward.
# ---------------------------------------------------------------------------
def _host_opts(seq_len, cp, causal, window, softcap, q_scale, hd):
    return RingOpts(axis=None, cp=cp, seq_len=seq_len, causal=causal,
                    window=window, softcap=softcap,
                    q_scale=q_scale if q_scale is not None
                    else 1.0 / math.sqrt(hd))


def _zigzag_split(x, cp: int, seq_len: int):
    """Full (B, S, ...) -> per-rank zigzag shards (padded when needed)."""
    c = chunk_len(seq_len, cp)
    pad = 2 * cp * c - seq_len
    xp = jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
    return [jnp.concatenate(
        [xp[:, r * c:(r + 1) * c],
         xp[:, (2 * cp - 1 - r) * c:(2 * cp - r) * c]], axis=1)
        for r in range(cp)]


def _zigzag_join(shards, cp: int, seq_len: int):
    """Inverse of `_zigzag_split` (drops padding)."""
    c = chunk_len(seq_len, cp)
    chunks = [None] * (2 * cp)
    for r, sh in enumerate(shards):
        chunks[r] = sh[:, :c]
        chunks[2 * cp - 1 - r] = sh[:, c:]
    return jnp.concatenate(chunks, axis=1)[:, :seq_len]


def _host_shard_fwd(q_r, ks, vs, r, opts: RingOpts):
    """One emulated rank's forward over every block (visit order matches
    the mesh ring: src = r - t mod cp)."""
    B, Sq, H, hd = q_r.shape
    Kh = ks[0].shape[2]
    g = H // Kh
    pos_q = zigzag_positions(r, opts.cp, opts.seq_len)
    qgs = (q_r.astype(jnp.float32) * opts.q_scale).reshape(B, Sq, Kh, g, hd)
    acc = jnp.zeros((B, Kh, g, Sq, hd), jnp.float32)
    m = jnp.full((B, Kh, g, Sq), -_NEG, jnp.float32)
    l = jnp.zeros((B, Kh, g, Sq), jnp.float32)
    for t in range(opts.cp):
        src = (r - t) % opts.cp
        pos_k = zigzag_positions(src, opts.cp, opts.seq_len)
        mask = hop_mask(pos_q, pos_k, opts)
        acc, m, l = _accum_hop(acc, m, l, qgs, ks[src], vs[src], mask, opts)
    return _finish(acc, m, l, q_r.dtype), qgs


def ring_attention_host(q, k, v, cp: int, *, causal: bool = True,
                        window: int | None = None,
                        softcap: float | None = None,
                        q_scale: float | None = None):
    """Single-process emulation of the ring over FULL (B, S, H, hd)
    inputs: zigzag-split, per-rank online-softmax sweep (identical hop
    updates to the mesh path), reassemble.  Differentiable by autodiff —
    tests pit it (and `ring_attention_host_grads`) against attention_ref."""
    seq_len = q.shape[1]
    opts = _host_opts(seq_len, cp, causal, window, softcap, q_scale,
                      q.shape[-1])
    qs = _zigzag_split(q, cp, seq_len)
    ks = _zigzag_split(k, cp, seq_len)
    vs = _zigzag_split(v, cp, seq_len)
    outs = [_host_shard_fwd(qs[r], ks, vs, r, opts)[0][0]
            for r in range(cp)]
    return _zigzag_join(outs, cp, seq_len)


def ring_attention_host_grads(q, k, v, do, cp: int, *, causal: bool = True,
                              window: int | None = None,
                              softcap: float | None = None,
                              q_scale: float | None = None):
    """Drive the HAND-WRITTEN per-hop backward (`_hop_grads` — the exact
    math the mesh reverse-ring VJP runs) on full tensors: returns
    (dq, dk, dv).  The mesh VJP's travelling accumulators become direct
    scatter-adds here; parity against ``jax.grad(attention_ref)`` is the
    unit-level proof of the reverse ring."""
    seq_len = q.shape[1]
    opts = _host_opts(seq_len, cp, causal, window, softcap, q_scale,
                      q.shape[-1])
    qs = _zigzag_split(q, cp, seq_len)
    ks = _zigzag_split(k, cp, seq_len)
    vs = _zigzag_split(v, cp, seq_len)
    dos = _zigzag_split(do, cp, seq_len)
    dqs_out = []
    dk_acc = [jnp.zeros(ks[0].shape, jnp.float32) for _ in range(cp)]
    dv_acc = [jnp.zeros(vs[0].shape, jnp.float32) for _ in range(cp)]
    for r in range(cp):
        (out_r, lse), qgs = _host_shard_fwd(qs[r], ks, vs, r, opts)
        B, Sq, H, hd = qs[r].shape
        Kh = ks[0].shape[2]
        g = H // Kh
        do_r = jnp.transpose(dos[r].astype(jnp.float32)
                             .reshape(B, Sq, Kh, g, hd), (0, 2, 3, 1, 4))
        o_r = jnp.transpose(out_r.astype(jnp.float32)
                            .reshape(B, Sq, Kh, g, hd), (0, 2, 3, 1, 4))
        D = (do_r * o_r).sum(-1)
        dq = jnp.zeros((B, Kh, g, Sq, hd), jnp.float32)
        pos_q = zigzag_positions(r, opts.cp, opts.seq_len)
        for src in range(cp):
            pos_k = zigzag_positions(src, opts.cp, opts.seq_len)
            mask = hop_mask(pos_q, pos_k, opts)
            dq_h, dk_b, dv_b = _hop_grads(qgs, ks[src], vs[src], do_r, D,
                                          lse, mask, opts)
            dq = dq + dq_h
            dk_acc[src] = dk_acc[src] + dk_b
            dv_acc[src] = dv_acc[src] + dv_b
        dq = jnp.transpose(dq, (0, 3, 1, 2, 4)).reshape(B, Sq, H, hd)
        dqs_out.append(dq * opts.q_scale)
    return (_zigzag_join(dqs_out, cp, seq_len).astype(q.dtype),
            _zigzag_join(dk_acc, cp, seq_len).astype(k.dtype),
            _zigzag_join(dv_acc, cp, seq_len).astype(v.dtype))


# ---------------------------------------------------------------------------
# Cost model (hw.ring_hop_time_s is the single hop-cost source).
# ---------------------------------------------------------------------------
def ring_live_hops(cp: int, seq_len: int, window: int | None) -> int:
    """Modeled count of ring hops with any in-window attention work.

    Full/causal attention touches every hop (zigzag gives every rank one
    early chunk every other rank's late queries see).  A sliding window of
    w only reaches chunks within ~w of a query chunk: hops whose nearest
    chunk distance exceeds the window carry no live pair and skip their
    attention compute (`_hop_maybe`); their exchange still runs."""
    if window is None or cp <= 1:
        return cp
    c = chunk_len(seq_len, cp)
    return max(1, min(cp, 2 + window // max(1, c)))


def ring_cost(arch_cfg, dcfg: DistConfig, batch_shape,
              window: int | None = None) -> dict:
    """Modeled per-layer ring-attention schedule for one attention call.

    `batch_shape` is the per-device (rows, seq_shard).  Returns hop bytes /
    per-hop comm and compute times / live hops / total EXPOSED exchange
    time: exchange t+1 is issued before hop t's compute, so a live hop
    hides one exchange and only the spill (or a skipped hop's whole
    exchange) is exposed — the quantity dry-run rows and BENCH_context
    track across cp degrees."""
    B, S_local = batch_shape
    cp = dcfg.cp_size
    tp = dcfg.tp_size
    it = jnp.dtype(dcfg.param_dtype).itemsize
    lay = arch_cfg.gqa_layout(tp)
    kl = max(1, lay["kvp"] // tp)          # kv heads held per rank
    hd = arch_cfg.head_dim
    hop_bytes = 2.0 * B * S_local * kl * hd * it          # one K+V block
    hop_comm_s = hw.ring_hop_time_s(hop_bytes, dcfg.cp_axis or "data")
    # per-hop attention compute: scores + out for Sq x Sk block, all local
    # q heads (4 = 2 matmuls x 2 flops/MAC)
    hop_flops = 4.0 * B * S_local * S_local * hd * (lay["hq"] / tp)
    hop_comp_s = hop_flops / hw.PEAK_FLOPS_BF16
    seq_global = S_local * cp
    live = ring_live_hops(cp, seq_global, window)
    # cp-1 exchanges: those riding a live hop hide behind its compute;
    # skipped hops expose their whole exchange (the ring must keep moving)
    hidden = max(0, live - 1)
    exposed = hidden * max(0.0, hop_comm_s - hop_comp_s) \
        + max(0, (cp - 1) - hidden) * hop_comm_s
    return {
        "cp": cp, "seq_local": S_local, "hop_bytes": hop_bytes,
        "hop_comm_s": hop_comm_s, "hop_comp_s": hop_comp_s,
        "live_hops": live, "exposed_s": exposed,
        "total_comm_s": (cp - 1) * hop_comm_s,
    }
