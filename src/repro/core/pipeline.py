"""Pipeline parallelism (GPipe microbatch schedule) composed with SimpleFSDP.

Paper SS4 "Pipeline Parallel": each device receives its stage's submodule and
SimpleFSDP wraps it — no extra code. Same shape here: the `pipe` mesh axis
holds one stage per rank; stage parameters are ordinary SimpleFSDP storage
(ZeRO-3 over the FSDP axes, bucket-gathered per use), and activations stream
between stages with `lax.ppermute` inside the same shard_map (so the full
computation+communication graph — FSDP gathers AND pipeline sends — is one
jit, the paper's full-graph property).

Schedule: GPipe with M microbatches over S stages: T = M + S - 1 slots; slot
t computes microbatch (t - stage) on each stage and permutes activations
forward. Autodiff through ppermute gives the reverse-permute backward (1F1B
memory behaviour is a follow-up; M activations are live, as in GPipe).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.dist import DistConfig


def pipe_rank(axis: str):
    return lax.axis_index(axis)


def gpipe(stage_fn: Callable, xs, n_stages: int, axis: str = "pipe"):
    """Run `stage_fn(x) -> y` as an S-stage pipeline.

    Inside shard_map: every rank along `axis` holds ITS stage's closure
    (stage_fn usually closes over that rank's gathered params). `xs` is the
    (M, ...) stack of microbatch activations fed to stage 0 (other ranks'
    xs values are ignored). Returns the (M, ...) outputs of the LAST stage
    (valid on every rank only at stage S-1; callers psum/select as needed).
    """
    M = xs.shape[0]
    S = n_stages
    T = M + S - 1
    rank = pipe_rank(axis)
    perm = [(i, (i + 1) % S) for i in range(S)]

    buf0 = jnp.zeros_like(xs)          # per-stage output collection
    state0 = jnp.zeros_like(xs[0])     # activation entering this stage

    def slot(carry, t):
        state, outs = carry
        mb_idx = t - rank              # microbatch this stage works on
        active = (mb_idx >= 0) & (mb_idx < M)
        # stage 0 pulls its input from xs; others use the permuted state
        x_in = jnp.where(rank == 0,
                         xs[jnp.clip(mb_idx, 0, M - 1)], state)
        y = stage_fn(x_in)
        y = jnp.where(active, y, state)
        # last stage collects; everyone else forwards
        outs = jnp.where(
            (rank == S - 1) & active,
            lax.dynamic_update_index_in_dim(
                outs, y, jnp.clip(mb_idx, 0, M - 1), 0),
            outs)
        state_next = lax.ppermute(y, axis, perm)
        return (state_next, outs), None

    (_, outs), _ = lax.scan(slot, (state0, buf0), jnp.arange(T))
    return outs
