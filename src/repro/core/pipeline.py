"""Pipeline parallelism (GPipe and 1F1B schedules) composed with SimpleFSDP.

Paper SS4 "Pipeline Parallel": each device receives its stage's submodule and
SimpleFSDP wraps it — no extra code. Same shape here: the `pipe` mesh axis
holds one stage per rank; stage parameters are ordinary SimpleFSDP storage
(ZeRO-3 over the FSDP axes, bucket-gathered per use via `fsdp_stage_fn`), and
activations stream between stages with `pipe_shift` — a `ppermute` whose
custom backward is the reverse permute of the cotangent — inside the same
shard_map (so the full computation+communication graph — FSDP gathers AND
pipeline sends — is one jit, the paper's full-graph property).

Mesh layout convention (pp x dp x tp): axes are ordered
``('pipe', <fsdp/data axes...>, 'model')`` with **pipe outermost**.  Per-slot
pipeline traffic is one small point-to-point activation send, so it tolerates
the slowest interconnect (DCN), while the fat FSDP all-gathers and TP psums
stay on the inner ICI axes.  `DistConfig.pp_axis` names the pipe axis;
`dp_total` and `grad_sync_axes` exclude it (pipe ranks own DISTINCT stage
parameters — nothing to sync, nothing data-parallel).

Two stage contracts share the same schedule cores:

  * **Raw-stream contract** (`gpipe_grads` / `one_f_one_b` /
    `pipeline_grads`): ``stage_fn(params, x) -> y`` with an (M, ...)
    activation stack ``xs`` injected at stage 0 and ``loss_fn(y) -> scalar``
    per microbatch.  This is the bring-your-own-stage path (dist_harness
    `pipeline`, benchmarks).
  * **Model contract** (`gpipe_loss_grads` / `one_f_one_b_loss_grads` /
    `pipeline_loss_grads`): ``stage_step(params, state, mb) -> state`` where
    `state` is ANY pytree (the homogeneous inter-stage activation state) and
    ``mb`` is a raw per-microbatch batch pytree from the M-leading stream
    ``mbs`` (the same stream on every pipe rank; never differentiated unless
    ``with_dxs``).  `stage_step` performs its own stage-0 injection (derive
    the state from `mb` and `jnp.where(rank == 0, ...)` it in), which is how
    a full LM enters tokens at the bottom; ``loss_fn(params, y, mb)`` runs
    the head+loss of the LAST stage (masked there by the schedule, traced on
    every rank — SPMD-uniform collectives).  `ParallelPlan.stage`
    (core/api.py) + the models' stage contract (models/common.StageSpec)
    drive this path via train/train_step.make_staged_train_step.

Schedules and their memory models (M microbatches, S stages):

  * GPipe (`gpipe`, `gpipe_grads`, `gpipe_loss_grads`): T = M + S - 1
    forward slots; slot t computes microbatch (t - stage) on each stage.
    Backward is ordinary autodiff through the scan, so every stage keeps
    **M** live microbatch activations (all forwards finish before any
    backward starts).
  * 1F1B (`one_f_one_b`, `one_f_one_b_loss_grads`): T = 2(M + S - 1) slots;
    stage s runs forward of microbatch m at slot s + 2m and backward of m at
    slot 2(S-1) - s + 2m + 1 (opposite parities, so each stage does one unit
    of work per slot, one forward per backward in steady state).  Stage
    inputs are kept in a ring buffer of depth **S** and the backward
    recomputes the stage via `jax.vjp` from the saved input, so live
    activation storage is bounded by S (in fact S - s at stage s)
    **independent of M** — the PipeDream-flush/1F1B memory bound, vs
    GPipe's M.
  * Interleaved 1F1B (`pp_schedule='interleaved'`, `_table_loss_grads` over
    a `PipeSchedule`): each pipe rank owns **V non-contiguous virtual stage
    slices** (chunk j = v*S + s of the layer stack lives on rank j % S), so
    the warmup/cooldown ramps are V times shallower in stage units — bubble
    ~(S-1)/(V*M+S-1).  Every forward hop is the SAME +1 cyclic ppermute
    (rank S-1 -> 0 advances to the next chunk round), the price is ~V times
    the saved-input states per rank (see `schedule_peak_state`).  Needs the
    model contract (the chunk_step slices its chunk's parameters); the raw
    stream cannot run it.
  * Zero-bubble W-split (`pp_schedule='zb'`, `zero_bubble`): F and the
    input-grad half (Bx) of the backward keep their 1F1B slots; the
    weight-grad half W is decoupled (pushed onto a per-rank queue at Bx,
    drained at a first-fit scheduled W slot), so the dW work fills the
    cooldown bubble — modeled idle drops to ~(S-1)/(3M+S-1), strictly below
    1F1B for every M.  Conceptually the same dW/dX flow separation as the
    bucketed `rs_delay` (core/fsdp v2): delay the weight-gradient flow so
    the critical dX path never waits on it.

All schedules return identical losses/gradients (exact-parity tested against
a single-device dense reference in tests/dist_harness.py cases `pipeline`,
`pipeline_v2` and `trainer_pipeline`).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.dist import DistConfig


def pipe_rank(axis: str):
    return lax.axis_index(axis)


def _fwd_perm(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def _bwd_perm(n: int):
    return [(i, (i - 1) % n) for i in range(n)]


# ---------------------------------------------------------------------------
# The differentiable pipeline send: forward permute, reverse-permute backward.
# ---------------------------------------------------------------------------
def _shift_raw(x, axis: str, n_stages: int):
    return lax.ppermute(x, axis, _fwd_perm(n_stages))


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def pipe_shift(x, axis: str, n_stages: int):
    """Send `x` to the next pipe rank (cyclically). The cotangent travels the
    opposite direction: d(stage s+1 input) arrives back at stage s."""
    return _shift_raw(x, axis, n_stages)


def _pipe_shift_fwd(x, axis, n_stages):
    return _shift_raw(x, axis, n_stages), None


def _pipe_shift_bwd(axis, n_stages, _res, ct):
    return (lax.ppermute(ct, axis, _bwd_perm(n_stages)),)


pipe_shift.defvjp(_pipe_shift_fwd, _pipe_shift_bwd)


# ---------------------------------------------------------------------------
# Pytree helpers: the inter-stage state (and microbatch stream) are pytrees.
# ---------------------------------------------------------------------------
def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _tree_index(tree, i):
    return jax.tree.map(
        lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False), tree)


def _tree_update(tree, val, i, pred=None):
    def one(a, v):
        upd = lax.dynamic_update_index_in_dim(a, v.astype(a.dtype), i, 0)
        return upd if pred is None else jnp.where(pred, upd, a)
    return jax.tree.map(one, tree, val)


def _tree_shift(tree, axis, n):
    return jax.tree.map(lambda a: pipe_shift(a, axis, n), tree)


def _tree_stack_zeros(template, n):
    return jax.tree.map(
        lambda l: jnp.zeros((n,) + tuple(l.shape), l.dtype), template)


def _leading_dim(tree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


# ---------------------------------------------------------------------------
# Schedule tables (pure host-side helpers; used by tests, benches and docs).
# ---------------------------------------------------------------------------
def gpipe_schedule(n_micro: int, n_stages: int) -> np.ndarray:
    """(T, S) table: microbatch id stage s computes at slot t, -1 when idle.

    T = M + S - 1; stage s is active exactly on slots [s, s + M)."""
    T = n_micro + n_stages - 1
    sched = np.full((T, n_stages), -1, dtype=np.int64)
    for t in range(T):
        for s in range(n_stages):
            mb = t - s
            if 0 <= mb < n_micro:
                sched[t, s] = mb
    return sched


def one_f_one_b_schedule(n_micro: int, n_stages: int) \
        -> tuple[np.ndarray, np.ndarray]:
    """Two (T, S) tables (fwd_mb, bwd_mb): microbatch whose forward /
    backward stage s runs at slot t, -1 when idle.  T = 2(M + S - 1);
    forward of m at stage s lands on slot s + 2m, backward on
    2(S-1) - s + 2m + 1 — opposite parities, so a stage never does both in
    one slot, and at most S - s microbatches are in flight at stage s."""
    M, S = n_micro, n_stages
    T = 2 * (M + S - 1)
    fwd = np.full((T, S), -1, dtype=np.int64)
    bwd = np.full((T, S), -1, dtype=np.int64)
    for s in range(S):
        for m in range(M):
            fwd[s + 2 * m, s] = m
            bwd[2 * (S - 1) - s + 2 * m + 1, s] = m
    return fwd, bwd


PIPE_SCHEDULES = ("gpipe", "1f1b", "interleaved", "zb")


def _greedy_interleaved(n_micro: int, n_stages: int, virtual: int):
    """Slot assignment for interleaved 1F1B: virtual stage j = v*S + s lives
    on rank j % S (rank r owns V non-contiguous chunks).  A greedy list
    scheduler — one work unit per rank per slot, backwards first (lowest
    microbatch), then forwards advancing the DEEPEST ready chunk (highest j)
    — reproduces the Megatron-style interleaved pattern: for M a multiple of
    S it lands on T = 2(V*M + S - 1) chunk-slots, i.e. bubble
    (S-1)/(V*M + S - 1), ~1/V of plain 1F1B's.

    Dependencies: F(j,m) after F(j-1,m) (the +1 cyclic activation hop —
    rank S-1 -> rank 0 advances the chunk, so EVERY forward send is the
    same ppermute); B(j,m) after F(j,m) and B(j+1,m) (cotangents travel the
    reverse ring); the last virtual stage seeds its own cotangent from the
    loss.  Returns ({(j, m): slot} x2 for fwd/bwd).
    """
    M, S, V = n_micro, n_stages, virtual
    VS = V * S
    fslot: dict = {}
    bslot: dict = {}
    pend_f = {(j, m) for j in range(VS) for m in range(M)}
    pend_b = set(pend_f)
    t = 0
    limit = 4 * (VS * M + S) + 8
    while pend_f or pend_b:
        if t > limit:
            raise RuntimeError(
                f"interleaved scheduler stalled (M={M} S={S} V={V})")
        for r in range(S):
            ready_b = sorted(
                (m, j) for (j, m) in pend_b
                if j % S == r and (j, m) in fslot and fslot[(j, m)] < t
                and (j == VS - 1
                     or ((j + 1, m) in bslot and bslot[(j + 1, m)] < t)))
            if ready_b:
                m, j = ready_b[0]
                bslot[(j, m)] = t
                pend_b.discard((j, m))
                continue
            ready_f = sorted(
                (-j, m) for (j, m) in pend_f
                if j % S == r
                and (j == 0 or ((j - 1, m) in fslot and fslot[(j - 1, m)] < t)))
            if ready_f:
                j, m = -ready_f[0][0], ready_f[0][1]
                fslot[(j, m)] = t
                pend_f.discard((j, m))
        t += 1
    return fslot, bslot


def _zb_assignment(n_micro: int, n_stages: int):
    """Slot assignment for the zero-bubble W-split: F and the input-grad
    half (Bx) keep their exact 1F1B positions; the weight-grad half W(m) is
    first-fit placed into this rank's idle slots after Bx(m), in microbatch
    order (FIFO — the drain order of the W queue).  The cooldown bubble
    behind the last Bx absorbs the W work, so the idle fraction drops below
    1F1B's (S-1)/(M+S-1) for every M >= 1, S > 1.

    Returns ({(j,m): slot} fwd, same bwd, {(rank, m): slot} W)."""
    M, S = n_micro, n_stages
    fslot = {(s, m): s + 2 * m for s in range(S) for m in range(M)}
    bslot = {(s, m): 2 * (S - 1) - s + 2 * m + 1
             for s in range(S) for m in range(M)}
    wslot: dict = {}
    for s in range(S):
        busy = {fslot[(s, m)] for m in range(M)}
        busy |= {bslot[(s, m)] for m in range(M)}
        prev = -1
        for m in range(M):
            t = max(bslot[(s, m)] + 1, prev + 1)
            while t in busy:
                t += 1
            wslot[(s, m)] = t
            busy.add(t)
            prev = t
    return fslot, bslot, wslot


def _alloc_registers(entries):
    """Interval register allocation: entries [(birth, death, key)] ->
    ({key: reg}, n_regs).  Greedy smallest-free-index over lifetimes
    (optimal for intervals); birth/death slots are inclusive."""
    import heapq

    regs: dict = {}
    free: list = []
    active: list = []          # (death, reg)
    n_regs = 0
    for birth, death, key in sorted(entries):
        while active and active[0][0] < birth:
            _, r = heapq.heappop(active)
            heapq.heappush(free, r)
        if free:
            r = heapq.heappop(free)
        else:
            r = n_regs
            n_regs += 1
        regs[key] = r
        heapq.heappush(active, (death, r))
    return regs, max(n_regs, 1)


class PipeSchedule:
    """A fully tabulated pipeline schedule for the scan engine
    (`_table_loss_grads`): (T, S) int32/bool tables indexed [slot, rank].

    Forward tables: `f_mb`/`f_chunk` — microbatch / local chunk this rank
    runs (-1 idle); `f_in` — input ring-buffer register to read;
    `f_recv` — register the activation arriving at the START of this slot
    (sent by the left neighbour last slot) is written to (-1 none).
    Backward tables: `b_mb`/`b_chunk`/`b_in` (saved-input register for the
    recompute replay), `b_ct` (cotangent register to consume), `b_recv`
    (arriving cotangent's register), `b_last` (this backward is the LAST
    virtual stage — seed the cotangent from the loss instead).
    W-split tables (zb): `b_push` — W-queue register the weight-grad half
    is pushed to at a Bx slot; `w_idx` — register drained at a W slot.

    `depth_in`/`depth_ct`/`depth_w` size the ring buffers (max over ranks
    of an optimal interval register allocation of entry lifetimes)."""

    def __init__(self, schedule: str, n_micro: int, n_stages: int,
                 virtual: int = 1):
        M, S = n_micro, n_stages
        V = virtual if schedule == "interleaved" else 1
        if schedule == "interleaved":
            fslot, bslot = _greedy_interleaved(M, S, V)
            wslot = {}
        elif schedule == "zb":
            fslot, bslot, wslot = _zb_assignment(M, S)
        else:
            raise ValueError(
                f"PipeSchedule tabulates 'interleaved'/'zb', not "
                f"{schedule!r}")
        VS = V * S
        T = 1 + max(max(fslot.values()), max(bslot.values()),
                    max(wslot.values(), default=0))
        self.schedule, self.n_micro, self.n_stages, self.virtual, self.slots \
            = schedule, M, S, V, T

        ii = lambda: np.full((T, S), -1, np.int32)
        self.f_mb, self.f_chunk, self.f_in, self.f_recv = (ii(), ii(), ii(),
                                                           ii())
        self.b_mb, self.b_chunk, self.b_in, self.b_ct, self.b_recv = (
            ii(), ii(), ii(), ii(), ii())
        self.b_push, self.w_idx = ii(), ii()
        self.b_last = np.zeros((T, S), bool)

        # input entries: chunk j's input (j>0) arrives at fslot(j-1)+1 and
        # is read at its forward AND at its backward replay; j=0 injects
        # from the microbatch stream (no buffer entry, dummy register 0)
        in_regs: dict = {}
        depth_in = 1
        for r in range(S):
            ent = [(fslot[(j - 1, m)] + 1, bslot[(j, m)], (j, m))
                   for (j, m) in fslot if j % S == r and j > 0]
            regs, n = _alloc_registers(ent)
            in_regs.update(regs)
            depth_in = max(depth_in, n)
        # cotangent entries: d(chunk j output) is produced by B(j+1,m) on
        # rank (j+1)%S and reverse-ppermuted here, arriving at
        # bslot(j+1)+1; consumed at bslot(j).  The last virtual stage seeds
        # from the loss; B(0,m)'s outgoing dx is the stream cotangent
        # (with_dxs) and is never ring-buffered.
        ct_regs: dict = {}
        depth_ct = 1
        for r in range(S):
            ent = [(bslot[(j + 1, m)] + 1, bslot[(j, m)], (j, m))
                   for (j, m) in bslot if j % S == r and j < VS - 1]
            regs, n = _alloc_registers(ent)
            ct_regs.update(regs)
            depth_ct = max(depth_ct, n)
        # W-queue entries (zb): pushed at the Bx slot, drained at the W slot
        w_regs: dict = {}
        depth_w = 1
        for r in range(S):
            ent = [(bslot[(r, m)], wslot[(r, m)], m)
                   for (rr, m) in wslot if rr == r]
            regs, n = _alloc_registers(ent)
            w_regs.update({(r, m): v for m, v in regs.items()})
            depth_w = max(depth_w, n)
        self.depth_in, self.depth_ct, self.depth_w = (depth_in, depth_ct,
                                                      depth_w)

        for (j, m), t in fslot.items():
            s = j % S
            self.f_mb[t, s] = m
            self.f_chunk[t, s] = j // S
            self.f_in[t, s] = in_regs.get((j, m), 0)
            if j + 1 < VS:
                nxt = j + 1                       # arrives at rank (j+1)%S
                self.f_recv[t + 1, nxt % S] = in_regs[(nxt, m)]
        for (j, m), t in bslot.items():
            s = j % S
            self.b_mb[t, s] = m
            self.b_chunk[t, s] = j // S
            self.b_in[t, s] = in_regs.get((j, m), 0)
            self.b_ct[t, s] = ct_regs.get((j, m), 0)
            self.b_last[t, s] = j == VS - 1
            if j > 0 and t + 1 < T:
                self.b_recv[t + 1, (j - 1) % S] = ct_regs[(j - 1, m)]
            if wslot:
                self.b_push[t, s] = w_regs[(s, m)]
        for (s, m), t in wslot.items():
            self.w_idx[t, s] = w_regs[(s, m)]

        # per-rank peak of simultaneously live saved-input states (the
        # in-flight memory model consumed by core/memory/simulator)
        self.peak_state = [0] * S
        for r in range(S):
            ent = [(fslot[(j - 1, m)] + 1, bslot[(j, m)])
                   for (j, m) in fslot if j % S == r and j > 0]
            for t in range(T):
                live = sum(1 for b, d in ent if b <= t <= d)
                self.peak_state[r] = max(self.peak_state[r], live)
        # rank 0's chunk-0 inputs live on the microbatch stream, not the
        # ring; count them as one resident state so the model never says 0
        self.peak_state = [max(1, p) for p in self.peak_state]

    @property
    def work_units(self) -> int:
        """Uniform-cost work slots per rank (F=Bx=W=1, full backward = 2):
        2*V*M chunk-units for interleaved (each 1/V of a stage unit, so
        utilization compares 1:1 with 1F1B), 3*M for zb."""
        if self.schedule == "zb":
            return 3 * self.n_micro
        return 2 * self.virtual * self.n_micro


@functools.lru_cache(maxsize=None)
def build_pipe_schedule(n_micro: int, n_stages: int, schedule: str,
                        virtual: int = 1) -> PipeSchedule:
    return PipeSchedule(schedule, n_micro, n_stages, virtual)


def schedule_slots(n_micro: int, n_stages: int, schedule: str,
                   virtual: int = 1) -> int:
    """Total scan length of a schedule (analytic for gpipe/1f1b, from the
    built table for interleaved/zb)."""
    if schedule == "gpipe":
        return n_micro + n_stages - 1
    if schedule == "1f1b":
        return 2 * (n_micro + n_stages - 1)
    if schedule in ("interleaved", "zb"):
        return build_pipe_schedule(n_micro, n_stages, schedule,
                                   virtual).slots
    raise ValueError(f"unknown pipeline schedule {schedule!r}; "
                     f"known: {PIPE_SCHEDULES}")


def bubble_fraction(n_micro: int, n_stages: int, schedule: str,
                    virtual: int = 1) -> float:
    """Idle fraction of the schedule under uniform work units (F = Bx = W =
    1 unit; a full backward = 2 — so `modeled_step = work / (1 - bubble)`
    is comparable across schedules):

      * gpipe == 1f1b: (S-1)/(M+S-1) (1F1B trades nothing in bubble, only
        in memory);
      * interleaved: (S-1)/(V*M+S-1) for M a multiple of S — each rank's
        V chunk slices shrink the warmup/cooldown ramps by ~1/V (computed
        from the built table, so irregular M stays honest);
      * zb: the W half of the backward fills the cooldown ramp; from the
        built table (~(S-1)/(3M+S-1) at the ideal placement), strictly
        below 1F1B for every M >= 1, S > 1.
    """
    if schedule in ("gpipe", "1f1b"):
        return (n_stages - 1) / (n_micro + n_stages - 1)
    if schedule in ("interleaved", "zb"):
        sched = build_pipe_schedule(n_micro, n_stages, schedule, virtual)
        return 1.0 - sched.work_units / sched.slots
    raise ValueError(f"unknown pipeline schedule {schedule!r}; "
                     f"known: {PIPE_SCHEDULES}")


def schedule_peak_state(n_micro: int, n_stages: int, schedule: str,
                        virtual: int = 1) -> list:
    """Per-rank peak count of resident microbatch input states (the
    in-flight memory model): M for gpipe, min(M, S-s) for 1f1b/zb, and the
    table-derived buffer peak for interleaved (V chunks per rank hold
    ~V * min(M, S-s) states — the schedule's extra in-flight memory)."""
    M, S = n_micro, n_stages
    if schedule == "gpipe":
        return [M] * S
    if schedule in ("1f1b", "zb"):
        return [max(1, min(M, S - s)) for s in range(S)]
    if schedule == "interleaved":
        return list(build_pipe_schedule(M, S, schedule, virtual).peak_state)
    raise ValueError(f"unknown pipeline schedule {schedule!r}; "
                     f"known: {PIPE_SCHEDULES}")


def zb_queue_depth(n_micro: int, n_stages: int) -> int:
    """Max backlog of the zb W-queue (weight-grad halves pushed at Bx,
    drained at W slots) — sizes the queue's parameter-gradient storage."""
    return build_pipe_schedule(n_micro, n_stages, "zb").depth_w


# ---------------------------------------------------------------------------
# GPipe: forward-only schedule, differentiable end-to-end by autodiff.
# ---------------------------------------------------------------------------
def gpipe(stage_fn: Callable, xs, n_stages: int, axis: str = "pipe"):
    """Run `stage_fn(x) -> y` as an S-stage pipeline.

    Inside shard_map: every rank along `axis` holds ITS stage's closure
    (stage_fn usually closes over that rank's gathered params). `xs` is the
    (M, ...) stack (any pytree, M-leading) of microbatch activations fed to
    stage 0 (other ranks' xs values are ignored). Returns the (M, ...)
    outputs of the LAST stage (valid on every rank only at stage S-1;
    callers psum/select as needed).

    Differentiable: activation sends use `pipe_shift`, whose backward
    reverse-permutes the cotangents, so plain `jax.grad` through this
    function yields the pipelined backward schedule (at the cost of M live
    activations per stage — use `one_f_one_b` for the S-bounded variant).
    """
    M = _leading_dim(xs)
    S = n_stages
    T = M + S - 1
    rank = pipe_rank(axis)

    state0 = _tree_index(xs, 0)
    state0 = jax.tree.map(jnp.zeros_like, state0)
    buf0 = jax.tree.map(jnp.zeros_like, xs)     # per-stage output collection

    def slot(carry, t):
        state, outs = carry
        mb_idx = t - rank              # microbatch this stage works on
        active = (mb_idx >= 0) & (mb_idx < M)
        mbc = jnp.clip(mb_idx, 0, M - 1)
        # stage 0 pulls its input from xs; others use the permuted state
        x_in = _tree_where(rank == 0, _tree_index(xs, mbc), state)
        y = stage_fn(x_in)
        y = _tree_where(active, y, state)
        # last stage collects; everyone else forwards
        outs = _tree_update(outs, y, mbc, pred=(rank == S - 1) & active)
        state_next = _tree_shift(y, axis, S)
        return (state_next, outs), None

    (_, outs), _ = lax.scan(slot, (state0, buf0), jnp.arange(T))
    return outs


# ---------------------------------------------------------------------------
# Schedule cores (model contract):
#   stage_step(params, state, mb, pre) -> state,
#   loss_fn(params, y, mb) -> scalar,
#   pre_fn(params, mbs) -> (M, ...) stack of stage-0 entry states (or None).
# `stage_step` does its own stage-0 injection — from its per-slot `pre`
# when a pre_fn is given (the hoisted stage_pre stream: traced ONCE per
# step, not once per slot), else from `mb` directly (see module docstring).
# ---------------------------------------------------------------------------
def _pre_slot(pres, mbc):
    return _tree_index(pres, mbc) if pres is not None else ()


def _gpipe_total_loss(stage_step: Callable, loss_fn: Callable, state0,
                      n_stages: int, axis: str, pre_fn: Callable | None = None):
    """The masked total-loss function shared by the GPipe grad and
    forward-only (eval) paths."""
    S = n_stages
    rank = pipe_rank(axis)

    def run(params, mbs):
        M = _leading_dim(mbs)
        T = M + S - 1
        outs0 = _tree_stack_zeros(state0, M)
        # hoisted stage-0 stream: ONE trace before the slot loop; autodiff
        # routes the per-slot injection cotangents back through it
        pres = pre_fn(params, mbs) if pre_fn is not None else None

        def slot(carry, t):
            state, outs = carry
            mb_idx = t - rank
            active = (mb_idx >= 0) & (mb_idx < M)
            mbc = jnp.clip(mb_idx, 0, M - 1)
            y = stage_step(params, state, _tree_index(mbs, mbc),
                           _pre_slot(pres, mbc))
            y = _tree_where(active, y, state)
            outs = _tree_update(outs, y, mbc, pred=(rank == S - 1) & active)
            return (_tree_shift(y, axis, S), outs), None

        (_, outs), _ = lax.scan(slot, (state0, outs0), jnp.arange(T))
        # per-microbatch losses over the collected last-stage outputs.
        # lax.map (not vmap): the LM loss contains collectives (vocab-
        # parallel CE psums) whose scan-body form is uniform on every rank.
        losses = lax.map(lambda ym: loss_fn(params, ym[0], ym[1]),
                         (outs, mbs))
        return jnp.where(rank == S - 1, jnp.sum(losses), 0.0)

    return run


def gpipe_loss(stage_step: Callable, loss_fn: Callable, params, mbs, state0,
               n_stages: int, axis: str = "pipe",
               pre_fn: Callable | None = None):
    """Forward-only pipelined total loss (eval path), psum'ed over `axis`."""
    run = _gpipe_total_loss(stage_step, loss_fn, state0, n_stages, axis,
                            pre_fn)
    return lax.psum(run(params, mbs), axis)


def gpipe_loss_grads(stage_step: Callable, loss_fn: Callable, params, mbs,
                     state0, n_stages: int, axis: str = "pipe",
                     with_dxs: bool = False,
                     pre_fn: Callable | None = None):
    """(loss, dparams, dmbs?) for the GPipe schedule via autodiff.

    `mbs` is the M-leading microbatch stream (identical on every pipe rank);
    `state0` a zero pytree of the inter-stage state.  The loss is masked to
    the last stage (SPMD grad convention: every rank seeds a backward and
    the `pipe_shift` transposes SUM them, so sum_r L_r == L) and psum'ed
    over `axis` for logging.  `dmbs` (d loss / d mbs, meaningful where the
    stream is consumed — stage 0 and the last stage) is only computed under
    ``with_dxs``; the LM path never differentiates the raw batch.
    """
    run = _gpipe_total_loss(stage_step, loss_fn, state0, n_stages, axis,
                            pre_fn)
    if with_dxs:
        loss, (dparams, dmbs) = jax.value_and_grad(run, argnums=(0, 1))(
            params, mbs)
    else:
        loss, dparams = jax.value_and_grad(run)(params, mbs)
        dmbs = None
    return lax.psum(loss, axis), dparams, dmbs


def one_f_one_b_loss_grads(stage_step: Callable, loss_fn: Callable, params,
                           mbs, state0, n_stages: int, axis: str = "pipe",
                           with_dxs: bool = False,
                           pre_fn: Callable | None = None):
    """(loss, dparams, dmbs?) under the 1F1B schedule — same contract as
    `gpipe_loss_grads`, but the backward is hand-interleaved with the
    forward.

    Per slot each stage does (at most) one forward and one backward, on
    opposite parities (see `one_f_one_b_schedule`). Incoming stage states
    are saved in a ring buffer of depth S and the backward re-runs the stage
    (and, on the last rank, the loss) via `jax.vjp` from the saved input
    (recompute-based, like the FSDP selective-AC re-gather), so live
    activation memory is O(S), not O(M).  Cotangents are zeroed on inactive
    slots, which makes the vjp's parameter/input gradients vanish by
    linearity — no masking of the accumulators is needed.

    With `pre_fn`, the stage-0 entry stream is computed ONCE (one trace,
    one `jax.vjp` outside the slot loop); per-slot replays differentiate
    w.r.t. their `pre` slice, the cotangents accumulate into a d(pres)
    stream, and the hoisted vjp maps it back to parameter gradients after
    the scan.  Non-injecting ranks contribute exact zeros (linearity).
    """
    M = _leading_dim(mbs)
    S = n_stages
    T = schedule_slots(M, S, "1f1b")
    rank = pipe_rank(axis)
    on_last = rank == S - 1

    if pre_fn is not None:
        pres, pre_vjp = jax.vjp(lambda p: pre_fn(p, mbs), params)
    else:
        pres, pre_vjp = None, None

    def fwd_and_loss(p, x, pr, mb):
        y = stage_step(p, x, mb, pr)
        return y, loss_fn(p, y, mb)

    carry0 = (
        state0,                                    # state from the left
        jax.tree.map(jnp.zeros_like, state0),      # cotangent from the right
        _tree_stack_zeros(state0, S),              # ring of saved inputs
        jax.tree.map(jnp.zeros_like, params),      # grad accumulator
        jax.tree.map(jnp.zeros_like, pres) if pres is not None else (),
        jax.tree.map(jnp.zeros_like, mbs) if with_dxs else (),
        jnp.zeros((), jnp.float32),                # loss accumulator
    )

    def slot(carry, t):
        fwd_state, bwd_state, ring, acc_g, d_pres, dmbs, loss_acc = carry

        # forward half: microbatch mf at slot rank + 2*mf --------------------
        tf = t - rank
        mf = tf // 2
        fwd_active = (tf >= 0) & (tf % 2 == 0) & (mf < M)
        mfc = jnp.clip(mf, 0, M - 1)
        y = stage_step(params, fwd_state, _tree_index(mbs, mfc),
                       _pre_slot(pres, mfc))
        y = _tree_where(fwd_active, y, fwd_state)
        # save the INCOMING state; the backward replay re-runs stage_step on
        # it (stage 0's injection re-derives its input from `pre`/`mb`)
        ring = _tree_update(ring, fwd_state, mfc % S, pred=fwd_active)

        # backward half: microbatch mb at slot 2(S-1) - rank + 2*mb + 1 ------
        tb = t - (2 * (S - 1) - rank + 1)
        mb = tb // 2
        bwd_active = (tb >= 0) & (tb % 2 == 0) & (mb < M)
        mbc = jnp.clip(mb, 0, M - 1)
        x_saved = _tree_index(ring, mbc % S)
        mb_b = _tree_index(mbs, mbc)
        pre_b = _pre_slot(pres, mbc)
        if with_dxs:
            (_, l_mb), vjp = jax.vjp(fwd_and_loss, params, x_saved, pre_b,
                                     mb_b)
        else:
            (_, l_mb), vjp = jax.vjp(
                lambda p, x, pr: fwd_and_loss(p, x, pr, mb_b), params,
                x_saved, pre_b)
        ct_y = _tree_where(bwd_active & ~on_last, bwd_state,
                           jax.tree.map(jnp.zeros_like, bwd_state))
        ct_l = jnp.where(bwd_active & on_last, jnp.ones_like(l_mb),
                         jnp.zeros_like(l_mb))
        out_ct = vjp((ct_y, ct_l))
        dp, dx = out_ct[0], out_ct[1]
        acc_g = jax.tree.map(jnp.add, acc_g, dp)
        loss_acc = loss_acc + jnp.where(
            bwd_active & on_last, l_mb, 0.0).astype(jnp.float32)
        if pres is not None:
            d_pres = _tree_update(d_pres, out_ct[2], mbc, pred=bwd_active)
        if with_dxs:
            dmbs = _tree_update(dmbs, out_ct[3], mbc, pred=bwd_active)

        # communicate: activations right, cotangents left --------------------
        fwd_next = jax.tree.map(lambda a: _shift_raw(a, axis, S), y)
        bwd_next = jax.tree.map(
            lambda a: lax.ppermute(a, axis, _bwd_perm(S)), dx)
        return (fwd_next, bwd_next, ring, acc_g, d_pres, dmbs, loss_acc), None

    carry, _ = lax.scan(slot, carry0, jnp.arange(T))
    _, _, _, grads, d_pres, dmbs, loss = carry
    if pre_vjp is not None:
        grads = jax.tree.map(jnp.add, grads, pre_vjp(d_pres)[0])
    return lax.psum(loss, axis), grads, (dmbs if with_dxs else None)


# ---------------------------------------------------------------------------
# Table engine: runs any PipeSchedule (interleaved, zb) slot by slot.
# Chunk contract: chunk_step(params, chunk, state, mb, pre) -> state, where
# `chunk` is the LOCAL virtual-stage index on this rank (traced int; V=1
# schedules always pass 0) — the step slices its chunk's parameters and does
# its own injection for (rank 0, chunk 0).
# ---------------------------------------------------------------------------
def _table_loss_grads(sched: PipeSchedule, chunk_step: Callable,
                      loss_fn: Callable, params, mbs, state0, axis: str,
                      with_dxs: bool = False,
                      pre_fn: Callable | None = None):
    """(loss, dparams, dmbs?) by scanning a tabulated schedule.

    One scan slot = one table row: (1) the activation/cotangent sent by the
    neighbours LAST slot is filed into its ring-buffer register (`f_recv` /
    `b_recv`); (2) the forward chunk runs from its input register; (3) the
    backward chunk replays from its SAVED input register via `jax.vjp`
    (recompute-based, exactly like 1F1B) with the cotangent read from the
    ct register — or seeded from the loss on the last virtual stage — and
    its parameter gradient is accumulated; under zb the blocks' weight-grad
    half is instead pushed onto the W-queue at its `b_push` register and
    drained into the accumulator at the scheduled W slot; (4) this slot's
    outputs are ppermuted (+1 for activations, -1 for cotangents) —
    unconditionally, SPMD-uniform; receivers discard garbage by table.

    Inactive phases run masked (zero cotangents -> exact-zero gradient
    contributions by linearity), so accumulators need no masking beyond
    the table preds.  Gradient exactness is pinned by the dist_harness
    `pipeline_v2` parity case.
    """
    M, S, T = sched.n_micro, sched.n_stages, sched.slots
    rank = pipe_rank(axis)
    is_zb = sched.schedule == "zb"

    if pre_fn is not None:
        pres, pre_vjp = jax.vjp(lambda p: pre_fn(p, mbs), params)
    else:
        pres, pre_vjp = None, None

    if is_zb:
        # W-split: the queue holds the FULL parameter-gradient pytree of one
        # backward (the weight half); the input half is the dx that leaves
        # immediately.  depth_w bounds the backlog.
        wq0 = _tree_stack_zeros(jax.tree.map(jnp.zeros_like, params),
                                sched.depth_w)
    else:
        wq0 = ()

    zeros_state = jax.tree.map(jnp.zeros_like, state0)
    carry0 = (
        zeros_state,                                   # arriving activation
        zeros_state,                                   # arriving cotangent
        _tree_stack_zeros(state0, sched.depth_in),     # saved-input registers
        _tree_stack_zeros(state0, sched.depth_ct),     # cotangent registers
        jax.tree.map(jnp.zeros_like, params),          # grad accumulator
        wq0,                                           # zb W-queue
        jax.tree.map(jnp.zeros_like, pres) if pres is not None else (),
        jax.tree.map(jnp.zeros_like, mbs) if with_dxs else (),
        jnp.zeros((), jnp.float32),                    # loss accumulator
    )
    tables = dict(
        f_mb=sched.f_mb, f_chunk=sched.f_chunk, f_in=sched.f_in,
        f_recv=sched.f_recv, b_mb=sched.b_mb, b_chunk=sched.b_chunk,
        b_in=sched.b_in, b_ct=sched.b_ct, b_recv=sched.b_recv,
        b_last=sched.b_last, b_push=sched.b_push, w_idx=sched.w_idx)
    tables = {k: jnp.asarray(v) for k, v in tables.items()}

    def slot(carry, row):
        (in_state, in_ct, in_buf, ct_buf, acc_g, wq, d_pres, dmbs,
         loss_acc) = carry
        g = lambda k: row[k][rank]

        # (1) file the arrivals --------------------------------------------
        f_recv, b_recv = g("f_recv"), g("b_recv")
        in_buf = _tree_update(in_buf, in_state, jnp.maximum(f_recv, 0),
                              pred=f_recv >= 0)
        ct_buf = _tree_update(ct_buf, in_ct, jnp.maximum(b_recv, 0),
                              pred=b_recv >= 0)

        # (2) forward chunk -------------------------------------------------
        mfc = jnp.clip(g("f_mb"), 0, M - 1)
        y = chunk_step(params, jnp.maximum(g("f_chunk"), 0),
                       _tree_index(in_buf, g("f_in")),
                       _tree_index(mbs, mfc), _pre_slot(pres, mfc))

        # (3) backward chunk: replay from the saved input -------------------
        b_act = g("b_mb") >= 0
        mbc = jnp.clip(g("b_mb"), 0, M - 1)
        chunk_b = jnp.maximum(g("b_chunk"), 0)
        x_saved = _tree_index(in_buf, g("b_in"))
        mb_b = _tree_index(mbs, mbc)
        pre_b = _pre_slot(pres, mbc)
        on_last = g("b_last")

        def replay(p, x, pr, mb_):
            yb = chunk_step(p, chunk_b, x, mb_, pr)
            return yb, loss_fn(p, yb, mb_)

        if with_dxs:
            (_, l_mb), vjp = jax.vjp(replay, params, x_saved, pre_b, mb_b)
        else:
            (_, l_mb), vjp = jax.vjp(
                lambda p, x, pr: replay(p, x, pr, mb_b), params, x_saved,
                pre_b)
        ct_read = _tree_index(ct_buf, g("b_ct"))
        ct_y = _tree_where(b_act & ~on_last, ct_read,
                           jax.tree.map(jnp.zeros_like, ct_read))
        ct_l = jnp.where(b_act & on_last, jnp.ones_like(l_mb),
                         jnp.zeros_like(l_mb))
        out_ct = vjp((ct_y, ct_l))
        dp, dx = out_ct[0], out_ct[1]
        if is_zb:
            # push the weight half; drain the scheduled W-queue register
            push, widx = g("b_push"), g("w_idx")
            wq = _tree_update(wq, dp, jnp.maximum(push, 0),
                              pred=(push >= 0) & b_act)
            drained = _tree_index(wq, jnp.maximum(widx, 0))
            acc_g = jax.tree.map(
                lambda a, d: a + jnp.where(widx >= 0, d, jnp.zeros_like(d)),
                acc_g, drained)
        else:
            acc_g = jax.tree.map(jnp.add, acc_g, dp)
        loss_acc = loss_acc + jnp.where(
            b_act & on_last, l_mb, 0.0).astype(jnp.float32)
        if pres is not None:
            # accumulate (a rank backs up SEVERAL chunks of the same
            # microbatch under interleaving; non-injecting chunks add exact
            # zeros)
            d_pres = _tree_update(
                d_pres,
                jax.tree.map(jnp.add, _tree_index(d_pres, mbc), out_ct[2]),
                mbc, pred=b_act)
        if with_dxs:
            dmbs = _tree_update(
                dmbs,
                jax.tree.map(jnp.add, _tree_index(dmbs, mbc), out_ct[3]),
                mbc, pred=b_act)

        # (4) communicate: activations +1, cotangents -1 --------------------
        out_state = jax.tree.map(lambda a: _shift_raw(a, axis, S), y)
        out_ct = jax.tree.map(
            lambda a: lax.ppermute(a, axis, _bwd_perm(S)), dx)
        return (out_state, out_ct, in_buf, ct_buf, acc_g, wq, d_pres, dmbs,
                loss_acc), None

    carry, _ = lax.scan(slot, carry0, tables)
    _, _, _, _, grads, _, d_pres, dmbs, loss = carry
    if pre_vjp is not None:
        grads = jax.tree.map(jnp.add, grads, pre_vjp(d_pres)[0])
    return lax.psum(loss, axis), grads, (dmbs if with_dxs else None)


def _as_chunk_step(stage_step: Callable) -> Callable:
    """Lift a single-chunk stage_step(params, state, mb, pre) to the chunk
    contract (V=1 schedules: the chunk index is always 0)."""
    return lambda p, chunk, state, mb, pre: stage_step(p, state, mb, pre)


def resolve_schedule(schedule: str) -> str:
    """Map 'auto' to a concrete schedule for paths without a planner (BYO
    dispatchers, benches): zb — it strictly improves the 1F1B bubble with
    the same V=1 stage layout, so it is the safe argmin absent a model."""
    return "zb" if schedule == "auto" else schedule


def pipeline_loss_grads(stage_step: Callable, loss_fn: Callable, params, mbs,
                        state0, cfg: DistConfig, schedule: str | None = None,
                        with_dxs: bool = False,
                        pre_fn: Callable | None = None,
                        chunk_step: Callable | None = None):
    """Dispatch the model-contract schedules: (loss, dparams, dmbs?).

    `cfg.pp_axis` names the pipe mesh axis; `cfg.pp_size` is the stage
    count; `schedule` overrides `cfg.pp_schedule` ('auto' resolves to zb
    here — the planner resolves 'auto' properly via the cost model before
    reaching this).  The interleaved schedule needs `chunk_step` (per-
    virtual-stage parameter slicing) and `cfg.pp_virtual >= 2`.
    """
    if cfg.pp_axis is None:
        raise ValueError(
            "pipeline_loss_grads needs cfg.pp_axis (the pipe axis)")
    M = _leading_dim(mbs)
    if cfg.pp_microbatches and M != cfg.pp_microbatches:
        raise ValueError(
            f"mbs carries {M} microbatches but cfg.pp_microbatches="
            f"{cfg.pp_microbatches}; stack the batch to match (or leave "
            "pp_microbatches=0 to accept any M)")
    schedule = resolve_schedule(schedule or cfg.pp_schedule)
    if schedule == "gpipe":
        return gpipe_loss_grads(stage_step, loss_fn, params, mbs, state0,
                                cfg.pp_size, cfg.pp_axis, with_dxs, pre_fn)
    if schedule == "1f1b":
        return one_f_one_b_loss_grads(stage_step, loss_fn, params, mbs,
                                      state0, cfg.pp_size, cfg.pp_axis,
                                      with_dxs, pre_fn)
    if schedule in ("zb", "interleaved"):
        if schedule == "interleaved":
            if chunk_step is None:
                raise ValueError(
                    "pp_schedule='interleaved' needs a chunk_step (per-"
                    "virtual-stage parameter slicing); the staged Trainer "
                    "path provides one — see train/train_step.py")
            V = cfg.pp_virtual
            if V < 2:
                raise ValueError(
                    "pp_schedule='interleaved' needs pp_virtual >= 2 "
                    f"(got {V}); plan_parallel resolves pp_virtual=0")
        else:
            V = 1
            chunk_step = chunk_step or _as_chunk_step(stage_step)
        sched = build_pipe_schedule(M, cfg.pp_size, schedule, V)
        return _table_loss_grads(sched, chunk_step, loss_fn, params, mbs,
                                 state0, cfg.pp_axis, with_dxs, pre_fn)
    raise ValueError(f"unknown pipeline schedule {schedule!r}; "
                     f"known: {PIPE_SCHEDULES} or 'auto'")


# ---------------------------------------------------------------------------
# Raw-stream contract (bring-your-own-stage): stage_fn(params, x) with an
# (M, ...) activation stack injected at stage 0 — adapters over the cores.
# ---------------------------------------------------------------------------
def _inject_xs(stage_fn: Callable, axis: str):
    """Lift stage_fn(params, x) to the model contract: the per-slot `mb` IS
    the stage-0 activation, where()'d in on rank 0 (the transpose routes the
    stage-0 input cotangent back onto the stream — that is `dxs`).  The
    `pre` slot of the 4-arg contract is unused (raw streams have no hoisted
    stage-0 entry computation)."""
    def step(params, state, mb, pre=()):
        x_in = _tree_where(lax.axis_index(axis) == 0, mb, state)
        return stage_fn(params, x_in)
    return step


def gpipe_grads(stage_fn: Callable, params, xs, loss_fn: Callable,
                n_stages: int, axis: str = "pipe"):
    """(loss, dparams, dxs) for the GPipe schedule via autodiff.

    `stage_fn(params, x) -> y` runs this rank's stage on its own `params`;
    `loss_fn(y) -> scalar` is one microbatch's contribution to the total
    loss (include any 1/M normalization there). `dparams` is each rank's own
    stage gradient; `dxs` is d(loss)/d(xs), meaningful on rank 0.
    """
    state0 = jax.tree.map(jnp.zeros_like, _tree_index(xs, 0))
    loss, dparams, dxs = gpipe_loss_grads(
        _inject_xs(stage_fn, axis), lambda p, y, mb: loss_fn(y), params,
        xs, state0, n_stages, axis, with_dxs=True)
    return loss, dparams, dxs


def one_f_one_b(stage_fn: Callable, params, xs, loss_fn: Callable,
                n_stages: int, axis: str = "pipe"):
    """(loss, dparams, dxs) under the 1F1B schedule — same contract as
    `gpipe_grads`, but with the S-bounded live-activation memory model."""
    state0 = jax.tree.map(jnp.zeros_like, _tree_index(xs, 0))
    loss, dparams, dxs = one_f_one_b_loss_grads(
        _inject_xs(stage_fn, axis), lambda p, y, mb: loss_fn(y), params,
        xs, state0, n_stages, axis, with_dxs=True)
    return loss, dparams, dxs


def zero_bubble(stage_fn: Callable, params, xs, loss_fn: Callable,
                n_stages: int, axis: str = "pipe"):
    """(loss, dparams, dxs) under the zero-bubble W-split schedule — same
    contract as `gpipe_grads`.  F/Bx sit at their 1F1B slots; the weight-
    grad half of each backward is queued and drained into the accumulator
    at its scheduled W slot (filling the cooldown bubble), so the modeled
    idle fraction drops to ~(S-1)/(3M+S-1)."""
    M = _leading_dim(xs)
    state0 = jax.tree.map(jnp.zeros_like, _tree_index(xs, 0))
    sched = build_pipe_schedule(M, n_stages, "zb")
    loss, dparams, dxs = _table_loss_grads(
        sched, _as_chunk_step(_inject_xs(stage_fn, axis)),
        lambda p, y, mb: loss_fn(y), params, xs, state0, axis,
        with_dxs=True)
    return loss, dparams, dxs


# ---------------------------------------------------------------------------
# SimpleFSDP composition + schedule dispatch.
# ---------------------------------------------------------------------------
def fsdp_stage_fn(stage_fn: Callable, metas_tree, cfg: DistConfig, plan=None):
    """Wrap `stage_fn(full_params, x)` so it takes ZeRO-3 storage shards and
    bucket-gathers them PER USE inside the pipelined stage (paper SS4: the
    stage submodule is SimpleFSDP-wrapped with no extra code).

    The gather is the differentiable `gather_group` custom_vjp, so each
    backward slot issues the matching reduce-scatter; under a non-'none'
    remat policy the gathered params are dropped after forward use and
    re-gathered in backward (selective-AC), keeping the per-slot footprint
    at one bucket.
    """
    from repro.core.collectives import replicate_tree
    from repro.core.remat import maybe_remat, whole_block_policy

    # a per-segment vector (core/memory's resolved form) collapses to its
    # most aggressive entry here — the BYO stage fn is one opaque block
    policy = whole_block_policy(cfg.remat)

    def wrapped(storage, x):
        def inner(storage, x):
            full = replicate_tree(storage, metas_tree, cfg, plan)
            return stage_fn(full, x)
        return maybe_remat(inner, policy)(storage, x)

    return wrapped


def pipeline_grads(stage_fn: Callable, params, xs, loss_fn: Callable,
                   cfg: DistConfig, schedule: str | None = None):
    """Dispatch the raw-stream schedules: (loss, dparams, dxs).

    `cfg.pp_axis` names the pipe mesh axis; `cfg.pp_size` is the stage
    count; `schedule` overrides `cfg.pp_schedule`.
    """
    if cfg.pp_axis is None:
        raise ValueError("pipeline_grads needs cfg.pp_axis (the pipe axis)")
    if cfg.pp_microbatches and _leading_dim(xs) != cfg.pp_microbatches:
        raise ValueError(
            f"xs carries {_leading_dim(xs)} microbatches but "
            f"cfg.pp_microbatches={cfg.pp_microbatches}; stack the batch to "
            "match (or leave pp_microbatches=0 to accept any M)")
    schedule = resolve_schedule(schedule or cfg.pp_schedule)
    args = (stage_fn, params, xs, loss_fn, cfg.pp_size, cfg.pp_axis)
    if schedule == "gpipe":
        return gpipe_grads(*args)
    if schedule == "1f1b":
        return one_f_one_b(*args)
    if schedule == "zb":
        return zero_bubble(*args)
    if schedule == "interleaved":
        raise ValueError(
            "the raw-stream contract cannot run 'interleaved': an opaque "
            "stage_fn(params, x) has no virtual-stage slicing.  Use the "
            "model contract (parallelize() / pipeline_loss_grads with a "
            "chunk_step) instead.")
    raise ValueError(f"unknown pipeline schedule {schedule!r}; "
                     f"known: {PIPE_SCHEDULES} or 'auto'")
