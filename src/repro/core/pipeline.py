"""Pipeline parallelism (GPipe and 1F1B schedules) composed with SimpleFSDP.

Paper SS4 "Pipeline Parallel": each device receives its stage's submodule and
SimpleFSDP wraps it — no extra code. Same shape here: the `pipe` mesh axis
holds one stage per rank; stage parameters are ordinary SimpleFSDP storage
(ZeRO-3 over the FSDP axes, bucket-gathered per use via `fsdp_stage_fn`), and
activations stream between stages with `pipe_shift` — a `ppermute` whose
custom backward is the reverse permute of the cotangent — inside the same
shard_map (so the full computation+communication graph — FSDP gathers AND
pipeline sends — is one jit, the paper's full-graph property).

Mesh layout convention (pp x dp x tp): axes are ordered
``('pipe', <fsdp/data axes...>, 'model')`` with **pipe outermost**.  Per-slot
pipeline traffic is one small point-to-point activation send, so it tolerates
the slowest interconnect (DCN), while the fat FSDP all-gathers and TP psums
stay on the inner ICI axes.  `DistConfig.pp_axis` names the pipe axis;
`dp_total` and `grad_sync_axes` exclude it (pipe ranks own DISTINCT stage
parameters — nothing to sync, nothing data-parallel).

Two stage contracts share the same schedule cores:

  * **Raw-stream contract** (`gpipe_grads` / `one_f_one_b` /
    `pipeline_grads`): ``stage_fn(params, x) -> y`` with an (M, ...)
    activation stack ``xs`` injected at stage 0 and ``loss_fn(y) -> scalar``
    per microbatch.  This is the bring-your-own-stage path (dist_harness
    `pipeline`, benchmarks).
  * **Model contract** (`gpipe_loss_grads` / `one_f_one_b_loss_grads` /
    `pipeline_loss_grads`): ``stage_step(params, state, mb) -> state`` where
    `state` is ANY pytree (the homogeneous inter-stage activation state) and
    ``mb`` is a raw per-microbatch batch pytree from the M-leading stream
    ``mbs`` (the same stream on every pipe rank; never differentiated unless
    ``with_dxs``).  `stage_step` performs its own stage-0 injection (derive
    the state from `mb` and `jnp.where(rank == 0, ...)` it in), which is how
    a full LM enters tokens at the bottom; ``loss_fn(params, y, mb)`` runs
    the head+loss of the LAST stage (masked there by the schedule, traced on
    every rank — SPMD-uniform collectives).  `ParallelPlan.stage`
    (core/api.py) + the models' stage contract (models/common.StageSpec)
    drive this path via train/train_step.make_staged_train_step.

Schedules and their memory models (M microbatches, S stages):

  * GPipe (`gpipe`, `gpipe_grads`, `gpipe_loss_grads`): T = M + S - 1
    forward slots; slot t computes microbatch (t - stage) on each stage.
    Backward is ordinary autodiff through the scan, so every stage keeps
    **M** live microbatch activations (all forwards finish before any
    backward starts).
  * 1F1B (`one_f_one_b`, `one_f_one_b_loss_grads`): T = 2(M + S - 1) slots;
    stage s runs forward of microbatch m at slot s + 2m and backward of m at
    slot 2(S-1) - s + 2m + 1 (opposite parities, so each stage does one unit
    of work per slot, one forward per backward in steady state).  Stage
    inputs are kept in a ring buffer of depth **S** and the backward
    recomputes the stage via `jax.vjp` from the saved input, so live
    activation storage is bounded by S (in fact S - s at stage s)
    **independent of M** — the PipeDream-flush/1F1B memory bound, vs
    GPipe's M.

Both schedules return identical losses/gradients (exact-parity tested against
a single-device dense reference in tests/dist_harness.py cases `pipeline` and
`trainer_pipeline`).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.dist import DistConfig


def pipe_rank(axis: str):
    return lax.axis_index(axis)


def _fwd_perm(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def _bwd_perm(n: int):
    return [(i, (i - 1) % n) for i in range(n)]


# ---------------------------------------------------------------------------
# The differentiable pipeline send: forward permute, reverse-permute backward.
# ---------------------------------------------------------------------------
def _shift_raw(x, axis: str, n_stages: int):
    return lax.ppermute(x, axis, _fwd_perm(n_stages))


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def pipe_shift(x, axis: str, n_stages: int):
    """Send `x` to the next pipe rank (cyclically). The cotangent travels the
    opposite direction: d(stage s+1 input) arrives back at stage s."""
    return _shift_raw(x, axis, n_stages)


def _pipe_shift_fwd(x, axis, n_stages):
    return _shift_raw(x, axis, n_stages), None


def _pipe_shift_bwd(axis, n_stages, _res, ct):
    return (lax.ppermute(ct, axis, _bwd_perm(n_stages)),)


pipe_shift.defvjp(_pipe_shift_fwd, _pipe_shift_bwd)


# ---------------------------------------------------------------------------
# Pytree helpers: the inter-stage state (and microbatch stream) are pytrees.
# ---------------------------------------------------------------------------
def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _tree_index(tree, i):
    return jax.tree.map(
        lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False), tree)


def _tree_update(tree, val, i, pred=None):
    def one(a, v):
        upd = lax.dynamic_update_index_in_dim(a, v.astype(a.dtype), i, 0)
        return upd if pred is None else jnp.where(pred, upd, a)
    return jax.tree.map(one, tree, val)


def _tree_shift(tree, axis, n):
    return jax.tree.map(lambda a: pipe_shift(a, axis, n), tree)


def _tree_stack_zeros(template, n):
    return jax.tree.map(
        lambda l: jnp.zeros((n,) + tuple(l.shape), l.dtype), template)


def _leading_dim(tree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


# ---------------------------------------------------------------------------
# Schedule tables (pure host-side helpers; used by tests, benches and docs).
# ---------------------------------------------------------------------------
def gpipe_schedule(n_micro: int, n_stages: int) -> np.ndarray:
    """(T, S) table: microbatch id stage s computes at slot t, -1 when idle.

    T = M + S - 1; stage s is active exactly on slots [s, s + M)."""
    T = n_micro + n_stages - 1
    sched = np.full((T, n_stages), -1, dtype=np.int64)
    for t in range(T):
        for s in range(n_stages):
            mb = t - s
            if 0 <= mb < n_micro:
                sched[t, s] = mb
    return sched


def one_f_one_b_schedule(n_micro: int, n_stages: int) \
        -> tuple[np.ndarray, np.ndarray]:
    """Two (T, S) tables (fwd_mb, bwd_mb): microbatch whose forward /
    backward stage s runs at slot t, -1 when idle.  T = 2(M + S - 1);
    forward of m at stage s lands on slot s + 2m, backward on
    2(S-1) - s + 2m + 1 — opposite parities, so a stage never does both in
    one slot, and at most S - s microbatches are in flight at stage s."""
    M, S = n_micro, n_stages
    T = 2 * (M + S - 1)
    fwd = np.full((T, S), -1, dtype=np.int64)
    bwd = np.full((T, S), -1, dtype=np.int64)
    for s in range(S):
        for m in range(M):
            fwd[s + 2 * m, s] = m
            bwd[2 * (S - 1) - s + 2 * m + 1, s] = m
    return fwd, bwd


def schedule_slots(n_micro: int, n_stages: int, schedule: str) -> int:
    """Total scan length of a schedule (analytic)."""
    if schedule == "gpipe":
        return n_micro + n_stages - 1
    if schedule == "1f1b":
        return 2 * (n_micro + n_stages - 1)
    raise ValueError(f"unknown pipeline schedule {schedule!r}")


def bubble_fraction(n_micro: int, n_stages: int, schedule: str) -> float:
    """Idle fraction of the steady-state schedule: (S-1) warmup + (S-1)
    cooldown slots over M units of work per stage — (S-1)/(M+S-1) for both
    GPipe and 1F1B (1F1B trades nothing in bubble, only in memory)."""
    schedule_slots(n_micro, n_stages, schedule)   # validates the name
    return (n_stages - 1) / (n_micro + n_stages - 1)


# ---------------------------------------------------------------------------
# GPipe: forward-only schedule, differentiable end-to-end by autodiff.
# ---------------------------------------------------------------------------
def gpipe(stage_fn: Callable, xs, n_stages: int, axis: str = "pipe"):
    """Run `stage_fn(x) -> y` as an S-stage pipeline.

    Inside shard_map: every rank along `axis` holds ITS stage's closure
    (stage_fn usually closes over that rank's gathered params). `xs` is the
    (M, ...) stack (any pytree, M-leading) of microbatch activations fed to
    stage 0 (other ranks' xs values are ignored). Returns the (M, ...)
    outputs of the LAST stage (valid on every rank only at stage S-1;
    callers psum/select as needed).

    Differentiable: activation sends use `pipe_shift`, whose backward
    reverse-permutes the cotangents, so plain `jax.grad` through this
    function yields the pipelined backward schedule (at the cost of M live
    activations per stage — use `one_f_one_b` for the S-bounded variant).
    """
    M = _leading_dim(xs)
    S = n_stages
    T = M + S - 1
    rank = pipe_rank(axis)

    state0 = _tree_index(xs, 0)
    state0 = jax.tree.map(jnp.zeros_like, state0)
    buf0 = jax.tree.map(jnp.zeros_like, xs)     # per-stage output collection

    def slot(carry, t):
        state, outs = carry
        mb_idx = t - rank              # microbatch this stage works on
        active = (mb_idx >= 0) & (mb_idx < M)
        mbc = jnp.clip(mb_idx, 0, M - 1)
        # stage 0 pulls its input from xs; others use the permuted state
        x_in = _tree_where(rank == 0, _tree_index(xs, mbc), state)
        y = stage_fn(x_in)
        y = _tree_where(active, y, state)
        # last stage collects; everyone else forwards
        outs = _tree_update(outs, y, mbc, pred=(rank == S - 1) & active)
        state_next = _tree_shift(y, axis, S)
        return (state_next, outs), None

    (_, outs), _ = lax.scan(slot, (state0, buf0), jnp.arange(T))
    return outs


# ---------------------------------------------------------------------------
# Schedule cores (model contract): stage_step(params, state, mb) -> state,
# loss_fn(params, y, mb) -> scalar.  `stage_step` does its own stage-0
# injection from `mb` (see module docstring).
# ---------------------------------------------------------------------------
def _gpipe_total_loss(stage_step: Callable, loss_fn: Callable, state0,
                      n_stages: int, axis: str):
    """The masked total-loss function shared by the GPipe grad and
    forward-only (eval) paths."""
    S = n_stages
    rank = pipe_rank(axis)

    def run(params, mbs):
        M = _leading_dim(mbs)
        T = M + S - 1
        outs0 = _tree_stack_zeros(state0, M)

        def slot(carry, t):
            state, outs = carry
            mb_idx = t - rank
            active = (mb_idx >= 0) & (mb_idx < M)
            mbc = jnp.clip(mb_idx, 0, M - 1)
            y = stage_step(params, state, _tree_index(mbs, mbc))
            y = _tree_where(active, y, state)
            outs = _tree_update(outs, y, mbc, pred=(rank == S - 1) & active)
            return (_tree_shift(y, axis, S), outs), None

        (_, outs), _ = lax.scan(slot, (state0, outs0), jnp.arange(T))
        # per-microbatch losses over the collected last-stage outputs.
        # lax.map (not vmap): the LM loss contains collectives (vocab-
        # parallel CE psums) whose scan-body form is uniform on every rank.
        losses = lax.map(lambda ym: loss_fn(params, ym[0], ym[1]),
                         (outs, mbs))
        return jnp.where(rank == S - 1, jnp.sum(losses), 0.0)

    return run


def gpipe_loss(stage_step: Callable, loss_fn: Callable, params, mbs, state0,
               n_stages: int, axis: str = "pipe"):
    """Forward-only pipelined total loss (eval path), psum'ed over `axis`."""
    run = _gpipe_total_loss(stage_step, loss_fn, state0, n_stages, axis)
    return lax.psum(run(params, mbs), axis)


def gpipe_loss_grads(stage_step: Callable, loss_fn: Callable, params, mbs,
                     state0, n_stages: int, axis: str = "pipe",
                     with_dxs: bool = False):
    """(loss, dparams, dmbs?) for the GPipe schedule via autodiff.

    `mbs` is the M-leading microbatch stream (identical on every pipe rank);
    `state0` a zero pytree of the inter-stage state.  The loss is masked to
    the last stage (SPMD grad convention: every rank seeds a backward and
    the `pipe_shift` transposes SUM them, so sum_r L_r == L) and psum'ed
    over `axis` for logging.  `dmbs` (d loss / d mbs, meaningful where the
    stream is consumed — stage 0 and the last stage) is only computed under
    ``with_dxs``; the LM path never differentiates the raw batch.
    """
    run = _gpipe_total_loss(stage_step, loss_fn, state0, n_stages, axis)
    if with_dxs:
        loss, (dparams, dmbs) = jax.value_and_grad(run, argnums=(0, 1))(
            params, mbs)
    else:
        loss, dparams = jax.value_and_grad(run)(params, mbs)
        dmbs = None
    return lax.psum(loss, axis), dparams, dmbs


def one_f_one_b_loss_grads(stage_step: Callable, loss_fn: Callable, params,
                           mbs, state0, n_stages: int, axis: str = "pipe",
                           with_dxs: bool = False):
    """(loss, dparams, dmbs?) under the 1F1B schedule — same contract as
    `gpipe_loss_grads`, but the backward is hand-interleaved with the
    forward.

    Per slot each stage does (at most) one forward and one backward, on
    opposite parities (see `one_f_one_b_schedule`). Incoming stage states
    are saved in a ring buffer of depth S and the backward re-runs the stage
    (and, on the last rank, the loss) via `jax.vjp` from the saved input
    (recompute-based, like the FSDP selective-AC re-gather), so live
    activation memory is O(S), not O(M).  Cotangents are zeroed on inactive
    slots, which makes the vjp's parameter/input gradients vanish by
    linearity — no masking of the accumulators is needed.
    """
    M = _leading_dim(mbs)
    S = n_stages
    T = schedule_slots(M, S, "1f1b")
    rank = pipe_rank(axis)
    on_last = rank == S - 1

    def fwd_and_loss(p, x, mb):
        y = stage_step(p, x, mb)
        return y, loss_fn(p, y, mb)

    carry0 = (
        state0,                                    # state from the left
        jax.tree.map(jnp.zeros_like, state0),      # cotangent from the right
        _tree_stack_zeros(state0, S),              # ring of saved inputs
        jax.tree.map(jnp.zeros_like, params),      # grad accumulator
        jax.tree.map(jnp.zeros_like, mbs) if with_dxs else (),
        jnp.zeros((), jnp.float32),                # loss accumulator
    )

    def slot(carry, t):
        fwd_state, bwd_state, ring, acc_g, dmbs, loss_acc = carry

        # forward half: microbatch mf at slot rank + 2*mf --------------------
        tf = t - rank
        mf = tf // 2
        fwd_active = (tf >= 0) & (tf % 2 == 0) & (mf < M)
        mfc = jnp.clip(mf, 0, M - 1)
        y = stage_step(params, fwd_state, _tree_index(mbs, mfc))
        y = _tree_where(fwd_active, y, fwd_state)
        # save the INCOMING state; the backward replay re-runs stage_step on
        # it (stage 0's injection re-derives its input from the microbatch)
        ring = _tree_update(ring, fwd_state, mfc % S, pred=fwd_active)

        # backward half: microbatch mb at slot 2(S-1) - rank + 2*mb + 1 ------
        tb = t - (2 * (S - 1) - rank + 1)
        mb = tb // 2
        bwd_active = (tb >= 0) & (tb % 2 == 0) & (mb < M)
        mbc = jnp.clip(mb, 0, M - 1)
        x_saved = _tree_index(ring, mbc % S)
        mb_b = _tree_index(mbs, mbc)
        if with_dxs:
            (_, l_mb), vjp = jax.vjp(fwd_and_loss, params, x_saved, mb_b)
        else:
            (_, l_mb), vjp = jax.vjp(
                lambda p, x: fwd_and_loss(p, x, mb_b), params, x_saved)
        ct_y = _tree_where(bwd_active & ~on_last, bwd_state,
                           jax.tree.map(jnp.zeros_like, bwd_state))
        ct_l = jnp.where(bwd_active & on_last, jnp.ones_like(l_mb),
                         jnp.zeros_like(l_mb))
        out_ct = vjp((ct_y, ct_l))
        dp, dx = out_ct[0], out_ct[1]
        acc_g = jax.tree.map(jnp.add, acc_g, dp)
        loss_acc = loss_acc + jnp.where(
            bwd_active & on_last, l_mb, 0.0).astype(jnp.float32)
        if with_dxs:
            dmbs = _tree_update(dmbs, out_ct[2], mbc, pred=bwd_active)

        # communicate: activations right, cotangents left --------------------
        fwd_next = jax.tree.map(lambda a: _shift_raw(a, axis, S), y)
        bwd_next = jax.tree.map(
            lambda a: lax.ppermute(a, axis, _bwd_perm(S)), dx)
        return (fwd_next, bwd_next, ring, acc_g, dmbs, loss_acc), None

    carry, _ = lax.scan(slot, carry0, jnp.arange(T))
    _, _, _, grads, dmbs, loss = carry
    return lax.psum(loss, axis), grads, (dmbs if with_dxs else None)


def pipeline_loss_grads(stage_step: Callable, loss_fn: Callable, params, mbs,
                        state0, cfg: DistConfig, schedule: str | None = None,
                        with_dxs: bool = False):
    """Dispatch the model-contract schedules: (loss, dparams, dmbs?).

    `cfg.pp_axis` names the pipe mesh axis; `cfg.pp_size` is the stage
    count; `schedule` overrides `cfg.pp_schedule`.
    """
    if cfg.pp_axis is None:
        raise ValueError(
            "pipeline_loss_grads needs cfg.pp_axis (the pipe axis)")
    M = _leading_dim(mbs)
    if cfg.pp_microbatches and M != cfg.pp_microbatches:
        raise ValueError(
            f"mbs carries {M} microbatches but cfg.pp_microbatches="
            f"{cfg.pp_microbatches}; stack the batch to match (or leave "
            "pp_microbatches=0 to accept any M)")
    schedule = schedule or cfg.pp_schedule
    args = (stage_step, loss_fn, params, mbs, state0, cfg.pp_size,
            cfg.pp_axis, with_dxs)
    if schedule == "gpipe":
        return gpipe_loss_grads(*args)
    if schedule == "1f1b":
        return one_f_one_b_loss_grads(*args)
    raise ValueError(f"unknown pipeline schedule {schedule!r}")


# ---------------------------------------------------------------------------
# Raw-stream contract (bring-your-own-stage): stage_fn(params, x) with an
# (M, ...) activation stack injected at stage 0 — adapters over the cores.
# ---------------------------------------------------------------------------
def _inject_xs(stage_fn: Callable, axis: str):
    """Lift stage_fn(params, x) to the model contract: the per-slot `mb` IS
    the stage-0 activation, where()'d in on rank 0 (the transpose routes the
    stage-0 input cotangent back onto the stream — that is `dxs`)."""
    def step(params, state, mb):
        x_in = _tree_where(lax.axis_index(axis) == 0, mb, state)
        return stage_fn(params, x_in)
    return step


def gpipe_grads(stage_fn: Callable, params, xs, loss_fn: Callable,
                n_stages: int, axis: str = "pipe"):
    """(loss, dparams, dxs) for the GPipe schedule via autodiff.

    `stage_fn(params, x) -> y` runs this rank's stage on its own `params`;
    `loss_fn(y) -> scalar` is one microbatch's contribution to the total
    loss (include any 1/M normalization there). `dparams` is each rank's own
    stage gradient; `dxs` is d(loss)/d(xs), meaningful on rank 0.
    """
    state0 = jax.tree.map(jnp.zeros_like, _tree_index(xs, 0))
    loss, dparams, dxs = gpipe_loss_grads(
        _inject_xs(stage_fn, axis), lambda p, y, mb: loss_fn(y), params,
        xs, state0, n_stages, axis, with_dxs=True)
    return loss, dparams, dxs


def one_f_one_b(stage_fn: Callable, params, xs, loss_fn: Callable,
                n_stages: int, axis: str = "pipe"):
    """(loss, dparams, dxs) under the 1F1B schedule — same contract as
    `gpipe_grads`, but with the S-bounded live-activation memory model."""
    state0 = jax.tree.map(jnp.zeros_like, _tree_index(xs, 0))
    loss, dparams, dxs = one_f_one_b_loss_grads(
        _inject_xs(stage_fn, axis), lambda p, y, mb: loss_fn(y), params,
        xs, state0, n_stages, axis, with_dxs=True)
    return loss, dparams, dxs


# ---------------------------------------------------------------------------
# SimpleFSDP composition + schedule dispatch.
# ---------------------------------------------------------------------------
def fsdp_stage_fn(stage_fn: Callable, metas_tree, cfg: DistConfig, plan=None):
    """Wrap `stage_fn(full_params, x)` so it takes ZeRO-3 storage shards and
    bucket-gathers them PER USE inside the pipelined stage (paper SS4: the
    stage submodule is SimpleFSDP-wrapped with no extra code).

    The gather is the differentiable `gather_group` custom_vjp, so each
    backward slot issues the matching reduce-scatter; under a non-'none'
    remat policy the gathered params are dropped after forward use and
    re-gathered in backward (selective-AC), keeping the per-slot footprint
    at one bucket.
    """
    from repro.core.collectives import replicate_tree
    from repro.core.remat import maybe_remat, whole_block_policy

    # a per-segment vector (core/memory's resolved form) collapses to its
    # most aggressive entry here — the BYO stage fn is one opaque block
    policy = whole_block_policy(cfg.remat)

    def wrapped(storage, x):
        def inner(storage, x):
            full = replicate_tree(storage, metas_tree, cfg, plan)
            return stage_fn(full, x)
        return maybe_remat(inner, policy)(storage, x)

    return wrapped


def pipeline_grads(stage_fn: Callable, params, xs, loss_fn: Callable,
                   cfg: DistConfig, schedule: str | None = None):
    """Dispatch the raw-stream schedules: (loss, dparams, dxs).

    `cfg.pp_axis` names the pipe mesh axis; `cfg.pp_size` is the stage
    count; `schedule` overrides `cfg.pp_schedule`.
    """
    if cfg.pp_axis is None:
        raise ValueError("pipeline_grads needs cfg.pp_axis (the pipe axis)")
    if cfg.pp_microbatches and _leading_dim(xs) != cfg.pp_microbatches:
        raise ValueError(
            f"xs carries {_leading_dim(xs)} microbatches but "
            f"cfg.pp_microbatches={cfg.pp_microbatches}; stack the batch to "
            "match (or leave pp_microbatches=0 to accept any M)")
    schedule = schedule or cfg.pp_schedule
    args = (stage_fn, params, xs, loss_fn, cfg.pp_size, cfg.pp_axis)
    if schedule == "gpipe":
        return gpipe_grads(*args)
    if schedule == "1f1b":
        return one_f_one_b(*args)
    raise ValueError(f"unknown pipeline schedule {schedule!r}")
