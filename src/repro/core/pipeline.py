"""Pipeline parallelism (GPipe and 1F1B schedules) composed with SimpleFSDP.

Paper SS4 "Pipeline Parallel": each device receives its stage's submodule and
SimpleFSDP wraps it — no extra code. Same shape here: the `pipe` mesh axis
holds one stage per rank; stage parameters are ordinary SimpleFSDP storage
(ZeRO-3 over the FSDP axes, bucket-gathered per use via `fsdp_stage_fn`), and
activations stream between stages with `pipe_shift` — a `ppermute` whose
custom backward is the reverse permute of the cotangent — inside the same
shard_map (so the full computation+communication graph — FSDP gathers AND
pipeline sends — is one jit, the paper's full-graph property).

Mesh layout convention (pp x dp x tp): axes are ordered
``('pipe', <fsdp/data axes...>, 'model')`` with **pipe outermost**.  Per-slot
pipeline traffic is one small point-to-point activation send, so it tolerates
the slowest interconnect (DCN), while the fat FSDP all-gathers and TP psums
stay on the inner ICI axes.  `DistConfig.pp_axis` names the pipe axis;
`dp_total` and `grad_sync_axes` exclude it (pipe ranks own DISTINCT stage
parameters — nothing to sync, nothing data-parallel).

Schedules and their memory models (M microbatches, S stages):

  * GPipe (`gpipe`, `gpipe_grads`): T = M + S - 1 forward slots; slot t
    computes microbatch (t - stage) on each stage.  Backward is ordinary
    autodiff through the scan, so every stage keeps **M** live microbatch
    activations (all forwards finish before any backward starts).
  * 1F1B (`one_f_one_b`): T = 2(M + S - 1) slots; stage s runs forward of
    microbatch m at slot s + 2m and backward of m at slot 2(S-1) - s + 2m + 1
    (opposite parities, so each stage does one unit of work per slot, one
    forward per backward in steady state).  Stage inputs are kept in a ring
    buffer of depth **S** and the backward recomputes the stage via
    `jax.vjp` from the saved input, so live activation storage is bounded by
    S (in fact S - s at stage s) **independent of M** — the
    PipeDream-flush/1F1B memory bound, vs GPipe's M.

Both schedules return identical losses/gradients (exact-parity tested against
a single-device dense reference in tests/dist_harness.py case `pipeline`).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.dist import DistConfig


def pipe_rank(axis: str):
    return lax.axis_index(axis)


def _fwd_perm(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def _bwd_perm(n: int):
    return [(i, (i - 1) % n) for i in range(n)]


# ---------------------------------------------------------------------------
# The differentiable pipeline send: forward permute, reverse-permute backward.
# ---------------------------------------------------------------------------
def _shift_raw(x, axis: str, n_stages: int):
    return lax.ppermute(x, axis, _fwd_perm(n_stages))


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def pipe_shift(x, axis: str, n_stages: int):
    """Send `x` to the next pipe rank (cyclically). The cotangent travels the
    opposite direction: d(stage s+1 input) arrives back at stage s."""
    return _shift_raw(x, axis, n_stages)


def _pipe_shift_fwd(x, axis, n_stages):
    return _shift_raw(x, axis, n_stages), None


def _pipe_shift_bwd(axis, n_stages, _res, ct):
    return (lax.ppermute(ct, axis, _bwd_perm(n_stages)),)


pipe_shift.defvjp(_pipe_shift_fwd, _pipe_shift_bwd)


# ---------------------------------------------------------------------------
# Schedule tables (pure host-side helpers; used by tests and docs).
# ---------------------------------------------------------------------------
def gpipe_schedule(n_micro: int, n_stages: int) -> np.ndarray:
    """(T, S) table: microbatch id stage s computes at slot t, -1 when idle.

    T = M + S - 1; stage s is active exactly on slots [s, s + M)."""
    T = n_micro + n_stages - 1
    sched = np.full((T, n_stages), -1, dtype=np.int64)
    for t in range(T):
        for s in range(n_stages):
            mb = t - s
            if 0 <= mb < n_micro:
                sched[t, s] = mb
    return sched


def one_f_one_b_schedule(n_micro: int, n_stages: int) \
        -> tuple[np.ndarray, np.ndarray]:
    """Two (T, S) tables (fwd_mb, bwd_mb): microbatch whose forward /
    backward stage s runs at slot t, -1 when idle.  T = 2(M + S - 1);
    forward of m at stage s lands on slot s + 2m, backward on
    2(S-1) - s + 2m + 1 — opposite parities, so a stage never does both in
    one slot, and at most S - s microbatches are in flight at stage s."""
    M, S = n_micro, n_stages
    T = 2 * (M + S - 1)
    fwd = np.full((T, S), -1, dtype=np.int64)
    bwd = np.full((T, S), -1, dtype=np.int64)
    for s in range(S):
        for m in range(M):
            fwd[s + 2 * m, s] = m
            bwd[2 * (S - 1) - s + 2 * m + 1, s] = m
    return fwd, bwd


def schedule_slots(n_micro: int, n_stages: int, schedule: str) -> int:
    """Total scan length of a schedule (analytic)."""
    if schedule == "gpipe":
        return n_micro + n_stages - 1
    if schedule == "1f1b":
        return 2 * (n_micro + n_stages - 1)
    raise ValueError(f"unknown pipeline schedule {schedule!r}")


# ---------------------------------------------------------------------------
# GPipe: forward-only schedule, differentiable end-to-end by autodiff.
# ---------------------------------------------------------------------------
def gpipe(stage_fn: Callable, xs, n_stages: int, axis: str = "pipe"):
    """Run `stage_fn(x) -> y` as an S-stage pipeline.

    Inside shard_map: every rank along `axis` holds ITS stage's closure
    (stage_fn usually closes over that rank's gathered params). `xs` is the
    (M, ...) stack of microbatch activations fed to stage 0 (other ranks'
    xs values are ignored). Returns the (M, ...) outputs of the LAST stage
    (valid on every rank only at stage S-1; callers psum/select as needed).

    Differentiable: activation sends use `pipe_shift`, whose backward
    reverse-permutes the cotangents, so plain `jax.grad` through this
    function yields the pipelined backward schedule (at the cost of M live
    activations per stage — use `one_f_one_b` for the S-bounded variant).
    """
    M = xs.shape[0]
    S = n_stages
    T = M + S - 1
    rank = pipe_rank(axis)

    buf0 = jnp.zeros_like(xs)          # per-stage output collection
    state0 = jnp.zeros_like(xs[0])     # activation entering this stage

    def slot(carry, t):
        state, outs = carry
        mb_idx = t - rank              # microbatch this stage works on
        active = (mb_idx >= 0) & (mb_idx < M)
        # stage 0 pulls its input from xs; others use the permuted state
        x_in = jnp.where(rank == 0,
                         xs[jnp.clip(mb_idx, 0, M - 1)], state)
        y = stage_fn(x_in)
        y = jnp.where(active, y, state)
        # last stage collects; everyone else forwards
        outs = jnp.where(
            (rank == S - 1) & active,
            lax.dynamic_update_index_in_dim(
                outs, y, jnp.clip(mb_idx, 0, M - 1), 0),
            outs)
        state_next = pipe_shift(y, axis, S)
        return (state_next, outs), None

    (_, outs), _ = lax.scan(slot, (state0, buf0), jnp.arange(T))
    return outs


def gpipe_grads(stage_fn: Callable, params, xs, loss_fn: Callable,
                n_stages: int, axis: str = "pipe"):
    """(loss, dparams, dxs) for the GPipe schedule via autodiff.

    `stage_fn(params, x) -> y` runs this rank's stage on its own `params`;
    `loss_fn(y) -> scalar` is one microbatch's contribution to the total
    loss (include any 1/M normalization there). SPMD grad convention: every
    pipe rank seeds a backward and the cross-rank `pipe_shift` transposes
    SUM them, so the loss is masked to the last stage (sum_r L_r == L);
    the returned loss is psum'ed over `axis` for logging. `dparams` is each
    rank's own stage gradient; `dxs` is d(loss)/d(xs), meaningful on rank 0.
    """
    S = n_stages

    def total_loss(params, xs):
        outs = gpipe(lambda x: stage_fn(params, x), xs, S, axis)
        per_mb = jax.vmap(loss_fn)(outs)
        on_last = pipe_rank(axis) == S - 1
        return jnp.where(on_last, jnp.sum(per_mb), 0.0)

    loss, (dparams, dxs) = jax.value_and_grad(total_loss, argnums=(0, 1))(
        params, xs)
    return lax.psum(loss, axis), dparams, dxs


# ---------------------------------------------------------------------------
# 1F1B: interleaved forward/backward, live activations bounded by S.
# ---------------------------------------------------------------------------
def one_f_one_b(stage_fn: Callable, params, xs, loss_fn: Callable,
                n_stages: int, axis: str = "pipe"):
    """(loss, dparams, dxs) under the 1F1B schedule — same contract as
    `gpipe_grads`, but the backward is hand-interleaved with the forward.

    Per slot each stage does (at most) one forward and one backward, on
    opposite parities (see `one_f_one_b_schedule`). Stage INPUTS are saved
    in a ring buffer of depth S and the backward re-runs the stage via
    `jax.vjp` from the saved input (recompute-based, like the FSDP
    selective-AC re-gather), so live activation memory is O(S), not O(M).
    Cotangents are zeroed on inactive slots, which makes the vjp's
    parameter/input gradients vanish by linearity — no masking of the
    accumulators is needed.
    """
    M = xs.shape[0]
    S = n_stages
    T = schedule_slots(M, S, "1f1b")
    rank = pipe_rank(axis)

    def fwd_and_loss(p, x):
        y = stage_fn(p, x)
        return y, loss_fn(y)

    carry0 = (
        jnp.zeros_like(xs[0]),                     # activation from the left
        jnp.zeros_like(xs[0]),                     # cotangent from the right
        jnp.zeros((S,) + xs.shape[1:], xs.dtype),  # ring of saved inputs
        jax.tree.map(jnp.zeros_like, params),      # grad accumulator
        jnp.zeros_like(xs),                        # dxs (rank 0)
        jnp.zeros((), jnp.float32),                # loss accumulator
    )

    def slot(carry, t):
        fwd_state, bwd_state, ring, acc_g, dxs, loss_acc = carry
        on_last = rank == S - 1

        # forward half: microbatch mf at slot rank + 2*mf --------------------
        tf = t - rank
        mf = tf // 2
        fwd_active = (tf >= 0) & (tf % 2 == 0) & (mf < M)
        mfc = jnp.clip(mf, 0, M - 1)
        x_in = jnp.where(rank == 0, xs[mfc], fwd_state)
        y = stage_fn(params, x_in)
        y = jnp.where(fwd_active, y, fwd_state)
        ring = jnp.where(
            fwd_active,
            lax.dynamic_update_index_in_dim(ring, x_in, mfc % S, 0),
            ring)

        # backward half: microbatch mb at slot 2(S-1) - rank + 2*mb + 1 ------
        tb = t - (2 * (S - 1) - rank + 1)
        mb = tb // 2
        bwd_active = (tb >= 0) & (tb % 2 == 0) & (mb < M)
        mbc = jnp.clip(mb, 0, M - 1)
        x_saved = lax.dynamic_index_in_dim(ring, mbc % S, 0, keepdims=False)
        (_, l_mb), vjp = jax.vjp(fwd_and_loss, params, x_saved)
        ct_y = jnp.where(bwd_active & ~on_last, bwd_state,
                         jnp.zeros_like(bwd_state))
        ct_l = jnp.where(bwd_active & on_last, jnp.ones_like(l_mb),
                         jnp.zeros_like(l_mb))
        dp, dx = vjp((ct_y, ct_l))
        acc_g = jax.tree.map(jnp.add, acc_g, dp)
        loss_acc = loss_acc + jnp.where(
            bwd_active & on_last, l_mb, 0.0).astype(jnp.float32)
        dxs = jnp.where(
            (rank == 0) & bwd_active,
            lax.dynamic_update_index_in_dim(dxs, dx, mbc, 0),
            dxs)

        # communicate: activations right, cotangents left --------------------
        fwd_next = _shift_raw(y, axis, S)
        bwd_next = lax.ppermute(dx, axis, _bwd_perm(S))
        return (fwd_next, bwd_next, ring, acc_g, dxs, loss_acc), None

    carry, _ = lax.scan(slot, carry0, jnp.arange(T))
    _, _, _, grads, dxs, loss = carry
    return lax.psum(loss, axis), grads, dxs


# ---------------------------------------------------------------------------
# SimpleFSDP composition + schedule dispatch.
# ---------------------------------------------------------------------------
def fsdp_stage_fn(stage_fn: Callable, metas_tree, cfg: DistConfig, plan=None):
    """Wrap `stage_fn(full_params, x)` so it takes ZeRO-3 storage shards and
    bucket-gathers them PER USE inside the pipelined stage (paper SS4: the
    stage submodule is SimpleFSDP-wrapped with no extra code).

    The gather is the differentiable `gather_group` custom_vjp, so each
    backward slot issues the matching reduce-scatter; under a non-'none'
    remat policy the gathered params are dropped after forward use and
    re-gathered in backward (selective-AC), keeping the per-slot footprint
    at one bucket.
    """
    from repro.core.collectives import replicate_tree
    from repro.core.remat import maybe_remat

    def wrapped(storage, x):
        def inner(storage, x):
            full = replicate_tree(storage, metas_tree, cfg, plan)
            return stage_fn(full, x)
        return maybe_remat(inner, cfg.remat)(storage, x)

    return wrapped


def pipeline_grads(stage_fn: Callable, params, xs, loss_fn: Callable,
                   cfg: DistConfig, schedule: str | None = None):
    """Dispatch to the configured schedule: (loss, dparams, dxs).

    `cfg.pp_axis` names the pipe mesh axis; `cfg.pp_size` is the stage
    count; `schedule` overrides `cfg.pp_schedule`.
    """
    if cfg.pp_axis is None:
        raise ValueError("pipeline_grads needs cfg.pp_axis (the pipe axis)")
    if cfg.pp_microbatches and xs.shape[0] != cfg.pp_microbatches:
        raise ValueError(
            f"xs carries {xs.shape[0]} microbatches but cfg.pp_microbatches="
            f"{cfg.pp_microbatches}; stack the batch to match (or leave "
            "pp_microbatches=0 to accept any M)")
    schedule = schedule or cfg.pp_schedule
    args = (stage_fn, params, xs, loss_fn, cfg.pp_size, cfg.pp_axis)
    if schedule == "gpipe":
        return gpipe_grads(*args)
    if schedule == "1f1b":
        return one_f_one_b(*args)
    raise ValueError(f"unknown pipeline schedule {schedule!r}")
