"""FSDP collectives: the JAX port of the paper's ``ReplicateComputation``.

Three layers, bottom-up:

  1. raw pack / all-gather / unpack / reduce-scatter helpers (used directly by
     the hand-scheduled backward in `core/stack.py`);
  2. `gather_group` — a ``jax.custom_vjp`` that gathers a *group* of parameter
     shards (group of one == the paper's per-parameter parametrization;
     group of many == a TorchInductor-style bucket: one flat buffer, ONE
     all-gather, copy-out slices) and whose backward is the matching single
     reduce-scatter with ``Partial(avg)`` gradient placement and
     ``reduce_dtype`` casting (paper Fig. 1(2) + SS4 mixed precision);
  3. `replicate` — per-parameter convenience wrapper.

Everything runs *inside* ``shard_map``: a "shard" here is the per-device
``(chunk,)`` / ``(1, chunk)`` slice of the flat storage layout (core/meta.py).
Gathered tensors are tagged with ``checkpoint_name(..., 'fsdp_gather')`` so the
remat policy in `core/remat.py` re-issues the all-gather in the backward pass
instead of saving full parameters — the paper's selective-AC trick (Fig. 1(1)).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name

from repro.core.dist import DistConfig, precision_codecs
from repro.core.meta import ParamMeta, flatten_local, unflatten_local
from repro.kernels.quant import ops as quant_ops

FSDP_GATHER_NAME = "fsdp_gather"


def default_precision(cfg: DistConfig) -> str:
    """The wire precision a collective runs at when its bucket has no
    per-bucket annotation: the config's own value, with 'auto' degrading to
    bf16 (under 'auto' the resolved plan is what carries fp8 buckets)."""
    return "bf16" if cfg.comm_precision == "auto" else cfg.comm_precision


def _quant_wire(buf: jax.Array, codec: str | None,
                stochastic: bool) -> jax.Array:
    """Encode+decode the flat buffer to the wire codec ahead of the
    collective.  Because dequantization commutes with all-gather (each
    rank's slice decodes independently) and with psum's direct reduce when
    every contribution is quantized exactly once, this local roundtrip is
    numerically identical to shipping the quantized payload — the cost
    model prices the actual wire bytes separately (irgraph.wire_bytes)."""
    if codec is None:
        return buf
    return quant_ops.roundtrip(buf, codec, stochastic=stochastic)


def _fsdp_axes(cfg: DistConfig):
    return cfg.fsdp_axes if len(cfg.fsdp_axes) > 1 else cfg.fsdp_axes[0]


def _squeeze_tp(shard: jax.Array, meta: ParamMeta) -> jax.Array:
    """Inside shard_map a TP param shard arrives as (1, chunk) -> (chunk,)."""
    return shard[0] if meta.tp_dim is not None else shard


# ---------------------------------------------------------------------------
# 1. Raw primitives (no autodiff attached).
# ---------------------------------------------------------------------------
def pack_shards(shards: Sequence[jax.Array]) -> jax.Array:
    """Concatenate per-param local chunks into one flat bucket buffer."""
    if len(shards) == 1:
        return shards[0].reshape(-1)
    return jnp.concatenate([s.reshape(-1) for s in shards])


def gather_flat(buf: jax.Array, cfg: DistConfig) -> jax.Array:
    """One all-gather of the bucket buffer -> (fsdp_size, bucket_len)."""
    if cfg.fsdp_size == 1:
        return buf[None]
    return lax.all_gather(buf, _fsdp_axes(cfg), tiled=False)


def unpack_gathered(g: jax.Array, metas: Sequence[ParamMeta],
                    cfg: DistConfig) -> list[jax.Array]:
    """Copy-out: slice the (fsdp, bucket_len) buffer back into params."""
    outs, off = [], 0
    for m in metas:
        chunk = m.chunk_len(cfg)
        seg = lax.slice_in_dim(g, off, off + chunk, axis=1)
        outs.append(unflatten_local(seg.reshape(-1), m, cfg))
        off += chunk
    return outs


def pack_grads(grads: Sequence[jax.Array], metas: Sequence[ParamMeta],
               cfg: DistConfig) -> jax.Array:
    """Copy-in: full TP-local grads -> (fsdp, bucket_len) RS layout."""
    cols = []
    for g, m in zip(grads, metas):
        flat = flatten_local(g, m, cfg)
        cols.append(flat.reshape(cfg.fsdp_size, m.chunk_len(cfg)))
    return cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)


def reduce_scatter_flat(ct: jax.Array, cfg: DistConfig) -> jax.Array:
    """One reduce-scatter of the grad bucket -> local (bucket_len,) chunk."""
    if cfg.fsdp_size == 1:
        return ct[0]
    return lax.psum_scatter(ct, _fsdp_axes(cfg), scatter_dimension=0,
                            tiled=False)


def split_grad_chunks(flat: jax.Array, metas: Sequence[ParamMeta],
                      cfg: DistConfig, shard_shapes: Sequence[tuple]) \
        -> list[jax.Array]:
    outs, off = [], 0
    for m, ss in zip(metas, shard_shapes):
        chunk = m.chunk_len(cfg)
        outs.append(
            lax.slice_in_dim(flat, off, off + chunk, axis=0).reshape(ss))
        off += chunk
    return outs


# ---------------------------------------------------------------------------
# Forward / backward halves shared by custom_vjp and core/stack.py.
# ---------------------------------------------------------------------------
def _vma_classes(metas: Sequence[ParamMeta]) -> list[list[int]]:
    """Split a bucket into vma classes. TP-sharded storage is varying over
    the TP mesh axis while TP-replicated storage is invariant there; packing
    them into ONE buffer would erase that distinction (shard_map's vma type
    system has no sound downcast), so each class gets its own flat buffer.
    A bucket therefore lowers to at most two collectives."""
    cls: dict[bool, list[int]] = {}
    for i, m in enumerate(metas):
        cls.setdefault(m.tp_dim is not None, []).append(i)
    return list(cls.values())


def gather_group_fwd_raw(shards: Sequence[jax.Array],
                         metas: Sequence[ParamMeta],
                         cfg: DistConfig,
                         precision: str | None = None) -> list[jax.Array]:
    """Pack -> one AG per vma class -> unpack; returns compute tensors.

    `precision` is the bucket's resolved wire precision (None = the config
    default): a quantized AG encodes the packed buffer to per-chunk-scaled
    fp8 (deterministic round-to-nearest — every rank must decode identical
    params) before the gather."""
    ag_codec, _ = precision_codecs(precision or default_precision(cfg))
    flats = [_squeeze_tp(s, m) for s, m in zip(shards, metas)]
    if cfg.gather_in_param_dtype:
        flats = [f.astype(cfg.param_dtype) for f in flats]
    outs: list = [None] * len(flats)
    for idxs in _vma_classes(metas):
        buf = pack_shards([flats[i] for i in idxs])
        buf = _quant_wire(buf, ag_codec, stochastic=False)
        g = checkpoint_name(gather_flat(buf, cfg), FSDP_GATHER_NAME)
        sub = unpack_gathered(g, [metas[i] for i in idxs], cfg)
        for i, o in zip(idxs, sub):
            outs[i] = o
    if not cfg.gather_in_param_dtype:
        outs = [o.astype(cfg.param_dtype) for o in outs]
    return outs


def rs_dtype(cfg: DistConfig):
    return jnp.bfloat16 if cfg.grad_compression else cfg.reduce_dtype


def pack_grad_bucket(grads_full: Sequence[jax.Array],
                     metas: Sequence[ParamMeta],
                     cfg: DistConfig) -> tuple[jax.Array, ...]:
    """Copy-in: full TP-local grads -> per-vma-class (fsdp, len) buffers."""
    gs = [g.astype(rs_dtype(cfg)) for g in grads_full]
    return tuple(
        pack_grads([gs[i] for i in idxs], [metas[i] for i in idxs], cfg)
        for idxs in _vma_classes(metas)
    )


def finalize_grad_bucket(cts: tuple, metas: Sequence[ParamMeta],
                         cfg: DistConfig,
                         shard_shapes: Sequence[tuple],
                         precision: str | None = None) -> list[jax.Array]:
    """One RS per vma class (mean over DP) -> per-param local grad chunks.

    A quantized RS ('fp8'/'fp8_ef') encodes each rank's contribution to
    per-chunk-scaled fp8 with STOCHASTIC rounding before the psum-scatter —
    one quantization per contribution, direct-reduced (the qgZ shape), and
    unbiased, which is the condition Markov et al.'s convergence analysis
    needs; 'fp8_ef' additionally compensates the reduced shard's wire
    format with the persistent error-feedback accumulator in the optimizer
    (optim/adamw.py — gradient state cannot thread through this vjp).

    Cross-pod (HSDP) and TP-replication gradient sums are NOT issued here:
    under shard_map's varying-manual-axes (vma) tracking, the transpose of
    the automatic `pvary` at each replicated->varying boundary inserts
    exactly the required psum over 'pod'/'model', so cotangents arrive at
    this reduce-scatter already summed over every axis the parameter is
    replicated on. (Verified by tests/dist_harness.py against dense refs.)
    """
    _, rs_codec = precision_codecs(precision or default_precision(cfg))
    outs: list = [None] * len(metas)
    for ct, idxs in zip(cts, _vma_classes(metas)):
        ct = _quant_wire(ct, rs_codec, stochastic=True)
        local = reduce_scatter_flat(ct, cfg)
        # Partial(avg): mean over the full DP domain. Combined with a
        # per-device local-mean loss this is the global-batch mean gradient.
        local = local.astype(cfg.reduce_dtype) / cfg.dp_total
        sub = split_grad_chunks(local, [metas[i] for i in idxs], cfg,
                                [shard_shapes[i] for i in idxs])
        for i, o in zip(idxs, sub):
            outs[i] = o
    return [o.astype(m.dtype) for o, m in zip(outs, metas)]


def reduce_group_bwd_raw(grads_full: Sequence[jax.Array],
                         metas: Sequence[ParamMeta],
                         cfg: DistConfig,
                         shard_shapes: Sequence[tuple],
                         precision: str | None = None) -> list[jax.Array]:
    """Pack grads -> one RS (reduce_dtype, mean) -> per-param local chunks."""
    ct = pack_grad_bucket(grads_full, metas, cfg)
    return finalize_grad_bucket(ct, metas, cfg, shard_shapes, precision)


# ---------------------------------------------------------------------------
# 2. The differentiable bucket gather (paper's parametrization).
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def gather_group(shards: tuple, metas: tuple, cfg: DistConfig,
                 precision: str | None = None):
    return gather_group_fwd_raw(shards, metas, cfg, precision)


def _gg_fwd(shards, metas, cfg, precision):
    outs = gather_group_fwd_raw(shards, metas, cfg, precision)
    return outs, tuple(s.shape for s in shards)


def _gg_bwd(metas, cfg, precision, shard_shapes, cts):
    # shard_shapes already carry the (1, chunk) tp-index dim where present
    grads = reduce_group_bwd_raw(cts, metas, cfg, shard_shapes, precision)
    return (tuple(grads),)


gather_group.defvjp(_gg_fwd, _gg_bwd)


# ---------------------------------------------------------------------------
# 2b. Pipe-axis param reconstruction for pipe-SHARDED single-owner groups
# (models/staging.py): a pre/post group's storage is split (S, chunk/S)
# over the pipe axis instead of zero-filled on non-owner slots, and each
# step re-assembles this device's ordinary FSDP chunk with one all-gather
# over the pipe axis.  The backward is the exact transpose: a tiled
# psum-scatter (no mean — non-consuming ranks contribute exact-zero
# cotangents by schedule masking, so the sum IS the owner's gradient, and
# each pipe rank keeps d(its slice)).
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def pipe_param_gather(x: jax.Array, axis: str, n_stages: int) -> jax.Array:
    """(..., chunk/S) pipe-local slice -> (..., chunk) full FSDP chunk."""
    return lax.all_gather(x, axis, axis=x.ndim - 1, tiled=True)


def _ppg_fwd(x, axis, n_stages):
    return lax.all_gather(x, axis, axis=x.ndim - 1, tiled=True), None


def _ppg_bwd(axis, n_stages, _res, ct):
    return (lax.psum_scatter(ct, axis, scatter_dimension=ct.ndim - 1,
                             tiled=True),)


pipe_param_gather.defvjp(_ppg_fwd, _ppg_bwd)


# ---------------------------------------------------------------------------
# 3. Per-parameter convenience (paper Fig. 1(2), group of one).
# ---------------------------------------------------------------------------
def replicate(shard: jax.Array, meta: ParamMeta, cfg: DistConfig) -> jax.Array:
    """shard -> full TP-local tensor; d(full) -> reduce-scattered d(shard)."""
    (out,) = gather_group((shard,), (meta,), cfg)
    return out


def replicate_tree(shards_tree, metas_tree, cfg: DistConfig, plan=None):
    """Gather a whole pytree of shards, bucketed per `plan` (BucketPlan) or
    per-parameter when plan is None."""
    from repro.core.bucketing import BucketPlan  # local import, no cycle

    leaves, treedef = jax.tree_util.tree_flatten(shards_tree)
    metas = treedef.flatten_up_to(metas_tree)
    if plan is None:
        groups = [[i] for i in range(len(leaves))]
        precisions = [default_precision(cfg)] * len(groups)
    else:
        assert isinstance(plan, BucketPlan)
        groups = plan.index_groups(metas_tree)
        precisions = plan.group_precisions(metas_tree, cfg)
    out: list = [None] * len(leaves)
    for grp, prec in zip(groups, precisions):
        gathered = gather_group(tuple(leaves[i] for i in grp),
                                tuple(metas[i] for i in grp), cfg, prec)
        for i, g in zip(grp, gathered):
            out[i] = g
    return jax.tree_util.tree_unflatten(treedef, out)
