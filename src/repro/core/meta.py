"""Parameter metadata and the ZeRO-3 storage layout.

The paper stores each parameter as a DTensor ``Shard(0)`` (optionally 2-D
sharded with TP). We use the flat-shard equivalent, which is divisibility-proof
and TPU-layout friendly:

  * Every parameter is flattened (per TP rank), padded to a multiple of
    ``fsdp_size * LANE`` and sharded 1-D over the FSDP mesh axes.
  * TP-sharded parameters carry an explicit leading ``tp`` index axis in
    storage: shape ``(tp, padded_flat)`` with spec ``P(tp_axis, fsdp_axes)``.
    Row ``t`` is the flattened TP-local block of rank ``t``.
  * Layer-stacked parameters (for ``lax.scan`` over blocks) get a leading
    ``L`` axis on top of that.

`ParamMeta` records the logical <-> storage mapping; `to_storage` /
`from_storage` are exact inverses (property-tested).  Inside ``shard_map`` a
device holds the ``(1, chunk)`` / ``(chunk,)`` local shard; the gather path in
`core/collectives.py` reconstructs the TP-local compute tensor.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.dist import DistConfig

LANE = 128  # pad flat shards so per-device chunks are lane-aligned


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    name: str
    global_shape: tuple[int, ...]     # logical full shape (after head padding)
    tp_dim: int | None = None         # which logical dim is TP-sharded
    dtype: Any = jnp.float32          # storage (master) dtype

    # ------------------------------------------------------------- derived --
    def local_shape(self, cfg: DistConfig) -> tuple[int, ...]:
        """TP-local compute shape (what the model sees after FSDP gather)."""
        if self.tp_dim is None:
            return self.global_shape
        tp = cfg.tp_size
        s = list(self.global_shape)
        if s[self.tp_dim] % tp != 0:
            raise ValueError(
                f"{self.name}: dim {self.tp_dim} ({s[self.tp_dim]}) "
                f"not divisible by tp={tp}; pad the config."
            )
        s[self.tp_dim] //= tp
        return tuple(s)

    def numel_local(self, cfg: DistConfig) -> int:
        return math.prod(self.local_shape(cfg))

    def padded_len(self, cfg: DistConfig) -> int:
        quantum = cfg.fsdp_size * LANE
        return ((self.numel_local(cfg) + quantum - 1) // quantum) * quantum

    def chunk_len(self, cfg: DistConfig) -> int:
        return self.padded_len(cfg) // cfg.fsdp_size

    def storage_shape(self, cfg: DistConfig) -> tuple[int, ...]:
        if self.tp_dim is None:
            return (self.padded_len(cfg),)
        return (cfg.tp_size, self.padded_len(cfg))

    def storage_spec(self, cfg: DistConfig) -> P:
        fsdp = cfg.fsdp_axes if len(cfg.fsdp_axes) > 1 else cfg.fsdp_axes[0]
        if self.tp_dim is None:
            return P(fsdp)
        return P(cfg.tp_axis, fsdp)

    def stacked_storage_shape(self, cfg: DistConfig, n: int) -> tuple[int, ...]:
        return (n, *self.storage_shape(cfg))

    def stacked_storage_spec(self, cfg: DistConfig) -> P:
        return P(None, *self.storage_spec(cfg))

    def pipe_stacked_storage_spec(self, cfg: DistConfig) -> P:
        """Spec for an (S, storage...) stage stack: the leading stage dim is
        sharded over the pipe axis (each pipe rank holds ITS stage's ZeRO-3
        shard), inner dims keep the plain storage layout."""
        if cfg.pp_axis is None:
            raise ValueError("pipe_stacked_storage_spec needs cfg.pp_axis")
        return P(cfg.pp_axis, *self.storage_spec(cfg))

    def shard_shape(self, cfg: DistConfig) -> tuple[int, ...]:
        """Per-device shape inside shard_map."""
        if self.tp_dim is None:
            return (self.chunk_len(cfg),)
        return (1, self.chunk_len(cfg))


# --------------------------------------------------------------------------
# Layout transforms (host-side; exact inverses).
# --------------------------------------------------------------------------
def to_storage(full: jax.Array | np.ndarray, meta: ParamMeta,
               cfg: DistConfig) -> jax.Array:
    """Logical full param -> storage layout (flat/padded/TP-stacked)."""
    full = jnp.asarray(full, dtype=meta.dtype)
    if full.shape != meta.global_shape:
        raise ValueError(
            f"{meta.name}: expected {meta.global_shape}, got {full.shape}"
        )
    pad = meta.padded_len(cfg)
    if meta.tp_dim is None:
        flat = full.reshape(-1)
        return jnp.pad(flat, (0, pad - flat.size))
    tp = cfg.tp_size
    # split the tp_dim into (tp, local) and move tp to the front
    moved = jnp.moveaxis(full, meta.tp_dim, 0)
    blk = moved.reshape(tp, moved.shape[0] // tp, *moved.shape[1:])
    blk = jnp.moveaxis(blk, 1, meta.tp_dim + 1)  # restore dim order per block
    flat = blk.reshape(tp, -1)
    return jnp.pad(flat, ((0, 0), (0, pad - flat.shape[1])))


def from_storage(storage: jax.Array | np.ndarray, meta: ParamMeta,
                 cfg: DistConfig) -> jax.Array:
    """Inverse of `to_storage` (used by checkpointing export and tests)."""
    storage = jnp.asarray(storage)
    local = meta.local_shape(cfg)
    if meta.tp_dim is None:
        return storage[: meta.numel_local(cfg)].reshape(local)
    tp = cfg.tp_size
    blk = storage[:, : meta.numel_local(cfg)].reshape(tp, *local)
    blk = jnp.moveaxis(blk, meta.tp_dim + 1, 1)   # (tp, loc_tp, ...)
    merged = blk.reshape(tp * blk.shape[1], *blk.shape[2:])
    return jnp.moveaxis(merged, 0, meta.tp_dim)


def unflatten_local(flat: jax.Array, meta: ParamMeta,
                    cfg: DistConfig) -> jax.Array:
    """Gathered padded flat (padded_len,) -> TP-local compute tensor."""
    return flat[: meta.numel_local(cfg)].reshape(meta.local_shape(cfg))


def flatten_local(x: jax.Array, meta: ParamMeta, cfg: DistConfig) -> jax.Array:
    """TP-local compute tensor -> padded flat (padded_len,)."""
    flat = x.reshape(-1)
    return jnp.pad(flat, (0, meta.padded_len(cfg) - flat.size))


# --------------------------------------------------------------------------
# Pytree helpers: params and metas travel as parallel pytrees keyed by path.
# --------------------------------------------------------------------------
def named_leaves(tree) -> list[tuple[str, Any]]:
    from repro.core.compat import keystr

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append((keystr(path, simple=True, separator="/"), leaf))
    return out


def tree_paths(tree) -> list[str]:
    return [k for k, _ in named_leaves(tree)]


def abstract_storage(metas, cfg: DistConfig, n_layers: int | None = None):
    """ShapeDtypeStructs of the storage layout (dry-run / meta-init)."""
    def one(m: ParamMeta):
        shape = (m.stacked_storage_shape(cfg, n_layers)
                 if n_layers is not None else m.storage_shape(cfg))
        return jax.ShapeDtypeStruct(shape, m.dtype)
    return jax.tree.map(one, metas,
                        is_leaf=lambda x: isinstance(x, ParamMeta))


def storage_specs(metas, cfg: DistConfig, stacked: bool = False):
    def one(m: ParamMeta):
        return m.stacked_storage_spec(cfg) if stacked else m.storage_spec(cfg)
    return jax.tree.map(one, metas,
                        is_leaf=lambda x: isinstance(x, ParamMeta))


def pipe_shardable(metas, cfg: DistConfig) -> bool:
    """True iff every ParamMeta leaf's per-device FSDP chunk splits evenly
    over the pipe axis — the condition for storing a single-owner (pre/post)
    param group as (S, chunk/S) pipe-sharded slices instead of zero-filling
    non-owner stage slots (models/staging.py).  All-or-nothing per group so
    the staged layout stays uniform.  chunk_len is a multiple of LANE=128,
    so any power-of-two pipe degree qualifies in practice."""
    if cfg.pp_axis is None or cfg.pp_size <= 1:
        return False
    ms = jax.tree.leaves(metas, is_leaf=lambda x: isinstance(x, ParamMeta))
    return bool(ms) and all(
        m.chunk_len(cfg) % cfg.pp_size == 0 for m in ms)


def param_bytes(metas, cfg: DistConfig, n_layers: int = 1) -> int:
    total = 0
    for _, m in named_leaves(metas):
        total += n_layers * m.padded_len(cfg) * (
            cfg.tp_size if m.tp_dim is not None else 1
        ) * jnp.dtype(m.dtype).itemsize
    return total
