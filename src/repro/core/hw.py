"""Hardware model for the TARGET platform (TPU v5e pod) and its interconnect.

The container runs on CPU; every performance number derived here is an
*analytic* roofline term computed from compiled HLO (see launch/dryrun.py and
benchmarks/roofline.py), not a wall-clock measurement.  The constants below are
the single source of truth for:

  * the roofline denominators (peak FLOP/s, HBM bandwidth, ICI/DCN bandwidth),
  * the alpha+beta communication model used by auto-wrapping (paper Alg. 1),
  * the analytic compute-time estimates used in place of the paper's
    CUDA-event profiling (DESIGN.md SS2 [changed]).
"""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# TPU v5e chip (per-chip numbers), per the assignment's hardware constants.
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s per chip, bf16 on the MXU
HBM_BANDWIDTH = 819e9             # bytes/s per chip
HBM_BYTES = 16 * 1024**3          # 16 GiB HBM per v5e chip
VMEM_BYTES = 128 * 1024**2        # ~128 MiB vector memory (tiling budget)

# Inter-chip interconnect (ICI): ~50 GB/s per link per direction; a v5e chip
# has 4 ICI links in a 2D torus (16x16 pod).
ICI_BW_PER_LINK = 50e9            # bytes/s/link
ICI_LINKS_PER_CHIP = 4
# Base latency for issuing one collective over ICI (the paper's alpha).
ICI_ALPHA_S = 1e-6

# Data-center network between pods (DCN). Much lower bandwidth, much higher
# base latency -- this is the paper's "inter-node" regime where bucketing wins
# (Table 5, 8-node column).
DCN_BW_PER_HOST = 6.25e9          # bytes/s effective per host NIC share
DCN_ALPHA_S = 25e-6

# Host DMA (device <-> host DRAM over PCIe): the offload channel used by the
# memory planner's optimizer-state / residual host-offload options
# (core/memory). Effective per-direction bandwidth; double-buffered copies
# hide behind compute when the per-layer transfer fits under the layer time.
HOST_DMA_BW = 32e9                # bytes/s effective per chip
HOST_DMA_ALPHA_S = 10e-6

# MXU/VPU native tiling (used by Pallas BlockSpec choices and padding rules).
MXU_TILE = 128                    # systolic array dim; matmul dims want %128
SUBLANE = 8                       # f32 sublane tiling (8, 128) vregs


@dataclasses.dataclass(frozen=True)
class AxisBandwidth:
    """Effective collective bandwidth of one mesh axis for one chip."""

    bytes_per_s: float
    alpha_s: float


# Measured per-axis collective bandwidth, installed by the step profiler
# (core/obs/profile.py) under the calibration context
# (core/obs/calibrate.calibration).  Empty = the analytic constants above
# stand.  Same install/restore idiom as irgraph's measured quant rate: the
# setter returns the previous value so callers can save/restore.
_MEASURED_AXIS_BW: dict[str, AxisBandwidth] = {}


def set_measured_axis_bandwidth(axis_name: str,
                                bw: AxisBandwidth | None
                                ) -> AxisBandwidth | None:
    """Install (or clear, with None) a measured bandwidth for one mesh
    axis; returns the previous override so callers can restore it."""
    prev = _MEASURED_AXIS_BW.get(axis_name)
    if bw is None:
        _MEASURED_AXIS_BW.pop(axis_name, None)
    else:
        _MEASURED_AXIS_BW[axis_name] = bw
    return prev


def axis_bandwidth(axis_name: str) -> AxisBandwidth:
    """Bandwidth model per mesh axis.

    A measured override (installed by the profiler's calibration context)
    wins; otherwise 'pod' is the cross-pod DCN axis and everything else
    rides the ICI torus. A ring collective on one torus dimension uses 2 of
    the 4 links (bidirectional ring), so an axis gets 2 links' worth of
    bandwidth.
    """
    meas = _MEASURED_AXIS_BW.get(axis_name)
    if meas is not None:
        return meas
    if axis_name == "pod":
        return AxisBandwidth(bytes_per_s=DCN_BW_PER_HOST, alpha_s=DCN_ALPHA_S)
    return AxisBandwidth(
        bytes_per_s=2 * ICI_BW_PER_LINK, alpha_s=ICI_ALPHA_S
    )


def ring_hop_time_s(nbytes: float, axis_name: str = "data") -> float:
    """One neighbour hop of a ring exchange (``lax.ppermute``) on one axis.

    The SINGLE source for ring/point-to-point hop costs: pipeline activation
    sends, the context-parallel ring attention's KV exchange
    (core/context.py), and the roofline's collective-permute terms all price
    a hop as alpha + payload/bw of the axis it rides — same `axis_bandwidth`
    model the bucketed all-gather/reduce-scatter planners use, so the two
    schedules can never be costed from drifting constants.
    """
    bw = axis_bandwidth(axis_name)
    return bw.alpha_s + nbytes / bw.bytes_per_s


def collective_time_s(nbytes: float, axis_sizes: dict[str, int],
                      axes: tuple[str, ...]) -> float:
    """alpha + beta*n model for an all-gather/reduce-scatter over `axes`.

    `nbytes` is the *full* (gathered) payload. A ring all-gather over an axis
    of size k moves (k-1)/k of the payload through each chip's axis links.
    Multi-axis collectives are modelled as sequential per-axis phases (how XLA
    lowers them on a torus).
    """
    t = 0.0
    for ax in axes:
        k = axis_sizes[ax]
        if k <= 1:
            continue
        bw = axis_bandwidth(ax)
        t += bw.alpha_s + (nbytes * (k - 1) / k) / bw.bytes_per_s
    return t


def compute_time_s(flops: float, bytes_accessed: float) -> float:
    """Analytic kernel-time estimate: max of compute and memory roofline."""
    return max(flops / PEAK_FLOPS_BF16, bytes_accessed / HBM_BANDWIDTH)
