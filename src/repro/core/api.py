"""The single user-facing entry point — the analogue of the paper's

    model = simple_fsdp(model)
    model = torch.compile(model, fullgraph=True)

Two objects carry the whole story:

  * **`ParallelPlan`** — a frozen, fully RESOLVED description of how one
    model runs on one mesh: the stacked param groups, the bucket plan per
    group (the paper's wrapping decision, manual or auto), the remat
    policy, and — when ``dcfg.pp_axis`` is set — the pipeline stage
    partition (models/common.StageSpec) plus the microbatch count.  Built
    once by `plan_parallel(model, dcfg, shape)` and validated there
    (stage partitions cover every top-level param group exactly once,
    layer slices divide evenly); every downstream consumer — `Trainer`,
    the dry-run, benches, tests — reads the same plan instead of
    re-deriving flags.
  * **`parallelize(model, dcfg, shape)`** — returns a `Parallelized`
    bundle: the plan, the mesh, the (stage-aware) storage specs, storage
    init, and the shard_map-wrapped loss/train steps.  Under
    ``dcfg.pp_axis`` the steps route through `core/pipeline`'s GPipe/1F1B
    schedules with per-stage SimpleFSDP storage; otherwise they are the
    familiar whole-model SimpleFSDP steps.  pp x dp x tp is a config flip,
    not a different trainer.

Any model implementing the model contract (``metas`` / ``init_full`` /
``loss_local`` / ``input_specs`` / ``stacked_keys`` + the stage-partition
methods, see models/common.py) goes through this path — all registered
architectures do.

The original bring-your-own-module wrapper `simple_fsdp(apply_fn, params,
cfg)` is kept as a thin DEPRECATED shim for raw apply functions that have no
model contract (examples/quickstart.py shows both). `shard_params` /
`unshard_params` are the one canonical full<->storage layout transform
(models/runtime.tree_to_storage delegates here).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core import collectives as coll
from repro.core.bucketing import BucketPlan, plan_for
from repro.core.dist import DistConfig, make_mesh
from repro.core.meta import ParamMeta, from_storage, to_storage
from repro.core.remat import AUTO_PREFIX, parse_remat

# ---------------------------------------------------------------------------
# The canonical full <-> storage layout transforms (stacked-aware).
# ---------------------------------------------------------------------------


def _is_meta(x):
    return isinstance(x, ParamMeta)


def shard_params(params_full, metas, cfg: DistConfig):
    """Full shaped params -> flat/padded/TP-indexed ZeRO-3 storage layout.

    Leaves with one extra leading dim relative to their meta are treated as
    layer-stacked (the `lax.scan` stacks). Host-side layout transform;
    placement onto the mesh happens via jax.device_put with
    `meta.storage_spec` — see train/trainer.py.  The ONE implementation:
    models/runtime.tree_to_storage is an alias.
    """
    def one(p, m):
        if p.ndim == len(m.global_shape) + 1:
            return jnp.stack(
                [to_storage(p[i], m, cfg) for i in range(p.shape[0])])
        return to_storage(p, m, cfg)
    return jax.tree.map(one, params_full, metas,
                        is_leaf=lambda x: _is_meta(x) or hasattr(x, "shape"))


def unshard_params(storage, metas, cfg: DistConfig):
    """Inverse of `shard_params` (stacked-aware)."""
    def one(p, m):
        if p.ndim == len(m.storage_shape(cfg)) + 1:
            return jnp.stack(
                [from_storage(p[i], m, cfg) for i in range(p.shape[0])])
        return from_storage(p, m, cfg)
    return jax.tree.map(one, storage, metas, is_leaf=_is_meta)


# ---------------------------------------------------------------------------
# ParallelPlan: one resolved, frozen description of the parallelism.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """Resolved mesh/bucketing/remat/pipeline decisions for (model, dcfg).

    `bucket_plans[k]` is the BucketPlan of stacked group `k` (the paper's
    wrapping decision — what gathers together); `stage` is the pipeline
    partition (None when ``dcfg.pp_axis`` is unset) and `microbatches` the
    pipeline M (0 without pipelining). Frozen: later passes consume one
    schedule instead of scattered flags.
    """

    dcfg: DistConfig
    stacked_keys: Mapping[str, int]
    bucket_plans: Mapping[str, BucketPlan]
    remat: str
    stage: Any = None                   # models/common.StageSpec | None
    microbatches: int = 0
    memory: Any = None                  # core/memory.MemoryPlan | None
    # Resolved pipeline schedule: dcfg.pp_schedule="auto" is scored here
    # (bubble_fraction argmin, peak in-flight state as the tie-break) and
    # the winner recorded; pp_virtual is the resolved V for 'interleaved'
    # (1 for every other schedule).  "" when not pipelined.
    pp_schedule: str = ""
    pp_virtual: int = 1

    @property
    def pipelined(self) -> bool:
        return self.stage is not None

    def bucket_plan(self, key: str) -> BucketPlan | None:
        return self.bucket_plans.get(key)

    @property
    def exec_dcfg(self) -> DistConfig:
        """The DistConfig the steps actually trace with: `dcfg` with the
        memory plan's decisions written back — the resolved per-segment
        policy vector replacing ``remat="auto:<GB>"`` and, when the planner
        retightened buckets against the budget, the chosen BucketPlan as
        the explicit bucket_mode.  This is what keeps the pp=1 path (which
        re-resolves plans inside `apply_stack`) executing exactly the plan
        this object reports."""
        d = self.dcfg
        kw = {}
        if self.pipelined and self.pp_schedule != d.pp_schedule:
            kw["pp_schedule"] = self.pp_schedule
        if self.pipelined and self.pp_virtual != d.pp_virtual:
            kw["pp_virtual"] = self.pp_virtual
        if self.memory is not None:
            if self.memory.policy_spec != d.remat:
                kw["remat"] = self.memory.policy_spec
            if self.memory.bucket_plan is not None:
                kw["bucket_mode"] = self.memory.bucket_plan
        return d.with_(**kw) if kw else d

    def describe(self) -> str:
        d = self.dcfg
        mesh = "x".join(f"{a}={s}" for a, s in
                        zip(d.mesh_axes, d.mesh_shape))
        sched = self.pp_schedule + (
            f"xV{self.pp_virtual}" if self.pp_virtual > 1 else "")
        pp = (f" pp={self.stage.n_stages}({sched},M="
              f"{self.microbatches})" if self.pipelined else "")
        cp = f" cp={d.cp_size}(ring)" if d.cp_size > 1 else ""
        buckets = ",".join(f"{k}:{p.n_buckets}"
                           for k, p in self.bucket_plans.items())
        mem = f" mem[{self.memory.describe()}]" if self.memory is not None \
            else ""
        quant = ""
        if d.comm_precision != "bf16":
            per_bucket = {q for p in self.bucket_plans.values()
                          for q in (p.precisions or ())}
            quant = f" comm={d.comm_precision}"
            if per_bucket:
                quant += "(" + ",".join(sorted(per_bucket)) + ")"
        return (f"mesh[{mesh}] fsdp={d.fsdp_axes} tp={d.tp_size}"
                f"{cp}{pp} remat={self.remat} buckets[{buckets}]{quant}{mem}")


def _auto_virtual(dcfg: DistConfig, stage) -> int:
    """The V the planner proposes for 'interleaved': dcfg.pp_virtual when
    the user pinned one, else the smallest divisor >= 2 of layers_per_stage
    (smallest V already captures most of the ~1/V bubble shrink while
    holding the least extra in-flight state).  0 when no valid V exists."""
    if dcfg.pp_virtual >= 2:
        return dcfg.pp_virtual
    lps = stage.layers_per_stage
    for v in range(2, lps + 1):
        if lps % v == 0:
            return v
    return 0


def _resolve_pp_schedule(dcfg: DistConfig, stage, microbatches: int):
    """Resolve dcfg.pp_schedule to a concrete (schedule, V, stage).

    'auto' scores every schedule valid for this stage partition by modeled
    bubble fraction (core/pipeline.bubble_fraction — computed from the real
    slot tables for interleaved/zb) with peak in-flight saved state as the
    tie-break, and picks the argmin.  An explicit schedule is honored but
    validated (interleaved needs a chunkable, even partition and V >= 2).
    Returns the stage with `virtual` stamped in so the staged storage
    layout, the memory simulator and the engines all see the same V.
    """
    from repro.core.pipeline import (PIPE_SCHEDULES, bubble_fraction,
                                     schedule_peak_state)

    def interleave_ok(v: int) -> str | None:
        if not stage.chunkable:
            return ("this model's stage program is not chunkable "
                    "(StageSpec.chunkable=False — e.g. zamba2's superblock "
                    "cadence)")
        if stage.stage_layers is not None:
            return "uneven stage partitions cannot be virtual-chunked"
        if v < 2:
            return (f"layers_per_stage={stage.layers_per_stage} has no "
                    "divisor >= 2 to chunk into virtual stages")
        if stage.layers_per_stage % v:
            return (f"pp_virtual={v} does not divide layers_per_stage="
                    f"{stage.layers_per_stage}")
        return None

    req = dcfg.pp_schedule
    if req == "auto":
        v = _auto_virtual(dcfg, stage)
        # candidate order is the tie-break of last resort: prefer the
        # bounded-memory baseline when scores come out equal
        cands = [("1f1b", 1), ("zb", 1), ("gpipe", 1)]
        if interleave_ok(v) is None:
            cands.append(("interleaved", v))

        def score(c):
            s, cv = c
            bf = bubble_fraction(microbatches, stage.n_stages, s, cv)
            peak = max(schedule_peak_state(
                microbatches, stage.n_stages, s, cv))
            return (round(bf, 6), peak)

        sched, virtual = min(cands, key=score)
    elif req == "interleaved":
        virtual = _auto_virtual(dcfg, stage)
        why = interleave_ok(virtual)
        if why is not None:
            raise ValueError(f"pp_schedule='interleaved': {why}")
        sched = req
    elif req in PIPE_SCHEDULES:
        sched, virtual = req, 1
    else:
        raise ValueError(
            f"unknown pp_schedule {req!r}; valid: "
            f"{PIPE_SCHEDULES + ('auto',)}")
    if virtual != stage.virtual:
        stage = dataclasses.replace(stage, virtual=virtual)
    return sched, virtual, stage


def plan_parallel(model, dcfg: DistConfig, shape=None) -> ParallelPlan:
    """Build + validate the frozen `ParallelPlan` for one (model, dcfg).

    `shape` (models/common.ShapeConfig) feeds the auto bucket planners'
    workload model (per-device batch); without it the planners fall back to
    their distribution prior.  Raises with a pointed message when the
    requested pipeline degree cannot partition this model.
    """
    from repro.models.runtime import stacked_keys as model_stacked_keys

    # malformed remat strings ('auto:' without a budget, unknown policies,
    # bad vectors) fail HERE, once, not at first trace
    remat_kind, _ = parse_remat(dcfg.remat)

    # ---- context parallelism (core/context.py): validate the cp axis,
    # the model contract and the zigzag divisibility ONCE, at plan time
    if dcfg.cp_axis is not None:
        if dcfg.cp_axis not in dcfg.mesh_axes:
            raise ValueError(
                f"cp_axis={dcfg.cp_axis!r} is not a mesh axis "
                f"({dcfg.mesh_axes})")
        if dcfg.cp_axis in (dcfg.tp_axis, dcfg.pp_axis):
            raise ValueError(
                f"cp_axis={dcfg.cp_axis!r} collides with the TP/PP axis; "
                "context parallelism needs its own mesh axis")
        if dcfg.cp_size > 1:
            from repro.core.context import supports_cp
            if not supports_cp(model):
                raise ValueError(
                    f"{type(model).__name__} does not support context "
                    "parallelism (cp_supported is not set); the ctx axis "
                    "requires the model to route attention/RoPE/loss "
                    "through the zigzag sequence shard (models/dense.py "
                    "is the reference)")
            if dcfg.cp_axis not in dcfg.fsdp_axes:
                raise ValueError(
                    f"cp_axis={dcfg.cp_axis!r} must be one of fsdp_axes="
                    f"{dcfg.fsdp_axes}: parameters shard over data x ctx "
                    "so every cross-ctx gradient flow is an explicit "
                    "collective with an exact transpose (bucket "
                    "reduce-scatter / reverse-ring ppermute — see "
                    "core/context.py); a ctx-replicated layout would "
                    "depend on vma replication-transpose")
            if shape is not None:
                cp = dcfg.cp_size
                if shape.seq_len % (2 * cp):
                    raise ValueError(
                        f"seq_len={shape.seq_len} does not split into "
                        f"2*cp={2 * cp} zigzag chunks; pad the sequence "
                        "or lower the cp degree")
                if (shape.seq_len // cp) % dcfg.tp_size:
                    raise ValueError(
                        f"per-ctx-rank sequence {shape.seq_len // cp} is "
                        f"not divisible by tp={dcfg.tp_size} (the SP "
                        "layout shards the cp-local sequence over the "
                        "model axis)")

    metas = model.metas(dcfg)
    sk = model_stacked_keys(model)     # pointed error for non-contract models
    for k, n in sk.items():
        if k not in metas:
            raise ValueError(
                f"{type(model).__name__}.stacked_keys names {k!r} which is "
                f"not a param group ({sorted(metas)})")

    stats = None
    if shape is not None and hasattr(model, "block_stats") \
            and "blocks" in metas:
        # per-device workload: rows shard over batch_dp, the sequence over
        # the ctx axis — planners see the cp-shrunk compute and re-tighten
        b_local = max(1, shape.global_batch // max(1, dcfg.batch_dp))
        stats = model.block_stats(
            dcfg, (b_local, shape.seq_len // max(1, dcfg.cp_size)))

    bucket_plans = {}
    for k in sk:
        segments = model.block_segments(dcfg) \
            if k == "blocks" and hasattr(model, "block_segments") else None
        bucket_plans[k] = plan_for(metas[k], dcfg,
                                   stats if k == "blocks" else None,
                                   segments=segments)

    stage, microbatches, pp_schedule, pp_virtual = None, 0, "", 1
    if dcfg.pp_axis is not None:
        if not hasattr(model, "stage_spec"):
            raise ValueError(
                f"{type(model).__name__} does not implement the "
                "stage-partition contract (stage_spec/stage_pre/"
                "stage_blocks/stage_loss) — cannot pipeline it")
        if dcfg.microbatches > 1:
            raise ValueError(
                "dcfg.microbatches (gradient accumulation) is not "
                "implemented for the staged pipeline step; use "
                "dcfg.pp_microbatches — pipeline microbatches ARE the "
                "accumulation under pp")
        stage = model.stage_spec(dcfg.pp_size)
        microbatches = dcfg.pp_microbatches or dcfg.pp_size
        pp_schedule, pp_virtual, stage = _resolve_pp_schedule(
            dcfg, stage, microbatches)
        stage.validate(metas.keys(), sk)

    # ---- memory plan: simulate (and, for remat="auto:<GB>", CHOOSE) the
    # per-segment policy vector + offload under the HBM budget.  Needs the
    # workload shape to size activations; fixed-policy plans without a
    # shape simply carry no memory record (nothing to choose).
    memory = None
    if remat_kind == AUTO_PREFIX and not hasattr(model, "block_stats"):
        raise ValueError(
            f"remat={dcfg.remat!r}: the budgeted auto form needs the "
            f"model's cost contract, but {type(model).__name__} does not "
            "implement block_stats; set an explicit policy (or vector) "
            "instead")
    if (shape is not None or remat_kind == AUTO_PREFIX) \
            and hasattr(model, "block_stats"):
        from repro.core.memory import plan_memory
        # the memory model walks the RESOLVED schedule (in-flight state and
        # the zb W-queue depend on it), not the user's 'auto'
        mem_dcfg = dcfg if stage is None else dcfg.with_(
            pp_schedule=pp_schedule, pp_virtual=pp_virtual)
        memory = plan_memory(model, mem_dcfg, shape,
                             bucket_plans=bucket_plans,
                             stage=stage, microbatches=microbatches)
        if memory.bucket_plan is not None:
            bucket_plans = dict(bucket_plans)
            bucket_plans[memory.main_key] = memory.bucket_plan

    return ParallelPlan(dcfg=dcfg, stacked_keys=sk,
                        bucket_plans=bucket_plans, remat=dcfg.remat,
                        stage=stage, microbatches=microbatches,
                        memory=memory, pp_schedule=pp_schedule,
                        pp_virtual=pp_virtual)


# ---------------------------------------------------------------------------
# parallelize(): the one entry point.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Parallelized:
    """What `parallelize` returns: the plan plus everything a training or
    eval loop needs — storage specs/init and jit(shard_map(...)) steps, all
    stage-aware.  Step builders import train/ lazily (core stays importable
    without the training stack)."""

    model: Any
    plan: ParallelPlan
    mesh: Any
    shape: Any = None

    # ------------------------------------------------------------ layout --
    @property
    def dcfg(self) -> DistConfig:
        return self.plan.dcfg

    @property
    def storage_specs(self):
        if self.plan.pipelined:
            from repro.models import staging
            return staging.stage_storage_specs(self.model, self.dcfg,
                                               self.plan.stage)
        from repro.models import runtime as RT
        return RT.model_storage_specs(self.model, self.dcfg)

    @property
    def pipe_sharded(self) -> frozenset:
        """The single-owner param groups stored pipe-SHARDED (see
        models/staging.pipe_sharded_groups) — empty at pp=1."""
        if not self.plan.pipelined:
            return frozenset()
        from repro.models import staging
        return staging.pipe_sharded_groups(self.model, self.dcfg,
                                           self.plan.stage)

    @property
    def abstract_storage(self):
        if self.plan.pipelined:
            from repro.models import staging
            return staging.stage_abstract_storage(self.model, self.dcfg,
                                                  self.plan.stage)
        from repro.models import runtime as RT
        return RT.model_abstract_storage(self.model, self.dcfg)

    def _resolve_shape(self, shape, what: str):
        shape = shape or self.shape
        if shape is None:
            raise ValueError(
                f"{what} needs a ShapeConfig for the batch specs; pass "
                "shape= to parallelize() or to this call")
        return shape

    def batch_specs(self, shape=None):
        from repro.models import runtime as RT
        shape = self._resolve_shape(shape, "batch_specs")
        return RT.batch_specs(self.model, shape, self.dcfg)

    def init_storage(self, key=None):
        """Init full params host-side and lay them out (staged under pp)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        from repro.models import runtime as RT
        storage = RT.init_storage(self.model, key, self.dcfg)
        return self.stage_storage(storage)

    # ------------------------------------------- staged layout round-trip --
    def stage_storage(self, storage):
        """Plain storage -> the layout this plan trains on (no-op at pp=1).

        Checkpoints always store the PLAIN layout (topology-independent);
        Trainer stages on restore and unstages on save."""
        if not self.plan.pipelined:
            return storage
        from repro.models import staging
        return staging.stage_tree(storage, self.plan.stage, self.dcfg,
                                  self.pipe_sharded)

    def unstage_storage(self, storage):
        if not self.plan.pipelined:
            return storage
        from repro.models import staging
        return staging.unstage_tree(storage, self.plan.stage, self.dcfg,
                                    self.pipe_sharded)

    # ------------------------------------------------------------- steps --
    # Steps trace with plan.exec_dcfg — dcfg with the memory plan's resolved
    # per-segment remat vector (and any bucket retightening) written back,
    # so the executed schedule IS the plan's (core/memory).
    def loss_step(self, with_grads: bool = True, shape=None):
        """jit(shard_map(step)): (storage, batch) -> loss | (loss, grads)."""
        from repro.train import train_step as TS
        return TS.wrap_loss_step(self.model, self.plan, self.plan.exec_dcfg,
                                 self._resolve_shape(shape, "loss_step"),
                                 with_grads=with_grads, mesh=self.mesh)

    def train_step(self, ocfg, lr_schedule=None, donate: bool = True,
                   shape=None):
        """jit(shard_map(step)): (storage, opt_state, batch) ->
        (storage, opt_state, metrics)."""
        from repro.train import train_step as TS
        return TS.wrap_any_train_step(
            self.model, self.plan, self.plan.exec_dcfg,
            self._resolve_shape(shape, "train_step"), ocfg, lr_schedule,
            mesh=self.mesh, donate=donate)


def parallelize(model, dcfg: DistConfig, shape=None,
                plan: ParallelPlan | None = None) -> Parallelized:
    """The paper's one-line wrap, resolved for (model, dcfg[, shape]).

    Returns a `Parallelized` bundle (plan + specs + steps).  Pass a
    pre-built `plan` to skip re-resolution (it must describe the same
    dcfg)."""
    plan = plan if plan is not None else plan_parallel(model, dcfg, shape)
    if plan.dcfg is not dcfg and plan.dcfg != dcfg:
        raise ValueError("plan was resolved for a different DistConfig")
    return Parallelized(model=model, plan=plan, mesh=make_mesh(dcfg),
                        shape=shape)


# ---------------------------------------------------------------------------
# DEPRECATED bring-your-own-module shim (pre-ParallelPlan API).
# ---------------------------------------------------------------------------
def build_metas(params_full, cfg: DistConfig, tp_dims: dict[str, int] | None
                = None, dtype=None):
    """One ParamMeta per leaf; `tp_dims` maps param path -> TP-sharded dim."""
    tp_dims = tp_dims or {}

    def one(path, leaf):
        return ParamMeta(
            name=path,
            global_shape=tuple(leaf.shape),
            tp_dim=tp_dims.get(path),
            dtype=dtype or leaf.dtype,
        )

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_full)
    from repro.core.compat import keystr
    metas = [one(keystr(p, simple=True, separator="/"), l)
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, metas)


def simple_fsdp(apply_fn: Callable, params_full, cfg: DistConfig,
                tp_dims: dict[str, int] | None = None,
                plan: BucketPlan | None = None):
    """DEPRECATED: wrap a raw `apply_fn(params, *args)` with FSDP semantics.

    Kept as a thin shim for modules with no model contract (the paper's
    Fig. 1(3) bring-your-own-module loop); registered architectures should
    go through `parallelize()` instead.  Returns (sharded_params, metas,
    wrapped_apply) where `wrapped_apply` expects the sharded storage layout
    and must run inside shard_map over cfg's mesh.
    """
    metas = build_metas(params_full, cfg, tp_dims)
    sharded = shard_params(params_full, metas, cfg)
    resolved_plan = plan if plan is not None else plan_for(metas, cfg)

    def wrapped_apply(shards, *args, **kwargs):
        full = coll.replicate_tree(shards, metas, cfg, resolved_plan)
        return apply_fn(full, *args, **kwargs)

    return sharded, metas, wrapped_apply
