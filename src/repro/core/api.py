"""User-facing entry point — the analogue of the paper's

    model = simple_fsdp(model)
    model = torch.compile(model, fullgraph=True)

`simple_fsdp` takes a pure apply function plus a (full, shaped) parameter
pytree and returns (sharded_params, metas, wrapped_apply). `wrapped_apply`
gathers parameters per the configured bucket plan before calling the original
function, and its backward reduce-scatters gradients — i.e. the model now
*is* FSDP, with no change to its code. Compile by wrapping in
``jax.jit(shard_map(...))`` (see train/ and examples/quickstart.py).

Large production models do not go through this generic wrapper — they build
metas directly and use `core.stack.apply_stack` for scanned layer stacks
(see models/); this entry point covers the "bring your own module" case and
is what the paper's Fig. 1(3) loop corresponds to.
"""

from __future__ import annotations

from typing import Callable

import jax

from repro.core import collectives as coll
from repro.core.bucketing import BucketPlan, plan_for
from repro.core.dist import DistConfig
from repro.core.meta import ParamMeta, named_leaves, to_storage


def build_metas(params_full, cfg: DistConfig, tp_dims: dict[str, int] | None
                = None, dtype=None):
    """One ParamMeta per leaf; `tp_dims` maps param path -> TP-sharded dim."""
    tp_dims = tp_dims or {}
    named = dict(named_leaves(params_full))
    metas = {}

    def one(path, leaf):
        return ParamMeta(
            name=path,
            global_shape=tuple(leaf.shape),
            tp_dim=tp_dims.get(path),
            dtype=dtype or leaf.dtype,
        )

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_full)
    from repro.core.compat import keystr
    metas = [one(keystr(p, simple=True, separator="/"), l)
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, metas)


def shard_params(params_full, metas, cfg: DistConfig):
    """Full shaped params -> flat/padded/TP-indexed ZeRO-3 storage layout.

    (Host-side layout transform; placement onto the mesh happens via
    jax.device_put with `meta.storage_spec` — see train/trainer.py.)
    """
    return jax.tree.map(
        lambda p, m: to_storage(p, m, cfg), params_full, metas,
        is_leaf=lambda x: isinstance(x, ParamMeta) or hasattr(x, "shape"),
    )


def simple_fsdp(apply_fn: Callable, params_full, cfg: DistConfig,
                tp_dims: dict[str, int] | None = None,
                plan: BucketPlan | None = None):
    """Wrap `apply_fn(params, *args)` with FSDP semantics.

    Returns (sharded_params, metas, wrapped_apply) where `wrapped_apply`
    expects the sharded storage layout and must run inside shard_map over
    cfg's mesh.
    """
    metas = build_metas(params_full, cfg, tp_dims)
    sharded = shard_params(params_full, metas, cfg)
    resolved_plan = plan if plan is not None else plan_for(metas, cfg)

    def wrapped_apply(shards, *args, **kwargs):
        full = coll.replicate_tree(shards, metas, cfg, resolved_plan)
        return apply_fn(full, *args, **kwargs)

    return sharded, metas, wrapped_apply
