"""JAX version compatibility shims.

The repo targets the jax>=0.6 API surface (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``,
``keystr(..., simple=True)``).  The container may pin an older jax (0.4.x)
where those live under different names/signatures; this module backfills
them so every call site imports from here and runs on both.

On old jax, ``check_vma``/``check_rep`` is force-disabled: the 0.4.x
``check_rep`` rule set predates the vma type system and rejects valid
programs (custom_vjp whose backward issues ``psum_scatter``, ppermute in
scan carries).  Correctness is asserted numerically by the parity suite
(tests/dist_harness.py) instead of by the static checker.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.6: top-level export, vma-typed
    from jax import shard_map as _shard_map

    _NEW_SHARD_MAP = True
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _NEW_SHARD_MAP = False

# The vma (varying-manual-axes) type system ships with the new shard_map.
# Without it, autodiff inside shard_map does not auto-psum cotangents of
# TP-replicated values consumed by TP-varying compute (see ROADMAP "Old-jax
# vma parity gap") — version-gated tests key off this flag.
HAS_VMA = _NEW_SHARD_MAP


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the new keyword signature on any jax."""
    if _NEW_SHARD_MAP:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
    return _shard_map(f, mesh, in_specs, out_specs, check_rep=False)


def make_mesh(shape, axes, devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis_types where supported."""
    if devices is not None:
        import numpy as np

        return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def pallas_tpu_compiler_params():
    """The pallas TPU compiler-params class: ``pltpu.CompilerParams`` on
    new pallas, ``TPUCompilerParams`` before the rename."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        raise ImportError("pallas TPU backend has no CompilerParams class")
    return cls


def vma_of(x) -> frozenset:
    """`jax.typeof(x).vma` where the vma type system exists; empty set on
    old jax (no vma tracking — shard_map runs with checking disabled)."""
    try:
        return jax.typeof(x).vma
    except AttributeError:
        return frozenset()


def keystr(path, simple: bool = False, separator: str = "") -> str:
    """``jax.tree_util.keystr(path, simple=, separator=)`` on any jax."""
    try:
        return jax.tree_util.keystr(path, simple=simple, separator=separator)
    except TypeError:
        if not simple:
            return jax.tree_util.keystr(path)
        parts = []
        for k in path:
            for attr in ("key", "idx", "name"):
                if hasattr(k, attr):
                    parts.append(str(getattr(k, attr)))
                    break
            else:
                parts.append(str(k))
        return separator.join(parts)
