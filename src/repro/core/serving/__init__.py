"""Serving subsystem: paged KV cache, continuous batching, prefix cache,
and the multi-replica admission router.

The unit of planning here is a *request stream*, not a train step, but the
architecture is the same plan-centric one the training side uses: a frozen
``ServePlan`` (the serving analogue of ``ParallelPlan``) is resolved ONCE
from the hw.py roofline and the cache-arena budget, and every runtime
decision — page allocation, slot assignment, chunked-prefill interleaving,
eviction, routing — executes that plan.

  pages.py      fixed-size KV pages in a pooled arena (+ the gather/scatter
                decode path the models call), page tables, host PagePool
  scheduler.py  ServePlan + the continuous-batching scheduler
  prefix.py     prefix caching via page-table sharing on full pages
  router.py     multi-replica admission router + latency projection
"""

from repro.core.serving.pages import (PagePool, arena_abstract,
                                      dense_to_pages, gather_tokens,
                                      scatter_tokens)
from repro.core.serving.prefix import PrefixCache
from repro.core.serving.scheduler import (ContinuousBatcher, Request,
                                          ServePlan, plan_serve,
                                          run_virtual, static_schedule)
from repro.core.serving.router import Router, simulate_trace, synthetic_trace

__all__ = [
    "PagePool", "arena_abstract", "dense_to_pages", "gather_tokens",
    "scatter_tokens", "PrefixCache", "ContinuousBatcher", "Request",
    "ServePlan", "plan_serve", "run_virtual", "static_schedule",
    "Router", "simulate_trace", "synthetic_trace",
]
