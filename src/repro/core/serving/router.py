"""Multi-replica admission router: cost-model-driven routing + latency
projection under a synthetic traffic trace.

Each replica is a ServePlan; its service rates come straight from the
roofline numbers frozen into the plan (tokens/sec prefill, per-step
decode).  The router projects every candidate replica's finish time for
an incoming request from its current slot backlog and routes to the
argmin — the serving analogue of the training planners' cost-model
argmin, and the same numbers the p50/p99 projection integrates.

Everything here is host math (an event simulation over slot free-times),
deterministic by construction: the trace generator uses its own seeded
PRNG, never wall clock."""

from __future__ import annotations

import dataclasses
import heapq
import math
import random

from repro.core.serving.scheduler import Request, ServePlan, _pct


def synthetic_trace(n: int, *, seed: int = 0, mean_interarrival_s: float,
                    prompt_lens=(64, 128, 256), gen_lens=(16, 64, 256),
                    vocab: int = 256) -> list[Request]:
    """Poisson arrivals, mixed prompt/gen lengths — the heavy-traffic mix
    (mostly short, a long tail) serving schedulers are judged on."""
    rng = random.Random(seed)
    reqs, t = [], 0.0
    for i in range(n):
        t += rng.expovariate(1.0 / mean_interarrival_s)
        pl = rng.choice(prompt_lens)
        reqs.append(Request(
            rid=i,
            prompt=tuple(rng.randrange(3, vocab) for _ in range(pl)),
            max_new=rng.choice(gen_lens), arrival=t))
    return reqs


@dataclasses.dataclass
class _Replica:
    plan: ServePlan
    slots: list          # heap of slot free-times
    assigned: int = 0
    busy_s: float = 0.0
    # measured-over-modeled decode-time ratio: the roofline is the
    # prior (1.0), observed decode steps move it (the posterior the
    # projections integrate)
    decode_scale: float = 1.0

    def projected_start(self, arrival: float) -> float:
        return max(arrival, self.slots[0])

    def service_time(self, req: Request) -> float:
        p = self.plan
        return (p.prefill_time(len(req.prompt))
                + req.max_new * self.decode_scale * p.decode_step_time(
                    p.max_batch, (len(req.prompt) + req.max_new / 2)))


class Router:
    """Admission control + routing over N replicas.

    `admit_slo_s`: a request whose best projected queue wait exceeds the
    SLO is rejected at the door (load shedding) instead of blowing up
    the tail for everyone already admitted.

    Latency projections start from each replica's roofline (the prior)
    and are corrected by measured decode-step feedback when an executor
    reports it (`observe_decode` / `feed_from_batcher`) — with no
    feedback the behavior is bit-identical to the pure-model router."""

    def __init__(self, plans: list[ServePlan],
                 admit_slo_s: float | None = None, registry=None):
        self.replicas = [
            _Replica(plan=p, slots=[0.0] * p.max_batch) for p in plans]
        self.admit_slo_s = admit_slo_s
        self.registry = registry
        self.rejected: list[Request] = []

    def observe_decode(self, idx: int, measured_step_s: float,
                       modeled_step_s: float | None = None,
                       alpha: float = 0.2) -> float:
        """Fold one measured decode step into replica `idx`'s posterior.
        `modeled_step_s` defaults to the replica's own full-batch roofline
        step; returns the updated decode_scale."""
        rep = self.replicas[idx]
        if modeled_step_s is None:
            modeled_step_s = rep.plan.decode_step_s
        ratio = measured_step_s / modeled_step_s if modeled_step_s > 0 \
            else 1.0
        rep.decode_scale = alpha * ratio + (1.0 - alpha) * rep.decode_scale
        if self.registry is not None:
            self.registry.gauge(f"router/replica{idx}/decode_scale").set(
                rep.decode_scale)
        return rep.decode_scale

    def feed_from_batcher(self, idx: int, batcher,
                          alpha: float = 0.2) -> float:
        """Pull the scale-free decode_ratio EWMA a ContinuousBatcher
        accumulated (scheduler.decode_ratio) into replica `idx`."""
        rep = self.replicas[idx]
        if getattr(batcher, "decode_ratio", None) is not None:
            rep.decode_scale = (alpha * batcher.decode_ratio
                                + (1.0 - alpha) * rep.decode_scale)
            if self.registry is not None:
                self.registry.gauge(
                    f"router/replica{idx}/decode_scale").set(
                        rep.decode_scale)
        return rep.decode_scale

    def route(self, req: Request) -> tuple[int, float] | None:
        """Pick the replica with the earliest projected start; returns
        (replica index, projected completion latency), or None when
        admission control rejects."""
        best, best_t = None, math.inf
        for i, rep in enumerate(self.replicas):
            t = rep.projected_start(req.arrival)
            if t < best_t:
                best, best_t = i, t
        if (self.admit_slo_s is not None
                and best_t - req.arrival > self.admit_slo_s):
            self.rejected.append(req)
            if self.registry is not None:
                self.registry.counter("router/rejected").inc()
            return None
        rep = self.replicas[best]
        start = max(heapq.heappop(rep.slots), req.arrival)
        svc = rep.service_time(req)
        heapq.heappush(rep.slots, start + svc)
        rep.assigned += 1
        rep.busy_s += svc
        lat = start + svc - req.arrival
        if self.registry is not None:
            self.registry.histogram("router/projected_latency_s").observe(
                lat)
        return best, lat


def simulate_trace(plans: list[ServePlan], trace: list[Request],
                   admit_slo_s: float | None = None) -> dict:
    """Route a whole trace, project per-request latency, aggregate."""
    router = Router(plans, admit_slo_s=admit_slo_s)
    lats = []
    for req in sorted(trace, key=lambda r: r.arrival):
        routed = router.route(req)
        if routed is not None:
            lats.append(routed[1])
    horizon = max((max(r.slots) for r in router.replicas), default=0.0)
    total_tokens = sum(r.max_new for r in trace) - \
        sum(r.max_new for r in router.rejected)
    return dict(
        requests=len(trace), admitted=len(lats),
        rejected=len(router.rejected),
        p50_s=_pct(lats, 50), p99_s=_pct(lats, 99),
        tok_s=total_tokens / horizon if horizon else 0.0,
        per_replica=[
            dict(assigned=r.assigned,
                 utilization=r.busy_s / horizon if horizon else 0.0)
            for r in router.replicas])
