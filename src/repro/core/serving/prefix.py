"""Prefix caching via page-table sharing.

Only FULL pages are shareable: a page is immutable once all `page` slots
are written (decode only ever appends past it), so two sequences whose
prompts agree on the first k*page tokens can point their first k page-
table entries at the same pool pages.  The cache holds one reference per
cached page (PagePool refcounts), sequences holding a hit add their own,
and release drops back to the cache's reference — nothing is copied.

Keys are hash-chains over page-sized token chunks, so lookup walks the
longest cached prefix in O(pages).  Eviction is LRU, deepest chain
entries first (evicting a parent strands its children until their own
LRU turn — they stay refcounted, just unreachable; documented cost of
keeping the structure a flat map instead of a trie)."""

from __future__ import annotations

from collections import OrderedDict


def _chain_keys(prompt, page: int):
    """Hash-chain keys for each FULL page of the prompt."""
    keys = []
    k = ()
    for j in range(len(prompt) // page):
        k = (k, tuple(prompt[j * page:(j + 1) * page]))
        keys.append(k)
    return keys


class PrefixCache:
    def __init__(self, capacity_pages: int | None = None):
        self.capacity = capacity_pages
        self._lru: OrderedDict = OrderedDict()   # key -> page id
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._lru)

    def lookup(self, prompt, pool, page: int) -> list[int]:
        """Longest cached full-page prefix of `prompt`; retains every
        returned page on behalf of the caller's sequence."""
        out = []
        for key in _chain_keys(prompt, page):
            pid = self._lru.get(key)
            if pid is None:
                self.misses += 1
                break
            self._lru.move_to_end(key)
            pool.retain(pid)
            out.append(pid)
            self.hits += 1
        return out

    def insert(self, prompt, table, pool, page: int) -> int:
        """Cache the full prompt pages of a finished/prefilled sequence
        (retaining them) — call BEFORE the sequence releases its table.
        Returns how many new pages were cached."""
        added = 0
        for j, key in enumerate(_chain_keys(prompt, page)):
            if j >= len(table):
                break
            if key in self._lru:
                self._lru.move_to_end(key)
                continue
            pool.retain(table[j])
            self._lru[key] = table[j]
            added += 1
        if self.capacity is not None:
            self.reclaim(pool, max(0, len(self._lru) - self.capacity))
        return added

    def reclaim(self, pool, n: int) -> int:
        """Release up to n cached pages (LRU-first, deepest chains first
        among equally-stale entries) back to the pool.  Returns how many
        pages actually went back to the free list."""
        freed = 0
        for _ in range(min(n, len(self._lru))):
            key, pid = self._lru.popitem(last=False)
            if pool.release(pid):
                freed += 1
        return freed
