"""Paged KV cache: fixed-size pages in a pooled arena.

Layout (ParallelPlan-style, resolved at plan time): every cache leaf the
dense prefill emits as ``(L, B, T, *rest)`` becomes an arena pool leaf
``(L, n_pages_global, page, *rest)`` with the SAME partition spec — heads
stay sharded over the model axis, and the pages dimension is sharded over
the data axes (each data shard owns its own pool; page ids are local to
the shard).  The last pool row of every shard is a scratch page: inactive
batch slots (page-table entries -1) write there and are never read back.

Device side (called from models/dense.py::_paged_writer, inside
shard_map):  `scatter_tokens` commits new K/V at the slots the page table
maps logical positions to; `gather_tokens` reads the table's full logical
window back as a dense (B, max_pages*page, ...) view — for every
allocated position this is bit-identical to the dense cache, which is
what makes paged-vs-dense decode EXACTLY parity-checkable.

Host side: `PagePool` (free list + refcounts, shared pages for the prefix
cache), `dense_to_pages` (repage a prefilled dense cache into an arena —
the load path and the parity harness), `arena_abstract` (abstract
shapes/specs derived from the dense cache abstracts).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Device gather/scatter over page indices
# ---------------------------------------------------------------------------
def scatter_tokens(pool, table, qpos, val, page: int):
    """Commit val (B, C, *rest) at logical positions qpos (B, C).

    pool: (n_pages+1, page, *rest) — local pool, last row = scratch;
    table: (B, max_pages) int32 local page ids, -1 = unallocated (routed
    to the scratch page so inactive slots never corrupt live pages)."""
    B, C = qpos.shape
    ib = jnp.arange(B)[:, None]
    pi = jnp.clip(qpos // page, 0, table.shape[1] - 1)
    pid = table[ib, pi]
    pid = jnp.where(pid < 0, pool.shape[0] - 1, pid)
    slot = qpos % page
    return pool.at[pid, slot].set(val.astype(pool.dtype))


def gather_tokens(pool, table, page: int):
    """Read the table's logical window: (B, max_pages*page, *rest).

    Unallocated table entries gather arbitrary pool rows (clipped ids) —
    callers mask by position, and the scheduler invariant (every position
    <= pos is backed by an allocated page) keeps the masked-in region
    exact."""
    flat = pool.reshape(pool.shape[0] * page, *pool.shape[2:])
    safe = jnp.clip(table, 0, pool.shape[0] - 1)
    idx = (safe[:, :, None] * page
           + jnp.arange(page)[None, None, :]).reshape(table.shape[0], -1)
    return flat[idx]


# ---------------------------------------------------------------------------
# Abstract arena layout (plan-time)
# ---------------------------------------------------------------------------
def arena_abstract(cache_abs, cache_specs, n_pages_local: int, page: int,
                   dp_shards: int):
    """Derive (arena_abs, arena_specs) from the dense cache abstracts.

    Each leaf (L, B, T, *rest) -> (L, dp_shards*(n_pages_local+1), page,
    *rest) with the SAME spec: dim 1 (pages) rides the data axes exactly
    where the batch dim did, heads keep the model axis (+1 is the
    per-shard scratch page)."""
    np_global = dp_shards * (n_pages_local + 1)

    def leaf(a):
        return jax.ShapeDtypeStruct(
            (a.shape[0], np_global, page, *a.shape[3:]), a.dtype)

    # the dense cache specs apply unchanged: dim 1 (pages for the arena,
    # batch for the dense cache) rides the data axes either way
    return jax.tree.map(leaf, cache_abs), cache_specs


# ---------------------------------------------------------------------------
# Host page pool
# ---------------------------------------------------------------------------
class PagePool:
    """Free-list + refcount page allocator for ONE data shard's pool.

    Pages are the unit of both allocation and sharing: the prefix cache
    retains full pages by bumping refcounts, so `release` only returns a
    page to the free list when its last reference drops.  The scratch
    page is NOT managed here — it sits past `n_pages` in the arena."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))
        self._ref = np.zeros(n_pages, dtype=np.int64)

    @property
    def used(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Allocate n pages (refcount 1 each) or None — never partial."""
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        self._ref[ids] = 1
        return ids

    def retain(self, pid: int) -> None:
        assert self._ref[pid] > 0, f"retain of free page {pid}"
        self._ref[pid] += 1

    def release(self, pid: int) -> bool:
        """Drop one reference; True when the page actually freed."""
        assert self._ref[pid] > 0, f"release of free page {pid}"
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            self._free.append(pid)
            return True
        return False

    def release_all(self, pids) -> None:
        for p in pids:
            self.release(p)

    def check(self) -> None:
        """Invariant: every page is exactly free or referenced."""
        free = set(self._free)
        assert len(free) == len(self._free), "double-free"
        for pid in range(self.n_pages):
            assert (pid in free) == (self._ref[pid] == 0), pid


# ---------------------------------------------------------------------------
# Repage a dense cache (host) — the load path and the parity harness
# ---------------------------------------------------------------------------
def dense_to_pages(cache, lengths, page: int, n_pages_local: int,
                   max_pages: int, dp_shards: int = 1):
    """Scatter a prefilled dense cache into a fresh arena.

    cache: pytree of np/jnp leaves (L, B, T, *rest); lengths: (B,) valid
    prefix per sequence.  Rows are dealt to data shards contiguously
    (shard = b // (B/dp_shards)) and each shard allocates from its own
    pool, so the returned table holds LOCAL page ids.  Returns
    (arena_tree, tables (B, max_pages) int32, pools per shard)."""
    leaves, treedef = jax.tree.flatten(cache)
    B = leaves[0].shape[1]
    assert B % dp_shards == 0
    rows_per = B // dp_shards
    pools = [PagePool(n_pages_local) for _ in range(dp_shards)]
    np1 = n_pages_local + 1
    tables = np.full((B, max_pages), -1, dtype=np.int32)

    out = [np.zeros((lf.shape[0], dp_shards * np1, page, *lf.shape[3:]),
                    dtype=lf.dtype) for lf in leaves]
    for b in range(B):
        shard = b // rows_per
        n_needed = -(-int(lengths[b]) // page) if lengths[b] else 0
        assert n_needed <= max_pages, (b, lengths[b])
        ids = pools[shard].alloc(n_needed)
        assert ids is not None, "arena too small for dense_to_pages"
        for j, pid in enumerate(ids):
            tables[b, j] = pid
            lo = j * page
            m = min(page, int(lengths[b]) - lo)
            for lf, dst in zip(leaves, out):
                dst[:, shard * np1 + pid, :m] = np.asarray(
                    lf[:, b, lo:lo + m])
    return (jax.tree.unflatten(treedef, [jnp.asarray(a) for a in out]),
            jnp.asarray(tables), pools)
