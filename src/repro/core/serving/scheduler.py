"""ServePlan (the serving analogue of ParallelPlan) + continuous batching.

Everything latency-shaped is resolved ONCE at plan time from the hw.py
roofline and the cache-arena budget — page size, pool capacity, decode
slot count, the chunked-prefill chunk size (sized so one interleaved
chunk never stalls decode past the SLO), and the prefill/decode service
rates the router projects with.  The runtime scheduler then only executes
the plan: admission, slot assignment, chunked prefill interleaved with
decode, page allocation/eviction, preemption.

The scheduler is HOST code driving device steps it does not own: callers
(launch/serve.py, benchmarks) translate `next_action()` into
train/serve.py paged-step invocations and feed results back through
`on_prefill` / `on_token`.  A virtual clock advanced by the plan's
modeled step costs gives deterministic p50/p99 numbers alongside the
wall-clock measurements the drivers record.
"""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.core import hw
from repro.core.serving.pages import PagePool


# ---------------------------------------------------------------------------
# ServePlan
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServePlan:
    arch: str
    family: str
    page: int                 # tokens per KV page
    n_pages: int              # pool capacity per data shard (excl. scratch)
    max_pages_per_seq: int    # page-table width
    max_batch: int            # decode slots per data shard
    prefill_chunk: int        # tokens per interleaved prefill chunk
    interleave: int           # decode steps drained between prefill chunks
    codec: str | None         # KV page storage codec (kernels/quant)
    kv_token_bytes: int       # per-device cache bytes per token (all layers)
    weight_bytes: int         # per-device serving param bytes
    arena_bytes: int          # kv_token_bytes * page * n_pages
    decode_step_s: float      # modeled decode step at max_batch, full ctx
    prefill_tok_s: float      # modeled prefill throughput (chunked)
    cp_prefill: int           # recommended ring-attention degree (PR 5) for
                              # prompts that overflow the chunk SLO; 1 = off

    @property
    def tmax(self) -> int:
        return self.max_pages_per_seq * self.page

    def decode_step_time(self, batch: int, ctx_tokens: float) -> float:
        """Roofline one-token step: stream all weights + the live context
        KV once; MXU side is 2*P flops per sequence."""
        ctx_bytes = batch * ctx_tokens * self.kv_token_bytes
        return hw.compute_time_s(2.0 * self.weight_bytes * batch,
                                 self.weight_bytes + ctx_bytes)

    def modeled_decode_tok_s(self, batch: int, ctx_tokens: float,
                             paged: bool = True) -> float:
        """Tokens/sec at `batch` live sequences with mean context
        `ctx_tokens`.  The DENSE cache streams the full allocated window
        (tmax) per sequence regardless of occupancy; pages stream only
        the allocated context — that gap is the paged win at equal
        batch."""
        ctx = ctx_tokens if paged else float(self.tmax)
        return batch / self.decode_step_time(batch, ctx)

    def prefill_time(self, n_tokens: int) -> float:
        return max(n_tokens, 1) / self.prefill_tok_s


def _weight_bytes(model, dcfg) -> int:
    import jax.numpy as jnp

    from repro.core.meta import ParamMeta, named_leaves
    it = jnp.dtype(dcfg.param_dtype).itemsize
    total = 0
    metas = model.metas(dcfg)
    for k in metas:
        for _, m in named_leaves(metas[k]):
            if isinstance(m, ParamMeta):
                total += m.numel_local(dcfg) * it
    return total


def _kv_token_bytes(model, dcfg) -> int:
    """Per-device cache bytes per token, summed over layers: derived from
    the family's own cache abstracts so codec/scale overheads and
    grouped-KV layouts are priced exactly once."""
    import math

    import jax
    import jax.numpy as jnp

    from repro.models.common import ShapeConfig
    from repro.train.serve import cache_abstract
    B, T = 2, 2 * 8
    abs_, _ = cache_abstract(model, ShapeConfig("plan", T, B, "decode"),
                             dcfg)
    total = 0
    for lf in jax.tree.leaves(abs_):
        # leaves are (L, B, T, *rest); heads shard over tp
        per_tok = (lf.shape[0] * math.prod(lf.shape[3:])
                   * jnp.dtype(lf.dtype).itemsize)
        total += per_tok // max(1, dcfg.tp_size)
    return int(total)


def plan_serve(model, dcfg, *, arena_bytes: int, max_batch: int,
               max_seq: int, page: int = 16, slo_decode_ms: float = 30.0,
               interleave: int = 4) -> ServePlan:
    """Freeze the serving plan from the roofline + arena budget.

    slo_decode_ms bounds the decode stall one interleaved prefill chunk
    may add: the chunk is the largest power of two whose modeled prefill
    time fits under it.  Prompts so long that even chunked prefill blows
    the time-to-first-token budget get a ring-attention (PR 5) prefill
    recommendation when the family supports cp."""
    cfg = model.cfg
    if not getattr(model, "paged_kv", False):
        raise ValueError(
            f"{cfg.name} (family={cfg.family}) has no paged KV serving "
            f"path: recurrent state (xlstm/zamba) and the encdec dual "
            f"cache serve through the dense steps (ROADMAP serving "
            f"follow-ups)")
    kv_tok = _kv_token_bytes(model, dcfg)
    weights = _weight_bytes(model, dcfg)
    n_pages = int(arena_bytes // (kv_tok * page))
    if n_pages < max_batch:
        need = max_batch * page * kv_tok
        raise ValueError(
            f"arena budget {arena_bytes/2**20:.1f} MiB holds {n_pages} "
            f"pages of {page} tokens ({kv_tok} B/token) — fewer than "
            f"max_batch={max_batch} sequences need; raise the budget to "
            f">= {need/2**20:.1f} MiB or shrink page/max_batch")
    max_pages_per_seq = min(-(-max_seq // page), n_pages)

    # prefill rate: MXU-bound chunk forward (2*P flops/token) with the
    # weight stream amortized over the chunk
    def chunk_time(c):
        return hw.compute_time_s(2.0 * weights * c, weights + c * kv_tok)

    chunk = page
    while (chunk * 2 <= max_seq
           and chunk_time(chunk * 2) <= slo_decode_ms / 1e3):
        chunk *= 2
    prefill_tok_s = chunk / chunk_time(chunk)

    # long-context prefill: if a full prompt would take > 2s even chunked,
    # recommend ring-attention prefill over cp shards (time/cp, + ring
    # hops priced by hw.ring_hop_time_s — negligible next to the MXU term)
    cp = 1
    if getattr(model, "cp_supported", False):
        while (cp < 8 and max_seq / prefill_tok_s / cp > 2.0
               and max_seq // (2 * cp) >= page):
            cp *= 2

    plan = ServePlan(
        arch=cfg.name, family=cfg.family, page=page, n_pages=n_pages,
        max_pages_per_seq=max_pages_per_seq, max_batch=max_batch,
        prefill_chunk=chunk, interleave=interleave, codec=dcfg.kv_codec,
        kv_token_bytes=kv_tok, weight_bytes=weights,
        arena_bytes=n_pages * page * kv_tok,
        decode_step_s=hw.compute_time_s(
            2.0 * weights * max_batch,
            weights + max_batch * max_pages_per_seq * page * kv_tok),
        prefill_tok_s=prefill_tok_s, cp_prefill=cp)
    return plan


# ---------------------------------------------------------------------------
# Requests / sequences
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    rid: int
    prompt: tuple
    max_new: int
    arrival: float = 0.0


class _Seq:
    def __init__(self, req: Request, slot: int):
        self.req = req
        self.slot = slot
        self.table: list[int] = []      # local page ids, logical order
        self.shared: int = 0            # leading table entries owned by
                                        # the prefix cache (refcounted)
        self.pos = 0                    # tokens materialized in the cache
        self.out: list[int] = []
        self.prefill_done = False
        self.t_first: float | None = None
        self.t_done: float | None = None

    @property
    def prompt_len(self) -> int:
        return len(self.req.prompt)


def _pages_through(pos: int, page: int) -> int:
    """Pages required to back logical positions [0, pos]."""
    return -(-(pos + 1) // page)


# ---------------------------------------------------------------------------
# Continuous batcher
# ---------------------------------------------------------------------------
class ContinuousBatcher:
    """Continuous batching over `plan.max_batch` decode slots.

    Policy (all constants from the frozen plan):
      * admission in arrival order, gated on a free slot + pages for the
        first prefill chunk (prefix-cache hits skip straight past their
        shared full pages);
      * chunked prefill interleaved with decode — after each chunk, up to
        `plan.interleave` decode steps drain before the next chunk, so
        decode latency stays bounded while prefill still makes progress;
      * page-boundary allocation during decode; when the pool runs dry
        the YOUNGEST running sequence is preempted (pages released,
        request requeued at the front) — LIFO preemption wastes the
        least completed work;
      * a virtual clock priced by the plan gives deterministic latency
        accounting next to the driver's wall measurements.
    """

    def __init__(self, plan: ServePlan, prefix_cache=None, registry=None):
        self.plan = plan
        self.pool = PagePool(plan.n_pages)
        self.prefix = prefix_cache
        self.slots: list[_Seq | None] = [None] * plan.max_batch
        self.waiting: deque[Request] = deque()
        self.pending: list[Request] = []    # not yet arrived (virtual time)
        self.done: list[_Seq] = []
        self.vtime = 0.0
        self._since_prefill = plan.interleave
        self.stats = {"decode_steps": 0, "prefill_chunks": 0,
                      "preemptions": 0, "prefix_hit_tokens": 0,
                      "prefix_lookup_tokens": 0, "peak_pages": 0}
        # observability (core/obs): optional MetricsRegistry + trace event
        # log.  `decode_ewma` is the measured per-step decode time the
        # Router's posterior feeds on; `decode_ratio` its scale-free form
        # (measured / plan roofline at the SAME batch+context, EWMA).
        self.registry = registry
        self.events: list[tuple] | None = None
        self.decode_ewma: float | None = None
        self.decode_ratio: float | None = None
        self._ewma_alpha = 0.2

    def enable_trace(self) -> None:
        """Start logging (kind, vtime...) events for
        `core/obs.trace.serving_lanes` — admission, prefill chunks,
        decode windows, preemptions, finishes, all stamped by the same
        virtual clock that prices the latency metrics."""
        self.events = []

    # -------------------------------------------------------------- admit --
    def submit(self, req: Request) -> None:
        self.pending.append(req)
        self.pending.sort(key=lambda r: r.arrival)

    def _admit_arrivals(self) -> None:
        while self.pending and self.pending[0].arrival <= self.vtime:
            self.waiting.append(self.pending.pop(0))

    def _free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _start(self, req: Request, slot: int) -> _Seq | None:
        seq = _Seq(req, slot)
        if self.prefix is not None:
            hit_pages = self.prefix.lookup(req.prompt, self.pool,
                                           self.plan.page)
            # shared pages are read-only — fast-forward must stop BEFORE
            # the last prompt token so the resumed prefill (which computes
            # the first output logits) writes only into fresh pages
            keep = min(len(hit_pages),
                       (seq.prompt_len - 1) // self.plan.page)
            for pid in hit_pages[keep:]:
                self.pool.release(pid)
            seq.table = list(hit_pages[:keep])
            seq.shared = keep
            seq.pos = keep * self.plan.page
            self.stats["prefix_hit_tokens"] += seq.pos
            self.stats["prefix_lookup_tokens"] += seq.prompt_len
        self.slots[slot] = seq
        if self.events is not None:
            self.events.append(("admit", self.vtime, req.rid))
        if self.registry is not None:
            self.registry.counter("serving/admitted").inc()
            self.registry.gauge("serving/queue_depth").set(len(self.waiting))
        return seq

    # ------------------------------------------------------------- paging --
    def _ensure_pages(self, seq: _Seq, through_pos: int) -> bool:
        """Back positions [0, through_pos] with pages, allocating (and
        preempting if needed) at boundaries.  False = could not."""
        need = _pages_through(through_pos, self.plan.page) - len(seq.table)
        while need > 0:
            ids = self.pool.alloc(need)
            if ids is None:
                # reclaim idle prefix-cache pages before evicting live work
                if (self.prefix is not None
                        and self.prefix.reclaim(self.pool, need) > 0):
                    continue
                if not self._preempt_someone(but=seq):
                    return False
                continue
            seq.table.extend(ids)
            need = 0
        self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                       self.pool.used)
        return True

    def _preempt_someone(self, but: _Seq) -> bool:
        """Evict the youngest running sequence (≠ `but`) and requeue it."""
        victims = [s for s in self.slots
                   if s is not None and s is not but]
        if not victims:
            return False
        victim = max(victims, key=lambda s: s.req.arrival)
        self._release_seq(victim)
        self.slots[victim.slot] = None
        # requeue at the front, reset to re-prefill (prefix cache keeps
        # any full pages it owns, so the re-run may fast-forward)
        req = victim.req
        self.waiting.appendleft(dataclasses.replace(
            req, prompt=tuple(req.prompt) + tuple(victim.out),
            max_new=req.max_new - len(victim.out)))
        self.stats["preemptions"] += 1
        if self.events is not None:
            self.events.append(("preempt", self.vtime, req.rid))
        if self.registry is not None:
            self.registry.counter("serving/preemptions").inc()
        return True

    def _release_seq(self, seq: _Seq) -> None:
        for j, pid in enumerate(seq.table):
            self.pool.release(pid)    # shared pages just drop one ref
        seq.table = []

    # ------------------------------------------------------------- policy --
    def next_action(self):
        """-> ("prefill", seq, start, tokens) | ("decode", [seqs]) | None.

        None with work still pending means the virtual clock advanced to
        the next arrival; call again.  None with nothing pending = done.
        """
        self._admit_arrivals()
        active = [s for s in self.slots if s is not None and s.prefill_done]
        prefilling = [s for s in self.slots
                      if s is not None and not s.prefill_done]

        want_prefill = (self._since_prefill >= self.plan.interleave
                        or not active)
        if want_prefill:
            # continue a partially-prefilled resident first
            seq = prefilling[0] if prefilling else None
            if seq is None and self.waiting:
                slot = self._free_slot()
                if slot is not None:
                    seq = self._start(self.waiting.popleft(), slot)
            if seq is not None:
                start = seq.pos
                n = min(self.plan.prefill_chunk, seq.prompt_len - start)
                if n > 0 and self._ensure_pages(seq, start + n - 1):
                    toks = seq.req.prompt[start:start + n]
                    self._since_prefill = 0
                    return ("prefill", seq, start, tuple(toks))
                if n <= 0:   # fully cached by prefix hits: decode-ready
                    seq.prefill_done = True
                    if self._ensure_pages(seq, seq.pos):
                        active.append(seq)
        if active:
            ok = []
            for s in active:
                # `active` is a snapshot: an ensure above (or earlier in
                # this loop) may have preempted s — allocating pages to an
                # evicted seq would leak them
                if self.slots[s.slot] is not s:
                    continue
                if self._ensure_pages(s, s.pos):
                    ok.append(s)
            ok = [s for s in ok if self.slots[s.slot] is s]
            if ok:
                self._since_prefill += 1
                return ("decode", ok)
        if self.pending:
            self.vtime = max(self.vtime, self.pending[0].arrival)
            return None
        if self.waiting or any(s is not None for s in self.slots):
            # blocked on pages with nothing preemptible — drain decode
            self._since_prefill = self.plan.interleave
            return None
        return None

    # ------------------------------------------------------------ results --
    def on_prefill(self, seq: _Seq, n_tokens: int,
                   wall_s: float | None = None) -> None:
        t0 = self.vtime
        seq.pos += n_tokens
        self.vtime += (wall_s if wall_s is not None
                       else self.plan.prefill_time(n_tokens))
        self.stats["prefill_chunks"] += 1
        if self.events is not None:
            self.events.append(("prefill", t0, self.vtime, seq.req.rid,
                                n_tokens))
        if self.registry is not None:
            self.registry.histogram("serving/prefill_chunk_s").observe(
                self.vtime - t0)
        if seq.pos >= seq.prompt_len:
            seq.prefill_done = True

    def on_decode(self, seqs, tokens, wall_s: float | None = None) -> None:
        """One decode step completed: `tokens[i]` sampled for seqs[i]."""
        t0 = self.vtime
        modeled = self.plan.decode_step_time(
            len(seqs), sum(s.pos for s in seqs) / len(seqs))
        dt = wall_s if wall_s is not None else modeled
        self.vtime += dt
        self.stats["decode_steps"] += 1
        # measured decode EWMA: the posterior signal the Router's
        # projection consumes (ROADMAP serving follow-up (d)); on the
        # virtual clock dt == modeled and the ratio stays 1.0, so the
        # roofline prior is recovered exactly
        a = self._ewma_alpha
        self.decode_ewma = dt if self.decode_ewma is None \
            else a * dt + (1.0 - a) * self.decode_ewma
        ratio = dt / modeled if modeled > 0 else 1.0
        self.decode_ratio = ratio if self.decode_ratio is None \
            else a * ratio + (1.0 - a) * self.decode_ratio
        if self.events is not None:
            self.events.append(("decode", t0, self.vtime, len(seqs)))
        if self.registry is not None:
            self.registry.gauge("serving/decode_step_s").set(dt)
            self.registry.gauge("serving/decode_batch").set(len(seqs))
        for s, t in zip(seqs, tokens):
            if s.t_first is None:
                s.t_first = self.vtime
            s.out.append(int(t))
            s.pos += 1
            if len(s.out) >= s.req.max_new:
                self._finish(s)

    def _finish(self, seq: _Seq) -> None:
        seq.t_done = self.vtime
        if self.events is not None:
            self.events.append(("finish", self.vtime, seq.req.rid))
        if self.prefix is not None:
            self.prefix.insert(seq.req.prompt, seq.table, self.pool,
                               self.plan.page)
        self._release_seq(seq)
        self.slots[seq.slot] = None
        self.done.append(seq)

    # ------------------------------------------------------------ metrics --
    def finished(self) -> bool:
        return (not self.pending and not self.waiting
                and all(s is None for s in self.slots))

    def metrics(self) -> dict:
        lats = [s.t_done - s.req.arrival for s in self.done]
        firsts = [s.t_first - s.req.arrival for s in self.done]
        toks = sum(len(s.out) for s in self.done)
        out = dict(self.stats)
        out.update(
            requests=len(self.done), gen_tokens=toks,
            virtual_s=self.vtime,
            tok_s=toks / self.vtime if self.vtime else 0.0,
            p50_s=_pct(lats, 50), p99_s=_pct(lats, 99),
            p50_first_s=_pct(firsts, 50), p99_first_s=_pct(firsts, 99),
            arena_util=self.stats["peak_pages"] / self.plan.n_pages,
            prefix_hit_rate=(
                self.stats["prefix_hit_tokens"]
                / max(1, self.stats["prefix_lookup_tokens"])))
        if self.registry is not None:
            r = self.registry
            r.gauge("serving/p50_s").set(out["p50_s"])
            r.gauge("serving/p99_s").set(out["p99_s"])
            r.gauge("serving/prefix_hit_rate").set(out["prefix_hit_rate"])
            r.gauge("serving/arena_util").set(out["arena_util"])
            r.gauge("serving/tok_s").set(out["tok_s"])
        return out


def _pct(xs, q) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, int(round((q / 100) * (len(ys) - 1))))
    return float(ys[i])


def run_virtual(plan: ServePlan, requests, prefix_cache=None,
                gen_token: int = 7, registry=None,
                trace: bool = False) -> ContinuousBatcher:
    """Execute the batcher against a stub executor: no device in the
    loop, every latency priced by the plan's virtual clock — the
    deterministic path the bench assertions and scheduler tests use.
    `registry`/`trace` feed core/obs (metrics + serving_lanes)."""
    b = ContinuousBatcher(plan, prefix_cache=prefix_cache,
                          registry=registry)
    if trace:
        b.enable_trace()
    for r in requests:
        b.submit(r)
    idle = 0
    while not b.finished():
        act = b.next_action()
        if act is None:
            idle += 1
            assert idle < 100_000, "scheduler stalled"
            continue
        idle = 0
        if act[0] == "prefill":
            _, seq, start, toks = act
            b.on_prefill(seq, len(toks))
        else:
            _, seqs = act
            b.on_decode(seqs, [gen_token] * len(seqs))
    return b


# ---------------------------------------------------------------------------
# Static-batch baseline (virtual time): the pre-PR serving loop — admit a
# full batch, prefill everything (padded to the longest prompt, blocking),
# decode until EVERY sequence hits max_new, repeat.
# ---------------------------------------------------------------------------
def static_schedule(plan: ServePlan, requests) -> dict:
    reqs = sorted(requests, key=lambda r: r.arrival)
    vtime = 0.0
    lats, firsts, toks = [], [], 0
    decode_steps = 0
    i = 0
    while i < len(reqs):
        batch = reqs[i:i + plan.max_batch]
        i += len(batch)
        vtime = max(vtime, max(r.arrival for r in batch))
        pad_len = max(len(r.prompt) for r in batch)
        vtime += plan.prefill_time(pad_len * len(batch))
        firsts += [vtime - r.arrival for r in batch]
        steps = max(r.max_new for r in batch)
        for step in range(steps):
            # dense static cache: every slot streams the padded window
            vtime += plan.decode_step_time(len(batch), plan.tmax)
            decode_steps += 1
            for r in batch:
                if step == r.max_new - 1:
                    lats.append(vtime - r.arrival)
                    toks += r.max_new
    return dict(requests=len(reqs), gen_tokens=toks, virtual_s=vtime,
                tok_s=toks / vtime if vtime else 0.0,
                p50_s=_pct(lats, 50), p99_s=_pct(lats, 99),
                p50_first_s=_pct(firsts, 50), p99_first_s=_pct(firsts, 99),
                decode_steps=decode_steps)
