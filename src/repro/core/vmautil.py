"""Helpers for shard_map's varying-manual-axes (vma) type tracking.

Fresh zero-initialized scan carries (recurrent states, accumulators) are
vma-invariant while the values computed from real inputs vary over mesh axes;
lax.scan requires carry types to match exactly. `vary_like` upcasts the
zeros to the union of the reference values' vma (a pure type cast — pcast to
'varying' moves no data)."""

from __future__ import annotations

import jax
from jax import lax


def _vma(x) -> frozenset:
    from repro.core.compat import vma_of

    return vma_of(x)


def vary_to(x, axes):
    need = tuple(a for a in axes if a not in _vma(x))
    return lax.pcast(x, need, to="varying") if need else x


def tree_vma_union(tree) -> frozenset:
    out: frozenset = frozenset()
    for leaf in jax.tree.leaves(tree):
        out |= _vma(leaf)
    return out


def vary_like(tree, ref_tree):
    """Upcast every leaf of `tree` to the vma union of `ref_tree`."""
    axes = tuple(sorted(tree_vma_union(ref_tree)))
    return jax.tree.map(lambda v: vary_to(v, axes), tree)
