"""Pseudo IR nodes: the unit the auto-wrapper reasons about.

TorchInductor hands the paper real IR nodes with module provenance; XLA gives
us no such hook, so we synthesize the equivalent *before lowering*: one
`CommNode` per parameter (its all-gather + matching reduce-scatter) annotated
with the compute that consumes it. Models supply the per-parameter FLOP/byte
estimates via `BlockStats` (their `block_stats()` method); `core/autowrap.py`
runs the paper's greedy Algorithm 1 over this list.

This mirrors the paper's structure faithfully: profiling (SS3.3.2 "Profiling")
is replaced by the analytic model in `core/hw.py` because the container
cannot execute TPU kernels (DESIGN.md SS2 [changed]).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import hw
from repro.core.dist import DistConfig, precision_codecs
from repro.core.meta import ParamMeta, named_leaves
from repro.kernels.quant.ref import QCHUNK, SCALE_BYTES


def wire_bytes(n_elems: int, itemsize: int, codec: str | None = None) -> int:
    """THE place modeled comm bytes come from: the payload one length-n
    buffer occupies on the wire.  Uncompressed (codec=None): n * itemsize.
    Quantized (fp8/int8): one byte per element plus an f32 scale per
    QCHUNK-element group — n + 4*ceil(n/128)."""
    if codec is None:
        return n_elems * itemsize
    return n_elems + SCALE_BYTES * (-(-n_elems // QCHUNK))


@dataclasses.dataclass(frozen=True)
class CommNode:
    """One parameter's collective + the compute it feeds (paper Table 1)."""

    name: str
    ag_bytes: int          # gathered payload (param_dtype, uncompressed)
    rs_bytes: int          # grad reduce-scatter payload (reduce_dtype, ditto)
    comp_flops: float      # T_ci numerator: FLOPs of the consuming compute
    comp_bytes: float      # bytes accessed by the consuming compute
    mem_bytes: float       # M_ci: peak bytes to hold param + its activations
    n_elems: int = 0       # padded element count (0 on hand-built test nodes)

    def ag_wire(self, precision: str = "bf16") -> int:
        """All-gather wire bytes under a resolved comm precision."""
        codec = precision_codecs(precision)[0]
        if codec is None or not self.n_elems:
            return self.ag_bytes
        return wire_bytes(self.n_elems, 0, codec)

    def rs_wire(self, precision: str = "bf16") -> int:
        codec = precision_codecs(precision)[1]
        if codec is None or not self.n_elems:
            return self.rs_bytes
        return wire_bytes(self.n_elems, 0, codec)

    def t_comp(self) -> float:
        return hw.compute_time_s(self.comp_flops, self.comp_bytes)

    def act_out_bytes(self) -> float:
        """Estimated bytes of the intermediate activation(s) the consuming
        op produces — what a saving remat policy would keep live per layer.

        Derived from the same numbers the planners already trust:
        `comp_bytes` counts the op's total traffic (param read + activation
        in/out), so traffic minus the param read is the activation in+out
        volume and half of that is the output.  Exact for the analytic
        dense/MoE models (their per-param bytes are numel*it + flops/d*it),
        proportionally calibrated when BlockStats are measured (the dryrun
        harvest scales param_bytes by the XLA-measured totals)."""
        return max(0.0, self.comp_bytes - self.ag_bytes) / 2.0


@dataclasses.dataclass(frozen=True)
class BlockStats:
    """Per-block workload: {param name: (flops, bytes_accessed)} for the op
    consuming each param, plus activation footprint.

    ``source`` records where the numbers came from:
      * ``"analytic"``  — the hw.py roofline model (models' `block_stats()`),
      * ``"measured"``  — harvested from XLA's ``compiled.cost_analysis()``
        by `launch/dryrun.harvest_block_stats` (totals measured, distributed
        across params in proportion to the analytic shares).
    The planners treat both identically; the dryrun records which one fed a
    plan so perf numbers are attributable.
    """

    param_flops: dict[str, float]
    param_bytes: dict[str, float]
    act_bytes: float = 0.0
    source: str = "analytic"
    # measured per-segment activation footprints (segment name -> bytes),
    # filled by launch/dryrun.harvest_block_stats when it compiles the block
    # segment by segment; the memory simulator prefers these over the
    # per-param activation estimates (None = derive analytically).
    seg_act_bytes: dict[str, float] | None = None

    def cache_key(self) -> tuple:
        """Hashable identity for plan memoization (dict fields break the
        generated __hash__)."""
        return (self.source, self.act_bytes,
                tuple(sorted(self.param_flops.items())),
                tuple(sorted(self.param_bytes.items())),
                tuple(sorted(self.seg_act_bytes.items()))
                if self.seg_act_bytes else None)


def build_nodes(metas_tree, cfg: DistConfig,
                stats: BlockStats | None) -> list[CommNode]:
    """One CommNode per parameter, in declaration (flatten) order."""
    p_item = jnp.dtype(cfg.param_dtype).itemsize
    r_item = jnp.dtype(
        jnp.bfloat16 if cfg.grad_compression else cfg.reduce_dtype).itemsize
    nodes = []
    for name, m in named_leaves(metas_tree):
        assert isinstance(m, ParamMeta)
        n = m.padded_len(cfg)
        flops = stats.param_flops.get(name, 2.0 * n) if stats else 2.0 * n
        bts = stats.param_bytes.get(name, 3.0 * n * p_item) if stats \
            else 3.0 * n * p_item
        nodes.append(CommNode(
            name=name,
            ag_bytes=wire_bytes(n, p_item),
            rs_bytes=wire_bytes(n, r_item),
            comp_flops=flops,
            comp_bytes=bts,
            mem_bytes=n * p_item + (stats.act_bytes if stats else 0.0),
            n_elems=n,
        ))
    return nodes


def ag_time(nodes: list[CommNode], cfg: DistConfig,
            precision: str = "bf16") -> float:
    """alpha + beta*n for ONE bucketed all-gather of these nodes, priced at
    the bucket's resolved wire precision."""
    return hw.collective_time_s(sum(n.ag_wire(precision) for n in nodes),
                                cfg.axis_sizes, cfg.fsdp_axes)


def rs_time(nodes: list[CommNode], cfg: DistConfig,
            precision: str = "bf16") -> float:
    return hw.collective_time_s(sum(n.rs_wire(precision) for n in nodes),
                                cfg.axis_sizes, cfg.fsdp_axes)


# Measured codec throughput (bytes of full-precision input per second)
# PER WIRE CODEC, installed by the dryrun's `harvest_quant_timing` or the
# step profiler's calibration context — a codec absent from the dict means
# the analytic 2x-HBM-pass estimate stands.  fp8 and int8 have identical
# wire bytes (`wire_bytes`), so a measured rate difference is the ONLY
# thing that separates them in the planner lattice (AUTO_PRECISIONS).
_MEASURED_QUANT_RATE: dict[str, float] = {}


def set_measured_quant_rate(rate: float | None,
                            codec: str = "fp8") -> float | None:
    """Install (or clear, with None) the measured quant rate for one
    codec; returns the previous value so callers can restore it."""
    prev = _MEASURED_QUANT_RATE.get(codec)
    if rate is None:
        _MEASURED_QUANT_RATE.pop(codec, None)
    else:
        _MEASURED_QUANT_RATE[codec] = rate
    return prev


def quant_codec_rate(codec: str = "fp8") -> float:
    """Bytes of full-precision buffer one quantize round-trip of `codec`
    processes per second: the measured rate when one was harvested, else
    the analytic prior (2 HBM passes per endpoint = HBM_BANDWIDTH / 2)."""
    meas = _MEASURED_QUANT_RATE.get(codec)
    return meas if meas is not None else hw.HBM_BANDWIDTH / 2.0


def quant_overhead_s(nodes: list[CommNode], precision: str = "bf16") -> float:
    """Encode+decode cost of quantizing a bucket per quantized endpoint.
    Each endpoint is priced at ITS codec's `quant_codec_rate` — the
    analytic prior is one read + one write of the full-precision buffer at
    HBM bandwidth (the Pallas kernels are bandwidth-bound elementwise
    passes); the dryrun/profiler replace that with measured per-codec
    rates (`harvest_quant_timing`), which is what lets the auto lattice
    separate int8 from fp8 at equal wire bytes.  Zero for bf16 — the
    planner's tie-break toward bf16 then falls out of the exposure
    objective itself."""
    ag_codec, rs_codec = precision_codecs(precision)
    t = 0.0
    if ag_codec is not None:
        t += sum(n.ag_bytes for n in nodes) / quant_codec_rate(ag_codec)
    if rs_codec is not None:
        t += sum(n.rs_bytes for n in nodes) / quant_codec_rate(rs_codec)
    return t


def comp_time(nodes: list[CommNode]) -> float:
    return sum(n.t_comp() for n in nodes)
