"""Host offload: the device<->host channel the memory planner can spend.

Two offloadable stores (chosen by `core/memory/planner.plan_memory`):

  * optimizer state (AdamW m/v) — cold between steps, 2x the master param
    bytes; round-trips host once per step;
  * segment-boundary residuals — the per-layer saved block inputs; streamed
    out during forward and prefetched back double-buffered during backward,
    so only the spill over a layer's compute time is exposed (the cost
    model in planner._offload_cost_s).

On TPU runtimes JAX exposes host DRAM as the ``pinned_host`` memory kind
and these helpers place arrays there for real.  This container's CPU
backend has no distinct host memory space, so the helpers probe the
capability once and degrade to identity (the PLAN still records the
offload decision and the simulator still subtracts the bytes — the modeled
numbers are the deliverable on this container, DESIGN.md SS2 [changed]).
"""

from __future__ import annotations

import functools

import jax

HOST_MEMORY_KIND = "pinned_host"
DEVICE_MEMORY_KIND = "device"


@functools.lru_cache(maxsize=1)
def host_offload_supported() -> bool:
    """True when the backend exposes a pinned_host memory space."""
    try:
        dev = jax.devices()[0]
        kinds = getattr(dev, "memory_kinds", None)
        if callable(kinds):
            return HOST_MEMORY_KIND in kinds()
        return any(m.kind == HOST_MEMORY_KIND
                   for m in getattr(dev, "addressable_memories", lambda: [])())
    except Exception:
        return False


def _transfer(tree, kind: str):
    if not host_offload_supported():
        return tree
    try:
        from jax.sharding import SingleDeviceSharding

        dev = jax.devices()[0]
        sh = SingleDeviceSharding(dev, memory_kind=kind)
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)
    except Exception:
        return tree


def to_host(tree):
    """Move a pytree to pinned host memory (identity when unsupported)."""
    return _transfer(tree, HOST_MEMORY_KIND)


def to_device(tree):
    """Move a pytree back to device HBM (identity when unsupported)."""
    return _transfer(tree, DEVICE_MEMORY_KIND)
