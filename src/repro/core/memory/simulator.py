"""Live-range peak-memory simulator: the memory-side twin of
`core/autowrap.exposed_comm_time`.

`exposed_comm_time` walks the executed schedule and integrates TIME that is
not hidden; this module walks the same schedule and takes the max over LIVE
BYTES.  Per (stage, segment, bucket) it accounts:

  * sharded params / grads / optimizer state (the ZeRO-3 storage layout —
    under pp, pre/post groups whose chunks divide by S are pipe-sharded
    1/S slices per rank; only non-divisible groups still pay the
    zero-filled full slot, models/staging.py);
  * gathered buckets in flight: the executed partition (split at segment
    boundaries, segment-major — `bucketing.split_plan_at_segments`, the SAME
    rewrite the stack and the exposure model apply) with
    `core/stack._prefetch_stack`'s double buffering — segment s's gathered
    pool is live together with the pool being prefetched (segment s+1, or
    the next layer's first pool across the layer boundary);
  * saved residuals per remat policy (`core/remat.POLICIES`), per segment:
    `full` keeps the segment input, `save_dots` the dot outputs,
    `fsdp_only` everything but the re-gathered params, `none` additionally
    the gathered params themselves (the paper's no-AC memory cliff);
  * the delayed per-bucket reduce-scatter buffers (`cfg.rs_delay` holds one
    layer's packed grad cotangents across the backward sweep);
  * pipeline in-flight microbatches: GPipe holds M live activation stacks
    per stage, 1F1B (and zb's matching F/Bx slots) bounds stage s to
    min(M, S - s), interleaved counts chunk-granularity entries from its
    actual slot table, and zb adds its params-shaped W-queue
    (core/pipeline.py);
  * context parallelism (core/context.py): every activation-derived term is
    sized from the cp-LOCAL sequence shard (batch_shape carries seq/cp —
    activations divide by the ctx degree), plus the two in-flight ring KV
    buffers of the circulating attention (current block + arriving block);
  * optional host offload (core/memory/offload.py): optimizer state and
    segment-boundary residuals move to host, leaving a double-buffered
    2-layer staging window on device.

Numbers come from the SAME `BlockStats` the bucket planners consume
(analytic roofline by default, XLA-measured via
`launch/dryrun.harvest_block_stats` when available) so "planned" and
"scored" can't drift; `launch/dryrun.harvest_memory_stats` calibrates the
activation model against ``compiled.memory_analysis()`` on a 1-device block.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core.bucketing import (BucketPlan, assign_segments, plan_for,
                                  split_plan_at_segments)
from repro.core.dist import DistConfig
from repro.core.irgraph import BlockStats, build_nodes
from repro.core.meta import named_leaves
from repro.core.remat import (POLICIES, most_aggressive,
                              resolve_segment_policies)

# fraction of a segment's intermediate activations the save_dots policy
# keeps (matmul outputs; elementwise intermediates are recomputed)
SAVE_DOTS_FRAC = 0.5


# ---------------------------------------------------------------------------
# Block profile: the per-layer memory raw material.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SegmentProfile:
    """One block segment's memory/compute summary (whole block if the model
    declares no segments)."""

    name: str
    gather_bytes: float        # gathered params of this segment (param dtype)
    rs_bytes: float            # packed grad cotangents (reduce dtype, full)
    act_bytes: float           # intermediate activations produced inside
    input_bytes: float         # the inter-segment state entering it
    comp_s: float              # forward compute time (hw.py roofline)

    def residency(self, policy: str) -> float:
        """Live bytes this segment contributes per layer under `policy` —
        saved residuals on the vanilla path, backward recompute residency on
        the prefetch path.  Monotone by construction:
        full <= save_dots <= fsdp_only <= none."""
        if policy == "full":
            return self.input_bytes
        if policy == "save_dots":
            return self.input_bytes + SAVE_DOTS_FRAC * self.act_bytes
        if policy == "fsdp_only":
            return self.input_bytes + self.act_bytes
        if policy == "none":
            return self.input_bytes + self.act_bytes + self.gather_bytes
        raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")


@dataclasses.dataclass(frozen=True)
class BlockProfile:
    """Executed-schedule view of ONE layer of the main block stack."""

    segments: tuple[SegmentProfile, ...]
    exec_pools: tuple[float, ...]      # gathered bytes per executed pool
    layer_gather_bytes: float          # one whole layer gathered at once
    layer_rs_bytes: float              # one layer's pending RS buffers
    comp_s: float                      # one layer's forward compute

    def residency(self, policies) -> float:
        return sum(s.residency(p) for s, p in zip(self.segments, policies))

    def gathered_live(self, cfg: DistConfig) -> float:
        """Peak gathered bytes in flight under the executed schedule."""
        if not cfg.reorder:
            return self.layer_gather_bytes       # one gather point per layer
        pools = self.exec_pools
        if len(pools) == 1:
            return 2.0 * pools[0]                # double buffer across layers
        # segment s's pool + the pool being prefetched (cyclic wrap = the
        # next layer's first pool riding the last segment's compute)
        return max(pools[i] + pools[(i + 1) % len(pools)]
                   for i in range(len(pools)))


def main_block_key(metas: dict, stacked_keys: dict) -> str:
    """The stacked group the block profile describes — the one
    `model.block_stats` / `block_segments` talk about."""
    if "blocks" in stacked_keys:
        return "blocks"
    if "dec_blocks" in stacked_keys:
        return "dec_blocks"
    return max(stacked_keys,
               key=lambda k: sum(math.prod(m.global_shape)
                                 for _, m in named_leaves(metas[k])))


def _group_storage_bytes(metas_tree, cfg: DistConfig) -> float:
    """Per-device sharded storage bytes of one (per-layer) group: every
    param's flat padded shard is padded_len/fsdp_size long (TP rows add a
    leading index dim sharded over the TP axis — per-device unchanged)."""
    return sum(
        m.padded_len(cfg) / max(1, cfg.fsdp_size)
        * jnp.dtype(m.dtype).itemsize
        for _, m in named_leaves(metas_tree))


def _group_gather_bytes(metas_tree, cfg: DistConfig) -> float:
    """TP-local gathered bytes of one group (param dtype)."""
    it = jnp.dtype(cfg.param_dtype).itemsize
    return sum(m.numel_local(cfg) * it for _, m in named_leaves(metas_tree))


def storage_bytes(metas: dict, stacked_keys: dict, dcfg: DistConfig,
                  stage=None) -> float:
    """Per-device sharded master-param bytes of the whole model (one pipe
    rank's slot under `stage`): the pipelined stack holds 1/S of its
    layers; single-owner (pre/post) groups whose chunks divide by S are
    pipe-SHARDED — 1/S per rank instead of a full zero-filled slot
    (models/staging.py); only non-divisible groups still pay the
    zero-fill."""
    from repro.core.meta import pipe_shardable

    total = 0.0
    for k in metas:
        g = _group_storage_bytes(metas[k], dcfg)
        if stage is not None and k == stage.pipelined:
            # the per-rank slot: layers_per_stage rows (zero-padded under
            # uneven stage_layers partitions — padding occupies real bytes)
            g *= stage.layers_per_stage
        elif k in stacked_keys:
            g *= stacked_keys[k]
            if stage is not None and isinstance(_owner(stage, k), int) \
                    and pipe_shardable(metas[k], dcfg):
                g /= stage.n_stages
        elif stage is not None and isinstance(_owner(stage, k), int) \
                and pipe_shardable(metas[k], dcfg):
            g /= stage.n_stages
        total += g
    return total


def build_block_profile(metas_tree, cfg: DistConfig,
                        stats: BlockStats | None = None,
                        segments=None,
                        plan: BucketPlan | None = None) -> BlockProfile:
    """Assemble the per-layer profile from the planners' own raw material."""
    from repro.core.irgraph import comp_time

    nodes = build_nodes(metas_tree, cfg, stats)
    names = [n.name for n in nodes]

    if segments is not None and len(segments.fns) > 1:
        seg_of = assign_segments(names, segments.param_globs, segments.names)
        seg_names = tuple(segments.names)
    else:
        seg_of = [0] * len(nodes)
        seg_names = ("block",)

    input_b = float(stats.act_bytes) if stats is not None and \
        stats.act_bytes > 0 else max(
            (n.act_out_bytes() for n in nodes), default=0.0)

    seg_meas = stats.seg_act_bytes if stats is not None else None
    segs = []
    for s, name in enumerate(seg_names):
        sub = [n for n, sg in zip(nodes, seg_of) if sg == s]
        # measured per-segment activation footprint (dryrun's per-segment
        # harvest) wins over the per-param analytic estimate
        act = seg_meas.get(name) if seg_meas else None
        segs.append(SegmentProfile(
            name=name,
            gather_bytes=sum(n.ag_bytes for n in sub),
            rs_bytes=sum(n.rs_bytes for n in sub),
            act_bytes=act if act is not None
            else sum(n.act_out_bytes() for n in sub),
            input_bytes=input_b,
            comp_s=comp_time(sub),
        ))

    if plan is None:
        plan = plan_for(metas_tree, cfg, stats, segments=segments)
    exec_plan = split_plan_at_segments(plan, metas_tree, segments) \
        if segments is not None and len(segments.fns) > 1 else plan
    by_name = {n.name: n for n in nodes}
    pools = tuple(sum(by_name[nm].ag_bytes for nm in grp)
                  for grp in exec_plan.groups)

    return BlockProfile(
        segments=tuple(segs),
        exec_pools=pools,
        layer_gather_bytes=sum(n.ag_bytes for n in nodes),
        layer_rs_bytes=sum(n.rs_bytes for n in nodes),
        comp_s=comp_time(nodes),
    )


# ---------------------------------------------------------------------------
# The simulator proper.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MemoryBreakdown:
    """Modeled per-device peak of ONE pipeline stage, by component."""

    stage: int
    parts: dict                     # component name -> bytes at the peak
    peak_bytes: float
    peak_point: str                 # program point where the peak occurs
    host_bytes: float = 0.0         # moved to host (NOT in peak_bytes)

    def describe(self) -> str:
        gib = 1 / 1024**3
        comps = " ".join(f"{k}={v*gib:.2f}" for k, v in
                         sorted(self.parts.items(), key=lambda kv: -kv[1])
                         if v > 0)
        off = f" host={self.host_bytes*gib:.2f}" if self.host_bytes else ""
        return (f"stage{self.stage}: peak {self.peak_bytes*gib:.2f} GiB "
                f"@{self.peak_point} [{comps}]{off} (GiB)")


def executed_segments(dcfg: DistConfig, segments, policies=None):
    """The (segments, policy vector) the runtime will actually execute.

    `core/stack._prefetch_stack` only applies the segment chain (and a
    per-segment vector) when ``cfg.segment_prefetch`` is on; with it off it
    collapses the vector to its most aggressive entry and gathers per
    whole layer — the simulator and the planner must model THAT schedule,
    not the declared one (the vanilla path executes vectors regardless).
    Returns (segments-or-None, policies-or-None) as executed.
    """
    active = segments is not None and len(segments.fns) > 1
    if active and dcfg.reorder and not dcfg.segment_prefetch:
        return None, ((most_aggressive(policies),)
                      if policies is not None else None)
    return (segments if active else None), \
        (tuple(policies) if policies is not None else None)


def _resolved_schedule(dcfg: DistConfig, virtual: int = 1) -> str:
    """The schedule the memory model walks: a stamped StageSpec.virtual > 1
    means the planner chose interleaved; a still-unresolved 'auto' is
    modeled as 1f1b (the bounded-memory baseline the scorer ties back to)."""
    if virtual > 1 or dcfg.pp_schedule == "interleaved":
        return "interleaved"
    return "1f1b" if dcfg.pp_schedule == "auto" else dcfg.pp_schedule


def in_flight_microbatches(dcfg: DistConfig, stage_idx: int, n_stages: int,
                           microbatches: int, virtual: int = 1) -> int:
    """Live saved-state entries at one stage: GPipe keeps all M microbatch
    stacks, 1F1B (and zb, whose F/Bx slots match 1F1B exactly) bounds stage
    s to min(M, S - s) (core/pipeline.py's ring).  Interleaved counts
    CHUNK-granularity entries from the actual slot table (roughly
    V*min(M, S - s) — each entry covers only layers_per_stage/V layers, so
    multiply by the per-chunk residency, not the per-stage one)."""
    if n_stages <= 1:
        return 1
    M = microbatches or n_stages
    sched = _resolved_schedule(dcfg, virtual)
    if sched == "interleaved":
        from repro.core.pipeline import schedule_peak_state
        v = virtual if virtual > 1 else max(2, dcfg.pp_virtual)
        return schedule_peak_state(M, n_stages, "interleaved", v)[stage_idx]
    if sched in ("1f1b", "zb"):
        return max(1, min(M, n_stages - stage_idx))
    return M


@dataclasses.dataclass(frozen=True)
class SimContext:
    """Everything `context_peaks` needs that does NOT depend on the
    candidate (policy vector / offload flags / act_scale): derived once per
    (model, dcfg, batch shape, bucket plans) and reused across the
    planner's whole candidate sweep."""

    dcfg: DistConfig
    prof: BlockProfile
    default_policies: tuple[str, ...] | None   # None while remat is auto
    params_b: float
    other_gather: float
    extras: tuple[float, ...]          # stage-entry/exit transient per stage
    L_stage: int
    n_stages: int
    microbatches: int
    # context parallelism (core/context.py): in-flight ring buffers, live
    # at every attention segment's peak.  Forward: the KV block being
    # attended plus the one arriving from the previous ctx rank (ppermute
    # double buffering), param dtype.  Backward additionally circulates
    # the travelling dK/dV accumulators in fp32 alongside the KV blocks
    # (the reverse ring), so its residency is strictly larger.  0 without
    # a ctx axis.
    ring_kv_b: float = 0.0          # forward-point in-flight bytes
    ring_kv_bwd_b: float = 0.0      # backward-point in-flight bytes
    # interleaved pipeline: virtual chunks per rank (StageSpec.virtual);
    # saved-state entries are chunk-granular (L_stage/virtual layers each)
    virtual: int = 1


def make_context(model, dcfg: DistConfig, batch_shape,
                 bucket_plans=None, stage=None, microbatches: int = 0,
                 stats: BlockStats | None = None) -> SimContext:
    """Derive the candidate-independent simulation state (the expensive
    part: metas, block profiles, storage accounting)."""
    metas = model.metas(dcfg)
    sk = dict(model.stacked_keys)
    main = main_block_key(metas, sk)
    segments = model.block_segments(dcfg) \
        if hasattr(model, "block_segments") else None
    if stats is None and hasattr(model, "block_stats"):
        stats = model.block_stats(dcfg, batch_shape)
    seg_names = tuple(segments.names) \
        if segments is not None and len(segments.fns) > 1 else ()
    from repro.core.remat import AUTO_PREFIX, parse_remat
    if parse_remat(dcfg.remat)[0] == AUTO_PREFIX:
        # mid-search context: the planner supplies every candidate vector,
        # there is no resolvable default yet
        default = None
        segments, _ = executed_segments(dcfg, segments)
    else:
        default = resolve_segment_policies(dcfg.remat, seg_names)
        # model the schedule the runtime executes (segment_prefetch collapse)
        segments, default = executed_segments(dcfg, segments, default)

    prof = build_block_profile(metas[main], dcfg, stats, segments,
                               (bucket_plans or {}).get(main))
    params_b = storage_bytes(metas, sk, dcfg, stage)
    # other stacked groups: storage counted in params_b; their transient
    # gather (one layer live) rides the same peak point
    other_gather = max(
        (build_block_profile(metas[k], dcfg, None, None,
                             (bucket_plans or {}).get(k))
         .gathered_live(dcfg)
         for k in sk if k != main), default=0.0)

    n_stages = stage.n_stages if stage is not None else 1
    b_mb, seq = batch_shape                 # seq is the cp-LOCAL shard

    # ring attention in flight: current KV block + the arriving one; the
    # backward's reverse ring also carries double-buffered fp32 dK/dV
    # accumulators travelling with the blocks
    ring_kv_b = ring_kv_bwd_b = 0.0
    if dcfg.cp_size > 1:
        from repro.core.context import supports_cp
        acfg = getattr(model, "cfg", None)
        if supports_cp(model) and acfg is not None \
                and getattr(acfg, "head_dim", 0):
            lay = acfg.gqa_layout(dcfg.tp_size)
            kl = max(1, lay["kvp"] // dcfg.tp_size)
            numel = 2.0 * b_mb * seq * kl * acfg.head_dim   # one K+V block
            it = jnp.dtype(dcfg.param_dtype).itemsize
            ring_kv_b = 2.0 * numel * it
            ring_kv_bwd_b = ring_kv_b + 2.0 * numel * 4.0   # + fp32 dK/dV
    extras = []
    for si in range(n_stages):
        # stage-entry / exit extras (transient at the peak point): gathered
        # non-stacked groups this stage touches, plus the f32 logits on the
        # loss-owning stage
        e = 0.0
        for k in metas:
            if k in sk:
                continue
            owner = _owner(stage, k)
            if owner == "all" or owner == si:
                e += _group_gather_bytes(metas[k], dcfg)
        if stage is None or si == n_stages - 1:
            vocab = getattr(model.cfg, "vocab", 0)
            e += b_mb * seq * (vocab / max(1, dcfg.tp_size)) * 4.0
        extras.append(e)

    return SimContext(
        dcfg=dcfg, prof=prof, default_policies=default, params_b=params_b,
        other_gather=other_gather, extras=tuple(extras),
        L_stage=(stage.layers_per_stage if stage is not None else sk[main]),
        n_stages=n_stages, microbatches=microbatches, ring_kv_b=ring_kv_b,
        ring_kv_bwd_b=ring_kv_bwd_b,
        virtual=(getattr(stage, "virtual", 1) if stage is not None else 1))


def context_peaks(ctx: SimContext,
                  policies: tuple[str, ...] | None = None,
                  offload_opt: bool = False,
                  offload_residuals: bool = False,
                  act_scale: float = 1.0) -> list[MemoryBreakdown]:
    """The candidate-dependent arithmetic: per-stage peak for one
    (policy vector, offload, act_scale) candidate over a `SimContext`."""
    dcfg, prof = ctx.dcfg, ctx.prof
    if policies is None:
        if ctx.default_policies is None:
            raise ValueError(
                f"remat={dcfg.remat!r} has no default policy vector; pass "
                "policies= explicitly (the auto form is resolved by the "
                "planner)")
        policies = ctx.default_policies
    elif dcfg.reorder and not dcfg.segment_prefetch \
            and len(policies) != len(prof.segments):
        from repro.core.remat import most_aggressive
        policies = (most_aggressive(policies),)
    if len(policies) != len(prof.segments):
        raise ValueError(
            f"policy vector {policies} does not match the executed "
            f"{len(prof.segments)} segment(s) "
            f"{tuple(s.name for s in prof.segments)}")

    # ---- storage-resident state (near-identical on every pipe rank:
    # pre/post groups are pipe-sharded 1/S slices where chunks divide,
    # zero-filled full slots otherwise — models/staging.py) ----
    params_b = ctx.params_b
    grads_b = params_b
    opt_b = 2.0 * params_b
    if dcfg.needs_ef:
        # quantized-RS error-feedback accumulator (optim/adamw): one more
        # storage-shaped tree, held in fp32 regardless of param dtype
        opt_b += params_b * (4.0 / jnp.dtype(dcfg.param_dtype).itemsize)

    # zb decouples the weight-grad half of each backward and queues the
    # per-microbatch dW cotangent pytrees until their fill slots drain
    # them into the accumulator (core/pipeline.py's W-queue) — a real
    # params-shaped buffer per queued entry
    w_queue_b = 0.0
    if ctx.n_stages > 1 and \
            _resolved_schedule(dcfg, ctx.virtual) == "zb":
        from repro.core.pipeline import zb_queue_depth
        w_queue_b = zb_queue_depth(ctx.microbatches or ctx.n_stages,
                                   ctx.n_stages) * params_b

    # ---- per-layer terms ----
    reorder = bool(dcfg.reorder)
    residency = act_scale * prof.residency(policies)
    per_layer_saved = act_scale * prof.segments[0].input_bytes \
        if reorder else residency
    gathered = prof.gathered_live(dcfg)
    pending_rs = prof.layer_rs_bytes if (reorder and dcfg.rs_delay) else 0.0
    workspace = residency if reorder else 0.0

    # quantized collectives (kernels/quant): per-QCHUNK(=128-elem) fp32
    # scale buffers live alongside the packed payload while it is in
    # flight — 4B per 128 elems of a 2B payload = payload/64
    scales_fwd = scales_bwd = 0.0
    if dcfg.comm_precision != "bf16":
        scales_fwd = gathered / 64.0
        scales_bwd = (gathered + pending_rs) / 64.0

    # interleaved saved-state entries are chunk-granular: each covers only
    # L_stage/virtual layers (in_flight_microbatches counts entries)
    layers_per_entry = ctx.L_stage // max(1, ctx.virtual)

    out = []
    for si in range(ctx.n_stages):
        inflight = in_flight_microbatches(dcfg, si, ctx.n_stages,
                                          ctx.microbatches, ctx.virtual)
        saved = layers_per_entry * per_layer_saved * inflight

        host = 0.0
        if offload_opt:
            host += opt_b
            opt_dev = 0.0
        else:
            opt_dev = opt_b
        if offload_residuals:
            # segment-boundary residuals (the per-layer inputs) stream to
            # host; a double-buffered 2-layer staging window stays on device
            boundary = layers_per_entry * act_scale \
                * prof.segments[0].input_bytes * inflight
            boundary = min(boundary, saved)
            keep = min(boundary, 2.0 * act_scale
                       * prof.segments[0].input_bytes)
            host += boundary - keep
            saved = saved - boundary + keep

        candidates = {
            "forward": {
                "params": params_b, "opt_state": opt_dev,
                "saved_residuals": saved, "gathered": gathered,
                "other_stacks": ctx.other_gather,
                "stage_extras": ctx.extras[si],
                "ring_kv": ctx.ring_kv_b,
                "quant_scales": scales_fwd,
            },
            "backward": {
                "params": params_b, "grads": grads_b, "opt_state": opt_dev,
                "saved_residuals": saved, "gathered": gathered,
                "pending_rs": pending_rs, "workspace": workspace,
                "other_stacks": ctx.other_gather,
                "stage_extras": ctx.extras[si],
                "ring_kv": ctx.ring_kv_bwd_b,
                "w_queue": w_queue_b,
                "quant_scales": scales_bwd,
            },
        }
        point, parts = max(candidates.items(),
                           key=lambda kv: sum(kv[1].values()))
        out.append(MemoryBreakdown(
            stage=si, parts=parts, peak_bytes=sum(parts.values()),
            peak_point=point, host_bytes=host))
    return out


def simulate_peak(model, dcfg: DistConfig, batch_shape,
                  policies: tuple[str, ...] | None = None,
                  bucket_plans=None, stage=None, microbatches: int = 0,
                  stats: BlockStats | None = None,
                  offload_opt: bool = False,
                  offload_residuals: bool = False,
                  act_scale: float = 1.0) -> list[MemoryBreakdown]:
    """Walk the executed schedule and return the modeled per-device peak of
    every pipeline stage (one entry at pp=1).

    `policies` is the per-segment remat vector for the main block stack
    (resolved from ``dcfg.remat`` when omitted); `act_scale` is the
    calibration factor from `launch/dryrun.harvest_memory_stats` (scales
    every activation-derived term, 1.0 = pure analytic model).  One-shot
    convenience over `make_context` + `context_peaks` — sweeps (the
    planner) build the context once and iterate the arithmetic."""
    ctx = make_context(model, dcfg, batch_shape, bucket_plans=bucket_plans,
                       stage=stage, microbatches=microbatches, stats=stats)
    return context_peaks(ctx, policies=policies, offload_opt=offload_opt,
                         offload_residuals=offload_residuals,
                         act_scale=act_scale)


def _owner(stage, key: str):
    """StageSpec.owner with the pp=1 convention (everything on stage 0 and
    the last stage at once)."""
    if stage is None:
        return "all"
    try:
        return stage.owner(key)
    except KeyError:
        return "all"
