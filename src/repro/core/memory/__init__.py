"""Memory subsystem: live-range peak simulation + budgeted auto-SAC.

  simulator   walk the executed schedule, take max over live bytes —
              the memory-side twin of autowrap's exposure walk
  planner     ``remat="auto:<GB>"`` -> per-segment policy vector (+ offload
              + joint bucket retightening) under an explicit HBM budget
  offload     host DRAM channel (pinned_host when the backend has it)

Resolved once per (model, dcfg, shape) by `core/api.plan_parallel` into the
frozen `MemoryPlan` on the `ParallelPlan`.
"""

from repro.core.memory.planner import (MemoryPlan, RECOMPUTE_W,
                                       auto_microbatches, plan_cost_s,
                                       plan_memory)
from repro.core.memory.simulator import (BlockProfile, MemoryBreakdown,
                                         SegmentProfile, SimContext,
                                         build_block_profile, context_peaks,
                                         executed_segments,
                                         in_flight_microbatches,
                                         main_block_key, make_context,
                                         simulate_peak, storage_bytes)
from repro.core.memory.offload import (host_offload_supported, to_device,
                                       to_host)

__all__ = [
    "BlockProfile", "MemoryBreakdown", "MemoryPlan", "RECOMPUTE_W",
    "SegmentProfile", "SimContext", "auto_microbatches",
    "build_block_profile", "context_peaks",
    "executed_segments", "host_offload_supported",
    "in_flight_microbatches", "main_block_key", "make_context",
    "plan_cost_s", "plan_memory", "simulate_peak", "storage_bytes",
    "to_device", "to_host",
]
