"""Budgeted auto-SAC planner: ``dcfg.remat="auto:<GB>"``.

Chooses, under an explicit per-device HBM budget, the cheapest combination
of

  * a per-segment remat policy vector over `core/remat.POLICIES` (the
    paper's selective-AC knob, at segment rather than whole-block
    granularity),
  * optional host offload of optimizer state and segment-boundary residuals
    (double-buffered device<->host copies, core/memory/offload.py), and
  * the bucket partition of the main block stack — tighter buckets shrink
    the gathered peak but pay more collective alpha/exposure, so the search
    evaluates bucket candidates jointly with the policy vector against the
    SAME exposure objective the PR-2 bucket DP optimizes
    (`core/autowrap.exposed_comm_time`),

minimizing the modeled recompute + exposed-communication + offload-traffic
cost per step, subject to `simulate_peak` <= budget on EVERY pipeline
stage.  DeepCompile (arXiv 2504.09983) motivates compiler-chosen
recompute/offload over hand-set global policies; "Memory and Bandwidth are
All You Need for FSDP" motivates peak-memory modeling as the selector.

The chosen vector is written back as the resolved `dcfg.remat` string (the
vector grammar of `core/remat.parse_policy_vector`), so the runtime applies
exactly what was planned — `core/api.ParallelPlan.exec_dcfg`.
"""

from __future__ import annotations

import dataclasses
import itertools

from repro.core import hw
from repro.core.bucketing import (BucketPlan, per_param_plan, plan_for)
from repro.core.dist import DistConfig
from repro.core.irgraph import BlockStats
from repro.core.memory.simulator import (BlockProfile, MemoryBreakdown,
                                         build_block_profile, context_peaks,
                                         executed_segments, main_block_key,
                                         make_context)
from repro.core.remat import (AUTO_PREFIX, POLICIES, parse_remat,
                              resolve_segment_policies)

# modeled recompute weight per policy: the fraction of a segment's forward
# compute the backward pays again. fsdp_only re-gathers (comm, mostly
# hidden) plus cheap unpack work; save_dots redoes the elementwise tail;
# full redoes the whole segment forward.
RECOMPUTE_W = {"none": 0.0, "fsdp_only": 0.10, "save_dots": 0.35,
               "full": 1.0}


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    """Frozen memory-side decisions for one (model, dcfg, shape) — carried
    by `core/api.ParallelPlan.memory`."""

    main_key: str                       # the stacked group the vector wraps
    segment_names: tuple[str, ...]
    policies: tuple[str, ...]           # one per segment (len 1 when unsegmented)
    policy_spec: str                    # resolved dcfg.remat string form
    offload_opt_state: bool
    offload_residuals: bool
    budget_bytes: float | None          # None for fixed (non-auto) specs
    peak_bytes: tuple[float, ...]       # modeled per-device peak per stage
    cost_s: float                       # recompute+exposure+offload per step
    bucket_plan: BucketPlan | None      # override for main_key (None = keep)
    breakdown: tuple                    # MemoryBreakdown per stage

    @property
    def peak(self) -> float:
        return max(self.peak_bytes)

    def describe(self) -> str:
        gib = 1 / 1024**3
        pol = self.policy_spec
        off = "".join([",+opt_offload" if self.offload_opt_state else "",
                       ",+res_offload" if self.offload_residuals else ""])
        bud = (f" budget={self.budget_bytes*gib:.2f}GiB"
               if self.budget_bytes else "")
        return (f"remat[{pol}{off}]{bud} peak="
                f"{self.peak*gib:.2f}GiB cost={self.cost_s*1e3:.2f}ms")


def _policy_spec(policies: tuple[str, ...], seg_names) -> str:
    if len(set(policies)) == 1:
        return policies[0]
    if seg_names and seg_names != ("block",) \
            and len(seg_names) == len(policies):
        return ",".join(f"{n}={p}" for n, p in zip(seg_names, policies))
    return ",".join(policies)


def _policy_vectors(n_seg: int):
    """Candidate vectors, exhaustive when small. For very segment-rich
    blocks fall back to two-policy prefix mixes (which still cover every
    uniform vector), deduplicated."""
    if 4 ** n_seg <= 4096:
        yield from itertools.product(POLICIES, repeat=n_seg)
        return
    seen = set()
    for a in POLICIES:
        for b in POLICIES:
            for k in range(n_seg + 1):
                v = (a,) * k + (b,) * (n_seg - k)
                if v not in seen:
                    seen.add(v)
                    yield v


def _exposure_s(plan: BucketPlan, metas_tree, cfg, stats, segments) -> float:
    from repro.core.autowrap import exposed_comm_time

    return exposed_comm_time(plan, metas_tree, cfg, stats,
                             segments=segments)["exposed_s"]


def _offload_cost_s(prof: BlockProfile, L_total: int, opt_bytes: float,
                    offload_opt: bool, offload_res: bool) -> float:
    """Per-step exposed transfer time of the host-offload channel.

    Optimizer state crosses twice per step (out after the update, back in
    before the next); residual copies are double-buffered per layer and
    only their spill over the layer's compute time is exposed."""
    t = 0.0
    if offload_opt:
        t += hw.HOST_DMA_ALPHA_S + 2.0 * opt_bytes / hw.HOST_DMA_BW
    if offload_res:
        per_layer = 2.0 * prof.segments[0].input_bytes / hw.HOST_DMA_BW
        t += L_total * max(0.0, per_layer - prof.comp_s) \
            + L_total * hw.HOST_DMA_ALPHA_S
    return t


def plan_cost_s(prof: BlockProfile, policies, L_total: int,
                exposure_s: float, opt_bytes: float = 0.0,
                offload_opt: bool = False,
                offload_res: bool = False) -> float:
    """Modeled per-step cost of one candidate: backward recompute per the
    policy vector + steady-state exposed communication of the bucket
    partition + exposed offload traffic.  Relative metric — the planner's
    objective, also logged for cross-PR tracking."""
    recompute = sum(RECOMPUTE_W[p] * s.comp_s
                    for s, p in zip(prof.segments, policies))
    return L_total * (recompute + exposure_s) \
        + _offload_cost_s(prof, L_total, opt_bytes, offload_opt, offload_res)


def _batch_shape_for(dcfg: DistConfig, shape, microbatches: int):
    # rows shard over batch_dp, the sequence over the ctx axis — the
    # simulator's activation terms see the true per-device token count
    b_local = max(1, shape.global_batch // max(1, dcfg.batch_dp))
    mb = microbatches or dcfg.microbatches or 1
    return (max(1, b_local // max(1, mb)),
            shape.seq_len // max(1, dcfg.cp_size))


def auto_microbatches(model, dcfg: DistConfig, shape,
                      budget: float | None = None, stage=None,
                      act_scale: float | None = None) -> int:
    """Smallest microbatch count whose modeled per-device peak fits
    `budget` (HBM by default) — the simulator's stage peaks replacing the
    hand-kept dry-run MICROBATCH table (consumed by
    `launch.mesh.production_dcfg_for` and `launch/dryrun.run_cell`).

    Candidates are DIVISORS of the per-device row count, ascending — the
    train step reshapes rows into equal microbatches, so a non-divisor
    pick would fail at first trace.  Without a pipeline `stage` the count
    is gradient accumulation; with one it is the pipeline M itself
    (candidates start at the stage count, and each candidate is simulated
    with THAT M in flight — GPipe holds all M live stacks, so modeling a
    smaller M than executed would understate the very peak this rule
    guards).  Returns the deepest split when even it does not fit (the
    dry-run's fits-HBM check reports the overflow).

    `act_scale` is the measured calibration factor from
    `launch/dryrun.harvest_memory_stats`; when the caller has no
    measurement (pure-analytic contexts) the pick defaults to the
    calibration clamp ceiling (4.0 — XLA's real residual footprint runs
    well above the analytic estimate, and an optimistic split here turns
    into an OOM at run time while a pessimistic one only costs a few
    accumulation steps).  An unresolved ``remat='auto:<GB>'`` is evaluated
    at the default 'fsdp_only' policy — the budgeted SAC planner refines
    the policy afterwards, this only sizes the batch split."""
    from repro.core.memory.simulator import simulate_peak
    from repro.core.remat import AUTO_PREFIX, parse_remat

    if not hasattr(model, "block_stats"):
        return 1
    budget = budget or hw.HBM_BYTES
    act_scale = 4.0 if act_scale is None else act_scale
    if parse_remat(dcfg.remat)[0] == AUTO_PREFIX:
        dcfg = dcfg.with_(remat="fsdp_only")
    b_local = max(1, shape.global_batch // max(1, dcfg.batch_dp))
    floor = stage.n_stages if stage is not None else 1
    cands = [d for d in range(1, b_local + 1)
             if b_local % d == 0 and d >= floor] or [b_local]
    for mb in cands:
        bshape = _batch_shape_for(dcfg, shape, mb)
        peaks = simulate_peak(model, dcfg, bshape, stage=stage,
                              microbatches=(mb if stage is not None else 0),
                              act_scale=act_scale)
        if max(b.peak_bytes for b in peaks) <= budget:
            return mb
    return cands[-1]


def plan_memory(model, dcfg: DistConfig, shape=None, bucket_plans=None,
                stage=None, microbatches: int = 0,
                stats: BlockStats | None = None,
                batch_shape=None, act_scale: float = 1.0) -> MemoryPlan:
    """Resolve ``dcfg.remat`` into a frozen `MemoryPlan`.

    Fixed specs (a POLICIES entry or an explicit vector) are simulated and
    recorded as-is; ``"auto:<GB>"`` runs the budgeted search.  Raises a
    pointed ValueError when no candidate fits the budget, naming the budget,
    the offending stage and the residual components."""
    kind, budget = parse_remat(dcfg.remat)
    if batch_shape is None:
        if shape is None:
            raise ValueError(
                f"remat={dcfg.remat!r}: plan_memory needs the workload "
                "shape to size activations; pass shape= (ShapeConfig) to "
                "plan_parallel/parallelize or batch_shape= here")
        batch_shape = _batch_shape_for(dcfg, shape, microbatches)

    metas = model.metas(dcfg)
    sk = dict(model.stacked_keys)
    main = main_block_key(metas, sk)
    declared = model.block_segments(dcfg) \
        if hasattr(model, "block_segments") else None
    declared_names = tuple(declared.names) \
        if declared is not None and len(declared.fns) > 1 else ()
    # plan over the EXECUTED schedule: with segment_prefetch off the
    # prefetch runtime collapses any vector to one whole-layer policy, so
    # the search space and the profile must collapse with it
    segments, _ = executed_segments(dcfg, declared)
    seg_names = tuple(segments.names) if segments is not None else ("block",)
    if stats is None and hasattr(model, "block_stats"):
        stats = model.block_stats(dcfg, batch_shape)
    L_total = sk[main]
    base_plan = (bucket_plans or {}).get(main) \
        or plan_for(metas[main], dcfg, stats, segments=segments)

    from repro.core.memory.simulator import storage_bytes
    opt_bytes = 2.0 * storage_bytes(metas, sk, dcfg, stage)

    def context_for(plan):
        """Candidate-independent simulation state per bucket plan — the
        expensive derivation, hoisted out of the search loops (the inner
        sweep is pure arithmetic via `context_peaks`)."""
        plans = dict(bucket_plans or {})
        plans[main] = plan
        ctx = make_context(model, dcfg, batch_shape, bucket_plans=plans,
                           stage=stage, microbatches=microbatches,
                           stats=stats)
        exp = _exposure_s(plan, metas[main], dcfg, stats, segments)
        return ctx, exp

    def simulate(ctx, policies, off_opt, off_res):
        return context_peaks(ctx, policies=policies, offload_opt=off_opt,
                             offload_residuals=off_res,
                             act_scale=act_scale)

    def build(policies, ctx, exp, off_opt, off_res, bk, override):
        cost = plan_cost_s(ctx.prof, policies, L_total, exp, opt_bytes,
                           off_opt, off_res)
        return MemoryPlan(
            main_key=main, segment_names=seg_names,
            policies=tuple(policies),
            policy_spec=_policy_spec(tuple(policies), seg_names),
            offload_opt_state=off_opt, offload_residuals=off_res,
            budget_bytes=budget,
            peak_bytes=tuple(b.peak_bytes for b in bk),
            cost_s=cost, bucket_plan=override, breakdown=tuple(bk))

    if kind != AUTO_PREFIX:
        policies = resolve_segment_policies(dcfg.remat, declared_names)
        _, policies = executed_segments(dcfg, declared, policies)
        ctx, exp = context_for(base_plan)
        bk = simulate(ctx, policies, False, False)
        return build(policies, ctx, exp, False, False, bk, None)

    # ---------------- the budgeted search ----------------
    # bucket candidates: the resolved plan, plus (joint with the bucket DP)
    # tighter-cap replans and the per-param partition — smaller gathered
    # peak, more alpha/exposure. Overridable only when the model has a
    # single main stack to retarget.
    bucket_cands: list[tuple[BucketPlan, BucketPlan | None]] = [
        (base_plan, None)]
    if len(sk) == 1:
        if dcfg.bucket_mode in ("auto", "auto_dp"):
            for frac in (0.25, 0.0625):
                tight = dcfg.with_(
                    autowrap_mem_limit=dcfg.autowrap_mem_limit * frac)
                p = plan_for(metas[main], tight, stats, segments=segments)
                if p.groups != base_plan.groups:
                    bucket_cands.append((p, p))
        solo = per_param_plan(metas[main])
        if solo.groups != base_plan.groups:
            bucket_cands.append((solo, solo))

    offload_cands = ((False, False), (True, False), (False, True),
                     (True, True))

    best = None          # (cost, peak, MemoryPlan)
    tightest = None      # (peak, breakdown) of the most frugal candidate
    for plan, override in bucket_cands:
        ctx, exp = context_for(plan)             # per bucket plan, hoisted
        for policies in _policy_vectors(len(seg_names)):
            for off_opt, off_res in offload_cands:
                bk = simulate(ctx, policies, off_opt, off_res)
                peak = max(b.peak_bytes for b in bk)
                if tightest is None or peak < tightest[0]:
                    tightest = (peak, bk)
                if peak > budget:
                    continue
                cand = build(policies, ctx, exp, off_opt, off_res, bk,
                             override)
                key = (cand.cost_s, peak)
                if best is None or key < best[0]:
                    best = (key, cand)
    if best is None:
        peak, bk = tightest
        worst = max(bk, key=lambda b: b.peak_bytes)
        gib = 1 / 1024**3
        raise ValueError(
            f"remat={dcfg.remat!r}: no plan fits the {budget*gib:.2f} GiB "
            f"budget for {type(model).__name__}"
            f"[{getattr(model.cfg, 'name', '?')}] — the most frugal "
            f"candidate (full remat + offload + per-param buckets) still "
            f"peaks at {peak*gib:.2f} GiB on stage {worst.stage} "
            f"({worst.describe()}); raise the budget, shrink the "
            f"microbatch, or add parallelism")
    return best[1]
