"""Production mesh definitions (TPU v5e).

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); the 'pod' axis
crosses DCN. Defined as a FUNCTION so importing this module never touches
jax device state (the dry-run pins a fake 512-device platform first).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dist import DistConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def production_dcfg(*, multi_pod: bool = False, zero3_global: bool = False,
                    **overrides) -> DistConfig:
    """bf16 training config on the production mesh. Default multi-pod
    sharding is HSDP (shard in-pod, replicate across pods — bounded DCN
    traffic); zero3_global shards over pod x data instead."""
    if multi_pod:
        base = dict(
            mesh_axes=("pod", "data", "model"), mesh_shape=(2, 16, 16),
            fsdp_axes=("pod", "data") if zero3_global else ("data",),
        )
    else:
        base = dict(mesh_axes=("data", "model"), mesh_shape=(16, 16),
                    fsdp_axes=("data",))
    base.update(
        param_dtype=jnp.bfloat16, reduce_dtype=jnp.float32,
        storage_dtype=jnp.float32,
    )
    base.update(overrides)
    return DistConfig(**base)
