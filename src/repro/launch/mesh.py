"""Production mesh definitions (TPU v5e).

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); the 'pod' axis
crosses DCN. With pipeline parallelism the 'pipe' axis is carved out of the
data axis and placed OUTERMOST (per-slot pipeline traffic is one small
point-to-point activation send, so it tolerates the slowest interconnect,
while FSDP gathers and TP psums stay on the inner ICI axes — see
core/pipeline.py for the layout convention). With context parallelism the
'ctx' axis is also carved out of the data axis and sits BETWEEN data and
model: its per-hop ring ppermute traffic (one KV block per layer per hop,
core/context.py) is lighter than the fat FSDP all-gathers riding 'data' but
heavier than pipeline sends, while the highest-frequency TP psums keep the
innermost axis.  The ctx axis joins `fsdp_axes` (parameters shard over
data x ctx) so every cross-ctx gradient is an explicit collective. Defined
as FUNCTIONS so importing this module never touches jax device state (the
dry-run pins a fake 512-device platform first).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import compat
from repro.core.dist import DistConfig


def _production_layout(multi_pod: bool, pipeline_stages: int,
                       context_degree: int = 1):
    inner = pipeline_stages * context_degree
    if inner > 1 and 16 % inner:
        raise ValueError(
            f"pipeline_stages={pipeline_stages} x context_degree="
            f"{context_degree} must divide the 16-chip data axis")
    data = 16 // inner
    shape: tuple[int, ...] = (data,)
    axes: tuple[str, ...] = ("data",)
    if context_degree > 1:
        shape = shape + (context_degree,)
        axes = axes + ("ctx",)
    shape, axes = shape + (16,), axes + ("model",)
    if multi_pod:
        shape, axes = (2,) + shape, ("pod",) + axes
    if pipeline_stages > 1:
        shape, axes = (pipeline_stages,) + shape, ("pipe",) + axes
    return shape, axes


def make_production_mesh(*, multi_pod: bool = False,
                         pipeline_stages: int = 1,
                         context_degree: int = 1):
    shape, axes = _production_layout(multi_pod, pipeline_stages,
                                     context_degree)
    return compat.make_mesh(shape, axes)


def production_dcfg(*, multi_pod: bool = False, zero3_global: bool = False,
                    pipeline_stages: int = 1, pp_schedule: str = "auto",
                    context_degree: int = 1, **overrides) -> DistConfig:
    """bf16 training config on the production mesh. Default multi-pod
    sharding is HSDP (shard in-pod, replicate across pods — bounded DCN
    traffic); zero3_global shards over pod x data instead.
    pipeline_stages > 1 adds an outermost 'pipe' axis ('auto' by default:
    plan_parallel scores gpipe/1f1b/interleaved/zb by modeled bubble
    fraction and picks the argmin, see core/pipeline.py + core/api.py);
    context_degree > 1 adds the 'ctx' axis between data and model (ring
    attention, core/context.py) and folds it into the FSDP domain."""
    shape, axes = _production_layout(multi_pod, pipeline_stages,
                                     context_degree)
    fsdp = ("pod", "data") if (multi_pod and zero3_global) else ("data",)
    if context_degree > 1:
        fsdp = fsdp + ("ctx",)
    base = dict(
        mesh_axes=axes, mesh_shape=shape, fsdp_axes=fsdp,
        param_dtype=jnp.bfloat16, reduce_dtype=jnp.float32,
        storage_dtype=jnp.float32,
    )
    if pipeline_stages > 1:
        base.update(pp_axis="pipe", pp_schedule=pp_schedule)
    if context_degree > 1:
        base.update(cp_axis="ctx")
    base.update(overrides)
    return DistConfig(**base)


def production_dcfg_for(arch_cfg, *, shape=None, model=None,
                        **kw) -> DistConfig:
    """Production DistConfig honouring the arch's recommended pipeline
    degree (`ArchConfig.pp_stages`): validates that stages split the layer
    stack evenly before carving the pipe axis out of the data axis.

    When the workload `shape` (models/common.ShapeConfig) and the `model`
    instance are given, the gradient-accumulation microbatch count is
    picked automatically from the memory simulator's stage peaks
    (core/memory.auto_microbatches — the modeled-peak-fits-HBM rule that
    replaced the dry-run's hand-kept MICROBATCH table)."""
    stages = arch_cfg.pp_stages
    if stages > 1 and arch_cfg.n_layers % stages:
        raise ValueError(
            f"{arch_cfg.name}: pp_stages={stages} does not divide "
            f"n_layers={arch_cfg.n_layers}")
    dcfg = production_dcfg(pipeline_stages=stages, **kw)
    if shape is not None and model is not None and shape.kind == "train":
        from repro.core.memory import auto_microbatches
        stage = model.stage_spec(stages) if stages > 1 else None
        # the pick is a DIVISOR of the per-device rows (the step reshapes
        # rows into equal microbatches) and, under pp, the pipeline M
        # itself — simulated with that M in flight (GPipe holds all M)
        mb = auto_microbatches(model, dcfg, shape, stage=stage)
        if stages > 1:
            dcfg = dcfg.with_(pp_microbatches=mb)
        elif mb > 1:
            dcfg = dcfg.with_(microbatches=mb)
    return dcfg
