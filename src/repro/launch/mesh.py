"""Production mesh definitions (TPU v5e).

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); the 'pod' axis
crosses DCN. With pipeline parallelism the 'pipe' axis is carved out of the
data axis and placed OUTERMOST (per-slot pipeline traffic is one small
point-to-point activation send, so it tolerates the slowest interconnect,
while FSDP gathers and TP psums stay on the inner ICI axes — see
core/pipeline.py for the layout convention). Defined as FUNCTIONS so
importing this module never touches jax device state (the dry-run pins a
fake 512-device platform first).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import compat
from repro.core.dist import DistConfig


def _production_layout(multi_pod: bool, pipeline_stages: int):
    if pipeline_stages > 1:
        if 16 % pipeline_stages:
            raise ValueError(
                f"pipeline_stages={pipeline_stages} must divide the 16-chip "
                "data axis")
        data = 16 // pipeline_stages
        if multi_pod:
            return (pipeline_stages, 2, data, 16), \
                ("pipe", "pod", "data", "model")
        return (pipeline_stages, data, 16), ("pipe", "data", "model")
    if multi_pod:
        return (2, 16, 16), ("pod", "data", "model")
    return (16, 16), ("data", "model")


def make_production_mesh(*, multi_pod: bool = False,
                         pipeline_stages: int = 1):
    shape, axes = _production_layout(multi_pod, pipeline_stages)
    return compat.make_mesh(shape, axes)


def production_dcfg(*, multi_pod: bool = False, zero3_global: bool = False,
                    pipeline_stages: int = 1, pp_schedule: str = "1f1b",
                    **overrides) -> DistConfig:
    """bf16 training config on the production mesh. Default multi-pod
    sharding is HSDP (shard in-pod, replicate across pods — bounded DCN
    traffic); zero3_global shards over pod x data instead.
    pipeline_stages > 1 adds an outermost 'pipe' axis (1F1B by default —
    live activations bounded by the stage count, see core/pipeline.py)."""
    shape, axes = _production_layout(multi_pod, pipeline_stages)
    base = dict(
        mesh_axes=axes, mesh_shape=shape,
        fsdp_axes=("pod", "data") if (multi_pod and zero3_global)
        else ("data",),
        param_dtype=jnp.bfloat16, reduce_dtype=jnp.float32,
        storage_dtype=jnp.float32,
    )
    if pipeline_stages > 1:
        base.update(pp_axis="pipe", pp_schedule=pp_schedule)
    base.update(overrides)
    return DistConfig(**base)


def production_dcfg_for(arch_cfg, **kw) -> DistConfig:
    """Production DistConfig honouring the arch's recommended pipeline
    degree (`ArchConfig.pp_stages`): validates that stages split the layer
    stack evenly before carving the pipe axis out of the data axis."""
    stages = arch_cfg.pp_stages
    if stages > 1 and arch_cfg.n_layers % stages:
        raise ValueError(
            f"{arch_cfg.name}: pp_stages={stages} does not divide "
            f"n_layers={arch_cfg.n_layers}")
    return production_dcfg(pipeline_stages=stages, **kw)
