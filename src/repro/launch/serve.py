"""Serving launcher CLI: prefill a synthetic batch, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --smoke \
      --devices 8 --mesh 2,4 --gen 16
"""

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--mesh", default="2,4")
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="append a metrics-registry snapshot (compile + "
                         "steady prefill/decode timings) here (core/obs)")
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", ""))

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.dist import DistConfig
    from repro.models import runtime as RT
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch
    from repro.train import serve as SV

    mesh_shape = tuple(int(x) for x in args.mesh.split(","))
    dcfg = DistConfig(mesh_axes=("data", "model"), mesh_shape=mesh_shape,
                      param_dtype=jnp.float32, reduce_dtype=jnp.float32,
                      kv_cache_int8=args.int8_kv)
    cfg, model = get_arch(args.arch, smoke=args.smoke)
    T = args.prompt_len + args.gen
    storage = RT.init_storage(model, jax.random.PRNGKey(0), dcfg)
    params = SV.serve_params_from_storage(model, storage, dcfg)
    prefill, mesh = SV.make_prefill_step(
        model, dcfg, ShapeConfig("p", T, args.batch, "prefill"))
    decode, _ = SV.make_decode_step(
        model, dcfg, ShapeConfig("d", T, args.batch, "decode"), mesh=mesh)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 3, cfg.vocab)
    padded = jnp.pad(prompts, ((0, 0), (0, args.gen)), constant_values=3)

    # warm-up iteration first: the initial call pays XLA compilation, so
    # it is timed separately and kept out of the steady-state window
    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": padded})
    jax.block_until_ready(logits)
    t_pf_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": padded})
    jax.block_until_ready(logits)
    t_pf = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [np.asarray(tok)]
    # per-request positions: every row advances independently (ragged
    # batches under continuous batching); here all start equal
    pos = jnp.full((args.batch,), args.prompt_len, jnp.int32)
    t0 = time.perf_counter()
    logits, cache = decode(params, cache, tok, pos)
    jax.block_until_ready(logits)
    t_dec_compile = time.perf_counter() - t0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs.append(np.asarray(tok))
    t0 = time.perf_counter()
    for i in range(1, args.gen - 1):
        logits, cache = decode(params, cache, tok, pos + i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    n_steady = max(1, args.gen - 2)
    print("generated:", np.stack(outs, 1))
    print(f"compile: prefill {t_pf_compile*1e3:.1f}ms, "
          f"first-decode {t_dec_compile*1e3:.1f}ms")
    print(f"steady:  prefill {t_pf*1e3:.1f}ms; "
          f"decode {t_dec/n_steady*1e3:.1f}ms/tok; "
          f"tp={dcfg.tp_size} int8_kv={args.int8_kv}")
    if args.metrics_jsonl:
        from repro.core.obs import MetricsRegistry
        reg = MetricsRegistry()
        reg.gauge("serve/prefill_compile_s").set(t_pf_compile)
        reg.gauge("serve/decode_compile_s").set(t_dec_compile)
        reg.gauge("serve/prefill_s").set(t_pf)
        reg.gauge("serve/decode_step_s").set(t_dec / n_steady)
        reg.gauge("serve/decode_tok_s").set(
            args.batch * n_steady / max(1e-9, t_dec))
        reg.dump_jsonl(args.metrics_jsonl, arch=args.arch,
                       batch=args.batch, gen=args.gen)
        print(f"metrics: {args.metrics_jsonl}")


if __name__ == "__main__":
    main()
