"""Training launcher CLI.

On real hardware this runs under one process per host with
jax.distributed.initialize(); on this container it drives the same code on
fake CPU devices (--devices N). Selects any assigned architecture.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_1_7b --smoke \
      --steps 50 --devices 8 --mesh 4,2
"""

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--mesh", default="4,2")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--bucket-mode", default="block")
    ap.add_argument("--no-reorder", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", ""))

    import logging

    import jax.numpy as jnp

    from repro.core.dist import DistConfig
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    logging.basicConfig(level=logging.INFO)
    mesh_shape = tuple(int(x) for x in args.mesh.split(","))
    dcfg = DistConfig(
        mesh_axes=("data", "model"), mesh_shape=mesh_shape,
        param_dtype=jnp.bfloat16, reduce_dtype=jnp.float32,
        bucket_mode=args.bucket_mode, reorder=not args.no_reorder,
        microbatches=args.microbatches,
        grad_compression=args.grad_compression)
    cfg, model = get_arch(args.arch, smoke=args.smoke)
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.steps,
                         log_every=5, warmup=10, ckpt_dir=args.ckpt_dir)
    trainer = Trainer(model, dcfg, shape, AdamWConfig(lr=args.lr), tcfg)
    _, _, hist = trainer.run()
    print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
