"""Training launcher CLI.

On real hardware this runs under one process per host with
jax.distributed.initialize(); on this container it drives the same code on
fake CPU devices. Selects any assigned architecture; mesh axes are DERIVED
from the flags (2 entries -> (data, model), 3 -> (pod, data, model); --pp>1
prepends an outermost 'pipe' axis), and the single Trainer routes through
`core/api.parallelize` — pp x dp x tp is a config flip.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_1_7b --smoke \
      --steps 50 --mesh 4,2
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_1_7b --smoke \
      --steps 50 --mesh 2,2 --pp 2 --pp-schedule zb --pp-microbatches 4
"""

import argparse
import math
import os


def mesh_from_flags(mesh: str, pp: int, cp: int = 1) \
        -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Mesh (shape, axes) from the --mesh/--pp/--cp flags.

    `mesh` names the non-pipe part: "D,M" -> (data, model), "P,D,M" ->
    (pod, data, model). --pp>1 prepends the 'pipe' axis OUTERMOST
    (core/pipeline layout convention: tiny point-to-point sends tolerate
    the slowest interconnect; fat FSDP gathers stay inner). --cp>1 inserts
    the 'ctx' axis BETWEEN data and model (ring ppermute traffic is
    lighter than FSDP gathers, heavier than pipe sends; TP psums stay
    innermost — core/context.py)."""
    shape = tuple(int(x) for x in mesh.split(","))
    if len(shape) == 2:
        axes: tuple[str, ...] = ("data", "model")
    elif len(shape) == 3:
        axes = ("pod", "data", "model")
    else:
        raise SystemExit(f"--mesh must have 2 or 3 entries, got {mesh!r}")
    if cp > 1:
        shape = (*shape[:-1], cp, shape[-1])
        axes = (*axes[:-1], "ctx", axes[-1])
    if pp > 1:
        return (pp, *shape), ("pipe", *axes)
    return shape, axes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--devices", type=int, default=0,
                    help="fake CPU device count (0 = sized to the mesh)")
    ap.add_argument("--mesh", default="4,2",
                    help="non-pipe mesh: 'data,model' or 'pod,data,model'")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages; >1 adds an outermost 'pipe' axis")
    ap.add_argument("--pp-schedule", default="auto",
                    choices=("auto", "gpipe", "1f1b", "interleaved", "zb"),
                    help="'auto' scores every valid schedule by modeled "
                         "bubble fraction + in-flight memory and picks the "
                         "argmin (core/api); the resolved pick is printed "
                         "in the plan line")
    ap.add_argument("--pp-virtual", type=int, default=0,
                    help="virtual stage chunks per rank for 'interleaved' "
                         "(0 = smallest divisor >= 2 of layers_per_stage)")
    ap.add_argument("--pp-microbatches", type=int, default=0,
                    help="pipeline microbatches M (0 = use the stage count)")
    ap.add_argument("--cp", type=int, default=1,
                    help="context-parallel degree; >1 inserts a 'ctx' axis "
                         "between data and model (zigzag seq sharding + "
                         "ring attention, cp-capable archs only)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches (pp=1 only; "
                         "under --pp use --pp-microbatches)")
    ap.add_argument("--bucket-mode", default="block")
    ap.add_argument("--comm-precision", default="bf16",
                    choices=("bf16", "fp8_ag", "fp8", "fp8_ef",
                             "int8_ag", "int8", "int8_ef", "auto"),
                    help="collective wire precision (kernels/quant): bf16 "
                         "is bit-exact; fp8_ag quantizes param all-gathers "
                         "only; fp8 adds stochastically-rounded grad "
                         "reduce-scatters; fp8_ef adds the error-feedback "
                         "accumulator; int8_* are the same modes on the "
                         "int8 codec; 'auto' lets the bucket planner pick "
                         "per bucket from the full lattice")
    ap.add_argument("--no-reorder", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--metrics-jsonl", default=None,
                    help="append a metrics-registry snapshot (step time, "
                         "tokens/s, wire bytes, drift gauges) here at "
                         "every log interval (core/obs)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace of the executed "
                         "plan's modeled timeline here after the run "
                         "(core/obs.plan_trace)")
    ap.add_argument("--profile-out", default=None,
                    help="after the run, profile the executed schedule "
                         "(core/obs.profile_step), write the frozen "
                         "MeasuredProfile JSON here plus a modeled-vs-"
                         "measured overlay trace next to it "
                         "(<profile-out>.trace.json)")
    ap.add_argument("--replan-threshold", type=float, default=None,
                    help="arm profile-guided replanning: mean |rel| "
                         "step-time drift above this for --replan-patience "
                         "consecutive steps triggers profile_step + replan "
                         "(core/obs)")
    ap.add_argument("--replan-patience", type=int, default=3)
    ap.add_argument("--replan-apply", action="store_true",
                    help="restart the loop onto the replanned ParallelPlan "
                         "(default: log the delta only)")
    args = ap.parse_args()

    mesh_shape, mesh_axes = mesh_from_flags(args.mesh, args.pp, args.cp)
    devices = args.devices or math.prod(mesh_shape)
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + os.environ.get("XLA_FLAGS", ""))

    import logging

    import jax.numpy as jnp

    from repro.core.dist import DistConfig
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch, get_arch_for_pp
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    logging.basicConfig(level=logging.INFO)
    dcfg = DistConfig(
        mesh_axes=mesh_axes, mesh_shape=mesh_shape,
        pp_axis="pipe" if args.pp > 1 else None,
        pp_schedule=args.pp_schedule,
        pp_virtual=args.pp_virtual,
        pp_microbatches=args.pp_microbatches,
        cp_axis="ctx" if args.cp > 1 else None,
        # the ctx axis joins the FSDP domain: params shard over data x ctx
        # so cross-ctx grads ride explicit collectives (core/context.py)
        fsdp_axes=("data", "ctx") if args.cp > 1 else ("data",),
        param_dtype=jnp.bfloat16, reduce_dtype=jnp.float32,
        bucket_mode=args.bucket_mode, reorder=not args.no_reorder,
        comm_precision=args.comm_precision,
        microbatches=args.microbatches,
        grad_compression=args.grad_compression)
    if args.pp > 1:
        # smoke stacks too shallow to partition get the registry override
        cfg, model = get_arch_for_pp(args.arch, n_stages=args.pp,
                                     smoke=args.smoke)
    else:
        cfg, model = get_arch(args.arch, smoke=args.smoke)
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.steps,
                         log_every=5, warmup=10, ckpt_dir=args.ckpt_dir,
                         metrics_jsonl=args.metrics_jsonl,
                         replan_threshold=args.replan_threshold,
                         replan_patience=args.replan_patience,
                         replan_apply=args.replan_apply)
    trainer = Trainer(model, dcfg, shape, AdamWConfig(lr=args.lr), tcfg)
    print(f"plan: {trainer.plan.describe()}")
    _, _, hist = trainer.run()
    print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    if trainer.drift.records:
        print(trainer.drift.report())
    if trainer.replans:
        last = trainer.replans[-1]
        print(f"replan: changed={last['changed']} "
              f"applied={last['applied']} gain={last['modeled_gain_s']}")
    profile = trainer.profile
    if args.profile_out:
        from repro.core.obs import profile_step
        if profile is None:
            # reuse the measured wall from the run so the profiler only
            # has to time segments/collectives, not re-drive full steps
            rows = trainer.drift.records.get("step_time", [])
            wall = rows[-1]["measured"] if rows else None
            profile = profile_step(model, trainer.plan, shape,
                                   wall_step_s=wall)
        profile.save(args.profile_out)
        print(f"profile: {args.profile_out} "
              f"(wall {profile.wall_step_s:.4f}s, "
              f"{len(profile.spans)} spans)")
    if args.trace_out or (args.profile_out and profile is not None):
        from repro.core.obs import plan_trace
        out = args.trace_out or f"{args.profile_out}.trace.json"
        tb = plan_trace(model, trainer.plan, shape, arch_cfg=cfg,
                        profile=profile)
        tb.save(out)
        print(f"trace: {out} ({len(tb.events)} events; "
              f"{'overlay' if profile is not None else 'modeled only'}; "
              f"open in Perfetto)")


if __name__ == "__main__":
    main()
