import os

if __name__ == "__main__":
    # entry-point only: must land before jax initializes.  Library imports
    # (tests harvesting BlockStats/MemoryStats in-process) must NOT mutate
    # the environment — os.environ leaks into every later subprocess.
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", "")
    )

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent — the full
SimpleFSDP computation+communication graph lowers, SPMD-partitions over the
production mesh (16x16 single-pod / 2x16x16 multi-pod) and compiles — and
extracts the roofline raw material:

  * compiled.memory_analysis()  -> per-device bytes (fits-in-HBM check)
  * compiled.cost_analysis()    -> per-device HLO FLOPs / bytes accessed
  * compiled.as_text()          -> collective ops parsed into per-axis-class
                                   payload bytes (ICI vs DCN)

It is also where measured planner inputs come from: `harvest_block_stats`
compiles ONE block and turns its XLA cost/memory analysis into a measured
`BlockStats` that replaces the analytic roofline defaults for the auto
planners (`plan_for` with bucket_mode='auto'/'auto_dp'); the chosen plan and
its modeled exposure are recorded on each auto-mode result row under
"autowrap". Analytic stats remain the fallback when the local backend cannot
cost the block (CPU-only containers with no cost model).

Results land in benchmarks/results/dryrun_<mesh>.json; EXPERIMENTS.md
sections SSDry-run and SSRoofline are generated from them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek_coder_33b \
      --shape train_4k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from repro.core.compat import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import compat, hw
from repro.core.dist import DistConfig
from repro.core.irgraph import BlockStats
from repro.core.meta import ParamMeta
from repro.launch.mesh import make_production_mesh, production_dcfg
from repro.models import runtime as RT
from repro.models.common import SHAPE_SUITE, ShapeConfig, get_shape
from repro.models.registry import ARCH_IDS, get_arch
from repro.optim.adamw import AdamWConfig
from repro.train import serve as SV
from repro.train.train_step import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")


def pick_microbatches(model, dcfg: DistConfig, shape,
                      calibrate: bool = False) -> int:
    """Gradient-accumulation count for one training cell: the memory
    simulator's stage peaks decide (core/memory.auto_microbatches — this
    replaced the hand-kept per-(arch, shape) MICROBATCH table; --microbatch
    remains as an explicit override).  With `calibrate` the activation
    model is first calibrated against a 1-device XLA compile
    (harvest_memory_stats); otherwise the pick uses the conservative
    default act_scale."""
    if shape.kind != "train" or not hasattr(model, "block_stats"):
        return 1
    from repro.core.memory import auto_microbatches
    act_scale = None
    if calibrate:
        bshape1 = (max(1, shape.global_batch // dcfg.batch_dp),
                   shape.seq_len // max(1, dcfg.cp_size))
        ms = harvest_memory_stats(model, dcfg, bshape1)
        act_scale = ms.act_scale if ms is not None else None
    return auto_microbatches(model, dcfg, shape, act_scale=act_scale)


def _sds_with_sharding(tree_abs, tree_specs, mesh):
    def one(a, s):
        return jax.ShapeDtypeStruct(a.shape, a.dtype,
                                    sharding=NamedSharding(mesh, s))
    return jax.tree.map(one, tree_abs, tree_specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _batch_specs(model, shape, dcfg, B):
    """`models/runtime.batch_specs` (the ONE cp/rows sharding contract),
    with the leading dim downgraded to replicated when the global batch
    does not divide over the row axes (long_500k has global_batch=1)."""
    base = RT.batch_specs(model, shape, dcfg)
    dp_total = dcfg.batch_dp
    specs = {}
    for k, sds in model.input_specs(shape, dcfg).items():
        lead = sds.shape[0]
        spec = base[k]
        if lead % dp_total or lead < dp_total:
            spec = P(None, *spec[1:])
        specs[k] = spec
    return specs


# ---------------------------------------------------------------------------
# collective parsing
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64)\[([\d,]*)\]")
_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8}
_COLL_RE = re.compile(
    r"= \S+ (all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")


def _line_out_bytes(line: str) -> int:
    m = _SHAPE_RE.search(line.split("=", 1)[1] if "=" in line else line)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collectives(hlo_text: str, dcfg: DistConfig) -> dict:
    """Classify each collective by replica-group size -> axis class (ICI/DCN)
    and accumulate effective per-device payload bytes."""
    per_class = {"ici_bytes": 0.0, "dcn_bytes": 0.0}
    ops = []
    pod = dcfg.axis_sizes.get("pod", 1)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        g = _GROUPS_RE.search(line)
        gsize = len(g.group(1).split(",")) if g else 1
        if gsize <= 1:
            continue
        out_b = _line_out_bytes(line)
        k = gsize
        frac = (k - 1) / k
        if kind == "all-gather":
            payload = out_b * frac
        elif kind == "reduce-scatter":
            payload = out_b * (k - 1)          # input = out*k; moves (k-1)/k
        elif kind == "all-reduce":
            payload = 2.0 * out_b * frac
        elif kind == "all-to-all":
            payload = out_b * frac
        else:                                   # collective-permute
            payload = out_b
        # axis class: a group spanning across pods touches DCN
        is_dcn = pod > 1 and gsize in (pod, pod * dcfg.axis_size("data"))
        per_class["dcn_bytes" if is_dcn else "ici_bytes"] += payload
        ops.append({"kind": kind, "group": k, "bytes": out_b})
    per_class["n_collectives"] = len(ops)
    kinds = {}
    for o in ops:
        kinds[o["kind"]] = kinds.get(o["kind"], 0) + 1
    per_class["by_kind"] = kinds
    return per_class


# ---------------------------------------------------------------------------
# compiled-cost harvesting: measured BlockStats for the auto planners
# ---------------------------------------------------------------------------
def _harvest_setup(model, dcfg: DistConfig, batch_shape):
    """Shared 1-device harvest scaffolding: (dcfg1, mesh1, metas, consts,
    x_abs, params_abs, analytic target/reference stats)."""
    saved = getattr(model, "measured_stats", None)
    if hasattr(model, "measured_stats"):
        model.measured_stats = None
    try:
        an_tgt = model.block_stats(dcfg, batch_shape)
        dcfg1 = dcfg.with_(mesh_axes=("data", "model"),
                           mesh_shape=(1, 1), fsdp_axes=("data",),
                           tp_axis="model", pp_axis=None,
                           microbatches=1)
        an_ref = model.block_stats(dcfg1, batch_shape)
    finally:
        if hasattr(model, "measured_stats"):
            model.measured_stats = saved

    mesh1 = compat.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
    metas = model.block_metas(dcfg1)
    B, S = batch_shape
    consts = model.consts(S, dcfg1)
    x_abs = jax.ShapeDtypeStruct((B, S, model.cfg.d_model),
                                 dcfg1.param_dtype)
    params_abs = jax.tree.map(
        lambda m: jax.ShapeDtypeStruct(m.local_shape(dcfg1),
                                       dcfg1.param_dtype),
        metas, is_leaf=lambda v: isinstance(v, ParamMeta))
    return dcfg1, mesh1, metas, consts, x_abs, params_abs, an_tgt, an_ref


def _compile_costs(fn, mesh1, in_abs):
    """jit(shard_map(fn)) on the 1-device mesh ->
    (flops, bytes, temp, out_aval) — out_aval feeds the next segment's
    abstract state (collectives only have bound axes inside the wrap)."""
    wrapped = shard_map(fn, mesh=mesh1,
                        in_specs=tuple(P() for _ in in_abs),
                        out_specs=P(), check_vma=False)
    compiled = jax.jit(wrapped).lower(*in_abs).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(compiled.memory_analysis().temp_size_in_bytes),
            jax.eval_shape(wrapped, *in_abs))


def harvest_block_stats(model, dcfg: DistConfig,
                        batch_shape) -> BlockStats | None:
    """Measured per-block costs from XLA, as a `BlockStats` the planners use
    in place of the analytic roofline model.

    The block is compiled on the local backend over a degenerate 1x1 mesh
    (so the model's TP collectives lower as no-ops).  Models that declare a
    segment chain (models/common.BlockSegments) are compiled PER SEGMENT —
    each segment's XLA FLOPs / bytes-accessed / activation footprint scales
    that segment's analytic shares, so both the exposure DP and the memory
    simulator see measured per-segment numbers instead of whole-block
    totals smeared proportionally (ROADMAP bucketing-v2 follow-up).
    Unsegmented blocks keep the whole-block attribution.  Harvest at the
    same per-device microbatch shape the cell runs.

    Returns None whenever compilation or costing is unavailable (e.g. a
    backend whose cost model reports no FLOPs) — callers fall back to the
    analytic stats.
    """
    try:
        (dcfg1, mesh1, metas, consts, x_abs, params_abs,
         an_tgt, an_ref) = _harvest_setup(model, dcfg, batch_shape)
        segments = model.block_segments(dcfg1) \
            if hasattr(model, "block_segments") else None

        if segments is not None and len(segments.fns) > 1:
            return _harvest_segmented(model, dcfg1, mesh1, metas, consts,
                                      x_abs, params_abs, an_tgt, an_ref,
                                      segments)

        def blk(params, x):
            return model.block_fn(params, consts, x, dcfg1)

        flops, bts, act, _ = _compile_costs(blk, mesh1, (params_abs, x_abs))
        if flops <= 0.0:
            return None
        f_ref = sum(an_ref.param_flops.values())
        b_ref = sum(an_ref.param_bytes.values())
        f_scale = flops / f_ref if f_ref > 0 else 1.0
        b_scale = bts / b_ref if b_ref > 0 and bts > 0 else 1.0
        a_scale = act / an_ref.act_bytes if an_ref.act_bytes > 0 and act > 0 \
            else 1.0
        return BlockStats(
            param_flops={k: v * f_scale
                         for k, v in an_tgt.param_flops.items()},
            param_bytes={k: v * b_scale
                         for k, v in an_tgt.param_bytes.items()},
            act_bytes=an_tgt.act_bytes * a_scale,
            source="measured",
        )
    except Exception as e:
        # Analytic fallback is legitimate on backends without a cost model,
        # but the reason must be visible or a harvest regression silently
        # reverts every auto plan to analytic stats.
        print(f"[harvest] measured BlockStats unavailable "
              f"({type(e).__name__}: {e}); falling back to analytic",
              flush=True)
        return None


def _harvest_segmented(model, dcfg1, mesh1, metas, consts, x_abs,
                       params_abs, an_tgt, an_ref, segments) -> BlockStats:
    """Per-segment compilation: one XLA executable per segment of the
    chain, abstract inter-segment states threaded with `jax.eval_shape`."""
    from repro.core.bucketing import assign_segments
    from repro.core.meta import named_leaves

    names = [k for k, _ in named_leaves(metas)]
    seg_of = assign_segments(names, segments.param_globs, segments.names)
    leaves, treedef = jax.tree_util.tree_flatten(
        params_abs, is_leaf=lambda v: v is None)

    pf = dict(an_tgt.param_flops)
    pb = dict(an_tgt.param_bytes)
    seg_act: dict[str, float] = {}
    state = x_abs
    total_flops = 0.0
    act_ratio = an_tgt.act_bytes / an_ref.act_bytes \
        if an_ref.act_bytes > 0 else 1.0
    for s, seg_name in enumerate(segments.names):
        masked = jax.tree_util.tree_unflatten(
            treedef, [lf if seg_of[i] == s else None
                      for i, lf in enumerate(leaves)])

        def seg_fn(params, st, s=s):
            return segments.fns[s](params, consts, st)

        flops, bts, act, state = _compile_costs(seg_fn, mesh1,
                                                (masked, state))
        total_flops += flops
        in_seg = [n for n, sg in zip(names, seg_of) if sg == s]
        f_ref = sum(an_ref.param_flops[n] for n in in_seg)
        b_ref = sum(an_ref.param_bytes[n] for n in in_seg)
        f_scale = flops / f_ref if f_ref > 0 and flops > 0 else 1.0
        b_scale = bts / b_ref if b_ref > 0 and bts > 0 else 1.0
        for n in in_seg:
            pf[n] = an_tgt.param_flops[n] * f_scale
            pb[n] = an_tgt.param_bytes[n] * b_scale
        # segment activation footprint, rescaled to the target mesh (the
        # analytic target/reference ratio carries the tp/batch scaling)
        seg_act[seg_name] = act * act_ratio
    if total_flops <= 0.0:
        raise RuntimeError("cost model reported no FLOPs for any segment")
    return BlockStats(
        param_flops=pf, param_bytes=pb,
        act_bytes=an_tgt.act_bytes,        # block input: analytic shape math
        source="measured", seg_act_bytes=seg_act,
    )


# ---------------------------------------------------------------------------
# memory-model calibration: measured residual footprint for the simulator
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MemoryStats:
    """Calibration of core/memory's activation model against XLA.

    `measured_bytes` is ``memory_analysis().temp_size`` of a 1-device
    forward+backward block compile under `policy`; `modeled_bytes` the
    simulator's residency for the same policy; `act_scale` their clamped
    ratio — multiply every activation-derived term by it
    (`simulate_peak(act_scale=...)`)."""

    measured_bytes: float
    modeled_bytes: float
    act_scale: float
    policy: str = "fsdp_only"
    source: str = "measured"


def harvest_memory_stats(model, dcfg: DistConfig, batch_shape,
                         policy: str = "fsdp_only") -> MemoryStats | None:
    """Compile ONE block's loss+grad on a 1-device mesh and calibrate the
    live-range simulator's activation model against
    ``compiled.memory_analysis()``.  Returns None when the backend cannot
    compile/cost the block (callers keep act_scale=1.0)."""
    try:
        from repro.core.memory import build_block_profile
        from repro.core.remat import maybe_remat

        (dcfg1, mesh1, metas, consts, x_abs, params_abs,
         _, an_ref) = _harvest_setup(model, dcfg, batch_shape)
        segments = model.block_segments(dcfg1) \
            if hasattr(model, "block_segments") else None

        blk = maybe_remat(
            lambda params, x: model.block_fn(params, consts, x, dcfg1)[0],
            policy)

        def grad_step(params, x):
            def loss(xx):
                y = blk(params, xx)
                return jnp.sum(y.astype(jnp.float32) ** 2)
            return jax.grad(loss)(x)

        _, _, measured, _ = _compile_costs(grad_step, mesh1,
                                           (params_abs, x_abs))
        prof = build_block_profile(metas, dcfg1, an_ref, segments)
        n_seg = len(prof.segments)
        modeled = prof.residency((policy,) * n_seg)
        if measured <= 0 or modeled <= 0:
            return None
        scale = min(4.0, max(0.25, measured / modeled))
        return MemoryStats(measured_bytes=measured, modeled_bytes=modeled,
                           act_scale=scale, policy=policy)
    except Exception as e:
        print(f"[harvest] memory calibration unavailable "
              f"({type(e).__name__}: {e}); act_scale=1.0", flush=True)
        return None


def harvest_quant_timing(bucket_elems, codec: str = "fp8", iters: int = 4,
                         cap_elems: int = 1 << 21) -> dict | None:
    """Time the quant round-trip kernel at the plan's actual bucket sizes
    (jit-compiled on THIS backend) and derive a measured codec throughput,
    replacing the analytic 2x-HBM-pass prior in `quant_overhead_s`.
    `bucket_elems`: per-bucket element counts (each capped at `cap_elems`
    so a 1-bucket 8B-param plan doesn't allocate the full buffer).
    Returns {"rate_bytes_per_s", "codec", "samples"} or None when the
    backend can't run the kernel."""
    try:
        import functools

        import numpy as np

        from repro.kernels.quant import ops as QOPS

        sizes = sorted({min(int(n), cap_elems)
                        for n in bucket_elems if n and n > 0})
        if not sizes:
            return None
        # smallest / median / largest: enough to see the fixed-cost knee
        # without timing every bucket of a 30-bucket plan
        picks = sorted({sizes[0], sizes[len(sizes) // 2], sizes[-1]})
        fn = jax.jit(functools.partial(QOPS.roundtrip, codec=codec))
        samples = []
        for n in picks:
            n = max(QOPS.QCHUNK, (n // QOPS.QCHUNK) * QOPS.QCHUNK)
            x = jnp.asarray(
                np.random.default_rng(0).standard_normal(n), jnp.bfloat16)
            fn(x).block_until_ready()             # compile + warmup
            t0 = time.perf_counter()
            for _ in range(iters):
                y = fn(x)
            y.block_until_ready()
            dt = (time.perf_counter() - t0) / iters
            samples.append({"n_elems": n, "bytes": n * 2,
                            "t_us": dt * 1e6})
        big = samples[-1]
        rate = big["bytes"] / max(1e-12, big["t_us"] * 1e-6)
        return {"rate_bytes_per_s": rate, "codec": codec,
                "samples": samples}
    except Exception as e:
        print(f"[harvest] quant timing unavailable "
              f"({type(e).__name__}: {e}); analytic estimate stands",
              flush=True)
        return None


def _autowrap_record(model, dcfg: DistConfig, batch_shape, stats,
                     measure_quant: bool = False) -> dict:
    """The partition the cell will EXECUTE + its modeled exposure (logged
    into the dryrun row so perf numbers are attributable to a concrete
    plan). exposed_comm_time rewrites the plan to the executed segmented
    partition (split + segment-major + pooled hiding), matching fig4.

    `measure_quant`: on quantized-comm cells, time the codec kernel at
    this plan's bucket sizes first and price `quant_overhead_s` by the
    measured rate (the record then carries the measured AND the analytic
    estimate side by side)."""
    from repro.core import irgraph
    from repro.core.autowrap import exposed_comm_time
    from repro.core.bucketing import (_active_segments, plan_for,
                                      split_plan_at_segments)

    metas = model.block_metas(dcfg)
    segments = model.block_segments(dcfg) \
        if hasattr(model, "block_segments") else None
    segments, _ = _active_segments(metas, dcfg, segments)

    qtiming = None
    prev_rate = None
    if measure_quant and dcfg.comm_precision != "bf16":
        nodes = {n.name: n for n in
                 irgraph.build_nodes(metas, dcfg, stats)}
        pre_plan = plan_for(metas, dcfg, stats, segments=segments)
        qtiming = harvest_quant_timing(
            [sum(nodes[p].n_elems for p in grp if p in nodes)
             for grp in pre_plan.groups])
        if qtiming is not None:
            prev_rate = irgraph.set_measured_quant_rate(
                qtiming["rate_bytes_per_s"])
    try:
        plan = plan_for(metas, dcfg, stats, segments=segments)
        r = exposed_comm_time(plan, metas, dcfg, stats, segments=segments)
    finally:
        if qtiming is not None:
            irgraph.set_measured_quant_rate(prev_rate)
    if segments is not None:
        plan = split_plan_at_segments(plan, metas, segments)   # as executed
    rec = {
        "bucket_mode": str(dcfg.bucket_mode),
        "stats_source": getattr(stats, "source", None) or "default",
        "n_buckets": r["n_buckets"],
        "exposed_us": r["exposed_s"] * 1e6,
        "total_comm_us": r["total_comm_s"] * 1e6,
        "compute_us": r["compute_s"] * 1e6,
        "comm_precision": dcfg.comm_precision,
        "precisions": list(r["precisions"]),
        "comm_wire_bytes": r["comm_wire_bytes"],
        "plan": [list(g) for g in plan.groups],
    }
    if qtiming is not None:
        meas_us = r["quant_overhead_s"] * 1e6
        # overhead is linear in 1/rate, so the analytic counterpart is
        # the measured figure rescaled to the 2x-HBM-pass prior
        est_us = meas_us * (qtiming["rate_bytes_per_s"]
                            / (hw.HBM_BANDWIDTH / 2.0))
        rec["quant_overhead_meas_us"] = meas_us
        rec["quant_overhead_est_us"] = est_us
        rec["quant_rate_bytes_per_s"] = qtiming["rate_bytes_per_s"]
        rec["quant_timing_samples"] = qtiming["samples"]
    return rec


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------
def build_lowered(arch_id: str, shape_name: str, dcfg: DistConfig, mesh,
                  bucket_mode="block", reorder=True, measured_stats=None,
                  microbatches: int = 1):
    cfg, model = get_arch(arch_id)
    if measured_stats is not None and hasattr(model, "measured_stats"):
        model.measured_stats = measured_stats
    shape = get_shape(shape_name)
    b_local = max(1, shape.global_batch // dcfg.batch_dp)
    mb = min(microbatches, b_local)  # can't split below one sample/device
    dcfg = dcfg.with_(microbatches=mb, bucket_mode=bucket_mode,
                      reorder=reorder)

    if shape.kind == "train":
        step = make_train_step(model, dcfg, AdamWConfig())
        pspecs = RT.model_storage_specs(model, dcfg)
        opt_specs = {"m": pspecs, "v": pspecs, "step": P()}
        if dcfg.needs_ef:
            opt_specs["ef"] = pspecs
        bspecs = _batch_specs(model, shape, dcfg, shape.global_batch)
        fn = shard_map(step, mesh=mesh,
                       in_specs=(pspecs, opt_specs, bspecs),
                       out_specs=(pspecs, opt_specs,
                                  {"loss": P(), "grad_norm": P(),
                                   "lr": P()}),
                       check_vma=False)
        params_abs = RT.model_abstract_storage(model, dcfg)
        opt_abs = {"m": params_abs, "v": params_abs,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
        if dcfg.needs_ef:
            opt_abs["ef"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                params_abs)
        batch_abs = model.input_specs(shape, dcfg)
        args = (
            _sds_with_sharding(params_abs, pspecs, mesh),
            _sds_with_sharding(opt_abs, opt_specs, mesh),
            _sds_with_sharding(batch_abs, bspecs, mesh),
        )
        lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(*args)
    elif shape.kind == "prefill":
        if cfg.family in ("dense", "moe", "vlm"):
            dcfg = dcfg.with_(kv_cache_int8=True)   # cache decode consumes
        dp = tuple(a for a in dcfg.mesh_axes if a != dcfg.tp_axis)
        bspecs = _batch_specs(model, shape, dcfg, shape.global_batch)
        _, cache_specs = SV.cache_abstract(model, shape, dcfg)

        def step(params, batch):
            return model.prefill_local(params, batch, dcfg)

        fn = shard_map(step, mesh=mesh,
                       in_specs=(SV.serve_param_specs(model, dcfg), bspecs),
                       out_specs=(P(bspecs["tokens"][0], dcfg.tp_axis),
                                  cache_specs),
                       check_vma=False)
        args = (
            _sds_with_sharding(SV.serve_abstract_params(model, dcfg),
                               SV.serve_param_specs(model, dcfg), mesh),
            _sds_with_sharding(model.input_specs(shape, dcfg), bspecs,
                               mesh),
        )
        lowered = jax.jit(fn).lower(*args)
    else:  # decode
        if cfg.family in ("dense", "moe", "vlm"):
            # int8 KV-cache quantization: halves the dominant decode buffer
            dcfg = dcfg.with_(kv_cache_int8=True)
        dp = tuple(a for a in dcfg.mesh_axes if a != dcfg.tp_axis)
        B = shape.global_batch
        lead = dp if B % dcfg.dp_total == 0 and B >= dcfg.dp_total else None
        cache_abs, cache_specs = SV.cache_abstract(model, shape, dcfg)
        # re-spec the cache batch dim when batch is replicated
        if lead is None:
            cache_specs = jax.tree.map(
                lambda s: P(*[None if ax else ax for ax in [None]])
                if False else _strip_dp(s, dcfg), cache_specs,
                is_leaf=lambda x: isinstance(x, P))

        def step(params, cache, tok, pos):
            logits, cache = model.decode_local(params, cache, tok, pos,
                                               dcfg)
            return logits, cache

        fn = shard_map(step, mesh=mesh,
                       in_specs=(SV.serve_param_specs(model, dcfg),
                                 cache_specs, P(lead), P(lead)),
                       out_specs=(P(lead, dcfg.tp_axis), cache_specs),
                       check_vma=False)
        args = (
            _sds_with_sharding(SV.serve_abstract_params(model, dcfg),
                               SV.serve_param_specs(model, dcfg), mesh),
            _sds_with_sharding(cache_abs, cache_specs, mesh),
            jax.ShapeDtypeStruct((B,), jnp.int32,
                                 sharding=NamedSharding(mesh, P(lead))),
            jax.ShapeDtypeStruct((B,), jnp.int32,
                                 sharding=NamedSharding(mesh, P(lead))),
        )
        # donate the cache: decode updates it in place (halves HBM)
        lowered = jax.jit(fn, donate_argnums=(1,)).lower(*args)
    return lowered, model, shape, dcfg


def _strip_dp(spec: P, dcfg: DistConfig):
    """Replace dp-axis entries with None (batch replicated)."""
    dp = set(a for a in dcfg.mesh_axes if a != dcfg.tp_axis)

    def clean(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            kept = tuple(a for a in e if a not in dp)
            return kept if kept else None
        return None if e in dp else e

    return P(*[clean(e) for e in spec])


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------
def roofline_terms(cost: dict, colls: dict, model, shape: ShapeConfig,
                   dcfg: DistConfig) -> dict:
    flops = float(cost.get("flops", 0.0))
    bts = float(cost.get("bytes accessed", 0.0))
    t_comp = flops / hw.PEAK_FLOPS_BF16
    t_mem = bts / hw.HBM_BANDWIDTH
    # per-axis bandwidths from hw.axis_bandwidth — the same single source
    # the bucket planners and the ring scheduler cost against
    t_ici = colls["ici_bytes"] / hw.axis_bandwidth("data").bytes_per_s
    t_dcn = colls["dcn_bytes"] / hw.axis_bandwidth("pod").bytes_per_s
    t_coll = t_ici + t_dcn
    cfg = model.cfg
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        model_flops = 6.0 * cfg.n_params_active() * tokens / dcfg.n_devices
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        model_flops = 2.0 * cfg.n_params_active() * tokens / dcfg.n_devices
    else:
        tokens = shape.global_batch
        model_flops = 2.0 * cfg.n_params_active() * tokens / dcfg.n_devices
    dominant = max((("compute", t_comp), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "t_ici_s": t_ici, "t_dcn_s": t_dcn,
        "dominant": dominant,
        "hlo_flops_per_dev": flops, "hlo_bytes_per_dev": bts,
        "model_flops_per_dev": model_flops,
        "useful_flop_frac": model_flops / flops if flops else 0.0,
        "roofline_frac": (min(t_comp, max(t_comp, t_mem, t_coll))
                          / max(t_comp, t_mem, t_coll, 1e-30)),
    }


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             bucket_mode="block", reorder=True, zero3=False,
             mesh_shape=None, microbatch=None, harvest=None,
             remat=None, context_degree: int = 1,
             comm_precision=None) -> dict:
    """Lower+compile one (arch, shape, mesh) cell.

    `harvest`: None = harvest measured BlockStats iff an auto planner will
    consume them; True/False force it. Harvested stats are plumbed into the
    cell's model so `plan_for` plans over measured costs; on failure the
    analytic model is the fallback and the row records which one fed the
    plan.

    `remat`: override dcfg.remat for the cell — a fixed policy, a
    per-segment vector, or ``"auto:<GB>"`` (resolved by core/memory's
    budgeted planner BEFORE lowering; an infeasible budget raises the
    planner's pointed error and the row records it).

    `context_degree` > 1 carves the 'ctx' axis out of the data axis (ring
    attention, core/context.py): training cells of cp-capable models lower
    with the sequence sharded; the row records the per-device sequence
    shard and the modeled ring exposure.

    Gradient-accumulation microbatches come from the memory simulator
    (`pick_microbatches`) unless `microbatch` overrides them."""
    cfg, model = get_arch(arch_id)
    if shape_name in cfg.skip_shapes:
        return {"arch": arch_id, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "SKIP",
                "reason": "quadratic attention at 500k (DESIGN.md)"}
    if context_degree > 1:
        from repro.core.context import supports_cp
        shape0 = get_shape(shape_name)
        if shape0.kind != "train":
            return {"arch": arch_id, "shape": shape_name, "status": "SKIP",
                    "cp": context_degree,
                    "reason": "context parallelism is a training-path "
                              "feature (serving shards the KV cache "
                              "instead)"}
        if not supports_cp(model):
            return {"arch": arch_id, "shape": shape_name, "status": "SKIP",
                    "cp": context_degree,
                    "reason": f"{type(model).__name__} does not implement "
                              "the cp contract (cp_supported)"}
        if shape0.seq_len % (2 * context_degree):
            return {"arch": arch_id, "shape": shape_name, "status": "SKIP",
                    "cp": context_degree,
                    "reason": f"seq {shape0.seq_len} not divisible into "
                              f"{2 * context_degree} zigzag chunks"}
    if mesh_shape is not None:      # hillclimb: alternative factorization
        import math as _m
        assert _m.prod(mesh_shape) == (512 if multi_pod else 256)
        assert context_degree == 1, "--mesh-shape and --cp are exclusive"
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        from repro.core import compat
        mesh = compat.make_mesh(mesh_shape, axes)
        dcfg = production_dcfg(multi_pod=multi_pod, zero3_global=zero3) \
            .with_(mesh_shape=mesh_shape)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod,
                                    context_degree=context_degree)
        dcfg = production_dcfg(multi_pod=multi_pod, zero3_global=zero3,
                               context_degree=context_degree)
    if remat is not None:
        dcfg = dcfg.with_(remat=remat)
    if comm_precision is not None:
        dcfg = dcfg.with_(comm_precision=comm_precision)

    # ---- measured-cost harvest + plan/memory records ----
    if harvest is None:
        harvest = bucket_mode in ("auto", "auto_dp")

    # microbatches: the simulator's stage-peak rule (calibrated against a
    # 1-device compile when harvesting), overridable per cell
    shape0 = get_shape(shape_name)
    mb = microbatch if microbatch is not None \
        else pick_microbatches(model, dcfg, shape0, calibrate=harvest)
    mb = min(mb, max(1, shape0.global_batch // dcfg.batch_dp))
    measured = None
    autowrap_rec = None
    memory_rec = None
    ring_rec = None
    mem_plan = None
    step_time_rec = None
    # bucket/memory plans (and thus harvest records) only exist on the
    # training stack — serving paths run prefill/decode without apply_stack
    if shape0.kind == "train":
        _, model0 = get_arch(arch_id)
        if hasattr(model0, "block_stats"):
            mb0 = mb
            b_local = max(1, shape0.global_batch // dcfg.batch_dp // mb0)
            bshape = (b_local, shape0.seq_len // max(1, dcfg.cp_size))
            dcfg_plan = dcfg.with_(microbatches=mb0, bucket_mode=bucket_mode,
                                   reorder=reorder)
            if harvest:
                measured = harvest_block_stats(model0, dcfg_plan, bshape)
                if measured is not None:
                    model0.measured_stats = measured
            if bucket_mode in ("auto", "auto_dp"):
                stats = model0.block_stats(dcfg_plan, bshape)
                autowrap_rec = _autowrap_record(model0, dcfg_plan, bshape,
                                                stats,
                                                measure_quant=harvest)
            # live-range memory model for the cell (core/memory): resolves
            # remat="auto:<GB>" to its policy vector before lowering and
            # feeds the modeled-vs-measured fits-in-HBM check below
            from repro.core.memory import plan_memory
            mstats = harvest_memory_stats(model0, dcfg_plan, bshape) \
                if harvest else None
            mem_plan = plan_memory(
                model0, dcfg_plan, batch_shape=bshape,
                stats=measured,
                act_scale=mstats.act_scale if mstats else 1.0)
            memory_rec = {
                "policy_spec": mem_plan.policy_spec,
                "offload_opt_state": mem_plan.offload_opt_state,
                "offload_residuals": mem_plan.offload_residuals,
                "bucket_override_n_buckets":
                    mem_plan.bucket_plan.n_buckets
                    if mem_plan.bucket_plan is not None else None,
                "modeled_peak_bytes": mem_plan.peak,
                "budget_bytes": mem_plan.budget_bytes,
                "cost_s": mem_plan.cost_s,
                "act_scale": mstats.act_scale if mstats else 1.0,
                "breakdown": [b.describe() for b in mem_plan.breakdown],
            }
            if dcfg.remat != mem_plan.policy_spec:
                dcfg = dcfg.with_(remat=mem_plan.policy_spec)
            # modeled step-time promise of the cell, analytic next to
            # calibrated: the analytic row prices the pure roofline
            # priors; the calibrated row re-plans with the harvested
            # measured BlockStats installed (equal when no harvest ran)
            try:
                from repro.core.api import plan_parallel
                from repro.core.obs import modeled_step_time
                saved_ms = getattr(model0, "measured_stats", None)
                try:
                    model0.measured_stats = None
                    p_a = plan_parallel(model0, dcfg_plan, shape0)
                    t_an = modeled_step_time(model0, p_a, shape0)
                    t_cal = t_an
                    if measured is not None:
                        model0.measured_stats = measured
                        p_c = plan_parallel(model0, dcfg_plan, shape0)
                        t_cal = modeled_step_time(model0, p_c, shape0)
                finally:
                    model0.measured_stats = saved_ms
                if t_an is not None:
                    step_time_rec = {
                        "step_time_us": t_an * 1e6,
                        "step_time_calibrated_us": t_cal * 1e6,
                    }
            except Exception as e:  # keep the cell alive on model gaps
                print(f"[step] modeled step time unavailable: {e}",
                      flush=True)
            if dcfg.cp_size > 1:
                # modeled ring-attention schedule of the cell (per layer):
                # hop sizes/compute and the exposed exchange time
                from repro.core.context import ring_cost
                ring_rec = ring_cost(cfg, dcfg_plan, bshape,
                                     window=cfg.sliding_window)

    # when the memory planner retightened buckets against the budget, the
    # cell must execute that partition (build_lowered re-applies the mode)
    bucket_mode_exec = mem_plan.bucket_plan \
        if mem_plan is not None and mem_plan.bucket_plan is not None \
        else bucket_mode
    t0 = time.time()
    lowered, model, shape, dcfg = build_lowered(arch_id, shape_name, dcfg,
                                                mesh, bucket_mode_exec,
                                                reorder,
                                                measured_stats=measured,
                                                microbatches=mb)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # older jax: one dict per device
        cost = cost[0] if cost else {}
    colls = parse_collectives(compiled.as_text(), dcfg)
    terms = roofline_terms(cost, colls, model, shape, dcfg)
    per_dev = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
               + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "OK",
        "fits_hbm": bool(per_dev <= hw.HBM_BYTES),
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_bytes": per_dev,
        },
        "collectives": colls,
        "roofline": terms,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "bucket_mode": bucket_mode, "reorder": reorder,
        "microbatches": mb,
        "comm_precision": dcfg.comm_precision,
    }
    if dcfg.cp_size > 1:
        rec["cp"] = dcfg.cp_size
        rec["seq_local"] = shape.seq_len // dcfg.cp_size
        if ring_rec is not None:
            rec["ring"] = {
                "hop_bytes": ring_rec["hop_bytes"],
                "hop_comm_us": ring_rec["hop_comm_s"] * 1e6,
                "hop_comp_us": ring_rec["hop_comp_s"] * 1e6,
                "live_hops": ring_rec["live_hops"],
                "exposed_us": ring_rec["exposed_s"] * 1e6,
            }
            print(f"[ctx] {arch_id} x {shape_name}: cp={dcfg.cp_size} "
                  f"seq/dev={rec['seq_local']} ring exposed "
                  f"{rec['ring']['exposed_us']:.1f}us "
                  f"(live hops {ring_rec['live_hops']}/{dcfg.cp_size})",
                  flush=True)
    if step_time_rec is not None:
        rec.update(step_time_rec)
    if autowrap_rec is not None:
        rec["autowrap"] = autowrap_rec
    if memory_rec is not None:
        # modeled (live-range simulator) vs measured (XLA memory_analysis),
        # side by side — the fits-in-HBM check now consumes BOTH
        gib = 1 / 1024**3
        modeled = memory_rec["modeled_peak_bytes"]
        memory_rec["measured_peak_bytes"] = per_dev
        memory_rec["modeled_over_measured"] = modeled / max(1.0, per_dev)
        rec["memory"] = memory_rec
        rec["fits_hbm_modeled"] = bool(modeled <= hw.HBM_BYTES)
        # the ONE audited modeled-vs-measured peak path (core/obs):
        # same gauges + format as trainer.memory_report
        from repro.core.obs import default_registry
        print("[mem] " + default_registry().record_peak(
            f"{arch_id} x {shape_name}", modeled, per_dev,
            budget_bytes=hw.HBM_BYTES,
            note=f"remat={memory_rec['policy_spec']}"), flush=True)
        if modeled > hw.HBM_BYTES:
            worst = max(mem_plan.breakdown, key=lambda b: b.peak_bytes)
            msg = (f"{arch_id} x {shape_name}: modeled peak "
                   f"{modeled*gib:.2f} GiB exceeds the "
                   f"{hw.HBM_BYTES*gib:.0f} GiB HBM budget on stage "
                   f"{worst.stage} ({worst.describe()}); tighten remat "
                   f"(remat='auto:{hw.HBM_BYTES*gib:.0f}'), raise "
                   f"microbatching, or add parallelism")
            rec["memory_error"] = msg
            print(f"[mem] OVER BUDGET: {msg}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--zero3", action="store_true")
    ap.add_argument("--bucket-mode", default="block")
    ap.add_argument("--remat", default=None,
                    help="override dcfg.remat: a policy, a per-segment "
                         "vector ('attn=full,mlp=fsdp_only'), or the "
                         "budgeted 'auto:<GB>' form")
    ap.add_argument("--no-reorder", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="alternative factorization, e.g. 64,4")
    ap.add_argument("--cp", type=int, default=1,
                    help="context-parallel degree: carves a 'ctx' axis out "
                         "of the data axis (ring attention; train cells of "
                         "cp-capable archs only)")
    ap.add_argument("--comm-precision", default=None,
                    help="override dcfg.comm_precision: bf16 | fp8_ag | "
                         "fp8 | fp8_ef | int8_ag | int8 | int8_ef | auto "
                         "(per-bucket planner choice)")
    ap.add_argument("--microbatch", type=int, default=None,
                    help="override the simulator-picked gradient-"
                         "accumulation count")
    ap.add_argument("--harvest-stats", dest="harvest", action="store_true",
                    default=None,
                    help="force measured BlockStats harvesting (default: "
                         "only for auto bucket modes)")
    ap.add_argument("--no-harvest-stats", dest="harvest",
                    action="store_false")
    ap.add_argument("--tag", default=None, help="suffix for the result row")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            if a == "llama3_8b":
                continue
            for s in SHAPE_SUITE:
                cells.append((a, s.name))
    else:
        cells.append((args.arch, args.shape))

    results = []
    for a, s in cells:
        try:
            ms = tuple(int(x) for x in args.mesh_shape.split(",")) \
                if args.mesh_shape else None
            rec = run_cell(a, s, args.multi_pod,
                           bucket_mode=args.bucket_mode,
                           reorder=not args.no_reorder,
                           zero3=args.zero3, mesh_shape=ms,
                           microbatch=args.microbatch,
                           harvest=args.harvest, remat=args.remat,
                           context_degree=args.cp,
                           comm_precision=args.comm_precision)
            if args.tag:
                rec["tag"] = args.tag
        except Exception as e:
            rec = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        results.append(rec)
        status = rec["status"]
        extra = ""
        if status == "OK":
            r = rec["roofline"]
            extra = (f" mem={rec['mem']['per_device_bytes']/2**30:.2f}GiB"
                     f" fits={rec['fits_hbm']}"
                     f" dom={r['dominant']}"
                     f" comp={r['t_compute_s']:.3f}s"
                     f" coll={r['t_collective_s']:.3f}s")
        elif status == "FAIL":
            extra = " " + rec["error"][:160]
        print(f"[{status}] {a} x {s}{extra}", flush=True)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = "multipod" if args.multi_pod else "singlepod"
    out = args.out or os.path.join(RESULTS_DIR, f"dryrun_{tag}.json")
    existing = []
    if os.path.exists(out) and not args.all:
        existing = json.load(open(out))
        keep = {(r["arch"], r["shape"], r.get("tag")) for r in results}
        existing = [r for r in existing
                    if (r["arch"], r["shape"], r.get("tag")) not in keep]
    json.dump(existing + results, open(out, "w"), indent=1)
    print("wrote", out)


if __name__ == "__main__":
    main()
