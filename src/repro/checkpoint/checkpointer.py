"""Sharded, atomic, elastic checkpointing.

Layout: one .npy per leaf (logical FULL arrays via from_storage, so restores
are topology-independent — save on a 256-chip mesh, restore on 512: "elastic
scaling") + a JSON manifest with step/config. Writes go to a temp dir that is
atomically renamed; an optional background thread makes saves async. The
trainer's restart path (ft/) relies on `latest_step` + bit-exact restore
(tested in tests/test_integration.py).

At datacenter scale each host would write only its local shards; the
manifest format already records per-leaf paths so that change is local to
_save_tree/_load_tree.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

from repro.core.dist import DistConfig
from repro.models import runtime as RT


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


class Checkpointer:
    def __init__(self, root: str, async_save: bool = False):
        self.root = root
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------- save --
    def save(self, step: int, storage, opt_state, model, dcfg: DistConfig,
             extra: dict | None = None):
        metas = model.metas(dcfg)
        logical = {k: RT.tree_from_storage(storage[k], metas[k], dcfg)
                   for k in storage}
        mom = {
            "m": {k: RT.tree_from_storage(opt_state["m"][k], metas[k], dcfg)
                  for k in opt_state["m"]},
            "v": {k: RT.tree_from_storage(opt_state["v"][k], metas[k], dcfg)
                  for k in opt_state["v"]},
        }
        payload = _flatten({"params": logical, **mom})
        payload["opt_step"] = opt_state["step"]
        if self._thread is not None:
            self._thread.join()     # previous async save must land first
        host = {k: np.asarray(v) for k, v in payload.items()}

        def _write():
            tmp = os.path.join(self.root, f".tmp_step_{step}")
            final = os.path.join(self.root, f"step_{step:08d}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            index = {}
            for k, v in host.items():
                fn = k.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fn), v)
                index[k] = fn
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "leaves": index,
                           "extra": extra or {}}, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)   # atomic publish

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
        return step

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------- restore --
    def latest_step(self) -> int | None:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.root)
                 if d.startswith("step_")]
        return max(steps) if steps else None

    def restore(self, step: int, model, dcfg: DistConfig):
        """Returns (storage, opt_state) re-sharded for `dcfg` — restoring on
        a different mesh than the save is supported (elastic)."""
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = {k: np.load(os.path.join(d, fn))
                  for k, fn in manifest["leaves"].items()}
        metas = model.metas(dcfg)

        def unflatten(prefix, template):
            if isinstance(template, dict):
                return {k: unflatten(f"{prefix}{k}/", template[k])
                        for k in sorted(template)}
            return leaves[prefix[:-1]]

        abstract = RT.model_abstract_storage(model, dcfg)
        logical = unflatten("params/", abstract)
        storage = {k: RT.tree_to_storage(logical[k], metas[k], dcfg)
                   for k in logical}
        m = unflatten("m/", abstract)
        v = unflatten("v/", abstract)
        opt_state = {
            "m": {k: RT.tree_to_storage(m[k], metas[k], dcfg) for k in m},
            "v": {k: RT.tree_to_storage(v[k], metas[k], dcfg) for k in v},
            "step": jax.numpy.asarray(leaves["opt_step"]),
        }
        return storage, opt_state, manifest
