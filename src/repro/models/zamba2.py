"""Zamba2 hybrid family (zamba2-1.2b): Mamba-2 backbone + ONE weight-tied
("shared") attention block invoked every `shared_attn_every` layers
(arXiv:2411.15242).

Structure here: 38 mamba layers = 6 superblocks of 6 (each scanned via
core.stack, so the paper's bucketing/prefetch applies) + 2 trailing layers;
after each superblock the shared attention block runs on concat(hidden,
initial_embedding) (2d wide, 32 heads x 128) and projects back to d. The
shared block's params are FSDP-gathered per invocation (6 gathers/step) and
its gradients accumulate across invocations through ordinary autodiff.

Mamba-2 TP: heads sharded over the model axis via explicit (head, dim)
param layouts; B/C (ngroups=1) and conv are TP-replicated; per-head gated
RMSNorm; out-proj row-parallel back into sequence-parallel layout.
O(1)-state decode -> runs the long_500k cell.

Simplifications (DESIGN.md): shared-block LoRA adapters omitted (weight-tied
plain block); per-head RMSNorm instead of full-d_inner groupnorm.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives as coll
from repro.core.dist import DistConfig
from repro.core.irgraph import BlockStats
from repro.core.meta import ParamMeta
from repro.core.remat import maybe_remat
from repro.core.stack import apply_stack
from repro.kernels.ssd.ref import ssd_chunked, ssd_step
from repro.models import layers as LY
from repro.models.common import ArchConfig, ShapeConfig, StageSpec
from repro.models.xlstm import causal_conv1d


class Zamba2LM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.d_inner = cfg.ssm_expand * cfg.d_model
        self.hd = cfg.ssm_head_dim
        self.nh = self.d_inner // self.hd            # mamba heads
        self.ds = cfg.ssm_state
        self.per = cfg.shared_attn_every or 6
        self.n_super = cfg.n_layers // self.per      # full superblocks
        self.n_tail = cfg.n_layers - self.n_super * self.per
        self.n_steps = cfg.n_layers                  # logical layer count

    # ------------------------------------------------------------- metas --
    def mamba_metas(self, dcfg: DistConfig, dt=None) -> dict:
        cfg = self.cfg
        d, di, nh, hd, ds = (cfg.d_model, self.d_inner, self.nh, self.hd,
                             self.ds)
        dt = dt or dcfg.storage_dtype
        K = cfg.ssm_conv
        return {
            "ln": LY.norm_meta("ln", d, dt),
            "w_x": ParamMeta("w_x", (d, nh, hd), 1, dt),
            "w_z": ParamMeta("w_z", (d, nh, hd), 1, dt),
            "w_bc": ParamMeta("w_bc", (d, 2 * ds), None, dt),
            "w_dt": ParamMeta("w_dt", (d, nh), 1, dt),
            "dt_bias": ParamMeta("dt_bias", (nh,), 0, dt),
            "A_log": ParamMeta("A_log", (nh,), 0, dt),
            "Dskip": ParamMeta("Dskip", (nh,), 0, dt),
            "conv_x": ParamMeta("conv_x", (K, nh, hd), 1, dt),
            "conv_bc": ParamMeta("conv_bc", (K, 2 * ds), None, dt),
            "gn": ParamMeta("gn", (nh, hd), 0, dt),
            "w_out": ParamMeta("w_out", (nh, hd, d), 0, dt),
        }

    def shared_metas(self, dcfg: DistConfig) -> dict:
        cfg = self.cfg
        dt = dcfg.storage_dtype
        d2 = 2 * cfg.d_model
        lay = cfg.gqa_layout(dcfg.tp_size)
        hq, kvp = lay["hq"], lay["kvp"]
        hd = cfg.head_dim
        kv_tp = 0 if lay["mode"] == "sharded" else None
        return {
            "ln1": LY.norm_meta("sh.ln1", d2, dt),
            "wq": ParamMeta("sh.wq", (d2, hq * hd), 1, dt),
            "wk": ParamMeta("sh.wk", (kvp * hd, d2), kv_tp, dt),
            "wv": ParamMeta("sh.wv", (kvp * hd, d2), kv_tp, dt),
            "wo": ParamMeta("sh.wo", (hq * hd, cfg.d_model), 0, dt),
            "ln2": LY.norm_meta("sh.ln2", d2, dt),
            "wg": ParamMeta("sh.wg", (d2, cfg.d_ff), 1, dt),
            "wu": ParamMeta("sh.wu", (d2, cfg.d_ff), 1, dt),
            "wd": ParamMeta("sh.wd", (cfg.d_ff, cfg.d_model), 0, dt),
        }

    def block_metas(self, dcfg: DistConfig) -> dict:
        return self.mamba_metas(dcfg)

    def metas(self, dcfg: DistConfig) -> dict:
        cfg = self.cfg
        dt = dcfg.storage_dtype
        return {
            "embed": LY.embed_meta("embed", cfg, dt),
            "blocks": self.block_metas(dcfg),      # stacked over n_layers
            "shared": self.shared_metas(dcfg),
            "final_norm": LY.norm_meta("final_norm", cfg.d_model, dt),
            "head": LY.head_meta("head", cfg, dt),
        }

    @property
    def stacked_keys(self) -> dict:
        return {"blocks": self.n_steps}

    def _stage_partition(self, n_stages: int):
        """Superblock-granularity stage split with an uneven tail.

        The n_super full superblocks are dealt round-robin (earlier stages
        take the remainder) and the trailing partial superblock rides the
        LAST stage.  Every stage's storage slot is zero-padded to a uniform
        layers_per_stage that is a whole number of superblocks — an
        all-zero mamba block is an EXACT identity (output = x + y @ w_out
        with y == 0 and w_out == 0) whose parameter gradients are exactly
        zero (every grad path carries a w_out or y factor), so padding
        layers stay zero under AdamW and pp parity with the dense model is
        exact.  Returns (supers_per_stage, real_layers_per_stage, padded
        layers_per_stage)."""
        base, rem = divmod(self.n_super, n_stages)
        if base == 0:
            raise ValueError(
                f"{self.cfg.name}: {n_stages} pipeline stages need at least "
                f"one {self.per}-layer superblock each (n_super="
                f"{self.n_super})")
        supers = tuple(base + (1 if s < rem else 0) for s in range(n_stages))
        reals = [c * self.per for c in supers]
        reals[-1] += self.n_tail
        lps = -(-max(reals) // self.per) * self.per
        return supers, tuple(reals), lps

    def stage_spec(self, n_stages: int) -> StageSpec:
        """Mamba layers slice contiguously at SUPERBLOCK granularity; the
        weight-tied shared attention block is consumed after every full
        superblock on EVERY stage, so it is replicated across stages (grads
        psum'ed over the pipe axis).  Stages may be uneven (trailing
        partial superblock, non-divisible superblock counts): short stages
        are zero-padded to a uniform slot size and `stage_blocks` gates the
        shared block by this rank's real superblock count.  The superblock
        cadence means the stack must NOT be sliced into virtual chunks
        (chunkable=False — the planner never proposes interleaving)."""
        cfg = self.cfg
        if n_stages == 1:
            return StageSpec(
                n_stages=1, pipelined="blocks",
                layers_per_stage=cfg.n_layers, pre_keys=("embed",),
                post_keys=("final_norm", "head"),
                replicated_keys=("shared",), chunkable=False)
        _, reals, lps = self._stage_partition(n_stages)
        uneven = any(r != lps for r in reals)
        return StageSpec(
            n_stages=n_stages,
            pipelined="blocks",
            layers_per_stage=lps,
            pre_keys=("embed",),
            post_keys=("final_norm", "head"),
            replicated_keys=("shared",),
            stage_layers=reals if uneven else None,
            chunkable=False,
        )

    # -------------------------------------------------------------- init --
    def mamba_init(self, key) -> dict:
        cfg = self.cfg
        d, di, nh, hd, ds = (cfg.d_model, self.d_inner, self.nh, self.hd,
                             self.ds)
        K = cfg.ssm_conv
        ks = jax.random.split(key, 8)
        sd = 0.02
        dt_bias = jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[6], (nh,),
                                       minval=math.log(1e-3),
                                       maxval=math.log(1e-1)))))
        return {
            "ln": LY.norm_init(d),
            "w_x": jax.random.normal(ks[0], (d, nh, hd)) * sd,
            "w_z": jax.random.normal(ks[1], (d, nh, hd)) * sd,
            "w_bc": jax.random.normal(ks[2], (d, 2 * ds)) * sd,
            "w_dt": jax.random.normal(ks[3], (d, nh)) * sd,
            "dt_bias": dt_bias,
            "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
            "Dskip": jnp.ones((nh,)),
            "conv_x": jax.random.normal(ks[4], (K, nh, hd))
            / math.sqrt(K),
            "conv_bc": jax.random.normal(ks[5], (K, 2 * ds))
            / math.sqrt(K),
            "gn": jnp.ones((nh, hd)),
            "w_out": jax.random.normal(ks[7], (nh, hd, d))
            * (sd / math.sqrt(2 * cfg.n_layers)),
        }

    def shared_init(self, key, dcfg) -> dict:
        cfg = self.cfg
        d2 = 2 * cfg.d_model
        lay = cfg.gqa_layout(dcfg.tp_size)
        hq, kvp = lay["hq"], lay["kvp"]
        ks = jax.random.split(key, 7)
        sd = 0.02
        hd = cfg.head_dim
        return {
            "ln1": LY.norm_init(d2),
            "wq": jax.random.normal(ks[0], (d2, hq * hd)) * sd,
            "wk": jax.random.normal(ks[1], (kvp * hd, d2)) * sd,
            "wv": jax.random.normal(ks[2], (kvp * hd, d2)) * sd,
            "wo": jax.random.normal(ks[3], (hq * hd, cfg.d_model))
            * sd * 0.5,
            "ln2": LY.norm_init(d2),
            "wg": jax.random.normal(ks[4], (d2, cfg.d_ff)) * sd,
            "wu": jax.random.normal(ks[5], (d2, cfg.d_ff)) * sd,
            "wd": jax.random.normal(ks[6], (cfg.d_ff, cfg.d_model))
            * sd * 0.5,
        }

    def init_full(self, key, dcfg: DistConfig) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.n_layers + 3)
        blocks = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[self.mamba_init(keys[i]) for i in range(cfg.n_layers)])
        sh = self.shared_init(keys[-3], dcfg)
        return {
            "embed": LY.embed_init(keys[-1], cfg),
            "blocks": blocks,
            "shared": sh,
            "final_norm": LY.norm_init(cfg.d_model),
            "head": LY.head_init(keys[-2], cfg),
        }

    # ------------------------------------------------------------- mamba --
    def mamba_block(self, p, consts, x_sp, dcfg: DistConfig):
        cfg = self.cfg
        nh_l = p["w_x"].shape[1]                  # heads local (nh/tp)
        hd, ds = self.hd, self.ds
        h = LY.rmsnorm(x_sp, p["ln"], cfg.norm_eps)
        xg = LY.sp_gather(h, dcfg)
        B, T, _ = xg.shape
        xh = jnp.einsum("btd,dhp->bthp", xg, p["w_x"])      # (B,T,nh_l,hd)
        z = jnp.einsum("btd,dhp->bthp", xg, p["w_z"])
        bc = jnp.einsum("btd,dn->btn", xg, p["w_bc"])       # (B,T,2ds)
        dt_pre = jnp.einsum("btd,dh->bth", xg, p["w_dt"])
        # causal convs (x per-head-channel, bc replicated)
        xh2, _ = causal_conv1d(xh.reshape(B, T, nh_l * hd),
                               p["conv_x"].reshape(-1, nh_l * hd))
        xh = jax.nn.silu(xh2).reshape(B, T, nh_l, hd)
        bc2, _ = causal_conv1d(bc, p["conv_bc"])
        bc = jax.nn.silu(bc2)
        Bm = bc[..., :ds][:, :, None, :]                    # (B,T,1,ds)
        Cm = bc[..., ds:][:, :, None, :]
        dt = jax.nn.softplus(dt_pre.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        # heads local -> groups: ngroups=1 shared across all heads
        Bh = jnp.broadcast_to(Bm, (B, T, 1, ds))
        y, _ = ssd_chunked(xh, dt, A, Bh, Cm, D=p["Dskip"],
                           chunk=cfg.ssm_chunk)
        # gated per-head RMSNorm
        y = y * jax.nn.silu(z)
        yf = y.astype(jnp.float32)
        var = jnp.mean(yf * yf, axis=-1, keepdims=True)
        y = (yf * jax.lax.rsqrt(var + cfg.norm_eps)
             * p["gn"][None, None].astype(jnp.float32)).astype(xg.dtype)
        o = jnp.einsum("bthp,hpd->btd", y, p["w_out"])
        return x_sp + LY.sp_scatter(o, dcfg)

    def _mamba_stack_fn(self, p, consts, x, dcfg):
        blk = jax.checkpoint(
            lambda pp, xx: self.mamba_block(pp, consts, xx, dcfg))
        return blk(p, x), {}

    # ------------------------------------------------------ shared block --
    def shared_block(self, p, x_sp, emb_sp, consts, dcfg: DistConfig):
        """concat(hidden, embedding) -> attn -> +x ; -> mlp -> +x."""
        cfg = self.cfg
        u = jnp.concatenate([x_sp, emb_sp], axis=-1)        # (B,S/tp,2d)
        h = LY.rmsnorm(u, p["ln1"], cfg.norm_eps)
        hg = LY.sp_gather(h, dcfg)
        fake = ArchConfig(
            name="zshared", family="dense", n_layers=cfg.n_layers,
            d_model=2 * cfg.d_model, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, d_ff=cfg.d_ff, vocab=cfg.vocab,
            head_dim=cfg.head_dim, pad_to=cfg.pad_to)
        q, k, v, head_mask = LY._local_qkv(
            {"wq": p["wq"], "wk": p["wk"], "wv": p["wv"]}, hg, fake, dcfg)
        cos, sin = consts["rope_cos"], consts["rope_sin"]
        q = LY.apply_rope(q, cos, sin)
        k = LY.apply_rope(k, cos, sin)
        out = LY.attention(q, k, v, causal=True)
        out = out * head_mask[None, None, :, None]
        Bq, S, hl, hd = out.shape
        o = jnp.einsum("bsh,hd->bsd", out.reshape(Bq, S, hl * hd), p["wo"])
        x_sp = x_sp + LY.sp_scatter(o, dcfg)
        u = jnp.concatenate([x_sp, emb_sp], axis=-1)
        h = LY.rmsnorm(u, p["ln2"], cfg.norm_eps)
        hg = LY.sp_gather(h, dcfg)
        g = jnp.einsum("bsd,df->bsf", hg, p["wg"])
        w = jnp.einsum("bsd,df->bsf", hg, p["wu"])
        o = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * w, p["wd"])
        return x_sp + LY.sp_scatter(o, dcfg)

    # ------------------------------------------------------------- train --
    def _shared_fn(self, consts, dcfg: DistConfig):
        """FSDP-gathering applier of the weight-tied shared block.

        'full' remat: the shared block touches gathered full-seq
        activations (concat 2d wide); saving its internals per invocation
        costs ~2-3 GiB x n_super — recompute instead.
        """
        sh_metas = self.shared_metas(dcfg)

        def shared_fn(sh_storage, xc, embc):
            sh = coll.replicate_tree(sh_storage, sh_metas, dcfg)
            return self.shared_block(sh, xc, embc, consts, dcfg)

        return maybe_remat(shared_fn, "full"
                           if dcfg.remat != "none" else "none")

    def _consts_for(self, x_sp, dcfg: DistConfig) -> dict:
        cos, sin = LY.rope_cache(x_sp.shape[1] * dcfg.tp_size,
                                 self.cfg.head_dim, self.cfg.rope_theta)
        return {"rope_cos": cos, "rope_sin": sin}

    def stage_pre(self, storage, mb, dcfg: DistConfig):
        cfg = self.cfg
        emb_meta = LY.embed_meta("embed", cfg, dcfg.storage_dtype)

        def embed_fn(shard, ids):
            table = coll.replicate(shard, emb_meta, dcfg)
            return LY.embed_apply(table, ids, cfg, dcfg)

        x = maybe_remat(embed_fn, "fsdp_only")(storage["embed"],
                                               mb["tokens"])
        # the shared block re-reads the initial embedding on every
        # superblock, so it rides the inter-stage state alongside x
        return {"x": x, "emb0": x}

    def stage_blocks(self, storage, state, dcfg: DistConfig, plan=None):
        """A whole number of superblock SLOTS: each = `per` scanned mamba
        layers + one invocation of the (stage-replicated) shared block,
        GATED by this rank's real full-superblock count.  Uneven stages
        (trailing partial superblock / non-divisible splits) zero-pad the
        layer stack — zero mamba layers are exact identities — and skip the
        shared block on padded/tail slots.  The program stays rank-uniform
        (SPMD): every rank traces the same groups and the same shared-block
        collectives, and jnp.where selects which outputs take effect."""
        x, emb0 = state["x"], state["emb0"]
        consts = self._consts_for(x, dcfg)
        blk = functools.partial(self._mamba_stack_fn, dcfg=dcfg)
        bmetas = self.block_metas(dcfg)
        shared_fn = self._shared_fn(consts, dcfg)
        Lp = jax.tree.leaves(storage["blocks"])[0].shape[0]
        assert Lp % self.per == 0, "stage_spec pads to whole superblocks"
        if dcfg.pp_axis is not None and dcfg.pp_size > 1:
            supers, _, _ = self._stage_partition(dcfg.pp_size)
            my_count = jnp.asarray(supers)[jax.lax.axis_index(dcfg.pp_axis)]
        else:
            my_count = jnp.asarray(Lp // self.per)
        for g in range(Lp // self.per):
            seg = jax.tree.map(
                lambda s: s[g * self.per:(g + 1) * self.per],
                storage["blocks"])
            x, _ = apply_stack(blk, bmetas, dcfg, seg, consts, x, plan=plan)
            x_sh = shared_fn(storage["shared"], x, emb0)
            x = jnp.where(g < my_count, x_sh, x)
        return {"x": x, "emb0": emb0}

    def stage_loss(self, storage, state, mb, dcfg: DistConfig):
        cfg = self.cfg
        x = state["x"]
        fn_meta = LY.norm_meta("final_norm", cfg.d_model, dcfg.storage_dtype)
        w_fn = coll.replicate(storage["final_norm"], fn_meta, dcfg)
        x = LY.rmsnorm(x, w_fn, cfg.norm_eps)
        hd_meta = LY.head_meta("head", cfg, dcfg.storage_dtype)
        w = coll.replicate(storage["head"], hd_meta, dcfg)
        logits = LY.head_logits(w, LY.sp_gather(x, dcfg), cfg, dcfg)
        loss, _ = LY.vocab_parallel_xent(logits, mb["targets"],
                                         mb["valid"], cfg, dcfg)
        return loss

    def loss_local(self, storage, batch, dcfg: DistConfig):
        # general path: full superblocks then the trailing partial
        # superblock (no shared block after the tail) — the staged program
        # reproduces this exactly via zero-padded slots (see stage_spec)
        state = self.stage_pre(storage, batch, dcfg)
        x, emb0 = state["x"], state["emb0"]
        consts = self._consts_for(x, dcfg)
        blk = functools.partial(self._mamba_stack_fn, dcfg=dcfg)
        bmetas = self.block_metas(dcfg)
        shared_fn = self._shared_fn(consts, dcfg)

        pos = 0
        for _ in range(self.n_super):
            seg = jax.tree.map(lambda s: s[pos:pos + self.per],
                               storage["blocks"])
            x, _ = apply_stack(blk, bmetas, dcfg, seg, consts, x)
            x = shared_fn(storage["shared"], x, emb0)
            pos += self.per
        if self.n_tail:
            seg = jax.tree.map(lambda s: s[pos:pos + self.n_tail],
                               storage["blocks"])
            x, _ = apply_stack(blk, bmetas, dcfg, seg, consts, x)
        loss = self.stage_loss(storage, {"x": x, "emb0": emb0}, batch, dcfg)
        return loss, {}

    # ------------------------------------------------------------- serve --
    def init_state(self, batch_local: int, dcfg: DistConfig,
                   seq_len: int = 0):
        cfg = self.cfg
        nh_l = self.nh // dcfg.tp_size if self.nh % dcfg.tp_size == 0 \
            else self.nh
        K = cfg.ssm_conv
        B = batch_local
        tp = dcfg.tp_size
        lay = cfg.gqa_layout(tp)
        kl = lay["kvp"] // tp if lay["mode"] == "sharded" \
            else max(1, lay["kvp"] // tp)
        kv = tuple(
            (jnp.zeros((B, seq_len, kl, cfg.head_dim), dcfg.param_dtype),
             jnp.zeros((B, seq_len, kl, cfg.head_dim), dcfg.param_dtype))
            for _ in range(self.n_super)
        )
        return {
            "S": jnp.zeros((cfg.n_layers, B, nh_l, self.hd, self.ds),
                           jnp.float32),
            "conv_x": jnp.zeros((cfg.n_layers, B, K - 1, nh_l * self.hd),
                                jnp.float32),
            "conv_bc": jnp.zeros((cfg.n_layers, B, K - 1, 2 * self.ds),
                                 jnp.float32),
            "sh_kv": kv,
        }

    def _mamba_prefill(self, p, consts, x_sp, dcfg):
        """mamba_block variant returning the final SSD + conv states."""
        cfg = self.cfg
        nh_l = p["w_x"].shape[1]
        hd, ds = self.hd, self.ds
        h = LY.rmsnorm(x_sp, p["ln"], cfg.norm_eps)
        xg = LY.sp_gather(h, dcfg)
        B, T, _ = xg.shape
        xh = jnp.einsum("btd,dhp->bthp", xg, p["w_x"])
        z = jnp.einsum("btd,dhp->bthp", xg, p["w_z"])
        bc = jnp.einsum("btd,dn->btn", xg, p["w_bc"])
        dt_pre = jnp.einsum("btd,dh->bth", xg, p["w_dt"])
        xh_flat = xh.reshape(B, T, nh_l * hd)
        xh2, _ = causal_conv1d(xh_flat, p["conv_x"].reshape(-1, nh_l * hd))
        xh_c = jax.nn.silu(xh2).reshape(B, T, nh_l, hd)
        bc2, _ = causal_conv1d(bc, p["conv_bc"])
        bc_c = jax.nn.silu(bc2)
        Bm = bc_c[..., :ds][:, :, None, :]
        Cm = bc_c[..., ds:][:, :, None, :]
        dt = jax.nn.softplus(dt_pre.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        y, S = ssd_chunked(xh_c, dt, A, Bm, Cm, D=p["Dskip"],
                           chunk=cfg.ssm_chunk)
        y = y * jax.nn.silu(z)
        yf = y.astype(jnp.float32)
        var = jnp.mean(yf * yf, axis=-1, keepdims=True)
        y = (yf * jax.lax.rsqrt(var + cfg.norm_eps)
             * p["gn"][None, None].astype(jnp.float32)).astype(xg.dtype)
        o = jnp.einsum("bthp,hpd->btd", y, p["w_out"])
        K = cfg.ssm_conv
        st = {"S": S,
              "conv_x": xh_flat[:, -(K - 1):].astype(jnp.float32),
              "conv_bc": bc[:, -(K - 1):].astype(jnp.float32)}
        return x_sp + LY.sp_scatter(o, dcfg), st

    def _shared_prefill(self, p, x_sp, emb_sp, consts, dcfg):
        """shared_block variant emitting its kv cache (full-seq)."""
        cfg = self.cfg
        u = jnp.concatenate([x_sp, emb_sp], axis=-1)
        h = LY.rmsnorm(u, p["ln1"], cfg.norm_eps)
        hg = LY.sp_gather(h, dcfg)
        fake = ArchConfig(
            name="zshared", family="dense", n_layers=cfg.n_layers,
            d_model=2 * cfg.d_model, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, d_ff=cfg.d_ff, vocab=cfg.vocab,
            head_dim=cfg.head_dim, pad_to=cfg.pad_to)
        q, k, v, head_mask = LY._local_qkv(
            {"wq": p["wq"], "wk": p["wk"], "wv": p["wv"]}, hg, fake, dcfg)
        cos, sin = consts["rope_cos"], consts["rope_sin"]
        q2 = LY.apply_rope(q, cos, sin)
        k2 = LY.apply_rope(k, cos, sin)
        out = LY.attention(q2, k2, v, causal=True)
        out = out * head_mask[None, None, :, None]
        Bq, S, hl, hd = out.shape
        o = jnp.einsum("bsh,hd->bsd", out.reshape(Bq, S, hl * hd), p["wo"])
        x_sp = x_sp + LY.sp_scatter(o, dcfg)
        u = jnp.concatenate([x_sp, emb_sp], axis=-1)
        h = LY.rmsnorm(u, p["ln2"], cfg.norm_eps)
        hg = LY.sp_gather(h, dcfg)
        g = jnp.einsum("bsd,df->bsf", hg, p["wg"])
        w = jnp.einsum("bsd,df->bsf", hg, p["wu"])
        o = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * w, p["wd"])
        kv_dt = dcfg.param_dtype
        return x_sp + LY.sp_scatter(o, dcfg), (k2.astype(kv_dt),
                                               v.astype(kv_dt))

    def prefill_local(self, params_tp, batch, dcfg: DistConfig):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = LY.embed_apply(params_tp["embed"], tokens, cfg, dcfg)
        emb0 = x
        cos, sin = LY.rope_cache(tokens.shape[1], cfg.head_dim,
                                 cfg.rope_theta)
        consts = {"rope_cos": cos, "rope_sin": sin}

        def seg_body(xc, p):
            y, st = self._mamba_prefill(p, consts, xc, dcfg)
            return y, st

        sts, kvs = [], []
        pos = 0
        for si in range(self.n_super):
            seg = jax.tree.map(lambda a: a[pos:pos + self.per],
                               params_tp["blocks"])
            x, st = lax.scan(seg_body, x, seg)
            sts.append(st)
            x, kv = self._shared_prefill(params_tp["shared"], x, emb0,
                                         consts, dcfg)
            kvs.append(kv)
            pos += self.per
        if self.n_tail:
            seg = jax.tree.map(lambda a: a[pos:pos + self.n_tail],
                               params_tp["blocks"])
            x, st = lax.scan(seg_body, x, seg)
            sts.append(st)
        state = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *sts)
        state["sh_kv"] = tuple(kvs)
        x = LY.rmsnorm(x, params_tp["final_norm"], cfg.norm_eps)
        xg = LY.sp_gather(x, dcfg)[:, -1:]
        logits = jnp.einsum("bsd,dv->bsv", xg, params_tp["head"],
                            preferred_element_type=jnp.float32)
        return logits[:, 0], state

    def mamba_decode(self, p, st, x, dcfg: DistConfig):
        cfg = self.cfg
        B = x.shape[0]
        nh_l, hd, ds = p["w_x"].shape[1], self.hd, self.ds
        h = LY.rmsnorm(x, p["ln"], cfg.norm_eps)
        xh = jnp.einsum("btd,dhp->bthp", h, p["w_x"])
        z = jnp.einsum("btd,dhp->bthp", h, p["w_z"])
        bc = jnp.einsum("btd,dn->btn", h, p["w_bc"])
        dt_pre = jnp.einsum("btd,dh->bth", h, p["w_dt"])
        xh2, cx = causal_conv1d(xh.reshape(B, 1, nh_l * hd),
                                p["conv_x"].reshape(-1, nh_l * hd),
                                state=st["conv_x"].astype(xh.dtype))
        xh = jax.nn.silu(xh2).reshape(B, nh_l, hd)
        bc2, cbc = causal_conv1d(bc, p["conv_bc"],
                                 state=st["conv_bc"].astype(bc.dtype))
        bc = jax.nn.silu(bc2)[:, 0]
        dt = jax.nn.softplus(dt_pre[:, 0].astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        S, y = ssd_step(st["S"], xh, dt, A, bc[:, None, :ds],
                        bc[:, None, ds:], D=p["Dskip"])
        y = y[:, None] * jax.nn.silu(z)
        yf = y.astype(jnp.float32)
        var = jnp.mean(yf * yf, axis=-1, keepdims=True)
        y = (yf * jax.lax.rsqrt(var + cfg.norm_eps)
             * p["gn"][None, None].astype(jnp.float32)).astype(x.dtype)
        o = jnp.einsum("bthp,hpd->btd", y, p["w_out"])
        o = lax.psum(o, dcfg.tp_axis)
        return x + o, {"S": S, "conv_x": cx.astype(jnp.float32),
                       "conv_bc": cbc.astype(jnp.float32)}

    def decode_local(self, params_tp, state, tok, pos, dcfg: DistConfig):
        """Shared attention during decode attends over its own KV cache held
        in `state['sh_kv']` (B, T, Kl, hd) per invocation point.
        pos: (B,) per-request positions."""
        cfg = self.cfg
        x = LY.embed_apply(params_tp["embed"], tok[:, None], cfg, dcfg,
                           scatter=False)
        emb0 = x
        cos, sin = LY.rope_pos(pos[:, None], cfg.head_dim, cfg.rope_theta)
        new_state = dict(state)
        # scan over mamba layers in python segments mirroring training
        S, cx, cbc = state["S"], state["conv_x"], state["conv_bc"]
        outs_S, outs_cx, outs_cbc = [], [], []
        li = 0
        for seg_idx in range(self.n_super + (1 if self.n_tail else 0)):
            n = self.per if seg_idx < self.n_super else self.n_tail
            for j in range(n):
                p = jax.tree.map(lambda a: a[li], params_tp["blocks"])
                st = {"S": S[li], "conv_x": cx[li], "conv_bc": cbc[li]}
                x, st2 = self.mamba_decode(p, st, x, dcfg)
                outs_S.append(st2["S"])
                outs_cx.append(st2["conv_x"])
                outs_cbc.append(st2["conv_bc"])
                li += 1
            if seg_idx < self.n_super:
                x, new_state = self._shared_decode(
                    params_tp["shared"], new_state, seg_idx, x, emb0, pos,
                    cos, sin, dcfg)
        new_state["S"] = jnp.stack(outs_S)
        new_state["conv_x"] = jnp.stack(outs_cx)
        new_state["conv_bc"] = jnp.stack(outs_cbc)
        x = LY.rmsnorm(x, params_tp["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params_tp["head"],
                            preferred_element_type=jnp.float32)
        return logits[:, 0], new_state

    def _shared_decode(self, p, state, idx, x, emb0, pos, cos, sin, dcfg):
        cfg = self.cfg
        u = jnp.concatenate([x, emb0], axis=-1)
        h = LY.rmsnorm(u, p["ln1"], cfg.norm_eps)
        fake = ArchConfig(
            name="zshared", family="dense", n_layers=cfg.n_layers,
            d_model=2 * cfg.d_model, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, d_ff=cfg.d_ff, vocab=cfg.vocab,
            head_dim=cfg.head_dim, pad_to=cfg.pad_to)
        q, k, v, head_mask = LY._local_qkv(
            {"wq": p["wq"], "wk": p["wk"], "wv": p["wv"]}, h, fake, dcfg)
        q = LY.apply_rope_pos(q, cos, sin)
        k = LY.apply_rope_pos(k, cos, sin)
        ck, cv = state["sh_kv"][idx]
        ib = jnp.arange(q.shape[0])
        ck = ck.at[ib, pos].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[ib, pos].set(v[:, 0].astype(cv.dtype))
        kl = ck.shape[2]
        hl = q.shape[2]
        group = hl // kl
        qg = q.reshape(q.shape[0], 1, kl, group, cfg.head_dim)
        s = jnp.einsum("bqkgh,btkh->bkgqt",
                       qg / math.sqrt(cfg.head_dim), ck,
                       preferred_element_type=jnp.float32)
        msk = jnp.arange(ck.shape[1])[None, :] <= pos[:, None]
        s = jnp.where(msk[:, None, None, None, :], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqt,btkh->bqkgh", pr.astype(cv.dtype), cv)
        out = out.reshape(q.shape[0], 1, hl, cfg.head_dim)
        out = out * head_mask[None, None, :, None]
        o = jnp.einsum("bsh,hd->bsd",
                       out.reshape(q.shape[0], 1, hl * cfg.head_dim),
                       p["wo"])
        o = lax.psum(o, dcfg.tp_axis)
        x = x + o
        u = jnp.concatenate([x, emb0], axis=-1)
        h = LY.rmsnorm(u, p["ln2"], cfg.norm_eps)
        g = jnp.einsum("bsd,df->bsf", h, p["wg"])
        w = jnp.einsum("bsd,df->bsf", h, p["wu"])
        o = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * w, p["wd"])
        o = lax.psum(o, dcfg.tp_axis)
        x = x + o
        kvs = list(state["sh_kv"])
        kvs[idx] = (ck, cv)
        state = dict(state)
        state["sh_kv"] = tuple(kvs)
        return x, state

    # ----------------------------------------------------------- costing --
    def block_stats(self, dcfg: DistConfig, batch_shape) -> BlockStats:
        B, S = batch_shape          # per-device microbatch
        tokens = B * S
        it = jnp.dtype(dcfg.param_dtype).itemsize
        pf, pb = {}, {}
        from repro.core.meta import named_leaves
        for nm, m in named_leaves(self.block_metas(dcfg)):
            numel = m.numel_local(dcfg)
            pf[nm] = 2.0 * tokens * numel
            pb[nm] = numel * it
        return BlockStats(param_flops=pf, param_bytes=pb,
                          act_bytes=tokens * self.cfg.d_model * it / dcfg.tp_size)

    def bucket_units(self) -> list[list[str]]:
        return [["w_x", "w_z", "conv_*", "ln"],
                ["w_bc", "w_dt", "dt_bias", "A_log", "Dskip", "gn",
                 "w_out"]]

    def input_specs(self, shape: ShapeConfig, dcfg: DistConfig) -> dict:
        B, S = shape.global_batch, shape.seq_len
        ids = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            return {"tokens": ids, "targets": ids,
                    "valid": jax.ShapeDtypeStruct((B, S), jnp.float32)}
        if shape.kind == "prefill":
            return {"tokens": ids}
        return {"tok": jax.ShapeDtypeStruct((B,), jnp.int32)}
