"""VLM family (internvl2-26b): InternViT frontend STUB + InternLM2 backbone.

Per the assignment, the modality frontend is a stub: `input_specs` feeds
precomputed ViT patch embeddings (B, n_img_tokens, vit_dim). The trainable
pieces here are the 2-layer MLP projector (vit_dim -> d_model) and the full
LM backbone (plain DenseLM, llama-style GQA). Image embeddings occupy the
first n_img_tokens positions; loss is masked to text positions.

Serving: prefill consumes (image embeddings + text prompt); decode is the
backbone's decode (image prefix lives in the KV cache) — delegated wholesale
to DenseLM.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import collectives as coll
from repro.core.dist import DistConfig
from repro.core.meta import ParamMeta
from repro.core.remat import maybe_remat
from repro.core.stack import apply_stack
from repro.models import layers as LY
from repro.models.common import ArchConfig, ShapeConfig, StageSpec
from repro.models.dense import DenseLM


class VLM(DenseLM):
    # the image-prefix/text-span sequence layout is positional: the zigzag
    # cp permutation would interleave modality chunks, so the VLM opts out
    # of context parallelism (plan_parallel rejects cp > 1 pointedly)
    cp_supported = False

    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        assert cfg.vit_dim and cfg.n_img_tokens

    # projector params ride alongside the backbone tree ----------------------
    def metas(self, dcfg: DistConfig) -> dict:
        m = super().metas(dcfg)
        cfg = self.cfg
        dt = dcfg.storage_dtype
        m["proj_w1"] = ParamMeta("proj_w1", (cfg.vit_dim, cfg.d_model),
                                 1, dt)
        m["proj_w2"] = ParamMeta("proj_w2", (cfg.d_model, cfg.d_model),
                                 None, dt)
        return m

    def init_full(self, key, dcfg: DistConfig) -> dict:
        p = super().init_full(key, dcfg)
        cfg = self.cfg
        k1, k2 = jax.random.split(jax.random.fold_in(key, 999))
        p["proj_w1"] = jax.random.normal(k1, (cfg.vit_dim, cfg.d_model)) \
            * 0.02
        p["proj_w2"] = jax.random.normal(k2, (cfg.d_model, cfg.d_model)) \
            * 0.02
        return p

    def _project_images(self, storage, img, dcfg):
        cfg = self.cfg
        m1 = ParamMeta("proj_w1", (cfg.vit_dim, cfg.d_model), 1,
                       dcfg.storage_dtype)
        m2 = ParamMeta("proj_w2", (cfg.d_model, cfg.d_model), None,
                       dcfg.storage_dtype)
        w1 = coll.replicate(storage["proj_w1"], m1, dcfg)
        w2 = coll.replicate(storage["proj_w2"], m2, dcfg)
        h = jnp.einsum("bnf,fd->bnd", img.astype(dcfg.param_dtype), w1)
        h = jax.nn.gelu(h, approximate=True)
        # w1 is TP-col-sharded -> h covers d/tp cols; w2 consumes the full d,
        # so gather the hidden over the model axis first.
        h = jax.lax.all_gather(h, dcfg.tp_axis, axis=2, tiled=True)
        return jnp.einsum("bnd,de->bne", h, w2)

    # ------------------------------------------------------------- train --
    def stage_spec(self, n_stages: int) -> StageSpec:
        """Backbone partition with the modality frontend (projector) joining
        the embedding on stage 0."""
        base = super().stage_spec(n_stages)
        return dataclasses.replace(
            base, pre_keys=base.pre_keys + ("proj_w1", "proj_w2"))

    def stage_pre(self, storage, mb, dcfg: DistConfig):
        """Stage-0 entry: project image embeddings, embed text, concat into
        the SP-layout sequence (image prefix first)."""
        cfg = self.cfg
        img_x = self._project_images(storage, mb["img_embeds"], dcfg)
        emb_meta = LY.embed_meta("embed", cfg, dcfg.storage_dtype)

        def embed_fn(shard, ids):
            table = coll.replicate(shard, emb_meta, dcfg)
            return LY.embed_apply(table, ids, cfg, dcfg, scatter=False)

        txt_x = maybe_remat(embed_fn, "fsdp_only")(storage["embed"],
                                                   mb["tokens"])
        x = jnp.concatenate([img_x.astype(txt_x.dtype), txt_x], axis=1)
        return LY.sp_slice(x, dcfg), self._aux0()    # full -> SP layout

    def stage_loss(self, storage, state, mb, dcfg: DistConfig):
        """Last-stage exit: image positions masked out of the CE loss."""
        cfg = self.cfg
        x, aux = state
        tokens = mb["tokens"]
        n_img = cfg.n_img_tokens
        fn_meta = LY.norm_meta("final_norm", cfg.d_model, dcfg.storage_dtype)
        w_fn = coll.replicate(storage["final_norm"], fn_meta, dcfg)
        x = LY.rmsnorm(x, w_fn, cfg.norm_eps)
        logits = self._lm_head(storage, x, dcfg)     # (B, S, V/tp)
        pad_t = jnp.zeros((tokens.shape[0], n_img), tokens.dtype)
        targets = jnp.concatenate([pad_t, mb["targets"]], axis=1)
        valid = jnp.concatenate(
            [jnp.zeros((tokens.shape[0], n_img), jnp.float32),
             mb["valid"]], axis=1)
        loss, _ = LY.vocab_parallel_xent(logits, targets, valid, cfg, dcfg)
        return loss + self._loss_aux(aux)

    def loss_local(self, storage, batch, dcfg: DistConfig):
        state = self.stage_blocks(storage,
                                  self.stage_pre(storage, batch, dcfg), dcfg)
        return self.stage_loss(storage, state, batch, dcfg), state[1]

    # ------------------------------------------------------------- serve --
    def prefill_local(self, params_tp, batch, dcfg: DistConfig):
        """Image embeddings prepend the text prompt; then the backbone's
        prefill. params_tp carries proj_w1/proj_w2 TP-local."""
        cfg = self.cfg
        img = batch["img_embeds"]
        h = jnp.einsum("bnf,fd->bnd", img.astype(dcfg.param_dtype),
                       params_tp["proj_w1"])
        h = jax.nn.gelu(h, approximate=True)
        h = jax.lax.all_gather(h, dcfg.tp_axis, axis=2, tiled=True)
        img_x = jnp.einsum("bnd,de->bne", h, params_tp["proj_w2"])
        txt_x = LY.embed_apply(params_tp["embed"], batch["tokens"], cfg,
                               dcfg, scatter=False)
        x = jnp.concatenate([img_x.astype(txt_x.dtype), txt_x], axis=1)
        x = LY.sp_slice(x, dcfg)
        S = img_x.shape[1] + batch["tokens"].shape[1]
        consts = self.consts(S, dcfg)

        def body(xc, p):
            y, kv = self.prefill_block(p, consts, xc, dcfg)
            return y, kv

        from jax import lax as _lax
        x, cache = _lax.scan(body, x, params_tp["blocks"])
        x = LY.rmsnorm(x, params_tp["final_norm"], cfg.norm_eps,
                       cfg.post_norms)
        xg = LY.sp_gather(x, dcfg)[:, -1:]
        logits = jnp.einsum("bsd,dv->bsv", xg, params_tp["head"],
                            preferred_element_type=jnp.float32)
        return logits[:, 0], cache

    # ------------------------------------------------------------ inputs --
    def input_specs(self, shape: ShapeConfig, dcfg: DistConfig) -> dict:
        cfg = self.cfg
        B = shape.global_batch
        S_text = shape.seq_len - cfg.n_img_tokens
        ids = jax.ShapeDtypeStruct((B, S_text), jnp.int32)
        img = jax.ShapeDtypeStruct((B, cfg.n_img_tokens, cfg.vit_dim),
                                   jnp.float32)
        if shape.kind == "train":
            return {"tokens": ids, "targets": ids, "img_embeds": img,
                    "valid": jax.ShapeDtypeStruct((B, S_text), jnp.float32)}
        if shape.kind == "prefill":
            return {"tokens": ids, "img_embeds": img}
        return {"tok": jax.ShapeDtypeStruct((B,), jnp.int32)}
