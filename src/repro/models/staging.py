"""Stage-stacked storage layout for pipeline-parallel SimpleFSDP training.

Under ``dcfg.pp_axis`` every storage leaf gains a leading stage dim sharded
over the pipe axis (spec ``P(pp_axis, *storage_spec)``): pipe rank s holds
slot s.  Slot contents follow the model's `StageSpec`
(models/common.py):

  * the ``pipelined`` stack's (L, storage...) leaves are RESHAPED to
    (S, L/S, storage...) — stage s owns its contiguous layer slice, real
    data in every slot, per-device block memory divided by S;
  * ``pre_keys`` / ``post_keys`` leaves are zero-filled except on the
    owning slot (0 / S-1).  SPMD needs every rank to trace the embedding
    and head compute, so the non-owning slots exist but hold zeros and
    receive zero gradients (the schedule's rank masks select them away);
  * ``replicated_keys`` leaves hold the SAME values in every slot; their
    gradients are psum'ed over the pipe axis by the staged train step and
    identical AdamW updates keep the slots in sync.

`stage_tree` / `unstage_tree` are exact inverses on the owned data, which is
what keeps checkpoints TOPOLOGY-INDEPENDENT: the Trainer always saves and
restores the plain (unstaged) layout, so a run can move between pp degrees
(and back to pp=1) across restarts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.dist import DistConfig
from repro.core.meta import ParamMeta
from repro.models.common import StageSpec


def _is_meta(x):
    return isinstance(x, ParamMeta)


def stage_storage_specs(model, dcfg: DistConfig) -> dict:
    """PartitionSpecs of the stage-stacked storage layout.

    Partition-independent: every leaf gains the same leading
    P(pp_axis, ...) stage dim regardless of which stage owns it (only the
    SHAPES — stage_abstract_storage — depend on the StageSpec)."""
    if dcfg.pp_axis is None:
        raise ValueError("stage_storage_specs needs dcfg.pp_axis")
    metas = model.metas(dcfg)
    sk = model.stacked_keys
    out = {}
    for k in metas:
        inner = (None,) if k in sk else ()

        def one(m: ParamMeta, inner=inner):
            return P(dcfg.pp_axis, *inner, *tuple(m.storage_spec(dcfg)))

        out[k] = jax.tree.map(one, metas[k], is_leaf=_is_meta)
    return out


def stage_abstract_storage(model, dcfg: DistConfig, spec: StageSpec) -> dict:
    """ShapeDtypeStructs of the stage-stacked layout (dry-run / meta-init)."""
    metas = model.metas(dcfg)
    sk = model.stacked_keys
    S = spec.n_stages
    out = {}
    for k in metas:
        if k == spec.pipelined:
            lead = (S, spec.layers_per_stage)
        elif k in sk:
            lead = (S, sk[k])
        else:
            lead = (S,)

        def one(m: ParamMeta, lead=lead):
            return jax.ShapeDtypeStruct((*lead, *m.storage_shape(dcfg)),
                                        m.dtype)

        out[k] = jax.tree.map(one, metas[k], is_leaf=_is_meta)
    return out


def stage_tree(storage: dict, spec: StageSpec) -> dict:
    """Plain storage (stacked leaves carry their full L dim) -> staged.

    Host-side layout transform over global arrays; placement happens via
    jax.device_put with `stage_storage_specs`.
    """
    S = spec.n_stages
    out = {}
    for k, sub in storage.items():
        owner = spec.owner(k)
        if owner == "sliced":
            out[k] = jax.tree.map(
                lambda a: a.reshape(S, spec.layers_per_stage, *a.shape[1:]),
                sub)
        elif owner == "all":
            out[k] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (S, *a.shape)), sub)
        else:
            out[k] = jax.tree.map(
                lambda a: jnp.zeros((S, *a.shape), a.dtype).at[owner].set(a),
                sub)
    return out


def unstage_tree(staged: dict, spec: StageSpec) -> dict:
    """Inverse of `stage_tree`: staged (S, ...) leaves -> plain storage.

    For replicated keys slot 0 is taken (all slots agree after the pipe-axis
    grad psum); for pre/post keys the owning slot; the pipelined stack's
    slices are re-concatenated in stage order.
    """
    out = {}
    for k, sub in staged.items():
        owner = spec.owner(k)
        if owner == "sliced":
            out[k] = jax.tree.map(
                lambda a: a.reshape(a.shape[0] * a.shape[1], *a.shape[2:]),
                sub)
        elif owner == "all":
            out[k] = jax.tree.map(lambda a: a[0], sub)
        else:
            out[k] = jax.tree.map(lambda a: a[owner], sub)
    return out


def stage_opt_state(opt_state: dict, spec: StageSpec) -> dict:
    """Stage the AdamW moments (storage-shaped trees); `step` is scalar."""
    return {"m": stage_tree(opt_state["m"], spec),
            "v": stage_tree(opt_state["v"], spec),
            "step": opt_state["step"]}


def unstage_opt_state(opt_state: dict, spec: StageSpec) -> dict:
    return {"m": unstage_tree(opt_state["m"], spec),
            "v": unstage_tree(opt_state["v"], spec),
            "step": opt_state["step"]}
