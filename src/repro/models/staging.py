"""Stage-stacked storage layout for pipeline-parallel SimpleFSDP training.

Under ``dcfg.pp_axis`` every storage leaf gains a leading stage dim sharded
over the pipe axis (spec ``P(pp_axis, *storage_spec)``): pipe rank s holds
slot s.  Slot contents follow the model's `StageSpec`
(models/common.py):

  * the ``pipelined`` stack's (L, storage...) leaves are RESHAPED to
    (S, L/S, storage...) — stage s owns its contiguous layer slice, real
    data in every slot, per-device block memory divided by S.  With
    ``spec.virtual = V > 1`` (interleaved schedule) the layout is
    (S, V, L/(S*V), storage...): slot [s, v] holds virtual-stage chunk
    j = v*S + s of the layer order, so rank s owns V NON-CONTIGUOUS slices.
    With ``spec.stage_layers`` (uneven stages, e.g. zamba2 superblocks)
    stage s holds its stage_layers[s] real layers zero-padded to
    layers_per_stage — the model's stage_blocks must make the zero-padding
    layers exact identities;
  * ``pre_keys`` / ``post_keys`` leaves are PIPE-SHARDED when their
    per-device FSDP chunk divides by S (core/meta.pipe_shardable — compute
    `pipe_sharded_groups` once and pass it in): the owner's storage is
    split (S, chunk/S) across the pipe ranks and re-assembled per step with
    one pipe-axis all-gather (core/collectives.pipe_param_gather), so no
    rank carries a full-size zero buffer and the memory simulator's staging
    term matches device reality.  Groups that don't divide fall back to the
    original zero-fill (owner slot real, others zero — SPMD still traces
    the embedding/head on every rank either way);
  * ``replicated_keys`` leaves hold the SAME values in every slot; their
    gradients are psum'ed over the pipe axis by the staged train step and
    identical AdamW updates keep the slots in sync.

`stage_tree` / `unstage_tree` are exact inverses on the owned data, which is
what keeps checkpoints TOPOLOGY-INDEPENDENT: the Trainer always saves and
restores the plain (unstaged) layout, so a run can move between pp degrees
(and back to pp=1) across restarts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.dist import DistConfig
from repro.core.meta import ParamMeta, pipe_shardable
from repro.models.common import StageSpec


def _is_meta(x):
    return isinstance(x, ParamMeta)


def pipe_sharded_groups(model, dcfg: DistConfig | None,
                        spec: StageSpec) -> frozenset:
    """The single-owner (pre/post) groups stored pipe-SHARDED under this
    (model, dcfg, spec) — the one decision point shared by stage_tree /
    unstage_tree / the abstract shapes / the train step / the memory
    simulator, so layouts can never disagree."""
    if dcfg is None or dcfg.pp_axis is None or dcfg.pp_size <= 1:
        return frozenset()
    metas = model.metas(dcfg)
    return frozenset(
        k for k in metas
        if isinstance(spec.owner(k), int) and pipe_shardable(metas[k], dcfg))


def _pipe_shard(a, S: int, fsdp: int):
    """(..., pl) -> (S, ..., pl/S): within EVERY per-device FSDP chunk of
    the flat storage, pipe rank r takes the r-th 1/S slice — so a tiled
    pipe-axis all-gather of the (fsdp-sharded) slices reconstructs each
    device's ordinary FSDP chunk exactly (core/collectives.
    pipe_param_gather)."""
    *lead, pl = a.shape
    q = pl // (fsdp * S)
    b = a.reshape(*lead, fsdp, S, q)
    b = jnp.moveaxis(b, -2, 0)
    return b.reshape(S, *lead, pl // S)


def _pipe_unshard(a, fsdp: int):
    """Exact inverse of `_pipe_shard`."""
    S = a.shape[0]
    lead, pls = list(a.shape[1:-1]), a.shape[-1]
    q = pls // fsdp
    b = a.reshape(S, *lead, fsdp, q)
    b = jnp.moveaxis(b, 0, -2)
    return b.reshape(*lead, S * fsdp * q)


def _stage_stack(a, spec: StageSpec):
    """(L, storage...) pipelined stack -> the staged slot layout."""
    S, Lp, V = spec.n_stages, spec.layers_per_stage, spec.virtual
    if spec.stage_layers is not None:
        out = jnp.zeros((S, Lp, *a.shape[1:]), a.dtype)
        off = 0
        for s, n in enumerate(spec.stage_layers):
            out = out.at[s, :n].set(a[off:off + n])
            off += n
        return out
    if V > 1:
        b = a.reshape(V, S, Lp // V, *a.shape[1:])
        return jnp.moveaxis(b, 0, 1)          # (S, V, Lp/V, ...)
    return a.reshape(S, Lp, *a.shape[1:])


def _unstage_stack(a, spec: StageSpec):
    if spec.stage_layers is not None:
        return jnp.concatenate(
            [a[s, :n] for s, n in enumerate(spec.stage_layers)], axis=0)
    if spec.virtual > 1:
        b = jnp.moveaxis(a, 1, 0)             # (V, S, Lp/V, ...)
        return b.reshape(b.shape[0] * b.shape[1] * b.shape[2], *b.shape[3:])
    return a.reshape(a.shape[0] * a.shape[1], *a.shape[2:])


def stage_storage_specs(model, dcfg: DistConfig,
                        spec: StageSpec | None = None) -> dict:
    """PartitionSpecs of the stage-stacked storage layout.

    Near-partition-independent: every leaf gains the same leading
    P(pp_axis, ...) stage dim (pipe-sharded groups keep the SAME spec —
    only their trailing length changes); the interleaved (S, V, L/(S*V))
    stack needs `spec` for its extra unsharded chunk dim."""
    if dcfg.pp_axis is None:
        raise ValueError("stage_storage_specs needs dcfg.pp_axis")
    metas = model.metas(dcfg)
    sk = model.stacked_keys
    out = {}
    for k in metas:
        inner = (None,) if k in sk else ()
        if (spec is not None and k == spec.pipelined and spec.virtual > 1):
            inner = (None, None)               # (V, Lp/V) chunk dims

        def one(m: ParamMeta, inner=inner):
            return P(dcfg.pp_axis, *inner, *tuple(m.storage_spec(dcfg)))

        out[k] = jax.tree.map(one, metas[k], is_leaf=_is_meta)
    return out


def stage_abstract_storage(model, dcfg: DistConfig, spec: StageSpec) -> dict:
    """ShapeDtypeStructs of the stage-stacked layout (dry-run / meta-init)."""
    metas = model.metas(dcfg)
    sk = model.stacked_keys
    S = spec.n_stages
    sharded = pipe_sharded_groups(model, dcfg, spec)
    out = {}
    for k in metas:
        if k == spec.pipelined:
            if spec.virtual > 1:
                lead = (S, spec.virtual, spec.layers_per_stage // spec.virtual)
            else:
                lead = (S, spec.layers_per_stage)
        elif k in sk:
            lead = (S, sk[k])
        else:
            lead = (S,)
        div = S if k in sharded else 1

        def one(m: ParamMeta, lead=lead, div=div):
            shape = m.storage_shape(dcfg)
            shape = (*shape[:-1], shape[-1] // div)
            return jax.ShapeDtypeStruct((*lead, *shape), m.dtype)

        out[k] = jax.tree.map(one, metas[k], is_leaf=_is_meta)
    return out


def stage_tree(storage: dict, spec: StageSpec, dcfg: DistConfig | None = None,
               sharded: frozenset = frozenset()) -> dict:
    """Plain storage (stacked leaves carry their full L dim) -> staged.

    Host-side layout transform over global arrays; placement happens via
    jax.device_put with `stage_storage_specs`.  `sharded` names the
    single-owner groups stored pipe-sharded (`pipe_sharded_groups`; needs
    `dcfg` for the FSDP degree) — others zero-fill non-owner slots.
    """
    S = spec.n_stages
    out = {}
    for k, sub in storage.items():
        owner = spec.owner(k)
        if owner == "sliced":
            out[k] = jax.tree.map(lambda a: _stage_stack(a, spec), sub)
        elif owner == "all":
            out[k] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (S, *a.shape)), sub)
        elif k in sharded:
            fsdp = dcfg.fsdp_size
            out[k] = jax.tree.map(lambda a: _pipe_shard(a, S, fsdp), sub)
        else:
            out[k] = jax.tree.map(
                lambda a: jnp.zeros((S, *a.shape), a.dtype).at[owner].set(a),
                sub)
    return out


def unstage_tree(staged: dict, spec: StageSpec,
                 dcfg: DistConfig | None = None,
                 sharded: frozenset = frozenset()) -> dict:
    """Inverse of `stage_tree`: staged (S, ...) leaves -> plain storage.

    For replicated keys slot 0 is taken (all slots agree after the pipe-axis
    grad psum); pipe-sharded groups are re-assembled from their slices;
    other pre/post keys take the owning slot; the pipelined stack's slices
    are re-concatenated in stage (and virtual-chunk) order.
    """
    out = {}
    for k, sub in staged.items():
        owner = spec.owner(k)
        if owner == "sliced":
            out[k] = jax.tree.map(lambda a: _unstage_stack(a, spec), sub)
        elif owner == "all":
            out[k] = jax.tree.map(lambda a: a[0], sub)
        elif k in sharded:
            fsdp = dcfg.fsdp_size
            out[k] = jax.tree.map(lambda a: _pipe_unshard(a, fsdp), sub)
        else:
            out[k] = jax.tree.map(lambda a: a[owner], sub)
    return out


def stage_opt_state(opt_state: dict, spec: StageSpec,
                    dcfg: DistConfig | None = None,
                    sharded: frozenset = frozenset()) -> dict:
    """Stage the AdamW moments (and the error-feedback accumulator when
    present — all storage-shaped trees); `step` is scalar."""
    return {k: (v if k == "step" else stage_tree(v, spec, dcfg, sharded))
            for k, v in opt_state.items()}


def unstage_opt_state(opt_state: dict, spec: StageSpec,
                      dcfg: DistConfig | None = None,
                      sharded: frozenset = frozenset()) -> dict:
    return {k: (v if k == "step" else unstage_tree(v, spec, dcfg, sharded))
            for k, v in opt_state.items()}
