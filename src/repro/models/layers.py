"""Shared TP/SP-aware layer primitives (run *inside* shard_map).

Activation convention between blocks: sequence-parallel (SP) layout
``(batch_local, seq/tp, d_model)``. Each unit gathers the sequence over the
TP axis on entry and reduce-scatters partial sums back on exit — the
Megatron-SP pattern, which both halves activation memory and turns the TP
all-reduce into all-gather + reduce-scatter.

Every unit comes as a (metas, init, apply) triple over plain dicts. Params
enter `apply` already FSDP-gathered (TP-local compute tensors) — gathering is
the caller's job via core.stack / core.collectives.

TP head handling (DESIGN.md adaptation notes):
  * query heads are padded up to a multiple of tp; padded heads are hard
    masked (zero output, zero grads) via a per-rank head mask;
  * kv projections TP-shard when n_kv % tp == 0, otherwise they are
    TP-replicated — every rank computes all kv heads and slices the groups
    its local q heads need (kv-proj compute is negligible; gradients stay
    exactly correct thanks to vma's automatic replication handling).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.dist import DistConfig
from repro.core.meta import ParamMeta
from repro.models.common import ArchConfig


# ---------------------------------------------------------------------------
# SP plumbing
# ---------------------------------------------------------------------------
# NOTE: no tp_size==1 fast paths anywhere — collectives over a size-1 axis
# are free, and skipping them would leave vma (varying-manual-axes) markings
# inconsistent between single- and multi-rank meshes.
def sp_gather(x, dcfg: DistConfig):
    """(B, S/tp, D) -> (B, S, D)."""
    return lax.all_gather(x, dcfg.tp_axis, axis=1, tiled=True)


def sp_scatter(x, dcfg: DistConfig):
    """(B, S, D) partial-sums -> (B, S/tp, D) reduced."""
    return lax.psum_scatter(x, dcfg.tp_axis, scatter_dimension=1, tiled=True)


def tp_rank(dcfg: DistConfig):
    return lax.axis_index(dcfg.tp_axis)


def tp_psum(x, dcfg: DistConfig):
    return lax.psum(x, dcfg.tp_axis)


def sp_slice(x, dcfg: DistConfig):
    """Full (B, S, D) with identical values per rank -> SP (B, S/tp, D) by
    local slicing (no collective)."""
    shard = x.shape[1] // dcfg.tp_size
    return lax.dynamic_slice_in_dim(x, tp_rank(dcfg) * shard, shard, axis=1)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps: float = 1e-5, unit_offset: bool = False):
    from repro.kernels.rmsnorm import ops as rms_ops
    return rms_ops.rmsnorm(x, w, eps=eps, unit_offset=unit_offset)


def norm_meta(name: str, d: int, dtype) -> ParamMeta:
    return ParamMeta(name, (d,), tp_dim=None, dtype=dtype)


def norm_init(d: int, unit_offset: bool = False):
    # gemma-style norms store (w - 1) when unit_offset; zeros either way is
    # identity for unit_offset=True, ones for standard RMSNorm.
    return jnp.zeros((d,)) if unit_offset else jnp.ones((d,))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_cache(seq_len: int, head_dim: int, theta: float,
               positions=None, dtype=jnp.float32):
    """cos/sin tables (S, hd/2). `positions` overrides 0..S-1 (decode)."""
    if positions is None:
        positions = jnp.arange(seq_len)
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2) / head_dim))
    ang = positions[:, None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def rope_pos(positions, head_dim: int, theta: float, dtype=jnp.float32):
    """cos/sin for an explicit per-request position grid.

    positions: (B, S) int — each row its own offsets (ragged decode /
    chunked prefill).  Returns (B, S, hd/2) tables for `apply_rope_pos`."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2) / head_dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope_pos(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) from `rope_pos`."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core (reference + chunked-online-softmax used for long context)
# ---------------------------------------------------------------------------
def _softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  q_scale=None):
    """q: (B, S, H, hd); k/v: (B, S, Kh, hd) with H % Kh == 0. Quadratic —
    used for seq <= ~8k; longer sequences route to attention_chunked."""
    B, S, H, hd = q.shape
    Kh = k.shape[2]
    group = H // Kh
    scale = q_scale if q_scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, Kh, group, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg * scale, k,
                        preferred_element_type=jnp.float32)
    scores = _softcap(scores, softcap)
    pos_q = jnp.arange(S)[:, None]
    pos_k = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((S, k.shape[1]), bool)
    if causal:
        mask &= pos_q >= pos_k
    if window is not None:
        mask &= pos_q - pos_k < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def attention_chunked(q, k, v, *, causal=True, window=None, softcap=None,
                      q_scale=None, q_chunk=512, kv_chunk=1024):
    """Flash-style online-softmax attention in pure lax (the lowering used
    by dry-runs and long-context cells; the Pallas kernel in
    repro/kernels/flash_attention mirrors this blocking on real TPUs)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    Kh = k.shape[2]
    group = H // Kh
    scale = q_scale if q_scale is not None else 1.0 / math.sqrt(hd)
    nq = -(-S // q_chunk)
    nk = -(-T // kv_chunk)
    qp = jnp.pad(q, ((0, 0), (0, nq * q_chunk - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kv_chunk - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kv_chunk - T), (0, 0), (0, 0)))
    qp = qp.reshape(B, nq, q_chunk, Kh, group, hd)
    kp = kp.reshape(B, nk, kv_chunk, Kh, hd)
    vp = vp.reshape(B, nk, kv_chunk, Kh, hd)

    def per_batch(qb, kb, vb):
        # qb: (nq, qc, Kh, g, hd); kb/vb: (nk, kc, Kh, hd)
        def q_step(_, qi_idx):
            qi, iq = qi_idx
            q_pos = iq * q_chunk + jnp.arange(q_chunk)

            def kv_step(carry, inp):
                acc, m, l = carry
                kj, vj, jk = inp
                k_pos = jk * kv_chunk + jnp.arange(kv_chunk)
                s = jnp.einsum("qkgh,tkh->kgqt", qi * scale, kj,
                               preferred_element_type=jnp.float32)
                s = _softcap(s, softcap)
                msk = jnp.ones((q_chunk, kv_chunk), bool)
                if causal:
                    msk &= q_pos[:, None] >= k_pos[None, :]
                if window is not None:
                    msk &= q_pos[:, None] - k_pos[None, :] < window
                msk &= (k_pos < T)[None, :]
                s = jnp.where(msk[None, None], s, -1e30)
                m_new = jnp.maximum(m, s.max(-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + p.sum(-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "kgqt,tkh->kgqh", p, vj.astype(jnp.float32))
                return (acc_new, m_new, l_new), None

            acc0 = jnp.zeros((Kh, group, q_chunk, hd), jnp.float32)
            m0 = jnp.full((Kh, group, q_chunk), -jnp.inf)
            l0 = jnp.zeros((Kh, group, q_chunk))
            (acc, m, l), _ = lax.scan(kv_step, (acc0, m0, l0),
                                      (kb, vb, jnp.arange(nk)))
            out = acc / jnp.maximum(l[..., None], 1e-30)
            # (Kh, g, qc, hd) -> (qc, Kh, g, hd)
            return None, jnp.moveaxis(out, 2, 0)

        # remat per q-chunk: backward recomputes one (qc x T) row band at a
        # time instead of saving all S x T attention weights (flash-bwd
        # memory behaviour, in pure lax)
        _, outs = lax.scan(jax.checkpoint(q_step), None,
                           (qb, jnp.arange(nq)))
        return outs.reshape(nq * q_chunk, Kh * group, hd)[:S]

    out = jax.vmap(per_batch)(qp, kp, vp)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def attention(q, k, v, **kw):
    """Dispatch: quadratic ref for short seq, chunked (online-softmax,
    q-chunk remat) beyond — the S x T score matrix is never live."""
    if q.shape[1] * k.shape[1] <= 1024 * 1024:
        kw.pop("q_chunk", None), kw.pop("kv_chunk", None)
        return attention_ref(q, k, v, **kw)
    return attention_chunked(q, k, v, **kw)


# ---------------------------------------------------------------------------
# Vocab-parallel embedding (+ reduce-scatter into SP)
# ---------------------------------------------------------------------------
def embed_meta(name: str, cfg: ArchConfig, dtype) -> ParamMeta:
    return ParamMeta(name, (cfg.vocab, cfg.d_model), tp_dim=0, dtype=dtype)


def embed_init(key, cfg: ArchConfig):
    return jax.random.normal(key, (cfg.vocab, cfg.d_model)) * 0.02


def embed_apply(table_local, ids, cfg: ArchConfig, dcfg: DistConfig,
                scale: float | None = None, scatter: bool = True):
    """table_local: (V/tp, D); ids: (B, S) -> SP (B, S/tp, D)."""
    vshard = cfg.vocab // dcfg.tp_size
    lo = tp_rank(dcfg) * vshard
    local_ids = jnp.clip(ids - lo, 0, vshard - 1)
    hit = (ids >= lo) & (ids < lo + vshard)
    x = jnp.take(table_local, local_ids, axis=0)
    x = jnp.where(hit[..., None], x, 0).astype(dcfg.param_dtype)
    if scale is not None:
        x = x * jnp.asarray(scale, dcfg.param_dtype)
    if not scatter:
        return lax.psum(x, dcfg.tp_axis)
    return sp_scatter(x, dcfg)


# ---------------------------------------------------------------------------
# Vocab-parallel LM head + fused stable cross-entropy (never materializes
# softmax over the full vocab; reductions ride psum/pmax over the TP axis).
# ---------------------------------------------------------------------------
def head_meta(name: str, cfg: ArchConfig, dtype) -> ParamMeta:
    return ParamMeta(name, (cfg.d_model, cfg.vocab), tp_dim=1, dtype=dtype)


def head_init(key, cfg: ArchConfig):
    return jax.random.normal(key, (cfg.d_model, cfg.vocab)) \
        * (0.02 / math.sqrt(2 * cfg.n_layers))


def head_logits(w_local, x, cfg: ArchConfig, dcfg: DistConfig):
    """x: (B, S, D) gathered -> local-vocab logits (B, S, V/tp), fp32."""
    logits = jnp.einsum("bsd,dv->bsv", x, w_local,
                        preferred_element_type=jnp.float32)
    return _softcap(logits, cfg.final_softcap)


def vocab_parallel_xent(logits_local, targets, valid, cfg: ArchConfig,
                        dcfg: DistConfig, z_coef: float = 0.0):
    """Stable CE over TP-sharded vocab. Returns (local mean loss, aux)."""
    vshard = cfg.vocab // dcfg.tp_size
    lo = tp_rank(dcfg) * vshard
    # the max is a numerical stabilizer only (exactly-zero gradient in
    # logsumexp); pmax has no AD rule, so compute it out-of-graph via
    # all_gather+max on a stop_gradient'ed operand.
    m_loc = lax.stop_gradient(logits_local.max(-1))
    m = lax.all_gather(m_loc, dcfg.tp_axis, axis=0, tiled=False).max(0)
    se = jnp.exp(logits_local - m[..., None]).sum(-1)
    tgt_local = jnp.clip(targets - lo, 0, vshard - 1)
    hit = (targets >= lo) & (targets < lo + vshard)
    tl = jnp.take_along_axis(logits_local, tgt_local[..., None],
                             axis=-1)[..., 0]
    tl = jnp.where(hit, tl, 0.0)
    se = lax.psum(se, dcfg.tp_axis)
    tl = lax.psum(tl, dcfg.tp_axis)
    lse = jnp.log(se) + m
    per_tok = (lse - tl) * valid
    denom = jnp.maximum(valid.sum(), 1.0)
    loss = per_tok.sum() / denom
    if z_coef:
        loss = loss + z_coef * ((lse * valid) ** 2).sum() / denom
    # SPMD gradient convention: every TP rank computes this same loss, and
    # cotangents crossing the sequence-parallel all_gather/reduce_scatter
    # transposes SUM over ranks — the differentiated objective is
    # sum_t(loss_t). Dividing by tp makes that sum the desired mean.
    # (Verified against single-device references in tests/dist_harness.py.)
    loss = loss / dcfg.tp_size
    return loss, {}


# ---------------------------------------------------------------------------
# Attention unit (one layer)
# ---------------------------------------------------------------------------
def attn_metas(cfg: ArchConfig, dcfg: DistConfig, dtype,
               prefix: str = "") -> dict:
    d, hd, tp = cfg.d_model, cfg.head_dim, dcfg.tp_size
    lay = cfg.gqa_layout(tp)
    hq, kvp = lay["hq"], lay["kvp"]
    kv_tp = 0 if lay["mode"] == "sharded" else None
    metas = {
        "wq": ParamMeta(prefix + "wq", (d, hq * hd), tp_dim=1, dtype=dtype),
        "wk": ParamMeta(prefix + "wk", (kvp * hd, d),
                        tp_dim=kv_tp, dtype=dtype),
        "wv": ParamMeta(prefix + "wv", (kvp * hd, d),
                        tp_dim=kv_tp, dtype=dtype),
        "wo": ParamMeta(prefix + "wo", (hq * hd, d), tp_dim=0, dtype=dtype),
    }
    if cfg.qk_norm:
        metas["q_norm"] = ParamMeta(prefix + "q_norm", (hd,), None, dtype)
        metas["k_norm"] = ParamMeta(prefix + "k_norm", (hd,), None, dtype)
    return metas


def attn_init(key, cfg: ArchConfig, dcfg: DistConfig) -> dict:
    d, hd, tp = cfg.d_model, cfg.head_dim, dcfg.tp_size
    lay = cfg.gqa_layout(tp)
    hq, kvp = lay["hq"], lay["kvp"]
    ks = jax.random.split(key, 4)
    sd = 0.02
    p = {
        "wq": jax.random.normal(ks[0], (d, hq * hd)) * sd,
        "wk": jax.random.normal(ks[1], (kvp * hd, d)) * sd,
        "wv": jax.random.normal(ks[2], (kvp * hd, d)) * sd,
        "wo": jax.random.normal(ks[3], (hq * hd, d))
        * (sd / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    return p


def _local_qkv(p, xg, cfg: ArchConfig, dcfg: DistConfig):
    """Project to this rank's q heads + the kv heads they need.

    Layout from cfg.gqa_layout (mesh-independent padding): each rank's q
    heads map to a CONTIGUOUS slice of kl = max(1, kvp/tp) kv heads, so the
    decode cache stores exactly kl heads per rank (no per-q-head
    duplication). Returns q (B,S,Hl,hd), k/v (B,S,Kl,hd), head_mask (Hl,)
    zeroing padded q heads.
    """
    B, S, _ = xg.shape
    hd, tp = cfg.head_dim, dcfg.tp_size
    lay = cfg.gqa_layout(tp)
    hq_pad, kvp, g = lay["hq"], lay["kvp"], lay["g"]
    hl = hq_pad // tp
    rank = tp_rank(dcfg)

    q = jnp.einsum("bsd,dh->bsh", xg, p["wq"]).reshape(B, S, hl, hd)
    gids = rank * hl + jnp.arange(hl)
    if lay["mode"] == "sharded":
        head_mask = jnp.ones((hl,), q.dtype)
        kl = kvp // tp
        k = jnp.einsum("bsd,hd->bsh", xg, p["wk"]).reshape(B, S, kl, hd)
        v = jnp.einsum("bsd,hd->bsh", xg, p["wv"]).reshape(B, S, kl, hd)
        return q, k, v, head_mask

    # grouped: hard-mask padded q heads / dead kv groups
    head_mask = ((gids // g < cfg.n_kv_heads)
                 & (gids % g < lay["g_real"])).astype(q.dtype)
    k_all = jnp.einsum("bsd,hd->bsh", xg, p["wk"]).reshape(B, S, kvp, hd)
    v_all = jnp.einsum("bsd,hd->bsh", xg, p["wv"]).reshape(B, S, kvp, hd)
    kl = max(1, kvp // tp)
    kv_start = (rank * hl) // g
    k = lax.dynamic_slice_in_dim(k_all, kv_start, kl, axis=2)
    v = lax.dynamic_slice_in_dim(v_all, kv_start, kl, axis=2)
    return q, k, v, head_mask


def attn_apply(p, x_sp, consts, cfg: ArchConfig, dcfg: DistConfig,
               window=None, q_scale=None):
    """Full attention sublayer on SP activations (train/prefill path).

    Under context parallelism (``dcfg.cp_axis``) the SP activations are
    additionally a ZIGZAG sequence shard: RoPE phases are looked up at this
    rank's GLOBAL positions and the attention itself runs as the ctx-axis
    ring (core/context.ring_attention — KV blocks circulate, exchange
    overlapped behind per-hop compute, exact reverse-ring gradients);
    causal/sliding-window/softcap masking applies per block from global
    positions, so gemma2's local layers skip out-of-window hops."""
    xg = sp_gather(x_sp, dcfg)
    q, k, v, head_mask = _local_qkv(p, xg, cfg, dcfg)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = consts["rope_cos"], consts["rope_sin"]
    if dcfg.cp_size > 1:
        from repro.core import context as CX
        seq_global = xg.shape[1] * dcfg.cp_size
        pos = CX.shard_positions(dcfg, seq_global)
        cos = jnp.take(cos, pos, axis=0)
        sin = jnp.take(sin, pos, axis=0)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        out = CX.ring_attention(q, k, v, dcfg=dcfg, seq_len=seq_global,
                                causal=True, window=window,
                                softcap=cfg.attn_softcap, q_scale=q_scale)
    else:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        out = attention(q, k, v, causal=True, window=window,
                        softcap=cfg.attn_softcap, q_scale=q_scale)
    out = out * head_mask[None, None, :, None]
    B, S, hl, hd = out.shape
    o = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, hl * hd), p["wo"])
    return sp_scatter(o, dcfg)


# ---------------------------------------------------------------------------
# Gated MLP unit
# ---------------------------------------------------------------------------
def mlp_metas(cfg: ArchConfig, dcfg: DistConfig, dtype, prefix: str = "",
              d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    m = {
        "wu": ParamMeta(prefix + "wu", (d, f), tp_dim=1, dtype=dtype),
        "wd": ParamMeta(prefix + "wd", (f, d), tp_dim=0, dtype=dtype),
    }
    if cfg.gated_mlp != "gelu":   # gated variants carry a gate matrix
        m["wg"] = ParamMeta(prefix + "wg", (d, f), tp_dim=1, dtype=dtype)
    return m


def mlp_init(key, cfg: ArchConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    sd = 0.02
    p = {
        "wu": jax.random.normal(ks[1], (d, f)) * sd,
        "wd": jax.random.normal(ks[2], (f, d))
        * (sd / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.gated_mlp != "gelu":
        p["wg"] = jax.random.normal(ks[0], (d, f)) * sd
    return p


def mlp_apply(p, x_sp, cfg: ArchConfig, dcfg: DistConfig):
    xg = sp_gather(x_sp, dcfg)
    u = jnp.einsum("bsd,df->bsf", xg, p["wu"])
    if cfg.gated_mlp == "gelu":       # plain 2-matrix FFN
        h = jax.nn.gelu(u, approximate=True)
    else:
        g = jnp.einsum("bsd,df->bsf", xg, p["wg"])
        act = jax.nn.gelu(g, approximate=True) \
            if cfg.gated_mlp == "geglu" else jax.nn.silu(g)
        h = act * u
    o = jnp.einsum("bsf,fd->bsd", h, p["wd"])
    return sp_scatter(o, dcfg)


# ---------------------------------------------------------------------------
# Quantized KV cache (kernels/quant codec: per-128-chunk f32 scales over
# each head vector — the SAME audited path the wire collectives use, so
# cache and collective quantization cannot drift).
# ---------------------------------------------------------------------------
def kv_quantize(x, codec="int8"):
    """x: (..., hd) -> (wire values (..., hd), f32 scales (..., nc))."""
    from repro.kernels.quant import ops as QOPS
    return QOPS.encode_kv(x, codec)


def kv_dequantize(q, s, dtype):
    from repro.kernels.quant import ops as QOPS
    return QOPS.decode_kv(q, s, dtype)
