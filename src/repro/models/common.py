"""Architecture config schema shared by all 10 assigned model families.

`ArchConfig` is a superset schema: each family reads the fields it needs.
`ShapeConfig` describes one assigned (seq_len, global_batch, kind) cell.
TP-divisibility padding (head counts) is resolved here and recorded on the
config so DESIGN.md's adaptation notes match the code.

`BlockSegments` is the segmented block contract consumed by
`core/stack._prefetch_stack`: it splits one block into an ordered chain of
segments mapped to bucket groups, which is what lets the runtime pipeline
all-gathers at BUCKET granularity (segment s's compute hides segment s+1's
gather) instead of gathering the whole layer at one program point.

`StageSpec` is the stage-partition contract: how a model's top-level param
groups map onto S pipeline stages (embedding-side groups on stage 0, the
layer stack sliced contiguously via its existing stacked leading dim,
head+loss groups on the last stage, with groups consumed by EVERY stage —
tied embeddings, zamba2's shared block — replicated and grad-synced over the
pipe axis).  Every model implements ``stage_spec(n_stages)`` plus the three
stage compute methods (``stage_pre`` / ``stage_blocks`` / ``stage_loss``)
and declares ``stacked_keys``; `core/api.plan_parallel` resolves and
validates the spec into the frozen `ParallelPlan`, and the single `Trainer`
drives it through `core/pipeline` (see models/staging.py for the storage
layout).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # 'train' | 'prefill' | 'decode'


SHAPE_SUITE = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPE_SUITE:
        if s.name == name:
            return s
    raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class BlockSegments:
    """Ordered segment chain of ONE block (the segmented block contract).

    A block `block_fn(params, consts, x) -> (y, aux)` is re-expressed as a
    chain  state_0 = x  ->  fns[0]  ->  ...  ->  fns[S-1]  ->  (y, aux):

      * ``names``       — segment labels, execution order (e.g. attn, mlp);
      * ``param_globs``  — per-segment fnmatch globs over the block's param
        names (ParamMeta paths). Every param must match exactly one segment
        — the FIRST whose globs match — and the segment that owns a param
        must be the first that consumes it: segment s's gathered tensors
        are the only ones populated when fns[s] runs (core/stack passes the
        metas-shaped tree with foreign leaves set to None, so touching a
        param owned by a later segment fails at trace time);
      * ``fns``         — fns[s](params_masked, consts, state) -> state.
        Intermediate state is any pytree; the last segment returns the
        block's (y, aux).

    Bucket plans are split at segment boundaries by the stack, so each
    bucket belongs to one segment and the prefetch schedule (forward and
    hand-written VJP) pipelines gather/compute per bucket. Declaring no
    segments (or cfg.segment_prefetch=False) keeps the whole-layer gather
    schedule.
    """

    names: tuple[str, ...]
    param_globs: tuple[tuple[str, ...], ...]
    fns: tuple[Callable, ...]

    def __post_init__(self):
        if not (len(self.names) == len(self.param_globs) == len(self.fns)):
            raise ValueError("BlockSegments fields must be parallel, got "
                             f"{len(self.names)}/{len(self.param_globs)}/"
                             f"{len(self.fns)}")


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """Stage-partition contract: top-level param groups -> S pipeline stages.

    * ``pipelined``        — the stacked metas key whose leading layer dim is
      sliced CONTIGUOUSLY into S equal chunks (stage s owns layers
      [s*layers_per_stage, (s+1)*layers_per_stage); a reshape of the
      existing (L, ...) stack to (S, L/S, ...)).
    * ``layers_per_stage`` — that equal chunk size (scan steps per stage).
    * ``pre_keys``         — groups owned by stage 0 only (embedding /
      modality frontends; zero-filled on other stages' storage slots).
    * ``post_keys``        — groups owned by the LAST stage only (final
      norm + LM head + loss-side params).
    * ``replicated_keys``  — groups consumed by EVERY stage (tied embedding
      tables, zamba2's shared attention block): every stage slot holds the
      same values and their gradients are psum'ed over the pipe axis (the
      pipe axis is otherwise excluded from grad sync — stages own disjoint
      parameters).

    Together the four sets must cover the model's top-level metas keys
    exactly once — validated by `core/api.plan_parallel`.
    """

    n_stages: int
    pipelined: str
    layers_per_stage: int
    pre_keys: tuple[str, ...]
    post_keys: tuple[str, ...]
    replicated_keys: tuple[str, ...] = ()
    # Virtual stages per pipe rank (interleaved schedule): the stack is laid
    # out (S, V, layers_per_stage/V, storage...) and chunk j = v*S + s (the
    # j-th slice of the layer order) lives at slot [s, v].  1 = plain
    # contiguous staging; set by plan_parallel from the resolved schedule.
    virtual: int = 1
    # Uneven stage sizes: stage_layers[s] REAL layers on stage s (models
    # whose block granularity doesn't divide L, e.g. zamba2 superblocks).
    # The stack is still stored (S, layers_per_stage, ...) with
    # layers_per_stage = max needed; the tail of a short stage is
    # ZERO-PADDED and the model's stage_blocks must make padding layers
    # exact identities (zamba2: zero-param blocks).  None = even.
    stage_layers: tuple[int, ...] | None = None
    # Whether the layer stack may be sliced into V > 1 virtual chunks.
    # Models with intra-stage structure that a chunk boundary would break
    # (zamba2's shared-block cadence) set False; the planner then never
    # proposes the interleaved schedule.
    chunkable: bool = True

    def owner(self, key: str) -> int | str:
        """Stage index owning `key` ('all' for replicated, 'sliced' for the
        pipelined stack)."""
        if key == self.pipelined:
            return "sliced"
        if key in self.replicated_keys:
            return "all"
        if key in self.pre_keys:
            return 0
        if key in self.post_keys:
            return self.n_stages - 1
        raise KeyError(f"{key!r} not covered by this StageSpec")

    def validate(self, metas_keys, stacked_keys: dict) -> None:
        """Coverage exactly once + slice divisibility, with pointed errors."""
        declared = [self.pipelined, *self.pre_keys, *self.post_keys,
                    *self.replicated_keys]
        if len(set(declared)) != len(declared):
            raise ValueError(f"StageSpec assigns a key twice: {declared}")
        missing = set(metas_keys) - set(declared)
        extra = set(declared) - set(metas_keys)
        if missing or extra:
            raise ValueError(
                f"StageSpec must cover every top-level param group exactly "
                f"once; missing={sorted(missing)} unknown={sorted(extra)}")
        if self.pipelined not in stacked_keys:
            raise ValueError(
                f"pipelined key {self.pipelined!r} is not a stacked key "
                f"({sorted(stacked_keys)})")
        L = stacked_keys[self.pipelined]
        if self.stage_layers is None:
            if self.layers_per_stage * self.n_stages != L:
                raise ValueError(
                    f"{self.pipelined!r}: {self.n_stages} stages x "
                    f"{self.layers_per_stage} layers != stack length {L}")
        else:
            if len(self.stage_layers) != self.n_stages:
                raise ValueError(
                    f"stage_layers has {len(self.stage_layers)} entries for "
                    f"{self.n_stages} stages")
            if sum(self.stage_layers) != L:
                raise ValueError(
                    f"{self.pipelined!r}: stage_layers {self.stage_layers} "
                    f"sum to {sum(self.stage_layers)} != stack length {L}")
            if max(self.stage_layers) > self.layers_per_stage:
                raise ValueError(
                    f"stage_layers max {max(self.stage_layers)} exceeds the "
                    f"padded layers_per_stage {self.layers_per_stage}")
            if self.virtual != 1:
                raise ValueError(
                    "uneven stage_layers cannot be interleaved (virtual "
                    f"must be 1, got {self.virtual})")
        if self.virtual < 1:
            raise ValueError(f"virtual must be >= 1, got {self.virtual}")
        if self.virtual > 1:
            if not self.chunkable:
                raise ValueError(
                    f"{self.pipelined!r} is not chunkable (model forbids "
                    "virtual stage slicing) but virtual="
                    f"{self.virtual}")
            if self.layers_per_stage % self.virtual:
                raise ValueError(
                    f"layers_per_stage {self.layers_per_stage} does not "
                    f"split into {self.virtual} virtual chunks")


def even_stage_slices(n_layers: int, n_stages: int, what: str) -> int:
    """layers_per_stage for a contiguous equal partition, or a clear error."""
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")
    if n_layers % n_stages:
        raise ValueError(
            f"{what}: {n_layers} scan steps do not split into "
            f"{n_stages} equal pipeline stages")
    return n_layers // n_stages


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | xlstm | zamba | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5

    # dense variants ---------------------------------------------------------
    qk_norm: bool = False                 # qwen3
    attn_softcap: float | None = None     # gemma2: 50.0
    final_softcap: float | None = None    # gemma2: 30.0
    sliding_window: int | None = None     # gemma2 local layers: 4096
    local_global_alternate: bool = False  # gemma2
    post_norms: bool = False              # gemma2 sandwich norms
    gated_mlp: str = "swiglu"             # swiglu | geglu | gelu
    tie_embeddings: bool = False

    # moe --------------------------------------------------------------------
    n_experts: int = 0
    n_experts_active: int = 0             # top-k
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    d_ff_shared: int = 0
    router_aux_coef: float = 1e-2
    capacity_factor: float = 1.25
    moe_norm_topk: bool = False           # qwen3-moe renormalizes top-k

    # ssm / hybrid -----------------------------------------------------------
    ssm_state: int = 0                    # mamba2 d_state
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    shared_attn_every: int = 0            # zamba2: shared block period
    slstm_every: int = 0                  # xlstm: 1 sLSTM per N blocks

    # enc-dec ----------------------------------------------------------------
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    frontend_dim: int = 0                 # stub frontend embedding width

    # vlm --------------------------------------------------------------------
    vit_dim: int = 0                      # stub ViT output width
    n_img_tokens: int = 0

    # which shape cells this arch runs (long_500k only for O(1)-state decode)
    skip_shapes: tuple[str, ...] = ()

    # recommended pipeline-parallel degree on the production mesh: stages
    # carved out of the data axis (must divide n_layers so stages are equal
    # layer slices and divide the 16-chip data axis). 1 = no pipelining.
    # Consumed (and validated) by launch.mesh.production_dcfg_for(cfg).
    pp_stages: int = 1

    # head/expert counts pad to a multiple of this (>= any runtime tp that
    # divides it), keeping GLOBAL param shapes mesh-independent.
    pad_to: int = 16

    # ------------------------------------------------------------- derived --
    def gqa_layout(self, tp: int) -> dict:
        """TP attention layout, mesh-independent for every tp dividing
        max(pad_to, tp).

        'sharded':  kv heads split over the TP axis (no padding needed).
        'grouped':  kv TP-replicated; q heads padded so each rank's q heads
                    map to a CONTIGUOUS slice of kv heads (usually exactly
                    one) — keeps decode caches at one kv head per rank
                    instead of per-q-head duplicates.

        Returns {mode, hq (padded q heads), kvp (padded kv heads),
                 g (padded group size), g_real (logical group size)}.
        """
        m = max(self.pad_to, tp)
        assert m % tp == 0, f"pad_to {self.pad_to} incompatible with tp={tp}"
        g_real = -(-self.n_heads // self.n_kv_heads)
        if (self.n_kv_heads % m == 0 and self.n_heads % m == 0
                and self.n_heads % self.n_kv_heads == 0):
            return dict(mode="sharded", hq=self.n_heads,
                        kvp=self.n_kv_heads, g=g_real, g_real=g_real)
        if self.n_kv_heads >= m:
            # kv heads exceed the padding quantum but don't divide it:
            # pad kv up to a multiple of m (ranks own kvp/tp heads each)
            kvp = -(-self.n_kv_heads // m) * m
            g = g_real
        else:
            kvp = next(d for d in range(self.n_kv_heads, m + 1)
                       if m % d == 0)
            step = m // kvp
            g = -(-g_real // step) * step
        return dict(mode="grouped", hq=kvp * g, kvp=kvp, g=g, g_real=g_real)

    def q_heads_padded(self, tp: int) -> int:
        return self.gqa_layout(tp)["hq"]

    def kv_heads_padded(self, tp: int) -> int:
        return self.gqa_layout(tp)["kvp"]

    def kv_sharded(self, tp: int) -> bool:
        return self.gqa_layout(tp)["mode"] == "sharded"

    def params_dense_block(self) -> int:
        """Per-layer parameter count (logical, unpadded)."""
        d, f = self.d_model, self.d_ff
        attn = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv_heads * self.head_dim * 2
        mlp = 3 * d * f if self.gated_mlp in ("swiglu", "geglu") else 2 * d * f
        return attn + mlp + 2 * d

    def n_params(self) -> int:
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        if self.family == "moe":
            d = self.d_model
            attn = d * self.n_heads * self.head_dim * 2 \
                + d * self.n_kv_heads * self.head_dim * 2
            experts = 3 * d * self.d_ff_expert * self.n_experts
            shared = 3 * d * self.d_ff_shared if self.d_ff_shared else 0
            per_layer = attn + experts + shared + d * self.n_experts + 2 * d
            return emb + self.n_layers * per_layer
        return emb + self.n_layers * self.params_dense_block()

    def n_params_active(self) -> int:
        """Active params per token (MoE top-k); == n_params for dense."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        attn = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv_heads * self.head_dim * 2
        experts = 3 * d * self.d_ff_expert * self.n_experts_active
        shared = 3 * d * self.d_ff_shared if self.d_ff_shared else 0
        per_layer = attn + experts + shared + d * self.n_experts + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return emb + self.n_layers * per_layer
