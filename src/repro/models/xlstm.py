"""xLSTM family (xlstm-1.3b): 7:1 mLSTM:sLSTM blocks (arXiv:2405.04517).

mLSTM — matrix-memory cell with stabilized exponential gating, implemented
in the CHUNKWISE parallel form (intra-chunk quadratic + inter-chunk state
carry, the same dual structure as Mamba-2's SSD): O(T·d²) compute, O(T/Lc)
scan steps, AD-friendly memory. The per-step recurrent form is used for
decode (O(1) state -> this arch runs the long_500k cell).

sLSTM — scalar-memory cell with recurrent per-head mixing (R matrices),
strictly sequential lax.scan over time.

TP strategy (DESIGN.md): only the *value* path TP-shards cleanly (the C
matrix memory is outer(k) x v — shard the v/output dim); q/k/gate/conv
projections are TP-replicated (vma keeps their grads exact), the output
projection is row-parallel back into sequence-parallel layout. sLSTM blocks
are fully TP-replicated (they are 1/8 of the stack and small).

Simplifications vs. the reference implementation (documented per DESIGN.md):
full-matrix q/k/v projections instead of block-diagonal-4, no learnable
skip-scales; block counts/dims/param budget match the paper's 1.3B config.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives as coll
from repro.core.dist import DistConfig
from repro.core.vmautil import vary_like
from repro.core.irgraph import BlockStats
from repro.core.meta import ParamMeta
from repro.core.stack import apply_stack
from repro.core.remat import maybe_remat
from repro.models import layers as LY
from repro.models.common import (ArchConfig, ShapeConfig, StageSpec,
                                 even_stage_slices)


def _logsig(x):
    return -jax.nn.softplus(-x)


# ---------------------------------------------------------------------------
# mLSTM cell: chunkwise parallel form (training/prefill)
# ---------------------------------------------------------------------------
def mlstm_chunked(q, k, v, i_pre, f_pre, chunk: int = 64, state=None):
    """q,k: (B,T,H,dk); v: (B,T,H,dv); i_pre,f_pre: (B,T,H) pre-activations.
    Returns y: (B,T,H,dv) and final state (C, n, m)."""
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    Lc = min(chunk, T)
    pad = (-T) % Lc
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        z3 = ((0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(a, z4) for a in (q, k, v))
        i_pre = jnp.pad(i_pre, z3)
        f_pre = jnp.pad(f_pre, z3, constant_values=30.0)  # decay ~1 on pad
    nC = (T + pad) // Lc
    scale = dk ** -0.5

    def reshape_c(a):
        return a.reshape(B, nC, Lc, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = reshape_c(q), reshape_c(k), reshape_c(v)   # (nC,B,Lc,H,*)
    ic, fc = reshape_c(i_pre), reshape_c(f_pre)             # (nC,B,Lc,H)

    if state is None:
        C0 = jnp.zeros((B, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
        m0 = jnp.full((B, H), -1e30)
        C0, n0, m0 = vary_like((C0, n0, m0), (q, k, v, i_pre, f_pre))
    else:
        C0, n0, m0 = state

    def chunk_step(carry, inp):
        C_in, n_in, m_in = carry
        qb, kb, vb, ib, fb = inp
        lf = _logsig(fb.astype(jnp.float32))       # (B,Lc,H)
        li = ib.astype(jnp.float32)
        F = jnp.cumsum(lf, axis=1)                 # inclusive
        Ftot = F[:, -1]                            # (B,H)
        # D[t,s] = F_t - F_s + li_s  (s <= t)
        D = F[:, :, None] - F[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((Lc, Lc), bool))
        D = jnp.where(tri[None, :, :, None], D, -jnp.inf)
        m_local = D.max(axis=2)                    # (B,Lc,H)
        m_cross = F + m_in[:, None]                # (B,Lc,H)
        m_t = jnp.maximum(m_local, m_cross)
        m_t = jnp.maximum(m_t, -1e30)
        # intra-chunk
        qf = qb.astype(jnp.float32) * scale
        kf = kb.astype(jnp.float32)
        vf = vb.astype(jnp.float32)
        S = jnp.einsum("blhd,bshd->blsh", qf, kf)
        W = jnp.exp(D - m_t[:, :, None])           # (B,Lc,Lc,H)
        W = jnp.where(tri[None, :, :, None], W, 0.0)
        y_intra = jnp.einsum("blsh,bshv->blhv", S * W, vf)
        n_intra = jnp.einsum("blsh,bshd->blhd", W, kf)
        # inter-chunk (incoming state)
        g_cross = jnp.exp(m_cross - m_t)           # (B,Lc,H)
        y_inter = jnp.einsum("blhd,bhdv->blhv", qf, C_in) \
            * g_cross[..., None]
        n_inter = n_in[:, None] * g_cross[..., None]
        n_t = n_intra + n_inter
        denom = jnp.maximum(jnp.abs(jnp.einsum("blhd,blhd->blh", qf, n_t)),
                            jnp.exp(-m_t))
        y = (y_intra + y_inter) / denom[..., None]
        # outgoing state
        g_out = Ftot[:, None] - F + li             # (B,Lc,H) decay to end
        m_out = jnp.maximum(Ftot + m_in, g_out.max(axis=1))
        W_out = jnp.exp(g_out - m_out[:, None])
        C_out = jnp.exp(Ftot + m_in - m_out)[..., None, None] * C_in \
            + jnp.einsum("bshd,bshv->bhdv", kf * W_out[..., None], vf)
        n_out = jnp.exp(Ftot + m_in - m_out)[..., None] * n_in \
            + jnp.einsum("bshd->bhd", kf * W_out[..., None])
        return (C_out, n_out, m_out), y

    (C, n, m), ys = lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, ic, fc))
    y = ys.swapaxes(0, 1).reshape(B, T + pad, H, dv)[:, :T]
    return y.astype(v.dtype), (C, n, m)


def mlstm_step(state, q, k, v, i_pre, f_pre):
    """Recurrent decode step. q,k: (B,H,dk); v: (B,H,dv); gates (B,H)."""
    C, n, m = state
    scale = q.shape[-1] ** -0.5
    lf = _logsig(f_pre.astype(jnp.float32))
    li = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(lf + m, li)
    fg = jnp.exp(lf + m - m_new)[..., None, None]
    ig = jnp.exp(li - m_new)[..., None, None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = fg * C + ig * (kf[..., :, None] * vf[..., None, :])
    n = fg[..., 0] * n + ig[..., 0] * kf
    qf = q.astype(jnp.float32) * scale
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                        jnp.exp(-m_new))
    y = jnp.einsum("bhd,bhdv->bhv", qf, C) / denom[..., None]
    return (C, n, m_new), y.astype(v.dtype)


# ---------------------------------------------------------------------------
# sLSTM cell (sequential scan; fully replicated under TP)
# ---------------------------------------------------------------------------
def slstm_seq(xg, R, state=None):
    """xg: (B,T,4,H,hd) gate pre-acts [i,f,z,o]; R: (4,H,hd,hd)."""
    B, T, _, H, hd = xg.shape
    if state is None:
        h0 = jnp.zeros((B, H, hd), jnp.float32)
        c0 = jnp.zeros((B, H, hd), jnp.float32)
        n0 = jnp.ones((B, H, hd), jnp.float32)
        m0 = jnp.zeros((B, H, hd), jnp.float32)
        state = vary_like((h0, c0, n0, m0), (xg, R))

    def step(carry, x_t):
        h, c, n, m = carry
        rec = jnp.einsum("bhd,ghde->gbhe", h, R)      # (4,B,H,hd)
        it = x_t[:, 0].astype(jnp.float32) + rec[0]
        ft = x_t[:, 1].astype(jnp.float32) + rec[1]
        zt = x_t[:, 2].astype(jnp.float32) + rec[2]
        ot = x_t[:, 3].astype(jnp.float32) + rec[3]
        lf = _logsig(ft)
        m_new = jnp.maximum(lf + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(lf + m - m_new)
        c_new = f_ * c + i_ * jnp.tanh(zt)
        n_new = f_ * n + i_
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    state, hs = lax.scan(step, state, xg.swapaxes(0, 1))
    return hs.swapaxes(0, 1), state  # (B,T,H,hd)


def causal_conv1d(x, w, state=None):
    """x: (B,T,C); w: (K,C) depthwise causal conv. state: (B,K-1,C)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return out, new_state


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
class XLSTMLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.per = cfg.slstm_every or 8          # 7 mLSTM + 1 sLSTM
        assert cfg.n_layers % self.per == 0
        self.n_steps = cfg.n_layers // self.per
        self.d_inner = cfg.ssm_expand * cfg.d_model
        self.n_heads = cfg.n_heads
        self.dk = self.d_inner // cfg.n_heads

    # ---------------------------------------------------------- sub-metas --
    def _mlstm_metas(self, dcfg, dt, tag):
        d, di, H = self.cfg.d_model, self.d_inner, self.n_heads
        dk = self.dk
        K = self.cfg.ssm_conv
        return {
            "ln": LY.norm_meta(tag + "ln", d, dt),
            "w_x": ParamMeta(tag + "w_x", (d, di), None, dt),
            # value-path tensors shard the PER-HEAD value dim (tp_dim on the
            # explicit head-split layout) so every rank holds dv/tp dims of
            # every head -- a contiguous tp-slice of the flat di dim would
            # straddle head boundaries.
            "w_z": ParamMeta(tag + "w_z", (d, H, dk), 2, dt),
            "conv": ParamMeta(tag + "conv", (K, di), None, dt),
            "wq": ParamMeta(tag + "wq", (di, di), None, dt),
            "wk": ParamMeta(tag + "wk", (di, di), None, dt),
            "wv": ParamMeta(tag + "wv", (di, H, dk), 2, dt),
            "w_if": ParamMeta(tag + "w_if", (di, 2 * H), None, dt),
            "w_out": ParamMeta(tag + "w_out", (H, dk, d), 1, dt),
        }

    def _slstm_metas(self, dcfg, dt, tag):
        d, H = self.cfg.d_model, self.n_heads
        hd = d // H
        return {
            "ln": LY.norm_meta(tag + "ln", d, dt),
            "w_g": ParamMeta(tag + "w_g", (d, 4 * d), None, dt),
            "R": ParamMeta(tag + "R", (4, H, hd, hd), None, dt),
            "w_out": ParamMeta(tag + "w_out", (d, d), None, dt),
        }

    def block_metas(self, dcfg: DistConfig) -> dict:
        dt = dcfg.storage_dtype
        m = {f"m{i}": self._mlstm_metas(dcfg, dt, f"m{i}.")
             for i in range(self.per - 1)}
        m["s"] = self._slstm_metas(dcfg, dt, "s.")
        return m

    def metas(self, dcfg: DistConfig) -> dict:
        dt = dcfg.storage_dtype
        return {
            "embed": LY.embed_meta("embed", self.cfg, dt),
            "blocks": self.block_metas(dcfg),
            "final_norm": LY.norm_meta("final_norm", self.cfg.d_model, dt),
            "head": LY.head_meta("head", self.cfg, dt),
        }

    @property
    def stacked_keys(self) -> dict:
        return {"blocks": self.n_steps}

    def stage_spec(self, n_stages: int) -> StageSpec:
        return StageSpec(
            n_stages=n_stages,
            pipelined="blocks",
            layers_per_stage=even_stage_slices(self.n_steps, n_stages,
                                               self.cfg.name),
            pre_keys=("embed",),
            post_keys=("final_norm", "head"),
        )

    # --------------------------------------------------------------- init --
    def _mlstm_init(self, key):
        d, di, H = self.cfg.d_model, self.d_inner, self.n_heads
        K = self.cfg.ssm_conv
        ks = jax.random.split(key, 8)
        sd = 0.02
        wif = jnp.concatenate([
            jnp.zeros((di, H)),                      # input gate pre ~ 0
            jnp.zeros((di, H)),                      # forget handled by bias
        ], axis=1) + jax.random.normal(ks[6], (di, 2 * H)) * 0.005
        dk = self.dk
        return {
            "ln": LY.norm_init(d),
            "w_x": jax.random.normal(ks[0], (d, di)) * sd,
            "w_z": jax.random.normal(ks[1], (d, H, dk)) * sd,
            "conv": jax.random.normal(ks[2], (K, di)) * (1 / math.sqrt(K)),
            "wq": jax.random.normal(ks[3], (di, di)) * sd,
            "wk": jax.random.normal(ks[4], (di, di)) * sd,
            "wv": jax.random.normal(ks[5], (di, H, dk)) * sd,
            "w_if": wif,
            "w_out": jax.random.normal(ks[7], (H, dk, d))
            * (sd / math.sqrt(2 * self.cfg.n_layers)),
        }

    def _slstm_init(self, key):
        d, H = self.cfg.d_model, self.n_heads
        hd = d // H
        ks = jax.random.split(key, 3)
        return {
            "ln": LY.norm_init(d),
            "w_g": jax.random.normal(ks[0], (d, 4 * d)) * 0.02,
            "R": jax.random.normal(ks[1], (4, H, hd, hd)) / math.sqrt(hd),
            "w_out": jax.random.normal(ks[2], (d, d))
            * (0.02 / math.sqrt(2 * self.cfg.n_layers)),
        }

    def init_block_full(self, key, dcfg) -> dict:
        ks = jax.random.split(key, self.per)
        p = {f"m{i}": self._mlstm_init(ks[i]) for i in range(self.per - 1)}
        p["s"] = self._slstm_init(ks[-1])
        return p

    def init_full(self, key, dcfg: DistConfig) -> dict:
        keys = jax.random.split(key, self.n_steps + 2)
        blocks = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[self.init_block_full(keys[i], dcfg)
              for i in range(self.n_steps)])
        return {
            "embed": LY.embed_init(keys[-1], self.cfg),
            "blocks": blocks,
            "final_norm": LY.norm_init(self.cfg.d_model),
            "head": LY.head_init(keys[-2], self.cfg),
        }

    def consts(self, seq_len: int, dcfg: DistConfig) -> dict:
        return {}

    # -------------------------------------------------------------- apply --
    def _mlstm_parts(self, p, xg, dcfg, tp_slice=True):
        """Shared projection math. xg: (B,T,D) full-seq."""
        B, T, _ = xg.shape
        H, dk = self.n_heads, self.dk
        x_in = jnp.einsum("btd,de->bte", xg, p["w_x"])
        xc, _ = causal_conv1d(x_in, p["conv"])
        xc = jax.nn.silu(xc)
        q = jnp.einsum("bte,ef->btf", xc, p["wq"]).reshape(B, T, H, dk)
        k = jnp.einsum("bte,ef->btf", xc, p["wk"]).reshape(B, T, H, dk)
        v = jnp.einsum("bte,ehv->bthv", x_in, p["wv"])        # (B,T,H,dv/tp)
        gates = jnp.einsum("bte,eg->btg", xc, p["w_if"])
        i_pre = gates[..., :H]
        f_pre = gates[..., H:] + 3.0                          # forget bias
        z = jnp.einsum("btd,dhv->bthv", xg, p["w_z"])         # (B,T,H,dv/tp)
        return q, k, v, i_pre, f_pre, z

    def _mlstm_block(self, p, x_sp, dcfg):
        cfg = self.cfg
        h = LY.rmsnorm(x_sp, p["ln"], cfg.norm_eps)
        xg = LY.sp_gather(h, dcfg)
        q, k, v, i_pre, f_pre, z = self._mlstm_parts(p, xg, dcfg)
        y, _ = mlstm_chunked(q, k, v, i_pre, f_pre, chunk=cfg.ssm_chunk)
        y = y * jax.nn.silu(z)                                # (B,T,H,dv/tp)
        o = jnp.einsum("bthv,hvd->btd", y, p["w_out"])
        return x_sp + LY.sp_scatter(o, dcfg)

    def _slstm_block(self, p, x_sp, dcfg):
        cfg = self.cfg
        d, H = cfg.d_model, self.n_heads
        hd = d // H
        h = LY.rmsnorm(x_sp, p["ln"], cfg.norm_eps)
        xg = LY.sp_gather(h, dcfg)
        B, T, _ = xg.shape
        g = jnp.einsum("btd,dg->btg", xg, p["w_g"]).reshape(B, T, 4, H, hd)
        hs, _ = slstm_seq(g, p["R"])
        o = jnp.einsum("btd,de->bte", hs.reshape(B, T, d).astype(xg.dtype),
                       p["w_out"])
        # sLSTM is TP-replicated; divide before the SP reduce-scatter sums
        # tp identical copies back together.
        o = o / dcfg.tp_size
        return x_sp + LY.sp_scatter(o, dcfg)

    def block_fn(self, p, consts, x, dcfg: DistConfig):
        # remat each sub-block: the superblock's backward re-derives one
        # cell's internals at a time (q/k projections at full d_inner are
        # the peak residency otherwise)
        mblk = jax.checkpoint(lambda pp, xx: self._mlstm_block(pp, xx, dcfg))
        sblk = jax.checkpoint(lambda pp, xx: self._slstm_block(pp, xx, dcfg))
        for i in range(self.per - 1):
            x = mblk(p[f"m{i}"], x)
        x = sblk(p["s"], x)
        return x, {}

    # -------------------------------------------------------------- train --
    def stage_pre(self, storage, mb, dcfg: DistConfig):
        cfg = self.cfg
        emb_meta = LY.embed_meta("embed", cfg, dcfg.storage_dtype)

        def embed_fn(shard, ids):
            table = coll.replicate(shard, emb_meta, dcfg)
            return LY.embed_apply(table, ids, cfg, dcfg)

        return maybe_remat(embed_fn, "fsdp_only")(storage["embed"],
                                                  mb["tokens"]), {}

    def stage_blocks(self, storage, state, dcfg: DistConfig, plan=None):
        x, aux = state
        blk = functools.partial(self.block_fn, dcfg=dcfg)
        x, aux2 = apply_stack(blk, self.block_metas(dcfg), dcfg,
                              storage["blocks"], self.consts(0, dcfg), x,
                              plan=plan)
        return x, jax.tree.map(jnp.add, aux, aux2)

    def stage_loss(self, storage, state, mb, dcfg: DistConfig):
        cfg = self.cfg
        x, _ = state
        fn_meta = LY.norm_meta("final_norm", cfg.d_model, dcfg.storage_dtype)
        w_fn = coll.replicate(storage["final_norm"], fn_meta, dcfg)
        x = LY.rmsnorm(x, w_fn, cfg.norm_eps)
        hd_meta = LY.head_meta("head", cfg, dcfg.storage_dtype)
        w = coll.replicate(storage["head"], hd_meta, dcfg)
        logits = LY.head_logits(w, LY.sp_gather(x, dcfg), cfg, dcfg)
        loss, _ = LY.vocab_parallel_xent(logits, mb["targets"],
                                         mb["valid"], cfg, dcfg)
        return loss

    def loss_local(self, storage, batch, dcfg: DistConfig):
        state = self.stage_blocks(storage,
                                  self.stage_pre(storage, batch, dcfg), dcfg)
        return self.stage_loss(storage, state, batch, dcfg), state[1]

    # -------------------------------------------------------------- serve --
    def init_state(self, batch_local: int, dcfg: DistConfig):
        """Recurrent state per scan step (stacked over n_steps outside)."""
        H, dk = self.n_heads, self.dk
        dv_l = self.d_inner // dcfg.tp_size // H
        d = self.cfg.d_model
        hd = d // H
        K = self.cfg.ssm_conv
        B = batch_local
        one = {
            f"m{i}": {
                "C": jnp.zeros((B, H, dk, dv_l), jnp.float32),
                "n": jnp.zeros((B, H, dk), jnp.float32),
                "m": jnp.full((B, H), -1e30),
                "conv": jnp.zeros((B, K - 1, self.d_inner),
                                  jnp.float32),
            } for i in range(self.per - 1)
        }
        one["s"] = {
            "h": jnp.zeros((B, H, hd), jnp.float32),
            "c": jnp.zeros((B, H, hd), jnp.float32),
            "n": jnp.ones((B, H, hd), jnp.float32),
            "m": jnp.zeros((B, H, hd), jnp.float32),
        }
        return one

    def _mlstm_decode(self, p, st, x, dcfg):
        """x: (B,1,D) replicated over model."""
        cfg = self.cfg
        B = x.shape[0]
        H, dk = self.n_heads, self.dk
        h = LY.rmsnorm(x, p["ln"], cfg.norm_eps)
        x_in = jnp.einsum("btd,de->bte", h, p["w_x"])
        xc, conv_state = causal_conv1d(x_in, p["conv"],
                                       state=st["conv"].astype(x_in.dtype))
        xc = jax.nn.silu(xc)
        q = jnp.einsum("bte,ef->btf", xc, p["wq"]).reshape(B, H, dk)
        k = jnp.einsum("bte,ef->btf", xc, p["wk"]).reshape(B, H, dk)
        v = jnp.einsum("bte,ehv->bthv", x_in, p["wv"])[:, 0]  # (B,H,dv/tp)
        gates = jnp.einsum("bte,eg->btg", xc, p["w_if"])[:, 0]
        (C, n, m), y = mlstm_step((st["C"], st["n"], st["m"]),
                                  q, k, v, gates[..., :H],
                                  gates[..., H:] + 3.0)
        z = jnp.einsum("btd,dhv->bthv", h, p["w_z"])          # (B,1,H,dv/tp)
        y = y[:, None] * jax.nn.silu(z)
        o = jnp.einsum("bthv,hvd->btd", y, p["w_out"])
        o = lax.psum(o, dcfg.tp_axis)
        st_new = {"C": C, "n": n, "m": m,
                  "conv": conv_state.astype(jnp.float32)}
        return x + o, st_new

    def _slstm_decode(self, p, st, x, dcfg):
        cfg = self.cfg
        d, H = cfg.d_model, self.n_heads
        hd = d // H
        B = x.shape[0]
        h = LY.rmsnorm(x, p["ln"], cfg.norm_eps)
        g = jnp.einsum("btd,dg->btg", h, p["w_g"]).reshape(B, 1, 4, H, hd)
        hs, state = slstm_seq(g, p["R"],
                              state=(st["h"], st["c"], st["n"], st["m"]))
        o = jnp.einsum("btd,de->bte",
                       hs.reshape(B, 1, d).astype(x.dtype), p["w_out"])
        st_new = dict(zip(("h", "c", "n", "m"), state))
        return x + o, st_new

    def decode_local(self, params_tp, state, tok, pos, dcfg: DistConfig):
        cfg = self.cfg
        x = LY.embed_apply(params_tp["embed"], tok[:, None], cfg, dcfg,
                           scatter=False)

        def body(xc, inputs):
            p, st = inputs
            st_new = dict(st)
            for i in range(self.per - 1):
                xc, st_new[f"m{i}"] = self._mlstm_decode(
                    p[f"m{i}"], st[f"m{i}"], xc, dcfg)
            xc, st_new["s"] = self._slstm_decode(p["s"], st["s"], xc, dcfg)
            return xc, st_new

        x, state = lax.scan(body, x, (params_tp["blocks"], state))
        x = LY.rmsnorm(x, params_tp["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params_tp["head"],
                            preferred_element_type=jnp.float32)
        return logits[:, 0], state

    def _mlstm_prefill(self, p, x_sp, dcfg):
        """Like _mlstm_block but also returns the final (C, n, m) state and
        trailing conv state."""
        cfg = self.cfg
        h = LY.rmsnorm(x_sp, p["ln"], cfg.norm_eps)
        xg = LY.sp_gather(h, dcfg)
        x_in = jnp.einsum("btd,de->bte", xg, p["w_x"])
        xc_full, conv_state = causal_conv1d(x_in, p["conv"])
        xc = jax.nn.silu(xc_full)
        B, T, _ = xg.shape
        H, dk = self.n_heads, self.dk
        q = jnp.einsum("bte,ef->btf", xc, p["wq"]).reshape(B, T, H, dk)
        k = jnp.einsum("bte,ef->btf", xc, p["wk"]).reshape(B, T, H, dk)
        v = jnp.einsum("bte,ehv->bthv", x_in, p["wv"])
        gates = jnp.einsum("bte,eg->btg", xc, p["w_if"])
        z = jnp.einsum("btd,dhv->bthv", xg, p["w_z"])
        y, (C, n, m) = mlstm_chunked(q, k, v, gates[..., :H],
                                     gates[..., H:] + 3.0,
                                     chunk=cfg.ssm_chunk)
        y = y * jax.nn.silu(z)
        o = jnp.einsum("bthv,hvd->btd", y, p["w_out"])
        st = {"C": C, "n": n, "m": m,
              "conv": x_in[:, -(cfg.ssm_conv - 1):].astype(jnp.float32)}
        return x_sp + LY.sp_scatter(o, dcfg), st

    def _slstm_prefill(self, p, x_sp, dcfg):
        cfg = self.cfg
        d, H = cfg.d_model, self.n_heads
        hd = d // H
        h = LY.rmsnorm(x_sp, p["ln"], cfg.norm_eps)
        xg = LY.sp_gather(h, dcfg)
        B, T, _ = xg.shape
        g = jnp.einsum("btd,dg->btg", xg, p["w_g"]).reshape(B, T, 4, H, hd)
        hs, state = slstm_seq(g, p["R"])
        o = jnp.einsum("btd,de->bte", hs.reshape(B, T, d).astype(xg.dtype),
                       p["w_out"]) / dcfg.tp_size
        st = dict(zip(("h", "c", "n", "m"), state))
        return x_sp + LY.sp_scatter(o, dcfg), st

    def prefill_local(self, params_tp, batch, dcfg: DistConfig):
        """Run the full-sequence forward in chunked form, returning last
        logits + the recurrent state for decode continuation."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = LY.embed_apply(params_tp["embed"], tokens, cfg, dcfg)

        def body(xc, p):
            st = {}
            for i in range(self.per - 1):
                xc, st[f"m{i}"] = self._mlstm_prefill(p[f"m{i}"], xc, dcfg)
            xc, st["s"] = self._slstm_prefill(p["s"], xc, dcfg)
            return xc, st

        x, state = lax.scan(body, x, params_tp["blocks"])
        x = LY.rmsnorm(x, params_tp["final_norm"], cfg.norm_eps)
        xg = LY.sp_gather(x, dcfg)[:, -1:]
        logits = jnp.einsum("bsd,dv->bsv", xg, params_tp["head"],
                            preferred_element_type=jnp.float32)
        return logits[:, 0], state

    # ------------------------------------------------------------ costing --
    def block_stats(self, dcfg: DistConfig, batch_shape) -> BlockStats:
        B, S = batch_shape          # per-device microbatch
        tokens = B * S
        it = jnp.dtype(dcfg.param_dtype).itemsize
        pf, pb = {}, {}
        from repro.core.meta import named_leaves
        for nm, m in named_leaves(self.block_metas(dcfg)):
            numel = m.numel_local(dcfg)
            flops = 2.0 * tokens * numel
            pf[nm] = flops
            pb[nm] = numel * it
        return BlockStats(param_flops=pf, param_bytes=pb,
                          act_bytes=tokens * self.cfg.d_model * it / dcfg.tp_size)

    def bucket_units(self) -> list[list[str]]:
        return [[f"m{i}/*"] for i in range(self.per - 1)] + [["s/*"]]

    def input_specs(self, shape: ShapeConfig, dcfg: DistConfig) -> dict:
        B, S = shape.global_batch, shape.seq_len
        ids = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            return {"tokens": ids, "targets": ids,
                    "valid": jax.ShapeDtypeStruct((B, S), jnp.float32)}
        if shape.kind == "prefill":
            return {"tokens": ids}
        return {"tok": jax.ShapeDtypeStruct((B,), jnp.int32)}
