"""Glue between model definitions and the mesh: storage conversion,
spec building, and shard_map-wrapped step construction.

Used by train/, launch/dryrun, tests and examples so they all build steps
the same way.
"""

from __future__ import annotations

import jax
from jax import lax

from repro.core.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.api import shard_params, unshard_params
from repro.core.dist import DistConfig, make_mesh
from repro.core.meta import abstract_storage, storage_specs

# The one canonical full<->storage transform lives in core/api.py
# (stacked-aware); these names are kept for existing call sites.
tree_to_storage = shard_params
tree_from_storage = unshard_params


def stacked_keys(model) -> dict:
    """Which top-level param groups carry a leading layer-stack dim.

    Part of the model contract: every model declares `stacked_keys`
    explicitly (no `n_steps` guessing — models without the attribute get a
    pointed error instead of an AttributeError deep in a tree map)."""
    sk = getattr(model, "stacked_keys", None)
    if sk is None:
        raise TypeError(
            f"{type(model).__name__} does not declare `stacked_keys`; the "
            "model contract (models/common.py) requires a property mapping "
            "each layer-stacked param group to its stack length, e.g. "
            "{'blocks': n_steps}")
    return dict(sk)


def model_storage_specs(model, dcfg: DistConfig):
    metas = model.metas(dcfg)
    sk = stacked_keys(model)
    return {
        k: storage_specs(metas[k], dcfg, stacked=(k in sk))
        for k in metas
    }


def model_abstract_storage(model, dcfg: DistConfig):
    metas = model.metas(dcfg)
    sk = stacked_keys(model)
    return {
        k: abstract_storage(metas[k], dcfg, n_layers=sk.get(k))
        for k in metas
    }


def init_storage(model, key, dcfg: DistConfig):
    full = model.init_full(key, dcfg)
    metas = model.metas(dcfg)
    return {k: tree_to_storage(full[k], metas[k], dcfg) for k in full}


def batch_specs(model, shape, dcfg: DistConfig):
    """Batch sharding: rows over the data axes; under context parallelism
    the SEQUENCE dim (dim 1 of every 2D+ input) additionally shards over
    the ctx axis — each rank receives its contiguous slice of the
    host-side zigzag-permuted sequence (core/context.zigzag_batch)."""
    axes = dp_axes(dcfg)
    cp_seq = dcfg.cp_axis if dcfg.cp_size > 1 else None
    specs = {}
    for k, sds in model.input_specs(shape, dcfg).items():
        if cp_seq is not None and len(sds.shape) >= 2:
            specs[k] = P(axes, cp_seq, *([None] * (len(sds.shape) - 2)))
        else:
            specs[k] = P(axes, *([None] * (len(sds.shape) - 1)))
    return specs


def dp_axes(dcfg: DistConfig) -> tuple[str, ...]:
    """Batch-ROW sharding axes: everything that is not TP, not the pipe
    axis (every pipe rank sees the same microbatch stream) and not the ctx
    axis (cp ranks replicate rows and shard the sequence dim instead)."""
    return tuple(a for a in dcfg.mesh_axes
                 if a != dcfg.tp_axis and a != dcfg.pp_axis
                 and a != dcfg.cp_axis)


def make_loss_step(model, dcfg: DistConfig, with_grads: bool = True):
    """Returns step(storage, batch) -> (loss, grads?) for shard_map."""
    def step(storage, batch):
        if with_grads:
            loss, grads = jax.value_and_grad(
                lambda s: model.loss_local(s, batch, dcfg)[0])(storage)
        else:
            loss = model.loss_local(storage, batch, dcfg)[0]
            grads = None
        # undo the 1/tp gradient-convention scaling for the LOGGED value
        loss = lax.pmean(loss, dcfg.mesh_axes) * dcfg.tp_size
        return (loss, grads) if with_grads else loss
    return step


def wrap_step(model, dcfg: DistConfig, shape, step_fn, out_specs,
              mesh=None):
    mesh = mesh or make_mesh(dcfg)
    in_specs = (model_storage_specs(model, dcfg),
                batch_specs(model, shape, dcfg))
    return jax.jit(shard_map(step_fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)), mesh
