"""Glue between model definitions and the mesh: storage conversion,
spec building, and shard_map-wrapped step construction.

Used by train/, launch/dryrun, tests and examples so they all build steps
the same way.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.dist import DistConfig, make_mesh
from repro.core.meta import (ParamMeta, abstract_storage, from_storage,
                             storage_specs, to_storage)


def _is_meta(x):
    return isinstance(x, ParamMeta)


def tree_to_storage(full_tree, metas_tree, dcfg: DistConfig):
    """Full shaped params -> storage layout; leaves with an extra leading dim
    relative to their meta are treated as layer-stacked."""
    def one(p, m):
        if p.ndim == len(m.global_shape) + 1:
            return jnp.stack(
                [to_storage(p[i], m, dcfg) for i in range(p.shape[0])])
        return to_storage(p, m, dcfg)
    return jax.tree.map(one, full_tree, metas_tree, is_leaf=_is_meta)


def tree_from_storage(storage_tree, metas_tree, dcfg: DistConfig):
    """Inverse of tree_to_storage (stacked-aware)."""
    def one(p, m):
        if p.ndim == len(m.storage_shape(dcfg)) + 1:
            return jnp.stack(
                [from_storage(p[i], m, dcfg) for i in range(p.shape[0])])
        return from_storage(p, m, dcfg)
    return jax.tree.map(one, storage_tree, metas_tree, is_leaf=_is_meta)


def stacked_keys(model) -> dict:
    """Which top-level param groups carry a leading layer-stack dim."""
    return getattr(model, "stacked_keys", {"blocks": model.n_steps})


def model_storage_specs(model, dcfg: DistConfig):
    metas = model.metas(dcfg)
    sk = stacked_keys(model)
    return {
        k: storage_specs(metas[k], dcfg, stacked=(k in sk))
        for k in metas
    }


def model_abstract_storage(model, dcfg: DistConfig):
    metas = model.metas(dcfg)
    sk = stacked_keys(model)
    return {
        k: abstract_storage(metas[k], dcfg, n_layers=sk.get(k))
        for k in metas
    }


def init_storage(model, key, dcfg: DistConfig):
    full = model.init_full(key, dcfg)
    metas = model.metas(dcfg)
    return {k: tree_to_storage(full[k], metas[k], dcfg) for k in full}


def batch_specs(model, shape, dcfg: DistConfig):
    axes = dp_axes(dcfg)
    specs = {}
    for k, sds in model.input_specs(shape, dcfg).items():
        specs[k] = P(axes, *([None] * (len(sds.shape) - 1)))
    return specs


def dp_axes(dcfg: DistConfig) -> tuple[str, ...]:
    """Batch-sharding axes: everything that is not TP and not the pipe axis
    (every pipe rank sees the same microbatch stream)."""
    return tuple(a for a in dcfg.mesh_axes
                 if a != dcfg.tp_axis and a != dcfg.pp_axis)


def make_loss_step(model, dcfg: DistConfig, with_grads: bool = True):
    """Returns step(storage, batch) -> (loss, grads?) for shard_map."""
    def step(storage, batch):
        if with_grads:
            loss, grads = jax.value_and_grad(
                lambda s: model.loss_local(s, batch, dcfg)[0])(storage)
        else:
            loss = model.loss_local(storage, batch, dcfg)[0]
            grads = None
        # undo the 1/tp gradient-convention scaling for the LOGGED value
        loss = lax.pmean(loss, dcfg.mesh_axes) * dcfg.tp_size
        return (loss, grads) if with_grads else loss
    return step


def wrap_step(model, dcfg: DistConfig, shape, step_fn, out_specs,
              mesh=None):
    mesh = mesh or make_mesh(dcfg)
    in_specs = (model_storage_specs(model, dcfg),
                batch_specs(model, shape, dcfg))
    return jax.jit(shard_map(step_fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)), mesh
