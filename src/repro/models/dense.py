"""Dense decoder-only LM family (deepseek-coder-33b, phi3-medium-14b,
gemma2-27b, qwen3-1.7b, llama3-8b, and the InternLM2 backbone of
internvl2-26b).

Variants are driven entirely by ArchConfig flags:
  * gemma2: alternating sliding-window/global attention (scanned as PAIRS so
    the stack stays homogeneous), attn/final logit softcaps, GeGLU,
    sandwich norms (pre+post), unit-offset RMSNorm, sqrt(d) embedding scale,
    tied embeddings, query_pre_attn scaling;
  * qwen3: qk-norm, tied embeddings;
  * others: llama-style RoPE + SwiGLU + GQA.

Three entry points (all run inside shard_map on local shards):
  loss_local    — training forward + vocab-parallel CE (FSDP via core.stack)
  prefill_local — serving prefill: SP forward emitting the KV cache
  decode_local  — one-token decode against the cache (TP-only weights)
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.dist import DistConfig
from repro.core.irgraph import BlockStats
from repro.core.meta import ParamMeta
from repro.core.stack import apply_stack
from repro.core import collectives as coll
from repro.core.remat import maybe_remat
from repro.models import layers as LY
from repro.models.common import (ArchConfig, BlockSegments, ShapeConfig,
                                 StageSpec, even_stage_slices)


class DenseLM:
    # Context-parallel contract (core/context.py): the dense family routes
    # attention/RoPE/loss masking through the zigzag sequence shard — the
    # whole training path is position-exact under dcfg.cp_axis.  Families
    # with their own stacks (xlstm/zamba2/encdec) or a modality stream
    # whose layout a sequence permutation would break (vlm) opt out.
    cp_supported = True

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        # gemma2 alternates (local, global); scan over pairs keeps the
        # stacked params homogeneous.
        self.layers_per_step = 2 if cfg.local_global_alternate else 1
        assert cfg.n_layers % self.layers_per_step == 0
        self.n_steps = cfg.n_layers // self.layers_per_step
        # measured BlockStats override (launch/dryrun.harvest_block_stats):
        # when set, block_stats() returns it instead of the analytic model.
        self.measured_stats: BlockStats | None = None

    # ------------------------------------------------------------- metas --
    def _sub_metas(self, dcfg: DistConfig, tag: str) -> dict:
        cfg = self.cfg
        dt = dcfg.storage_dtype
        m = {
            "ln1": LY.norm_meta(f"{tag}ln1", cfg.d_model, dt),
            "attn": LY.attn_metas(cfg, dcfg, dt, prefix=f"{tag}attn."),
            "ln2": LY.norm_meta(f"{tag}ln2", cfg.d_model, dt),
            "mlp": self._ffn_metas(dcfg, dt, prefix=f"{tag}mlp."),
        }
        if cfg.post_norms:
            m["pn1"] = LY.norm_meta(f"{tag}pn1", cfg.d_model, dt)
            m["pn2"] = LY.norm_meta(f"{tag}pn2", cfg.d_model, dt)
        return m

    def block_metas(self, dcfg: DistConfig) -> dict:
        if self.layers_per_step == 1:
            return self._sub_metas(dcfg, "")
        return {"local": self._sub_metas(dcfg, "local."),
                "global": self._sub_metas(dcfg, "global.")}

    def metas(self, dcfg: DistConfig) -> dict:
        cfg = self.cfg
        dt = dcfg.storage_dtype
        m = {
            "embed": LY.embed_meta("embed", cfg, dt),
            "blocks": self.block_metas(dcfg),
            "final_norm": LY.norm_meta("final_norm", cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            m["head"] = LY.head_meta("head", cfg, dt)
        return m

    @property
    def stacked_keys(self) -> dict:
        """Top-level param groups carrying a leading layer-stack dim (the
        model contract consumed by models/runtime and core/api)."""
        return {"blocks": self.n_steps}

    def stage_spec(self, n_stages: int) -> StageSpec:
        """Default LM partition: embedding on stage 0, the scanned block
        stack sliced contiguously, final norm + head + loss on the last
        stage.  A tied embedding table is consumed at BOTH ends, so it is
        replicated across stages (grads psum'ed over the pipe axis)."""
        tied = self.cfg.tie_embeddings
        return StageSpec(
            n_stages=n_stages,
            pipelined="blocks",
            layers_per_stage=even_stage_slices(self.n_steps, n_stages,
                                               self.cfg.name),
            pre_keys=() if tied else ("embed",),
            post_keys=("final_norm",) + (() if tied else ("head",)),
            replicated_keys=("embed",) if tied else (),
        )

    # -------------------------------------------------------------- init --
    def _sub_init(self, key, dcfg) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": LY.norm_init(cfg.d_model, cfg.post_norms),
            "attn": LY.attn_init(k1, cfg, dcfg),
            "ln2": LY.norm_init(cfg.d_model, cfg.post_norms),
            "mlp": self._ffn_init(k2, dcfg),
        }
        if cfg.post_norms:
            p["pn1"] = LY.norm_init(cfg.d_model, True)
            p["pn2"] = LY.norm_init(cfg.d_model, True)
        return p

    def init_block_full(self, key, dcfg) -> dict:
        if self.layers_per_step == 1:
            return self._sub_init(key, dcfg)
        k1, k2 = jax.random.split(key)
        return {"local": self._sub_init(k1, dcfg),
                "global": self._sub_init(k2, dcfg)}

    def init_full(self, key, dcfg: DistConfig) -> dict:
        """Full shaped params (host-side; small/smoke configs only)."""
        cfg = self.cfg
        keys = jax.random.split(key, self.n_steps + 2)
        blocks = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[self.init_block_full(keys[i], dcfg) for i in range(self.n_steps)]
        )
        p = {
            "embed": LY.embed_init(keys[-1], cfg),
            "blocks": blocks,
            "final_norm": LY.norm_init(cfg.d_model, cfg.post_norms),
        }
        if not cfg.tie_embeddings:
            p["head"] = LY.head_init(keys[-2], cfg)
        return p

    # --------------------------------------------------------- constants --
    def consts(self, seq_len: int, dcfg: DistConfig, positions=None) -> dict:
        cos, sin = LY.rope_cache(seq_len, self.cfg.head_dim,
                                 self.cfg.rope_theta, positions=positions)
        return {"rope_cos": cos, "rope_sin": sin}

    # ------------------------------------------------------------- block --
    @property
    def _q_scale(self):
        cfg = self.cfg
        if cfg.name.startswith("gemma2"):
            return 256.0 ** -0.5      # query_pre_attn_scalar
        return 1.0 / math.sqrt(cfg.head_dim)

    # FFN hooks — overridden by the MoE family --------------------------------
    def _ffn_metas(self, dcfg, dtype, prefix=""):
        return LY.mlp_metas(self.cfg, dcfg, dtype, prefix=prefix)

    def _ffn_init(self, key, dcfg):
        return LY.mlp_init(key, self.cfg)

    def _ffn_apply(self, p, x_sp, dcfg):
        return LY.mlp_apply(p, x_sp, self.cfg, dcfg), {}

    def _ffn_decode(self, p, x, dcfg):
        cfg = self.cfg
        hg = jnp.einsum("bsd,df->bsf", x, p["wg"])
        hu = jnp.einsum("bsd,df->bsf", x, p["wu"])
        act = jax.nn.gelu(hg, approximate=True) \
            if cfg.gated_mlp == "geglu" else jax.nn.silu(hg)
        o = jnp.einsum("bsf,fd->bsd", act * hu, p["wd"])
        o = lax.psum(o, dcfg.tp_axis)
        return o

    def _attn_half(self, p, consts, x, dcfg, window):
        """Attention residual branch: consumes ln1 + attn.* (+pn1)."""
        cfg = self.cfg
        uo = cfg.post_norms  # gemma-style unit-offset norms
        h = LY.rmsnorm(x, p["ln1"], cfg.norm_eps, uo)
        h = LY.attn_apply(p["attn"], h, consts, cfg, dcfg, window=window,
                          q_scale=self._q_scale)
        if cfg.post_norms:
            h = LY.rmsnorm(h, p["pn1"], cfg.norm_eps, uo)
        return x + h

    def _mlp_half(self, p, consts, x, dcfg):
        """FFN residual branch: consumes ln2 + mlp.* (+pn2); returns aux."""
        cfg = self.cfg
        uo = cfg.post_norms
        h = LY.rmsnorm(x, p["ln2"], cfg.norm_eps, uo)
        h, aux = self._ffn_apply(p["mlp"], h, dcfg)
        if cfg.post_norms:
            h = LY.rmsnorm(h, p["pn2"], cfg.norm_eps, uo)
        return x + h, aux

    def _sub_block(self, p, consts, x, dcfg, window):
        x = self._attn_half(p, consts, x, dcfg, window)
        return self._mlp_half(p, consts, x, dcfg)

    def block_fn(self, p, consts, x, dcfg: DistConfig):
        cfg = self.cfg
        if self.layers_per_step == 1:
            w = cfg.sliding_window if not cfg.local_global_alternate else None
            y, aux = self._sub_block(p, consts, x, dcfg, w)
            return y, aux
        # remat each half of the pair: halves peak backward residency
        sub = jax.checkpoint(
            lambda pp, xx, w: self._sub_block(pp, consts, xx, dcfg, w),
            static_argnums=(2,))
        x, aux1 = sub(p["local"], x, cfg.sliding_window)
        x, aux2 = sub(p["global"], x, None)
        return x, jax.tree.map(jnp.add, aux1, aux2)

    def block_segments(self, dcfg: DistConfig) -> BlockSegments:
        """Segmented block contract (attn / mlp residual branches).

        Each segment consumes exactly the params its globs name, so the
        prefetch stack can overlap the mlp bucket's all-gather with the attn
        segment's compute (and layer i+1's attn bucket with the mlp
        segment). The gemma2 local/global pair yields four segments; aux
        from the local mlp rides the inter-segment state.
        """
        cfg = self.cfg
        if self.layers_per_step == 1:
            w = cfg.sliding_window if not cfg.local_global_alternate else None

            def seg_attn(p, consts, x):
                return self._attn_half(p, consts, x, dcfg, w)

            def seg_mlp(p, consts, x):
                return self._mlp_half(p, consts, x, dcfg)

            return BlockSegments(
                names=("attn", "mlp"),
                param_globs=(("ln1", "attn/*", "pn1"),
                             ("ln2", "mlp/*", "pn2")),
                fns=(seg_attn, seg_mlp))

        def l_attn(p, consts, x):
            return self._attn_half(p["local"], consts, x, dcfg,
                                   cfg.sliding_window)

        def l_mlp(p, consts, x):
            return self._mlp_half(p["local"], consts, x, dcfg)

        def g_attn(p, consts, st):
            x, aux = st
            return self._attn_half(p["global"], consts, x, dcfg, None), aux

        def g_mlp(p, consts, st):
            x, aux = st
            y, aux2 = self._mlp_half(p["global"], consts, x, dcfg)
            return y, jax.tree.map(jnp.add, aux, aux2)

        # checkpoint each pair segment: block_fn remats each half to halve
        # peak backward residency, and the segmented path must not hold all
        # four segments' un-rematted vjp residuals at once — with checkpoint
        # the per-segment residuals are just the inter-segment states.
        return BlockSegments(
            names=("local.attn", "local.mlp", "global.attn", "global.mlp"),
            param_globs=(("local/ln1", "local/attn/*", "local/pn1"),
                         ("local/ln2", "local/mlp/*", "local/pn2"),
                         ("global/ln1", "global/attn/*", "global/pn1"),
                         ("global/ln2", "global/mlp/*", "global/pn2")),
            fns=tuple(jax.checkpoint(f)
                      for f in (l_attn, l_mlp, g_attn, g_mlp)))

    # ------------------------------------------------------------- train --
    def _embed_in(self, storage, tokens, dcfg):
        cfg = self.cfg
        emb_meta = LY.embed_meta("embed", cfg, dcfg.storage_dtype)

        def embed_fn(emb_shard, ids):
            table = coll.replicate(emb_shard, emb_meta, dcfg)
            scale = math.sqrt(cfg.d_model) if cfg.post_norms else None
            return LY.embed_apply(table, ids, cfg, dcfg, scale=scale)

        return maybe_remat(embed_fn, "fsdp_only" if dcfg.remat != "none"
                           else "none")(storage["embed"], tokens)

    def _lm_head(self, storage, x_sp, dcfg):
        cfg = self.cfg
        x = LY.sp_gather(x_sp, dcfg)
        if cfg.tie_embeddings:
            emb_meta = LY.embed_meta("embed", cfg, dcfg.storage_dtype)
            table = coll.replicate(storage["embed"], emb_meta, dcfg)
            logits = jnp.einsum("bsd,vd->bsv", x, table,
                                preferred_element_type=jnp.float32)
            logits = LY._softcap(logits, cfg.final_softcap)
        else:
            head_meta = LY.head_meta("head", cfg, dcfg.storage_dtype)
            w = coll.replicate(storage["head"], head_meta, dcfg)
            logits = LY.head_logits(w, x, cfg, dcfg)
        return logits

    def _aux0(self) -> dict:
        """Zero-valued aux accumulator matching apply_stack's aux structure
        (part of the inter-stage pipeline state)."""
        return {}

    def _loss_aux(self, aux):
        """Scalar added to the CE loss from the accumulated aux (MoE)."""
        return 0.0

    # -- the stage-partition contract (models/common.StageSpec). The three
    # methods compose to loss_local at pp=1 and are driven per-stage by the
    # pipeline schedules under dcfg.pp_axis; the inter-stage state is
    # (x_sp, aux_sums).
    def stage_pre(self, storage, mb, dcfg: DistConfig):
        """Stage-0 entry: tokens -> SP-layout embeddings (+ zero aux)."""
        return self._embed_in(storage, mb["tokens"], dcfg), self._aux0()

    def stage_blocks(self, storage, state, dcfg: DistConfig, plan=None):
        """This stage's contiguous slice of the scanned block stack."""
        x, aux = state
        # S_local is the per-device (cp-shard) sequence; RoPE tables span
        # the GLOBAL sequence and attn_apply slices them at this rank's
        # zigzag positions.  Planner stats describe per-device work.
        B, S_local = x.shape[0], x.shape[1] * dcfg.tp_size
        consts = self.consts(S_local * dcfg.cp_size, dcfg)
        blk = functools.partial(self.block_fn, dcfg=dcfg)
        x, aux2 = apply_stack(blk, self.block_metas(dcfg), dcfg,
                              storage["blocks"], consts, x, plan=plan,
                              block_stats=self.block_stats(dcfg,
                                                           (B, S_local)),
                              segments=self.block_segments(dcfg))
        return x, jax.tree.map(jnp.add, aux, aux2)

    def stage_loss(self, storage, state, mb, dcfg: DistConfig):
        """Last-stage exit: final norm, LM head, vocab-parallel CE (+aux)."""
        cfg = self.cfg
        x, aux = state
        fn_meta = LY.norm_meta("final_norm", cfg.d_model, dcfg.storage_dtype)
        w_fn = coll.replicate(storage["final_norm"], fn_meta, dcfg)
        x = LY.rmsnorm(x, w_fn, cfg.norm_eps, cfg.post_norms)
        logits = self._lm_head(storage, x, dcfg)
        loss, _ = LY.vocab_parallel_xent(
            logits, mb["targets"], mb["valid"], cfg, dcfg)
        return loss + self._loss_aux(aux)

    def loss_local(self, storage, batch, dcfg: DistConfig):
        """batch: tokens/targets (B,S) int32, valid (B,S) f32. Local mean."""
        state = self.stage_blocks(storage,
                                  self.stage_pre(storage, batch, dcfg), dcfg)
        return self.stage_loss(storage, state, batch, dcfg), state[1]

    # ------------------------------------------------------------- serve --
    def serve_block_metas(self, dcfg: DistConfig) -> dict:
        return self.block_metas(dcfg)

    def _serve_sub(self, p, consts, x_sp, dcfg, window):
        """Prefill sublayer: like _sub_block but also returns (k, v)."""
        cfg = self.cfg
        uo = cfg.post_norms
        h = LY.rmsnorm(x_sp, p["ln1"], cfg.norm_eps, uo)
        xg = LY.sp_gather(h, dcfg)
        q, k, v, head_mask = LY._local_qkv(p["attn"], xg, cfg, dcfg)
        if cfg.qk_norm:
            q = LY.rmsnorm(q, p["attn"]["q_norm"], cfg.norm_eps)
            k = LY.rmsnorm(k, p["attn"]["k_norm"], cfg.norm_eps)
        cos, sin = consts["rope_cos"], consts["rope_sin"]
        q = LY.apply_rope(q, cos, sin)
        k = LY.apply_rope(k, cos, sin)
        out = LY.attention(q, k, v, causal=True, window=window,
                           softcap=cfg.attn_softcap, q_scale=self._q_scale)
        out = out * head_mask[None, None, :, None]
        B, S, hl, hd = out.shape
        o = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, hl * hd),
                       p["attn"]["wo"])
        h = LY.sp_scatter(o, dcfg)
        if cfg.post_norms:
            h = LY.rmsnorm(h, p["pn1"], cfg.norm_eps, uo)
        x = x_sp + h
        h = LY.rmsnorm(x, p["ln2"], cfg.norm_eps, uo)
        h, _ = self._ffn_apply(p["mlp"], h, dcfg)
        if cfg.post_norms:
            h = LY.rmsnorm(h, p["pn2"], cfg.norm_eps, uo)
        codec = dcfg.kv_codec
        if codec:
            kq, ks = LY.kv_quantize(k, codec)
            vq, vs = LY.kv_quantize(v, codec)
            return x + h, {"k": kq, "ks": ks, "v": vq, "vs": vs}
        return x + h, (k.astype(dcfg.param_dtype), v.astype(dcfg.param_dtype))

    def prefill_block(self, p, consts, x, dcfg):
        cfg = self.cfg
        if self.layers_per_step == 1:
            w = cfg.sliding_window if not cfg.local_global_alternate else None
            y, kv = self._serve_sub(p, consts, x, dcfg, w)
            return y, kv
        y, kv_l = self._serve_sub(p["local"], consts, x, dcfg,
                                  cfg.sliding_window)
        y, kv_g = self._serve_sub(p["global"], consts, y, dcfg, None)
        return y, (kv_l, kv_g)

    def prefill_local(self, params_tp, batch, dcfg: DistConfig):
        """params_tp: TP-local FULL params stacked (n_steps, ...).

        Returns (last-token logits (B, V/tp), kv cache pytree stacked)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        consts = self.consts(tokens.shape[1], dcfg)
        scale = math.sqrt(cfg.d_model) if cfg.post_norms else None
        x = LY.embed_apply(params_tp["embed"], tokens, cfg, dcfg, scale=scale)

        def body(xc, p):
            y, kv = self.prefill_block(p, consts, xc, dcfg)
            return y, kv

        x, cache = lax.scan(body, x, params_tp["blocks"])
        x = LY.rmsnorm(x, params_tp["final_norm"], cfg.norm_eps,
                       cfg.post_norms)
        xg = LY.sp_gather(x, dcfg)[:, -1:]
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", xg, params_tp["embed"],
                                preferred_element_type=jnp.float32)
        else:
            logits = jnp.einsum("bsd,dv->bsv", xg, params_tp["head"],
                                preferred_element_type=jnp.float32)
        logits = LY._softcap(logits, cfg.final_softcap)
        return logits[:, 0], cache

    # decode -----------------------------------------------------------------
    # Paged-serving contract (core/serving): this family stores its cache
    # as fixed-size KV pages in a pooled arena and decodes through the
    # gather/scatter path below.  Recurrent families (xlstm/zamba2) carry
    # O(1) state — paging does not apply; encdec's dual cache is a
    # follow-up (ROADMAP serving notes).
    paged_kv = True

    def _dense_writer(self, kv, k, v, *, qpos, dcfg):
        """Commit new (B,C,Kl,hd) K/V into the dense (B,T,...) cache at
        per-request positions qpos (B,C); returns (new_kv, ck, cv) where
        ck/cv are the full dense read views the attention consumes."""
        ib = jnp.arange(k.shape[0])[:, None]
        codec = dcfg.kv_codec
        if codec:
            kq, ks = LY.kv_quantize(k, codec)
            vq, vs = LY.kv_quantize(v, codec)
            kv = {
                "k": kv["k"].at[ib, qpos].set(kq),
                "ks": kv["ks"].at[ib, qpos].set(ks),
                "v": kv["v"].at[ib, qpos].set(vq),
                "vs": kv["vs"].at[ib, qpos].set(vs),
            }
            ck = LY.kv_dequantize(kv["k"], kv["ks"], dcfg.param_dtype)
            cv = LY.kv_dequantize(kv["v"], kv["vs"], dcfg.param_dtype)
            return kv, ck, cv
        ck, cv = kv
        ck = ck.at[ib, qpos].set(k.astype(ck.dtype))
        cv = cv.at[ib, qpos].set(v.astype(cv.dtype))
        return (ck, cv), ck, cv

    def _paged_writer(self, kv, k, v, *, table, qpos, dcfg, page):
        """Paged cache commit: scatter new K/V into the page pool at the
        slots `table` maps qpos to, then gather the table's full logical
        window back as the dense read views (exactly the dense cache
        contents for every allocated position <= qpos)."""
        from repro.core.serving import pages as PG
        codec = dcfg.kv_codec
        if codec:
            kq, ks = LY.kv_quantize(k, codec)
            vq, vs = LY.kv_quantize(v, codec)
            kv = {
                "k": PG.scatter_tokens(kv["k"], table, qpos, kq, page),
                "ks": PG.scatter_tokens(kv["ks"], table, qpos, ks, page),
                "v": PG.scatter_tokens(kv["v"], table, qpos, vq, page),
                "vs": PG.scatter_tokens(kv["vs"], table, qpos, vs, page),
            }
            ck = LY.kv_dequantize(PG.gather_tokens(kv["k"], table, page),
                                  PG.gather_tokens(kv["ks"], table, page),
                                  dcfg.param_dtype)
            cv = LY.kv_dequantize(PG.gather_tokens(kv["v"], table, page),
                                  PG.gather_tokens(kv["vs"], table, page),
                                  dcfg.param_dtype)
            return kv, ck, cv
        pk, pv = kv
        pk = PG.scatter_tokens(pk, table, qpos, k.astype(pk.dtype), page)
        pv = PG.scatter_tokens(pv, table, qpos, v.astype(pv.dtype), page)
        return ((pk, pv), PG.gather_tokens(pk, table, page),
                PG.gather_tokens(pv, table, page))

    def _decode_sub(self, p, x, kv, qpos, cos, sin, dcfg, window,
                    writer=None):
        """x: (B,C,D) replicated over model; qpos: (B,C) absolute
        positions per query token.  `writer(kv, k, v)` commits new K/V to
        the cache and returns (new_kv, ck, cv) dense read views
        (B,T,Kl,hd); the default writes the dense cache in place."""
        cfg = self.cfg
        uo = cfg.post_norms
        h = LY.rmsnorm(x, p["ln1"], cfg.norm_eps, uo)
        q, k, v, head_mask = LY._local_qkv(p["attn"], h, cfg, dcfg)
        if cfg.qk_norm:
            q = LY.rmsnorm(q, p["attn"]["q_norm"], cfg.norm_eps)
            k = LY.rmsnorm(k, p["attn"]["k_norm"], cfg.norm_eps)
        q = LY.apply_rope_pos(q, cos, sin)
        k = LY.apply_rope_pos(k, cos, sin)
        if writer is None:
            writer = functools.partial(self._dense_writer, qpos=qpos,
                                       dcfg=dcfg)
        new_kv, ck, cv = writer(kv, k, v)
        B, C = qpos.shape
        T = ck.shape[1]
        kl = ck.shape[2]
        hl = q.shape[2]
        group = hl // kl
        qg = q.reshape(B, C, kl, group, cfg.head_dim)
        s = jnp.einsum("bqkgh,btkh->bkgqt", qg * self._q_scale, ck,
                       preferred_element_type=jnp.float32)
        s = LY._softcap(s, cfg.attn_softcap)
        tpos = jnp.arange(T)
        msk = tpos[None, None, :] <= qpos[:, :, None]
        if window is not None:
            msk &= tpos[None, None, :] > qpos[:, :, None] - window
        s = jnp.where(msk[:, None, None, :, :], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqt,btkh->bqkgh", pr.astype(cv.dtype), cv)
        out = out.reshape(B, C, hl, cfg.head_dim)
        out = out * head_mask[None, None, :, None]
        o = jnp.einsum("bsh,hd->bsd",
                       out.reshape(B, C, hl * cfg.head_dim),
                       p["attn"]["wo"])
        o = lax.psum(o, dcfg.tp_axis)
        if cfg.post_norms:
            o = LY.rmsnorm(o, p["pn1"], cfg.norm_eps, uo)
        x = x + o
        h = LY.rmsnorm(x, p["ln2"], cfg.norm_eps, uo)
        o = self._ffn_decode(p["mlp"], h, dcfg)
        if cfg.post_norms:
            o = LY.rmsnorm(o, p["pn2"], cfg.norm_eps, uo)
        return x + o, new_kv

    def _cached_forward(self, params_tp, cache, toks, qpos, dcfg,
                        writer=None):
        """Shared decode/chunked-prefill core: embed toks (B,C) at
        positions qpos (B,C), scan the stack against the cache (dense or
        paged via `writer`), return (last-position logits, cache)."""
        cfg = self.cfg
        cos, sin = LY.rope_pos(qpos, cfg.head_dim, cfg.rope_theta)
        scale = math.sqrt(cfg.d_model) if cfg.post_norms else None
        x = LY.embed_apply(params_tp["embed"], toks, cfg, dcfg, scale=scale,
                           scatter=False)

        # The cache rides the scan CARRY and is updated in place at the
        # layer index: XLA aliases in-place dynamic-update-slice on while
        # carries, so exactly ONE cache buffer is ever live (scan xs/ys
        # emission would double-buffer it).
        L = self.n_steps

        def slice_kv(kv, idx):
            return jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, idx, 0,
                                                   keepdims=False), kv)

        def put_kv(kv, new, idx):
            return jax.tree.map(
                lambda a, n: lax.dynamic_update_index_in_dim(
                    a, n.astype(a.dtype), idx, 0), kv, new)

        def body(carry, inputs):
            xc, cache_all = carry
            p, idx = inputs
            kv = slice_kv(cache_all, idx)
            if self.layers_per_step == 1:
                w = cfg.sliding_window \
                    if not cfg.local_global_alternate else None
                y, kv2 = self._decode_sub(p, xc, kv, qpos, cos, sin, dcfg,
                                          w, writer)
            else:
                y, kv_l = self._decode_sub(p["local"], xc, kv[0], qpos,
                                           cos, sin, dcfg,
                                           cfg.sliding_window, writer)
                y, kv_g = self._decode_sub(p["global"], y, kv[1], qpos,
                                           cos, sin, dcfg, None, writer)
                kv2 = (kv_l, kv_g)
            return (y, put_kv(cache_all, kv2, idx)), None

        (x, cache), _ = lax.scan(
            body, (x, cache), (params_tp["blocks"], jnp.arange(L)))
        x = LY.rmsnorm(x, params_tp["final_norm"], cfg.norm_eps,
                       cfg.post_norms)
        x = x[:, -1:]
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params_tp["embed"],
                                preferred_element_type=jnp.float32)
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params_tp["head"],
                                preferred_element_type=jnp.float32)
        logits = LY._softcap(logits, cfg.final_softcap)
        return logits[:, 0], cache

    def decode_local(self, params_tp, cache, tok, pos, dcfg: DistConfig):
        """One decode step. tok: (B,) int32; pos: (B,) int32 PER-REQUEST
        positions — ragged batches decode at their own offsets.
        cache: pytree of (n_steps, B, T, Kl, hd) pairs."""
        return self._cached_forward(params_tp, cache, tok[:, None],
                                    pos[:, None], dcfg)

    def paged_step_local(self, params_tp, arena, table, toks, qpos, dcfg,
                         page: int):
        """One paged serving step: decode (C=1) or a prefill chunk (C>1).

        arena: pytree of page pools, leaves (n_steps, n_pages+1, page, ...)
        — the last pool row is the scratch page that inactive slots
        (table entries -1) harmlessly write to; table: (B, max_pages)
        int32 page ids local to this shard's pool; toks/qpos: (B, C).
        Returns (last-position logits (B, V/tp), updated arena)."""
        writer = functools.partial(self._paged_writer, table=table,
                                   qpos=qpos, dcfg=dcfg, page=page)
        return self._cached_forward(params_tp, arena, toks, qpos, dcfg,
                                    writer=writer)

    # ----------------------------------------------------------- costing --
    def block_stats(self, dcfg: DistConfig, batch_shape) -> BlockStats:
        """Per-(scan-step) workload for auto-wrapping, per device.

        Analytic (hw.py roofline) by default; when the dryrun harvested
        measured costs for this model instance (`measured_stats`, keyed by
        the same param names and shaped at the cell's own microbatch) those
        replace the analytic numbers."""
        if self.measured_stats is not None:
            return self.measured_stats
        cfg = self.cfg
        B, S = batch_shape          # per-device microbatch (cp-local seq)
        tokens = B * S
        d, hd = cfg.d_model, cfg.head_dim
        hq = cfg.q_heads_padded(dcfg.tp_size)
        pf, pb = {}, {}
        it = jnp.dtype(dcfg.param_dtype).itemsize

        def add(name, flops, nbytes):
            pf[name] = flops
            pb[name] = nbytes

        names, metas, _ = [], [], None
        from repro.core.meta import named_leaves
        for nm, m in named_leaves(self.block_metas(dcfg)):
            numel = m.numel_local(dcfg)
            # matmul params: 2*tokens*numel flops; norms: O(tokens*d)
            flops = 2.0 * tokens * numel if numel > 4 * d \
                else 8.0 * tokens * d / max(1, dcfg.tp_size)
            add(nm, flops, numel * it + flops / max(d, 1) * it)
        # attention itself (not a param op) folds into wq's consumer cost.
        # Under context parallelism each rank's S/cp queries attend to the
        # FULL sequence (the ring visits every KV block), so the kv span is
        # S * cp — this is what lets the bucket planners re-tighten when
        # per-device matmul compute shrinks by cp.
        attn_flops = 4.0 * tokens * (S * dcfg.cp_size) * hd \
            * (hq / dcfg.tp_size)
        first = next(iter(pf))
        pf[first] += attn_flops
        act = tokens * d * it / dcfg.tp_size
        return BlockStats(param_flops=pf, param_bytes=pb, act_bytes=act)

    def bucket_units(self) -> list[list[str]]:
        """Manual-wrapping module lists (paper: per-transformer-block)."""
        if self.layers_per_step == 2:
            return [["local/*"], ["global/*"]]
        return [["attn/*", "ln1"], ["mlp/*", "ln2", "pn1", "pn2"]]

    # ------------------------------------------------------------ inputs --
    def input_specs(self, shape: ShapeConfig, dcfg: DistConfig) -> dict:
        B, S = shape.global_batch, shape.seq_len
        ids = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            return {"tokens": ids, "targets": ids,
                    "valid": jax.ShapeDtypeStruct((B, S), jnp.float32)}
        if shape.kind == "prefill":
            return {"tokens": ids}
        # decode: one token + cache handled by launch/serve
        return {"tok": jax.ShapeDtypeStruct((B,), jnp.int32)}
