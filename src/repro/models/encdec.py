"""Encoder-decoder backbone (seamless-m4t-large-v2, T2TT/S2TT path).

The multimodal (speech) frontend is a STUB per the assignment: `input_specs`
feeds precomputed 1024-d frame embeddings directly to the encoder; the text
path embeds source tokens. Decoder = causal self-attention + cross-attention
over encoder memory + plain GELU FFN (seamless uses non-gated FFNs).

Sequence budget per cell: the assigned seq_len splits evenly between source
frames and target tokens (S_src = S_tgt = seq_len/2), so the total processed
positions per sample match the shape spec (DESIGN.md note).

Cross-attention and the paper's technique: encoder memory travels in the
decoder stack's CARRY (not consts) so its cotangent flows back to the encoder
through the hand-scheduled prefetch backward (core/stack.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives as coll
from repro.core.dist import DistConfig
from repro.core.irgraph import BlockStats
from repro.core.meta import ParamMeta
from repro.core.remat import maybe_remat
from repro.core.stack import apply_stack
from repro.models import layers as LY
from repro.models.common import (ArchConfig, ShapeConfig, StageSpec,
                                 even_stage_slices)


class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.n_enc = cfg.n_enc_layers or cfg.n_layers
        self.n_dec = cfg.n_dec_layers or cfg.n_layers
        self.n_steps = self.n_enc + self.n_dec

    # ------------------------------------------------------------- metas --
    def _xattn_metas(self, dcfg, dt, prefix):
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.head_dim
        lay = cfg.gqa_layout(dcfg.tp_size)
        hq, kvp = lay["hq"], lay["kvp"]
        kv_tp = 0 if lay["mode"] == "sharded" else None
        return {
            "wq": ParamMeta(prefix + "wq", (d, hq * hd), 1, dt),
            "wk": ParamMeta(prefix + "wk", (kvp * hd, d), kv_tp, dt),
            "wv": ParamMeta(prefix + "wv", (kvp * hd, d), kv_tp, dt),
            "wo": ParamMeta(prefix + "wo", (hq * hd, d), 0, dt),
        }

    def enc_block_metas(self, dcfg: DistConfig) -> dict:
        cfg = self.cfg
        dt = dcfg.storage_dtype
        return {
            "ln1": LY.norm_meta("e.ln1", cfg.d_model, dt),
            "attn": LY.attn_metas(cfg, dcfg, dt, prefix="e.attn."),
            "ln2": LY.norm_meta("e.ln2", cfg.d_model, dt),
            "mlp": LY.mlp_metas(cfg, dcfg, dt, prefix="e.mlp."),
        }

    def dec_block_metas(self, dcfg: DistConfig) -> dict:
        cfg = self.cfg
        dt = dcfg.storage_dtype
        return {
            "ln1": LY.norm_meta("d.ln1", cfg.d_model, dt),
            "attn": LY.attn_metas(cfg, dcfg, dt, prefix="d.attn."),
            "lnx": LY.norm_meta("d.lnx", cfg.d_model, dt),
            "xattn": self._xattn_metas(dcfg, dt, "d.xattn."),
            "ln2": LY.norm_meta("d.ln2", cfg.d_model, dt),
            "mlp": LY.mlp_metas(cfg, dcfg, dt, prefix="d.mlp."),
        }

    def metas(self, dcfg: DistConfig) -> dict:
        cfg = self.cfg
        dt = dcfg.storage_dtype
        return {
            "embed": LY.embed_meta("embed", cfg, dt),
            "front_proj": ParamMeta("front_proj",
                                    (cfg.frontend_dim, cfg.d_model),
                                    None, dt),
            "enc_blocks": self.enc_block_metas(dcfg),
            "dec_blocks": self.dec_block_metas(dcfg),
            "enc_norm": LY.norm_meta("enc_norm", cfg.d_model, dt),
            "final_norm": LY.norm_meta("final_norm", cfg.d_model, dt),
            "head": LY.head_meta("head", cfg, dt),
        }

    # alias used by runtime helpers that expect 'blocks'
    @property
    def stacked_keys(self):
        return {"enc_blocks": self.n_enc, "dec_blocks": self.n_dec}

    def stage_spec(self, n_stages: int) -> StageSpec:
        """The DECODER stack pipelines; the whole encoder (frontend, enc
        blocks, enc norm) plus the target embedding runs on stage 0 and the
        encoder memory rides the inter-stage state next to the decoder
        hidden — every stage's cross-attention reads it from the stream."""
        return StageSpec(
            n_stages=n_stages,
            pipelined="dec_blocks",
            layers_per_stage=even_stage_slices(self.n_dec, n_stages,
                                               self.cfg.name + ".dec"),
            pre_keys=("embed", "front_proj", "enc_blocks", "enc_norm"),
            post_keys=("final_norm", "head"),
        )

    # -------------------------------------------------------------- init --
    def _enc_init(self, key, dcfg):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": LY.norm_init(cfg.d_model),
            "attn": LY.attn_init(k1, cfg, dcfg),
            "ln2": LY.norm_init(cfg.d_model),
            "mlp": LY.mlp_init(k2, cfg),
        }

    def _dec_init(self, key, dcfg):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        x = LY.attn_init(k3, cfg, dcfg)
        return {
            "ln1": LY.norm_init(cfg.d_model),
            "attn": LY.attn_init(k1, cfg, dcfg),
            "lnx": LY.norm_init(cfg.d_model),
            "xattn": {k: x[k] for k in ("wq", "wk", "wv", "wo")},
            "ln2": LY.norm_init(cfg.d_model),
            "mlp": LY.mlp_init(k2, cfg),
        }

    def init_full(self, key, dcfg: DistConfig) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, self.n_enc + self.n_dec + 4)
        enc = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[self._enc_init(keys[i], dcfg)
                             for i in range(self.n_enc)])
        dec = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[self._dec_init(keys[self.n_enc + i], dcfg)
                             for i in range(self.n_dec)])
        return {
            "embed": LY.embed_init(keys[-1], cfg),
            "front_proj": jax.random.normal(
                keys[-2], (cfg.frontend_dim, cfg.d_model)) * 0.02,
            "enc_blocks": enc,
            "dec_blocks": dec,
            "enc_norm": LY.norm_init(cfg.d_model),
            "final_norm": LY.norm_init(cfg.d_model),
            "head": LY.head_init(keys[-3], cfg),
        }

    # ------------------------------------------------------------- blocks --
    def enc_block(self, p, consts, x, dcfg: DistConfig):
        cfg = self.cfg
        h = LY.rmsnorm(x, p["ln1"], cfg.norm_eps)
        xg = LY.sp_gather(h, dcfg)
        q, k, v, head_mask = LY._local_qkv(p["attn"], xg, cfg, dcfg)
        cos, sin = consts["rope_cos"], consts["rope_sin"]
        q, k = LY.apply_rope(q, cos, sin), LY.apply_rope(k, cos, sin)
        out = LY.attention(q, k, v, causal=False)        # bidirectional
        out = out * head_mask[None, None, :, None]
        B, S, hl, hd = out.shape
        o = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, hl * hd),
                       p["attn"]["wo"])
        x = x + LY.sp_scatter(o, dcfg)
        h = LY.rmsnorm(x, p["ln2"], cfg.norm_eps)
        return x + LY.mlp_apply(p["mlp"], h, cfg, dcfg), {}

    def _cross_attn(self, p, x_sp, mem_sp, dcfg):
        """Queries from decoder SP hidden; keys/values from encoder memory."""
        cfg = self.cfg
        xg = LY.sp_gather(x_sp, dcfg)
        mg = LY.sp_gather(mem_sp, dcfg)
        q, _, _, head_mask = LY._local_qkv(
            {"wq": p["wq"], "wk": p["wk"], "wv": p["wv"]}, xg, cfg, dcfg)
        _, k, v, _ = LY._local_qkv(
            {"wq": p["wq"], "wk": p["wk"], "wv": p["wv"]}, mg, cfg, dcfg)
        out = LY.attention(q, k, v, causal=False)
        out = out * head_mask[None, None, :, None]
        B, S, hl, hd = out.shape
        o = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, hl * hd), p["wo"])
        return LY.sp_scatter(o, dcfg)

    def dec_block(self, p, consts, carry, dcfg: DistConfig):
        cfg = self.cfg
        x, mem = carry["h"], carry["mem"]
        h = LY.rmsnorm(x, p["ln1"], cfg.norm_eps)
        h = LY.attn_apply(p["attn"], h, consts, cfg, dcfg)
        x = x + h
        h = LY.rmsnorm(x, p["lnx"], cfg.norm_eps)
        x = x + self._cross_attn(p["xattn"], h, mem, dcfg)
        h = LY.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + LY.mlp_apply(p["mlp"], h, cfg, dcfg)
        return {"h": x, "mem": mem}, {}

    # ------------------------------------------------------------- train --
    def stage_pre(self, storage, mb, dcfg: DistConfig):
        """Stage-0 entry: frontend + full encoder -> memory; target tokens
        -> decoder input.  Both ride the inter-stage state."""
        cfg = self.cfg
        frames = mb["frames"]                      # (B, S_src, frontend_dim)
        tokens = mb["tokens"]                      # (B, S_tgt)
        S_src = frames.shape[1]
        cos_e, sin_e = LY.rope_cache(S_src, cfg.head_dim, cfg.rope_theta)

        fp_meta = ParamMeta("front_proj", (cfg.frontend_dim, cfg.d_model),
                            None, dcfg.storage_dtype)
        wp = coll.replicate(storage["front_proj"], fp_meta, dcfg)
        mem = jnp.einsum("bsf,fd->bsd",
                         frames.astype(dcfg.param_dtype), wp)
        # identical on every TP rank -> slice (not reduce) into SP layout
        mem = LY.sp_slice(mem, dcfg)

        enc_fn = functools.partial(self.enc_block, dcfg=dcfg)
        mem, _ = apply_stack(enc_fn, self.enc_block_metas(dcfg), dcfg,
                             storage["enc_blocks"],
                             {"rope_cos": cos_e, "rope_sin": sin_e}, mem)
        en_meta = LY.norm_meta("enc_norm", cfg.d_model, dcfg.storage_dtype)
        mem = LY.rmsnorm(mem, coll.replicate(storage["enc_norm"], en_meta,
                                             dcfg), cfg.norm_eps)

        emb_meta = LY.embed_meta("embed", cfg, dcfg.storage_dtype)

        def embed_fn(shard, ids):
            table = coll.replicate(shard, emb_meta, dcfg)
            return LY.embed_apply(table, ids, cfg, dcfg)

        x = maybe_remat(embed_fn, "fsdp_only")(storage["embed"], tokens)
        return {"h": x, "mem": mem}

    def stage_blocks(self, storage, state, dcfg: DistConfig, plan=None):
        cfg = self.cfg
        S_tgt = state["h"].shape[1] * dcfg.tp_size
        cos_d, sin_d = LY.rope_cache(S_tgt, cfg.head_dim, cfg.rope_theta)
        dec_fn = functools.partial(self.dec_block, dcfg=dcfg)
        carry, _ = apply_stack(dec_fn, self.dec_block_metas(dcfg), dcfg,
                               storage["dec_blocks"],
                               {"rope_cos": cos_d, "rope_sin": sin_d},
                               state, plan=plan)
        return carry

    def stage_loss(self, storage, state, mb, dcfg: DistConfig):
        cfg = self.cfg
        fn_meta = LY.norm_meta("final_norm", cfg.d_model, dcfg.storage_dtype)
        x = LY.rmsnorm(state["h"], coll.replicate(storage["final_norm"],
                                                  fn_meta, dcfg),
                       cfg.norm_eps)
        hd_meta = LY.head_meta("head", cfg, dcfg.storage_dtype)
        w = coll.replicate(storage["head"], hd_meta, dcfg)
        logits = LY.head_logits(w, LY.sp_gather(x, dcfg), cfg, dcfg)
        loss, _ = LY.vocab_parallel_xent(logits, mb["targets"],
                                         mb["valid"], cfg, dcfg)
        return loss

    def loss_local(self, storage, batch, dcfg: DistConfig):
        state = self.stage_blocks(storage,
                                  self.stage_pre(storage, batch, dcfg), dcfg)
        return self.stage_loss(storage, state, batch, dcfg), {}

    # ------------------------------------------------------------- serve --
    def prefill_local(self, params_tp, batch, dcfg: DistConfig):
        """Encode frames, prefill the decoder over the target prompt.
        Returns (last logits (B, V/tp), cache {self, cross})."""
        cfg = self.cfg
        frames, tokens = batch["frames"], batch["tokens"]
        S_src, S_tgt = frames.shape[1], tokens.shape[1]
        cos_e, sin_e = LY.rope_cache(S_src, cfg.head_dim, cfg.rope_theta)
        cos_d, sin_d = LY.rope_cache(S_tgt, cfg.head_dim, cfg.rope_theta)

        mem = jnp.einsum("bsf,fd->bsd", frames.astype(dcfg.param_dtype),
                         params_tp["front_proj"])
        mem = LY.sp_slice(mem, dcfg)

        def enc_body(xc, p):
            y, _ = self.enc_block(p, {"rope_cos": cos_e, "rope_sin": sin_e},
                                  xc, dcfg)
            return y, None

        mem, _ = lax.scan(enc_body, mem, params_tp["enc_blocks"])
        mem = LY.rmsnorm(mem, params_tp["enc_norm"], cfg.norm_eps)
        mem_g = LY.sp_gather(mem, dcfg)

        x = LY.embed_apply(params_tp["embed"], tokens, cfg, dcfg)
        consts_d = {"rope_cos": cos_d, "rope_sin": sin_d}

        def dec_body(xc, p):
            # self attention, emitting kv
            h = LY.rmsnorm(xc, p["ln1"], cfg.norm_eps)
            hg = LY.sp_gather(h, dcfg)
            q, k, v, hm = LY._local_qkv(p["attn"], hg, cfg, dcfg)
            q2 = LY.apply_rope(q, cos_d, sin_d)
            k2 = LY.apply_rope(k, cos_d, sin_d)
            out = LY.attention(q2, k2, v, causal=True)
            out = out * hm[None, None, :, None]
            B, S, hl, hd = out.shape
            o = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, hl * hd),
                           p["attn"]["wo"])
            xc = xc + LY.sp_scatter(o, dcfg)
            # cross attention + cached cross kv
            h = LY.rmsnorm(xc, p["lnx"], cfg.norm_eps)
            _, xk, xv, _ = LY._local_qkv(
                {"wq": p["xattn"]["wq"], "wk": p["xattn"]["wk"],
                 "wv": p["xattn"]["wv"]}, mem_g, cfg, dcfg)
            hgq = LY.sp_gather(h, dcfg)
            q, _, _, hm = LY._local_qkv(
                {"wq": p["xattn"]["wq"], "wk": p["xattn"]["wk"],
                 "wv": p["xattn"]["wv"]}, hgq, cfg, dcfg)
            out = LY.attention(q, xk, xv, causal=False)
            out = out * hm[None, None, :, None]
            o = jnp.einsum("bsh,hd->bsd",
                           out.reshape(B, S, hl * hd), p["xattn"]["wo"])
            xc = xc + LY.sp_scatter(o, dcfg)
            h = LY.rmsnorm(xc, p["ln2"], cfg.norm_eps)
            xc = xc + LY.mlp_apply(p["mlp"], h, cfg, dcfg)
            kv_dt = dcfg.param_dtype
            return xc, ((k2.astype(kv_dt), v.astype(kv_dt)),
                        (xk.astype(kv_dt), xv.astype(kv_dt)))

        x, (self_kv, cross_kv) = lax.scan(dec_body, x,
                                          params_tp["dec_blocks"])
        x = LY.rmsnorm(x, params_tp["final_norm"], cfg.norm_eps)
        xg = LY.sp_gather(x, dcfg)[:, -1:]
        logits = jnp.einsum("bsd,dv->bsv", xg, params_tp["head"],
                            preferred_element_type=jnp.float32)
        return logits[:, 0], {"self": self_kv, "cross": cross_kv}

    def decode_local(self, params_tp, cache, tok, pos, dcfg: DistConfig):
        """One decoder token against (self-KV cache, cross-KV cache).

        pos: (B,) per-request positions — ragged batches advance each
        row independently.  cache = {"self": (L,B,T,Kl,hd) pairs,
        "cross": (L,B,S_src,Kl,hd) pairs precomputed from encoder memory
        at prefill}."""
        cfg = self.cfg
        cos, sin = LY.rope_pos(pos[:, None], cfg.head_dim, cfg.rope_theta)
        x = LY.embed_apply(params_tp["embed"], tok[:, None], cfg, dcfg,
                           scatter=False)
        ib = jnp.arange(tok.shape[0])

        def body(xc, inp):
            p, (kv_self, kv_cross) = inp
            # self attention (causal, cached)
            h = LY.rmsnorm(xc, p["ln1"], cfg.norm_eps)
            q, k, v, hm = LY._local_qkv(p["attn"], h, cfg, dcfg)
            q = LY.apply_rope_pos(q, cos, sin)
            k = LY.apply_rope_pos(k, cos, sin)
            ck, cv = kv_self
            ck = ck.at[ib, pos].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[ib, pos].set(v[:, 0].astype(cv.dtype))
            o = _cached_attn(q, ck, cv, pos, cfg, hm)
            o = jnp.einsum("bsh,hd->bsd", o, p["attn"]["wo"])
            o = lax.psum(o, dcfg.tp_axis)
            xc = xc + o
            # cross attention (static cache, no position mask)
            h = LY.rmsnorm(xc, p["lnx"], cfg.norm_eps)
            q, _, _, hm = LY._local_qkv(
                {"wq": p["xattn"]["wq"], "wk": p["xattn"]["wk"],
                 "wv": p["xattn"]["wv"]}, h, cfg, dcfg)
            xk, xv = kv_cross
            o = _cached_attn(q, xk, xv, None, cfg, hm)
            o = jnp.einsum("bsh,hd->bsd", o, p["xattn"]["wo"])
            o = lax.psum(o, dcfg.tp_axis)
            xc = xc + o
            # ffn
            h = LY.rmsnorm(xc, p["ln2"], cfg.norm_eps)
            u = jnp.einsum("bsd,df->bsf", h, p["mlp"]["wu"])
            o = jnp.einsum("bsf,fd->bsd",
                           jax.nn.gelu(u, approximate=True), p["mlp"]["wd"])
            o = lax.psum(o, dcfg.tp_axis)
            return xc + o, (ck, cv)

        x, self_kv = lax.scan(body, x,
                              (params_tp["dec_blocks"],
                               (cache["self"], cache["cross"])))
        x = LY.rmsnorm(x, params_tp["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params_tp["head"],
                            preferred_element_type=jnp.float32)
        return logits[:, 0], {"self": self_kv, "cross": cache["cross"]}

    # ----------------------------------------------------------- costing --
    def block_stats(self, dcfg: DistConfig, batch_shape) -> BlockStats:
        B, S = batch_shape          # per-device microbatch
        tokens = B * S
        it = jnp.dtype(dcfg.param_dtype).itemsize
        pf, pb = {}, {}
        from repro.core.meta import named_leaves
        for nm, m in named_leaves(self.dec_block_metas(dcfg)):
            pf[nm] = 2.0 * tokens * m.numel_local(dcfg)
            pb[nm] = m.numel_local(dcfg) * it
        return BlockStats(param_flops=pf, param_bytes=pb,
                          act_bytes=tokens * self.cfg.d_model * it / dcfg.tp_size)

    def bucket_units(self) -> list[list[str]]:
        return [["attn/*", "ln1"], ["xattn/*", "lnx"], ["mlp/*", "ln2"]]

    def input_specs(self, shape: ShapeConfig, dcfg: DistConfig) -> dict:
        cfg = self.cfg
        B = shape.global_batch
        S = shape.seq_len // 2            # split: S_src = S_tgt = seq/2
        ids = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim),
                                               jnp.float32),
                "tokens": ids, "targets": ids,
                "valid": jax.ShapeDtypeStruct((B, S), jnp.float32),
            }
        if shape.kind == "prefill":
            return {"frames": jax.ShapeDtypeStruct(
                (B, S, cfg.frontend_dim), jnp.float32), "tokens": ids}
        return {"tok": jax.ShapeDtypeStruct((B,), jnp.int32)}


def _cached_attn(q, ck, cv, pos, cfg, head_mask):
    """q: (B,1,Hl,hd); ck/cv: (B,T,Kl,hd). pos (B,) per-request;
    pos=None -> attend everything."""
    B, _, hl, hd = q.shape
    kl = ck.shape[2]
    group = hl // kl
    qg = q.reshape(B, 1, kl, group, hd)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg / math.sqrt(hd), ck,
                   preferred_element_type=jnp.float32)
    if pos is not None:
        msk = jnp.arange(ck.shape[1])[None, :] <= pos[:, None]
        s = jnp.where(msk[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bqkgh", p.astype(cv.dtype), cv)
    out = out.reshape(B, 1, hl, hd) * head_mask[None, None, :, None]
    return out.reshape(B, 1, hl * hd)
