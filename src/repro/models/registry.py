"""Model registry: arch id -> (ArchConfig, model instance)."""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.common import ArchConfig

ARCH_IDS = (
    "deepseek_coder_33b", "phi3_medium_14b", "gemma2_27b", "qwen3_1_7b",
    "qwen2_moe_a2_7b", "qwen3_moe_30b_a3b", "xlstm_1_3b",
    "seamless_m4t_large_v2", "zamba2_1_2b", "internvl2_26b",
    # the paper's own eval family (Table 2), used by benchmarks
    "llama3_8b",
)

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def build_model(cfg: ArchConfig):
    if cfg.family == "dense":
        from repro.models.dense import DenseLM
        return DenseLM(cfg)
    if cfg.family == "moe":
        from repro.models.moe import MoELM
        return MoELM(cfg)
    if cfg.family == "xlstm":
        from repro.models.xlstm import XLSTMLM
        return XLSTMLM(cfg)
    if cfg.family == "zamba":
        from repro.models.zamba2 import Zamba2LM
        return Zamba2LM(cfg)
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM
        return EncDecLM(cfg)
    if cfg.family == "vlm":
        from repro.models.vlm import VLM
        return VLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


def get_arch(arch_id: str, smoke: bool = False):
    """Returns (ArchConfig, model). `smoke` selects the reduced config."""
    arch_id = _ALIASES.get(arch_id, arch_id)
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    cfg = mod.SMOKE if smoke else mod.CONFIG
    return cfg, build_model(cfg)


# Minimal stack-depth bumps that make the reduced (smoke) configs
# partitionable into >1 pipeline stage — some smoke stacks are too shallow
# (gemma2's local/global pair scans as ONE step; zamba2's smoke tail breaks
# the uniform superblock program). Production configs are untouched.
PP_SMOKE_OVERRIDES: dict[str, dict] = {
    "gemma2_27b": dict(n_layers=4),
    "xlstm_1_3b": dict(n_layers=8),
    "zamba2_1_2b": dict(shared_attn_every=4),
}


def get_arch_for_pp(arch_id: str, n_stages: int = 2, smoke: bool = True):
    """`get_arch`, but guaranteeing `model.stage_spec(n_stages)` resolves —
    applying the smoke-config override when the stock stack is too shallow.
    Returns (ArchConfig, model)."""
    cfg, model = get_arch(arch_id, smoke=smoke)
    try:
        model.stage_spec(n_stages)
        return cfg, model
    except ValueError:
        if not smoke:
            raise
    over = PP_SMOKE_OVERRIDES.get(_ALIASES.get(arch_id, arch_id))
    if over is None:
        raise ValueError(
            f"{arch_id}: smoke config cannot partition into {n_stages} "
            "stages and no PP_SMOKE_OVERRIDES entry exists")
    cfg = dataclasses.replace(cfg, **over)
    model = build_model(cfg)
    model.stage_spec(n_stages)     # still-invalid overrides raise here
    return cfg, model
