"""Mixture-of-Experts family (qwen2-moe-a2.7b, qwen3-moe-30b-a3b).

Inherits attention/embedding/CE/serving from DenseLM and replaces the FFN
with: router (TP-replicated) + capacity-based top-k dispatch + expert-parallel
(EP) FFN + optional shared experts (classic TP) + shared-expert gate
(qwen2-moe).

EP rides the *model* mesh axis (the same axis as attention TP): expert tensors
are sharded on their leading expert dim (padded to a multiple of tp), tokens
travel via two all_to_alls. Under SimpleFSDP the expert weights are
additionally ZeRO-3 sharded over the data axis and bucket-gathered like any
other parameter — the paper's technique composes with EP exactly as it does
with TP (DESIGN.md SSArch-applicability).

Load-balance auxiliary loss (switch-style) flows out through the aux channel
of core.stack and is added to the CE loss in loss_local.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.dist import DistConfig
from repro.core.meta import ParamMeta
from repro.models import layers as LY
from repro.models.common import ArchConfig
from repro.models.dense import DenseLM


def experts_padded(cfg: ArchConfig, tp: int) -> int:
    m = max(cfg.pad_to, tp)
    assert m % tp == 0
    return -(-cfg.n_experts // m) * m


class MoELM(DenseLM):
    # ------------------------------------------------------------- params --
    def _ffn_metas(self, dcfg, dtype, prefix=""):
        cfg = self.cfg
        d, fe = cfg.d_model, cfg.d_ff_expert
        ep = experts_padded(cfg, dcfg.tp_size)
        m = {
            "router": ParamMeta(prefix + "router", (d, ep), None, dtype),
            "we_g": ParamMeta(prefix + "we_g", (ep, d, fe), 0, dtype),
            "we_u": ParamMeta(prefix + "we_u", (ep, d, fe), 0, dtype),
            "we_d": ParamMeta(prefix + "we_d", (ep, fe, d), 0, dtype),
        }
        if cfg.d_ff_shared:
            m.update(LY.mlp_metas(cfg, dcfg, dtype, prefix + "shared.",
                                  d_ff=cfg.d_ff_shared))
            m["shared_gate"] = ParamMeta(prefix + "shared_gate", (d, 1),
                                         None, dtype)
        return m

    def _ffn_init(self, key, dcfg):
        cfg = self.cfg
        d, fe = cfg.d_model, cfg.d_ff_expert
        ep = experts_padded(cfg, dcfg.tp_size)
        ks = jax.random.split(key, 5)
        sd = 0.02
        p = {
            "router": jax.random.normal(ks[0], (d, ep)) * sd,
            "we_g": jax.random.normal(ks[1], (ep, d, fe)) * sd,
            "we_u": jax.random.normal(ks[2], (ep, d, fe)) * sd,
            "we_d": jax.random.normal(ks[3], (ep, fe, d)) * sd * 0.5,
        }
        if cfg.d_ff_shared:
            p.update(LY.mlp_init(ks[4], cfg, d_ff=cfg.d_ff_shared))
            p["shared_gate"] = jnp.zeros((d, 1))
        return p

    # ----------------------------------------------------------- dispatch --
    def _route(self, x2d, router):
        """x2d: (T, D) -> top-k ids/weights + aux loss terms."""
        cfg = self.cfg
        ep = router.shape[1]
        logits = jnp.einsum("td,de->te", x2d, router,
                            preferred_element_type=jnp.float32)
        # padded experts never win: mask their logits
        if ep > cfg.n_experts:
            pad_mask = jnp.arange(ep) >= cfg.n_experts
            logits = jnp.where(pad_mask[None, :], -1e30, logits)
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = lax.top_k(probs, cfg.n_experts_active)
        if cfg.moe_norm_topk:
            w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        # switch-style load balance on the real experts
        T = x2d.shape[0]
        occupancy = jnp.zeros((ep,)).at[ids.reshape(-1)].add(1.0) \
            / (T * cfg.n_experts_active)
        mean_prob = probs.mean(0)
        aux = cfg.n_experts * jnp.sum(occupancy * mean_prob)
        return w.astype(x2d.dtype), ids, aux

    def _moe_ffn(self, p, x2d, dcfg: DistConfig):
        """Capacity-based EP dispatch. x2d: (T, D) local tokens."""
        cfg = self.cfg
        tp = dcfg.tp_size
        ep = p["we_g"].shape[0]  # params arrive TP-local... see note below
        # NOTE: params enter _ffn_apply already FSDP-gathered to the TP-local
        # compute shape (ep/tp, d, fe) -- but the ROUTER covers all ep
        # experts, so derive ep from the router's full width.
        ep = p["router"].shape[1]
        w, ids, aux = self._route(x2d, p["router"])
        T, D = x2d.shape
        k = cfg.n_experts_active
        C = max(4, int(-(-T * k * cfg.capacity_factor // ep)))
        C = -(-C // 4) * 4

        flat_ids = ids.reshape(-1)                       # (T*k,)
        tok_idx = jnp.repeat(jnp.arange(T), k)
        onehot = jax.nn.one_hot(flat_ids, ep, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - 1)
        pos = jnp.take_along_axis(pos, flat_ids[:, None], axis=1)[:, 0]
        keep = pos < C
        slot = jnp.where(keep, flat_ids * C + pos, ep * C)  # drop -> OOB
        buf = jnp.zeros((ep * C + 1, D), x2d.dtype)
        buf = buf.at[slot].add(x2d[tok_idx] *
                               keep[:, None].astype(x2d.dtype))
        buf = buf[:-1].reshape(ep, C, D)

        if tp > 1:  # EP exchange: (E, C, D) -> (E/tp, C*tp, D)
            buf = lax.all_to_all(buf, dcfg.tp_axis, split_axis=0,
                                 concat_axis=1, tiled=True)
        g = jnp.einsum("ecd,edf->ecf", buf, p["we_g"])
        u = jnp.einsum("ecd,edf->ecf", buf, p["we_u"])
        h = jax.nn.silu(g) * u
        out = jnp.einsum("ecf,efd->ecd", h, p["we_d"])
        if tp > 1:   # return exchange
            out = lax.all_to_all(out, dcfg.tp_axis, split_axis=1,
                                 concat_axis=0, tiled=True)
        out = out.reshape(ep * C, D)
        gathered = jnp.take(out, jnp.minimum(slot, ep * C - 1), axis=0)
        gathered = gathered * (keep & (slot < ep * C))[:, None] \
            .astype(out.dtype)
        combined = jnp.zeros((T, D), out.dtype).at[tok_idx].add(
            gathered * w.reshape(-1)[:, None])
        return combined, aux

    def _ffn_apply(self, p, x_sp, dcfg):
        cfg = self.cfg
        B, Ssp, D = x_sp.shape
        x2d = x_sp.reshape(B * Ssp, D)
        out, aux = self._moe_ffn(p, x2d, dcfg)
        out = out.reshape(B, Ssp, D)
        if cfg.d_ff_shared:
            sh = LY.mlp_apply({k: p[k] for k in ("wg", "wu", "wd")},
                              x_sp, cfg, dcfg)
            gate = jax.nn.sigmoid(
                jnp.einsum("bsd,dg->bsg", x_sp, p["shared_gate"]))
            out = out + sh * gate
        # /tp: same sum-over-TP-ranks gradient convention as the CE head
        return out, {"moe_aux": aux * self.cfg.router_aux_coef
                     / dcfg.tp_size}

    def _ffn_decode(self, p, x, dcfg):
        B = x.shape[0]
        out, _ = self._moe_ffn(p, x.reshape(B, -1), dcfg)
        out = out.reshape(B, 1, -1)
        # dispatch output is already full (tokens replicated over model
        # ranks in decode); only the TP-partial shared expert needs a psum
        if self.cfg.d_ff_shared:
            cfg = self.cfg
            hg = jnp.einsum("bsd,df->bsf", x, p["wg"])
            hu = jnp.einsum("bsd,df->bsf", x, p["wu"])
            sh = jnp.einsum("bsf,fd->bsd", jax.nn.silu(hg) * hu, p["wd"])
            sh = lax.psum(sh, dcfg.tp_axis)
            gate = jax.nn.sigmoid(
                jnp.einsum("bsd,dg->bsg", x, p["shared_gate"]))
            out = out + sh * gate
        return out

    # ------------------------------------------------------------- train --
    # The load-balance aux rides the inter-stage pipeline state (summed
    # across every stage's block slice) and is added to the CE loss at the
    # last stage — stage_pre/stage_blocks/stage_loss are inherited.
    def _aux0(self) -> dict:
        return {"moe_aux": jnp.zeros((), jnp.float32)}

    def _loss_aux(self, aux):
        return aux["moe_aux"]

    def bucket_units(self) -> list[list[str]]:
        return [["attn/*", "ln1"],
                ["mlp/router", "mlp/shared*", "mlp/wg", "mlp/wu", "mlp/wd",
                 "ln2"],
                ["mlp/we_*"]]
