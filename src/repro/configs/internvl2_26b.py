"""internvl2-26b [vlm]: InternViT (STUB frontend: precomputed 3200-d patch
embeddings, 1025 tokens) + InternLM2 backbone 48L d=6144 48H (GQA kv=8)
ff=16384 v=92553 [arXiv:2404.16821; hf]. 48 q heads / tp16 = 3 per rank;
kv (8 < 16) TP-replicated. long_500k skipped (full attention)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=92_560, head_dim=128,  # vocab padded 92553->92560 (tp16)
    vit_dim=3200, n_img_tokens=1025, skip_shapes=("long_500k",),
)

SMOKE = ArchConfig(
    name="internvl2-26b-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    vit_dim=48, n_img_tokens=8,
    pad_to=4,
)
