"""zamba2-1.2b [hybrid]: 38L d=2048 (Mamba2 d_state=64, 64 ssm heads x 64) +
weight-tied shared attention block (32H x 128 on concat(h, emb) = 4096 wide,
GQA kv=32, ff=8192) invoked every 6 mamba layers [arXiv:2411.15242; hf].
O(1)-state decode -> runs long_500k. Simplifications in models/zamba2.py."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="zamba", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32_000, head_dim=128,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
    shared_attn_every=6,
)

SMOKE = ArchConfig(
    name="zamba2-1.2b-smoke", family="zamba", n_layers=8, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, head_dim=32,
    ssm_state=8, ssm_head_dim=16, ssm_expand=2, ssm_conv=4, ssm_chunk=16,
    shared_attn_every=3,
    pad_to=4,
)
