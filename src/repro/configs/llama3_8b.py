"""llama3-8b [dense]: the paper's own eval model (Table 2 row 1):
32L d=4096 32H (GQA kv=8) ff=14336 v=128256. Used by benchmarks/fig3."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128_256, head_dim=128,
    rope_theta=500_000.0, skip_shapes=("long_500k",),
    # 4 pipeline stages x 8 layers on the production mesh: (pipe, data,
    # model) = (4, 4, 16), 1F1B (launch.mesh.production_dcfg).
    pp_stages=4,
)

SMOKE = ArchConfig(
    name="llama3-8b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    pad_to=4,
)
