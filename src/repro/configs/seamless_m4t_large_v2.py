"""seamless-m4t-large-v2 [audio]: enc-dec transformer backbone, 24 enc + 24
dec layers, d=1024 16H (kv=16) ff=8192 v=256206, plain GELU FFN
[arXiv:2308.11596; hf]. The speech frontend is a STUB: input_specs feeds
precomputed 1024-d frame embeddings. Assigned seq_len splits S_src=S_tgt=
seq/2. Enc-dec (not encoder-only) -> decode shapes run; long_500k skipped
(quadratic decoder self-attention)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=48,
    n_enc_layers=24, n_dec_layers=24, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=8192, vocab=256_208, head_dim=64,  # vocab padded 256206->256208 (tp16)
    gated_mlp="gelu", frontend_dim=1024, skip_shapes=("long_500k",),
)

SMOKE = ArchConfig(
    name="seamless-m4t-large-v2-smoke", family="encdec", n_layers=4,
    n_enc_layers=2, n_dec_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16, gated_mlp="gelu", frontend_dim=32,
    pad_to=4,
)
