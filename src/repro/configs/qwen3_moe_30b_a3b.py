"""qwen3-moe-30b-a3b [moe]: 48L d=2048 32H (GQA kv=4) ff_expert=768
v=151936, 128 routed top-8, qk_norm, norm_topk [hf:Qwen/Qwen3-30B-A3B; hf].
EP16: 128/16 = 8 experts per rank; kv (4 < 16) TP-replicated."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=768, vocab=151_936, head_dim=128,
    rope_theta=1_000_000.0, qk_norm=True,
    n_experts=128, n_experts_active=8, d_ff_expert=768, moe_norm_topk=True,
    skip_shapes=("long_500k",),
)

SMOKE = ArchConfig(
    name="qwen3-moe-30b-a3b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=64, vocab=256, head_dim=16, qk_norm=True,
    n_experts=8, n_experts_active=2, d_ff_expert=32, moe_norm_topk=True, capacity_factor=8.0, router_aux_coef=0.0,
    pad_to=4,
)
