"""deepseek-coder-33b [dense]: 62L d=7168 56H (GQA kv=8) ff=19200 v=32256.
llama-arch [arXiv:2401.14196; hf]. TP16 note: 56 q heads pad to 64 (masked);
kv (8 < 16) TP-replicated + per-rank group slice (DESIGN.md)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense", n_layers=62, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=19200, vocab=32256, head_dim=128,
    rope_theta=100_000.0, skip_shapes=("long_500k",),
    # 62 layers only split evenly 2 ways; (pipe, data, model) = (2, 8, 16)
    # with 31 layers per stage, 1F1B (launch.mesh.production_dcfg).
    pp_stages=2,
)

SMOKE = ArchConfig(
    name="deepseek-coder-33b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=6, n_kv_heads=2, d_ff=160, vocab=256, head_dim=16,
    rope_theta=100_000.0,
    pad_to=4,
)
