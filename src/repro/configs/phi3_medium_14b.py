"""phi3-medium-14b [dense]: 40L d=5120 40H (GQA kv=10) ff=17920 v=100352.
RoPE SwiGLU GQA [arXiv:2404.14219; unverified]. TP16: 40 q heads pad to 48
(masked); kv (10) TP-replicated."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=10, d_ff=17920, vocab=100_352, head_dim=128,
    skip_shapes=("long_500k",),
)

SMOKE = ArchConfig(
    name="phi3-medium-14b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=5, n_kv_heads=5, d_ff=128, vocab=320, head_dim=16,
    pad_to=4,
)
