"""qwen2-moe-a2.7b [moe]: 24L d=2048 16H (GQA kv=16) ff_expert=1408
v=151936, 60 routed top-4 + 4 shared experts [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].
TP16/EP16 note: 60 experts pad to 64 (padded experts masked in routing);
shared experts fused into one TP MLP of d_ff 4*1408=5632."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=5632, vocab=151_936, head_dim=128,
    n_experts=60, n_experts_active=4, n_shared_experts=4,
    d_ff_expert=1408, d_ff_shared=5632, moe_norm_topk=False,
    skip_shapes=("long_500k",),
)

SMOKE = ArchConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
    n_experts=6, n_experts_active=2, n_shared_experts=1,
    d_ff_expert=32, d_ff_shared=128, moe_norm_topk=False, capacity_factor=8.0, router_aux_coef=0.0,
    pad_to=4,
)
