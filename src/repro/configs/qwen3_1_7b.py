"""qwen3-1.7b [dense]: 28L d=2048 16H (GQA kv=8) ff=6144 v=151936.
qk_norm, GQA, tied embeddings [hf:Qwen/Qwen3-8B; hf]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=6144, vocab=151_936, head_dim=128,
    rope_theta=1_000_000.0, qk_norm=True, tie_embeddings=True,
    skip_shapes=("long_500k",),
)

SMOKE = ArchConfig(
    name="qwen3-1.7b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    qk_norm=True, tie_embeddings=True,
    pad_to=4,
)
