"""gemma2-27b [dense]: 46L d=4608 32H (GQA kv=16) ff=36864 v=256000.
Local(4096)+global alternating, attn softcap 50 / final softcap 30, GeGLU,
sandwich norms, tied embeddings [arXiv:2408.00118; hf]. long_500k skipped:
every other layer is full global attention."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b", family="dense", n_layers=46, d_model=4608,
    n_heads=32, n_kv_heads=16, d_ff=36864, vocab=256_000, head_dim=128,
    attn_softcap=50.0, final_softcap=30.0, sliding_window=4096,
    local_global_alternate=True, post_norms=True, gated_mlp="geglu",
    tie_embeddings=True, skip_shapes=("long_500k",),
)

SMOKE = ArchConfig(
    name="gemma2-27b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=192, vocab=256, head_dim=16,
    attn_softcap=50.0, final_softcap=30.0, sliding_window=8,
    local_global_alternate=True, post_norms=True, gated_mlp="geglu",
    tie_embeddings=True,
    pad_to=4,
)
