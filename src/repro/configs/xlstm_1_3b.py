"""xlstm-1.3b [ssm]: 48L d=2048 4H ff=0 v=50304, sLSTM + mLSTM blocks 7:1
[arXiv:2405.04517; unverified]. O(1)-state decode -> runs long_500k.
Simplifications: full-matrix q/k/v projections (not block-diag-4); see
models/xlstm.py docstring."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="xlstm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50_304, head_dim=512,
    ssm_expand=2, ssm_conv=4, ssm_chunk=128, slstm_every=8,
)

SMOKE = ArchConfig(
    name="xlstm-1.3b-smoke", family="xlstm", n_layers=4, d_model=64,
    n_heads=2, n_kv_heads=2, d_ff=0, vocab=256, head_dim=32,
    ssm_expand=2, ssm_conv=4, ssm_chunk=16, slstm_every=4,
    pad_to=4,
)
