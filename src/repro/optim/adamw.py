"""ZeRO-sharded AdamW.

Because parameters live as flat local shards (core/meta.py), the optimizer is
trivially ZeRO-3: moments are allocated per-shard and the update is purely
elementwise on local data — no optimizer-state collectives, ever. Global-norm
clipping needs one scalar psum per vma class (TP-sharded leaves are summed
over the model axis; TP-replicated leaves are counted once).

The elementwise update dispatches to the fused Pallas kernel on TPU
(kernels/adamw) and to the jnp reference elsewhere.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.dist import DistConfig
from repro.core.meta import ParamMeta


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(storage_tree, cfg: DistConfig | None = None):
    """Fresh moments (+ the error-feedback accumulator when the config's
    comm_precision carries one — `DistConfig.needs_ef`).  The EF residual is
    strictly smaller than one quantization step, so it lives in float32
    regardless of the param dtype; it is storage-shaped like m/v (ZeRO-3:
    per-shard, no optimizer-state collectives)."""
    zeros = lambda p: jnp.zeros_like(p)
    state = {
        "m": jax.tree.map(zeros, storage_tree),
        "v": jax.tree.map(zeros, storage_tree),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg is not None and cfg.needs_ef:
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), storage_tree)
    return state


def _leaf_metas(metas_tree):
    return jax.tree_util.tree_flatten(
        metas_tree, is_leaf=lambda x: isinstance(x, ParamMeta))[0]


def global_grad_norm(grads_tree, metas_tree, cfg: DistConfig,
                     pp_replicated: tuple[str, ...] = ()):
    """sqrt(sum of squares over every distinct gradient element).

    `pp_replicated` names top-level groups replicated across pipeline
    stages (StageSpec.replicated_keys): after the pipe-axis grad psum every
    stage holds the SAME values, so their squares are scaled by 1/pp_size
    to count each element once under the pipe-axis psum below."""
    tp_sq = jnp.zeros((), jnp.float32)
    rep_sq = jnp.zeros((), jnp.float32)
    for k in sorted(grads_tree):   # match jax dict-key flatten order
        w = 1.0 / cfg.pp_size if k in pp_replicated else 1.0
        for g, m in zip(jax.tree.leaves(grads_tree[k]),
                        _leaf_metas(metas_tree[k])):
            s = jnp.sum(g.astype(jnp.float32) ** 2) * w
            if m.tp_dim is not None:
                tp_sq = tp_sq + s
            else:
                rep_sq = rep_sq + s
    # shards are distinct across fsdp axes -> always psum there;
    # tp-sharded leaves are also distinct across the model axis.
    total = lax.psum(rep_sq, cfg.fsdp_axes) \
        + lax.psum(tp_sq, (*cfg.fsdp_axes, cfg.tp_axis))
    if cfg.pp_axis is not None:
        # each pipe rank holds a distinct stage: the global norm (and hence
        # the clip scale, which must agree across stages) spans all of them
        total = lax.psum(total, cfg.pp_axis)
    return jnp.sqrt(total)


def _update_leaf(p, g, m, v, lr, ocfg: AdamWConfig, t):
    from repro.kernels.adamw import ops as adamw_ops
    return adamw_ops.adamw_update(p, g, m, v, lr=lr, b1=ocfg.b1, b2=ocfg.b2,
                                  eps=ocfg.eps, wd=ocfg.weight_decay, t=t)


def _error_feedback(grads, ef):
    """Quantize-compensate hop (QSGD/EF14 style): the shard-local reduced
    gradient is pushed through the SAME fp8 wire codec the quantized
    reduce-scatter uses, with the rounding residual carried to the next
    step.  `g2 = g + ef; gq = dq(q(g2)); ef' = g2 - gq` — deterministic RTN
    here (EF compensates the bias; the in-collective hop is the stochastic
    one).  Applied uniformly whenever the state carries "ef"
    (comm_precision in {"fp8_ef", "auto"}): the step function and the state
    tree must not depend on the per-block traced plan."""
    from repro.kernels.quant import ops as quant_ops

    def one(g, e):
        g2 = g.astype(jnp.float32) + e
        gq = quant_ops.roundtrip(g2, "fp8", stochastic=False)
        return gq, g2 - gq

    out = jax.tree.map(one, grads, ef)
    gq = jax.tree.map(lambda o: o[0], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return gq, new_ef


def apply_adamw(storage, grads, opt_state, metas_tree, cfg: DistConfig,
                ocfg: AdamWConfig, lr, pp_replicated: tuple[str, ...] = ()):
    """One AdamW step on the sharded storage. Returns (params, opt_state,
    grad_norm)."""
    t = opt_state["step"] + 1
    new_ef = None
    if "ef" in opt_state:
        grads, new_ef = _error_feedback(grads, opt_state["ef"])
    gnorm = global_grad_norm(grads, metas_tree, cfg, pp_replicated)
    scale = jnp.minimum(1.0, ocfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if ocfg.grad_clip else 1.0

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        return _update_leaf(p, g, m, v, lr, ocfg, t)

    out = jax.tree.map(upd, storage, grads, opt_state["m"], opt_state["v"])
    new_p = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": t}
    if new_ef is not None:
        new_state["ef"] = new_ef
    return new_p, new_state, gnorm
