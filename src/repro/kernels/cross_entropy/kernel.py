"""Streaming cross-entropy Pallas kernel.

At 256k vocab the logits row (1 MiB fp32 per token) dominates the LM head's
memory traffic; materializing softmax doubles it. This kernel streams vocab
blocks through VMEM keeping only running (max, sumexp, target-logit)
accumulators — one pass for the loss, one fused pass for dlogits.

Grid (row_blocks, vocab_blocks); vocab dim sequential so the scratch
accumulators carry; loss written on the last vocab step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import pallas_tpu_compiler_params

_CompilerParams = pallas_tpu_compiler_params()


ROW_BLOCK = 8
V_BLOCK = 2048
NEG = -1e30


def _xent_kernel(x_ref, t_ref, loss_ref, lse_ref, m_ref, s_ref, tl_ref, *,
                 vocab, n_v):
    jv = pl.program_id(1)

    @pl.when(jv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        tl_ref[...] = jnp.zeros_like(tl_ref)

    x = x_ref[...].astype(jnp.float32)            # (R, Vb)
    col = jv * V_BLOCK + jax.lax.broadcasted_iota(
        jnp.int32, (ROW_BLOCK, V_BLOCK), 1)
    x = jnp.where(col < vocab, x, NEG)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, x.max(-1))
    corr = jnp.exp(m_prev - m_new)
    s_ref[...] = s_ref[...] * corr + jnp.exp(x - m_new[:, None]).sum(-1)
    m_ref[...] = m_new
    t = t_ref[...]                                 # (R,)
    hit = (col == t[:, None])
    tl_ref[...] = tl_ref[...] + jnp.where(hit, x, 0.0).sum(-1)

    @pl.when(jv == n_v - 1)
    def _finish():
        lse = jnp.log(jnp.maximum(s_ref[...], 1e-30)) + m_ref[...]
        lse_ref[...] = lse
        loss_ref[...] = lse - tl_ref[...]


def xent_fwd(logits, targets, vocab: int | None = None,
             interpret: bool = False):
    """logits (R, V) with R % ROW_BLOCK == 0; V padded to V_BLOCK outside.
    `vocab` = real (unpadded) vocab width; padding columns are masked."""
    R, V = logits.shape
    n_v = V // V_BLOCK
    kern = functools.partial(_xent_kernel, vocab=vocab or V, n_v=n_v)
    return pl.pallas_call(
        kern,
        out_shape=[jax.ShapeDtypeStruct((R,), jnp.float32),
                   jax.ShapeDtypeStruct((R,), jnp.float32)],
        grid=(R // ROW_BLOCK, n_v),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, V_BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((ROW_BLOCK,), lambda i, j: (i,)),
        ],
        out_specs=[pl.BlockSpec((ROW_BLOCK,), lambda i, j: (i,)),
                   pl.BlockSpec((ROW_BLOCK,), lambda i, j: (i,))],
        scratch_shapes=[pltpu.VMEM((ROW_BLOCK,), jnp.float32)] * 3,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(logits, targets)


def _dx_kernel(x_ref, t_ref, lse_ref, g_ref, dx_ref):
    jv = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    p = jnp.exp(x - lse_ref[...][:, None])
    col = jv * V_BLOCK + jax.lax.broadcasted_iota(
        jnp.int32, (ROW_BLOCK, V_BLOCK), 1)
    onehot = (col == t_ref[...][:, None]).astype(jnp.float32)
    dx_ref[...] = ((p - onehot) * g_ref[...][:, None]).astype(dx_ref.dtype)


def xent_bwd(logits, targets, lse, g, interpret: bool = False):
    R, V = logits.shape
    return pl.pallas_call(
        _dx_kernel,
        out_shape=jax.ShapeDtypeStruct((R, V), logits.dtype),
        grid=(R // ROW_BLOCK, V // V_BLOCK),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, V_BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((ROW_BLOCK,), lambda i, j: (i,)),
            pl.BlockSpec((ROW_BLOCK,), lambda i, j: (i,)),
            pl.BlockSpec((ROW_BLOCK,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, V_BLOCK), lambda i, j: (i, j)),
        interpret=interpret,
    )(logits, targets, lse, g)
