"""Cross-entropy oracle: per-row loss + lse on full logits."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def xent(logits, targets):
    """logits (R, V) fp; targets (R,) int. Returns (loss (R,), lse (R,))."""
    lf = logits.astype(jnp.float32)
    m = lf.max(-1)
    lse = jnp.log(jnp.exp(lf - m[:, None]).sum(-1)) + m
    tl = jnp.take_along_axis(lf, targets[:, None], axis=1)[:, 0]
    return lse - tl, lse


def dlogits(logits, targets, lse, g):
    """Backward: d loss / d logits given upstream per-row cotangent g."""
    p = jnp.exp(logits.astype(jnp.float32) - lse[:, None])
    onehot = jax.nn.one_hot(targets, logits.shape[1], dtype=jnp.float32)
    return ((p - onehot) * g[:, None]).astype(logits.dtype)
