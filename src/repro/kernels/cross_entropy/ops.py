"""Fused streaming CE op with custom VJP (both directions Pallas)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cross_entropy import kernel as K
from repro.kernels.cross_entropy import ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fused_xent(logits, targets, interpret: bool = False):
    """logits (R, V), targets (R,) -> per-row loss (R,) fp32."""
    loss, _ = _run_fwd(logits, targets, interpret)
    return loss


def _pad(logits, targets):
    R, V = logits.shape
    pr = (-R) % K.ROW_BLOCK
    pv = (-V) % K.V_BLOCK
    lp = jnp.pad(logits, ((0, pr), (0, pv)), constant_values=0)
    tp = jnp.pad(targets, (0, pr))
    return lp, tp, R, V


def _run_fwd(logits, targets, interpret):
    lp, tp, R, V = _pad(logits, targets)
    loss, lse = K.xent_fwd(lp, tp, vocab=V, interpret=interpret)
    return loss[:R], lse[:R]


def _vjp_fwd(logits, targets, interpret):
    loss, lse = _run_fwd(logits, targets, interpret)
    return loss, (logits, targets, lse)


def _vjp_bwd(interpret, res, g):
    logits, targets, lse = res
    lp, tp, R, V = _pad(logits, targets)
    lsep = jnp.pad(lse, (0, lp.shape[0] - R), constant_values=1.0)
    gp = jnp.pad(g, (0, lp.shape[0] - R))
    dx = K.xent_bwd(lp, tp, lsep, gp, interpret=interpret)
    return dx[:R, :V], None


fused_xent.defvjp(_vjp_fwd, _vjp_bwd)
