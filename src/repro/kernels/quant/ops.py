"""Quantize/dequantize ops: backend dispatcher.

`roundtrip` — the entry the collectives use: encode the flat bucket buffer
to the wire codec and decode it back, which (because dequant commutes with
all-gather and with psum's direct reduce when each contribution is
quantized exactly once) is numerically identical to shipping the quantized
payload.  Pure-jnp math (ref.py) everywhere except real TPUs, where the
Pallas pair runs; `roundtrip_pallas` is also exercised in interpret mode
by the kernel test sweep on CPU.

The op is intentionally non-differentiable: it only ever runs inside the
gather custom_vjp's hand-written forward/backward, never under autodiff.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant import kernel as K
from repro.kernels.quant import ref

QCHUNK = ref.QCHUNK


def roundtrip(x: jax.Array, codec: str | None,
              stochastic: bool = False) -> jax.Array:
    if codec is None:
        return x
    if jax.default_backend() == "tpu":
        return roundtrip_pallas(x, codec, stochastic)
    return ref.roundtrip(x, codec, stochastic)


# ---------------------------------------------------------------------------
# KV-cache codec: the serving cache / paged-arena storage format.
#
# Each (..., head_dim) vector is padded to a whole number of QCHUNK groups
# and quantized with the SAME chunk_scales/encode_chunks math the wire
# codec uses (deterministic RTN — a cache readback must be reproducible),
# so KV-cache quantization and collective compression share one audited
# code path.  Scales ride alongside as (..., kv_chunks(head_dim)) f32.
# ---------------------------------------------------------------------------
def kv_chunks(head_dim: int) -> int:
    """Scale groups per head vector: ceil(head_dim / QCHUNK)."""
    return -(-head_dim // QCHUNK)


def kv_wire_dtype(codec: str):
    return ref.WIRE_DTYPE[codec]


def encode_kv(x: jax.Array, codec: str):
    """x: (..., hd) -> (wire values (..., hd), f32 scales (..., nc))."""
    hd = x.shape[-1]
    nc = kv_chunks(hd)
    pad = nc * QCHUNK - hd
    xf = x.astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    x2 = xf.reshape(-1, QCHUNK)
    scale = ref.chunk_scales(x2, codec)
    q = ref.encode_chunks(x2, scale, codec, stochastic=False)
    q = q.reshape(*x.shape[:-1], nc * QCHUNK)[..., :hd]
    return q, scale.reshape(*x.shape[:-1], nc)


def decode_kv(q: jax.Array, scales: jax.Array, dtype) -> jax.Array:
    """Inverse of `encode_kv` back to `dtype` (same trailing hd)."""
    hd = q.shape[-1]
    s = jnp.repeat(scales, QCHUNK, axis=-1)[..., :hd]
    return (q.astype(jnp.float32) * s).astype(dtype)


def roundtrip_pallas(x: jax.Array, codec: str, stochastic: bool = False,
                     interpret: bool = False) -> jax.Array:
    """Pallas encode+decode of an arbitrary-shaped buffer: chunk to
    (m, QCHUNK), pad rows to the kernel's ROW_BLOCK (zero rows quantize to
    zero under the scale=1 guard), run the pair, slice back."""
    x2, n = ref.chunk(x)
    m = x2.shape[0]
    pad = (-m) % K.ROW_BLOCK
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    seed = ref.buffer_seed(x2) if stochastic else jnp.uint32(0)
    q, s = K.quant_fwd(x2, seed, codec, stochastic, interpret=interpret)
    out = K.dequant_fwd(q, s, interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)
