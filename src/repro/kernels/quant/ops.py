"""Quantize/dequantize ops: backend dispatcher.

`roundtrip` — the entry the collectives use: encode the flat bucket buffer
to the wire codec and decode it back, which (because dequant commutes with
all-gather and with psum's direct reduce when each contribution is
quantized exactly once) is numerically identical to shipping the quantized
payload.  Pure-jnp math (ref.py) everywhere except real TPUs, where the
Pallas pair runs; `roundtrip_pallas` is also exercised in interpret mode
by the kernel test sweep on CPU.

The op is intentionally non-differentiable: it only ever runs inside the
gather custom_vjp's hand-written forward/backward, never under autodiff.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant import kernel as K
from repro.kernels.quant import ref

QCHUNK = ref.QCHUNK


def roundtrip(x: jax.Array, codec: str | None,
              stochastic: bool = False) -> jax.Array:
    if codec is None:
        return x
    if jax.default_backend() == "tpu":
        return roundtrip_pallas(x, codec, stochastic)
    return ref.roundtrip(x, codec, stochastic)


def roundtrip_pallas(x: jax.Array, codec: str, stochastic: bool = False,
                     interpret: bool = False) -> jax.Array:
    """Pallas encode+decode of an arbitrary-shaped buffer: chunk to
    (m, QCHUNK), pad rows to the kernel's ROW_BLOCK (zero rows quantize to
    zero under the scale=1 guard), run the pair, slice back."""
    x2, n = ref.chunk(x)
    m = x2.shape[0]
    pad = (-m) % K.ROW_BLOCK
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    seed = ref.buffer_seed(x2) if stochastic else jnp.uint32(0)
    q, s = K.quant_fwd(x2, seed, codec, stochastic, interpret=interpret)
    out = K.dequant_fwd(q, s, interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)
