"""Reference (pure-jnp) per-chunk quantization codec for wire compression.

Codec: the flat bucket buffer is chunked into QCHUNK=128-element groups;
each chunk carries one f32 absmax-derived scale plus one byte per element
(fp8 e4m3 or int8).  Wire bytes = n + 4*ceil(n/128), i.e. 0.516x of bf16
for LANE-aligned buckets — the figure the planner prices.

Encode is round-to-nearest for params (forward all-gather: deterministic,
bit-identical across ranks) and stochastic for grads (reduce-scatter:
unbiased, the condition Markov et al.'s EF convergence analysis needs).
Stochastic rounding is hand-rolled — jax 0.4 has no pltpu.stochastic_round:
fp8 adds a 20-bit uniform dither below e4m3's 3 retained mantissa bits in
the f32 bit pattern and truncates; int8 uses floor(y + u).  The dither is
an integer hash of (seed + flat index); the seed is the wraparound u32 sum
of the buffer's own bits — data-dependent yet trace-safe, so no PRNG key
threads through the gather custom_vjp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

QCHUNK = 128          # elements per scale group (= flat-shard storage LANE)
SCALE_BYTES = 4       # one f32 scale per chunk rides along on the wire
QMAX = {"fp8": 448.0, "int8": 127.0}
WIRE_DTYPE = {"fp8": jnp.float8_e4m3fn, "int8": jnp.int8}
CODECS = tuple(QMAX)


def hash_u32(idx: jax.Array, seed: jax.Array) -> jax.Array:
    """Cheap integer mix (Knuth multiplicative + xor-shift avalanche)."""
    h = seed + idx * jnp.uint32(2654435761)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x45D9F3B)
    return h ^ (h >> 16)


def buffer_seed(x2: jax.Array) -> jax.Array:
    """Wraparound u32 sum of the buffer's bits: a trace-safe, data-dependent
    dither seed (|1 so an all-zero buffer still dithers)."""
    bits = jax.lax.bitcast_convert_type(x2.astype(jnp.float32), jnp.uint32)
    return jnp.sum(bits, dtype=jnp.uint32) | jnp.uint32(1)


def sr_fp8(y: jax.Array, h: jax.Array) -> jax.Array:
    """Stochastic-round f32 (pre-clipped to +-448) to e4m3: add a 20-bit
    uniform dither below the 3 retained mantissa bits, truncate, cast.
    Carries into the exponent are correct SR at binade boundaries; the e4m3
    subnormal range re-rounds deterministically on cast (negligible mass)."""
    bits = jax.lax.bitcast_convert_type(y, jnp.uint32)
    sign = bits & jnp.uint32(0x80000000)
    mag = bits & jnp.uint32(0x7FFFFFFF)
    mag = (mag + (h >> 12)) & jnp.uint32(0xFFF00000)
    z = jax.lax.bitcast_convert_type(sign | mag, jnp.float32)
    return jnp.clip(z, -448.0, 448.0).astype(jnp.float8_e4m3fn)


def sr_int8(y: jax.Array, h: jax.Array) -> jax.Array:
    u = (h >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    return jnp.clip(jnp.floor(y + u), -127.0, 127.0).astype(jnp.int8)


def chunk(x: jax.Array) -> tuple[jax.Array, int]:
    """Flatten + zero-pad to the (n_chunks, QCHUNK) f32 view the codec
    quantizes over. Returns (view, original element count)."""
    n = x.size
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-n) % QCHUNK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, QCHUNK), n


def chunk_scales(x2: jax.Array, codec: str) -> jax.Array:
    """Per-chunk f32 scale: absmax / QMAX, with 1.0 guarding all-zero
    chunks (and the zero padding `chunk` appends).  Computed as a multiply
    by the reciprocal so the Pallas kernel and this reference produce
    bit-identical scales (a divide by a non-power-of-two constant is
    strength-reduced differently across backends)."""
    absmax = jnp.max(jnp.abs(x2), axis=1, keepdims=True)
    return jnp.where(absmax > 0, absmax * (1.0 / QMAX[codec]), 1.0)


def encode_chunks(x2: jax.Array, scale: jax.Array, codec: str,
                  stochastic: bool, seed: jax.Array | None = None):
    """Quantize a pre-chunked (m, QCHUNK) f32 view against `scale`."""
    qmax = QMAX[codec]
    y = jnp.clip(x2 / scale, -qmax, qmax)
    if stochastic:
        if seed is None:
            seed = buffer_seed(x2)
        idx = jnp.arange(x2.size, dtype=jnp.uint32).reshape(x2.shape)
        h = hash_u32(idx, seed)
        return sr_fp8(y, h) if codec == "fp8" else sr_int8(y, h)
    if codec == "fp8":
        return y.astype(jnp.float8_e4m3fn)
    return jnp.clip(jnp.round(y), -127.0, 127.0).astype(jnp.int8)


def quantize(x: jax.Array, codec: str = "fp8", stochastic: bool = False):
    """-> (q, scales): wire values (n_chunks, QCHUNK) in e4m3/int8
    (zero-padded past x.size) and f32 scales (n_chunks, 1)."""
    x2, _ = chunk(x)
    scale = chunk_scales(x2, codec)
    return encode_chunks(x2, scale, codec, stochastic), scale


def dequantize(q: jax.Array, scales: jax.Array, n: int, shape, dtype):
    """Inverse of `quantize`: wire values + scales back to the original
    shape/dtype."""
    x = q.astype(jnp.float32) * scales
    return x.reshape(-1)[:n].reshape(shape).astype(dtype)


def roundtrip(x: jax.Array, codec: str | None = "fp8",
              stochastic: bool = False) -> jax.Array:
    """quantize -> dequantize in one call — numerically identical to
    sending `x` over the wire in `codec` and decoding on the receiver
    (dequant commutes with gather/direct-reduce, so quantizing each
    contribution once before the existing collective reproduces the
    wire-quantized result exactly)."""
    if codec is None:
        return x
    q, s = quantize(x, codec, stochastic)
    return dequantize(q, s, x.size, x.shape, x.dtype)
