"""Pallas TPU quantize/dequantize over the (n_chunks, QCHUNK) chunk view.

Tiling: row blocks of (ROW_BLOCK, QCHUNK) = (8, 128) — one fp32 block is
4 KiB, the scale reduction is lane-local, and QCHUNK equals the flat-shard
storage LANE so bucket buffers chunk without reshuffling.  Scales are a
(n_chunks, 1) f32 output blocked (ROW_BLOCK, 1).  The stochastic-rounding
dither seed arrives as a (1, 1) u32 operand (it is traced — derived from
the buffer's own bits by ops.py); per-element dither indices come from
2-D broadcasted iotas offset by the grid position.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quant import ref

ROW_BLOCK = 8
QCHUNK = ref.QCHUNK


def _quant_kernel(x_ref, seed_ref, q_ref, s_ref, *, codec: str,
                  stochastic: bool):
    x = x_ref[...].astype(jnp.float32)           # (ROW_BLOCK, QCHUNK)
    scale = ref.chunk_scales(x, codec)
    qmax = ref.QMAX[codec]
    y = jnp.clip(x / scale, -qmax, qmax)
    if stochastic:
        row0 = (pl.program_id(0) * ROW_BLOCK).astype(jnp.uint32)
        r = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 0) + row0
        c = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
        h = ref.hash_u32(r * jnp.uint32(QCHUNK) + c, seed_ref[0, 0])
        q = ref.sr_fp8(y, h) if codec == "fp8" else ref.sr_int8(y, h)
    elif codec == "fp8":
        q = y.astype(jnp.float8_e4m3fn)
    else:
        q = jnp.clip(jnp.round(y), -127.0, 127.0).astype(jnp.int8)
    q_ref[...] = q
    s_ref[...] = scale


def quant_fwd(x2d: jax.Array, seed: jax.Array, codec: str,
              stochastic: bool, interpret: bool = False):
    """(m, QCHUNK) f32 -> ((m, QCHUNK) wire dtype, (m, 1) f32 scales).
    m must be a multiple of ROW_BLOCK (ops.py pads)."""
    m, d = x2d.shape
    assert d == QCHUNK and m % ROW_BLOCK == 0, "ops.py pads"
    kernel = functools.partial(_quant_kernel, codec=codec,
                               stochastic=stochastic)
    return pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((m, d), ref.WIRE_DTYPE[codec]),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        grid=(m // ROW_BLOCK,),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK, 1), lambda i: (i, 0)),
        ],
        interpret=interpret,
    )(x2d, seed.reshape(1, 1))


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...]).astype(o_ref.dtype)


def dequant_fwd(q: jax.Array, scales: jax.Array,
                interpret: bool = False) -> jax.Array:
    """Inverse pass: wire values + per-chunk scales -> f32 chunk view."""
    m, d = q.shape
    assert d == QCHUNK and m % ROW_BLOCK == 0, "ops.py pads"
    return pl.pallas_call(
        _dequant_kernel,
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        grid=(m // ROW_BLOCK,),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
        interpret=interpret,
    )(q, scales)
