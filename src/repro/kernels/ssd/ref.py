"""Mamba-2 SSD (state-space dual) — pure-jnp chunked oracle + decode step.

This is both the reference for the Pallas kernel and the lowering used by
models/zamba2.py (chunked: O(T·(hd·ds + Lc·hd)) compute, scan over chunks).

Shapes: x (B,T,H,P) [P=headdim], dt (B,T,H) positive, A (H,) negative,
Bm/Cm (B,T,G,N) [N=d_state, G groups, H % G == 0], D (H,) skip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.vmautil import vary_like


def ssd_chunked(x, dt, A, Bm, Cm, D=None, chunk: int = 128, state=None):
    B, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Lc = min(chunk, T)
    pad = (-T) % Lc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nC = (T + pad) // Lc

    def rs(a):
        return a.reshape(B, nC, Lc, *a.shape[2:]).swapaxes(0, 1)

    xc, dtc, Bc, Cc = rs(x), rs(dt), rs(Bm), rs(Cm)

    if state is None:
        S0 = jnp.zeros((B, H, P, N), jnp.float32)
        S0 = vary_like(S0, (x, dt, Bm, Cm))
    else:
        S0 = state

    def chunk_step(S_in, inp):
        xb, dtb, Bb, Cb = inp
        dtf = dtb.astype(jnp.float32)
        dA = dtf * A[None, None, :]                    # (B,Lc,H) negative
        cum = jnp.cumsum(dA, axis=1)                   # inclusive
        # L[t,s] = exp(cum_t - cum_s) for s <= t (decay between s and t)
        Ldec = jnp.exp(cum[:, :, None] - cum[:, None, :, :])
        tri = jnp.tril(jnp.ones((Lc, Lc), bool))
        Ldec = jnp.where(tri[None, :, :, None], Ldec, 0.0)
        xf = xb.astype(jnp.float32) * dtf[..., None]   # dt-weighted input
        Bf = Bb.astype(jnp.float32)
        Cf = Cb.astype(jnp.float32)
        # expand groups to heads
        Bh = jnp.repeat(Bf, rep, axis=2)               # (B,Lc,H,N)
        Ch = jnp.repeat(Cf, rep, axis=2)
        # intra-chunk: y_t = sum_s<=t (C_t . B_s) Ldec[t,s] x_s
        CB = jnp.einsum("blhn,bshn->blsh", Ch, Bh)
        y_intra = jnp.einsum("blsh,bshp->blhp", CB * Ldec, xf)
        # inter-chunk: y_t += C_t . (decay_t * S_in)
        dec_t = jnp.exp(cum)                           # (B,Lc,H)
        y_inter = jnp.einsum("blhn,bhpn->blhp", Ch, S_in) \
            * dec_t[..., None]
        y = y_intra + y_inter
        # state: S_out = exp(cum_T) S_in + sum_s exp(cum_T - cum_s) B_s x_s
        decT = jnp.exp(cum[:, -1])                     # (B,H)
        w = jnp.exp(cum[:, -1][:, None] - cum)         # (B,Lc,H)
        S_out = decT[..., None, None] * S_in + jnp.einsum(
            "bshp,bshn->bhpn", xf * w[..., None], Bh)
        return S_out, y

    S, ys = lax.scan(chunk_step, S0, (xc, dtc, Bc, Cc))
    y = ys.swapaxes(0, 1).reshape(B, T + pad, H, P)[:, :T]
    if D is not None:
        y = y + x[:, :T] * D[None, None, :, None]
    return y.astype(x.dtype), S


def ssd_step(S, x, dt, A, Bm, Cm, D=None):
    """Decode: x (B,H,P); dt (B,H); Bm/Cm (B,G,N); S (B,H,P,N)."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, :])                     # (B,H)
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    xf = x.astype(jnp.float32) * dtf[..., None]
    S = dA[..., None, None] * S + xf[..., :, None] * Bh[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", S, Ch)
    if D is not None:
        y = y + x * D[None, :, None]
    return S, y.astype(x.dtype)
