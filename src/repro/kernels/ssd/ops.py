"""SSD op wrapper: reshapes the model's (B,T,H,P) layout into the kernel's
chunked (BH, nC, Lc, *) layout; backward delegates to the jnp chunked oracle
(ref.ssd_chunked) via custom VJP."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd import kernel as K
from repro.kernels.ssd import ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def ssd(x, dt, A, Bm, Cm, D, chunk: int = K.CHUNK,
        interpret: bool = False):
    """Same contract as ref.ssd_chunked (state-less entry, y only)."""
    return _fwd_impl(x, dt, A, Bm, Cm, D, chunk, interpret)


def _fwd_impl(x, dt, A, Bm, Cm, D, chunk, interpret):
    B, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Lc = min(chunk, T)
    pad = (-T) % Lc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nC = (T + pad) // Lc
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)

    def to_bh(a):   # (B, T, H, ...) -> (B*H, nC, Lc, ...)
        a = a.reshape(B, nC, Lc, H, *a.shape[3:])
        a = jnp.moveaxis(a, 3, 1)
        return a.reshape(B * H, nC, Lc, *a.shape[4:])

    y = K.ssd_fwd(jnp.tile(A, B), to_bh(x), to_bh(dt), to_bh(Bh),
                  to_bh(Ch), interpret=interpret)
    y = y.reshape(B, H, nC, Lc, P)
    y = jnp.moveaxis(y, 1, 3).reshape(B, nC * Lc, H, P)[:, :T]
    if D is not None:
        y = y + x[:, :T] * D[None, None, :, None]
    return y.astype(x.dtype)


def _vjp_fwd(x, dt, A, Bm, Cm, D, chunk, interpret):
    return _fwd_impl(x, dt, A, Bm, Cm, D, chunk, interpret), \
        (x, dt, A, Bm, Cm, D)


def _vjp_bwd(chunk, interpret, res, ct):
    x, dt, A, Bm, Cm, D = res
    _, vjp = jax.vjp(
        lambda *args: ref.ssd_chunked(*args, chunk=chunk)[0],
        x, dt, A, Bm, Cm, D)
    return vjp(ct)


ssd.defvjp(_vjp_fwd, _vjp_bwd)
