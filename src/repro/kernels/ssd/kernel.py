"""Mamba-2 SSD chunk kernel (Pallas TPU).

One grid step processes one (batch*head, chunk) cell: the intra-chunk
quadratic term (Lc x Lc decay-masked CB^T), the inter-chunk contribution of
the carried state, and the state update — state lives in VMEM scratch and
carries across the sequential chunk dimension (same schedule as the flash
kernel's kv dim). Mirrors kernels/ssd/ref.ssd_chunked for ngroups folded to
per-head B/C (the ops wrapper pre-broadcasts groups).

Layout per (BH) slice: x (nC, Lc, P), dt (nC, Lc), B/C (nC, Lc, N).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import pallas_tpu_compiler_params

_CompilerParams = pallas_tpu_compiler_params()


CHUNK = 128


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, s_ref, *,
                n_chunks):
    jc = pl.program_id(1)

    @pl.when(jc == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    A = a_ref[0]                                    # scalar decay rate (neg)
    dt = dt_ref[0, 0].astype(jnp.float32)           # (Lc,)
    x = x_ref[0, 0].astype(jnp.float32)             # (Lc, P)
    Bm = b_ref[0, 0].astype(jnp.float32)            # (Lc, N)
    Cm = c_ref[0, 0].astype(jnp.float32)            # (Lc, N)
    Lc = dt.shape[0]

    dA = dt * A                                     # (Lc,)
    cum = jnp.cumsum(dA)                            # inclusive
    dec = cum[:, None] - cum[None, :]               # (Lc, Lc)
    tri = jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 1)
    L = jnp.where(tri, jnp.exp(dec), 0.0)
    xw = x * dt[:, None]                            # dt-weighted input
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Lc, Lc)
    y = jax.lax.dot_general(CB * L, xw, (((1,), (0,)), ((), ())))
    # inter-chunk
    S_in = s_ref[...]                               # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, S_in, (((1,), (1,)), ((), ())))
    y_ref[0, 0, ...] = y.astype(y_ref.dtype)
    # state update
    w = jnp.exp(cum[-1] - cum)                      # (Lc,)
    s_ref[...] = jnp.exp(cum[-1]) * S_in + jax.lax.dot_general(
        xw * w[:, None], Bm, (((0,), (0,)), ((), ())))


def ssd_fwd(A, x, dt, Bm, Cm, interpret: bool = False):
    """A (BH,); x (BH, nC, Lc, P); dt (BH, nC, Lc); Bm/Cm (BH, nC, Lc, N).
    Returns y (BH, nC, Lc, P)."""
    BH, nC, Lc, P = x.shape
    N = Bm.shape[-1]
    kern = functools.partial(_ssd_kernel, n_chunks=nC)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((BH, nC, Lc, P), x.dtype),
        grid=(BH, nC),
        in_specs=[
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, 1, Lc, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Lc), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, Lc, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Lc, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Lc, P), lambda b, c: (b, c, 0, 0)),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(A, x, dt, Bm, Cm)
