"""AdamW op dispatcher: Pallas fused kernel on TPU, jnp oracle elsewhere."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.adamw import kernel as K
from repro.kernels.adamw import ref


def adamw_update(p, g, m, v, *, lr, b1, b2, eps, wd, t):
    if jax.default_backend() == "tpu":
        return adamw_update_pallas(p, g, m, v, lr=lr, b1=b1, b2=b2, eps=eps,
                                   wd=wd, t=t)
    return ref.adamw_update(p, g, m, v, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                            t=t)


def adamw_update_pallas(p, g, m, v, *, lr, b1, b2, eps, wd, t,
                        interpret: bool = False):
    shape = p.shape
    n = p.size
    pad = (-n) % K.BLOCK

    def flat(x):
        f = x.reshape(-1).astype(jnp.float32) if x.dtype != p.dtype \
            else x.reshape(-1)
        return jnp.pad(f, (0, pad)) if pad else f

    lr_a = jnp.asarray([lr], jnp.float32)
    t_a = jnp.asarray([t], jnp.float32).reshape(1)
    po, mo, vo = K.adamw_flat(flat(p), flat(g).astype(p.dtype),
                              flat(m), flat(v), lr_a, t_a,
                              b1=b1, b2=b2, eps=eps, wd=wd,
                              interpret=interpret)
    unflat = lambda x: x[:n].reshape(shape)
    return unflat(po), unflat(mo), unflat(vo)
