"""AdamW update — pure-jnp oracle (bias-corrected, decoupled decay)."""

from __future__ import annotations

import jax.numpy as jnp


def adamw_update(p, g, m, v, *, lr, b1, b2, eps, wd, t):
    pf = p.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    m = b1 * m.astype(jnp.float32) + (1 - b1) * gf
    v = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
    tf = t.astype(jnp.float32) if hasattr(t, "astype") else float(t)
    mhat = m / (1 - b1 ** tf)
    vhat = v / (1 - b2 ** tf)
    pf = pf - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * pf)
    return pf.astype(p.dtype), m.astype(p.dtype), v.astype(p.dtype)
