"""Fused AdamW Pallas kernel: param, grad, m, v in one HBM pass.

The optimizer touches every parameter byte x4 reads + x3 writes; unfused XLA
on CPU/older compilers can issue these as several kernels. Fusing gives a
pure memory-bound single pass — the optimizer step's memory roofline term.

Tiling: everything is flat ZeRO-shard data; tile 1-D in (8*128)-element
blocks (fp32 vreg-aligned). ops.py pads to the block multiple.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 128


def _adamw_kernel(p_ref, g_ref, m_ref, v_ref, lr_ref, t_ref,
                  po_ref, mo_ref, vo_ref, *, b1, b2, eps, wd):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lr = lr_ref[0].astype(jnp.float32)
    t = t_ref[0].astype(jnp.float32)
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1 ** t)
    vhat = v / (1 - b2 ** t)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    po_ref[...] = p.astype(po_ref.dtype)
    mo_ref[...] = m.astype(mo_ref.dtype)
    vo_ref[...] = v.astype(vo_ref.dtype)


def adamw_flat(p, g, m, v, lr, t, *, b1, b2, eps, wd,
               interpret: bool = False):
    """All inputs flat (N,) with N % BLOCK == 0; lr/t are (1,) arrays."""
    n = p.shape[0]
    kern = functools.partial(_adamw_kernel, b1=b1, b2=b2, eps=eps, wd=wd)
    out_shape = [jax.ShapeDtypeStruct((n,), p.dtype)] * 3
    blk = pl.BlockSpec((BLOCK,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        kern,
        out_shape=out_shape,
        grid=(n // BLOCK,),
        in_specs=[blk, blk, blk, blk, scalar, scalar],
        out_specs=[blk, blk, blk],
        interpret=interpret,
    )(p, g, m, v, lr, t)
