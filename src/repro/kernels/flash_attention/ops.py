"""Flash attention op: GQA-aware wrapper + custom VJP.

Forward runs the Pallas kernel (TPU; interpret on CPU tests); backward uses
the jnp chunked formulation (models/layers.attention_chunked) — flash
backward kernels are a classic follow-up optimization and the chunked lax
bwd already has the right memory behaviour.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref


def _to_bh(x):
    B, S, H, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)


def _from_bh(x, B, H):
    BH, S, hd = x.shape
    return x.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    q_scale=None, interpret=False):
    """q: (B,S,H,hd); k/v: (B,T,Kh,hd), H % Kh == 0. Returns (B,S,H,hd)."""
    return _fwd_impl(q, k, v, causal, window, softcap, q_scale, interpret)


def _fwd_impl(q, k, v, causal, window, softcap, q_scale, interpret):
    B, S, H, hd = q.shape
    T, Kh = k.shape[1], k.shape[2]
    group = H // Kh
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    qb, kb, vb = _to_bh(q), _to_bh(kr), _to_bh(vr)
    pad_q = (-S) % K.Q_BLOCK
    pad_k = (-T) % K.KV_BLOCK
    if pad_q:
        qb = jnp.pad(qb, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kb = jnp.pad(kb, ((0, 0), (0, pad_k), (0, 0)))
        vb = jnp.pad(vb, ((0, 0), (0, pad_k), (0, 0)))
    out = K.flash_fwd(qb, kb, vb, causal=causal, window=window,
                      softcap=softcap, q_scale=q_scale, interpret=interpret)
    out = out[:, :S]
    return _from_bh(out, B, H)


def _vjp_fwd(q, k, v, causal, window, softcap, q_scale, interpret):
    out = _fwd_impl(q, k, v, causal, window, softcap, q_scale, interpret)
    return out, (q, k, v)


def _vjp_bwd(causal, window, softcap, q_scale, interpret, res, ct):
    from repro.models.layers import attention_chunked
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_chunked(
            q_, k_, v_, causal=causal, window=window, softcap=softcap,
            q_scale=q_scale), q, k, v)
    return vjp(ct)


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
