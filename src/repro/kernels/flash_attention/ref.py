"""Quadratic attention oracle for the flash kernel. Layout (BH, S, hd)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal=True, window=None, softcap=None,
              q_scale=None):
    BH, S, hd = q.shape
    T = k.shape[1]
    scale = q_scale if q_scale is not None else 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pos_q = jnp.arange(S)[:, None]
    pos_k = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= pos_q >= pos_k
    if window is not None:
        mask &= pos_q - pos_k < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)) \
        .astype(q.dtype)
