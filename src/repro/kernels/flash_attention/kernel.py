"""Flash attention (forward) Pallas TPU kernel.

Online-softmax over KV blocks with VMEM accumulators. Grid is
(batch*heads, q_blocks, kv_blocks); the kv dimension is sequential
("arbitrary") so the fp32 accumulator/max/sum scratch persists across kv
steps — the canonical TPU flash schedule. Blocks are MXU-aligned
(Q_BLOCK x head_dim and KV_BLOCK x head_dim with 128 defaults).

Variants (static): causal masking, sliding window (gemma2 local layers),
logit softcap. GQA is handled by the ops wrapper (q heads grouped to their
kv head before the kernel sees a plain (BH, S, hd) problem).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import pallas_tpu_compiler_params

_CompilerParams = pallas_tpu_compiler_params()


Q_BLOCK = 128
KV_BLOCK = 128
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal, window, softcap, scale, kv_len, n_kv):
    jq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (Qb, hd)
    k = k_ref[0].astype(jnp.float32)                  # (Kb, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (Qb, Kb)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jq * Q_BLOCK + jax.lax.broadcasted_iota(jnp.int32,
                                                    (Q_BLOCK, KV_BLOCK), 0)
    k_pos = jk * KV_BLOCK + jax.lax.broadcasted_iota(jnp.int32,
                                                     (Q_BLOCK, KV_BLOCK), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(jk == n_kv - 1)
    def _finish():
        o_ref[0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)[:, None]
                         ).astype(o_ref.dtype)


def flash_fwd(q, k, v, *, causal=True, window=None, softcap=None,
              q_scale=None, interpret: bool = False):
    """q: (BH, Sq, hd); k/v: (BH, Skv, hd). Sq % Q_BLOCK == 0,
    Skv padded to KV_BLOCK by the caller; kv_len masks the padding."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    assert Sq % Q_BLOCK == 0 and Skv % KV_BLOCK == 0
    n_q = Sq // Q_BLOCK
    n_kv = Skv // KV_BLOCK
    scale = q_scale if q_scale is not None else 1.0 / math.sqrt(hd)
    kern = functools.partial(
        _flash_kernel, causal=causal, window=window, softcap=softcap,
        scale=scale, kv_len=Skv, n_kv=n_kv)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, Q_BLOCK, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, KV_BLOCK, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, KV_BLOCK, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q_BLOCK, hd), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((Q_BLOCK, hd), jnp.float32),   # acc
            pltpu.VMEM((Q_BLOCK,), jnp.float32),      # running max
            pltpu.VMEM((Q_BLOCK,), jnp.float32),      # running sum
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
