"""Pure-jnp RMSNorm oracle (also the differentiable default implementation —
XLA fuses it into one pass; the Pallas kernel is the explicit-tiling TPU
fast path validated against this)."""

from __future__ import annotations

import jax.numpy as jnp


def rmsnorm(x, w, eps: float = 1e-5, unit_offset: bool = False):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jnp.reciprocal(jnp.sqrt(var + eps))
    scale = (w.astype(jnp.float32) + 1.0) if unit_offset \
        else w.astype(jnp.float32)
    return (y * scale).astype(dt)
