"""RMSNorm op: differentiable dispatcher.

`rmsnorm` — default entry used by the models: pure-jnp math (ref.py) that XLA
fuses; fully differentiable, runs everywhere.

`rmsnorm_pallas` — explicit Pallas forward with a custom VJP (backward in
jnp), used on real TPUs and exercised by the kernel test sweep in
interpret mode on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm import kernel as K
from repro.kernels.rmsnorm import ref


def rmsnorm(x, w, eps: float = 1e-5, unit_offset: bool = False):
    if jax.default_backend() == "tpu":
        return rmsnorm_pallas(x, w, eps=eps, unit_offset=unit_offset)
    return ref.rmsnorm(x, w, eps=eps, unit_offset=unit_offset)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def rmsnorm_pallas(x, w, eps: float = 1e-5, unit_offset: bool = False,
                   interpret: bool = False):
    d = x.shape[-1]
    rows = x.size // d
    pad = (-rows) % K.ROW_BLOCK
    x2 = x.reshape(rows, d)
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = K.rmsnorm_fwd(x2, w, eps, unit_offset, interpret=interpret)
    return out[:rows].reshape(x.shape)


def _fwd(x, w, eps, unit_offset, interpret):
    return rmsnorm_pallas(x, w, eps, unit_offset, interpret), (x, w)


def _bwd(eps, unit_offset, interpret, res, ct):
    x, w = res
    _, vjp = jax.vjp(
        lambda xx, ww: ref.rmsnorm(xx, ww, eps=eps, unit_offset=unit_offset),
        x, w)
    return vjp(ct)


rmsnorm_pallas.defvjp(_fwd, _bwd)
