"""Pallas TPU RMSNorm: one pass over rows, fp32 accumulation in VMEM.

Tiling: rows x D blocks of (ROW_BLOCK, D). D (model dim) stays whole per
block — for every assigned arch D <= 7168, so a (8, 7168) fp32 block is
~229 KiB, far under the ~128 MiB VMEM budget, and keeps the reduction
lane-local. Row count is padded to a multiple of ROW_BLOCK by `ops`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, unit_offset: bool):
    x = x_ref[...].astype(jnp.float32)            # (ROW_BLOCK, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    scale = w + 1.0 if unit_offset else w
    o_ref[...] = (x * inv * scale[None, :]).astype(o_ref.dtype)


def rmsnorm_fwd(x2d: jax.Array, w: jax.Array, eps: float,
                unit_offset: bool, interpret: bool = False) -> jax.Array:
    rows, d = x2d.shape
    assert rows % ROW_BLOCK == 0, "ops.py pads rows"
    kernel = functools.partial(_rmsnorm_kernel, eps=eps,
                               unit_offset=unit_offset)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((rows, d), x2d.dtype),
        grid=(rows // ROW_BLOCK,),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
        interpret=interpret,
    )(x2d, w)
