"""Fault-tolerance primitives: failure detection/injection, straggler
mitigation, restart policy.

The container is single-host, so hardware failures are *simulated* through
the same interfaces a multi-host deployment would use: the trainer consults a
`FailureSource` each step (in production: a heartbeat/barrier watchdog over
jax.distributed), and on failure tears the step down and restarts from the
last checkpoint — bit-exact, as tests/test_integration.py asserts.

Straggler mitigation follows the standard production recipe: track a rolling
median of step wall-times; a step exceeding `threshold x median` is flagged
and counted, and after `escalate_after` consecutive flags the policy asks for
a restart (in production: cordon the slow host and rejoin the job elastically
— which our topology-independent checkpoints support directly).
"""

from __future__ import annotations

import collections
import dataclasses
import statistics
import time


class FailureSource:
    """Interface: returns True if the cluster lost a participant."""

    def check(self, step: int) -> bool:
        return False


@dataclasses.dataclass
class InjectedFailures(FailureSource):
    """Deterministic failure injection for tests/examples."""

    fail_at_steps: tuple[int, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> bool:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            return True
        return False


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 3.0
    escalate_after: int = 3
    window: int = 32

    def __post_init__(self):
        self._times = collections.deque(maxlen=self.window)
        self._consecutive = 0
        self.flags = 0

    def observe(self, dt: float) -> str:
        """Returns 'ok' | 'straggler' | 'escalate'."""
        if len(self._times) >= 5:
            med = statistics.median(self._times)
            if dt > self.threshold * med:
                self.flags += 1
                self._consecutive += 1
                self._times.append(dt)
                if self._consecutive >= self.escalate_after:
                    self._consecutive = 0
                    return "escalate"
                return "straggler"
        self._consecutive = 0
        self._times.append(dt)
        return "ok"


class StepTimer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self.dt = time.monotonic() - self.t0
        return False
