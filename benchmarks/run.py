"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Distributed behaviour benches run
on 8 fake CPU devices (set here, in this entry point only — tests and the
dry-run manage their own device counts).

Run:  PYTHONPATH=src python -m benchmarks.run [table3 table5 ...] [--json]

``--json`` additionally writes machine-readable results for the benches that
support it (fig4 -> benchmarks/results/BENCH_overlap.json: per-arch exposure
+ modeled step time for the none/block/greedy/auto_dp plans; pipeline ->
benchmarks/results/BENCH_pipeline.json: modeled bubble fraction + per-stage
exposure per schedule over the staged archs; mem ->
benchmarks/results/BENCH_memory.json: modeled per-device peak + step time
per remat mode per arch incl. the budgeted auto-SAC row — the paper's
Table 3 sweep; ctx -> benchmarks/results/BENCH_context.json: per ctx
degree, the per-device sequence shard, modeled ring exposure and modeled
peak/activation memory — the long-context sweep; serve ->
benchmarks/results/BENCH_serving.json: ServePlan analytics — modeled paged
vs dense decode tok/s, continuous-vs-static virtual-clock latency, prefix
hit rates; obs -> benchmarks/results/BENCH_obs.json: instrumentation
overhead of the metrics registry vs a smoke step, per-arch
modeled-vs-measured drift residuals for step time / peak memory / decode
rate, and the trace invariant — non-overlapped comm-lane time equals the
modeled exposed_s on the pp2 x dp2 x cp2 layout; profile ->
benchmarks/results/BENCH_profile.json: the closed profile -> calibrate ->
replan loop per arch — measured wall step, the analytic plan's
modeled-step residual vs the calibrated replanned plan's (the calibrated
|residual| must be strictly smaller), plus the modeled-vs-measured
overlay trace invariant) so the perf trajectory is tracked across PRs.
"""

import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")
OVERLAP_JSON = os.path.join(RESULTS_DIR, "BENCH_overlap.json")
PIPELINE_JSON = os.path.join(RESULTS_DIR, "BENCH_pipeline.json")
MEMORY_JSON = os.path.join(RESULTS_DIR, "BENCH_memory.json")
CONTEXT_JSON = os.path.join(RESULTS_DIR, "BENCH_context.json")
SERVING_JSON = os.path.join(RESULTS_DIR, "BENCH_serving.json")
OBS_JSON = os.path.join(RESULTS_DIR, "BENCH_obs.json")
PROFILE_JSON = os.path.join(RESULTS_DIR, "BENCH_profile.json")


def main() -> None:
    from benchmarks import paper_tables as T
    from benchmarks import roofline

    args = sys.argv[1:]
    flags = [a for a in args if a.startswith("--")]
    unknown = [f for f in flags if f != "--json"]
    if unknown:
        sys.exit(f"unknown flag(s): {unknown}; supported: --json")
    emit_json = "--json" in flags
    names = [a for a in args if not a.startswith("--")]

    benches = {
        "table3": T.table3_debuggability,
        "table4": T.table4_compile_time,
        "table5": T.table5_reorder_bucket,
        "table6": T.table6_ag_placement,
        "fig3": T.fig3_vs_gspmd,
        "fig4": lambda: T.fig4_autowrap(
            json_path=OVERLAP_JSON if emit_json else None),
        "fig5": T.fig5_convergence,
        "pipeline": lambda: T.pipeline_bench(
            json_path=PIPELINE_JSON if emit_json else None),
        "mem": lambda: T.memory_table(
            json_path=MEMORY_JSON if emit_json else None),
        "ctx": lambda: T.context_table(
            json_path=CONTEXT_JSON if emit_json else None),
        "serve": lambda: T.serving_table(
            json_path=SERVING_JSON if emit_json else None),
        "obs": lambda: T.obs_table(
            json_path=OBS_JSON if emit_json else None),
        "profile": lambda: T.profile_table(
            json_path=PROFILE_JSON if emit_json else None),
        "roofline": lambda: roofline.emit_csv(T.emit),
    }
    names = names or list(benches)
    print("name,us_per_call,derived")
    for n in names:
        benches[n]()


if __name__ == "__main__":
    main()
