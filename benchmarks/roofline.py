"""Roofline report: reads the dry-run JSONs and prints/derives the per-cell
three-term analysis (EXPERIMENTS.md SSRoofline is generated from this)."""

from __future__ import annotations

import functools
import json
import os

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")


@functools.lru_cache(maxsize=None)
def _scan_trips(arch: str) -> int:
    """XLA cost_analysis counts while-loop bodies ONCE; the layer stack runs
    as a scan, so FLOPs/bytes/collectives inside it are undercounted by the
    trip count. Correct with the known scan length per architecture."""
    from repro.models.registry import get_arch
    cfg, model = get_arch(arch)
    if cfg.family == "zamba":
        return model.per            # 6 unrolled superblocks each scan `per`
    if cfg.family == "encdec":
        return model.n_dec          # enc and dec scans have equal length
    return model.n_steps


def corrected_terms(r: dict) -> dict:
    """Roofline terms with the loop-trip correction applied (microbatch
    accumulation is an outer scan too)."""
    t = dict(r["roofline"])
    k = _scan_trips(r["arch"])
    if r["shape"].startswith("train"):
        k *= r.get("microbatches", 1)
    for key in ("t_compute_s", "t_memory_s", "t_collective_s", "t_ici_s",
                "t_dcn_s", "hlo_flops_per_dev", "hlo_bytes_per_dev"):
        t[key] = t[key] * k
    t["useful_flop_frac"] = (t["model_flops_per_dev"]
                             / max(t["hlo_flops_per_dev"], 1e-30))
    terms = {"compute": t["t_compute_s"], "memory": t["t_memory_s"],
             "collective": t["t_collective_s"]}
    t["dominant"] = max(terms, key=terms.get)
    t["loop_correction"] = k
    return t


def load(tag: str):
    path = os.path.join(RESULTS, f"dryrun_{tag}.json")
    if not os.path.exists(path):
        return []
    return json.load(open(path))


def rows(tag="singlepod"):
    out = []
    for r in load(tag):
        if r.get("status") != "OK":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "status": r.get("status"),
                        "reason": r.get("reason", r.get("error", ""))[:60]})
            continue
        t = corrected_terms(r)
        terms = {"compute": t["t_compute_s"], "memory": t["t_memory_s"],
                 "collective": t["t_collective_s"]}
        bound = max(terms.values())
        out.append({
            "arch": r["arch"], "shape": r["shape"], "status": "OK",
            "compute_s": t["t_compute_s"], "memory_s": t["t_memory_s"],
            "collective_s": t["t_collective_s"], "dominant": t["dominant"],
            "roofline_frac": t["t_compute_s"] / bound if bound else 0.0,
            "useful_flop_frac": t["useful_flop_frac"],
            "mem_gib": r["mem"]["per_device_bytes"] / 2**30,
            "fits": r["fits_hbm"],
            "n_coll": r["collectives"]["n_collectives"],
        })
    return out


def table(tag="singlepod"):
    print(f"# roofline ({tag})")
    hdr = (f"{'arch':22s} {'shape':12s} {'comp_s':>8s} {'mem_s':>8s} "
           f"{'coll_s':>8s} {'dom':>10s} {'comp/roof':>9s} {'useful':>7s} "
           f"{'GiB':>6s} fits")
    print(hdr)
    for r in rows(tag):
        if r["status"] != "OK":
            print(f"{r['arch']:22s} {r['shape']:12s} "
                  f"{r['status']}: {r.get('reason','')}")
            continue
        print(f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:8.3f} "
              f"{r['memory_s']:8.3f} {r['collective_s']:8.3f} "
              f"{r['dominant']:>10s} {r['roofline_frac']:9.2f} "
              f"{r['useful_flop_frac']:7.2f} {r['mem_gib']:6.2f} "
              f"{'Y' if r['fits'] else 'N'}")


def emit_csv(emit):
    for tag in ("singlepod", "multipod"):
        for r in rows(tag):
            if r["status"] != "OK":
                emit(f"roofline/{tag}/{r['arch']}/{r['shape']}", 0.0,
                     r["status"])
                continue
            bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
            emit(f"roofline/{tag}/{r['arch']}/{r['shape']}", bound * 1e6,
                 f"dom={r['dominant']};roof_frac={r['roofline_frac']:.2f};"
                 f"useful={r['useful_flop_frac']:.2f};fits={r['fits']}")


if __name__ == "__main__":
    table("singlepod")
    table("multipod")
    analytic_table("singlepod")
    analytic_table("multipod")


# ---------------------------------------------------------------------------
# Exact-schedule analytic terms.
#
# cost_analysis counts while-loop bodies once and the trip-count correction
# above cannot separate peeled iterations / outside-loop ops, so the headline
# roofline terms are computed from the schedule the framework itself issues
# (it controls every collective and every matmul — the counts are exact, the
# hardware constants are from core/hw.py). The corrected-HLO values remain in
# the table as a cross-check.
# ---------------------------------------------------------------------------
def analytic_terms(arch: str, shape_name: str, mb: int,
                   multi_pod: bool = False, mesh_shape=None,
                   grad_compression: bool = False) -> dict:
    import jax.numpy as jnp

    from repro.core import hw
    from repro.core.meta import named_leaves, param_bytes
    from repro.launch.mesh import production_dcfg
    from repro.models.common import get_shape
    from repro.models.registry import get_arch

    cfg, model = get_arch(arch)
    shape = get_shape(shape_name)
    dcfg = production_dcfg(multi_pod=multi_pod)
    if mesh_shape is not None:
        dcfg = dcfg.with_(mesh_shape=tuple(mesh_shape))
    ndev = dcfg.n_devices
    fsdp = dcfg.fsdp_size
    tp = dcfg.tp_size
    d = cfg.d_model

    # padded parameter count (what actually moves over the wire)
    metas = model.metas(dcfg)
    n_layers_of = {k: v for k, v in
                   __import__("repro.models.runtime",
                              fromlist=["stacked_keys"])
                   .stacked_keys(model).items()}
    P_pad = 0       # global padded param count
    P_local = 0     # per-TP-rank param count (what FSDP gathers per device)
    for k in metas:
        reps = n_layers_of.get(k, 1)
        for _, m in named_leaves(metas[k]):
            P_pad += reps * m.padded_len(dcfg) * (
                tp if m.tp_dim is not None else 1)
            P_local += reps * m.padded_len(dcfg)

    tokens = shape.seq_len * shape.global_batch
    is_train = shape.kind == "train"
    if is_train:
        flops_dev = 6.0 * cfg.n_params_active() * tokens / ndev * (4.0 / 3.0)
    elif shape.kind == "prefill":
        flops_dev = 2.0 * cfg.n_params_active() * tokens / ndev
    else:
        flops_dev = 2.0 * cfg.n_params_active() * shape.global_batch / ndev
    # attention flops (not in 6ND): 12*L*d*S per token roughly
    if cfg.family not in ("xlstm",) and shape.kind != "decode":
        flops_dev += (12.0 * cfg.n_layers * d * shape.seq_len
                      * tokens / ndev) * (2.0 if is_train else 1.0) / 2
    t_comp = flops_dev / hw.PEAK_FLOPS_BF16

    # --- collective bytes per device --------------------------------------
    frac = (fsdp - 1) / fsdp
    ag = P_local * 2 * frac              # bf16 gather payload per device
    rs_itemsize = 2 if grad_compression else 4
    rs = P_local * rs_itemsize * frac    # grad reduce-scatter
    coll = 0.0
    if is_train:
        coll += mb * (2 * ag + rs)       # fwd AG + bwd re-AG + RS
    else:
        coll += ag                       # gather-once serving
    # sequence-parallel activation gathers/scatters (per layer, both ways;
    # backward recompute + transposes ~ 3x the forward count)
    gathers_per_layer = {"dense": 4, "moe": 4, "vlm": 4, "encdec": 6,
                         "xlstm": 2, "zamba": 2}[cfg.family]
    # SP activation traffic depends on TOTAL per-device tokens — it is
    # microbatch-count independent (each token crosses each boundary once).
    act_bytes = (tokens / max(1, dcfg.dp_total)) * d * 2  # bf16, per dev
    sp_frac = (tp - 1) / tp
    bwd_factor = 3.0 if is_train else 1.0
    if shape.kind != "decode":
        coll += (cfg.n_layers * gathers_per_layer * act_bytes * sp_frac
                 * bwd_factor)
    if cfg.family == "moe" and shape.kind != "decode":
        # two all_to_alls per layer over the routed capacity
        routed = act_bytes * cfg.n_experts_active * cfg.capacity_factor
        coll += cfg.n_layers * 2 * routed * sp_frac * bwd_factor
    # per-axis bandwidths from hw.axis_bandwidth (single cost source)
    t_coll = coll / hw.axis_bandwidth("data").bytes_per_s
    if multi_pod and is_train:
        # HSDP cross-pod grad all-reduce (fp32, 2x payload)
        t_coll += (2 * P_pad * 4 * (1 / 2)) \
            / hw.axis_bandwidth("pod").bytes_per_s / ndev * 256

    # --- HBM bytes per device ---------------------------------------------
    if is_train:
        weight_traffic = mb * (3 * P_local * 2)             # fwd+2xbwd reads
        opt_traffic = (P_pad / ndev * tp) * 4 * 5           # m,v,p rw (fp32)
        act_traffic = cfg.n_layers * 12 * act_bytes
        mem = weight_traffic + opt_traffic + act_traffic
    elif shape.kind == "prefill":
        mem = P_local * 2 + cfg.n_layers * 10 * act_bytes
    else:
        # decode: weights + KV cache read once per token
        kv = (cfg.n_layers * shape.seq_len * shape.global_batch
              * max(1, cfg.gqa_layout(tp)["kvp"] // tp) * tp
              * cfg.head_dim * 2 * 2) / ndev if cfg.family not in (
                  "xlstm", "zamba") else 0.0
        mem = P_local * 2 + kv
    t_mem = mem / hw.HBM_BANDWIDTH

    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom, "roofline_frac": t_comp / bound if bound else 0.0,
    }


def analytic_table(tag="singlepod"):
    print(f"# analytic (exact-schedule) roofline ({tag})")
    print(f"{'arch':22s} {'shape':12s} {'comp_s':>8s} {'mem_s':>8s} "
          f"{'coll_s':>8s} {'dom':>10s} {'roof_frac':>9s}")
    for r in load(tag):
        if r.get("status") != "OK":
            continue
        t = analytic_terms(r["arch"], r["shape"], r.get("microbatches", 1),
                           multi_pod=(tag == "multipod"))
        print(f"{r['arch']:22s} {r['shape']:12s} {t['t_compute_s']:8.3f} "
              f"{t['t_memory_s']:8.3f} {t['t_collective_s']:8.3f} "
              f"{t['dominant']:>10s} {t['roofline_frac']:9.2f}")
