"""One benchmark per paper table/figure (see DESIGN.md SS7 for the mapping).

CPU walltimes here are RELATIVE evidence (the ablation direction, not
absolute TPS); TPU-targeted numbers come from the dry-run roofline
(benchmarks/roofline.py). Runs on 8 fake CPU devices set up by run.py.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.dist import DistConfig
from repro.models import runtime as RT
from repro.models.common import ShapeConfig
from repro.models.registry import get_arch

ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


def _dcfg(**kw) -> DistConfig:
    base = dict(mesh_axes=("data", "model"),
                mesh_shape=(max(1, jax.device_count() // 2), 2),
                param_dtype=jnp.float32, reduce_dtype=jnp.float32)
    base.update(kw)
    return DistConfig(**base)


def _setup(dcfg, arch="qwen3_1_7b", B=8, S=64):
    cfg, model = get_arch(arch, smoke=True)
    shape = ShapeConfig("t", S, B, "train")
    storage = RT.init_storage(model, jax.random.PRNGKey(0), dcfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                      cfg.vocab),
        "valid": jnp.ones((B, S)),
    }
    return cfg, model, shape, storage, batch


def _timed(fn, *args, iters=8, warmup=2):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _train_fn(dcfg, with_opt=False, arch="qwen3_1_7b"):
    cfg, model, shape, storage, batch = _setup(dcfg, arch)
    step = RT.make_loss_step(model, dcfg)
    specs = RT.model_storage_specs(model, dcfg)
    fn, mesh = RT.wrap_step(model, dcfg, shape, step, (P(), specs))
    return fn, (storage, batch)


def _temp_bytes(fn, args):
    return fn.lower(*args).compile().memory_analysis().temp_size_in_bytes


# ---------------------------------------------------------------------------
# Table 3 — debuggability: eager vs compiled, same code
# ---------------------------------------------------------------------------
def table3_debuggability():
    dcfg = _dcfg(bucket_mode="block", reorder=False)
    cfg, model, shape, storage, batch = _setup(dcfg)
    step = RT.make_loss_step(model, dcfg)
    specs = RT.model_storage_specs(model, dcfg)
    jit_fn, mesh = RT.wrap_step(model, dcfg, shape, step, (P(), specs))
    from repro.core.compat import shard_map
    eager_fn = shard_map(step, mesh=mesh,
                         in_specs=(specs, RT.batch_specs(model, shape, dcfg)),
                         out_specs=(P(), specs))
    tokens = shape.seq_len * shape.global_batch
    t_e = _timed(eager_fn, storage, batch, iters=2, warmup=1)
    t_c = _timed(jit_fn, storage, batch)
    emit("table3/eager", t_e, f"tps={tokens/(t_e/1e6):.0f}")
    emit("table3/compiled", t_c,
         f"tps={tokens/(t_c/1e6):.0f};speedup={t_e/t_c:.2f}x")


# ---------------------------------------------------------------------------
# Table 4 — compilation time breakdown
# ---------------------------------------------------------------------------
def table4_compile_time():
    for mode, reorder in [("none", False), ("block", False),
                          ("block", True), ("auto", True)]:
        dcfg = _dcfg(bucket_mode=mode, reorder=reorder)
        t0 = time.perf_counter()
        fn, args = _train_fn(dcfg)
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        lowered.compile()
        t_comp = time.perf_counter() - t0
        emit(f"table4/bucket={mode},reorder={reorder}",
             (t_lower + t_comp) * 1e6,
             f"lower_s={t_lower:.2f};compile_s={t_comp:.2f}")


# ---------------------------------------------------------------------------
# Table 5 — reorder & bucket effectiveness (the paper's core ablation)
# ---------------------------------------------------------------------------
def table5_reorder_bucket():
    rows = [
        ("vanilla", dict(bucket_mode="none", reorder=False)),
        ("+reorder", dict(bucket_mode="none", reorder=True)),
        ("+bucket", dict(bucket_mode="block", reorder=False)),
        ("+reorder&bucket", dict(bucket_mode="block", reorder=True)),
    ]
    tokens = 64 * 8
    for name, kw in rows:
        fn, args = _train_fn(_dcfg(**kw))
        us = _timed(fn, *args)
        mem = _temp_bytes(fn, args)
        emit(f"table5/{name}", us,
             f"tps={tokens/(us/1e6):.0f};temp_mib={mem/2**20:.0f}")


# ---------------------------------------------------------------------------
# Table 6 — AG before/after last AG-wait placements
# ---------------------------------------------------------------------------
def table6_ag_placement():
    tokens = 64 * 8
    for fwd in (True, False):
        for bwd in (True, False):
            dcfg = _dcfg(bucket_mode="block", reorder=True,
                         ag_before_wait_fwd=fwd, ag_before_wait_bwd=bwd)
            fn, args = _train_fn(dcfg)
            us = _timed(fn, *args)
            mem = _temp_bytes(fn, args)
            emit(f"table6/fwd_before={fwd},bwd_before={bwd}", us,
                 f"tps={tokens/(us/1e6):.0f};temp_mib={mem/2**20:.0f}")


# ---------------------------------------------------------------------------
# Fig 3 — SimpleFSDP vs the compiler-auto baseline (GSPMD = FSDP2-compile
# analogue): same model math, weights sharding-constrained, XLA inserts
# the collectives itself.
# ---------------------------------------------------------------------------
def fig3_vs_gspmd():
    """Same bring-your-own-module model (examples/quickstart MLP), two
    compiler paths: SimpleFSDP explicit collectives vs GSPMD auto-sharding
    (weights sharding-constrained, XLA inserts the collectives itself —
    the FSDP2-compile analogue)."""
    import sys
    sys.path.insert(0, "examples")
    from quickstart import apply_fn, init_params, VOCAB

    from repro.core.compat import shard_map
    from repro.core import simple_fsdp
    from repro.core.dist import make_mesh as _mk

    dcfg = _dcfg(bucket_mode="block", reorder=True,
                 mesh_shape=(jax.device_count(), 1))
    mesh = _mk(dcfg)
    params = init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (16, 33), 0, VOCAB)
    tokens, targets = toks[:, :-1], toks[:, 1:]

    def nll(logits, targets):
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.take_along_axis(logp, targets[..., None], -1).mean()

    # SimpleFSDP path
    sharded, metas, fsdp_apply = simple_fsdp(apply_fn, params, dcfg)
    pspecs = jax.tree.map(lambda m: m.storage_spec(dcfg), metas,
                          is_leaf=lambda x: hasattr(x, "storage_spec"))

    def sf_step(p, tokens, targets):
        return jax.value_and_grad(
            lambda pp: nll(fsdp_apply(pp, tokens), targets))(p)

    sf = jax.jit(shard_map(sf_step, mesh=mesh,
                           in_specs=(pspecs, P("data"), P("data")),
                           out_specs=(P(), pspecs), check_vma=False))
    us_sf = _timed(sf, sharded, tokens, targets)
    mem_sf = sf.lower(sharded, tokens, targets).compile() \
        .memory_analysis().temp_size_in_bytes

    # GSPMD auto path: shard dim0 over 'data', let XLA place collectives
    sh = jax.tree.map(
        lambda p: NamedSharding(
            mesh, P("data") if p.ndim and p.shape[0] % dcfg.fsdp_size == 0
            else P()), params)
    params_g = jax.device_put(params, sh)
    bsh = NamedSharding(mesh, P("data"))
    tokens_g = jax.device_put(tokens, bsh)
    targets_g = jax.device_put(targets, bsh)

    g_fn = jax.jit(jax.value_and_grad(
        lambda pp, t, y: nll(apply_fn(pp, t), y)))
    us_g = _timed(g_fn, params_g, tokens_g, targets_g)
    mem_g = g_fn.lower(params_g, tokens_g, targets_g).compile() \
        .memory_analysis().temp_size_in_bytes
    emit("fig3/simplefsdp", us_sf, f"temp_mib={mem_sf/2**20:.1f}")
    emit("fig3/gspmd_auto(FSDP2-compile analog)", us_g,
         f"temp_mib={mem_g/2**20:.1f}")


# ---------------------------------------------------------------------------
# Fig 4 — manual vs auto wrapping: modeled exposure on REAL arch workloads,
# per planner (greedy Alg. 1 vs the exposure-minimizing DP). --json writes
# benchmarks/results/BENCH_overlap.json so the perf trajectory (exposure per
# mode per arch) is tracked across PRs.
# ---------------------------------------------------------------------------
OVERLAP_ARCHS = ("llama3_8b", "deepseek_coder_33b", "qwen3_moe_30b_a3b")
OVERLAP_SCHEMA = "bench_overlap_v2"
# v2: per-arch `comm_precision` ablation on the auto_dp partition — bf16
# wire vs fp8 both ways (stateless SR RS), fp8+error-feedback, and the
# planner's joint partition x precision choice (kernels/quant end to end)
QUANT_MODES = ("bf16", "fp8", "fp8_ef", "auto")


def _overlap_modes(metas, dcfg, stats, segments):
    """Plans scored as EXECUTED: auto planners plan the segmented schedule
    directly, and exposed_comm_time rewrites manual plans to the partition
    the runtime runs (split + segment-major + pooled hiding windows), so
    every exposure number describes the schedule core/stack actually runs."""
    from repro.core.autowrap import auto_dp_plan, auto_plan
    from repro.core.bucketing import per_param_plan, whole_block_plan

    return [
        ("none", per_param_plan(metas)),
        ("block", whole_block_plan(metas)),
        ("greedy", auto_plan(metas, dcfg, stats, segments=segments)),
        ("auto_dp", auto_dp_plan(metas, dcfg, stats, segments=segments)),
    ]


def fig4_autowrap(json_path: str | None = None):
    import json as _json
    import os as _os

    from repro.core.autowrap import exposed_comm_time
    from repro.launch.mesh import production_dcfg
    dcfg = production_dcfg()
    doc = {"schema": OVERLAP_SCHEMA, "mesh": "16x16", "archs": {}}
    for arch in OVERLAP_ARCHS:
        cfg, model = get_arch(arch)
        metas = model.block_metas(dcfg)
        stats = model.block_stats(dcfg, (1, 4096))
        segments = model.block_segments(dcfg) \
            if hasattr(model, "block_segments") else None
        # block_stats/exposure describe ONE scan step, which covers
        # layers_per_step layers (2 for local/global pairs) — scale by scan
        # steps, not raw layer count
        n_steps = getattr(model, "n_steps", cfg.n_layers)
        arch_rec = {"n_layers": cfg.n_layers, "n_scan_steps": n_steps,
                    "stats_source": stats.source, "modes": {}}
        for name, plan in _overlap_modes(metas, dcfg, stats, segments):
            r = exposed_comm_time(plan, metas, dcfg, stats,
                                  segments=segments)
            # modeled per-step time (tracking metric, not absolute): steps x
            # (fwd compute + ~2x bwd compute + steady-state exposed comm)
            modeled = n_steps * (3.0 * r["compute_s"] + r["exposed_s"])
            arch_rec["modes"][name] = {
                "exposed_s": r["exposed_s"],
                "total_comm_s": r["total_comm_s"],
                "compute_s": r["compute_s"],
                "n_buckets": r["n_buckets"],
                "modeled_step_s": modeled,
            }
            emit(f"fig4/{arch}/{name}", r["exposed_s"] * 1e6,
                 f"buckets={r['n_buckets']};"
                 f"comm_us={r['total_comm_s']*1e6:.0f};"
                 f"compute_us={r['compute_s']*1e6:.0f};"
                 f"step_ms={modeled*1e3:.2f}")

        # quantized-collective ablation on the auto_dp partition: modeled
        # wire bytes + exposure per comm_precision ('auto' = the joint
        # partition x precision DP's own pick)
        from repro.core.autowrap import auto_dp_plan
        qrows = {}
        for q in QUANT_MODES:
            dq = dcfg.with_(comm_precision=q)
            qplan = auto_dp_plan(metas, dq, stats, segments=segments)
            rq = exposed_comm_time(qplan, metas, dq, stats,
                                   segments=segments)
            qrows[q] = {
                "exposed_s": rq["exposed_s"],
                "exposed_comm_s": rq["exposed_comm_s"],
                "quant_overhead_s": rq["quant_overhead_s"],
                "total_comm_s": rq["total_comm_s"],
                "comm_wire_bytes": rq["comm_wire_bytes"],
                "n_buckets": rq["n_buckets"],
                "precisions": list(rq["precisions"]),
            }
            emit(f"fig4/{arch}/quant={q}", rq["exposed_s"] * 1e6,
                 f"wire_mib={rq['comm_wire_bytes']/2**20:.1f};"
                 f"exp_comm_us={rq['exposed_comm_s']*1e6:.0f};"
                 f"buckets={rq['n_buckets']};"
                 f"comm_us={rq['total_comm_s']*1e6:.0f}")
        bf = qrows["bf16"]
        for q in ("fp8", "fp8_ef"):
            assert qrows[q]["comm_wire_bytes"] \
                <= 0.55 * bf["comm_wire_bytes"], \
                (arch, q, qrows[q]["comm_wire_bytes"],
                 bf["comm_wire_bytes"])
            if bf["exposed_comm_s"] > 0:  # comm-exposed archs must win
                assert qrows[q]["exposed_comm_s"] \
                    < bf["exposed_comm_s"], \
                    (arch, q, qrows[q]["exposed_comm_s"],
                     bf["exposed_comm_s"])
        # the joint DP never does worse than all-bf16 on its own full
        # objective (bf16 is in its lattice; ties break to bf16)
        assert qrows["auto"]["exposed_s"] <= bf["exposed_s"] + 1e-12, arch
        arch_rec["comm_precision"] = qrows
        doc["archs"][arch] = arch_rec
    if json_path:
        _os.makedirs(_os.path.dirname(json_path), exist_ok=True)
        with open(json_path, "w") as f:
            _json.dump(doc, f, indent=1)
        print(f"wrote {json_path}", flush=True)
    return doc


# ---------------------------------------------------------------------------
# Memory — the paper's Table 3 sweep, modeled: per-device peak + step time
# per remat mode per arch from core/memory's live-range simulator on the
# production mesh, plus the budgeted auto-SAC row (remat='auto:<GB>').
# --json writes benchmarks/results/BENCH_memory.json (schema-smoked in
# tier-1 like the overlap/pipeline benches).
# ---------------------------------------------------------------------------
MEMORY_SCHEMA = "bench_memory_v1"
MEMORY_ARCHS = OVERLAP_ARCHS        # the same tracked trio
MEMORY_MODES = ("none", "save_dots", "fsdp_only", "full")


def memory_table(json_path: str | None = None, archs=MEMORY_ARCHS,
                 budget_gb: float | None = None):
    """Modeled per-device peak memory and step time per remat mode per arch
    (paper Table 3: no-AC > SAC > full-AC on memory, reversed on speed),
    with the auto:<GB> planner row showing what the budgeted search picks.
    Device-free analytics off the frozen MemoryPlan — the cross-PR tracking
    artifact BENCH_memory.json."""
    import json as _json
    import os as _os

    from repro.core import hw
    from repro.core import memory as MEM
    from repro.launch.mesh import production_dcfg

    base = production_dcfg()
    budget_gb = budget_gb or hw.HBM_BYTES / 1024**3
    doc = {"schema": MEMORY_SCHEMA, "mesh": "16x16",
           "budget_gb": budget_gb, "archs": {}}
    for arch in archs:
        cfg, model = get_arch(arch)
        bshape = (1, 4096)
        stats = model.block_stats(base, bshape)
        L = getattr(model, "n_steps", cfg.n_layers)
        arch_rec = {"n_scan_steps": L, "stats_source": stats.source,
                    "modes": {}}
        prof = MEM.build_block_profile(
            model.block_metas(base), base, stats,
            model.block_segments(base)
            if hasattr(model, "block_segments") else None)
        comp_s = prof.comp_s                  # mode-independent
        for mode in MEMORY_MODES + (f"auto:{budget_gb:g}",):
            mp = MEM.plan_memory(model, base.with_(remat=mode),
                                 batch_shape=bshape, stats=stats)
            row = {
                "policy_spec": mp.policy_spec,
                "peak_bytes": mp.peak,
                "peak_gib": mp.peak / 2**30,
                "cost_s": mp.cost_s,
                # fwd + ~2x bwd compute per layer + recompute/exposure cost
                "modeled_step_s": L * 3.0 * comp_s + mp.cost_s,
                "offload_opt_state": mp.offload_opt_state,
                "offload_residuals": mp.offload_residuals,
            }
            key = "auto" if mode.startswith("auto") else mode
            arch_rec["modes"][key] = row
            emit(f"memory_table/{arch}/{key}",
                 row["modeled_step_s"] * 1e6,
                 f"peak_gib={row['peak_gib']:.3f};"
                 f"policy={mp.policy_spec};"
                 f"offload={int(mp.offload_opt_state)}"
                 f"{int(mp.offload_residuals)}")
        # the paper's Table 3 ordering must reproduce in the model
        m = arch_rec["modes"]
        assert m["none"]["peak_bytes"] >= m["fsdp_only"]["peak_bytes"] \
            >= m["full"]["peak_bytes"], f"{arch}: AC ordering violated"
        doc["archs"][arch] = arch_rec
    if json_path:
        _os.makedirs(_os.path.dirname(json_path), exist_ok=True)
        with open(json_path, "w") as f:
            _json.dump(doc, f, indent=1)
        print(f"wrote {json_path}", flush=True)
    return doc


# ---------------------------------------------------------------------------
# Context parallelism — the long-context sweep: per-device sequence shard,
# modeled ring exposure and modeled peak/activation memory per ctx degree
# per arch (core/context.py x core/memory).  The ctx axis is carved out of
# the data axis with fsdp over data x ctx, so the FSDP domain (and the
# sharded param/opt state) stays CONSTANT across degrees — what moves is
# exactly the activation side, which must shrink ~1/cp. --json writes
# benchmarks/results/BENCH_context.json (schema-smoked in tier-1).
# ---------------------------------------------------------------------------
CONTEXT_SCHEMA = "bench_context_v1"
CONTEXT_ARCHS = ("llama3_8b", "gemma2_27b")   # full attn + sliding window
CONTEXT_DEGREES = (1, 2, 4, 8)
CONTEXT_SEQ = 32_768          # one long-context row per device at cp=1


def context_table(json_path: str | None = None):
    """Modeled context-parallel table: for each ctx degree, the per-device
    zigzag sequence shard, the ring schedule (hop bytes/compute, live hops
    under the arch's sliding window, exposed exchange time) and the
    live-range simulator's peak + activation components.  Device-free
    analytics — the cross-PR tracking artifact BENCH_context.json."""
    import json as _json
    import os as _os

    from repro.core import context as CX
    from repro.core import memory as MEM
    from repro.launch.mesh import production_dcfg

    doc = {"schema": CONTEXT_SCHEMA, "mesh": "16x16",
           "seq_len": CONTEXT_SEQ, "degrees": list(CONTEXT_DEGREES),
           "archs": {}}
    for arch in CONTEXT_ARCHS:
        cfg, model = get_arch(arch)
        arch_rec = {"window": cfg.sliding_window, "modes": {}}
        for cp in CONTEXT_DEGREES:
            dcfg = production_dcfg(context_degree=cp)
            bshape = (1, CONTEXT_SEQ // cp)
            stats = model.block_stats(dcfg, bshape)
            mp = MEM.plan_memory(model, dcfg, batch_shape=bshape,
                                 stats=stats)
            ring = CX.ring_cost(cfg, dcfg, bshape,
                                window=cfg.sliding_window)
            bk = max(mp.breakdown, key=lambda b: b.peak_bytes)
            act = bk.parts.get("saved_residuals", 0.0) \
                + bk.parts.get("workspace", 0.0)
            row = {
                "cp": cp, "seq_local": CONTEXT_SEQ // cp,
                "peak_bytes": mp.peak,
                "act_bytes": act,
                "ring_kv_bytes": bk.parts.get("ring_kv", 0.0),
                "hop_bytes": ring["hop_bytes"],
                "hop_comm_s": ring["hop_comm_s"],
                "hop_comp_s": ring["hop_comp_s"],
                "live_hops": ring["live_hops"],
                "ring_exposed_s": ring["exposed_s"],
            }
            arch_rec["modes"][str(cp)] = row
            emit(f"context_table/{arch}/cp={cp}",
                 ring["exposed_s"] * 1e6,
                 f"seq_local={row['seq_local']};"
                 f"peak_gib={mp.peak/2**30:.3f};"
                 f"act_gib={act/2**30:.3f};"
                 f"live_hops={ring['live_hops']}")
        # the acceptance invariant: activation memory strictly shrinks
        # with the ctx degree (params/opt are constant — fsdp covers
        # data x ctx, so the FSDP domain never changes)
        acts = [arch_rec["modes"][str(c)]["act_bytes"]
                for c in CONTEXT_DEGREES]
        assert all(a > b for a, b in zip(acts, acts[1:])), \
            f"{arch}: activation memory not strictly decreasing: {acts}"
        doc["archs"][arch] = arch_rec
    if json_path:
        _os.makedirs(_os.path.dirname(json_path), exist_ok=True)
        with open(json_path, "w") as f:
            _json.dump(doc, f, indent=1)
        print(f"wrote {json_path}", flush=True)
    return doc


# ---------------------------------------------------------------------------
# Pipeline — paper SS4 composability as a bench row: stage-stacked MLP on a
# (pipe, data, model) mesh, GPipe vs 1F1B vs zero-bubble trainable steps
# with FSDP bucket gathers per use inside each stage. 1F1B's claim is the
# activation bound (S live microbatches instead of M) — visible in temp_mib
# at M >> S.  v2 adds the table-driven schedules: interleaved 1F1B (V
# virtual stage chunks per rank, ~1/V of the ramp bubble, more in-flight
# state) and zb (W-split zero-bubble: the weight-grad halves drain into
# the cooldown ramp).
# ---------------------------------------------------------------------------
PIPELINE_SCHEMA = "bench_pipeline_v2"


def staged_archs() -> tuple[str, ...]:
    """Archs whose production config recommends a pipeline degree > 1."""
    from repro.models.registry import ARCH_IDS

    out = []
    for arch in ARCH_IDS:
        cfg, _ = get_arch(arch)
        if cfg.pp_stages > 1:
            out.append(arch)
    return tuple(out)


def _bench_virtual(layers_per_stage: int) -> int:
    """Smallest virtual-chunk count >= 2 that divides the stage slice —
    the same pick the planner's auto resolution makes (core/api)."""
    return next((v for v in range(2, layers_per_stage + 1)
                 if layers_per_stage % v == 0), 0)


def pipeline_table(json_path: str | None = None,
                   microbatches=(0, 4, 8, 32)):
    """Modeled pipeline table over the staged archs: bubble fraction and
    per-stage exposed comm for ALL FOUR schedules (gpipe / 1f1b /
    interleaved / zb) on the production mesh (device-free analytics off the
    resolved ParallelPlan — the cross-PR tracking artifact
    BENCH_pipeline.json, schema-smoke-tested in tier-1 like
    BENCH_overlap.json).  `microbatches` entries of 0 mean the plan's own
    resolved M.  v2 invariant (asserted in tier-1): the new schedules'
    modeled bubble is STRICTLY below 1F1B's at every benched M."""
    import json as _json
    import os as _os

    from repro.core.api import plan_parallel
    from repro.core.autowrap import exposed_comm_time
    from repro.core.pipeline import (bubble_fraction, schedule_peak_state,
                                     schedule_slots, zb_queue_depth)
    from repro.launch.mesh import production_dcfg_for

    doc = {"schema": PIPELINE_SCHEMA, "archs": {}}
    for arch in staged_archs():
        cfg, model = get_arch(arch)
        dcfg = production_dcfg_for(cfg)
        plan = plan_parallel(model, dcfg)
        S = plan.stage.n_stages
        metas = model.block_metas(dcfg)
        stats = model.block_stats(dcfg, (1, 4096))
        segments = model.block_segments(dcfg) \
            if hasattr(model, "block_segments") else None
        r = exposed_comm_time(plan.bucket_plans["blocks"], metas, dcfg,
                              stats, segments=segments)
        Lp = plan.stage.layers_per_stage
        # per-microbatch stage workload: fwd + ~2x bwd compute + the
        # steady-state exposed comm of this stage's layer slice
        stage_mb_s = Lp * (3.0 * r["compute_s"] + r["exposed_s"])
        V = _bench_virtual(Lp)
        rec = {
            "pp_stages": S, "n_scan_steps": plan.stage.layers_per_stage * S,
            "layers_per_stage": Lp, "stats_source": stats.source,
            "stage_exposed_s": Lp * r["exposed_s"],
            "stage_compute_s": Lp * r["compute_s"],
            # what the auto resolution ('auto' default, argmin modeled
            # bubble then in-flight memory) picked for this arch
            "planned_schedule": plan.pp_schedule,
            "planned_virtual": plan.pp_virtual,
            "schedules": {},
        }
        scheds = [("gpipe", 1), ("1f1b", 1), ("zb", 1)]
        if V:
            scheds.append(("interleaved", V))
        for schedule, virt in scheds:
            rows = {}
            for m in microbatches:
                M = m or plan.microbatches or S
                bub = bubble_fraction(M, S, schedule, virt)
                slots = schedule_slots(M, S, schedule, virt)
                row = {
                    "microbatches": M,
                    "slots": slots,
                    "virtual": virt,
                    "bubble_frac": bub,
                    # M units of work per stage stretched by the bubble
                    "modeled_step_s": M * stage_mb_s / (1.0 - bub),
                    # interleaved entries are chunk-granular (1/V of a
                    # stage slice each); gpipe/1f1b/zb count whole stages
                    "peak_live_microbatches":
                        max(schedule_peak_state(M, S, schedule, virt)),
                }
                if schedule == "zb":
                    row["w_queue_depth"] = zb_queue_depth(M, S)
                rows[str(M)] = row
                emit(f"pipeline_table/{arch}/{schedule}/M={M}",
                     row["modeled_step_s"] * 1e6,
                     f"bubble={bub:.3f};slots={slots};"
                     f"live={row['peak_live_microbatches']}"
                     + (f";V={virt}" if virt > 1 else ""))
            rec["schedules"][schedule] = rows
        doc["archs"][arch] = rec
    if json_path:
        _os.makedirs(_os.path.dirname(json_path), exist_ok=True)
        with open(json_path, "w") as f:
            _json.dump(doc, f, indent=1)
        print(f"wrote {json_path}", flush=True)
    return doc


def pipeline_bench(json_path: str | None = None):
    from jax import lax

    from repro.core.meta import ParamMeta
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import (init_pipeline_state,
                                        wrap_pipeline_train_step)

    S, M, B, Dm, H = 2, 8, 16, 64, 128
    tokens = M * B
    dcfg = DistConfig(
        mesh_axes=("pipe", "data", "model"), mesh_shape=(S, 2, 2),
        fsdp_axes=("data",), pp_axis="pipe",
        param_dtype=jnp.float32, reduce_dtype=jnp.float32)
    metas = {"w1": ParamMeta("w1", (Dm, H), tp_dim=1),
             "b": ParamMeta("b", (H,), tp_dim=0),
             "w2": ParamMeta("w2", (H, Dm), tp_dim=0)}

    def stage_fn(p, x):
        xg = lax.all_gather(x, dcfg.tp_axis, axis=0, tiled=True)
        h = jnp.tanh(xg @ p["w1"]) + p["b"]
        return x + lax.psum_scatter(h @ p["w2"], dcfg.tp_axis,
                                    scatter_dimension=0, tiled=True)

    def init_stage(key, _s):
        ks = jax.random.split(key, 3)
        return {"w1": jax.random.normal(ks[0], (Dm, H)) * 0.1,
                "b": jnp.zeros((H,)),
                "w2": jax.random.normal(ks[1], (H, Dm)) * 0.1}

    xs = jax.random.normal(jax.random.PRNGKey(3), (M, B, Dm))

    # Masked-slot cost correction for the measured CPU walltimes: the scan
    # engines execute EVERY slot's full work uniformly under SPMD masking
    # (an idle rank still runs the slot's compute, predicated off), so raw
    # walltime scales with slots x per-slot engine work, not with the
    # modeled critical path.  In uniform units (F=1, Bx=W=1, full B=2,
    # vjp replay = F+B = 3):
    #   * gpipe: T=M+S-1 F-slots by scan + T autodiff B-slots (saved
    #     activations, no replay) = 3T engine units == the modeled
    #     critical path 3(M+S-1) -> factor 1;
    #   * 1f1b:  2(M+S-1) slots, each executing F AND a jax.vjp of the
    #     stage (replay+transpose) = 4 units/slot = 8(M+S-1) engine units
    #     vs the modeled 3(M+S-1) -> factor 3/8;
    #   * zb:    T_zb single-unit slots, each executing the full F+vjp
    #     = 4 units/slot vs the modeled T_zb -> factor 1/4.
    # corrected = measured x modeled_units/engine_units estimates what the
    # schedule costs when idle slots are free (real hardware); the
    # corrected ordering must agree with the modeled bubble ordering.
    from repro.core.pipeline import bubble_fraction, schedule_slots

    def slot_factor(schedule: str) -> float:
        if schedule == "gpipe":
            return 1.0
        if schedule == "1f1b":
            return 3.0 * (M + S - 1) / (4.0 * schedule_slots(M, S, "1f1b"))
        if schedule == "zb":
            return 1.0 / 4.0
        raise ValueError(schedule)

    corrected = {}
    for schedule in ("gpipe", "1f1b", "zb"):
        fn, _ = wrap_pipeline_train_step(
            stage_fn, metas, dcfg.with_(pp_schedule=schedule),
            AdamWConfig(lr=1e-3), lambda y: jnp.mean(y ** 2) / M,
            xs_ndim=3, donate=False)
        storage, opt = init_pipeline_state(init_stage, metas, dcfg)
        us = _timed(fn, storage, opt, xs)
        mem = _temp_bytes(fn, (storage, opt, xs))
        f = slot_factor(schedule)
        corrected[schedule] = us * f
        emit(f"pipeline/{schedule}", us,
             f"tps={tokens/(us/1e6):.0f};temp_mib={mem/2**20:.2f};"
             f"stages={S};micro={M};"
             f"slot_factor={f:.4f};corrected_us={us*f:.1f}")
    # ordering agreement: modeled bubble says zb < 1f1b; the corrected
    # measurement must agree (the raw one cannot — zb's table is longer)
    assert bubble_fraction(M, S, "zb") < bubble_fraction(M, S, "1f1b")
    assert corrected["zb"] < corrected["1f1b"], corrected
    emit("pipeline/ordering", 0.0,
         f"corrected_zb={corrected['zb']:.1f};"
         f"corrected_1f1b={corrected['1f1b']:.1f};modeled_agrees=1")
    pipeline_table(json_path=json_path)


# ---------------------------------------------------------------------------
# Fig 5 — convergence: SimpleFSDP vs the auto-sharded baseline, same data
# ---------------------------------------------------------------------------
def fig5_convergence(steps=30):
    from repro.data.pipeline import DataConfig, SyntheticC4
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import init_train_state, wrap_train_step

    losses = {}
    for name, kw in [("simplefsdp", dict(bucket_mode="block", reorder=True)),
                     ("vanilla", dict(bucket_mode="none", reorder=False))]:
        dcfg = _dcfg(**kw)
        cfg, model = get_arch("qwen3_1_7b", smoke=True)
        shape = ShapeConfig("t", 64, 8, "train")
        fn, _ = wrap_train_step(model, dcfg, shape, AdamWConfig(lr=1e-3))
        storage, opt = init_train_state(model, dcfg)
        ds = SyntheticC4(DataConfig(vocab=cfg.vocab, seq_len=64,
                                    global_batch=8, seed=0))
        cur = []
        for s in range(steps):
            storage, opt, m = fn(storage, opt, ds.batch(s))
            cur.append(float(m["loss"]))
        losses[name] = cur
        emit(f"fig5/{name}", 0.0,
             f"loss0={cur[0]:.4f};loss_end={cur[-1]:.4f}")
    gap = max(abs(a - b) for a, b in
              zip(losses["simplefsdp"], losses["vanilla"]))
    emit("fig5/max_divergence", 0.0, f"abs={gap:.6f}")
    assert gap < 5e-3, "optimizations altered convergence!"


# ---------------------------------------------------------------------------
# Serving — the core/serving subsystem as a bench: ServePlan analytics for
# the paged arena (modeled paged vs dense decode throughput at equal batch)
# plus the continuous-batching scheduler run against the deterministic
# virtual clock — continuous+chunked-prefill vs the static prefill-blocking
# baseline on the same synthetic trace, and a prefix-cache variant with a
# shared system prompt.  Device-free: every latency is priced by the frozen
# plan (hw.py roofline), so the artifact BENCH_serving.json is stable
# across machines and schema-checked in tier-1.
# ---------------------------------------------------------------------------
SERVING_SCHEMA = "bench_serving_v1"
SERVING_ARCHS = ("qwen3_1_7b", "gemma2_27b", "qwen2_moe_a2_7b")
SERVING_ARENA_GIB = 4.0
SERVING_TRACE_N = 64


def serving_table(json_path: str | None = None):
    import dataclasses as _dc
    import json as _json
    import os as _os

    from repro.core.serving import (PrefixCache, plan_serve, run_virtual,
                                    static_schedule, synthetic_trace)
    from repro.launch.mesh import production_dcfg

    doc = {"schema": SERVING_SCHEMA, "mesh": "16x16",
           "arena_gib": SERVING_ARENA_GIB,
           "trace_n": SERVING_TRACE_N, "archs": {}}
    for arch in SERVING_ARCHS:
        cfg, model = get_arch(arch)
        dcfg = production_dcfg()
        plan = plan_serve(model, dcfg,
                          arena_bytes=int(SERVING_ARENA_GIB * 2**30),
                          max_batch=32, max_seq=1024, page=16)
        # modeled decode throughput at equal batch: dense streams the full
        # allocated window (tmax) per slot, pages stream only the live
        # context — the arena's bandwidth win, priced by the roofline
        mean_ctx = 256.0
        paged_tok_s = plan.modeled_decode_tok_s(plan.max_batch, mean_ctx)
        dense_tok_s = plan.modeled_decode_tok_s(plan.max_batch, mean_ctx,
                                                paged=False)
        assert paged_tok_s > dense_tok_s, \
            f"{arch}: paged decode not beating dense at equal batch"

        # one synthetic trace, three policies, one virtual clock
        ia = plan.decode_step_s / 4.0
        trace = synthetic_trace(SERVING_TRACE_N, seed=0,
                                mean_interarrival_s=ia)
        static = static_schedule(plan, trace)
        cont = run_virtual(plan, trace).metrics()
        assert cont["tok_s"] >= static["tok_s"], \
            f"{arch}: continuous batching slower than static"
        assert cont["p99_s"] <= static["p99_s"], \
            f"{arch}: chunked-prefill p99 above the prefill-blocking " \
            f"baseline"
        assert cont["peak_pages"] <= plan.n_pages, arch

        # prefix variant: every request shares a 64-token system prompt
        sysp = tuple(range(100, 164))
        ptrace = [_dc.replace(r, prompt=sysp + tuple(r.prompt))
                  for r in trace]
        contp = run_virtual(plan, ptrace,
                            prefix_cache=PrefixCache()).metrics()
        assert contp["requests"] == SERVING_TRACE_N, arch
        assert contp["prefix_hit_rate"] > 0.0, \
            f"{arch}: shared system prompt produced no prefix hits"

        doc["archs"][arch] = {
            "plan": {
                "page": plan.page, "n_pages": plan.n_pages,
                "max_pages_per_seq": plan.max_pages_per_seq,
                "max_batch": plan.max_batch,
                "prefill_chunk": plan.prefill_chunk,
                "interleave": plan.interleave, "codec": plan.codec,
                "kv_token_bytes": plan.kv_token_bytes,
                "arena_bytes": plan.arena_bytes,
                "decode_step_s": plan.decode_step_s,
                "prefill_tok_s": plan.prefill_tok_s,
                "cp_prefill": plan.cp_prefill,
            },
            "modeled": {"batch": plan.max_batch, "ctx_tokens": mean_ctx,
                        "paged_tok_s": paged_tok_s,
                        "dense_tok_s": dense_tok_s},
            "policies": {"static": static, "continuous": cont,
                         "continuous_prefix": contp},
        }
        emit(f"serving_table/{arch}", plan.decode_step_s * 1e6,
             f"paged_tok_s={paged_tok_s:.0f};dense_tok_s={dense_tok_s:.0f};"
             f"cont_tok_s={cont['tok_s']:.0f};"
             f"static_tok_s={static['tok_s']:.0f};"
             f"cont_p99_ms={cont['p99_s']*1e3:.2f};"
             f"static_p99_ms={static['p99_s']*1e3:.2f};"
             f"prefix_hit={contp['prefix_hit_rate']:.2f};"
             f"arena_util={cont['arena_util']:.2f}")
    if json_path:
        _os.makedirs(_os.path.dirname(json_path), exist_ok=True)
        with open(json_path, "w") as f:
            _json.dump(doc, f, indent=1)
        print(f"wrote {json_path}", flush=True)
    return doc


# ---------------------------------------------------------------------------
# Observability — instrumentation overhead + modeled-vs-measured drift +
# the trace invariant (non-overlapped comm lane time == exposed_s)
# ---------------------------------------------------------------------------
OBS_SCHEMA = "bench_obs_v1"
OBS_ARCHS = SERVING_ARCHS           # serving-capable: all 3 drift channels
OBS_OVERHEAD_BUDGET = 0.02
OBS_TRACE_TOL = 0.01


def _registry_step_us(iters: int = 2000) -> float:
    """Microbenchmark the EXACT registry work `Trainer._record_step` does
    per step (counter inc + 4 gauge sets + wire counter + drift record).
    Timed directly — a wall-clock A/B of two CPU train steps is noisier
    than the <2% effect being bounded."""
    from repro.core.obs import DriftMonitor, MetricsRegistry

    reg = MetricsRegistry()
    drift = DriftMonitor(reg)
    t0 = time.perf_counter()
    for i in range(iters):
        reg.counter("train/steps").inc()
        reg.gauge("train/step_time_s").set(0.1)
        reg.gauge("train/tokens_per_s").set(1e5)
        reg.gauge("train/grad_norm").set(1.0)
        reg.gauge("train/loss").set(2.0)
        reg.counter("train/wire_bytes/bf16").inc(1e6)
        drift.record("step_time", 0.09, 0.1, step=i)
    return (time.perf_counter() - t0) / iters * 1e6


def obs_table(json_path: str | None = None):
    import json as _json
    import os as _os
    import tempfile as _tempfile

    from repro.core.obs import nonoverlapped_comm_s, plan_trace
    from repro.core.serving import plan_serve, run_virtual, synthetic_trace
    from repro.models.registry import get_arch_for_pp
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    doc = {"schema": OBS_SCHEMA, "overhead_budget": OBS_OVERHEAD_BUDGET,
           "archs": {}, "overhead": {}, "trace": {}}

    # ---- instrumentation overhead: registry ops vs one real step ----
    dcfg = _dcfg()
    fn, args = _train_fn(dcfg)
    step_us = _timed(fn, *args, iters=4)
    instr_us = _registry_step_us()
    frac = instr_us / step_us
    doc["overhead"] = {"step_us": step_us, "instrument_us": instr_us,
                       "overhead_frac": frac}
    assert frac <= OBS_OVERHEAD_BUDGET, \
        f"instrumentation overhead {frac:.4f} above " \
        f"{OBS_OVERHEAD_BUDGET:.0%} of a smoke step"
    emit("obs_table/overhead", instr_us,
         f"step_us={step_us:.1f};frac={frac:.5f}")

    # ---- per-arch drift: step_time + peak_memory + decode_rate ----
    for arch in OBS_ARCHS:
        _, model = get_arch(arch, smoke=True)
        shape = ShapeConfig("t", 64, 8, "train")
        with _tempfile.TemporaryDirectory() as ckdir:
            tcfg = TrainerConfig(total_steps=4, ckpt_every=100,
                                 log_every=2, warmup=1, ckpt_dir=ckdir)
            tr = Trainer(model, _dcfg(), shape, AdamWConfig(lr=1e-3), tcfg)
            tr.run()
            tr.memory_report()          # records the peak_memory channel

        # decode_rate: measured tok/s from the batcher's own decode events
        # vs the plan's full-batch roofline promise at 256-token context
        plan = plan_serve(model, _dcfg(), arena_bytes=64 << 20,
                          max_batch=4, max_seq=128, page=16)
        reqs = synthetic_trace(32, seed=0,
                               mean_interarrival_s=plan.decode_step_s / 4,
                               prompt_lens=(16, 32, 64),
                               gen_lens=(8, 16, 32))
        b = run_virtual(plan, reqs, trace=True)
        dec = [(e[3], e[2] - e[1]) for e in b.events if e[0] == "decode"]
        measured_tok_s = (sum(n for n, _ in dec)
                          / max(1e-12, sum(dt for _, dt in dec)))
        modeled_tok_s = plan.modeled_decode_tok_s(plan.max_batch, 256.0)
        tr.drift.record("decode_rate", modeled_tok_s, measured_tok_s)

        s = tr.drift.summary()
        for ch in ("step_time", "peak_memory", "decode_rate"):
            assert ch in s and s[ch]["n"] > 0, f"{arch}: no {ch} residuals"
        doc["archs"][arch] = {"drift": s, "worst": tr.drift.worst(),
                              "report": tr.drift.report()}
        emit(f"obs_table/{arch}",
             s["step_time"]["measured_mean"] * 1e6,
             ";".join(f"{ch}_rel={s[ch]['last_rel']:+.2f}"
                      for ch in ("step_time", "peak_memory",
                                 "decode_rate")) + f";worst={tr.drift.worst()}")

    # ---- trace invariant on the full pp2 x dp2 x cp2 layout ----
    from repro.core.api import plan_parallel
    from repro.core.autowrap import exposed_comm_time

    tdcfg = DistConfig(
        mesh_axes=("pipe", "data", "ctx", "model"), mesh_shape=(2, 2, 2, 1),
        fsdp_axes=("data", "ctx"), pp_axis="pipe", cp_axis="ctx",
        tp_axis="model", pp_schedule="1f1b",
        param_dtype=jnp.bfloat16, reduce_dtype=jnp.float32)
    tcfg_arch, tmodel = get_arch_for_pp("llama3_8b", n_stages=2, smoke=True)
    tshape = ShapeConfig("t", 64, 8, "train")
    tplan = plan_parallel(tmodel, tdcfg, tshape)
    tb = plan_trace(tmodel, tplan, tshape, arch_cfg=tcfg_arch)
    tdoc = tb.to_doc()

    metas = tmodel.metas(tdcfg)
    b_local = max(1, tshape.global_batch // max(1, tdcfg.batch_dp))
    stats = tmodel.block_stats(
        tdcfg, (b_local, tshape.seq_len // max(1, tdcfg.cp_size)))
    segs = tmodel.block_segments(tdcfg) \
        if hasattr(tmodel, "block_segments") else None
    exposed = exposed_comm_time(tplan.bucket_plans["blocks"],
                                metas["blocks"], tdcfg, stats,
                                segments=segs)["exposed_s"]
    non = nonoverlapped_comm_s(tdoc)
    rel_err = abs(non - exposed) / max(1e-30, exposed)
    assert rel_err <= OBS_TRACE_TOL, \
        f"trace comm lane off modeled exposed_s by {rel_err:.2%}"
    doc["trace"] = {"layout": tplan.describe(),
                    "n_events": len(tdoc["traceEvents"]),
                    "exposed_s": exposed, "trace_nonoverlap_s": non,
                    "rel_err": rel_err, "tol": OBS_TRACE_TOL}
    emit("obs_table/trace", exposed * 1e6,
         f"rel_err={rel_err:.2e};n_events={len(tdoc['traceEvents'])}")

    if json_path:
        _os.makedirs(_os.path.dirname(json_path), exist_ok=True)
        with open(json_path, "w") as f:
            _json.dump(doc, f, indent=1)
        print(f"wrote {json_path}", flush=True)
    return doc


# ---------------------------------------------------------------------------
# Profile-guided replanning — the closed profile -> calibrate -> replan
# loop: calibrated |residual| strictly below analytic, overlay invariant
# ---------------------------------------------------------------------------
PROFILE_SCHEMA = "bench_profile_v1"
PROFILE_ARCHS = OBS_ARCHS
PROFILE_TRACE_TOL = 0.01


def profile_table(json_path: str | None = None):
    """Per arch: profile the executed plan, feed the calibrated stats back
    through `replan`, and score both plans' `modeled_step_time` against the
    measured wall step.  The analytic model prices the TPU roofline while
    the container executes on CPU, so the uncalibrated residual is ~1; the
    calibrated plan must land strictly closer (the closure guarantee).
    The modeled-vs-measured overlay must leave the PR-9 trace invariant
    intact: non-overlapped MODELED comm-lane time still equals exposed_s.
    """
    import json as _json
    import math as _math
    import os as _os

    from repro.core.api import plan_parallel
    from repro.core.autowrap import exposed_comm_time
    from repro.core.obs import (calibrated_step_time, modeled_step_time,
                                nonoverlapped_comm_s, plan_trace,
                                profile_step, replan)

    doc = {"schema": PROFILE_SCHEMA, "trace_tol": PROFILE_TRACE_TOL,
           "archs": {}}
    for arch in PROFILE_ARCHS:
        cfg, model = get_arch(arch, smoke=True)
        dcfg = _dcfg(bucket_mode="auto")
        shape = ShapeConfig("t", 64, 8, "train")
        plan = plan_parallel(model, dcfg, shape)
        prof = profile_step(model, plan, shape, steps=2)
        wall = prof.wall_step_s

        before = modeled_step_time(model, plan, shape)      # analytic prior
        new_plan, delta = replan(model, plan, shape, prof)
        after = calibrated_step_time(model, new_plan, shape, prof)
        resid_before = abs(before - wall) / wall
        resid_after = abs(after - wall) / wall
        assert _math.isfinite(resid_before) and _math.isfinite(resid_after)
        assert resid_after < resid_before, \
            f"{arch}: calibrated residual {resid_after:.3f} not below " \
            f"analytic {resid_before:.3f}"

        # overlay on the ORIGINAL plan; modeled lanes must be untouched
        tb = plan_trace(model, plan, shape, arch_cfg=cfg, profile=prof)
        tdoc = tb.to_doc()
        metas = model.metas(dcfg)
        b_local = max(1, shape.global_batch // max(1, dcfg.batch_dp))
        stats = model.block_stats(
            dcfg, (b_local, shape.seq_len // max(1, dcfg.cp_size)))
        segs = model.block_segments(dcfg) \
            if hasattr(model, "block_segments") else None
        exposed = exposed_comm_time(plan.bucket_plans["blocks"],
                                    metas["blocks"], dcfg, stats,
                                    segments=segs)["exposed_s"]
        non = nonoverlapped_comm_s(tdoc)
        rel_err = abs(non - exposed) / max(1e-30, exposed)
        assert rel_err <= PROFILE_TRACE_TOL, \
            f"{arch}: overlay broke the modeled comm-lane invariant " \
            f"({rel_err:.2%})"

        doc["archs"][arch] = {
            "wall_step_s": wall,
            "modeled_before_s": before,
            "modeled_after_s": after,
            "resid_before": resid_before,
            "resid_after": resid_after,
            "plan_changed": delta["changed"],
            "replan_fields": sorted(delta["fields"]),
            "closure_factor": prof.meta.get("closure_factor"),
            "n_spans": len(prof.spans),
            "comm_bandwidth": prof.comm_bandwidth,
            "trace": {"exposed_s": exposed, "trace_nonoverlap_s": non,
                      "rel_err": rel_err, "n_events":
                      len(tdoc["traceEvents"])},
        }
        emit(f"profile_table/{arch}", wall * 1e6,
             f"resid_before={resid_before:.3f};"
             f"resid_after={resid_after:.2e};"
             f"changed={delta['changed']};trace_err={rel_err:.2e}")

    if json_path:
        _os.makedirs(_os.path.dirname(json_path), exist_ok=True)
        with open(json_path, "w") as f:
            _json.dump(doc, f, indent=1)
        print(f"wrote {json_path}", flush=True)
    return doc
