"""Planner + segmented-scheduler tests (bucketing v2).

Covers the exposure-minimizing DP planner (`bucket_mode="auto_dp"`), the
guarded greedy planner, plan memoization, the segmented bucket-granular
prefetch stack's exact parity against the vanilla stack (1 device, fp32 —
the jax-0.4 vma gap stays out of tier-1, per ROADMAP), and the
BENCH_overlap.json emission schema.

Property tests use `hypothesis` when available and fall back to a fixed
parametrized sample on bare environments.
"""

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autowrap import (auto_dp_plan, auto_layer_group, auto_plan,
                                 dp_buckets, exposed_comm_time,
                                 greedy_partition, partition_exposure,
                                 per_param_partition)
from repro.core.bucketing import (BucketPlan, clear_plan_cache, plan_for,
                                  per_param_plan)
from repro.core.dist import DistConfig
from repro.core.irgraph import BlockStats, CommNode
from repro.core.meta import ParamMeta
from repro.models.common import BlockSegments

pytestmark = pytest.mark.autowrap

CFG2D = DistConfig(mesh_axes=("data", "model"), mesh_shape=(4, 2))
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rand_nodes(n, seed):
    rng = np.random.RandomState(seed)
    return [
        CommNode(f"p{i}",
                 ag_bytes=int(rng.randint(1, 1 << 22)),
                 rs_bytes=int(rng.randint(1, 1 << 22)),
                 comp_flops=float(10.0 ** rng.uniform(3, 13)),
                 comp_bytes=float(rng.randint(1, 1 << 22)),
                 mem_bytes=float(rng.randint(1, 1 << 22)))
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# exposure(auto_dp) <= exposure(greedy) <= exposure(none), + DP feasibility
# ---------------------------------------------------------------------------
def _check_planner_chain(n, seed, mem_limit):
    rng = np.random.RandomState((seed + 1) % (2 ** 31))
    nodes = _rand_nodes(n, seed)
    # random forced cuts (segment boundaries) half the time
    cuts = frozenset(int(i) for i in rng.choice(max(n - 1, 1),
                                                size=rng.randint(0, n),
                                                replace=False) + 1) \
        if n > 1 and rng.rand() < 0.5 else frozenset()
    dpb = dp_buckets(nodes, CFG2D, mem_limit, cuts)
    grd = greedy_partition(nodes, CFG2D, mem_limit, cuts)
    solo = per_param_partition(nodes)
    for b in dpb:           # buckets never span a forced cut
        lo = nodes.index(b[0])
        assert not any(lo < c < lo + len(b) for c in cuts)
    e_dp = partition_exposure(dpb, CFG2D)
    e_gr = partition_exposure(grd, CFG2D)
    e_pp = partition_exposure(solo, CFG2D)
    assert e_dp <= e_gr + 1e-15 * max(1.0, e_gr)
    assert e_gr <= e_pp + 1e-15 * max(1.0, e_pp)
    # DP output is an order-preserving complete partition under the cap
    flat = [nd.name for b in dpb for nd in b]
    assert flat == [nd.name for nd in nodes]
    for b in dpb:
        if len(b) > 1:
            assert sum(nd.mem_bytes for nd in b) <= mem_limit


CHAIN_SAMPLE = [
    (1, 0, 1e6), (2, 1, 1e4), (5, 2, 1e22), (8, 3, 1 << 21),
    (11, 4, 1 << 23), (14, 5, 1e5), (9, 6, 1 << 22), (12, 7, 3 << 20),
]

if HAVE_HYPOTHESIS:
    @hypothesis.given(
        n=st.integers(1, 14),
        seed=st.integers(0, 2**31 - 1),
        mem_limit=st.floats(1e4, 1e22),
    )
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_planner_exposure_chain(n, seed, mem_limit):
        _check_planner_chain(n, seed, mem_limit)
else:
    @pytest.mark.parametrize("n,seed,mem_limit", CHAIN_SAMPLE)
    def test_planner_exposure_chain(n, seed, mem_limit):
        _check_planner_chain(n, seed, mem_limit)


def test_dp_exact_on_small_instances():
    """DP == brute-force minimum over all contiguous partitions (n <= 8)."""
    import itertools
    for seed in range(12):
        nodes = _rand_nodes(seed % 8 + 1, 100 + seed)
        n = len(nodes)
        m_max = [1 << 20, 1 << 23, 1e22][seed % 3]
        best = np.inf
        for mask in range(1 << max(0, n - 1)):
            cuts = [0] + [i + 1 for i in range(n - 1)
                          if (mask >> i) & 1] + [n]
            bks = [nodes[a:b] for a, b in zip(cuts, cuts[1:])]
            if any(len(b) > 1 and sum(x.mem_bytes for x in b) > m_max
                   for b in bks):
                continue
            best = min(best, partition_exposure(bks, CFG2D))
        e_dp = partition_exposure(dp_buckets(nodes, CFG2D, m_max), CFG2D)
        assert abs(e_dp - best) <= 1e-12 + 1e-9 * best


@pytest.mark.parametrize("arch", [
    "llama3_8b", "deepseek_coder_33b", "phi3_medium_14b", "gemma2_27b",
    "qwen3_1_7b", "qwen2_moe_a2_7b", "qwen3_moe_30b_a3b", "xlstm_1_3b",
    "seamless_m4t_large_v2", "zamba2_1_2b", "internvl2_26b",
])
def test_auto_dp_beats_greedy_on_shipped_configs(arch):
    """Acceptance: modeled exposure(auto_dp) <= exposure(greedy) on every
    shipped model config (production mesh, analytic stats)."""
    from repro.launch.mesh import production_dcfg
    from repro.models.registry import get_arch

    cfg, model = get_arch(arch)
    dcfg = production_dcfg()
    # enc-dec has no single homogeneous block; plan its decoder stack
    metas_fn = getattr(model, "block_metas", None) \
        or getattr(model, "dec_block_metas")
    metas = metas_fn(dcfg)
    stats = model.block_stats(dcfg, (1, 4096)) \
        if hasattr(model, "block_stats") else None
    segments = model.block_segments(dcfg) \
        if hasattr(model, "block_segments") else None
    e_dp = exposed_comm_time(
        auto_dp_plan(metas, dcfg, stats, segments=segments),
        metas, dcfg, stats, segments=segments)["exposed_s"]
    e_gr = exposed_comm_time(
        auto_plan(metas, dcfg, stats, segments=segments),
        metas, dcfg, stats, segments=segments)["exposed_s"]
    e_pp = exposed_comm_time(per_param_plan(metas), metas, dcfg, stats,
                             segments=segments)["exposed_s"]
    assert e_dp <= e_gr + 1e-15
    assert e_gr <= e_pp + 1e-15


# ---------------------------------------------------------------------------
# auto_layer_group memory accounting (satellite regression)
# ---------------------------------------------------------------------------
def test_auto_layer_group_mem_single_counted():
    """Regression: auto_layer_group applied an ad-hoc 2x multiplier to the
    candidate bucket's bytes, inconsistent with greedy_buckets' single-count
    cap (same bug class as the greedy double count fixed in PR 1). With a
    cap of exactly 4 layers' bytes and compute that hides everything, the
    answer must be 4 (the doubled accounting stopped at 2)."""
    node = CommNode("p", ag_bytes=1 << 10, rs_bytes=1 << 10,
                    comp_flops=1e13, comp_bytes=1.0, mem_bytes=1 << 20)
    k = auto_layer_group([node], CFG2D, n_layers=8,
                         mem_limit=4 * (1 << 20))
    assert k == 4


# ---------------------------------------------------------------------------
# plan_for memoization
# ---------------------------------------------------------------------------
def _metas():
    return {
        "attn": {"wq": ParamMeta("attn.wq", (8, 8), 1),
                 "wo": ParamMeta("attn.wo", (8, 8), 0)},
        "mlp": {"wu": ParamMeta("mlp.wu", (8, 16), 1)},
        "ln": ParamMeta("ln", (8,)),
    }


def test_plan_for_memoized():
    clear_plan_cache()
    metas = _metas()
    cfg = CFG2D.with_(bucket_mode="auto_dp")
    stats = BlockStats({"attn/wq": 1e9}, {"attn/wq": 1e3})
    p1 = plan_for(metas, cfg, stats)
    p2 = plan_for(_metas(), cfg,
                  BlockStats({"attn/wq": 1e9}, {"attn/wq": 1e3}))
    assert p1 is p2                      # cache hit on equal-valued inputs
    from repro.core import bucketing as B
    assert len(B._PLAN_CACHE) == 1
    plan_for(metas, cfg, BlockStats({"attn/wq": 2e9}, {"attn/wq": 1e3}))
    assert len(B._PLAN_CACHE) == 2       # different stats: new cache entry
    p4 = plan_for(metas, cfg.with_(bucket_mode="none"), stats)
    assert p4.n_buckets == 4             # cfg participates in the key
    assert len(B._PLAN_CACHE) == 3
    clear_plan_cache()


def test_plan_for_auto_dp_resolves():
    plan = plan_for(_metas(), CFG2D.with_(bucket_mode="auto_dp"))
    covered = sorted(n for grp in plan.groups for n in grp)
    assert covered == ["attn/wo", "attn/wq", "ln", "mlp/wu"]


# ---------------------------------------------------------------------------
# Segmented bucket-granular prefetch: exact parity vs the vanilla stack
# (1 device, fp32 — keeps the jax-0.4 vma gap out of tier-1, per ROADMAP).
# ---------------------------------------------------------------------------
SD_CFG = DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                    param_dtype=jnp.float32, reduce_dtype=jnp.float32)


def _toy_setup():
    from repro.models import runtime as RT

    metas = {"a": {"w1": ParamMeta("a.w1", (8, 16)),
                   "b": ParamMeta("a.b", (16,)),
                   "w2": ParamMeta("a.w2", (16, 8))},
             "m": {"u": ParamMeta("m.u", (8, 12)),
                   "d": ParamMeta("m.d", (12, 8))}}
    L = 5
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    full = {"a": {"w1": jax.random.normal(ks[0], (L, 8, 16)) * 0.3,
                  "b": jax.random.normal(ks[1], (L, 16)) * 0.1,
                  "w2": jax.random.normal(ks[2], (L, 16, 8)) * 0.3},
            "m": {"u": jax.random.normal(ks[3], (L, 8, 12)) * 0.3,
                  "d": jax.random.normal(ks[4], (L, 12, 8)) * 0.3}}
    stacked = {k: RT.tree_to_storage(full[k], metas[k], SD_CFG)
               for k in full}
    x = jax.random.normal(jax.random.PRNGKey(9), (4, 8))

    def block_fn(p, consts, xc):
        h = jnp.tanh(xc @ p["a"]["w1"] + p["a"]["b"]) @ p["a"]["w2"]
        x1 = xc + h
        h2 = jax.nn.silu(x1 @ p["m"]["u"]) @ p["m"]["d"]
        return x1 + h2, {"z": (h2 ** 2).mean()}

    def seg_a(p, consts, xc):
        h = jnp.tanh(xc @ p["a"]["w1"] + p["a"]["b"]) @ p["a"]["w2"]
        return xc + h

    def seg_m(p, consts, x1):
        h2 = jax.nn.silu(x1 @ p["m"]["u"]) @ p["m"]["d"]
        return x1 + h2, {"z": (h2 ** 2).mean()}

    segs = BlockSegments(("a", "m"), (("a/*",), ("m/*",)), (seg_a, seg_m))
    return metas, stacked, x, block_fn, segs


@pytest.mark.parametrize("plan", [
    # multi-bucket, segment-aligned
    BucketPlan((("a/w1", "a/b"), ("a/w2",), ("m/u", "m/d"))),
    # a bucket SPANNING the segment boundary is split by the stack
    BucketPlan((("a/w1", "a/b", "a/w2", "m/u"), ("m/d",))),
])
@pytest.mark.parametrize("flags", [
    dict(),
    dict(rs_delay=False),
    dict(ag_before_wait_fwd=False, ag_before_wait_bwd=True),
])
def test_segmented_prefetch_matches_vanilla_toy(plan, flags):
    from repro.core.stack import apply_stack

    metas, stacked, x, block_fn, segs = _toy_setup()

    def loss(stacked_, reorder, use_segs, **kw):
        c = SD_CFG.with_(reorder=reorder, **kw)
        y, aux = apply_stack(block_fn, metas, c, stacked_, {}, x,
                             plan=plan, segments=segs if use_segs else None)
        return (y ** 2).mean() + aux["z"]

    l0, g0 = jax.value_and_grad(lambda s: loss(s, False, False))(stacked)
    l1, g1 = jax.value_and_grad(
        lambda s: loss(s, True, True, **flags))(stacked)
    np.testing.assert_allclose(l0, l1, rtol=1e-6)
    for (ka, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(g0),
            jax.tree_util.tree_leaves_with_path(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6, err_msg=str(ka))


def test_segmented_prefetch_single_layer():
    """L=1: within-layer bucket pipelining without cross-layer prefetch."""
    from repro.core.stack import apply_stack
    from repro.models import runtime as RT

    metas, stacked, x, block_fn, segs = _toy_setup()
    stacked1 = jax.tree.map(lambda v: v[:1], stacked)
    plan = BucketPlan((("a/w1", "a/b", "a/w2"), ("m/u", "m/d")))

    def loss(s, reorder):
        c = SD_CFG.with_(reorder=reorder)
        y, aux = apply_stack(block_fn, metas, c, s, {}, x, plan=plan,
                             segments=segs)
        return (y ** 2).mean() + aux["z"]

    l0, g0 = jax.value_and_grad(lambda s: loss(s, False))(stacked1)
    l1, g1 = jax.value_and_grad(lambda s: loss(s, True))(stacked1)
    np.testing.assert_allclose(l0, l1, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_segment_globs_must_cover_params():
    from repro.core.stack import apply_stack

    metas, stacked, x, block_fn, segs = _toy_setup()
    bad = BlockSegments(("a", "m"), (("a/*",), ("m/u",)), segs.fns)
    with pytest.raises(ValueError, match="unassigned"):
        apply_stack(block_fn, metas, SD_CFG.with_(reorder=True), stacked,
                    {}, x, segments=bad)


def test_model_segmented_prefetch_matches_vanilla():
    """Acceptance: the segmented bucket-granular stack passes exact fp32
    parity (outputs + grads) against the vanilla stack for a multi-bucket
    auto_dp plan, on the real dense model (1 device)."""
    from jax.sharding import PartitionSpec as P

    from repro.models import runtime as RT
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch

    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    shape = ShapeConfig("t", 32, 2, "train")
    storage = RT.init_storage(model, jax.random.PRNGKey(0), SD_CFG)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                     cfg.vocab),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                      cfg.vocab),
        "valid": jnp.ones((2, 32)),
    }
    # a plan with one bucket per segment -> true multi-bucket pipelining
    plan = BucketPlan((("ln1", "attn/wq", "attn/wk", "attn/wv", "attn/wo",
                        "attn/q_norm", "attn/k_norm"),
                       ("ln2", "mlp/wg", "mlp/wu", "mlp/wd")))
    outs = {}
    for name, kw in [("vanilla", dict(reorder=False, bucket_mode="none")),
                     ("segmented", dict(reorder=True, bucket_mode=plan)),
                     ("auto_dp", dict(reorder=True, bucket_mode="auto_dp"))]:
        dcfg = SD_CFG.with_(**kw)
        step = RT.make_loss_step(model, dcfg)
        specs = RT.model_storage_specs(model, dcfg)
        fn, _ = RT.wrap_step(model, dcfg, shape, step, (P(), specs))
        loss, grads = fn(storage, batch)
        outs[name] = (float(loss), grads)
    l0, g0 = outs["vanilla"]
    for name in ("segmented", "auto_dp"):
        l1, g1 = outs[name]
        np.testing.assert_allclose(l0, l1, rtol=1e-6, err_msg=name)
        for (ka, a), (_, b) in zip(
                jax.tree_util.tree_leaves_with_path(g0),
                jax.tree_util.tree_leaves_with_path(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6,
                                       err_msg=f"{name}/{ka}")


def test_gemma2_pair_segments_parity():
    """The 4-segment local/global pair (checkpointed segment fns, aux
    threaded through tuple inter-segment states) ships enabled by default —
    exact fp32 parity vs vanilla under block and auto_dp plans."""
    from jax.sharding import PartitionSpec as P

    from repro.models import runtime as RT
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch

    cfg, model = get_arch("gemma2_27b", smoke=True)
    assert model.layers_per_step == 2   # the pair path, 4 segments
    shape = ShapeConfig("t", 32, 2, "train")
    storage = RT.init_storage(model, jax.random.PRNGKey(0), SD_CFG)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                     cfg.vocab),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                      cfg.vocab),
        "valid": jnp.ones((2, 32)),
    }
    outs = {}
    for name, kw in [("vanilla", dict(reorder=False, bucket_mode="none")),
                     ("block", dict(reorder=True, bucket_mode="block")),
                     ("auto_dp", dict(reorder=True, bucket_mode="auto_dp"))]:
        dcfg = SD_CFG.with_(**kw)
        step = RT.make_loss_step(model, dcfg)
        fn, _ = RT.wrap_step(model, dcfg, shape, step,
                             (P(), RT.model_storage_specs(model, dcfg)))
        outs[name] = fn(storage, batch)
    l0, g0 = outs["vanilla"]
    for name in ("block", "auto_dp"):
        l1, g1 = outs[name]
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6,
                                   err_msg=name)
        for (ka, a), (_, b) in zip(
                jax.tree_util.tree_leaves_with_path(g0),
                jax.tree_util.tree_leaves_with_path(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6,
                                       err_msg=f"{name}/{ka}")


# ---------------------------------------------------------------------------
# BENCH_overlap.json emission (tier-1 smoke; plan regressions fail here)
# ---------------------------------------------------------------------------
def test_bench_overlap_json_schema(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + ROOT
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "fig4", "--json"],
        capture_output=True, text=True, timeout=540, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    path = os.path.join(ROOT, "benchmarks", "results", "BENCH_overlap.json")
    doc = json.load(open(path))
    assert doc["schema"] == "bench_overlap_v2"
    assert len(doc["archs"]) >= 2
    for arch, rec in doc["archs"].items():
        assert rec["stats_source"] in ("analytic", "measured")
        modes = rec["modes"]
        assert set(modes) == {"none", "block", "greedy", "auto_dp"}
        for m in modes.values():
            for k in ("exposed_s", "total_comm_s", "compute_s", "n_buckets",
                      "modeled_step_s"):
                assert k in m and m[k] >= 0
        # the acceptance invariant, re-checked on the emitted artifact
        assert modes["auto_dp"]["exposed_s"] \
            <= modes["greedy"]["exposed_s"] + 1e-12
        assert modes["greedy"]["exposed_s"] \
            <= modes["none"]["exposed_s"] + 1e-12
        # v2: the per-bucket comm_precision ablation (PR 7) — wire-byte
        # and exposed-comm claims re-checked on the emitted artifact
        cp = rec["comm_precision"]
        assert {"bf16", "fp8", "fp8_ef", "auto"} <= set(cp)
        bf16 = cp["bf16"]
        assert bf16["quant_overhead_s"] == 0.0
        for q in ("fp8", "fp8_ef"):
            assert cp[q]["comm_wire_bytes"] \
                <= 0.55 * bf16["comm_wire_bytes"], (arch, q)
            if bf16["exposed_comm_s"] > 0:
                assert cp[q]["exposed_comm_s"] < bf16["exposed_comm_s"], \
                    (arch, q)
        assert cp["auto"]["exposed_s"] <= bf16["exposed_s"] + 1e-12, arch
