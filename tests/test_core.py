"""Unit + property tests for the SimpleFSDP core (single device).

Property tests use `hypothesis` when available and fall back to a fixed
parametrized sample on bare environments (the module is optional so tier-1
collection never fails on a missing dev dependency).
"""

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hw
from repro.core.autowrap import auto_plan, exposed_comm_time, greedy_buckets
from repro.core.bucketing import (BucketPlan, manual_plan, per_param_plan,
                                  whole_block_plan)
from repro.core.dist import DistConfig, single_device_config
from repro.core.irgraph import BlockStats, CommNode, build_nodes
from repro.core.meta import ParamMeta, from_storage, to_storage
from repro.optim.schedule import warmup_cosine

CFG2D = DistConfig(mesh_axes=("data", "model"), mesh_shape=(4, 2))


# ---------------------------------------------------------------------------
# ParamMeta storage layout
# ---------------------------------------------------------------------------
def _check_storage_roundtrip(shape, tp_choice, seed):
    """to_storage / from_storage are exact inverses for any shape and any
    (valid) TP dim — the paper's DTensor Shard(0) analogue is lossless."""
    shape = tuple(shape)
    tp = CFG2D.tp_size
    tp_dim = None
    if tp_choice < len(shape) and shape[tp_choice] % tp == 0:
        tp_dim = tp_choice
    m = ParamMeta("p", shape, tp_dim=tp_dim)
    x = jax.random.normal(jax.random.PRNGKey(seed), shape)
    rt = from_storage(to_storage(x, m, CFG2D), m, CFG2D)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(x))


if HAVE_HYPOTHESIS:
    @hypothesis.given(
        shape=st.lists(st.integers(1, 12), min_size=1, max_size=3),
        tp_choice=st.integers(0, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_storage_roundtrip_property(shape, tp_choice, seed):
        _check_storage_roundtrip(shape, tp_choice, seed)
else:
    @pytest.mark.parametrize("shape,tp_choice,seed", [
        ((1,), 0, 0), ((4,), 0, 1), ((7,), 1, 2), ((8,), 0, 3),
        ((3, 5), 0, 4), ((4, 6), 1, 5), ((12, 2), 0, 6), ((2, 8), 1, 7),
        ((2, 3, 4), 2, 8), ((6, 1, 5), 0, 9), ((12, 12, 12), 1, 10),
        ((5, 9, 2), 3, 11),
    ])
    def test_storage_roundtrip_property(shape, tp_choice, seed):
        _check_storage_roundtrip(shape, tp_choice, seed)


def test_storage_shapes_lane_aligned():
    m = ParamMeta("p", (7, 13))
    assert m.padded_len(CFG2D) % (CFG2D.fsdp_size * 128) == 0
    assert m.chunk_len(CFG2D) % 128 == 0


def test_storage_spec_layout():
    m_tp = ParamMeta("w", (8, 16), tp_dim=1)
    m_rep = ParamMeta("s", (8,), tp_dim=None)
    assert m_tp.storage_shape(CFG2D)[0] == CFG2D.tp_size
    assert len(m_rep.storage_shape(CFG2D)) == 1


# ---------------------------------------------------------------------------
# Bucket plans
# ---------------------------------------------------------------------------
def _metas():
    return {
        "attn": {"wq": ParamMeta("attn.wq", (8, 8), 1),
                 "wo": ParamMeta("attn.wo", (8, 8), 0)},
        "mlp": {"wu": ParamMeta("mlp.wu", (8, 16), 1)},
        "ln": ParamMeta("ln", (8,)),
    }


def test_manual_plan_globs():
    plan = manual_plan(_metas(), [["attn/*"], ["mlp/*", "ln"]])
    assert plan.groups == (("attn/wo", "attn/wq"), ("ln", "mlp/wu"))


def test_plan_covers_all_params():
    metas = _metas()
    plan = manual_plan(metas, [["attn/*"]])
    idx_groups = plan.index_groups(metas)
    covered = sorted(i for g in idx_groups for i in g)
    assert covered == list(range(4))  # unplanned params auto-appended


def test_whole_block_single_bucket():
    assert whole_block_plan(_metas()).n_buckets == 1
    assert per_param_plan(_metas()).n_buckets == 4


# ---------------------------------------------------------------------------
# Auto-wrapping (paper Algorithm 1)
# ---------------------------------------------------------------------------
def _nodes(n, flops=1e9, nbytes=1 << 20):
    return [CommNode(f"p{i}", ag_bytes=nbytes, rs_bytes=2 * nbytes,
                     comp_flops=flops, comp_bytes=nbytes,
                     mem_bytes=nbytes) for i in range(n)]


def test_greedy_merges_when_compute_hides_comm():
    # huge compute per node -> everything after the first node can merge
    buckets = greedy_buckets(_nodes(8, flops=1e12), CFG2D)
    assert len(buckets) <= 2


def test_greedy_splits_when_comm_dominates():
    # compute ~0 -> nothing can hide; every node its own bucket
    buckets = greedy_buckets(_nodes(8, flops=1.0), CFG2D)
    assert len(buckets) == 8


def _check_greedy_invariants(n, flops, nbytes, mem_limit):
    """Partition invariants: order-preserving, complete, memory-capped."""
    nodes = _nodes(n, flops=flops, nbytes=nbytes)
    buckets = greedy_buckets(nodes, CFG2D, mem_limit=mem_limit)
    flat = [nd.name for b in buckets for nd in b]
    assert flat == [nd.name for nd in nodes]          # order + completeness
    for b in buckets:                                 # memory constraint
        if len(b) > 1:
            assert sum(nd.mem_bytes for nd in b) <= mem_limit


GREEDY_SAMPLE = [
    (1, 1e3, 1 << 10, 1e4), (3, 1e13, 1 << 20, 1e10),
    (8, 1e12, 1 << 20, 1e10), (8, 1.0, 1 << 20, 1e10),
    (24, 1e9, 1 << 14, 1e5), (24, 1e13, 1 << 24, 1e8),
    (16, 1e7, 1 << 12, 1e4), (12, 1e11, 1 << 16, 1e6),
]

if HAVE_HYPOTHESIS:
    @hypothesis.given(
        n=st.integers(1, 24),
        flops=st.floats(1e3, 1e13),
        nbytes=st.integers(1 << 10, 1 << 24),
        mem_limit=st.floats(1e4, 1e10),
    )
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_greedy_invariants(n, flops, nbytes, mem_limit):
        _check_greedy_invariants(n, flops, nbytes, mem_limit)
else:
    @pytest.mark.parametrize("n,flops,nbytes,mem_limit", GREEDY_SAMPLE)
    def test_greedy_invariants(n, flops, nbytes, mem_limit):
        _check_greedy_invariants(n, flops, nbytes, mem_limit)


def test_greedy_mem_cap_not_double_counted():
    """Regression (paper Alg. 1 line 5): `cand` already contains the
    incoming node — adding nd.mem_bytes AGAIN halved the effective cap for
    the node being merged. With cap = 3 node-sizes and compute large enough
    to hide everything, buckets must close at exactly 3 nodes (the buggy
    double count closed them at 2)."""
    nbytes = 1 << 20
    buckets = greedy_buckets(_nodes(6, flops=1e13, nbytes=nbytes), CFG2D,
                             mem_limit=3 * nbytes)
    assert [len(b) for b in buckets] == [3, 3]


def test_greedy_comm_dominated_stays_per_param():
    """A comm-dominated graph (no compute to hide behind) must not collapse
    into one giant bucket even with an unbounded memory cap — the first
    bucket is bounded by its OWN compute (exposed prologue, paper Fig. 2)."""
    buckets = greedy_buckets(_nodes(12, flops=1.0), CFG2D, mem_limit=1e18)
    assert len(buckets) == 12
    assert all(len(b) == 1 for b in buckets)


def test_exposed_time_decreases_with_compute():
    metas = _metas()
    stats_slow = BlockStats({k: 1e6 for k, _ in _flat(metas)},
                            {k: 1.0 for k, _ in _flat(metas)})
    stats_fast = BlockStats({k: 1e13 for k, _ in _flat(metas)},
                            {k: 1.0 for k, _ in _flat(metas)})
    plan = whole_block_plan(metas)
    slow = exposed_comm_time(plan, metas, CFG2D, stats_slow)
    fast = exposed_comm_time(plan, metas, CFG2D, stats_fast)
    assert fast["exposed_s"] <= slow["exposed_s"] + 1e-12


def _flat(tree):
    from repro.core.meta import named_leaves
    return named_leaves(tree)


# ---------------------------------------------------------------------------
# Comm model (alpha + beta n)
# ---------------------------------------------------------------------------
def test_collective_time_monotone_in_bytes():
    sizes = {"data": 16, "model": 16}
    t1 = hw.collective_time_s(1 << 20, sizes, ("data",))
    t2 = hw.collective_time_s(1 << 24, sizes, ("data",))
    assert t2 > t1


def test_bucketing_amortizes_alpha():
    """One bucketed collective of N bytes beats N separate 1-byte-ish ones
    — the paper's base-latency argument (SS3.2.1)."""
    sizes = {"data": 16, "model": 16}
    many = sum(hw.collective_time_s(1 << 12, sizes, ("data",))
               for _ in range(64))
    one = hw.collective_time_s(64 << 12, sizes, ("data",))
    assert one < many


def test_dcn_slower_than_ici():
    assert hw.axis_bandwidth("pod").bytes_per_s \
        < hw.axis_bandwidth("data").bytes_per_s


# ---------------------------------------------------------------------------
# Schedule
# ---------------------------------------------------------------------------
def test_warmup_cosine_shape():
    lr = [float(warmup_cosine(s, peak_lr=1.0, warmup=10, total=100))
          for s in range(100)]
    assert lr[0] < lr[9] <= 1.0
    assert abs(lr[10] - 1.0) < 0.01
    assert lr[99] < lr[50] < lr[11]


# ---------------------------------------------------------------------------
# GQA layout (mesh-independent padding)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", [
    "deepseek_coder_33b", "phi3_medium_14b", "gemma2_27b", "qwen3_1_7b",
    "qwen2_moe_a2_7b", "qwen3_moe_30b_a3b", "seamless_m4t_large_v2",
    "zamba2_1_2b", "internvl2_26b",
])
def test_gqa_layout_consistency(arch):
    from repro.models.registry import get_arch
    cfg, _ = get_arch(arch)
    layouts = [cfg.gqa_layout(tp) for tp in (1, 2, 4, 8, 16)]
    # global shapes identical across meshes
    assert len({(l["hq"], l["kvp"], l["g"]) for l in layouts}) == 1
    lay = layouts[0]
    assert lay["hq"] >= cfg.n_heads
    assert lay["kvp"] >= cfg.n_kv_heads
    for tp in (1, 2, 4, 8, 16):
        hl = lay["hq"] // tp
        kl = max(1, lay["kvp"] // tp)
        assert hl % kl == 0          # per-rank GQA grouping stays integral
