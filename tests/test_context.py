"""Device-free tests of the context-parallelism subsystem (core/context.py).

Ring-attention NUMERICS run through the host emulators — the same per-hop
block math (`_accum_hop` / `_hop_grads`) the mesh ring executes, driven
over sliced shards instead of ppermute — asserted exactly against
`models/layers.attention_ref` (forward, autodiff grads, and the
HAND-WRITTEN reverse-ring backward) across causal x sliding-window x
softcap x GQA x odd seq/cp remainders.  The mesh plumbing itself (ppermute
ring, travelling dK/dV accumulators, cp2 == cp1 training parity at
pp2 x dp2 x cp2) is covered by tests/dist_harness.py case `context`.

Also here: zigzag layout invariants (permutation, equal causal work),
plan_parallel's cp validation errors, seq-sharded batch specs, the memory
simulator's ring-KV term + activations/cp scaling, the simulator-driven
`auto_microbatches` pick, and the BENCH_context.json schema smoke.
"""

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import context as CX
from repro.core.dist import DistConfig
from repro.models.layers import attention_ref

pytestmark = pytest.mark.context

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _qkv(key, B=2, S=24, H=4, Kh=2, hd=8, scale=0.5):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)) * scale
    k = jax.random.normal(ks[1], (B, S, Kh, hd)) * scale
    v = jax.random.normal(ks[2], (B, S, Kh, hd)) * scale
    return q, k, v


# ---------------------------------------------------------------------------
# Zigzag layout
# ---------------------------------------------------------------------------
def test_zigzag_index_is_a_permutation():
    for S, cp in ((32, 2), (24, 3), (64, 8)):
        idx = CX.zigzag_index(S, cp)
        assert sorted(idx.tolist()) == list(range(S))
        # rank r's contiguous slice == zigzag_positions(r)
        c = S // (2 * cp)
        for r in range(cp):
            got = idx[r * 2 * c:(r + 1) * 2 * c]
            want = np.asarray(CX.zigzag_positions(r, cp, S))
            np.testing.assert_array_equal(got, want)


def test_zigzag_index_rejects_indivisible():
    with pytest.raises(ValueError, match="2\\*cp"):
        CX.zigzag_index(30, 4)


def test_zigzag_balances_causal_work():
    """Every rank's summed causal key-span (the attention work its queries
    own) is identical — the point of the zigzag interleave."""
    S, cp = 64, 4
    work = [int(sum(p + 1 for p in np.asarray(
        CX.zigzag_positions(r, cp, S)))) for r in range(cp)]
    assert len(set(work)) == 1, work


def test_zigzag_positions_mark_padding_on_remainders():
    # S=30, cp=4 -> chunks of 4, padded global length 32: the two pad
    # positions live in the LAST chunk, which the zigzag gives to rank 0
    pos0 = np.asarray(CX.zigzag_positions(0, 4, 30))
    assert pos0.shape == (8,)
    assert (pos0 >= 30).sum() == 2
    for r in range(1, 4):
        assert (np.asarray(CX.zigzag_positions(r, 4, 30)) < 30).all()


def test_zigzag_batch_roundtrip():
    dcfg = DistConfig(mesh_axes=("data", "ctx", "model"),
                      mesh_shape=(1, 2, 1), fsdp_axes=("data", "ctx"),
                      cp_axis="ctx")
    batch = {"tokens": np.arange(32).reshape(2, 16),
             "pos1d": np.arange(2)}
    out = CX.zigzag_batch(batch, dcfg)
    assert out["pos1d"] is batch["pos1d"]          # 1D untouched
    inv = np.argsort(CX.zigzag_index(16, 2))
    np.testing.assert_array_equal(out["tokens"][:, inv], batch["tokens"])


# ---------------------------------------------------------------------------
# Ring attention numerics: host emulators vs attention_ref
# ---------------------------------------------------------------------------
CASES = [
    # (cp, S, window, softcap)   -- incl. odd seq/cp remainders
    (1, 24, None, None),
    (2, 24, None, None),
    (3, 24, 5, None),
    (4, 24, None, 8.0),
    (2, 32, 8, 30.0),            # gemma2-shaped: window + softcap
    (4, 30, None, None),         # S % 2cp != 0 -> padded shards
    (3, 26, 7, 8.0),             # remainder x window x softcap
]


@pytest.mark.parametrize("cp,S,window,softcap", CASES)
def test_ring_forward_matches_attention_ref(cp, S, window, softcap):
    q, k, v = _qkv(jax.random.PRNGKey(0), S=S)
    ref = attention_ref(q, k, v, causal=True, window=window,
                        softcap=softcap)
    got = CX.ring_attention_host(q, k, v, cp, causal=True, window=window,
                                 softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("cp,S,window,softcap", CASES)
def test_reverse_ring_backward_matches_autodiff(cp, S, window, softcap):
    """The HAND-WRITTEN per-hop backward (the exact math the mesh VJP's
    travelling accumulators run) == jax.grad of the dense reference."""
    q, k, v = _qkv(jax.random.PRNGKey(1), S=S)
    do = jax.random.normal(jax.random.PRNGKey(2), q.shape) * 0.3

    def loss(q, k, v):
        out = attention_ref(q, k, v, causal=True, window=window,
                            softcap=softcap)
        return jnp.sum(out.astype(jnp.float32) * do)

    dq_r, dk_r, dv_r = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    dq, dk, dv = CX.ring_attention_host_grads(
        q, k, v, do, cp, causal=True, window=window, softcap=softcap)
    for name, a, b in (("dq", dq, dq_r), ("dk", dk, dk_r),
                       ("dv", dv, dv_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=5e-6, err_msg=name)


def test_ring_host_autodiff_grads_match_reference():
    """The emulator is also plain-differentiable (autodiff through the
    online softmax) — a second, independent check of the forward graph."""
    q, k, v = _qkv(jax.random.PRNGKey(3), S=16)
    do = jax.random.normal(jax.random.PRNGKey(4), q.shape) * 0.3

    def loss(fn):
        def inner(q, k, v):
            return jnp.sum(fn(q, k, v).astype(jnp.float32) * do)
        return jax.grad(inner, argnums=(0, 1, 2))(q, k, v)

    ref = loss(lambda q, k, v: attention_ref(q, k, v, causal=True))
    got = loss(lambda q, k, v: CX.ring_attention_host(q, k, v, 2,
                                                      causal=True))
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=5e-6)


def test_ring_respects_q_scale():
    q, k, v = _qkv(jax.random.PRNGKey(5), S=16)
    ref = attention_ref(q, k, v, causal=True, q_scale=0.25)
    got = CX.ring_attention_host(q, k, v, 2, causal=True, q_scale=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# plan_parallel validation + batch specs
# ---------------------------------------------------------------------------
def _cp_cfg(**kw):
    base = dict(mesh_axes=("data", "ctx", "model"), mesh_shape=(2, 2, 1),
                fsdp_axes=("data", "ctx"), cp_axis="ctx",
                param_dtype=jnp.float32, storage_dtype=jnp.float32)
    base.update(kw)
    return DistConfig(**base)


def test_dist_config_cp_properties():
    d = _cp_cfg()
    assert d.cp_size == 2 and d.dp_total == 4 and d.batch_dp == 2
    assert DistConfig().cp_size == 1


def test_plan_parallel_cp_validation_errors():
    from repro.core.api import plan_parallel
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch

    _, model = get_arch("qwen3_1_7b", smoke=True)
    shape = ShapeConfig("t", 32, 8, "train")
    # happy path resolves (and the plan mentions the ring)
    plan = plan_parallel(model, _cp_cfg(), shape)
    assert "cp=2(ring)" in plan.describe()
    # ctx must be in fsdp_axes (explicit-transpose rationale)
    with pytest.raises(ValueError, match="fsdp_axes"):
        plan_parallel(model, _cp_cfg(fsdp_axes=("data",)), shape)
    # zigzag divisibility
    with pytest.raises(ValueError, match="zigzag"):
        plan_parallel(model, _cp_cfg(), ShapeConfig("t", 30, 8, "train"))
    # unknown axis name
    with pytest.raises(ValueError, match="not a mesh axis"):
        plan_parallel(model, _cp_cfg(cp_axis="seq"), shape)
    # models without the cp contract are rejected pointedly
    _, xl = get_arch("xlstm_1_3b", smoke=True)
    with pytest.raises(ValueError, match="cp_supported"):
        plan_parallel(xl, _cp_cfg(), shape)
    # per-rank sequence must still split over TP (24/2 = 12, tp=8)
    with pytest.raises(ValueError, match="divisible by tp"):
        plan_parallel(model, _cp_cfg(mesh_shape=(1, 2, 8)),
                      ShapeConfig("t", 24, 8, "train"))


def test_batch_specs_shard_sequence_over_ctx():
    from jax.sharding import PartitionSpec as P
    from repro.models import runtime as RT
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch

    _, model = get_arch("qwen3_1_7b", smoke=True)
    shape = ShapeConfig("t", 32, 8, "train")
    specs = RT.batch_specs(model, shape, _cp_cfg())
    assert specs["tokens"] == P(("data",), "ctx")
    assert RT.dp_axes(_cp_cfg()) == ("data",)
    # without a ctx axis nothing changes
    flat = DistConfig(mesh_axes=("data", "model"), mesh_shape=(2, 2))
    assert RT.batch_specs(model, shape, flat)["tokens"] == P(("data",),
                                                            None)


def test_vlm_opts_out_of_cp():
    from repro.models.registry import get_arch

    _, vlm = get_arch("internvl2_26b", smoke=True)
    assert not CX.supports_cp(vlm)
    _, dense = get_arch("qwen3_1_7b", smoke=True)
    _, moe = get_arch("qwen2_moe_a2_7b", smoke=True)
    assert CX.supports_cp(dense) and CX.supports_cp(moe)


# ---------------------------------------------------------------------------
# Memory simulator: activations / cp + the ring KV term
# ---------------------------------------------------------------------------
def test_simulator_ring_kv_term_and_act_scaling():
    from repro.core.memory import simulate_peak
    from repro.launch.mesh import production_dcfg
    from repro.models.registry import get_arch

    _, model = get_arch("llama3_8b")
    S = 32_768
    peaks = {}
    for cp in (1, 2, 4):
        dcfg = production_dcfg(context_degree=cp)
        bk = simulate_peak(model, dcfg, (1, S // cp))[0]
        peaks[cp] = bk
        if cp == 1:
            assert bk.parts.get("ring_kv", 0.0) == 0.0
        else:
            assert bk.parts["ring_kv"] > 0.0
    # activations (saved residuals) scale ~1/cp; ring KV buffers shrink too
    r1 = peaks[1].parts["saved_residuals"]
    r2 = peaks[2].parts["saved_residuals"]
    r4 = peaks[4].parts["saved_residuals"]
    assert r1 > r2 > r4
    np.testing.assert_allclose(r2 / r1, 0.5, rtol=0.05)
    assert peaks[2].parts["ring_kv"] > peaks[4].parts["ring_kv"]
    # total modeled peak strictly decreases (params constant: fsdp spans
    # data x ctx, so the shard domain never changes)
    assert peaks[1].peak_bytes > peaks[2].peak_bytes \
        > peaks[4].peak_bytes


def test_ring_cost_model():
    from repro.launch.mesh import production_dcfg
    from repro.models.registry import get_arch

    cfg, _ = get_arch("gemma2_27b")
    dcfg = production_dcfg(context_degree=8)
    full = CX.ring_cost(cfg, dcfg, (1, 4096), window=None)
    win = CX.ring_cost(cfg, dcfg, (1, 4096), window=cfg.sliding_window)
    assert full["live_hops"] == 8
    assert win["live_hops"] < 8                 # window skips far hops
    assert full["hop_bytes"] > 0 and full["hop_comm_s"] > 0
    assert win["total_comm_s"] == full["total_comm_s"]  # ring always moves
    assert CX.ring_live_hops(1, 4096, 128) == 1


# ---------------------------------------------------------------------------
# auto_microbatches: the simulator's stage peaks pick the split
# ---------------------------------------------------------------------------
def test_auto_microbatches_fits_budget_and_monotone():
    from repro.core.memory import auto_microbatches, simulate_peak
    from repro.launch.mesh import production_dcfg, production_dcfg_for
    from repro.models.common import get_shape
    from repro.models.registry import get_arch

    shape = get_shape("train_4k")
    cfg, model = get_arch("gemma2_27b")
    dcfg = production_dcfg()
    mb = auto_microbatches(model, dcfg, shape)
    assert mb >= 1
    # the pick actually fits: modeled peak at mb within budget
    b = max(1, shape.global_batch // dcfg.batch_dp // mb)
    from repro.core import hw
    pk = simulate_peak(model, dcfg.with_(microbatches=mb),
                       (b, shape.seq_len), act_scale=4.0)
    assert max(x.peak_bytes for x in pk) <= hw.HBM_BYTES \
        or mb >= shape.global_batch // dcfg.batch_dp
    # a tighter budget can only deepen the split
    tighter = auto_microbatches(model, dcfg, shape,
                                budget=hw.HBM_BYTES / 4)
    assert tighter >= mb
    # models without a cost contract run unsplit
    class NoStats:
        pass
    assert auto_microbatches(NoStats(), dcfg, shape) == 1
    # production_dcfg_for wires the pick through (auto-accumulation)
    d2 = production_dcfg_for(cfg, shape=shape, model=model)
    assert d2.microbatches >= 1


def test_dryrun_pick_microbatches_replaces_table():
    """The dryrun module no longer carries the hand-kept MICROBATCH table;
    picks come from the simulator."""
    from repro.launch import dryrun

    assert not hasattr(dryrun, "MICROBATCH")
    from repro.models.common import get_shape
    from repro.models.registry import get_arch
    from repro.launch.mesh import production_dcfg

    _, model = get_arch("qwen3_1_7b")
    assert dryrun.pick_microbatches(model, production_dcfg(),
                                    get_shape("train_4k")) >= 1
    # serving cells never split
    assert dryrun.pick_microbatches(model, production_dcfg(),
                                    get_shape("prefill_32k")) == 1


# ---------------------------------------------------------------------------
# BENCH_context.json (tier-1 schema smoke + the checked-in artifact)
# ---------------------------------------------------------------------------
def _check_context_doc(doc):
    assert doc["schema"] == "bench_context_v1"
    assert len(doc["archs"]) >= 2
    for arch, rec in doc["archs"].items():
        degrees = [int(c) for c in rec["modes"]]
        assert 1 in degrees and max(degrees) >= 4
        acts, peaks = [], []
        for c in sorted(degrees):
            row = rec["modes"][str(c)]
            assert row["seq_local"] * c == doc["seq_len"]
            assert row["peak_bytes"] > 0
            assert 1 <= row["live_hops"] <= c
            if c == 1:
                assert row["ring_exposed_s"] == 0.0
            acts.append(row["act_bytes"])
            peaks.append(row["peak_bytes"])
        # the acceptance invariant: modeled peak activation memory
        # strictly decreases with the cp degree
        assert all(a > b for a, b in zip(acts, acts[1:])), (arch, acts)
        assert all(a > b for a, b in zip(peaks, peaks[1:])), (arch, peaks)


def test_bench_context_json_schema(tmp_path):
    sys.path.insert(0, ROOT)
    try:
        from benchmarks import paper_tables as T
    finally:
        sys.path.pop(0)
    path = str(tmp_path / "BENCH_context.json")
    doc = T.context_table(json_path=path)
    assert json.load(open(path)) == doc
    _check_context_doc(doc)


def test_bench_context_artifact_checked_in():
    path = os.path.join(ROOT, "benchmarks", "results",
                        "BENCH_context.json")
    assert os.path.exists(path), \
        "benchmarks/results/BENCH_context.json missing — run " \
        "`python -m benchmarks.run ctx --json`"
    _check_context_doc(json.load(open(path)))
