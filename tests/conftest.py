"""Pytest wiring: marker registration + default marking.

Two selection tiers (both recorded in ROADMAP's tier-1 line):

  python -m pytest -x -q                 # everything
  python -m pytest -q -m unit            # fast single-process tests only
  python -m pytest -q -m distributed     # 8-device subprocess harness only

Every test without an explicit ``distributed`` marker is auto-marked
``unit``, so ``-m unit`` deselects the slow subprocess parity suite.
"""

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "unit: fast single-process tests (auto-applied)")
    config.addinivalue_line(
        "markers",
        "distributed: multi-device semantics via the subprocess harness "
        "(tests/dist_harness.py on 8 fake CPU devices)")
    config.addinivalue_line(
        "markers", "slow: long-running cases (full schedule sweeps)")
    config.addinivalue_line(
        "markers",
        "autowrap: bucket planners + segmented prefetch scheduler "
        "(tests/test_autowrap.py; run `-m autowrap` after planner changes)")
    config.addinivalue_line(
        "markers",
        "memory: live-range peak simulator + budgeted auto-SAC planner "
        "(tests/test_memory.py; run `-m memory` after core/memory changes)")
    config.addinivalue_line(
        "markers",
        "context: context parallelism — zigzag sharding, ring attention "
        "numerics + cost model (tests/test_context.py; run `-m context` "
        "after core/context changes)")
    config.addinivalue_line(
        "markers",
        "quant: quantized collectives — fp8/int8 wire codec round-trips, "
        "error feedback, precision-aware planner (tests/test_quant.py; "
        "run `-m quant` after kernels/quant or comm_precision changes)")
    config.addinivalue_line(
        "markers",
        "serving: paged KV cache, continuous batching, prefix cache, "
        "router (tests/test_serving.py; run `-m serving` after "
        "core/serving or decode-path changes)")
    config.addinivalue_line(
        "markers",
        "obs: telemetry — trace emitter, metrics registry, drift monitor "
        "(tests/test_obs.py; run `-m obs` after core/obs or "
        "instrumentation changes)")
    config.addinivalue_line(
        "markers",
        "profile: profile-guided replanning — step profiler, calibrated "
        "BlockStats, measured trace overlay, replan loop "
        "(tests/test_profile.py; run `-m profile` after core/obs/profile "
        "or calibrate changes)")


def pytest_collection_modifyitems(config, items):
    for item in items:
        if "distributed" not in item.keywords:
            item.add_marker(pytest.mark.unit)
