"""Profile-guided replanning: step profiler, calibrated BlockStats,
modeled-vs-measured trace overlay, and the replan loop (core/obs/profile +
core/obs/calibrate).

The load-bearing claims:

  * closure — the calibrated plan's `modeled_step_time`, evaluated under
    `calibration`, lands on the measured wall step, so the calibrated
    |residual| is strictly below the analytic prior's (~1.0 here, since
    the analytic model prices the TPU roofline and the container runs
    CPU);
  * monotonicity — `calibrated_block_stats` never invents data: unseen
    params keep their analytic values, an empty profile is the identity,
    and `replan` with unchanged rates reproduces the plan verbatim;
  * overlay isolation — the measured track rides PID_MEASURED only; the
    modeled lanes (and the PR-9 invariant `nonoverlapped_comm_s ==
    exposed_s`) are byte-identical with or without a profile attached.

Everything runs on the single default CPU device (mesh 1x1 for executed
paths; planner-only tests use larger meshes, which are pure math).
"""

import dataclasses
import json
import math

import jax.numpy as jnp
import pytest

from repro.core import hw, irgraph
from repro.core.api import plan_parallel
from repro.core.dist import (AUTO_PRECISIONS, COMM_PRECISIONS, DistConfig,
                             precision_codecs)
from repro.core.obs import (PID_MEASURED, PID_MODELED, MeasuredProfile,
                            calibrated_block_stats, calibrated_step_time,
                            calibration, modeled_step_time,
                            nonoverlapped_comm_s, plan_trace, profile_step,
                            replan)
from repro.models.common import ShapeConfig
from repro.models.registry import get_arch

pytestmark = pytest.mark.profile

DCFG1 = DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                   param_dtype=jnp.float32, reduce_dtype=jnp.float32,
                   bucket_mode="auto")
SHAPE = ShapeConfig("t", 64, 8, "train")


@pytest.fixture(scope="module")
def profiled():
    """One measured profile of the executed 1-device plan, shared."""
    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    plan = plan_parallel(model, DCFG1, SHAPE)
    prof = profile_step(model, plan, SHAPE, steps=2)
    return cfg, model, plan, prof


# ---------------------------------------------------------------------------
# calibrated_block_stats: identity + monotonicity
# ---------------------------------------------------------------------------
def test_calibrated_stats_identity_on_empty_profile():
    _, model = get_arch("qwen3_1_7b", smoke=True)
    stats = model.block_stats(DCFG1, (8, 64))
    assert calibrated_block_stats(stats, None) is stats
    assert calibrated_block_stats(stats, MeasuredProfile.empty()) is stats
    assert calibrated_block_stats(None, MeasuredProfile.empty()) is None


def test_calibrated_stats_monotone_unseen_params():
    _, model = get_arch("qwen3_1_7b", smoke=True)
    stats = model.block_stats(DCFG1, (8, 64))
    names = sorted(stats.param_flops)
    seen, unseen = names[0], names[-1]
    prof = MeasuredProfile(seg_scales={"seg": 2.0},
                           param_segment={seen: "seg"})
    cal = calibrated_block_stats(stats, prof)
    assert cal.source == "calibrated"
    assert cal.param_flops[seen] == pytest.approx(
        2.0 * stats.param_flops[seen])
    assert cal.param_bytes[seen] == pytest.approx(
        2.0 * stats.param_bytes[seen])
    # a param the profiler never saw keeps its analytic value
    assert cal.param_flops[unseen] == stats.param_flops[unseen]
    assert cal.param_bytes[unseen] == stats.param_bytes[unseen]
    assert cal.act_bytes == stats.act_bytes
    assert cal.seg_act_bytes == stats.seg_act_bytes
    # the calibrated contract re-keys the plan memo
    assert cal.cache_key() != stats.cache_key()


def test_replan_unchanged_rates_is_identity():
    _, model = get_arch("qwen3_1_7b", smoke=True)
    plan = plan_parallel(model, DCFG1, SHAPE)
    new_plan, delta = replan(model, plan, SHAPE, MeasuredProfile.empty())
    assert delta["changed"] is False
    assert new_plan.describe() == plan.describe()
    assert delta["fields"] == {}
    assert new_plan.dcfg == plan.dcfg


# ---------------------------------------------------------------------------
# calibration context: install + restore of measured hw rates
# ---------------------------------------------------------------------------
def test_calibration_context_installs_and_restores():
    prof = MeasuredProfile(
        comm_bandwidth={"data": {"bytes_per_s": 1e9, "alpha_s": 2e-6}},
        quant_rates={"int8": 1e11, "fp8": 2e11})
    analytic_bw = hw.axis_bandwidth("data")
    with calibration(prof):
        bw = hw.axis_bandwidth("data")
        assert bw.bytes_per_s == 1e9 and bw.alpha_s == 2e-6
        assert irgraph.quant_codec_rate("int8") == 1e11
        assert irgraph.quant_codec_rate("fp8") == 2e11
    assert hw.axis_bandwidth("data") == analytic_bw
    assert irgraph.quant_codec_rate("int8") == hw.HBM_BANDWIDTH / 2.0
    assert irgraph.quant_codec_rate("fp8") == hw.HBM_BANDWIDTH / 2.0


# ---------------------------------------------------------------------------
# the measured profile itself
# ---------------------------------------------------------------------------
def test_profile_json_roundtrip(profiled):
    _, _, _, prof = profiled
    p2 = MeasuredProfile.from_json(prof.to_json())
    assert p2.to_json() == prof.to_json()
    assert p2.wall_step_s == prof.wall_step_s
    assert p2.seg_scales == prof.seg_scales


def test_profile_wall_spans_match_wall_step(profiled):
    _, _, _, prof = profiled
    walls = [s["dur_s"] for s in prof.spans if s["cat"] == "wall"]
    assert len(walls) == prof.meta["steps"]
    assert all(w > 0 for w in walls)
    # the frozen wall step is the median of the timed step spans, so the
    # span table sums to within CPU-noise tolerance of steps x wall
    assert sum(walls) == pytest.approx(
        len(walls) * prof.wall_step_s, rel=0.5)
    assert sorted(walls)[len(walls) // 2] >= prof.wall_step_s * 0.999 \
        or len(walls) % 2 == 0
    assert prof.rank_step_s == {"0": prof.wall_step_s}


def test_closed_loop_residual_shrinks(profiled):
    """The acceptance loop on one arch (the bench covers three): the
    calibrated, replanned plan's step-time promise must land strictly
    closer to the measured wall than the analytic prior."""
    _, model, plan, prof = profiled
    wall = prof.wall_step_s
    before = modeled_step_time(model, plan, SHAPE)
    new_plan, delta = replan(model, plan, SHAPE, prof)
    after = calibrated_step_time(model, new_plan, SHAPE, prof)
    resid_before = abs(before - wall) / wall
    resid_after = abs(after - wall) / wall
    assert math.isfinite(resid_before) and math.isfinite(resid_after)
    assert resid_after < resid_before
    # closure tolerance: the fixed point stops within 2% + slack
    assert resid_after <= 0.05
    assert delta["wall_step_s"] == wall


# ---------------------------------------------------------------------------
# modeled-vs-measured trace overlay
# ---------------------------------------------------------------------------
def test_overlay_golden_byte_identical(profiled):
    cfg, model, plan, prof = profiled
    j1 = plan_trace(model, plan, SHAPE, arch_cfg=cfg,
                    profile=prof).to_json()
    j2 = plan_trace(model, plan, SHAPE, arch_cfg=cfg,
                    profile=prof).to_json()
    assert j1 == j2


def test_overlay_preserves_modeled_lanes(profiled):
    """Attaching the measured track must not move a single modeled event,
    so the PR-9 invariant (nonoverlapped comm == exposed_s) survives by
    construction."""
    cfg, model, plan, prof = profiled
    bare = plan_trace(model, plan, SHAPE, arch_cfg=cfg).to_doc()
    over = plan_trace(model, plan, SHAPE, arch_cfg=cfg,
                      profile=prof).to_doc()

    def modeled(doc):
        return [e for e in doc["traceEvents"]
                if e.get("pid") == PID_MODELED]

    assert modeled(bare) == modeled(over)
    assert nonoverlapped_comm_s(bare) == nonoverlapped_comm_s(over)
    meas = [e for e in over["traceEvents"] if e.get("pid") == PID_MEASURED]
    assert meas, "no measured track emitted"
    spans = [e for e in meas if e.get("ph") == "X"]
    assert spans
    for e in spans:
        assert {"modeled_s", "measured_s", "rel_residual"} \
            <= set(e["args"]), e["name"]


# ---------------------------------------------------------------------------
# int8 on the precision lattice (quant follow-up (b))
# ---------------------------------------------------------------------------
def test_int8_lattice_vocabulary():
    for p in ("int8_ag", "int8", "int8_ef"):
        assert p in COMM_PRECISIONS
        DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                   comm_precision=p)       # accepted by validation
    assert {"int8_ag", "int8_ef"} <= set(AUTO_PRECISIONS)
    # fp8 stays ahead of int8 in the lattice: strict-< improvement keeps
    # analytic ties on fp8, so plans only move on measured rates
    assert AUTO_PRECISIONS.index("fp8_ag") < AUTO_PRECISIONS.index(
        "int8_ag")
    assert precision_codecs("int8_ag") == ("int8", None)
    assert precision_codecs("int8") == ("int8", "int8")
    assert precision_codecs("int8_ef") == ("int8", "int8")
    d = DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                   comm_precision="int8_ef")
    assert d.needs_ef
    assert not DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                          comm_precision="int8_ag").needs_ef


def _auto_nodes():
    """Planner-only setup at a comm-bound mesh (pure math, no devices):
    fsdp=256 makes wire time dominate, so 'auto' quantizes — which codec
    it picks is then decided by the quant overhead pricing."""
    _, model = get_arch("qwen3_1_7b", smoke=True)
    dcfg = DistConfig(mesh_axes=("data", "model"), mesh_shape=(256, 1),
                      comm_precision="auto")
    stats = model.block_stats(dcfg, (8, 64))
    nodes = irgraph.build_nodes(model.block_metas(dcfg), dcfg, stats)
    return nodes, dcfg


def test_auto_planner_keeps_fp8_without_measured_rates():
    from repro.core.autowrap import dp_buckets_precision

    nodes, dcfg = _auto_nodes()
    _, precs = dp_buckets_precision(nodes, dcfg)
    assert any(p != "bf16" for p in precs), "comm-bound mesh must quantize"
    # int8 prices identically to fp8 analytically (same wire bytes, same
    # default codec rate); strict-< improvement keeps the fp8 pick
    assert not any(p.startswith("int8") for p in precs)


def test_auto_planner_picks_int8_on_measured_rates():
    from repro.core.autowrap import dp_buckets_precision

    nodes, dcfg = _auto_nodes()
    prof = MeasuredProfile(quant_rates={"int8": 1e14, "fp8": 1e7})
    with calibration(prof):
        _, precs = dp_buckets_precision(nodes, dcfg)
    assert any(p.startswith("int8") for p in precs), precs
    assert not any(p.startswith("fp8") for p in precs), precs
    # restored: the analytic tie goes back to fp8
    _, precs2 = dp_buckets_precision(nodes, dcfg)
    assert not any(p.startswith("int8") for p in precs2)


# ---------------------------------------------------------------------------
# trainer hook: drift streak -> profile -> replan -> restart
# ---------------------------------------------------------------------------
def test_trainer_replan_hook_applies(tmp_path):
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    _, model = get_arch("qwen3_1_7b", smoke=True)
    tcfg = TrainerConfig(total_steps=4, ckpt_every=100, log_every=10,
                         warmup=1, ckpt_dir=str(tmp_path),
                         replan_threshold=0.5, replan_patience=2,
                         replan_apply=True, replan_profile_steps=1)
    tr = Trainer(model, DCFG1, SHAPE, AdamWConfig(lr=1e-3), tcfg)
    assert tr._modeled_step_s is not None
    tr.run()
    # the analytic promise is TPU-roofline us vs CPU-wall seconds, so the
    # |rel| streak trips on the first `replan_patience` steps
    assert len(tr.replans) >= 1
    delta = tr.replans[0]
    assert delta["step"] == tcfg.replan_patience
    assert tr.profile is not None and tr.profile.wall_step_s > 0
    assert tr.registry.counter("replan/count").value >= 1
    if delta["changed"]:
        assert delta["applied"]
        assert tr.plan.describe() == delta["after"]
        # the promise was re-anchored to the calibrated model: the loop's
        # remaining steps must not arm another replan
        rows = tr.drift.records["step_time"]
        assert abs(rows[-1]["rel"]) <= 0.5 or len(tr.replans) > 1
    # training survived the restart and ran to completion
    assert tr.registry.counter("train/steps").value == tcfg.total_steps
